"""Train a small LM end-to-end with checkpoint/restart fault tolerance.

Default: ~10M-param danube-family model, 120 steps, CPU-tractable. Scale up
with --d-model/--layers/--steps on real hardware (the production config is
`--arch h2o-danube-1.8b` without --smoke via repro.launch.train).

  PYTHONPATH=src python examples/train_lm.py
  PYTHONPATH=src python examples/train_lm.py --fail-at 40   # crash + restart
"""
import argparse
from functools import partial

import jax
import jax.numpy as jnp

from repro.data.synth import lm_batch
from repro.ft import FaultTolerantLoop, SimulatedFailure
from repro.models import transformer as tf
from repro.optim import adamw_init, adamw_update, cosine_schedule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()

    cfg = tf.LMConfig(
        name="example-lm",
        n_layers=args.layers,
        d_model=args.d_model,
        n_heads=max(args.d_model // 64, 2),
        n_kv_heads=max(args.d_model // 128, 1),
        d_ff=args.d_model * 3,
        vocab=args.vocab,
        window=args.seq,
        dtype=jnp.float32,
        remat=False,
    )
    print(f"model: {cfg.param_count() / 1e6:.1f}M params")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)

    @jax.jit
    def step(state, batch):
        params, opt = state
        loss, grads = jax.value_and_grad(partial(tf.lm_loss, cfg))(params, batch)
        lr = cosine_schedule(opt.step, args.lr, warmup=20, total=args.steps)
        params, opt, metrics = adamw_update(grads, opt, params, lr)
        metrics["loss"] = loss
        return (params, opt), metrics

    loop = FaultTolerantLoop(
        step_fn=step,
        batch_fn=lambda s: lm_batch(0, s, args.batch, args.seq, cfg.vocab),
        init_state=(params, opt),
        ckpt_dir=args.ckpt_dir,
        ckpt_every=20,
        fail_at=args.fail_at,
    )
    try:
        loop.run(args.steps)
    except SimulatedFailure as e:
        print(f"!! {e} — restarting from latest checkpoint")
        loop.maybe_restore()
        loop.run(args.steps)
    for m in loop.metrics_log:
        print({k: round(v, 4) if isinstance(v, float) else v for k, v in m.items()})
    first, last = loop.metrics_log[0]["loss"], loop.metrics_log[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f}")


if __name__ == "__main__":
    main()
