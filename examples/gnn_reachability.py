"""Oracle x GNN integration (see README "Serve architecture" for where the
engine sits): hop labels as reachability features for a GCN node classifier
on a DAG.

The oracle is built once on the workload graph; each vertex's label lengths
and top-hop ids become extra node features — the "reachability feature
channel" the framework exposes to the GNN family.

  PYTHONPATH=src python examples/gnn_reachability.py
"""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_oracle
from repro.data.synth import graph_batch_from_csr
from repro.graph.generators import layered_dag
from repro.models.gnn import gcn
from repro.optim import adamw_init, adamw_update


def main():
    g = layered_dag(600, 2.5, seed=0)
    co = build_oracle(g)
    stats = co.engine.stats()
    print(f"graph n={g.n} m={g.m}; oracle {co.total_label_size} ints")
    print(f"engine stats: epoch={stats['epoch']} backend={stats['backend']} "
          f"tier widths={stats['widths']} "
          f"quarantined rows={stats['n_quarantined']}")
    oracle, comp = co.oracle, co.comp

    d_base = 16
    batch = graph_batch_from_csr(g, d_base, seed=0, n_classes=4)
    # reachability feature channel per ORIGINAL vertex (labels live in the
    # condensation id space): [out_len, in_len, min_out_hop_rank]
    reach_feats = np.stack(
        [
            oracle.out_len[comp] / max(oracle.out_len.max(), 1),
            oracle.in_len[comp] / max(oracle.in_len.max(), 1),
            oracle.L_out[comp, 0] / g.n,
        ],
        axis=1,
    ).astype(np.float32)
    x = jnp.concatenate([batch.x, jnp.asarray(reach_feats)], axis=1)
    batch = batch._replace(x=x)
    # labels correlated with reachability depth (so the channel helps)
    from repro.graph.reach import bfs_levels

    lv = bfs_levels(g, int(np.argmax(oracle.out_len[comp])))
    y = np.clip(lv, 0, 3).astype(np.int32)
    batch = batch._replace(y=jnp.asarray(y))

    cfg = gcn.GCNConfig(n_layers=2, d_in=d_base + 3, d_hidden=32, n_classes=4)
    params = gcn.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt):
        loss, grads = jax.value_and_grad(partial(gcn.loss_fn, cfg))(params, batch)
        params, opt, _ = adamw_update(grads, opt, params, 5e-3)
        return params, opt, loss

    for s in range(60):
        params, opt, loss = step(params, opt)
        if s % 20 == 0 or s == 59:
            logits = gcn.forward(cfg, params, batch)
            acc = float((jnp.argmax(logits, -1) == batch.y).mean())
            print(f"step {s:3d} loss {float(loss):.4f} acc {acc:.3f}")


if __name__ == "__main__":
    main()
