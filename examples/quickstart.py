"""Quickstart: build a reachability oracle, answer queries, verify vs BFS.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import distribution_labeling, hierarchical_labeling
from repro.core.baselines import OnlineBFS
from repro.graph.generators import paper_dataset_analogue


def main():
    # a paper-benchmark-sized DAG (amaze analogue: n=3710, m=3600)
    g = paper_dataset_analogue("amaze")
    print(f"graph: n={g.n} m={g.m}")

    dl = distribution_labeling(g)
    print(f"Distribution-Labeling: {dl.total_label_size} label ints "
          f"({dl.total_label_size / g.n:.1f}/vertex)")

    hl = hierarchical_labeling(g, core_max=512)
    print(f"Hierarchical-Labeling: {hl.total_label_size} label ints "
          f"({hl.total_label_size / g.n:.1f}/vertex)")

    bfs = OnlineBFS(g)
    rng = np.random.default_rng(0)
    queries = rng.integers(0, g.n, size=(500, 2))
    agree = sum(
        dl.query(int(u), int(v)) == bfs.query(int(u), int(v)) == hl.query(int(u), int(v))
        for u, v in queries
    )
    print(f"oracle vs BFS agreement: {agree}/500")
    assert agree == 500

    # batched serving through the engine (prefilters + bucketed batching)
    from repro.serve import QueryEngine
    from repro.serve.prefilter import topo_levels

    engine = QueryEngine(dl, backend="auto", level=topo_levels(g))
    pred = engine.query_batch(queries.astype(np.int32))
    stats = engine.last_stats
    print(f"engine[{stats['backend']}]: {int(pred.sum())} reachable of {len(queries)} "
          f"({stats['n_prefiltered']} decided by prefilters)")


if __name__ == "__main__":
    main()
