"""Observability walkthrough: a ~5-second faulted serving run that leaves
behind a Perfetto-loadable timeline and a metrics snapshot.

  PYTHONPATH=src python examples/trace_demo.py

What it does:

  1. builds a small oracle (the build itself is traced: per-wave spans,
     stage seconds accumulate into ``build_stage_seconds_total``),
  2. drives the serving daemon open-loop with injected device stalls and
     failures — enough to expire deadlines, trip the circuit breaker, and
     exercise the host degradation rung,
  3. exports ``trace_demo.json`` (drag it into https://ui.perfetto.dev or
     chrome://tracing) and ``trace_demo_metrics.json``, then prints the
     reconciliation: registry counters == the daemon's own books.
"""
from __future__ import annotations

import json

import numpy as np

from repro.core.api import build_oracle
from repro.ft import inject
from repro.graph.generators import random_dag
from repro.obs import metrics, trace
from repro.serve.daemon import DaemonConfig
from repro.serve.openloop import run_open_loop

TRACE_OUT = "trace_demo.json"
METRICS_OUT = "trace_demo_metrics.json"


def main() -> None:
    # a clean slate, so the exported snapshot is THIS run and nothing else
    metrics.REGISTRY.reset()
    trace.TRACER.clear()

    g = random_dag(2000, 6000, seed=0)
    print(f"graph: random DAG, n={g.n} m={g.m}")
    # impl="wave": the engine builder, so the timeline gets per-wave spans
    # and the within-sweep stage seconds (the auto heuristic would pick the
    # reference builder at this size, which has no stage breakdown)
    co = build_oracle(g, impl="wave")

    # stall dispatch occurrences 3..8 by 120ms (deadlines expire behind the
    # stall) and hard-fail 10..12 (three consecutive: the breaker trips)
    plan = inject.Injector(
        {"serve.device_dispatch": list(range(10, 13))},
        latency={"serve.device_dispatch": (list(range(3, 9)), 0.12)},
    )
    report = run_open_loop(
        co, g, rate_arrivals_per_s=120.0, arrival_batch=64, duration_s=4.0,
        deadline_ms=80.0, config=DaemonConfig(deadline_ms=80.0),
        fault_plan=plan, seed=0, n_truth=200,
    )
    print(f"open-loop: sustained {report['sustained_qps']:.0f} qps, "
          f"shed_rate={report['shed_rate']:.3f}, p99={report['p99_ms']}ms, "
          f"breaker trips={report['breaker']['trips']}")

    trace.TRACER.export_chrome(TRACE_OUT, meta={"demo": "trace_demo"})
    metrics.REGISTRY.export_json(METRICS_OUT)
    n_events = len(trace.TRACER.events)
    print(f"wrote {TRACE_OUT} ({n_events} events) — open it at "
          f"https://ui.perfetto.dev")
    print(f"wrote {METRICS_OUT}")

    # the registry is the substrate under the daemon's counters, not a
    # parallel estimate: show the books reconciling
    snap = json.load(open(METRICS_OUT))
    answered = snap["daemon_requests_total"]["values"].get("event=answered", 0)
    shed = sum(snap["daemon_shed_total"]["values"].values())
    faults = sum(snap["faults_injected_total"]["values"].values())
    trips = snap["daemon_breaker_trips_total"]["values"].get("", 0)
    report_shed = sum(report["shed"].values())
    print(f"reconciliation: answered={answered} (report {report['answered']}), "
          f"shed={shed} (report {report_shed}), "
          f"breaker_trips={trips}, faults_fired={faults}")
    stage = snap["build_stage_seconds_total"]["values"]
    top = sorted(stage.items(), key=lambda kv: -kv[1])[:3]
    print("top build stages: "
          + ", ".join(f"{k.split('=', 1)[1]}={v:.3f}s" for k, v in top))
    assert answered == report["answered"] and shed == report_shed


if __name__ == "__main__":
    main()
