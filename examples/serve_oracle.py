"""End-to-end serving driver (the paper is an indexing/serving system, so
this is the paper-kind end-to-end example): build the Distribution-Labeling
index on a dataset analogue and serve 100k batched requests through the
QueryEngine with correctness checks and throughput reporting.

The default run keeps an index snapshot under ``./oracle_snapshot``: the
first invocation builds and saves it, every later invocation cold-starts
through ``persist.load_oracle`` (checksum-verified) instead of rebuilding —
delete the directory to force a fresh build.

  PYTHONPATH=src python examples/serve_oracle.py
  PYTHONPATH=src python examples/serve_oracle.py --dataset cit-Patents --scale 0.01
  PYTHONPATH=src python examples/serve_oracle.py --backend all   # sweep backends
  PYTHONPATH=src python examples/serve_oracle.py --mode daemon --rate 300 \
      --duration 3            # open-loop serving daemon (admission control,
                              # deadline shedding, circuit breaker)
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    args = sys.argv[1:]
    if not any(a.startswith("--dataset") for a in args):
        sys.argv += ["--dataset", "citeseer", "--scale", "0.02"]
    if not any(a.startswith("--n-queries") for a in args):
        sys.argv += ["--n-queries", "100000"]
    if not any(a.startswith(("--snapshot-dir", "--state-dir")) for a in args):
        # cold-start from the saved snapshot when it exists; build + save it
        # on the first run
        sys.argv += ["--snapshot-dir", "oracle_snapshot"]
    main()
