"""End-to-end serving driver (the paper is an indexing/serving system, so
this is the paper-kind end-to-end example): build the Distribution-Labeling
index on a dataset analogue and serve 100k batched requests through the
QueryEngine with correctness checks and throughput reporting.

  PYTHONPATH=src python examples/serve_oracle.py
  PYTHONPATH=src python examples/serve_oracle.py --dataset cit-Patents --scale 0.01
  PYTHONPATH=src python examples/serve_oracle.py --backend all   # sweep backends
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    if len(sys.argv) == 1:
        sys.argv += ["--dataset", "citeseer", "--scale", "0.02", "--n-queries", "100000"]
    main()
