"""Dynamic oracle walkthrough: edge updates, epochs, repair vs rebuild.

  PYTHONPATH=src python examples/dynamic_demo.py

Builds a DynamicOracle on a citation-style DAG, then walks the API:

  1. apply an update batch (inserts + deletes) — labels repair in place,
  2. publish an epoch — queries before/after see different worlds,
  3. pin an old epoch — answers stay frozen while the graph moves on,
  4. close a cycle — the SCC merge collapses condensation vertices and the
     staleness machinery routes the next publish through a full rebuild,
  5. replay an interleaved trace and print the repair-vs-rebuild economics.
"""
from __future__ import annotations

import time

import numpy as np

from repro.build.engine import build_distribution_labels
from repro.dynamic import DynamicOracle, UpdateBatch, generate_trace, replay
from repro.graph.generators import paper_dataset_analogue


def main() -> None:
    g = paper_dataset_analogue("citeseer", scale=0.02)
    print(f"graph: citeseer analogue, n={g.n} m={g.m}")
    dyn = DynamicOracle(g)
    print(f"epoch {dyn.epoch}: label ints = {dyn.total_label_size}")

    # ---- 1+2: update batch -> repair -> publish -------------------------
    rng = np.random.default_rng(0)
    # a DAG-preserving insert that actually creates reachability: orient
    # along the topological levels and prefer a not-yet-reachable pair
    lvl = dyn.level
    cand = rng.integers(0, g.n, size=(256, 2))
    pairs = [(int(a), int(b)) for a, b in cand
             if lvl[dyn.delta.comp[a]] < lvl[dyn.delta.comp[b]]]
    ins = next((p for p in pairs if not dyn.query(*p)), pairs[0])
    src, dst = g.edges()
    dele = (int(src[0]), int(dst[0]))
    before = dyn.query(*ins)
    stats = dyn.apply(UpdateBatch.of(inserts=[ins], deletes=[dele]))
    e1 = dyn.publish()
    print(f"applied 1 insert + 1 delete -> epoch {e1} "
          f"(repaired inserts={stats.repaired_inserts}, "
          f"deletes={stats.repaired_deletes}, "
          f"label appends={stats.label_appends}, drops={stats.label_drops})")
    print(f"query{ins}: {before} before, {dyn.query(*ins)} after")

    # ---- 3: epoch pinning ----------------------------------------------
    pinned = dyn.query(*ins, epoch=e1 - 1)
    print(f"pinned to epoch {e1 - 1}: query{ins} still {pinned}")

    # ---- 4: a structural event (SCC merge) ------------------------------
    # inserting the reverse of a reachable pair closes a cycle
    u, v = ins
    dyn.apply(UpdateBatch.of(inserts=[(v, u)]))
    dyn.publish()  # staleness machinery: merge -> compacting rebuild
    print(f"inserted ({v}, {u}) closing a cycle: same-SCC now "
          f"{dyn.query(v, u)} and {dyn.query(u, v)}; "
          f"rebuilds so far = {dyn.rebuild_count - 1}")

    # ---- 5: interleaved trace + the repair-vs-rebuild economics ---------
    trace = generate_trace(g, rounds=5, updates_per_round=50,
                           queries_per_round=1000, dag_preserving=True, seed=1)
    rstats = replay(dyn, trace)
    t0 = time.perf_counter()
    build_distribution_labels(dyn.delta.dag_csr())
    t_rebuild = time.perf_counter() - t0
    print(f"replayed {rstats.n_updates} updates / {rstats.n_queries} queries: "
          f"{rstats.updates_per_sec:,.0f} updates/sec repaired "
          f"(vs {50 / t_rebuild:,.0f} rebuilding per 50-update batch), "
          f"query p50 {rstats.query_pctile(0.5) * 1e3:.2f} ms/batch")
    print(f"epochs published: {rstats.epochs}; pinnable: {dyn.epochs}")


if __name__ == "__main__":
    main()
