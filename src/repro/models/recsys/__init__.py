from repro.models.recsys import xdeepfm

__all__ = ["xdeepfm"]
