"""xDeepFM (Lian et al., arXiv:1803.05170): CIN + DNN + linear.

Assigned config: 39 sparse fields, embed_dim=10, CIN 200-200-200, MLP
400-400. Embedding tables are the memory hot path (vocab rows x 10); lookup
is a one-id-per-field gather (Criteo layout) — the embedding_bag kernel
serves the multi-hot variant.

CIN layer k:  Z = X^k (outer) X^0 -> [B, H_k * m, D];  X^{k+1} = W_k Z
(1x1 conv over the H_k*m axis), sum-pool over D per layer -> logits.

retrieval_cand: one user context scored against C candidate items by
swapping field 0 (item id) per candidate — lowered as a single batched step.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class XDeepFMConfig:
    name: str = "xdeepfm"
    n_fields: int = 39
    embed_dim: int = 10
    vocab_per_field: int = 1_000_000
    cin_layers: Tuple[int, ...] = (200, 200, 200)
    mlp_layers: Tuple[int, ...] = (400, 400)
    dtype: object = jnp.float32


def init_params(cfg: XDeepFMConfig, key):
    ks = jax.random.split(key, 6 + len(cfg.cin_layers) + len(cfg.mlp_layers))
    m, D = cfg.n_fields, cfg.embed_dim
    params = {
        # one big table [n_fields * vocab, D]: row-sharded over the model axis
        "table": (jax.random.normal(ks[0], (cfg.n_fields * cfg.vocab_per_field, D)) * 0.01
                  ).astype(cfg.dtype),
        "linear": (jax.random.normal(ks[1], (cfg.n_fields * cfg.vocab_per_field,)) * 0.01
                   ).astype(cfg.dtype),
        "cin": [],
        "mlp": [],
        "bias": jnp.zeros((), cfg.dtype),
    }
    h_prev = m
    for i, h in enumerate(cfg.cin_layers):
        params["cin"].append(
            (jax.random.normal(ks[2 + i], (h, h_prev * m)) / jnp.sqrt(h_prev * m)
             ).astype(cfg.dtype)
        )
        h_prev = h
    sizes = [m * D] + list(cfg.mlp_layers) + [1]
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        params["mlp"].append(
            {
                "w": (jax.random.normal(ks[2 + len(cfg.cin_layers) + i], (a, b))
                      / jnp.sqrt(a)).astype(cfg.dtype),
                "b": jnp.zeros((b,), cfg.dtype),
            }
        )
    params["cin_out"] = (
        jax.random.normal(ks[-1], (sum(cfg.cin_layers), 1)) * 0.01
    ).astype(cfg.dtype)
    return params


def param_pspecs(cfg: XDeepFMConfig, model_axis: str = "model"):
    return {
        "table": P(model_axis, None),
        "linear": P(model_axis),
        "cin": [P(None, None) for _ in cfg.cin_layers],
        "mlp": [{"w": P(None, None), "b": P(None)} for _ in range(len(cfg.mlp_layers) + 1)],
        "cin_out": P(None, None),
        "bias": P(),
    }


def _field_ids(cfg: XDeepFMConfig, ids: jnp.ndarray) -> jnp.ndarray:
    """ids int32[B, n_fields] per-field local ids -> global table rows."""
    offs = jnp.arange(cfg.n_fields, dtype=ids.dtype) * cfg.vocab_per_field
    return ids + offs[None, :]


def _cin(cfg: XDeepFMConfig, params, x0: jnp.ndarray) -> jnp.ndarray:
    """x0: [B, m, D] -> concat sum-pooled CIN features [B, sum(H)]."""
    B, m, D = x0.shape
    xk = x0
    pooled = []
    for w in params["cin"]:
        h_prev = xk.shape[1]
        z = jnp.einsum("bhd,bmd->bhmd", xk, x0).reshape(B, h_prev * m, D)
        xk = jnp.einsum("hk,bkd->bhd", w, z)  # [B, H, D]
        xk = jax.nn.relu(xk)
        pooled.append(jnp.sum(xk, axis=-1))
    return jnp.concatenate(pooled, axis=-1)


def forward(cfg: XDeepFMConfig, params, ids: jnp.ndarray) -> jnp.ndarray:
    """ids: int32[B, n_fields] -> logits f32[B]."""
    rows = _field_ids(cfg, ids)
    emb = jnp.take(params["table"], rows, axis=0)         # [B, m, D]
    lin = jnp.take(params["linear"], rows, axis=0)        # [B, m]
    B = ids.shape[0]
    cin_feat = _cin(cfg, params, emb)
    h = emb.reshape(B, -1)
    for i, layer in enumerate(params["mlp"]):
        h = h @ layer["w"] + layer["b"]
        if i < len(params["mlp"]) - 1:
            h = jax.nn.relu(h)
    logit = (
        h[:, 0]
        + (cin_feat @ params["cin_out"])[:, 0]
        + jnp.sum(lin, axis=-1)
        + params["bias"]
    )
    return logit.astype(jnp.float32)


def loss_fn(cfg: XDeepFMConfig, params, batch) -> jnp.ndarray:
    """batch: {ids int32[B, m], y f32[B]} — BCE with logits."""
    logit = forward(cfg, params, batch["ids"])
    y = batch["y"].astype(jnp.float32)
    return jnp.mean(jnp.maximum(logit, 0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit))))


def retrieval_score(
    cfg: XDeepFMConfig,
    params,
    user_ids: jnp.ndarray,
    cand_ids: jnp.ndarray,
    chunk: int = 25_000,
):
    """Score one user context against C candidates (retrieval_cand shape).

    user_ids: int32[1, n_fields]; cand_ids: int32[C] (field-0 item ids).
    Candidate scoring re-runs the interaction stack with field 0 swapped —
    batched as [C, n_fields] ids built by broadcast, not a loop. Candidates
    stream through in `chunk`-sized slabs (lax.map) so the CIN outer-product
    intermediate [chunk, H*m, D] stays bounded (unchunked: 20.4GB/device at
    C=1M — §Perf memory fix)."""
    C = cand_ids.shape[0]
    if C <= chunk:
        ids = jnp.broadcast_to(user_ids, (C, cfg.n_fields)).at[:, 0].set(cand_ids)
        return forward(cfg, params, ids)
    n_chunks = C // chunk
    assert C % chunk == 0, (C, chunk)

    def score_chunk(cands_c):
        ids = jnp.broadcast_to(user_ids, (chunk, cfg.n_fields)).at[:, 0].set(cands_c)
        return forward(cfg, params, ids)

    out = jax.lax.map(score_chunk, cand_ids.reshape(n_chunks, chunk))
    return out.reshape(C)
