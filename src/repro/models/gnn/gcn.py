"""GCN (Kipf & Welling, arXiv:1609.02907) — gcn-cora config: 2L, d=16."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn.layers import GraphBatch, gcn_sym_coeff, segment_agg


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    name: str = "gcn-cora"
    n_layers: int = 2
    d_in: int = 1433
    d_hidden: int = 16
    n_classes: int = 7
    dtype: object = jnp.float32


def init_params(cfg: GCNConfig, key):
    sizes = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    keys = jax.random.split(key, cfg.n_layers)
    return [
        {"w": (jax.random.normal(k, (i, o)) / jnp.sqrt(i)).astype(cfg.dtype)}
        for k, i, o in zip(keys, sizes[:-1], sizes[1:])
    ]


def forward(cfg: GCNConfig, params, g: GraphBatch) -> jnp.ndarray:
    n = g.x.shape[0]
    coeff = gcn_sym_coeff(g.edge_src, g.edge_dst, g.edge_mask, n)
    x = g.x.astype(cfg.dtype)
    for i, layer in enumerate(params):
        h = x @ layer["w"]
        msg = jnp.take(h, g.edge_src, axis=0) * coeff[:, None]
        agg = segment_agg(msg, g.edge_dst, g.edge_mask, n, "sum")
        # self loop with 1/(deg+1) weight folded into sym coeff approximation
        x = agg + h
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return x  # [n, n_classes] logits


def loss_fn(cfg: GCNConfig, params, g: GraphBatch) -> jnp.ndarray:
    logits = forward(cfg, params, g)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    labels = g.y
    ll = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    mask = g.node_mask
    return -jnp.sum(jnp.where(mask, ll, 0.0)) / jnp.maximum(jnp.sum(mask), 1)
