"""GraphCast-style encoder-processor-decoder mesh GNN (arXiv:2212.12794).

Assigned config: 16 processor layers, d_hidden=512, sum aggregator,
n_vars=227, mesh_refinement=6 (-> 40962 mesh nodes on the real icosahedral
mesh; the shape cells parameterize grid size directly).

Three node/edge sets:
  grid nodes (n_g, 227 vars) --g2m--> mesh nodes (n_m) : encoder
  mesh nodes --mesh edges--> mesh nodes x16            : processor
  mesh nodes --m2g--> grid nodes                       : decoder -> 227 vars

Every block is an edge-MLP message + sum segment aggregate + node-MLP update
with residuals (MeshGraphNet recipe).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.gnn.layers import mlp_apply, mlp_init


@dataclasses.dataclass(frozen=True)
class GraphCastConfig:
    name: str = "graphcast"
    n_layers: int = 16
    d_hidden: int = 512
    n_vars: int = 227
    mesh_refinement: int = 6
    dtype: object = jnp.bfloat16


class MeshBatch(NamedTuple):
    """Static-shape weather state + mesh topology."""

    grid_x: jnp.ndarray      # f32[n_g, n_vars]
    g2m_src: jnp.ndarray     # int32[m_g2m] grid ids
    g2m_dst: jnp.ndarray     # int32[m_g2m] mesh ids
    mesh_src: jnp.ndarray    # int32[m_mesh]
    mesh_dst: jnp.ndarray    # int32[m_mesh]
    m2g_src: jnp.ndarray     # int32[m_m2g] mesh ids
    m2g_dst: jnp.ndarray     # int32[m_m2g] grid ids
    target: jnp.ndarray      # f32[n_g, n_vars]


def init_params(cfg: GraphCastConfig, key):
    d = cfg.d_hidden
    ks = jax.random.split(key, 6 + 2 * cfg.n_layers)
    params = {
        "grid_enc": mlp_init(ks[0], [cfg.n_vars, d, d], cfg.dtype),
        "g2m_edge": mlp_init(ks[1], [2 * d, d, d], cfg.dtype),
        "g2m_node": mlp_init(ks[2], [2 * d, d, d], cfg.dtype),
        "m2g_edge": mlp_init(ks[3], [2 * d, d, d], cfg.dtype),
        "m2g_node": mlp_init(ks[4], [2 * d, d, cfg.n_vars], cfg.dtype),
        "proc": [],
    }
    for l in range(cfg.n_layers):
        params["proc"].append(
            {
                "edge": mlp_init(ks[5 + 2 * l], [2 * d, d, d], cfg.dtype),
                "node": mlp_init(ks[6 + 2 * l], [2 * d, d, d], cfg.dtype),
            }
        )
    return params


def _mp(edge_mlp, node_mlp, h_src_nodes, h_dst_nodes, src, dst, n_dst):
    """One message-passing block: edge MLP on (src, dst) pairs -> sum agg ->
    node MLP on (node, agg) -> residual."""
    hs = jnp.take(h_src_nodes, src, axis=0)
    hd = jnp.take(h_dst_nodes, dst, axis=0)
    msg = mlp_apply(edge_mlp, jnp.concatenate([hs, hd], axis=-1))
    agg = jax.ops.segment_sum(msg, dst, num_segments=n_dst)
    upd = mlp_apply(node_mlp, jnp.concatenate([h_dst_nodes, agg], axis=-1))
    return h_dst_nodes + upd


def forward(cfg: GraphCastConfig, params, b: MeshBatch, n_mesh: int):
    n_g = b.grid_x.shape[0]
    h_g = mlp_apply(params["grid_enc"], b.grid_x.astype(cfg.dtype))
    h_m = jnp.zeros((n_mesh, cfg.d_hidden), cfg.dtype)
    # encoder: grid -> mesh
    h_m = _mp(params["g2m_edge"], params["g2m_node"], h_g, h_m, b.g2m_src, b.g2m_dst, n_mesh)
    # processor
    for lw in params["proc"]:
        h_m = _mp(lw["edge"], lw["node"], h_m, h_m, b.mesh_src, b.mesh_dst, n_mesh)
    # decoder: mesh -> grid (residual update in physical space)
    hs = jnp.take(h_m, b.m2g_src, axis=0)
    hd = jnp.take(h_g, b.m2g_dst, axis=0)
    msg = mlp_apply(params["m2g_edge"], jnp.concatenate([hs, hd], axis=-1))
    agg = jax.ops.segment_sum(msg, b.m2g_dst, num_segments=n_g)
    delta = mlp_apply(params["m2g_node"], jnp.concatenate([h_g, agg], axis=-1))
    return b.grid_x + delta.astype(b.grid_x.dtype)


def loss_fn(cfg: GraphCastConfig, params, b: MeshBatch, n_mesh: int):
    pred = forward(cfg, params, b, n_mesh)
    return jnp.mean((pred - b.target) ** 2)
