"""Shared GNN substrate: the GraphBatch container + segment message passing.

JAX has no sparse message-passing primitive (BCOO only) — per the assignment,
message passing IS part of the system: edge-indexed gather -> segment reduce
(jax.ops.segment_sum/max) with static shapes (padded edge lists, bool mask).

Vertices shard over the data axes at scale: a segment_sum over destination-
sharded edges lowers to local partial sums + reduce-scatter, which is exactly
the DP story the dry-run exercises.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class GraphBatch(NamedTuple):
    """Static-shape batched graph.

    x:         f32[n, f]      node features
    edge_src:  int32[m]       source node index per edge (padding -> 0)
    edge_dst:  int32[m]       destination node index per edge
    edge_mask: bool[m]
    node_mask: bool[n]
    edge_attr: f32[m, fe] | None
    pos:       f32[n, 3] | None    (SchNet)
    y:         f32/int32[...]      targets (model-specific)
    """

    x: jnp.ndarray
    edge_src: jnp.ndarray
    edge_dst: jnp.ndarray
    edge_mask: jnp.ndarray
    node_mask: jnp.ndarray
    edge_attr: Optional[jnp.ndarray] = None
    pos: Optional[jnp.ndarray] = None
    y: Optional[jnp.ndarray] = None


def segment_agg(
    messages: jnp.ndarray,      # [m, f]
    edge_dst: jnp.ndarray,      # int32[m]
    edge_mask: jnp.ndarray,     # bool[m]
    n: int,
    agg: str = "sum",
) -> jnp.ndarray:
    """Masked scatter-aggregate messages to destination nodes."""
    msg = jnp.where(edge_mask[:, None], messages, 0.0)
    if agg == "sum":
        return jax.ops.segment_sum(msg, edge_dst, num_segments=n)
    if agg == "mean":
        s = jax.ops.segment_sum(msg, edge_dst, num_segments=n)
        cnt = jax.ops.segment_sum(edge_mask.astype(msg.dtype), edge_dst, num_segments=n)
        return s / jnp.maximum(cnt, 1.0)[:, None]
    if agg == "max":
        neg = jnp.where(edge_mask[:, None], messages, -jnp.inf)
        out = jax.ops.segment_max(neg, edge_dst, num_segments=n)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(agg)


def gcn_sym_coeff(edge_src, edge_dst, edge_mask, n: int) -> jnp.ndarray:
    """Symmetric GCN normalization 1/sqrt((deg(src)+1)(deg(dst)+1)) per edge."""
    ones = edge_mask.astype(jnp.float32)
    deg_out = jax.ops.segment_sum(ones, edge_src, num_segments=n)
    deg_in = jax.ops.segment_sum(ones, edge_dst, num_segments=n)
    d_src = jnp.take(deg_out, edge_src)
    d_dst = jnp.take(deg_in, edge_dst)
    return jax.lax.rsqrt((d_src + 1.0) * (d_dst + 1.0))


def mlp_init(key, sizes, dtype=jnp.float32):
    params = []
    keys = jax.random.split(key, len(sizes) - 1)
    for k, (i, o) in zip(keys, zip(sizes[:-1], sizes[1:])):
        params.append(
            {
                "w": (jax.random.normal(k, (i, o)) / jnp.sqrt(i)).astype(dtype),
                "b": jnp.zeros((o,), dtype),
            }
        )
    return params


def mlp_apply(params, x, act=jax.nn.relu, final_act=False):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1 or final_act:
            x = act(x)
    return x
