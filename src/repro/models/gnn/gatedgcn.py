"""GatedGCN (Bresson & Laurent; benchmarking config of arXiv:2003.00982).

Per layer, with explicit edge features:
  e'_ij = A h_i + B h_j + C e_ij;      eta_ij = sigmoid(e'_ij)
  h'_i  = h_i U + ( sum_j eta_ij * (h_j V) ) / ( sum_j eta_ij + eps )
residual + LayerNorm on both node and edge streams.
Assigned config: 16 layers, d_hidden=70, gated aggregator.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn.layers import GraphBatch, segment_agg


@dataclasses.dataclass(frozen=True)
class GatedGCNConfig:
    name: str = "gatedgcn"
    n_layers: int = 16
    d_in: int = 16
    d_edge_in: int = 8
    d_hidden: int = 70
    n_classes: int = 8
    dtype: object = jnp.float32


def _lin(key, i, o, dtype):
    return (jax.random.normal(key, (i, o)) / jnp.sqrt(i)).astype(dtype)


def init_params(cfg: GatedGCNConfig, key):
    d = cfg.d_hidden
    ks = jax.random.split(key, 4 + 6 * cfg.n_layers)
    params = {
        "embed_x": _lin(ks[0], cfg.d_in, d, cfg.dtype),
        "embed_e": _lin(ks[1], cfg.d_edge_in, d, cfg.dtype),
        "readout": _lin(ks[2], d, cfg.n_classes, cfg.dtype),
        "layers": [],
    }
    for l in range(cfg.n_layers):
        k = ks[4 + 6 * l : 4 + 6 * (l + 1)]
        params["layers"].append(
            {
                "A": _lin(k[0], d, d, cfg.dtype),
                "B": _lin(k[1], d, d, cfg.dtype),
                "C": _lin(k[2], d, d, cfg.dtype),
                "U": _lin(k[3], d, d, cfg.dtype),
                "V": _lin(k[4], d, d, cfg.dtype),
                "ln_h": jnp.ones((d,), cfg.dtype),
                "ln_e": jnp.ones((d,), cfg.dtype),
            }
        )
    return params


def _norm(x, w):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * w


def forward(cfg: GatedGCNConfig, params, g: GraphBatch):
    n = g.x.shape[0]
    h = g.x.astype(cfg.dtype) @ params["embed_x"]
    e_attr = g.edge_attr if g.edge_attr is not None else jnp.zeros(
        (g.edge_src.shape[0], cfg.d_edge_in), cfg.dtype
    )
    e = e_attr.astype(cfg.dtype) @ params["embed_e"]
    for lw in params["layers"]:
        h_src = jnp.take(h, g.edge_src, axis=0)
        h_dst = jnp.take(h, g.edge_dst, axis=0)
        e_new = h_dst @ lw["A"] + h_src @ lw["B"] + e @ lw["C"]
        eta = jax.nn.sigmoid(e_new)
        num = segment_agg(eta * (h_src @ lw["V"]), g.edge_dst, g.edge_mask, n, "sum")
        den = segment_agg(eta, g.edge_dst, g.edge_mask, n, "sum")
        h_new = h @ lw["U"] + num / (den + 1e-6)
        h = h + jax.nn.relu(_norm(h_new, lw["ln_h"]))
        e = e + jax.nn.relu(_norm(e_new, lw["ln_e"]))
    return h @ params["readout"]


def loss_fn(cfg: GatedGCNConfig, params, g: GraphBatch):
    logits = forward(cfg, params, g)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, g.y[:, None], axis=-1)[:, 0]
    return -jnp.sum(jnp.where(g.node_mask, ll, 0.0)) / jnp.maximum(jnp.sum(g.node_mask), 1)


# ---------------------------------------------------------------------------
# dst-local distributed forward (hillclimbed variant, EXPERIMENTS.md §Perf)
#
# The naive SPMD lowering of segment_sum materializes a FULL dense [n, d]
# partial per device and all-reduces it (measured: 33x 2.17GB all-reduces and
# ~1.6TB/dev HBM churn on ogb_products). With the dst-local edge layout
# (graph/partition.py) each shard aggregates ONLY its own n/P destination
# rows; the single cross-shard exchange per layer is an all-gather of the
# node stream (and its reduce-scatter adjoint in backward).
# ---------------------------------------------------------------------------

def make_dstlocal_loss(cfg: GatedGCNConfig, mesh, data_axes=("data",)):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axis = data_axes[0] if len(data_axes) == 1 else data_axes
    n_shards = 1
    for a in data_axes:
        n_shards *= mesh.shape[a]

    def local_loss(params, x, e_attr, src, dst, emask, nmask, y):
        # local shards: x [n/P, d_in]; src/dst GLOBAL vertex ids [m/P]
        n_local = x.shape[0]
        idx = jax.lax.axis_index(data_axes[0]) if len(data_axes) == 1 else (
            jax.lax.axis_index(data_axes[0]) * mesh.shape[data_axes[1]]
            + jax.lax.axis_index(data_axes[1])
        )
        offset = idx * n_local
        h = x.astype(cfg.dtype) @ params["embed_x"]
        e = e_attr.astype(cfg.dtype) @ params["embed_e"]
        dst_local = jnp.clip(dst - offset, 0, n_local - 1)

        def layer(h, e, lw):
            # H8: gather/exchange the node stream in bf16 (halves AG wire
            # bytes + gather traffic); accumulate locally in model dtype
            h_full = jax.lax.all_gather(
                h.astype(jnp.bfloat16), axis, axis=0, tiled=True
            )  # [n, d] bf16
            h_src = jnp.take(h_full, src, axis=0).astype(cfg.dtype)
            h_dst = jnp.take(h_full, dst, axis=0).astype(cfg.dtype)
            e_new = h_dst @ lw["A"] + h_src @ lw["B"] + e @ lw["C"]
            eta = jax.nn.sigmoid(e_new)
            m = jnp.where(emask[:, None], eta * (h_src @ lw["V"]), 0.0)
            num = jax.ops.segment_sum(m, dst_local, num_segments=n_local)
            den = jax.ops.segment_sum(
                jnp.where(emask[:, None], eta, 0.0), dst_local, num_segments=n_local
            )
            h2 = h + jax.nn.relu(_norm(h @ lw["U"] + num / (den + 1e-6), lw["ln_h"]))
            e2 = e + jax.nn.relu(_norm(e_new, lw["ln_e"]))
            return h2, e2

        # H7 (refuted, reverted): jax.checkpoint per layer did NOT shrink
        # temp (133GB — the gather-adjoint scatter partials dominate, not the
        # saved activations) and cost +26% memory-term recompute. Next
        # iteration identified: custom gather adjoint via dst-local
        # segment_sum over incoming-edge lists. See EXPERIMENTS.md §Perf.
        for lw in params["layers"]:
            h, e = layer(h, e, lw)
        logits = h @ params["readout"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
        loss_sum = jnp.sum(jnp.where(nmask, ll, 0.0))
        cnt = jnp.sum(nmask)
        total = jax.lax.psum(loss_sum, axis)
        count = jax.lax.psum(cnt, axis)
        return -total / jnp.maximum(count, 1)

    lead = data_axes if len(data_axes) > 1 else data_axes[0]
    fn = shard_map(
        local_loss,
        mesh=mesh,
        in_specs=(
            P(),  # params replicated (spec prefix broadcasts over the pytree)
            P(lead, None), P(lead, None), P(lead), P(lead), P(lead), P(lead), P(lead),
        ),
        out_specs=P(),
        check_rep=False,
    )

    def loss(params, g: GraphBatch):
        return fn(params, g.x, g.edge_attr, g.edge_src, g.edge_dst,
                  g.edge_mask, g.node_mask, g.y)

    return loss
