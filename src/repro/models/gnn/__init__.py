from repro.models.gnn.layers import GraphBatch, segment_agg
from repro.models.gnn import gcn, gatedgcn, schnet, graphcast

__all__ = ["GraphBatch", "segment_agg", "gcn", "gatedgcn", "schnet", "graphcast"]
