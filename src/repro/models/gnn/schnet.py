"""SchNet (Schuett et al., arXiv:1706.08566) — continuous-filter conv GNN.

Assigned config: 3 interactions, d=64, 300 RBFs, cutoff 10 A.
cfconv: m_ij = x_j * W_filter(rbf(|r_i - r_j|));  x_i += MLP(sum_j m_ij).
The triplet-free SchNet regime is pairwise gather/scatter — same segment
substrate as the other GNNs, plus the radial-basis edge featurizer.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn.layers import GraphBatch, mlp_apply, mlp_init, segment_agg


@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    name: str = "schnet"
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    n_atom_types: int = 100
    dtype: object = jnp.float32


def init_params(cfg: SchNetConfig, key):
    ks = jax.random.split(key, 2 + 3 * cfg.n_interactions)
    d = cfg.d_hidden
    params = {
        "embed": (jax.random.normal(ks[0], (cfg.n_atom_types, d)) * 0.1).astype(cfg.dtype),
        "out": mlp_init(ks[1], [d, d // 2, 1], cfg.dtype),
        "interactions": [],
    }
    for i in range(cfg.n_interactions):
        k = ks[2 + 3 * i : 5 + 3 * i]
        params["interactions"].append(
            {
                "filter": mlp_init(k[0], [cfg.n_rbf, d, d], cfg.dtype),
                "w_in": mlp_init(k[1], [d, d], cfg.dtype),
                "update": mlp_init(k[2], [d, d, d], cfg.dtype),
            }
        )
    return params


def _rbf(dist: jnp.ndarray, cfg: SchNetConfig) -> jnp.ndarray:
    """Gaussian radial basis on [0, cutoff]; dist [m] -> [m, n_rbf]."""
    centers = jnp.linspace(0.0, cfg.cutoff, cfg.n_rbf)
    gamma = 10.0 / cfg.cutoff
    return jnp.exp(-gamma * (dist[:, None] - centers[None, :]) ** 2)


def _ssp(x):  # shifted softplus, SchNet's activation
    return jax.nn.softplus(x) - jnp.log(2.0)


def forward(cfg: SchNetConfig, params, g: GraphBatch):
    """g.x holds integer atom types in column 0; g.pos holds coordinates.
    Returns per-graph (segment 0) energy scalar per node summed later."""
    n = g.x.shape[0]
    z = g.x[:, 0].astype(jnp.int32)
    x = jnp.take(params["embed"], jnp.clip(z, 0, cfg.n_atom_types - 1), axis=0)
    ri = jnp.take(g.pos, g.edge_dst, axis=0)
    rj = jnp.take(g.pos, g.edge_src, axis=0)
    dist = jnp.sqrt(jnp.sum((ri - rj) ** 2, axis=-1) + 1e-12)
    rbf = _rbf(dist, cfg).astype(cfg.dtype)
    # cosine cutoff envelope
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(dist / cfg.cutoff, 0, 1)) + 1.0)
    for iw in params["interactions"]:
        w_f = mlp_apply(iw["filter"], rbf, act=_ssp) * env[:, None].astype(cfg.dtype)
        h = mlp_apply(iw["w_in"], x)
        msg = jnp.take(h, g.edge_src, axis=0) * w_f
        agg = segment_agg(msg, g.edge_dst, g.edge_mask, n, "sum")
        x = x + mlp_apply(iw["update"], agg, act=_ssp)
    e_atom = mlp_apply(params["out"], x, act=_ssp)  # [n, 1]
    return jnp.where(g.node_mask[:, None], e_atom, 0.0)


def loss_fn(cfg: SchNetConfig, params, g: GraphBatch):
    """Energy regression: per-node energies sum to the target scalar(s)."""
    e_atom = forward(cfg, params, g)
    total = jnp.sum(e_atom)
    target = jnp.sum(g.y) if g.y is not None else 0.0
    return (total - target) ** 2 / jnp.maximum(jnp.sum(g.node_mask), 1)
