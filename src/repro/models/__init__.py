"""Assigned-architecture model zoo (pure-functional JAX).

transformer.py : decoder LMs (dense GQA/SWA, MoE, MLA) — 5 LM archs
gnn/           : GCN, GatedGCN, SchNet, GraphCast — 4 GNN archs
recsys/        : xDeepFM — 1 recsys arch
"""
