"""Decoder-only transformer LM family (pure functional JAX).

Covers all five assigned LM architectures through one config:
  * dense GQA (granite-3-2b, deepseek-7b)
  * GQA + sliding-window attention (h2o-danube-1.8b)
  * MoE with shared experts (granite-moe-1b-a400m, deepseek-v2-lite-16b)
  * MLA multi-head latent attention with compressed KV cache
    (deepseek-v2-lite-16b)

Design:
  * params are a pytree of jnp arrays; layer weights are stacked [L, ...]
    and the layer stack runs under jax.lax.scan (bounds HLO size and compile
    time at 24-40 layers) with optional jax.checkpoint remat.
  * sharding is expressed as a parallel pytree of PartitionSpec from
    param_pspecs() (Megatron TP layout) + with_sharding_constraint hooks on
    activations (sequence sharding on residuals); launch/ wires the mesh.
  * decode path keeps a KV cache: [B, Hkv, T, Dh] for GQA, or the MLA
    compressed cache [B, T, kv_lora + rope_dim].
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    group_size: int = 1024  # tokens per dispatch group (GShard grouping):
    #                         keeps the [G, Tg, E, cap] dispatch tensor linear
    #                         in T instead of quadratic


@dataclasses.dataclass(frozen=True)
class MLACfg:
    kv_lora: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0  # 0 -> d_model // n_heads
    rope_theta: float = 10000.0
    window: Optional[int] = None          # sliding-window attention
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    norm_eps: float = 1e-5
    dtype: Any = jnp.bfloat16
    remat: bool = True
    attn_impl: str = "naive"   # naive | chunked | chunked_skip
    chunk_q: int = 512
    chunk_k: int = 1024
    logical_batch_axes: Tuple[str, ...] = ("pod", "data")

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    def param_count(self) -> int:
        """Total parameters (for 6ND model-FLOPs accounting)."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        hd = self.head_dim
        if self.mla is not None:
            m = self.mla
            qk = m.qk_nope_dim + m.qk_rope_dim
            attn = (
                d * self.n_heads * qk                       # q proj
                + d * (m.kv_lora + m.qk_rope_dim)           # compressed kv + shared rope
                + m.kv_lora * self.n_heads * (m.qk_nope_dim + m.v_dim)
                + self.n_heads * m.v_dim * d                # o proj
            )
        else:
            attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if self.moe is not None:
            mo = self.moe
            ffn = mo.n_experts * 3 * d * mo.d_ff_expert + d * mo.n_experts
            ffn += mo.n_shared * 3 * d * mo.d_ff_shared
        else:
            ffn = 3 * d * self.d_ff
        per_layer = attn + ffn + 2 * d
        return L * per_layer + V * d + d  # embed (tied logits) + final norm

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        mo = self.moe
        full = self.param_count()
        all_experts = L * mo.n_experts * 3 * d * mo.d_ff_expert
        active = L * mo.top_k * 3 * d * mo.d_ff_expert
        return full - all_experts + active


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _dense(key, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    s = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape) * s).astype(dtype)


def init_params(cfg: LMConfig, key: jax.Array) -> Dict[str, Any]:
    d, hd = cfg.d_model, cfg.head_dim
    keys = jax.random.split(key, 2)
    L = cfg.n_layers
    dt = cfg.dtype
    _counter = [0]

    def stack(fn):
        """init one leaf per layer, stacked on axis 0 (fresh keys per leaf)."""
        _counter[0] += 1
        ks = jax.random.split(jax.random.fold_in(keys[0], _counter[0]), L)
        return jax.vmap(fn)(ks)

    layer: Dict[str, Any] = {}
    if cfg.mla is None:
        layer["wq"] = stack(lambda k: _dense(k, (d, cfg.n_heads * hd), dt))
        layer["wk"] = stack(lambda k: _dense(k, (d, cfg.n_kv_heads * hd), dt))
        layer["wv"] = stack(lambda k: _dense(k, (d, cfg.n_kv_heads * hd), dt))
        layer["wo"] = stack(lambda k: _dense(k, (cfg.n_heads * hd, d), dt))
    else:
        m = cfg.mla
        qk = m.qk_nope_dim + m.qk_rope_dim
        layer["wq"] = stack(lambda k: _dense(k, (d, cfg.n_heads * qk), dt))
        layer["w_dkv"] = stack(lambda k: _dense(k, (d, m.kv_lora), dt))
        layer["w_krope"] = stack(lambda k: _dense(k, (d, m.qk_rope_dim), dt))
        layer["w_uk"] = stack(lambda k: _dense(k, (m.kv_lora, cfg.n_heads * m.qk_nope_dim), dt))
        layer["w_uv"] = stack(lambda k: _dense(k, (m.kv_lora, cfg.n_heads * m.v_dim), dt))
        layer["wo"] = stack(lambda k: _dense(k, (cfg.n_heads * m.v_dim, d), dt))

    if cfg.moe is None:
        layer["w_in"] = stack(lambda k: _dense(k, (d, cfg.d_ff), dt))
        layer["w_gate"] = stack(lambda k: _dense(k, (d, cfg.d_ff), dt))
        layer["w_out"] = stack(lambda k: _dense(k, (cfg.d_ff, d), dt))
    else:
        mo = cfg.moe
        layer["router"] = stack(lambda k: _dense(k, (d, mo.n_experts), jnp.float32))
        layer["e_in"] = stack(lambda k: _dense(k, (mo.n_experts, d, mo.d_ff_expert), dt))
        layer["e_gate"] = stack(lambda k: _dense(k, (mo.n_experts, d, mo.d_ff_expert), dt))
        layer["e_out"] = stack(lambda k: _dense(k, (mo.n_experts, mo.d_ff_expert, d), dt))
        if mo.n_shared:
            dsh = mo.d_ff_shared or mo.d_ff_expert
            layer["s_in"] = stack(lambda k: _dense(k, (d, mo.n_shared * dsh), dt))
            layer["s_gate"] = stack(lambda k: _dense(k, (d, mo.n_shared * dsh), dt))
            layer["s_out"] = stack(lambda k: _dense(k, (mo.n_shared * dsh, d), dt))

    layer["ln1"] = jnp.ones((L, d), dtype=jnp.float32)
    layer["ln2"] = jnp.ones((L, d), dtype=jnp.float32)

    return {
        "embed": _dense(keys[1], (cfg.vocab, d), dt, scale=0.02),
        "final_ln": jnp.ones((d,), dtype=jnp.float32),
        "layers": layer,
    }


def param_pspecs(cfg: LMConfig, model_axis: str = "model") -> Dict[str, Any]:
    """Megatron TP layout: column-shard in-projections, row-shard
    out-projections; experts sharded over the model axis (EP); embedding
    vocab-sharded."""
    M = model_axis
    layer: Dict[str, Any] = {}
    if cfg.mla is None:
        layer["wq"] = P(None, None, M)
        layer["wk"] = P(None, None, M)
        layer["wv"] = P(None, None, M)
        layer["wo"] = P(None, M, None)
    else:
        layer["wq"] = P(None, None, M)
        layer["w_dkv"] = P(None, None, None)   # latent projection replicated
        layer["w_krope"] = P(None, None, None)
        layer["w_uk"] = P(None, None, M)
        layer["w_uv"] = P(None, None, M)
        layer["wo"] = P(None, M, None)
    if cfg.moe is None:
        layer["w_in"] = P(None, None, M)
        layer["w_gate"] = P(None, None, M)
        layer["w_out"] = P(None, M, None)
    else:
        layer["router"] = P(None, None, None)
        layer["e_in"] = P(None, M, None, None)    # EP: experts over model axis
        layer["e_gate"] = P(None, M, None, None)
        layer["e_out"] = P(None, M, None, None)
        if cfg.moe.n_shared:
            layer["s_in"] = P(None, None, M)
            layer["s_gate"] = P(None, None, M)
            layer["s_out"] = P(None, M, None)
    layer["ln1"] = P(None, None)
    layer["ln2"] = P(None, None)
    return {
        "embed": P(M, None),
        "final_ln": P(None),
        "layers": layer,
    }


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * w).astype(x.dtype)


def rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, D] rotary over last dim; pos: [S] absolute positions."""
    D = x.shape[-1]
    half = D // 2
    freqs = jnp.exp(-np.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos[:, None].astype(jnp.float32) * freqs[None, :]  # [S, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return rotated.astype(x.dtype)


def _attention_scores(
    q, k, v, *, causal: bool, window: Optional[int], t_total: int,
    impl: str = "naive", chunk_q: int = 512, chunk_k: int = 1024,
):
    """q: [B, Hq, S, Dh], k/v: [B, Hkv, T, Dh] -> [B, Hq, S, Dh].
    Right-aligned positions (decode: S==1, T==cache).

    impl='naive'        materializes [.., S, T] logits — fine for short S.
    impl='chunked'      flash-style online softmax over (q, k) chunks; HBM
                        footprint O(S*chunk_k) instead of O(S*T). This is the
                        XLA mirror of kernels/flash_attention (the TPU dry-run
                        path; the Pallas kernel is the hardware hot path).
    impl='chunked_skip' chunked + static skip of fully-masked k chunks
                        (causal upper triangle / outside the SWA window):
                        halves causal FLOPs, bounds SWA cost by the window.
    """
    B, Hq, S, Dh = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    q = q.reshape(B, Hkv, rep, S, Dh)
    scale = 1.0 / np.sqrt(Dh)
    offset = t_total - S

    if impl == "naive":
        logits = jnp.einsum("bkrsd,bktd->bkrst", q, k).astype(jnp.float32) * scale
        qpos = jnp.arange(S) + offset
        kpos = jnp.arange(T)
        mask = jnp.ones((S, T), dtype=bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > (qpos[:, None] - window)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        out = jnp.einsum("bkrst,bktd->bkrsd", probs, v)
        return out.reshape(B, Hq, S, v.shape[-1])

    cq = min(chunk_q, S)
    ck = min(chunk_k, T)
    n_q, n_k = S // cq, T // ck
    assert S % cq == 0 and T % ck == 0, (S, T, cq, ck)
    Dv = v.shape[-1]

    def q_chunk(qi: int, q_blk):
        """online softmax across this q chunk's k range."""
        q_lo = qi * cq + offset
        q_hi = q_lo + cq - 1
        if impl == "chunked_skip":
            k_hi = n_k if not causal else min(n_k, (q_hi // ck) + 1)
            k_lo = 0 if window is None else max(0, (q_lo - window + 1) // ck)
        else:
            k_lo, k_hi = 0, n_k
        m = jnp.full((B, Hkv, rep, cq, 1), -1e30, jnp.float32)
        l = jnp.zeros((B, Hkv, rep, cq, 1), jnp.float32)
        acc = jnp.zeros((B, Hkv, rep, cq, Dv), jnp.float32)

        def k_step(carry, ki):
            m, l, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, ki * ck, ck, axis=2)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ki * ck, ck, axis=2)
            logits = jnp.einsum("bkrsd,bktd->bkrst", q_blk, k_blk).astype(jnp.float32) * scale
            qpos = jnp.arange(cq) + q_lo
            kpos = jnp.arange(ck) + ki * ck
            mask = jnp.ones((cq, ck), dtype=bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= kpos[None, :] > (qpos[:, None] - window)
            logits = jnp.where(mask[None, None, None], logits, -1e30)
            m_cur = jnp.max(logits, axis=-1, keepdims=True)
            m_new = jnp.maximum(m, m_cur)
            dead = m_new <= -1e29
            p = jnp.exp(logits - jnp.where(dead, 0.0, m_new))
            p = jnp.where(mask[None, None, None], p, 0.0)
            alpha = jnp.where(m <= -1e29, 0.0, jnp.exp(m - jnp.where(dead, 0.0, m_new)))
            l_new = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum("bkrst,bktd->bkrsd", p.astype(v.dtype), v_blk)
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(
            k_step, (m, l, acc), jnp.arange(k_lo, k_hi)
        )
        return acc / jnp.maximum(l, 1e-30)

    outs = []
    for qi in range(n_q):
        q_blk = jax.lax.dynamic_slice_in_dim(q, qi * cq, cq, axis=3)
        outs.append(q_chunk(qi, q_blk))
    out = jnp.concatenate(outs, axis=3).astype(v.dtype)
    return out.reshape(B, Hq, S, Dv)


def _moe_ffn(x, lw, cfg: LMConfig):
    """Grouped capacity-based one-hot dispatch MoE (GShard-style; EP over the
    model axis). x: [B, S, d] -> [B, S, d] plus aux load-balance loss.

    Tokens are split into dispatch groups of `group_size`; each group routes
    independently with capacity ceil(Tg * k / E * cf), so the dispatch tensor
    [G, Tg, E, cap] grows linearly with token count."""
    mo = cfg.moe
    B, S, d = x.shape
    T = B * S
    g_sz = min(mo.group_size, T)
    assert T % g_sz == 0, (T, g_sz)
    G = T // g_sz
    E, K = mo.n_experts, mo.top_k
    cap = int(np.ceil(g_sz * K / E * mo.capacity_factor))

    xt = x.reshape(G, g_sz, d)
    logits = (xt.astype(jnp.float32) @ lw["router"].astype(jnp.float32))  # [G, Tg, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)  # [G, Tg, K]
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)          # [G, Tg, K, E]
    flat = onehot.reshape(G, g_sz * K, E)
    pos = jnp.cumsum(flat, axis=1) * flat - 1                      # [G, Tg*K, E]
    pos = pos.reshape(G, g_sz, K, E)
    pos_tk = jnp.take_along_axis(pos, gate_idx[..., None], axis=3)[..., 0]   # [G, Tg, K]
    within = (pos_tk >= 0) & (pos_tk < cap)
    safe_pos = jnp.clip(pos_tk, 0, cap - 1)

    disp = jnp.zeros((G, g_sz, E, cap), dtype=x.dtype)
    gidx = jnp.arange(G)[:, None, None] * jnp.ones((1, g_sz, K), jnp.int32)
    tidx = jnp.arange(g_sz)[None, :, None] * jnp.ones((G, 1, K), jnp.int32)
    disp = disp.at[
        gidx.reshape(-1), tidx.reshape(-1), gate_idx.reshape(-1), safe_pos.reshape(-1)
    ].max(within.astype(x.dtype).reshape(-1))

    # expert compute (e sharded over the model axis = EP)
    xs = jnp.einsum("gtec,gtd->gecd", disp, xt)
    h = jnp.einsum("gecd,edf->gecf", xs, lw["e_in"])
    g = jnp.einsum("gecd,edf->gecf", xs, lw["e_gate"])
    h = jax.nn.silu(g) * h
    ys = jnp.einsum("gecf,efd->gecd", h, lw["e_out"])  # [G, E, cap, d]

    gate_per_slot = jnp.einsum("gtk,gtke->gte", gate_vals, onehot.astype(gate_vals.dtype))
    comb = disp * gate_per_slot[..., None].astype(x.dtype)
    out = jnp.einsum("gtec,gecd->gtd", comb, ys)

    if mo.n_shared:
        hs = jax.nn.silu(xt @ lw["s_gate"]) * (xt @ lw["s_in"])
        out = out + hs @ lw["s_out"]

    # load-balance aux loss (Switch style)
    density = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    router_prob = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(density * router_prob)
    return out.reshape(B, S, d), aux


def _dense_ffn(x, lw):
    h = jax.nn.silu(x @ lw["w_gate"]) * (x @ lw["w_in"])
    return h @ lw["w_out"]


def _layer(cfg: LMConfig, lw, x, pos):
    """One transformer block (training path, full sequence)."""
    B, S, d = x.shape
    hd = cfg.head_dim
    h = rms_norm(x, lw["ln1"], cfg.norm_eps)
    if cfg.mla is None:
        q = (h @ lw["wq"]).reshape(B, S, cfg.n_heads, hd).transpose(0, 2, 1, 3)
        k = (h @ lw["wk"]).reshape(B, S, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
        v = (h @ lw["wv"]).reshape(B, S, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
        attn = _attention_scores(
            q, k, v, causal=True, window=cfg.window, t_total=S,
            impl=cfg.attn_impl, chunk_q=cfg.chunk_q, chunk_k=cfg.chunk_k,
        )
        attn = attn.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * hd)
    else:
        m = cfg.mla
        qk = m.qk_nope_dim + m.qk_rope_dim
        q = (h @ lw["wq"]).reshape(B, S, cfg.n_heads, qk).transpose(0, 2, 1, 3)
        q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]
        q_rope = rope(q_rope, pos, cfg.rope_theta)
        c_kv = h @ lw["w_dkv"]                                     # [B, S, kv_lora]
        k_rope = rope(
            (h @ lw["w_krope"])[:, None, :, :], pos, cfg.rope_theta
        )                                                          # [B, 1, S, rope]
        k_nope = (c_kv @ lw["w_uk"]).reshape(B, S, cfg.n_heads, m.qk_nope_dim).transpose(0, 2, 1, 3)
        vproj = (c_kv @ lw["w_uv"]).reshape(B, S, cfg.n_heads, m.v_dim).transpose(0, 2, 1, 3)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (B, cfg.n_heads, S, m.qk_rope_dim))], axis=-1
        )
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        attn = _attention_scores(
            q_full, k_full, vproj, causal=True, window=cfg.window, t_total=S,
            impl=cfg.attn_impl, chunk_q=cfg.chunk_q, chunk_k=cfg.chunk_k,
        )
        attn = attn.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * m.v_dim)
    x = x + attn @ lw["wo"]

    h = rms_norm(x, lw["ln2"], cfg.norm_eps)
    if cfg.moe is None:
        x = x + _dense_ffn(h, lw)
        aux = jnp.zeros((), jnp.float32)
    else:
        y, aux = _moe_ffn(h, lw, cfg)
        x = x + y
    return x, aux


def forward(cfg: LMConfig, params, tokens: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens: int32[B, S] -> (logits f32[B, S, V], aux_loss)."""
    B, S = tokens.shape
    x = params["embed"][tokens]  # [B, S, d]
    pos = jnp.arange(S)

    def body(carry, lw):
        x = carry
        fn = _layer
        if cfg.remat:
            fn = jax.checkpoint(_layer, static_argnums=(0,))
        x, aux = fn(cfg, lw, x, pos)
        return x, aux

    x, auxes = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = (x @ params["embed"].T).astype(jnp.float32)
    return logits, jnp.sum(auxes)


def lm_loss(cfg: LMConfig, params, batch) -> jnp.ndarray:
    """batch: {tokens int32[B, S], labels int32[B, S]} next-token CE."""
    logits, aux = forward(cfg, params, batch["tokens"])
    V = logits.shape[-1]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)[..., 0]
    mask = batch["labels"] >= 0
    ce = -jnp.sum(jnp.where(mask, ll, 0.0)) / jnp.maximum(jnp.sum(mask), 1)
    return ce + 0.01 * aux


# ---------------------------------------------------------------------------
# decode / serve path
# ---------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, max_len: int) -> Dict[str, jnp.ndarray]:
    hd = cfg.head_dim
    if cfg.mla is None:
        return {
            "k": jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, max_len, hd), cfg.dtype),
            "v": jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, max_len, hd), cfg.dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((cfg.n_layers, batch, max_len, m.kv_lora), cfg.dtype),
        "k_rope": jnp.zeros((cfg.n_layers, batch, max_len, m.qk_rope_dim), cfg.dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def cache_pspecs(cfg: LMConfig, model_axis: str = "model", data_axes=("pod", "data")):
    if cfg.mla is None:
        return {
            "k": P(None, data_axes, model_axis, None, None),
            "v": P(None, data_axes, model_axis, None, None),
            "pos": P(),
        }
    return {
        "c_kv": P(None, data_axes, None, None),
        "k_rope": P(None, data_axes, None, None),
        "pos": P(),
    }


def _decode_layer(cfg: LMConfig, lw, x, cache_l, pos_scalar, t_total: int):
    """One block for a single new token. x: [B, 1, d]."""
    B = x.shape[0]
    hd = cfg.head_dim
    h = rms_norm(x, lw["ln1"], cfg.norm_eps)
    pos = pos_scalar[None]
    if cfg.mla is None:
        q = (h @ lw["wq"]).reshape(B, 1, cfg.n_heads, hd).transpose(0, 2, 1, 3)
        k_new = (h @ lw["wk"]).reshape(B, 1, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
        v_new = (h @ lw["wv"]).reshape(B, 1, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
        q = rope(q, pos, cfg.rope_theta)
        k_new = rope(k_new, pos, cfg.rope_theta)
        k = jax.lax.dynamic_update_slice(cache_l["k"], k_new, (0, 0, pos_scalar, 0))
        v = jax.lax.dynamic_update_slice(cache_l["v"], v_new, (0, 0, pos_scalar, 0))
        if cfg.window is not None and cfg.window < t_total:
            # SWA: only the last `window` cache entries participate (sub-
            # quadratic long-context decode; the ring indexing keeps the
            # attention cost O(window))
            start = jnp.maximum(pos_scalar - cfg.window + 1, 0)
            kw = jax.lax.dynamic_slice(
                k, (0, 0, start, 0), (B, cfg.n_kv_heads, cfg.window, hd)
            )
            vw = jax.lax.dynamic_slice(
                v, (0, 0, start, 0), (B, cfg.n_kv_heads, cfg.window, hd)
            )
            valid = jnp.arange(cfg.window) <= (pos_scalar - start)
            attn = _masked_decode_attn(q, kw, vw, valid)
        else:
            valid = jnp.arange(k.shape[2]) <= pos_scalar
            attn = _masked_decode_attn(q, k, v, valid)
        attn = attn.transpose(0, 2, 1, 3).reshape(B, 1, cfg.n_heads * hd)
        new_cache = {"k": k, "v": v}
    else:
        m = cfg.mla
        qk = m.qk_nope_dim + m.qk_rope_dim
        q = (h @ lw["wq"]).reshape(B, 1, cfg.n_heads, qk).transpose(0, 2, 1, 3)
        q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]
        q_rope = rope(q_rope, pos, cfg.rope_theta)
        c_new = h @ lw["w_dkv"]                             # [B, 1, kv_lora]
        kr_new = rope((h @ lw["w_krope"]), pos, cfg.rope_theta)
        c_kv = jax.lax.dynamic_update_slice(cache_l["c_kv"], c_new, (0, pos_scalar, 0))
        k_rope = jax.lax.dynamic_update_slice(cache_l["k_rope"], kr_new, (0, pos_scalar, 0))
        # latent-space attention (absorbed projections): score = q_nope^T W_uk c
        # fold W_uk into q: q_lat [B, H, 1, kv_lora]
        w_uk = lw["w_uk"].reshape(m.kv_lora, cfg.n_heads, m.qk_nope_dim)
        q_lat = jnp.einsum("bhsd,khd->bhsk", q_nope, w_uk)
        logits = jnp.einsum("bhsk,btk->bhst", q_lat.astype(jnp.float32), c_kv.astype(jnp.float32))
        logits += jnp.einsum(
            "bhsd,btd->bhst", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32)
        )
        logits *= 1.0 / np.sqrt(m.qk_nope_dim + m.qk_rope_dim)
        valid = jnp.arange(c_kv.shape[1]) <= pos_scalar
        logits = jnp.where(valid[None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        ctx = jnp.einsum("bhst,btk->bhsk", probs, c_kv.astype(jnp.float32))  # latent ctx
        w_uv = lw["w_uv"].reshape(m.kv_lora, cfg.n_heads, m.v_dim)
        attn = jnp.einsum("bhsk,khd->bhsd", ctx, w_uv).astype(x.dtype)
        attn = attn.transpose(0, 2, 1, 3).reshape(B, 1, cfg.n_heads * m.v_dim)
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}
    x = x + attn @ lw["wo"]
    h = rms_norm(x, lw["ln2"], cfg.norm_eps)
    if cfg.moe is None:
        x = x + _dense_ffn(h, lw)
    else:
        y, _ = _moe_ffn(h, lw, cfg)
        x = x + y
    return x, new_cache


def _masked_decode_attn(q, k, v, valid):
    """q: [B, Hq, 1, D], k/v: [B, Hkv, T, D], valid: bool[T]."""
    B, Hq, S, Dh = q.shape
    Hkv = k.shape[1]
    rep = Hq // Hkv
    q = q.reshape(B, Hkv, rep, S, Dh)
    logits = jnp.einsum("bkrsd,bktd->bkrst", q, k).astype(jnp.float32) / np.sqrt(Dh)
    logits = jnp.where(valid[None, None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkrst,bktd->bkrsd", probs, v)
    return out.reshape(B, Hq, S, Dh)


def decode_step(cfg: LMConfig, params, cache, tokens: jnp.ndarray):
    """One-token decode. tokens: int32[B, 1]. Returns (logits [B, 1, V], cache)."""
    B = tokens.shape[0]
    x = params["embed"][tokens]
    pos_scalar = cache["pos"]
    t_total = cache["k"].shape[3] if cfg.mla is None else cache["c_kv"].shape[2]

    def body(x, inputs):
        lw, cache_l = inputs
        fn = _decode_layer
        x, new_c = fn(cfg, lw, x, cache_l, pos_scalar, t_total)
        return x, new_c

    layer_caches = {k: v for k, v in cache.items() if k != "pos"}
    x, new_caches = jax.lax.scan(body, x, (params["layers"], layer_caches))
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = (x @ params["embed"].T).astype(jnp.float32)
    new_cache = dict(new_caches)
    new_cache["pos"] = pos_scalar + 1
    return logits, new_cache


def prefill(cfg: LMConfig, params, tokens: jnp.ndarray) -> jnp.ndarray:
    """Prefill = full forward over the prompt; returns last-position logits.
    (The dry-run lowers this as the prefill serve step.)"""
    logits, _ = forward(cfg, params, tokens)
    return logits[:, -1:, :]
