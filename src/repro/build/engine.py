"""Distribution-Labeling construction engine (paper §5, Algorithm 2).

Two host implementations of the same algorithm, differentially tested to be
byte-identical:

``impl="reference"``
    The seed scalar path: per-vertex pruned BFS with python sets + deque
    (via the shared ``traverse.pruned_bfs_distribute`` helper).  Kept as the
    ground-truth implementation.

``impl="wave"``
    The bit-parallel engine.  The §5.2 rank order is partitioned into
    *waves* of mutually unreachable vertices (``waves.wave_schedule``); each
    wave's up-to-256 pruned BFS sweeps run as ONE batched level-synchronous
    sweep over packed uint64 member masks:

      * frontier / visited state: uint64[n, K] — bit j = "wave member j",
      * prune test: ``hop_mask`` maps hop rank h -> mask of members whose
        source label contains h, so Algorithm 2's per-vertex set probe
        ``L_out(u) ∩ L_in(v_i) != ∅`` becomes one ragged gather of u's
        label entries plus a word-wide OR-reduce — no per-element set
        operations,
      * label append: grouped vectorized writes into ``_LabelStore`` (dense
        int32 head rows + side lists for the rare deep rows, so a handful
        of hub labels never force full-matrix growth copies).

    Why waves are exact: within a wave no member reaches another, so no
    member's append can appear in another member's prune source set (v_i in
    L_in(v_j) would require v_i -> v_j), and intra-wave ranks cannot occur
    in any wave-start label.  Hence every prune verdict equals the one the
    sequential loop would produce, and label *sets* match exactly; rows are
    sorted once at the end, giving byte-identical finalized labels.

``impl="device"``
    The sparse device wave engine (``engine_jax.py``): the same wave
    schedule, with the intra-wave sweep running on the accelerator through
    the packed-frontier ELL expansion kernel and an on-device segment-
    scatter label append.  Byte-identical to both host paths.

``impl="speculative"``
    The optimistic path for dense-reachability families (citeseerx /
    cit-Patents analogues), where true conflicts occur every ~1-2
    consecutive ranks and exact waves cannot amortize anything.  The
    scheduler (``waves.speculative_schedule``) emits rank-consecutive
    chunks WITHOUT proving mutual unreachability; the engine runs the same
    fused bitset sweep for the whole chunk, then a *certification pass*
    (word-level primitives in ``bitset.py``) detects prune-order
    violations — members whose pruned BFS should have seen a lower-ranked
    wave-mate's freshly distributed hops.  Violated members are rolled
    back in the ``_LabelStore`` (append-only rows make truncation-by-
    watermark cheap) and replayed scalar in rank order against the live
    store with rank-restricted prune sets — exactly the sequential §5.2
    semantics — so the finalized labels stay byte-identical to the
    reference builder (Theorem 4 non-redundancy preserved).  Chunk size
    adapts to the observed violation rate (bounded optimism), and a
    worst-case bailout degenerates to the scalar loop when speculation
    keeps losing.

``impl="auto"`` (default) picks "reference" for small graphs — the batched
sweeps only pay off once there are enough vertices to amortize them.
Otherwise one cheap optimistic schedule doubles as the profitability
probe: a fully-exact partition routes to "device" when an accelerator is
attached (jax backend != cpu) and "wave" otherwise; a partition with any
optimistic chunks routes to "speculative" (these graphs previously fell
back to the scalar reference — the dense-reachability wall).

Every oracle built here carries a ``build_stats`` breadcrumb:
``{"impl", "scheduler", "schedule_seconds", "sweep_seconds", "n_waves"}`` —
the scheduler-cost breakdown BENCH_build.json tracks (the ROADMAP's
"scheduler is 20-40% of wave builds" claim, measured per build) — plus a
``"speculation"`` sub-dict (waves attempted, violation rate, replayed
members, replay seconds) when the speculative engine ran.
"""
from __future__ import annotations

import os
import re
import shutil
import time
import warnings
import zlib
from typing import Dict, List, Optional

import numpy as np

from repro.build import bitset
from repro.ft import inject
from repro.obs import metrics, trace
from repro.obs.state import ON
# cone_resume_sweep is the engine's cone-scoped construction entry point
# (repro.dynamic repairs labels through it); it lives in traverse.py beside
# the sibling scalar sweep it generalizes
from repro.build.traverse import cone_resume_sweep, pruned_bfs_distribute  # noqa: F401
from repro.build.waves import speculative_schedule, wave_schedule
from repro.core.oracle import ReachabilityOracle, finalize_labels
from repro.core.order import get_order
from repro.graph.csr import CSRGraph, INVALID

_PAD_MULTIPLE = 8
# below this vertex count the scalar reference path wins (numpy dispatch
# overhead dominates the batched sweeps)
_AUTO_WAVE_MIN = 4096
# impl="auto" falls back to the reference builder when the schedule's mean
# wave is smaller than this — per-wave overhead would dominate
_AUTO_MIN_AVG_WAVE = 24.0
# impl="auto" routes straight to the speculative engine when the sampled
# mean forward-cone covers at least this fraction of the graph: the paper's
# dense-reachability families sit two orders of magnitude above the
# tree/sparse families (0.13-0.17 vs <= 1e-4 on the bench grid), and on the
# dense side even PROBING the exact scheduler is expensive (page closures
# span huge cones)
_AUTO_DENSE_REACH = 0.02
# speculative chunks cap at one uint64 word of members, so every mask op in
# the optimistic sweep (prune gather, certify, cleanup) runs on flat
# single-word arrays
_SPEC_CAP = 64

# Registry families for construction progress.  Stage attribution also lands
# in ``build_stats["stages"]`` / ``["stage_shares"]`` (the BENCH-gated view);
# the registry mirror exists so a long-running build is observable live
# through the same snapshot surface as the daemon.
_M_WAVES = metrics.counter(
    "build_waves_total", "completed schedule boundaries, by kind",
    labelnames=("kind",))
_WAVES_EXACT = _M_WAVES.labels(kind="exact")
_WAVES_SPEC = _M_WAVES.labels(kind="speculative")
_WAVES_BAILOUT = _M_WAVES.labels(kind="scalar_bailout")
_M_STAGE_SECONDS = metrics.counter(
    "build_stage_seconds_total", "cumulative construction seconds by stage",
    labelnames=("stage",))


def _sampled_reach_density(g: CSRGraph, samples: int = 12, seed: int = 0) -> float:
    """Mean forward-cone fraction over a few fixed-seed sample vertices —
    the cheap dense-reachability detector behind impl="auto" (a handful of
    plain BFS, deterministic for a given graph)."""
    from repro.graph.reach import reachable_set

    rng = np.random.default_rng(seed)
    verts = rng.integers(0, g.n, samples)
    return float(np.mean([reachable_set(g, int(v)).sum() / g.n for v in verts]))


def _device_backend_available() -> bool:
    """True when jax sees an accelerator (the device engine's auto gate)."""
    try:
        import jax

        return jax.default_backend() != "cpu"
    except Exception:  # jax missing/broken: host paths still work
        return False


def build_distribution_labels(
    g: CSRGraph,
    order: Optional[np.ndarray] = None,
    order_name: str = "degree_product",
    impl: str = "auto",
    max_wave: int = 256,
    scheduler: str = "onepass",
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 16,
    resume_dir: Optional[str] = None,
    **device_kwargs,
) -> ReachabilityOracle:
    """Build the DL oracle for DAG ``g`` with the selected implementation.

    ``checkpoint_dir`` enables wave/chunk-granular construction checkpoints
    (every ``checkpoint_every`` schedule boundaries); ``resume_dir``
    (defaulting to ``checkpoint_dir``) is scanned for the latest complete
    checkpoint of the SAME build, which resumes mid-schedule and finishes
    byte-identical to an uninterrupted run.  Host batched impls only
    ("wave"/"speculative" — a resumed build adopts its checkpoint's impl).

    ``device_kwargs`` (``expand=``, ``l_max=``, ``ell_width=``, ``mesh=``,
    ...) forward to the device engine and are rejected for the host impls —
    a typo'd tuning knob must not silently no-op.
    """
    if device_kwargs and impl not in ("device", "auto"):
        raise TypeError(
            f"impl={impl!r} accepts no extra kwargs (got {sorted(device_kwargs)}); "
            "they apply to the device engine only")
    if order is None:
        order = get_order(g, order_name)
    order = np.asarray(order, dtype=np.int64)
    waves = None
    spec_schedule = None
    t_sched = 0.0
    fingerprint = None
    restored = None
    if checkpoint_dir is not None or resume_dir is not None:
        fingerprint = _build_fingerprint(g, order, max_wave, scheduler)
    rdir = resume_dir if resume_dir is not None else checkpoint_dir
    if rdir is not None:
        restored = _BuildCheckpointer.latest(rdir, fingerprint)
    if restored is not None:
        ck_impl = restored[1]["impl"]
        if impl not in ("auto", ck_impl):
            warnings.warn(
                f"resuming from a {ck_impl!r} checkpoint; requested "
                f"impl={impl!r} ignored", stacklevel=2)
        impl = ck_impl
    if impl == "auto":
        if g.n < _AUTO_WAVE_MIN:
            impl = "reference"
        elif _sampled_reach_density(g) >= _AUTO_DENSE_REACH:
            # dense-reachability wall: true conflicts every ~1-2 consecutive
            # ranks degenerate the exact waves, AND the exact scheduler is
            # itself expensive here (its page closures span huge cones) —
            # route straight to the SPECULATIVE engine (optimistic chunks +
            # certification), previously the scalar-reference fallback
            impl = "speculative"
        else:
            # sparse side: the exact schedule is the profitability probe —
            # tiny mean waves cannot amortize the batched sweeps and route
            # to the speculative engine too (borderline graphs the density
            # sample misses); long waves run exactly as before.  The quick
            # speculative probe cannot play this role: it skips the
            # interval/budget closure machinery, so it marks tree-family
            # schedules optimistic as well (see waves.py).
            t0 = time.perf_counter()
            waves = wave_schedule(
                g, order, max_wave=max_wave, scheduler=scheduler,
                abort_below_avg=_AUTO_MIN_AVG_WAVE / 3,
            )
            t_sched = time.perf_counter() - t0
            if waves is None or g.n / waves.shape[0] < _AUTO_MIN_AVG_WAVE:
                impl, waves = "speculative", None
            else:
                impl = "device" if _device_backend_available() else "wave"
    if device_kwargs and impl not in ("device",):
        # auto resolved to a host impl: device tuning knobs will not apply
        # on THIS host — say so instead of silently no-opping
        warnings.warn(
            f"device kwargs {sorted(device_kwargs)} ignored: impl resolved "
            f"to {impl!r} on this host", stacklevel=2)
    if impl in ("wave", "bitset", "device") and waves is None:
        t0 = time.perf_counter()
        waves = wave_schedule(g, order, max_wave=max_wave, scheduler=scheduler)
        t_sched += time.perf_counter() - t0
    if impl == "speculative" and spec_schedule is None:
        t0 = time.perf_counter()
        spec_schedule = speculative_schedule(g, order, max_wave=max_wave)
        t_sched += time.perf_counter() - t0
    ckpt = None
    if checkpoint_dir is not None:
        if impl in ("wave", "bitset", "speculative"):
            ckpt = _BuildCheckpointer(checkpoint_dir, every=checkpoint_every)
        else:
            warnings.warn(
                f"construction checkpointing is host-batched only; "
                f"impl={impl!r} builds without checkpoints", stacklevel=2)
    spec_stats: dict = {}
    stage_seconds: dict = {}
    sweep_sp = (trace.span("build.sweep", cat="build",
                           args={"impl": impl, "n": g.n})
                if ON.enabled else trace.NOOP_SPAN)
    t0 = time.perf_counter()
    with sweep_sp:
        if impl in ("reference", "ref"):
            oracle = _build_reference(g, order)
            impl = "reference"
        elif impl in ("wave", "bitset"):
            oracle = _build_wave(g, order, max_wave=max_wave, waves=waves,
                                 ckpt=ckpt, fingerprint=fingerprint,
                                 restored=restored, stage_out=stage_seconds)
            impl = "wave"
        elif impl == "speculative":
            oracle = _build_speculative(
                g, order, max_wave=max_wave, schedule=spec_schedule,
                stats_out=spec_stats, ckpt=ckpt, fingerprint=fingerprint,
                restored=restored, stage_out=stage_seconds,
            )
        elif impl == "device":
            from repro.build.engine_jax import distribution_labeling_device

            oracle = distribution_labeling_device(
                g, order=order, waves=waves, **device_kwargs
            )
        else:
            raise ValueError(f"unknown construction impl {impl!r}")
    t_sweep = time.perf_counter() - t0
    if impl == "speculative":
        waves_n = int(spec_schedule.lengths.shape[0])
        scheduler = "speculative"
    else:
        waves_n = None if waves is None else int(waves.shape[0])
    # breadcrumbs for benchmarks/telemetry: which engine actually built this
    # and where the time went (scheduler share is a tracked BENCH metric)
    object.__setattr__(oracle, "build_impl", impl)
    stats = {
        "impl": impl,
        "scheduler": scheduler if (waves is not None or impl == "speculative") else None,
        "schedule_seconds": round(t_sched, 4),
        "sweep_seconds": round(t_sweep, 4),
        "n_waves": waves_n,
    }
    # Per-stage attribution: "schedule" and "sweep" partition the build;
    # the remaining stages are WITHIN-sweep shares (prune gather, label
    # append, finalize, certify/replay, checkpoint writes), so shares are
    # fractions of total build time and need not sum to 1.  BENCH rows
    # carry stage_shares so ``check_monotone`` can gate attribution creep.
    stages = dict(stage_seconds)
    if ckpt is not None:
        stages["checkpoint"] = ckpt.save_seconds
    stages["schedule"] = t_sched
    stages["sweep"] = t_sweep
    total = t_sched + t_sweep
    stats["stages"] = {k: round(float(v), 4) for k, v in sorted(stages.items())}
    stats["stage_shares"] = {
        k: (round(float(v) / total, 4) if total > 0 else 0.0)
        for k, v in sorted(stages.items())
    }
    for k, v in stages.items():
        _M_STAGE_SECONDS.labels(stage=k).inc(float(v))
    if spec_stats:
        stats["speculation"] = spec_stats
    if ckpt is not None or restored is not None:
        stats["checkpoint"] = {
            "resumed_from": None if restored is None else int(restored[1]["done"]),
            "written": 0 if ckpt is None else ckpt.written,
        }
    object.__setattr__(oracle, "build_stats", stats)
    return oracle


# ---------------------------------------------------------------------------
# reference scalar implementation (the seed path)
# ---------------------------------------------------------------------------


def _build_reference(g: CSRGraph, order: np.ndarray) -> ReachabilityOracle:
    n = g.n
    g_rev = g.reverse()

    # Python sets give C-speed isdisjoint (the pruning hot path); parallel
    # lists keep insertion order for the final packed arrays.
    L_out_sets = [set() for _ in range(n)]
    L_in_sets = [set() for _ in range(n)]
    L_out_lists: list[list[int]] = [[] for _ in range(n)]
    L_in_lists: list[list[int]] = [[] for _ in range(n)]

    visited = np.full(n, -1, dtype=np.int64)  # iteration stamp, avoids clearing

    for it, vi in enumerate(order):
        vi = int(vi)
        # reverse BFS: distribute vi into L_out of its ancestors
        pruned_bfs_distribute(
            g_rev.indptr, g_rev.indices, vi, L_in_sets[vi],
            L_out_sets, L_out_lists, visited, 2 * it,
        )
        # forward BFS: distribute vi into L_in of its descendants
        pruned_bfs_distribute(
            g.indptr, g.indices, vi, L_out_sets[vi],
            L_in_sets, L_in_lists, visited, 2 * it + 1,
        )

    return finalize_labels(L_out_lists, L_in_lists, hop_rank=_hop_rank(order, n))


# ---------------------------------------------------------------------------
# wave-scheduled bitset implementation
# ---------------------------------------------------------------------------


def _hop_rank(order: np.ndarray, n: int) -> np.ndarray:
    """rank[order[i]] = i — the rank-space remap shared by all impls."""
    hop_rank = np.empty(n, dtype=np.int32)
    hop_rank[order] = np.arange(n, dtype=np.int32)
    return hop_rank


class _LabelStore:
    """Ragged rank-space label rows under construction.

    Dense int32[n, cap] head rows (cap grows geometrically up to DEEP_CAP)
    hold columns < len; a few *deep* rows (hub labels can reach hundreds of
    hops while the average stays single-digit) spill their tail into python
    lists so they never force O(n x max_len) matrix growth.  No pad values
    anywhere: every reader walks columns < len.
    """

    DEEP_CAP = 64

    def __init__(
        self, n: int, deep_cap: int | None = None, null: int | None = None
    ):
        self.n = n
        # deep_cap tunes the dense-head/python-tail split: the speculative
        # builder raises it so hub rows (which sit in most frontiers on the
        # dense families) stay on the vectorized paths instead of paying the
        # per-row dict loops on every gather
        if deep_cap is not None:
            self.DEEP_CAP = deep_cap
        # ``null`` is a rank that indexes an always-zero row of every prune
        # table (builders pass the vertex count).  When set, slots beyond a
        # row's length always hold it — appends only write real slots, growth
        # and rollback refill — so rectangular gathers feed whole head rows
        # straight into the table with no tail-masking pass.
        self.null = null
        if null is None:
            self.mat = np.empty((n, _PAD_MULTIPLE), dtype=np.int32)
        else:
            self.mat = np.full((n, _PAD_MULTIPLE), null, dtype=np.int32)
        self.lens = np.zeros(n, dtype=np.int32)
        self.deep: Dict[int, List[int]] = {}
        # within-sweep stage attribution: the builders surface these as
        # ``build_stats["stages"]`` so BENCH can gate attribution drift
        # (prune gather is the measured ~2/3 sweep hot spot)
        self.stage_seconds: Dict[str, float] = {
            "prune_gather": 0.0, "label_append": 0.0, "finalize": 0.0}

    def _timed(self, stage: str, fn, *args):
        """Run a store hot spot under stage attribution (no-op clock when
        obs is disabled — the store methods themselves stay unchanged)."""
        if not ON.enabled:
            return fn(*args)
        t0 = time.perf_counter()
        try:
            return fn(*args)
        finally:
            self.stage_seconds[stage] += time.perf_counter() - t0

    # -- writes ---------------------------------------------------------

    def append(self, verts: np.ndarray, counts: np.ndarray, vals: np.ndarray) -> None:
        """Append ``counts[k]`` rank values to row verts[k] (vals row-major)."""
        return self._timed("label_append", self._append, verts, counts, vals)

    def _append(self, verts: np.ndarray, counts: np.ndarray, vals: np.ndarray) -> None:
        row_lens = self.lens[verts].astype(np.int64)
        new_lens = row_lens + counts
        need = int(new_lens.max())
        if need > self.mat.shape[1] and self.mat.shape[1] < self.DEEP_CAP:
            cap = self.mat.shape[1]
            while cap < min(need, self.DEEP_CAP):
                cap *= 2
            if self.null is None:
                grown = np.empty((self.n, cap), dtype=np.int32)
            else:
                grown = np.full((self.n, cap), self.null, dtype=np.int32)
            grown[:, : self.mat.shape[1]] = self.mat
            self.mat = grown
        if need > self.DEEP_CAP:
            shallow = new_lens <= self.DEEP_CAP
            if not shallow.all():
                self._append_deep(verts, counts, vals, shallow)
                if not shallow.any():
                    return
                keep = np.repeat(shallow, counts)
                verts, counts, row_lens = verts[shallow], counts[shallow], row_lens[shallow]
                vals = vals[keep]
        if int(counts.max()) == 1:  # common case: one member labels each vertex
            self.mat[verts, row_lens] = vals
            self.lens[verts] += 1
            return
        total = int(counts.sum())
        v_rep = np.repeat(verts, counts)
        cum = np.cumsum(counts)
        within = np.arange(total, dtype=np.int64) - np.repeat(cum - counts, counts)
        self.mat[v_rep, np.repeat(row_lens, counts) + within] = vals
        self.lens[verts] += counts.astype(np.int32)

    def _append_deep(self, verts, counts, vals, shallow) -> None:
        """Slow path for rows crossing/beyond DEEP_CAP (a handful per build)."""
        offs = np.concatenate(([0], np.cumsum(counts)))
        for k in np.flatnonzero(~shallow):
            v = int(verts[k])
            row_vals = vals[offs[k] : offs[k + 1]].tolist()
            ln = int(self.lens[v])
            tail = self.deep.setdefault(v, [])
            room = self.DEEP_CAP - ln
            if room > 0:  # fill the dense head first
                self.mat[v, ln : self.DEEP_CAP] = row_vals[:room]
                row_vals = row_vals[room:]
            tail.extend(row_vals)
            self.lens[v] += counts[k]

    def rollback(self, verts: np.ndarray, new_lens: np.ndarray) -> None:
        """Truncate rows back to per-row watermarks (speculative undo).

        Rows are append-only, so rolling back a wave's writes is just
        restoring each touched row's length — stale values beyond the new
        length are never read.  Deep tails shrink (or vanish) to match."""
        old = self.lens[verts]
        self.lens[verts] = new_lens
        if self.null is not None:  # restore the tail-slot invariant
            width = self.mat.shape[1]
            lo = np.minimum(new_lens.astype(np.int64), width)
            hi = np.minimum(old.astype(np.int64), width)
            d = hi - lo
            shrunk = d > 0
            if shrunk.any():
                dd = d[shrunk]
                cum = np.cumsum(dd)
                cols = np.arange(int(cum[-1]), dtype=np.int64) - np.repeat(
                    cum - dd, dd) + np.repeat(lo[shrunk], dd)
                self.mat[np.repeat(verts[shrunk], dd), cols] = self.null
        if self.deep:
            for k in np.flatnonzero(old > self.DEEP_CAP):
                v = int(verts[k])
                tail = self.deep.get(v)
                if tail is None:
                    continue
                nl = int(new_lens[k])
                if nl > self.DEEP_CAP:
                    del tail[nl - self.DEEP_CAP :]
                else:
                    del self.deep[v]

    # -- checkpoint serialization ---------------------------------------

    def to_arrays(self) -> Dict[str, np.ndarray]:
        """Exact store state as named arrays (the checkpoint payload).

        The head matrix is saved at its CURRENT width: capacity growth is a
        deterministic function of the append sequence, so restoring the
        exact width keeps a resumed build on the identical growth path."""
        from repro.persist.blocks import pack_ragged

        keys = np.fromiter(self.deep.keys(), dtype=np.int64, count=len(self.deep))
        vals, offs = pack_ragged([self.deep[int(k)] for k in keys])
        return {
            "store_mat": self.mat,
            "store_lens": self.lens,
            "store_deep_keys": keys,
            "store_deep_vals": vals,
            "store_deep_offs": offs,
        }

    @classmethod
    def from_arrays(cls, arrays: Dict[str, np.ndarray], meta: dict) -> "_LabelStore":
        """Rebuild a store from ``to_arrays`` output + the builder meta
        (``store_n`` / ``store_deep_cap`` / ``store_null``)."""
        from repro.persist.blocks import unpack_ragged

        self = cls(int(meta["store_n"]), deep_cap=int(meta["store_deep_cap"]),
                   null=meta["store_null"])
        self.mat = np.ascontiguousarray(arrays["store_mat"], dtype=np.int32)
        self.lens = np.ascontiguousarray(arrays["store_lens"], dtype=np.int32)
        keys = arrays["store_deep_keys"]
        tails = unpack_ragged(arrays["store_deep_vals"], arrays["store_deep_offs"])
        self.deep = {int(k): list(t) for k, t in zip(keys, tails)}
        return self

    # -- reads ----------------------------------------------------------

    def row(self, v: int) -> np.ndarray:
        """Full label row of one vertex (deep tail included)."""
        ln = int(self.lens[v])
        head = self.mat[v, : min(ln, self.DEEP_CAP)]
        if ln <= self.DEEP_CAP:
            return head
        return np.concatenate([head, np.asarray(self.deep[v], dtype=np.int32)])

    def ragged_entries(self, verts: np.ndarray):
        """(values int32[t], lens int64[k]) — concatenated label entries of
        ``verts`` in order, deep tails included."""
        return self._timed("prune_gather", self._ragged_entries, verts)

    def _ragged_entries(self, verts: np.ndarray):
        lens = self.lens[verts].astype(np.int64)
        head_lens = np.minimum(lens, self.DEEP_CAP) if self.deep else lens
        total = int(head_lens.sum())
        cum = np.cumsum(head_lens)
        col = np.arange(total, dtype=np.int64) - np.repeat(cum - head_lens, head_lens)
        vals = self.mat[np.repeat(verts, head_lens), col]
        if self.deep and (lens > self.DEEP_CAP).any():
            parts: List[np.ndarray] = []
            prev = 0
            for k in np.flatnonzero(lens > self.DEEP_CAP):
                parts.append(vals[prev : int(cum[k])])
                parts.append(np.asarray(self.deep[int(verts[k])], dtype=np.int32))
                prev = int(cum[k])
            parts.append(vals[prev:])
            vals = np.concatenate(parts)
        return vals, lens

    def pruned_or(self, frontier: np.ndarray, hop_mask: np.ndarray) -> np.ndarray:
        """Member masks pruned[f] = OR_{h in L(frontier[f])} hop_mask[h].

        Single-word masks take a rectangular fast path — gather whole head
        rows, point tail columns at the hop table's always-zero last row,
        one flat take + one axis reduce, no ragged index arithmetic.  Wider
        masks gather raggedly so cost tracks actual label ints."""
        return self._timed("prune_gather", self._pruned_or, frontier, hop_mask)

    def _pruned_or(self, frontier: np.ndarray, hop_mask: np.ndarray) -> np.ndarray:
        lens = self.lens[frontier].astype(np.int64)
        out = np.zeros((frontier.shape[0], hop_mask.shape[1]), dtype=np.uint64)
        if frontier.shape[0] == 0:
            return out
        total = int(lens.sum())
        w = int(min(lens.max(initial=0), self.mat.shape[1]))
        # rect pays rows*w slots vs ragged's actual ints — worth it only while
        # the frontier's length skew is mild
        if hop_mask.shape[1] == 1 and w * frontier.shape[0] <= 4 * total:
            cols = np.arange(w, dtype=np.int64)[None, :]
            vals = self.mat[frontier[:, None], cols]  # narrow 2D gather
            if self.null is None:
                vals = np.where(
                    cols < lens[:, None], vals, np.int32(hop_mask.shape[0] - 1))
            out[:, 0] = np.bitwise_or.reduce(hop_mask[:, 0][vals], axis=1)
            if self.deep:
                for k in np.flatnonzero(lens > self.DEEP_CAP):  # rare deep rows
                    tail = np.asarray(self.deep[int(frontier[k])], dtype=np.int64)
                    out[k] |= np.bitwise_or.reduce(hop_mask[tail], axis=0)
            return out
        head_lens = np.minimum(lens, self.DEEP_CAP) if self.deep else lens
        total = int(head_lens.sum())
        if total:
            nz = head_lens > 0
            rows = frontier[nz]
            ln = head_lens[nz]
            cum = np.cumsum(ln)
            col = np.arange(int(cum[-1]), dtype=np.int64) - np.repeat(cum - ln, ln)
            hits = hop_mask[self.mat[np.repeat(rows, ln), col]]  # [t, K]
            out[nz] = np.bitwise_or.reduceat(hits, cum - ln, axis=0)
        if self.deep:
            for k in np.flatnonzero(lens > self.DEEP_CAP):  # rare deep rows
                tail = np.asarray(self.deep[int(frontier[k])], dtype=np.int64)
                out[k] |= np.bitwise_or.reduce(hop_mask[tail], axis=0)
        return out

    def pruned_any(self, frontier: np.ndarray, mark: np.ndarray) -> np.ndarray:
        """bool[f] — does any label of frontier[f] hit the bool[n+1] ``mark``
        table?  The single-member analogue of ``pruned_or`` (replay's prune
        test), same rectangular layout: tail slots index mark's always-False
        last entry."""
        return self._timed("prune_gather", self._pruned_any, frontier, mark)

    def _pruned_any(self, frontier: np.ndarray, mark: np.ndarray) -> np.ndarray:
        lens = self.lens[frontier].astype(np.int64)
        out = np.zeros(frontier.shape[0], dtype=bool)
        if frontier.shape[0] == 0:
            return out
        total = int(lens.sum())
        w = int(min(lens.max(initial=0), self.mat.shape[1]))
        if w * frontier.shape[0] <= 4 * total:  # same skew heuristic as pruned_or
            if w:
                cols = np.arange(w, dtype=np.int64)[None, :]
                vals = self.mat[frontier[:, None], cols]  # narrow 2D gather
                if self.null is None:
                    vals = np.where(
                        cols < lens[:, None], vals, np.int32(mark.shape[0] - 1))
                out = mark[vals].any(axis=1)
        else:
            head_lens = np.minimum(lens, self.DEEP_CAP) if self.deep else lens
            nz = head_lens > 0
            if nz.any():
                rows = frontier[nz]
                ln = head_lens[nz]
                cum = np.cumsum(ln)
                col = np.arange(int(cum[-1]), dtype=np.int64) - np.repeat(cum - ln, ln)
                hits = mark[self.mat[np.repeat(rows, ln), col]]
                out[nz] = np.logical_or.reduceat(hits, cum - ln)
        if self.deep:
            for k in np.flatnonzero(lens > self.DEEP_CAP):  # rare deep rows
                tail = np.asarray(self.deep[int(frontier[k])], dtype=np.int64)
                out[k] |= mark[tail].any()
        return out

    # -- finalize -------------------------------------------------------

    def finalize(self, start: int = 0, stop: Optional[int] = None) -> np.ndarray:
        """Sort rows [start, stop) ascending, pack into the reference padding
        (multiple of 8, min 8, INVALID-padded) — byte-compatible with
        ``finalize_labels``.  The range lets one store hold both label sides
        (the fused sweep's role-split layout)."""
        return self._timed("finalize", self._finalize, start, stop)

    def _finalize(self, start: int = 0, stop: Optional[int] = None) -> np.ndarray:
        stop = self.n if stop is None else stop
        lens = self.lens[start:stop]
        mat = self.mat[start:stop]
        k = stop - start
        lmax = int(lens.max()) if k else 1
        width = max(
            ((max(lmax, 1) + _PAD_MULTIPLE - 1) // _PAD_MULTIPLE) * _PAD_MULTIPLE,
            _PAD_MULTIPLE,
        )
        out = np.full((k, width), INVALID, dtype=np.int32)
        # sort rows bucketed by length so short rows (the vast majority)
        # don't pay for the width a few deep rows force
        lo = 0
        b = _PAD_MULTIPLE
        cols = np.arange(width, dtype=np.int32)
        lens64 = lens.astype(np.int64)
        big = np.int32(self.n)  # sorts past every rank
        while lo < min(lmax, self.DEEP_CAP):
            sel = np.flatnonzero((lens64 > lo) & (lens64 <= min(b, self.DEEP_CAP)))
            if sel.size:
                w = min(b, self.DEEP_CAP)
                in_row = cols[None, :w] < lens64[sel, None]
                sub = np.where(in_row, mat[sel[:, None], cols[None, :w]], big)
                sub.sort(axis=1)
                out[sel[:, None], cols[None, :w]] = np.where(in_row, sub, INVALID)
            lo = b
            b *= 2
        for v in self.deep:  # rare deep rows, one by one
            if start <= v < stop:
                out[v - start, : lens64[v - start]] = np.sort(self.row(v))
        return out


# ---------------------------------------------------------------------------
# wave-granular build checkpointing
# ---------------------------------------------------------------------------


def _build_fingerprint(g: CSRGraph, order: np.ndarray, max_wave: int,
                       scheduler: str) -> str:
    """Identity of one build problem: a checkpoint resumes only a build of
    the SAME graph, rank order, and schedule parameters (schedules are
    deterministic in these, so the resumed run recomputes an identical
    schedule instead of persisting it)."""
    h = zlib.crc32(np.ascontiguousarray(g.indptr).tobytes())
    h = zlib.crc32(np.ascontiguousarray(g.indices).tobytes(), h)
    h = zlib.crc32(np.ascontiguousarray(order, dtype=np.int64).tobytes(), h)
    return f"{g.n}:{int(g.indices.shape[0])}:{max_wave}:{scheduler}:{h & 0xFFFFFFFF:08x}"


_CKPT_RE = re.compile(r"^ckpt_(\d{8})$")


class _BuildCheckpointer:
    """Wave/chunk-granular construction checkpoints.

    Each completed schedule boundary (exact wave, speculative chunk, or
    scalar-bailout chunk) bumps a monotone ``done`` counter; every
    ``every``-th boundary snapshots the exact ``_LabelStore`` state plus the
    cursor + adaptive-speculation state through ``persist.save_blocks``
    (checksummed, write-temp-then-rename — a crash mid-save leaves the
    previous checkpoint intact).  All scratch arrays are provably zero at
    boundaries, so store + cursor IS the complete builder state and a
    resumed build is byte-identical to an uninterrupted one."""

    def __init__(self, path: str, every: int = 16, keep: int = 2):
        self.path = path
        self.every = max(int(every), 1)
        self.keep = max(int(keep), 1)
        self.written = 0
        self.save_seconds = 0.0

    def maybe_save(self, done: int, store: _LabelStore, meta: dict) -> None:
        if done % self.every:
            return
        from repro.persist.blocks import save_blocks

        meta = dict(meta, done=int(done),
                    store_n=store.n, store_deep_cap=store.DEEP_CAP,
                    store_null=store.null)
        sp = (trace.span("build.checkpoint", cat="build", args={"done": int(done)})
              if ON.enabled else trace.NOOP_SPAN)
        t0 = time.perf_counter()
        with sp:
            os.makedirs(self.path, exist_ok=True)
            save_blocks(os.path.join(self.path, f"ckpt_{done:08d}"),
                        store.to_arrays(), meta)
        self.save_seconds += time.perf_counter() - t0
        self.written += 1
        self._gc()

    def _gc(self) -> None:
        names = sorted(d for d in os.listdir(self.path) if _CKPT_RE.match(d))
        for stale in names[: -self.keep]:
            shutil.rmtree(os.path.join(self.path, stale), ignore_errors=True)

    @staticmethod
    def latest(path: str, fingerprint: str):
        """Newest complete checkpoint matching ``fingerprint``, as
        ``(arrays, meta)`` — or None.  A corrupt or foreign checkpoint is
        skipped (with a warning) in favor of the next older one; a crash
        mid-save leaves only a ``.tmp`` which is never scanned."""
        from repro.persist.blocks import CorruptSnapshotError, load_blocks

        if not os.path.isdir(path):
            return None
        for name in sorted(
                (d for d in os.listdir(path) if _CKPT_RE.match(d)), reverse=True):
            cpath = os.path.join(path, name)
            try:
                arrays, meta, _ = load_blocks(cpath, strict=True)
            except CorruptSnapshotError as e:
                warnings.warn(f"skipping unusable checkpoint {cpath}: {e}",
                              stacklevel=2)
                continue
            if meta.get("fingerprint") != fingerprint:
                warnings.warn(
                    f"skipping checkpoint {cpath}: fingerprint "
                    f"{meta.get('fingerprint')!r} does not match this build "
                    f"({fingerprint!r})", stacklevel=2)
                continue
            return arrays, meta
        return None


def _wave_sweep(
    members_c: np.ndarray,    # int64[2W] role-split ids: rev members + fwd (+n)
    ranks_c: np.ndarray,      # int32[2W] their global ranks (duplicated)
    hop_row_ids: np.ndarray,  # int64[2W] store rows feeding each BFS's prune test
    extra_hop_keys: np.ndarray,  # int64[W] wave ranks (fwd prune sets include v_j)
    store: _LabelStore,       # role-split labels: rows < n L_out, rows >= n L_in
    indptr: np.ndarray,       # combined CSR: rev graph rows then fwd (+n) rows
    indices: np.ndarray,
    hop_mask: np.ndarray,     # uint64[n + 1, K] scratch, zeros on entry/exit
    visited: np.ndarray,      # uint64[2n, K] scratch, zeros on entry/exit
) -> None:
    """Both directions of Algorithm 2 for a whole wave, fused: the reverse
    sweeps run in the [0, n) half of the role-split graph, the forward
    sweeps in [n, 2n), with disjoint member bits — one level loop drives up
    to 2 * max_wave pruned BFS at once."""
    w2 = members_c.shape[0]
    w = w2 // 2
    mbits = bitset.member_bits(w2, hop_mask.shape[1])  # uint64[2W, K]

    # hop_mask[h] = mask of member BFS whose prune set contains hop h: the
    # reverse BFS of v_j prunes on L_in(v_j) (store row n + v_j), the
    # forward BFS on L_out(v_j) ∪ {rank_j} (store row v_j + an extra key —
    # v_j itself joins L_out(v_j) during this very wave).  Hop keys live in
    # one rank space, but member bits are disjoint across roles, so a single
    # table serves both; foreign-role bits are masked off by fbits.  Members
    # may share hops (a common high-rank ancestor), so the scatter must OR.
    hop_vals, hop_lens = store.ragged_entries(hop_row_ids)
    hm_keys, hm_bits = bitset.group_or(
        np.concatenate([hop_vals, extra_hop_keys]),  # int32 + int64 upcasts
        np.concatenate([mbits[np.repeat(np.arange(w2), hop_lens)], mbits[w:]]),
    )
    hop_mask[hm_keys] = hm_bits

    visited[members_c] = mbits
    touched = [members_c]

    # level 0 specialization: every member labels itself (the self prune
    # test L_out(v) ∩ L_in(v) is empty in a DAG) and expands — skip the
    # generic prune/expand machinery for it
    store.append(members_c, np.ones(w2, dtype=np.int64), ranks_c)
    nbrs0, seg0 = bitset.csr_gather(indptr, indices, members_c)
    if nbrs0.size == 0:
        visited[members_c] = 0
        hop_mask[hm_keys] = 0
        return
    uniq0, obits0 = bitset.group_or(nbrs0, mbits[seg0])
    new0 = obits0 & ~visited[uniq0]
    keep0 = new0.any(axis=1)
    frontier = uniq0[keep0]
    fbits = new0[keep0]
    visited[frontier] |= fbits
    touched.append(frontier)

    while frontier.size:
        # prune test, whole frontier at once: OR the member masks of every
        # frontier vertex's current label entries.  Intra-wave appends can
        # appear in rows, but only the static wave-start verdict bits ever
        # intersect fbits (see waves.py for why).
        pruned = store.pruned_or(frontier, hop_mask)
        lab = fbits & ~pruned
        active = lab.any(axis=1)
        if not active.any():
            break
        v_lab = frontier[active]
        bits = lab[active]

        # label append: expand member masks to (vertex, member) pairs —
        # row-major, so values per row arrive member- (= rank-) ascending
        _, member, counts = bitset.expand_member_bits(bits, w2)
        store.append(v_lab, counts, ranks_c[member])

        # expansion: only labeled (un-pruned) vertices expand, carrying
        # exactly their labeled member bits
        nbrs, seg = bitset.csr_gather(indptr, indices, v_lab)
        if nbrs.size == 0:
            break
        uniq, obits = bitset.group_or(nbrs, bits[seg])  # indices already int64
        new = obits & ~visited[uniq]
        keep = new.any(axis=1)
        frontier = uniq[keep]
        fbits = new[keep]
        visited[frontier] |= fbits
        touched.append(frontier)

    # scratch cleanup (exactly the entries we wrote)
    visited[np.concatenate(touched)] = 0
    hop_mask[hm_keys] = 0


def _build_wave(
    g: CSRGraph,
    order: np.ndarray,
    max_wave: int = 256,
    waves: Optional[np.ndarray] = None,
    ckpt: Optional[_BuildCheckpointer] = None,
    fingerprint: Optional[str] = None,
    restored=None,
    stage_out: Optional[dict] = None,
) -> ReachabilityOracle:
    n = g.n
    if n == 0:
        return finalize_labels([], [], hop_rank=np.empty(0, dtype=np.int32))
    g_rev = g.reverse()
    if waves is None:
        waves = wave_schedule(g, order, max_wave=max_wave)
    ranks_of = np.arange(n, dtype=np.int32)

    # role-split layout: ids [0, n) run the reverse BFS over the reverse
    # graph and write L_out; ids [n, 2n) run the forward BFS over the
    # forward graph and write L_in.  One combined CSR + one label store let
    # a single level loop drive both directions of a wave.
    indptr = g.indptr.astype(np.int64)
    indices = g.indices.astype(np.int64)
    r_indptr = g_rev.indptr.astype(np.int64)
    r_indices = g_rev.indices.astype(np.int64)
    indptr_c = np.concatenate([r_indptr, r_indptr[-1] + indptr[1:]])
    indices_c = np.concatenate([r_indices, indices + n])

    k_words = bitset.n_words(2 * max_wave)
    # deep_cap=1024 keeps hub rows dense: on the dense-reachability families
    # hubs sit in most frontiers, and the per-row deep-dict loops would
    # otherwise run on every gather/append (max observed label length is a
    # few hundred, so the head matrix stays modest)
    store = _LabelStore(2 * n, deep_cap=1024, null=n)
    hop_mask = np.zeros((n + 1, k_words), dtype=np.uint64)
    visited = np.zeros((2 * n, k_words), dtype=np.uint64)

    start_wave, done = 0, 0
    if restored is not None:
        arrays, meta = restored
        store = _LabelStore.from_arrays(arrays, meta)
        start_wave = int(meta["wave_idx"])
        done = int(meta["done"])
    base = int(np.asarray(waves[:start_wave], dtype=np.int64).sum())
    for wi in range(start_wave, int(waves.shape[0])):
        wlen = int(waves[wi])
        inject.fire("build.wave", index=wi)
        members = order[base : base + wlen]
        ranks = ranks_of[base : base + wlen]
        members_c = np.concatenate([members, members + n])
        ranks_c = np.concatenate([ranks, ranks])
        # reverse BFS prunes on L_in rows (store n + v), forward on L_out
        # rows (store v) plus the member's own rank; narrow the scratch to
        # this wave's word width so short waves don't pay for max_wave
        hop_row_ids = np.concatenate([members + n, members])
        kwe = bitset.n_words(2 * wlen)
        sp = (trace.span("build.wave", cat="build",
                         args={"index": wi, "size": wlen})
              if ON.enabled else trace.NOOP_SPAN)
        with sp:
            _wave_sweep(
                members_c, ranks_c, hop_row_ids, ranks.astype(np.int64),
                store, indptr_c, indices_c, hop_mask[:, :kwe], visited[:, :kwe],
            )
        _WAVES_EXACT.inc()
        base += wlen
        done += 1
        if ckpt is not None:
            # all sweep scratch is zero again here: store + cursor is the
            # complete builder state
            ckpt.maybe_save(done, store, {
                "impl": "wave", "fingerprint": fingerprint, "wave_idx": wi + 1,
            })

    oracle = ReachabilityOracle(
        L_out=store.finalize(0, n),
        L_in=store.finalize(n, 2 * n),
        out_len=store.lens[:n].copy(),
        in_len=store.lens[n:].copy(),
        hop_rank=_hop_rank(order, n),
    )
    if stage_out is not None:
        stage_out.update(store.stage_seconds)
    return oracle


# ---------------------------------------------------------------------------
# speculative wave implementation (optimistic batching + certify + replay)
# ---------------------------------------------------------------------------


def _speculative_sweep(
    members_c: np.ndarray,    # int64[2W] role-split ids: rev members + fwd (+n)
    ranks_c: np.ndarray,      # int32[2W] their global ranks (duplicated)
    hop_row_ids: np.ndarray,  # int64[2W] store rows feeding each BFS's prune test
    extra_hop_keys: np.ndarray,  # int64[W] wave ranks (fwd prune sets include v_j)
    ranks: np.ndarray,        # int32[W] member-bit id -> global rank (both roles)
    half: np.ndarray,         # uint64[W, kr] one-hot member masks (bit j = member j)
    store: _LabelStore,
    indptr: np.ndarray,
    indices: np.ndarray,
    hop_rev: np.ndarray,      # uint64[n + 1, kr] scratch, zeros on entry
    hop_fwd: np.ndarray,      # uint64[n + 1, kr] scratch, zeros on entry
    visited: np.ndarray,      # uint64[2n, kr] scratch, zeros on entry
    labeled: np.ndarray,      # uint64[2n, kr] scratch, zeros on entry
):
    """The fused wave sweep of ``_wave_sweep``, run OPTIMISTICALLY: members
    are not proven mutually unreachable, so prune verdicts may be stale.

    Member bits use a SINGLE bank: bit j means member j in both sweep roles.
    That is unambiguous because the combined CSR keeps roles disjoint —
    rows < n only ever carry reverse-sweep bits and rows >= n forward-sweep
    bits — so the two roles need separate hop tables (``hop_rev`` feeding
    rows < n, ``hop_fwd`` rows >= n) but can share the narrowest possible
    word width, n_words(W), on every mask op.  Every append also accumulates
    into ``labeled`` and an append log (for rollback); the scratch is NOT
    cleared on exit — certification reads ``labeled`` first, then the caller
    cleans via the returned (touched, keys_rev, keys_fwd).

    Because wave-start prune sets are SUBSETS of the sequential ones, the
    sweep over-labels and over-visits relative to the sequential loop —
    which is exactly what makes the certification mask exact (bitset.
    violation_mask) and non-violated members exactly sequential.
    """
    w2 = members_c.shape[0]
    w = w2 // 2
    n = indptr.shape[0] // 2
    log: list = []

    hop_vals, hop_lens = store.ragged_entries(hop_row_ids)
    cut = int(hop_lens[:w].sum())
    jrep = np.arange(w)
    keys_rev, bits_rev = bitset.group_or(
        hop_vals[:cut], half[np.repeat(jrep, hop_lens[:w])])
    keys_fwd, bits_fwd = bitset.group_or(
        np.concatenate([hop_vals[cut:], extra_hop_keys]),
        np.concatenate([half[np.repeat(jrep, hop_lens[w:])], half]),
    )
    hop_rev[keys_rev] = bits_rev
    hop_fwd[keys_fwd] = bits_fwd

    mbits_c = np.concatenate([half, half])
    _seed_and_sweep(
        members_c, mbits_c, ranks_c, w, ranks, store, indptr, indices,
        hop_rev, hop_fwd, visited, labeled, log, touched := [])
    return np.concatenate(touched), keys_rev, keys_fwd, log


def _seed_and_sweep(
    seed_rows: np.ndarray,
    seed_bits: np.ndarray,
    seed_ranks: np.ndarray,
    w: int,
    ranks: np.ndarray,
    store: _LabelStore,
    indptr: np.ndarray,
    indices: np.ndarray,
    hop_rev: np.ndarray,
    hop_fwd: np.ndarray,
    visited: np.ndarray,
    labeled: np.ndarray,
    log: list,
    touched: list,
) -> None:
    """Seed the member rows (always labeled — a seed sharing a prune hop both
    ways would imply a cycle) and run the shared level loop of every
    optimistic sweep: whole-frontier prune gathers split by role at ``n``,
    append + log, frontier expansion under the visited masks."""
    n = indptr.shape[0] // 2
    visited[seed_rows] |= seed_bits
    labeled[seed_rows] |= seed_bits
    touched.append(seed_rows)
    ones = np.ones(seed_rows.shape[0], dtype=np.int64)
    store.append(seed_rows, ones, seed_ranks)
    log.append((seed_rows, ones, seed_ranks))
    nbrs0, seg0 = bitset.csr_gather(indptr, indices, seed_rows)
    if nbrs0.size == 0:
        return
    uniq0, obits0 = bitset.group_or(nbrs0, seed_bits[seg0])
    new0 = obits0 & ~visited[uniq0]
    keep0 = new0.any(axis=1)
    frontier = uniq0[keep0]
    fbits = new0[keep0]
    visited[frontier] |= fbits
    touched.append(frontier)

    while frontier.size:
        # frontier is sorted (group_or keys), so one searchsorted splits it
        # into the rev rows (< n, pruned against hop_rev) and the fwd rows
        cutf = int(np.searchsorted(frontier, n))
        pruned = np.empty((frontier.shape[0], fbits.shape[1]), dtype=np.uint64)
        pruned[:cutf] = store.pruned_or(frontier[:cutf], hop_rev)
        pruned[cutf:] = store.pruned_or(frontier[cutf:], hop_fwd)
        lab = fbits & ~pruned
        active = lab.any(axis=1)
        if not active.any():
            break
        v_lab = frontier[active]
        bits = lab[active]
        labeled[v_lab] |= bits

        _, member, counts = bitset.expand_member_bits(bits, w)
        vals = ranks[member]
        store.append(v_lab, counts, vals)
        log.append((v_lab, counts, vals))

        nbrs, seg = bitset.csr_gather(indptr, indices, v_lab)
        if nbrs.size == 0:
            break
        uniq, obits = bitset.group_or(nbrs, bits[seg])
        new = obits & ~visited[uniq]
        keep = new.any(axis=1)
        frontier = uniq[keep]
        fbits = new[keep]
        visited[frontier] |= fbits
        touched.append(frontier)


def _certify_chunk(
    members: np.ndarray,
    n: int,
    kr: int,
    labeled: np.ndarray,
    log: list,
) -> Optional[np.ndarray]:
    """Violation detection for one speculative chunk: None when every member
    certifies (the common case — and a cheap word-level quick-check when no
    member appended into a wave-mate's prune-source row at all), else the
    PER-SIDE pair (viol_rev bool[w], viol_fwd bool[w]) of sweeps needing
    correction — a member violated on one side keeps its other side's
    appends.

    The detector is EXACT given the sweep's over-approximation invariant
    (probes only ever prune on pre-chunk entries — mid-sweep appends carry
    other members' hop bits, never the prober's — so every sweep labels a
    superset of its sequential label set): member j's sweep truly diverges
    from the sequential loop iff it *labeled* a row u the sequential pass
    would have pruned, and that happens iff some lower-ranked mate i put
    its rank BOTH into j's prune-source row and into L(u) during the
    sweep.  Both conditions read the ``labeled`` scratch bits, which at
    certify time are exactly "which chunk ranks each row's label gained"
    (no chunk rank exists anywhere at chunk start).  An entry counted here
    may still be removed by the mate's own correction, so the error
    direction is over-flagging — sound, because the correction pass
    recomputes the exact surviving set per flagged side; rows j merely
    *visited* but was pruned at don't count, because the sequential pass
    prunes there too (its prune sets are supersets of the stale ones)."""
    w = members.shape[0]
    pref = bitset.prefix_bits(w, kr)
    own_rev = labeled[members, :kr]      # mates that entered L_out(v_j)
    own_fwd = labeled[n + members, :kr]  # mates that entered L_in(v_j)
    pf = own_fwd & pref  # lower-ranked candidates that stale-ed j's rev sweep
    pr = own_rev & pref  # lower-ranked candidates that stale-ed j's fwd sweep
    if not pf.any() and not pr.any():
        return None
    # which members' ranks each swept row's label gained, aggregated over
    # the rows each victim labeled.  Touch matrices mask the victim bits so
    # cost tracks candidate hits.
    rows = np.unique(np.concatenate([e[0] for e in log]))
    rrev = rows[rows < n]
    rfwd = rows[rows >= n]
    mb = bitset.member_bits(w, kr)
    jr = np.flatnonzero(pf.any(axis=1))
    jf = np.flatnonzero(pr.any(axis=1))
    zeros = np.zeros((w, kr), dtype=np.uint64)
    if jr.size:
        vm = np.bitwise_or.reduce(mb[jr], axis=0)
        lr = labeled[rrev, :kr]
        sel = np.flatnonzero((lr & vm).any(axis=1))
        t_rev = bitset.touch_matrix(lr[sel] & vm, lr[sel], w)
    else:
        t_rev = zeros
    if jf.size:
        vm = np.bitwise_or.reduce(mb[jf], axis=0)
        lf = labeled[rfwd, :kr]
        sel = np.flatnonzero((lf & vm).any(axis=1))
        t_fwd = bitset.touch_matrix(lf[sel] & vm, lf[sel], w)
    else:
        t_fwd = zeros
    viol_rev, viol_fwd = bitset.violation_mask(
        own_rev, own_fwd, t_rev, t_fwd, sides=True)
    if not viol_rev.any() and not viol_fwd.any():
        return None
    return viol_rev, viol_fwd


def _correct_chunk(
    store: _LabelStore,
    log: list,
    viol_rev: np.ndarray,
    viol_fwd: np.ndarray,
    members: np.ndarray,
    base: int,
    n: int,
    indptr: np.ndarray,
    indices: np.ndarray,
    mask: np.ndarray,
) -> None:
    """Exact rank-order correction of a violated chunk — no re-sweep.

    Because the speculative sweep over-approximates (each side labels a
    SUPERSET of its sequential label set) and certification is exact, the
    sequential result for a violated side is recoverable from the chunk log
    alone: it is the subset of the side's speculatively labeled rows still
    reachable from the seed once the rows the sequential pass would have
    *fresh-pruned* are removed.  A row u is fresh-pruned for member j's
    reverse sweep iff some surviving mate rank r < rank_j sits both in j's
    prune-source row (L_in(v_j) — mate r's forward append) and in L_out(u)
    (mate r's reverse append); both memberships are chunk appends, so they
    are read off the log, never the store.  The pruned-BFS connectivity is
    then a plain boolean BFS over the member's own labeled rows with the
    fresh-pruned rows blocked — no label gathers at all, which is what
    makes corrections an order of magnitude cheaper than re-running the
    pruned sweep.

    Violated sides are corrected in ascending rank order so each member's
    fresh keys and blocked sets are evaluated against the *surviving*
    (already corrected) appends of its lower-ranked mates — exactly the
    sequential store state at that member's turn.  The lowest violated
    member sees only certified mates, so the induction grounds out; one
    pass suffices, no re-certification.  Rolled-back entries are restored
    through per-row watermark truncation + one filtered stable re-append
    (rows only ever LOSE entries relative to the speculative run, and the
    finalize sorts row contents, so the surviving multiset is all that
    must match the sequential builder).

    ``mask`` is a caller-owned all-False bool[2n] scratch, returned
    all-False."""
    verts_cat = np.concatenate([e[0] for e in log])
    counts_cat = np.concatenate([e[1] for e in log]).astype(np.int64)
    vals_cat = np.concatenate([e[2] for e in log])
    v_rep = np.repeat(verts_cat, counts_cat)
    j_ent = vals_cat.astype(np.int64) - base  # chunk index of each entry
    keep = np.ones(v_rep.shape[0], dtype=bool)
    # entry indices sorted by row (fresh-key lookups) and by (member, side)
    o_row = np.argsort(v_rep, kind="stable")
    rows_sorted = v_rep[o_row]
    side_key = 2 * j_ent + (v_rep >= n)  # 2j = rev entries, 2j+1 = fwd
    o_ms = np.argsort(side_key, kind="stable")
    sk_sorted = side_key[o_ms]

    def ent_of(j: int, fwd: int) -> np.ndarray:
        lo, hi = np.searchsorted(sk_sorted, [2 * j + fwd, 2 * j + fwd + 1])
        return o_ms[lo:hi]

    surv: dict = {}  # (j, fwd) -> surviving rows of corrected sides

    def surviving(r: int, fwd: int) -> np.ndarray:
        got = surv.get((r, fwd))
        return got if got is not None else v_rep[ent_of(r, fwd)]

    for j in np.flatnonzero(viol_rev | viol_fwd):
        j = int(j)
        for fwd in (0, 1):
            if not (viol_fwd[j] if fwd else viol_rev[j]):
                continue
            seed = int(members[j]) + (n if fwd else 0)
            key_row = int(members[j]) + (0 if fwd else n)
            ent = ent_of(j, fwd)
            cand = v_rep[ent]  # j's labeled rows this side, seed included
            # fresh keys: surviving mate appends into the prune-source row
            lo, hi = np.searchsorted(rows_sorted, [key_row, key_row + 1])
            mask[cand] = True
            blocked = False
            for e in o_row[lo:hi]:
                r = int(j_ent[e])
                if r >= j or not keep[e]:
                    continue
                mask[surviving(r, fwd)] = False
                blocked = True
            if not blocked:  # over-flagged (keys all rolled back): no-op
                mask[cand] = False
                continue
            # a blocked seed would imply a cycle through a wave mate —
            # impossible in the condensation DAG, so the BFS always starts
            mask[seed] = False
            kept_parts = [np.asarray([seed], dtype=np.int64)]
            frontier = kept_parts[0]
            while frontier.size:
                nbrs, _ = bitset.csr_gather(indptr, indices, frontier)
                if nbrs.size == 0:
                    break
                nxt = np.unique(nbrs)
                nxt = nxt[mask[nxt]]
                if nxt.size == 0:
                    break
                mask[nxt] = False
                kept_parts.append(nxt)
                frontier = nxt
            mask[cand] = False  # reset blocked/unreached stragglers
            kept_rows = np.concatenate(kept_parts)
            surv[(j, fwd)] = kept_rows
            mask[kept_rows] = True
            keep[ent] = mask[cand]
            mask[kept_rows] = False

    # the store is only touched where an entry was actually removed: rows
    # losing nothing keep their speculative appends verbatim, so the
    # rollback-and-reappend rewrite cost tracks the violated members'
    # cones, not the whole chunk log
    removed = ~keep
    if not removed.any():  # pure over-flag: the chunk was already exact
        return
    af_rows = np.unique(v_rep[removed])
    mask[af_rows] = True
    sel = mask[v_rep]  # all log entries living in an affected row
    mask[af_rows] = False
    rows_a = v_rep[sel]
    u2, c2 = np.unique(rows_a, return_counts=True)  # u2 == af_rows
    if ON.enabled:
        trace.event("build.rollback", cat="build", rows=int(u2.shape[0]))
    store.rollback(u2, (store.lens[u2] - c2).astype(np.int32))
    # chaos hook: a crash between the watermark rollback and the surviving
    # re-append is the worst case for checkpoint resume — the store has
    # LOST the chunk's appends; resume must replay from the last boundary
    inject.fire("build.spec_replay", rows=int(u2.shape[0]))
    ksel = keep[sel]
    kv_rows, kv_vals = rows_a[ksel], vals_cat[sel][ksel]
    if kv_rows.size:
        o = np.argsort(kv_rows, kind="stable")
        rows_s, vals_s = kv_rows[o], kv_vals[o]
        u3, c3 = np.unique(rows_s, return_counts=True)
        store.append(u3, c3.astype(np.int64), vals_s)


def _scalar_replay(
    indptr: np.ndarray,
    indices: np.ndarray,
    seed: int,
    prune_row: int,
    rank: int,
    store: _LabelStore,
    prune_mark: np.ndarray,
) -> int:
    """One side of the sequential Algorithm-2 pass for one member, replayed
    against the live store.  The prune set is the member's prune-source row
    restricted to ranks BELOW its own — certified wave-mates with higher
    ranks have already appended 'future' entries that the sequential loop
    would not have seen yet, and the restriction is exactly what excludes
    them (same rank-restriction idea as ``cone_resume_sweep``).  Replaying
    violated members in ascending rank order makes each replay see exactly
    the sequential store state, so one pass per member suffices (no
    re-speculation cascades on adversarial rank-consecutive chains)."""
    pvals = store.row(prune_row)
    pv = pvals[pvals < rank]
    prune_mark[pv] = True
    seen = np.zeros(indptr.shape[0] - 1, dtype=bool)
    seen[seed] = True
    frontier = np.asarray([seed], dtype=np.int64)
    out: List[np.ndarray] = []
    while frontier.size:
        # whole-level prune test: one rectangular gather of the frontier's
        # label rows against the marked prune ranks
        lab = frontier[~store.pruned_any(frontier, prune_mark)]
        if lab.size == 0:
            break
        out.append(lab)
        nbrs, _ = bitset.csr_gather(indptr, indices, lab)
        if nbrs.size == 0:
            break
        nbrs = np.unique(nbrs)
        frontier = nbrs[~seen[nbrs]]
        seen[frontier] = True
    prune_mark[pv] = False
    if out:
        rows = np.concatenate(out)
        store.append(
            rows, np.ones(rows.shape[0], dtype=np.int64),
            np.full(rows.shape[0], rank, dtype=np.int32),
        )
        return int(rows.shape[0])
    return 0


def _build_speculative(
    g: CSRGraph,
    order: np.ndarray,
    max_wave: int = 256,
    schedule=None,
    stats_out: Optional[dict] = None,
    ckpt: Optional[_BuildCheckpointer] = None,
    fingerprint: Optional[str] = None,
    restored=None,
    stage_out: Optional[dict] = None,
) -> ReachabilityOracle:
    """Speculative wave construction: optimistic chunks + certify + bounded
    rollback-replay.  Byte-identical to the scalar reference builder."""
    n = g.n
    if n == 0:
        return finalize_labels([], [], hop_rank=np.empty(0, dtype=np.int32))
    g_rev = g.reverse()
    if schedule is None:
        schedule = speculative_schedule(g, order, max_wave=max_wave)
    ranks_of = np.arange(n, dtype=np.int32)

    indptr = g.indptr.astype(np.int64)
    indices = g.indices.astype(np.int64)
    r_indptr = g_rev.indptr.astype(np.int64)
    r_indices = g_rev.indices.astype(np.int64)
    indptr_c = np.concatenate([r_indptr, r_indptr[-1] + indptr[1:]])
    indices_c = np.concatenate([r_indices, indices + n])

    # two scratch tiers: the exact fused sweep runs contiguous 2W bits at up
    # to n_words(2 * max_wave) words, while speculative chunks cap at
    # _SPEC_CAP members so every chunk mask is exactly ONE uint64 word —
    # dedicated contiguous single-word arrays keep the rectangular prune
    # gather and all level ops flat
    k_words = bitset.n_words(2 * max_wave)
    # deep_cap=1024 keeps hub rows dense: on the dense-reachability families
    # hubs sit in most frontiers, and the per-row deep-dict loops would
    # otherwise run on every gather/append (max observed label length is a
    # few hundred, so the head matrix stays modest)
    store = _LabelStore(2 * n, deep_cap=1024, null=n)
    hop_mask = np.zeros((n + 1, k_words), dtype=np.uint64)
    visited = np.zeros((2 * n, k_words), dtype=np.uint64)
    spec_cap = min(_SPEC_CAP, max_wave)
    hop_rev1 = np.zeros((n + 1, 1), dtype=np.uint64)
    hop_fwd1 = np.zeros((n + 1, 1), dtype=np.uint64)
    visited1 = np.zeros((2 * n, 1), dtype=np.uint64)
    labeled1 = np.zeros((2 * n, 1), dtype=np.uint64)
    prune_mark = np.zeros(n + 1, dtype=bool)  # trailing always-False fill slot
    corr_mask = np.zeros(2 * n, dtype=bool)  # _correct_chunk BFS scratch

    st = {
        "spec_waves": 0, "spec_members": 0, "clean_waves": 0, "violations": 0,
        "replayed_members": 0, "replayed_sides": 0, "exact_waves": 0,
        "annotated_pairs": 0, "certify_seconds": 0.0, "replay_seconds": 0.0,
        "scalar_bailout": False,
    }
    cap = spec_cap  # adaptive optimism: current speculative chunk size
    clean_streak = 0
    start_wave, start_off, done = 0, 0, 0
    if restored is not None:
        arrays, meta = restored
        store = _LabelStore.from_arrays(arrays, meta)
        start_wave = int(meta["wave_idx"])
        start_off = int(meta["off"])
        done = int(meta["done"])
        # the adaptive state decides every later chunk boundary — restoring
        # it keeps the resumed chunk sequence identical to an uninterrupted
        # run (byte-identity needs only store state, but stats/cadence
        # should not fork either)
        cap = int(meta["cap"])
        clean_streak = int(meta["clean_streak"])
        st.update(meta["st"])

    def _spec_chunk(base: int, w: int) -> None:
        nonlocal cap, clean_streak
        members = order[base : base + w]
        ranks = ranks_of[base : base + w]
        half = bitset.member_bits(w, 1)  # w <= _SPEC_CAP: one word always
        members_c = np.concatenate([members, members + n])
        ranks_c = np.concatenate([ranks, ranks])
        hop_row_ids = np.concatenate([members + n, members])
        touched, keys_rev, keys_fwd, log = _speculative_sweep(
            members_c, ranks_c, hop_row_ids, ranks.astype(np.int64),
            ranks, half, store, indptr_c, indices_c,
            hop_rev1, hop_fwd1, visited1, labeled1,
        )
        sp = (trace.span("build.certify", cat="build", args={"w": w})
              if ON.enabled else trace.NOOP_SPAN)
        t0 = time.perf_counter()
        with sp:
            viol = _certify_chunk(members, n, 1, labeled1, log)
        st["certify_seconds"] += time.perf_counter() - t0
        st["spec_waves"] += 1
        _WAVES_SPEC.inc()
        st["spec_members"] += w
        n_viol = 0
        if viol is not None:
            viol_rev, viol_fwd = viol
            either = viol_rev | viol_fwd
            n_viol = int(either.sum())
            st["violations"] += n_viol
            st["replayed_sides"] += int(viol_rev.sum()) + int(viol_fwd.sum())
            sp = (trace.span("build.replay", cat="build",
                             args={"violations": n_viol, "w": w})
                  if ON.enabled else trace.NOOP_SPAN)
            t0 = time.perf_counter()
            with sp:
                _correct_chunk(store, log, viol_rev, viol_fwd, members, base,
                               n, indptr_c, indices_c, corr_mask)
            st["replayed_members"] += n_viol
            st["replay_seconds"] += time.perf_counter() - t0
        visited1[touched] = 0
        labeled1[touched] = 0
        hop_rev1[keys_rev] = 0
        hop_fwd1[keys_fwd] = 0
        # bounded optimism: grow the chunk cap while rollbacks stay rare
        # (certification is exact, so a few violations per chunk cost only
        # their own replays), shrink it when they dominate
        rate = n_viol / w
        if n_viol == 0:
            st["clean_waves"] += 1
        if rate <= 0.05:
            clean_streak += 1
            if clean_streak >= 2:
                cap = min(cap * 2, spec_cap)
        else:
            clean_streak = 0
            if rate > 0.25:
                cap = max(cap // 2, 8)

    def _save(wi: int, off: int, wlen: int) -> None:
        # normalize the cursor so a resume never lands past a wave's end
        if off >= wlen:
            wi, off = wi + 1, 0
        ckpt.maybe_save(done, store, {
            "impl": "speculative", "fingerprint": fingerprint,
            "wave_idx": wi, "off": off,
            "cap": cap, "clean_streak": clean_streak, "st": dict(st),
        })

    base = int(np.asarray(schedule.lengths[:start_wave], dtype=np.int64).sum())
    n_sched = int(schedule.lengths.shape[0])
    for wi in range(start_wave, n_sched):
        wlen = int(schedule.lengths[wi])
        opt = bool(schedule.optimistic[wi])
        pr = schedule.pairs[wi]
        off = start_off if wi == start_wave else 0
        if not opt:
            # proven conflict-free: the exact fused sweep, no certification,
            # run at the wave's own word width
            inject.fire("build.wave", index=wi)
            members = order[base : base + wlen]
            ranks = ranks_of[base : base + wlen]
            members_c = np.concatenate([members, members + n])
            hop_row_ids = np.concatenate([members + n, members])
            kwe = bitset.n_words(2 * wlen)
            sp = (trace.span("build.wave", cat="build",
                             args={"index": wi, "size": wlen})
                  if ON.enabled else trace.NOOP_SPAN)
            with sp:
                _wave_sweep(
                    members_c, np.concatenate([ranks, ranks]), hop_row_ids,
                    ranks.astype(np.int64), store, indptr_c, indices_c,
                    hop_mask[:, :kwe], visited[:, :kwe],
                )
            st["exact_waves"] += 1
            _WAVES_EXACT.inc()
            done += 1
            if ckpt is not None:
                _save(wi, wlen, wlen)
        else:
            if off == 0 and isinstance(pr, np.ndarray):
                # a resumed wave (off > 0) already counted its pairs before
                # the checkpoint was taken
                st["annotated_pairs"] += int(pr.shape[0])
            while off < wlen:
                c = min(cap, wlen - off)
                inject.fire("build.chunk", index=done, wave=wi, off=off)
                # the chunk's lowest-ranked member can never be violated, so
                # the replay fraction is capped at (w - 1) / w = 0.875 at the
                # minimum cap of 8 — 0.85 sits just under that ceiling
                # (reachable by a true adversarial chain) and far above
                # healthy workloads
                if not st["scalar_bailout"] and (
                    st["spec_members"] >= 2048 and cap <= 8
                    and st["replayed_members"] > 0.85 * st["spec_members"]
                ):
                    st["scalar_bailout"] = True
                if st["scalar_bailout"]:
                    # worst case (adversarial chains): speculation keeps
                    # losing even at the minimum cap — degrade to the
                    # sequential scalar loop for the remaining optimistic
                    # ranks (chunk-wise, so the checkpoint cursor still
                    # covers it), bounding total work at ~reference cost
                    sp = (trace.span("build.chunk", cat="build",
                                     args={"wave": wi, "off": off, "size": c,
                                           "mode": "scalar_bailout"})
                          if ON.enabled else trace.NOOP_SPAN)
                    with sp:
                        for j in range(off, off + c):
                            v_j = int(order[base + j])
                            rank_j = base + j
                            _scalar_replay(indptr_c, indices_c, v_j, n + v_j,
                                           rank_j, store, prune_mark)
                            _scalar_replay(indptr_c, indices_c, n + v_j, v_j,
                                           rank_j, store, prune_mark)
                    _WAVES_BAILOUT.inc()
                else:
                    sp = (trace.span("build.chunk", cat="build",
                                     args={"wave": wi, "off": off, "size": c,
                                           "mode": "speculative"})
                          if ON.enabled else trace.NOOP_SPAN)
                    with sp:
                        _spec_chunk(base + off, c)
                off += c
                done += 1
                if ckpt is not None:
                    _save(wi, off, wlen)
        base += wlen

    if stats_out is not None:
        st["violation_rate"] = round(
            st["violations"] / max(st["spec_members"], 1), 4)
        st["certify_seconds"] = round(st["certify_seconds"], 4)
        st["replay_seconds"] = round(st["replay_seconds"], 4)
        stats_out.update(st)
    oracle = ReachabilityOracle(
        L_out=store.finalize(0, n),
        L_in=store.finalize(n, 2 * n),
        out_len=store.lens[:n].copy(),
        in_len=store.lens[n:].copy(),
        hop_rank=_hop_rank(order, n),
    )
    if stage_out is not None:
        stage_out.update(store.stage_seconds)
        stage_out["certify"] = st["certify_seconds"]
        stage_out["replay"] = st["replay_seconds"]
    return oracle


def sort_label_rows(mat: np.ndarray) -> np.ndarray:
    """Canonicalize INVALID-padded label rows: ascending values, pads last.

    Shared by the device builders (``core/distribution_jax.py``,
    ``build/engine_jax.py``) whose scatters append out of order.
    """
    big = np.iinfo(np.int32).max
    key = np.sort(np.where(mat == INVALID, big, mat), axis=1)
    return np.where(key == big, INVALID, key).astype(np.int32)
