"""Distribution-Labeling construction engine (paper §5, Algorithm 2).

Two host implementations of the same algorithm, differentially tested to be
byte-identical:

``impl="reference"``
    The seed scalar path: per-vertex pruned BFS with python sets + deque
    (via the shared ``traverse.pruned_bfs_distribute`` helper).  Kept as the
    ground-truth implementation.

``impl="wave"``
    The bit-parallel engine.  The §5.2 rank order is partitioned into
    *waves* of mutually unreachable vertices (``waves.wave_schedule``); each
    wave's up-to-256 pruned BFS sweeps run as ONE batched level-synchronous
    sweep over packed uint64 member masks:

      * frontier / visited state: uint64[n, K] — bit j = "wave member j",
      * prune test: ``hop_mask`` maps hop rank h -> mask of members whose
        source label contains h, so Algorithm 2's per-vertex set probe
        ``L_out(u) ∩ L_in(v_i) != ∅`` becomes one ragged gather of u's
        label entries plus a word-wide OR-reduce — no per-element set
        operations,
      * label append: grouped vectorized writes into ``_LabelStore`` (dense
        int32 head rows + side lists for the rare deep rows, so a handful
        of hub labels never force full-matrix growth copies).

    Why waves are exact: within a wave no member reaches another, so no
    member's append can appear in another member's prune source set (v_i in
    L_in(v_j) would require v_i -> v_j), and intra-wave ranks cannot occur
    in any wave-start label.  Hence every prune verdict equals the one the
    sequential loop would produce, and label *sets* match exactly; rows are
    sorted once at the end, giving byte-identical finalized labels.

``impl="device"``
    The sparse device wave engine (``engine_jax.py``): the same wave
    schedule, with the intra-wave sweep running on the accelerator through
    the packed-frontier ELL expansion kernel and an on-device segment-
    scatter label append.  Byte-identical to both host paths.

``impl="auto"`` (default) picks "reference" for small graphs — the batched
sweeps only pay off once there are enough vertices to amortize them — then
"device" when an accelerator is attached (jax backend != cpu) and "wave"
otherwise.

Every oracle built here carries a ``build_stats`` breadcrumb:
``{"impl", "scheduler", "schedule_seconds", "sweep_seconds", "n_waves"}`` —
the scheduler-cost breakdown BENCH_build.json tracks (the ROADMAP's
"scheduler is 20-40% of wave builds" claim, measured per build).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from repro.build import bitset
# cone_resume_sweep is the engine's cone-scoped construction entry point
# (repro.dynamic repairs labels through it); it lives in traverse.py beside
# the sibling scalar sweep it generalizes
from repro.build.traverse import cone_resume_sweep, pruned_bfs_distribute  # noqa: F401
from repro.build.waves import wave_schedule
from repro.core.oracle import ReachabilityOracle, finalize_labels
from repro.core.order import get_order
from repro.graph.csr import CSRGraph, INVALID

_PAD_MULTIPLE = 8
# below this vertex count the scalar reference path wins (numpy dispatch
# overhead dominates the batched sweeps)
_AUTO_WAVE_MIN = 4096
# impl="auto" falls back to the reference builder when the schedule's mean
# wave is smaller than this — per-wave overhead would dominate
_AUTO_MIN_AVG_WAVE = 24.0


def _device_backend_available() -> bool:
    """True when jax sees an accelerator (the device engine's auto gate)."""
    try:
        import jax

        return jax.default_backend() != "cpu"
    except Exception:  # jax missing/broken: host paths still work
        return False


def build_distribution_labels(
    g: CSRGraph,
    order: Optional[np.ndarray] = None,
    order_name: str = "degree_product",
    impl: str = "auto",
    max_wave: int = 256,
    scheduler: str = "onepass",
    **device_kwargs,
) -> ReachabilityOracle:
    """Build the DL oracle for DAG ``g`` with the selected implementation.

    ``device_kwargs`` (``expand=``, ``l_max=``, ``ell_width=``, ``mesh=``,
    ...) forward to the device engine and are rejected for the host impls —
    a typo'd tuning knob must not silently no-op.
    """
    if device_kwargs and impl not in ("device", "auto"):
        raise TypeError(
            f"impl={impl!r} accepts no extra kwargs (got {sorted(device_kwargs)}); "
            "they apply to the device engine only")
    if order is None:
        order = get_order(g, order_name)
    order = np.asarray(order, dtype=np.int64)
    waves = None
    t_sched = 0.0
    if impl == "auto":
        if g.n < _AUTO_WAVE_MIN:
            impl = "reference"
        else:
            # the schedule itself is the profitability probe: dense
            # high-reachability graphs (true conflicts everywhere) yield
            # tiny waves that cannot amortize the batched sweeps
            t0 = time.perf_counter()
            waves = wave_schedule(
                g, order, max_wave=max_wave, scheduler=scheduler,
                abort_below_avg=_AUTO_MIN_AVG_WAVE / 3,
            )
            t_sched = time.perf_counter() - t0
            if waves is None or g.n / waves.shape[0] < _AUTO_MIN_AVG_WAVE:
                impl, waves = "reference", None
            else:
                impl = "device" if _device_backend_available() else "wave"
    if device_kwargs and impl not in ("device",):
        # auto resolved to a host impl: device tuning knobs will not apply
        # on THIS host — say so instead of silently no-opping
        import warnings

        warnings.warn(
            f"device kwargs {sorted(device_kwargs)} ignored: impl resolved "
            f"to {impl!r} on this host", stacklevel=2)
    if impl in ("wave", "bitset", "device") and waves is None:
        t0 = time.perf_counter()
        waves = wave_schedule(g, order, max_wave=max_wave, scheduler=scheduler)
        t_sched = time.perf_counter() - t0
    t0 = time.perf_counter()
    if impl in ("reference", "ref"):
        oracle = _build_reference(g, order)
        impl = "reference"
    elif impl in ("wave", "bitset"):
        oracle = _build_wave(g, order, max_wave=max_wave, waves=waves)
        impl = "wave"
    elif impl == "device":
        from repro.build.engine_jax import distribution_labeling_device

        oracle = distribution_labeling_device(
            g, order=order, waves=waves, **device_kwargs
        )
    else:
        raise ValueError(f"unknown construction impl {impl!r}")
    t_sweep = time.perf_counter() - t0
    # breadcrumbs for benchmarks/telemetry: which engine actually built this
    # and where the time went (scheduler share is a tracked BENCH metric)
    object.__setattr__(oracle, "build_impl", impl)
    object.__setattr__(oracle, "build_stats", {
        "impl": impl,
        "scheduler": scheduler if waves is not None else None,
        "schedule_seconds": round(t_sched, 4),
        "sweep_seconds": round(t_sweep, 4),
        "n_waves": None if waves is None else int(waves.shape[0]),
    })
    return oracle


# ---------------------------------------------------------------------------
# reference scalar implementation (the seed path)
# ---------------------------------------------------------------------------


def _build_reference(g: CSRGraph, order: np.ndarray) -> ReachabilityOracle:
    n = g.n
    g_rev = g.reverse()

    # Python sets give C-speed isdisjoint (the pruning hot path); parallel
    # lists keep insertion order for the final packed arrays.
    L_out_sets = [set() for _ in range(n)]
    L_in_sets = [set() for _ in range(n)]
    L_out_lists: list[list[int]] = [[] for _ in range(n)]
    L_in_lists: list[list[int]] = [[] for _ in range(n)]

    visited = np.full(n, -1, dtype=np.int64)  # iteration stamp, avoids clearing

    for it, vi in enumerate(order):
        vi = int(vi)
        # reverse BFS: distribute vi into L_out of its ancestors
        pruned_bfs_distribute(
            g_rev.indptr, g_rev.indices, vi, L_in_sets[vi],
            L_out_sets, L_out_lists, visited, 2 * it,
        )
        # forward BFS: distribute vi into L_in of its descendants
        pruned_bfs_distribute(
            g.indptr, g.indices, vi, L_out_sets[vi],
            L_in_sets, L_in_lists, visited, 2 * it + 1,
        )

    return finalize_labels(L_out_lists, L_in_lists, hop_rank=_hop_rank(order, n))


# ---------------------------------------------------------------------------
# wave-scheduled bitset implementation
# ---------------------------------------------------------------------------


def _hop_rank(order: np.ndarray, n: int) -> np.ndarray:
    """rank[order[i]] = i — the rank-space remap shared by all impls."""
    hop_rank = np.empty(n, dtype=np.int32)
    hop_rank[order] = np.arange(n, dtype=np.int32)
    return hop_rank


class _LabelStore:
    """Ragged rank-space label rows under construction.

    Dense int32[n, cap] head rows (cap grows geometrically up to DEEP_CAP)
    hold columns < len; a few *deep* rows (hub labels can reach hundreds of
    hops while the average stays single-digit) spill their tail into python
    lists so they never force O(n x max_len) matrix growth.  No pad values
    anywhere: every reader walks columns < len.
    """

    DEEP_CAP = 64

    def __init__(self, n: int):
        self.n = n
        self.mat = np.empty((n, _PAD_MULTIPLE), dtype=np.int32)
        self.lens = np.zeros(n, dtype=np.int32)
        self.deep: Dict[int, List[int]] = {}

    # -- writes ---------------------------------------------------------

    def append(self, verts: np.ndarray, counts: np.ndarray, vals: np.ndarray) -> None:
        """Append ``counts[k]`` rank values to row verts[k] (vals row-major)."""
        row_lens = self.lens[verts].astype(np.int64)
        new_lens = row_lens + counts
        need = int(new_lens.max())
        if need > self.mat.shape[1] and self.mat.shape[1] < self.DEEP_CAP:
            cap = self.mat.shape[1]
            while cap < min(need, self.DEEP_CAP):
                cap *= 2
            grown = np.empty((self.n, cap), dtype=np.int32)
            grown[:, : self.mat.shape[1]] = self.mat
            self.mat = grown
        if need > self.DEEP_CAP:
            shallow = new_lens <= self.DEEP_CAP
            if not shallow.all():
                self._append_deep(verts, counts, vals, shallow)
                if not shallow.any():
                    return
                keep = np.repeat(shallow, counts)
                verts, counts, row_lens = verts[shallow], counts[shallow], row_lens[shallow]
                vals = vals[keep]
        if int(counts.max()) == 1:  # common case: one member labels each vertex
            self.mat[verts, row_lens] = vals
            self.lens[verts] += 1
            return
        total = int(counts.sum())
        v_rep = np.repeat(verts, counts)
        cum = np.cumsum(counts)
        within = np.arange(total, dtype=np.int64) - np.repeat(cum - counts, counts)
        self.mat[v_rep, np.repeat(row_lens, counts) + within] = vals
        self.lens[verts] += counts.astype(np.int32)

    def _append_deep(self, verts, counts, vals, shallow) -> None:
        """Slow path for rows crossing/beyond DEEP_CAP (a handful per build)."""
        offs = np.concatenate(([0], np.cumsum(counts)))
        for k in np.flatnonzero(~shallow):
            v = int(verts[k])
            row_vals = vals[offs[k] : offs[k + 1]].tolist()
            ln = int(self.lens[v])
            tail = self.deep.setdefault(v, [])
            room = self.DEEP_CAP - ln
            if room > 0:  # fill the dense head first
                self.mat[v, ln : self.DEEP_CAP] = row_vals[:room]
                row_vals = row_vals[room:]
            tail.extend(row_vals)
            self.lens[v] += counts[k]

    # -- reads ----------------------------------------------------------

    def row(self, v: int) -> np.ndarray:
        """Full label row of one vertex (deep tail included)."""
        ln = int(self.lens[v])
        head = self.mat[v, : min(ln, self.DEEP_CAP)]
        if ln <= self.DEEP_CAP:
            return head
        return np.concatenate([head, np.asarray(self.deep[v], dtype=np.int32)])

    def ragged_entries(self, verts: np.ndarray):
        """(values int32[t], lens int64[k]) — concatenated label entries of
        ``verts`` in order, deep tails included."""
        lens = self.lens[verts].astype(np.int64)
        head_lens = np.minimum(lens, self.DEEP_CAP) if self.deep else lens
        total = int(head_lens.sum())
        cum = np.cumsum(head_lens)
        col = np.arange(total, dtype=np.int64) - np.repeat(cum - head_lens, head_lens)
        vals = self.mat[np.repeat(verts, head_lens), col]
        if self.deep and (lens > self.DEEP_CAP).any():
            parts: List[np.ndarray] = []
            prev = 0
            for k in np.flatnonzero(lens > self.DEEP_CAP):
                parts.append(vals[prev : int(cum[k])])
                parts.append(np.asarray(self.deep[int(verts[k])], dtype=np.int32))
                prev = int(cum[k])
            parts.append(vals[prev:])
            vals = np.concatenate(parts)
        return vals, lens

    def pruned_or(self, frontier: np.ndarray, hop_mask: np.ndarray) -> np.ndarray:
        """Member masks pruned[f] = OR_{h in L(frontier[f])} hop_mask[h],
        gathered raggedly so cost tracks actual label ints, not row width."""
        lens = self.lens[frontier].astype(np.int64)
        out = np.zeros((frontier.shape[0], hop_mask.shape[1]), dtype=np.uint64)
        head_lens = np.minimum(lens, self.DEEP_CAP) if self.deep else lens
        total = int(head_lens.sum())
        if total:
            nz = head_lens > 0
            rows = frontier[nz]
            ln = head_lens[nz]
            cum = np.cumsum(ln)
            col = np.arange(int(cum[-1]), dtype=np.int64) - np.repeat(cum - ln, ln)
            hits = hop_mask[self.mat[np.repeat(rows, ln), col]]  # [t, K]
            out[nz] = np.bitwise_or.reduceat(hits, cum - ln, axis=0)
        if self.deep:
            for k in np.flatnonzero(lens > self.DEEP_CAP):  # rare deep rows
                tail = np.asarray(self.deep[int(frontier[k])], dtype=np.int64)
                out[k] |= np.bitwise_or.reduce(hop_mask[tail], axis=0)
        return out

    # -- finalize -------------------------------------------------------

    def finalize(self, start: int = 0, stop: Optional[int] = None) -> np.ndarray:
        """Sort rows [start, stop) ascending, pack into the reference padding
        (multiple of 8, min 8, INVALID-padded) — byte-compatible with
        ``finalize_labels``.  The range lets one store hold both label sides
        (the fused sweep's role-split layout)."""
        stop = self.n if stop is None else stop
        lens = self.lens[start:stop]
        mat = self.mat[start:stop]
        k = stop - start
        lmax = int(lens.max()) if k else 1
        width = max(
            ((max(lmax, 1) + _PAD_MULTIPLE - 1) // _PAD_MULTIPLE) * _PAD_MULTIPLE,
            _PAD_MULTIPLE,
        )
        out = np.full((k, width), INVALID, dtype=np.int32)
        # sort rows bucketed by length so short rows (the vast majority)
        # don't pay for the width a few deep rows force
        lo = 0
        b = _PAD_MULTIPLE
        cols = np.arange(width, dtype=np.int32)
        lens64 = lens.astype(np.int64)
        big = np.int32(self.n)  # sorts past every rank
        while lo < min(lmax, self.DEEP_CAP):
            sel = np.flatnonzero((lens64 > lo) & (lens64 <= min(b, self.DEEP_CAP)))
            if sel.size:
                w = min(b, self.DEEP_CAP)
                in_row = cols[None, :w] < lens64[sel, None]
                sub = np.where(in_row, mat[sel[:, None], cols[None, :w]], big)
                sub.sort(axis=1)
                out[sel[:, None], cols[None, :w]] = np.where(in_row, sub, INVALID)
            lo = b
            b *= 2
        for v in self.deep:  # rare deep rows, one by one
            if start <= v < stop:
                out[v - start, : lens64[v - start]] = np.sort(self.row(v))
        return out


def _wave_sweep(
    members_c: np.ndarray,    # int64[2W] role-split ids: rev members + fwd (+n)
    ranks_c: np.ndarray,      # int32[2W] their global ranks (duplicated)
    hop_row_ids: np.ndarray,  # int64[2W] store rows feeding each BFS's prune test
    extra_hop_keys: np.ndarray,  # int64[W] wave ranks (fwd prune sets include v_j)
    store: _LabelStore,       # role-split labels: rows < n L_out, rows >= n L_in
    indptr: np.ndarray,       # combined CSR: rev graph rows then fwd (+n) rows
    indices: np.ndarray,
    hop_mask: np.ndarray,     # uint64[n + 1, K] scratch, zeros on entry/exit
    visited: np.ndarray,      # uint64[2n, K] scratch, zeros on entry/exit
) -> None:
    """Both directions of Algorithm 2 for a whole wave, fused: the reverse
    sweeps run in the [0, n) half of the role-split graph, the forward
    sweeps in [n, 2n), with disjoint member bits — one level loop drives up
    to 2 * max_wave pruned BFS at once."""
    w2 = members_c.shape[0]
    w = w2 // 2
    mbits = bitset.member_bits(w2, hop_mask.shape[1])  # uint64[2W, K]

    # hop_mask[h] = mask of member BFS whose prune set contains hop h: the
    # reverse BFS of v_j prunes on L_in(v_j) (store row n + v_j), the
    # forward BFS on L_out(v_j) ∪ {rank_j} (store row v_j + an extra key —
    # v_j itself joins L_out(v_j) during this very wave).  Hop keys live in
    # one rank space, but member bits are disjoint across roles, so a single
    # table serves both; foreign-role bits are masked off by fbits.  Members
    # may share hops (a common high-rank ancestor), so the scatter must OR.
    hop_vals, hop_lens = store.ragged_entries(hop_row_ids)
    hm_keys, hm_bits = bitset.group_or(
        np.concatenate([hop_vals, extra_hop_keys]),  # int32 + int64 upcasts
        np.concatenate([mbits[np.repeat(np.arange(w2), hop_lens)], mbits[w:]]),
    )
    hop_mask[hm_keys] = hm_bits

    visited[members_c] = mbits
    touched = [members_c]

    # level 0 specialization: every member labels itself (the self prune
    # test L_out(v) ∩ L_in(v) is empty in a DAG) and expands — skip the
    # generic prune/expand machinery for it
    store.append(members_c, np.ones(w2, dtype=np.int64), ranks_c)
    nbrs0, seg0 = bitset.csr_gather(indptr, indices, members_c)
    if nbrs0.size == 0:
        visited[members_c] = 0
        hop_mask[hm_keys] = 0
        return
    uniq0, obits0 = bitset.group_or(nbrs0, mbits[seg0])
    new0 = obits0 & ~visited[uniq0]
    keep0 = new0.any(axis=1)
    frontier = uniq0[keep0]
    fbits = new0[keep0]
    visited[frontier] |= fbits
    touched.append(frontier)

    while frontier.size:
        # prune test, whole frontier at once: OR the member masks of every
        # frontier vertex's current label entries.  Intra-wave appends can
        # appear in rows, but only the static wave-start verdict bits ever
        # intersect fbits (see waves.py for why).
        pruned = store.pruned_or(frontier, hop_mask)
        lab = fbits & ~pruned
        active = lab.any(axis=1)
        if not active.any():
            break
        v_lab = frontier[active]
        bits = lab[active]

        # label append: expand member masks to (vertex, member) pairs —
        # row-major, so values per row arrive member- (= rank-) ascending
        _, member, counts = bitset.expand_member_bits(bits, w2)
        store.append(v_lab, counts, ranks_c[member])

        # expansion: only labeled (un-pruned) vertices expand, carrying
        # exactly their labeled member bits
        nbrs, seg = bitset.csr_gather(indptr, indices, v_lab)
        if nbrs.size == 0:
            break
        uniq, obits = bitset.group_or(nbrs, bits[seg])  # indices already int64
        new = obits & ~visited[uniq]
        keep = new.any(axis=1)
        frontier = uniq[keep]
        fbits = new[keep]
        visited[frontier] |= fbits
        touched.append(frontier)

    # scratch cleanup (exactly the entries we wrote)
    visited[np.concatenate(touched)] = 0
    hop_mask[hm_keys] = 0


def _build_wave(
    g: CSRGraph,
    order: np.ndarray,
    max_wave: int = 256,
    waves: Optional[np.ndarray] = None,
) -> ReachabilityOracle:
    n = g.n
    if n == 0:
        return finalize_labels([], [], hop_rank=np.empty(0, dtype=np.int32))
    g_rev = g.reverse()
    if waves is None:
        waves = wave_schedule(g, order, max_wave=max_wave)
    ranks_of = np.arange(n, dtype=np.int32)

    # role-split layout: ids [0, n) run the reverse BFS over the reverse
    # graph and write L_out; ids [n, 2n) run the forward BFS over the
    # forward graph and write L_in.  One combined CSR + one label store let
    # a single level loop drive both directions of a wave.
    indptr = g.indptr.astype(np.int64)
    indices = g.indices.astype(np.int64)
    r_indptr = g_rev.indptr.astype(np.int64)
    r_indices = g_rev.indices.astype(np.int64)
    indptr_c = np.concatenate([r_indptr, r_indptr[-1] + indptr[1:]])
    indices_c = np.concatenate([r_indices, indices + n])

    k_words = bitset.n_words(2 * max_wave)
    store = _LabelStore(2 * n)
    hop_mask = np.zeros((n + 1, k_words), dtype=np.uint64)
    visited = np.zeros((2 * n, k_words), dtype=np.uint64)

    base = 0
    for wlen in waves:
        wlen = int(wlen)
        members = order[base : base + wlen]
        ranks = ranks_of[base : base + wlen]
        members_c = np.concatenate([members, members + n])
        ranks_c = np.concatenate([ranks, ranks])
        # reverse BFS prunes on L_in rows (store n + v), forward on L_out
        # rows (store v) plus the member's own rank
        hop_row_ids = np.concatenate([members + n, members])
        _wave_sweep(
            members_c, ranks_c, hop_row_ids, ranks.astype(np.int64),
            store, indptr_c, indices_c, hop_mask, visited,
        )
        base += wlen

    return ReachabilityOracle(
        L_out=store.finalize(0, n),
        L_in=store.finalize(n, 2 * n),
        out_len=store.lens[:n].copy(),
        in_len=store.lens[n:].copy(),
        hop_rank=_hop_rank(order, n),
    )


def sort_label_rows(mat: np.ndarray) -> np.ndarray:
    """Canonicalize INVALID-padded label rows: ascending values, pads last.

    Shared by the device builders (``core/distribution_jax.py``,
    ``build/engine_jax.py``) whose scatters append out of order.
    """
    big = np.iinfo(np.int32).max
    key = np.sort(np.where(mat == INVALID, big, mat), axis=1)
    return np.where(key == big, INVALID, key).astype(np.int32)
