"""Device formulation of the wave-batched construction sweep.

The host engine (``engine.py``) and this module share one dataflow per wave
and per direction:

  1. prune:   pruned[u] = OR_{h in L(u)} hop_mask[h]      (gather + OR-reduce)
  2. reach:   masked multi-source BFS from the wave members where pruned
              member-bits do not expand                    (OR-AND semiring)
  3. append:  labeled = visited & ~pruned -> rank appends  (output-sized)

On device, step 2 is exactly the Pallas ``kernels/bitset_mm.py`` OR-AND
kernel: one BFS level for all <= 64 member BFS sweeps is
``bitset_mm(adjacency_bits, frontier_words)`` over packed uint32 words.
Step 1 is a dense gather over the label matrix — the same membership-LUT
dataflow as ``core/distribution_jax.py``'s per-vertex sweep, batched over
the wave.  Because prune verdicts within a wave are static (no member's
append can flip another member's test — see ``waves.py``), the whole wave
reaches fixpoint on device with zero host round-trips per level.

This builder materializes packed adjacency bits (n x n/32), so it is the
*small-graph demonstrator* of the device dataflow; the production-scale
sharded build remains ``distribution_jax.build_sweep`` (vertex-sharded,
edge-list expansion).  Both produce labels byte-identical to the host
engine's — asserted in tests.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.build import bitset
from repro.build.engine import _hop_rank, _LabelStore
from repro.build.waves import wave_schedule
from repro.core.oracle import ReachabilityOracle, finalize_labels
from repro.core.order import get_order
from repro.graph.csr import CSRGraph


def _padded_rows(store: _LabelStore, pad: int) -> np.ndarray:
    """Materialize the store's ragged label rows as a dense pad-filled matrix
    (the device gather operand); columns >= len become ``pad``."""
    lens = store.lens
    used = max(int(lens.max()), 1)
    out = np.full((store.n, used), pad, dtype=np.int32)
    head = min(used, store.mat.shape[1])
    cols = np.arange(head, dtype=np.int32)
    out[:, :head] = np.where(cols[None, :] < lens[:, None], store.mat[:, :head], pad)
    for v in store.deep:
        row = store.row(v)
        out[v, : row.shape[0]] = row
    return out


def _wave_sweep_device(
    members: np.ndarray,
    ranks: np.ndarray,
    src: _LabelStore,       # label rows feeding the prune test
    tgt: _LabelStore,       # labels being distributed into
    adj_bits,               # jnp uint32[n, ceil(n/32)] expansion operand
    n: int,
    interpret: bool,
) -> None:
    """One direction of Algorithm 2 for a whole wave, frontier expansion on
    device through the OR-AND kernel."""
    import jax.numpy as jnp

    from repro.kernels.ops import bitset_mm

    w = members.shape[0]
    wm = (w + 31) // 32
    pad = n

    # hop_mask[h] = uint32 member words of members whose prune row contains h
    hop_mask = np.zeros((n + 1, wm), dtype=np.uint32)
    word = np.arange(w) // 32
    bit = np.uint32(1) << (np.arange(w, dtype=np.uint32) % np.uint32(32))
    for j in range(w):  # W <= 64 rows, host-side setup
        hops = src.row(int(members[j]))
        hop_mask[hops, word[j]] |= bit[j]

    # 1. static prune verdicts: gather every vertex's label row, OR the words
    hm = jnp.asarray(hop_mask)
    rows = jnp.asarray(_padded_rows(tgt, pad))
    pruned = jnp.bitwise_or.reduce(hm[rows], axis=1)  # [n, wm]

    # 2. fixpoint masked reach: one bitset_mm per BFS level, all members at once
    start = np.zeros((n, wm), dtype=np.uint32)
    start[members, word] = bit
    visited = jnp.asarray(start)
    while True:
        expand = visited & ~pruned
        new = visited | bitset_mm(adj_bits, expand, interpret=interpret)
        if not bool(jnp.any(new != visited)):
            break
        visited = new

    # 3. labeled = visited & ~pruned -> host append (output-sized traffic)
    labeled = np.asarray(visited & ~pruned)
    masks = bitset.words_u32_to_u64(labeled)
    verts = np.flatnonzero(masks.any(axis=1))
    if verts.size == 0:
        return
    bits = masks[verts]
    _, member, counts = bitset.expand_member_bits(bits, w)
    tgt.append(verts, counts, ranks[member])


def distribution_labeling_wave_jax(
    g: CSRGraph,
    order: Optional[np.ndarray] = None,
    order_name: str = "degree_product",
    max_wave: int = 64,
    interpret: bool | None = None,
) -> ReachabilityOracle:
    """Full device wave build (host loop over waves, device sweeps)."""
    import jax
    import jax.numpy as jnp

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = g.n
    if n == 0:
        return finalize_labels([], [], hop_rank=np.empty(0, dtype=np.int32))
    if order is None:
        order = get_order(g, order_name)
    order = np.asarray(order, dtype=np.int64)
    g_rev = g.reverse()
    waves = wave_schedule(g, order, max_wave=max_wave)

    # reverse pass expands u -> in-neighbors w (edge w->u): A[w, u] = w->u,
    # i.e. packed OUT-neighbor rows; forward pass symmetric with the reverse
    # graph's rows
    a_out = jnp.asarray(bitset.adjacency_bits_u32(g.indptr, g.indices, n))
    a_in = jnp.asarray(bitset.adjacency_bits_u32(g_rev.indptr, g_rev.indices, n))

    L_out = _LabelStore(n)
    L_in = _LabelStore(n)
    ranks_of = np.arange(n, dtype=np.int32)

    base = 0
    for wlen in waves:
        wlen = int(wlen)
        members = order[base : base + wlen]
        ranks = ranks_of[base : base + wlen]
        _wave_sweep_device(members, ranks, L_in, L_out, a_out, n, interpret)
        _wave_sweep_device(members, ranks, L_out, L_in, a_in, n, interpret)
        base += wlen

    return ReachabilityOracle(
        L_out=L_out.finalize(),
        L_in=L_in.finalize(),
        out_len=L_out.lens,
        in_len=L_in.lens,
        hop_rank=_hop_rank(order, n),
    )
