"""Sparse device formulation of the wave-batched construction sweep.

The host engine (``engine.py``) and this module share one dataflow per wave
and per direction:

  1. prune:   pruned[u] = OR_{h in L(u)} hop_mask[h]      (gather + OR-reduce)
  2. reach:   masked multi-source BFS from the wave members where pruned
              member-bits do not expand                    (ELL OR-gather)
  3. append:  labeled = visited & ~pruned -> rank appends  (segment scatter)

Everything inside a wave runs ON DEVICE:

  * frontier expansion is the packed-frontier ELL kernel
    (``kernels/frontier_ell.py``) over the degree-sorted neighbor slabs of
    ``bitset.ell_slabs`` — operand footprint O(m + n*width), never the dense
    ``n x n/32`` adjacency bits the old demonstrator materialized
    (``expand="xla"`` swaps the Pallas call for an equivalent jnp gather —
    the fast path on CPU hosts, same dataflow),
  * the BFS fixpoint is a ``lax.while_loop`` — zero host round-trips per
    level (prune verdicts within a wave are static, see ``waves.py``),
  * the label append is a device segment scatter: member bits unpack to
    per-vertex column positions (``lens + prefix-popcount``) and one
    ``.at[rows, cols].set(ranks, mode="drop")`` lands every (vertex, rank)
    append of the wave into the dense label matrix.  Per-level results
    never round-trip to host; only a one-word overflow flag is read back
    per direction, and the label matrices come down ONCE at finalize.
  * with ``mesh=`` given, each slab's expansion runs under ``shard_map``
    with destination rows sharded over the mesh's data axes and the (tiny,
    packed) frontier words replicated — the vertex-sharded layout of
    ``core/distribution_jax.py``; waves stay sequential, the sweep inside a
    wave is embarrassingly data-parallel.

Labels are byte-identical to the host engine's — asserted in tests across
the serve-test graph families.
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np

from repro.build import bitset
from repro.build.engine import _hop_rank, sort_label_rows
from repro.build.waves import wave_schedule
from repro.obs import trace
from repro.obs.state import ON
from repro.core.oracle import ReachabilityOracle, finalize_labels
from repro.core.order import get_order
from repro.graph.csr import CSRGraph, INVALID


def _expand_fn(slabs, pos_of, n, wm, expand_impl, interpret, block_n, mesh):
    """Build the per-level expansion closure: frontier words [n, wm] ->
    OR-gathered words [n, wm] (one BFS step for every member at once)."""
    import jax.numpy as jnp

    def _slab_xla(slab, f_pad):
        idx = jnp.where(slab == INVALID, n, slab)
        return jnp.bitwise_or.reduce(f_pad[idx], axis=1)

    if mesh is not None:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        axes = tuple(ax for ax in mesh.axis_names if ax != "model")
        shards = 1
        for ax in axes:
            shards *= mesh.shape[ax]

        def _sharded(slab, f_pad):
            pad = (-slab.shape[0]) % shards
            if pad:
                slab = jnp.pad(slab, ((0, pad), (0, 0)), constant_values=INVALID)
            out = shard_map(
                _slab_xla, mesh=mesh,
                in_specs=(P(axes, None), P(None, None)),
                out_specs=P(axes, None),
            )(slab, f_pad)
            return out[: out.shape[0] - pad] if pad else out

        slab_fn = _sharded
    elif expand_impl == "pallas":
        from repro.kernels.ops import frontier_or

        def slab_fn(slab, f_pad):
            return frontier_or(slab, f_pad[:-1], block_n=block_n, interpret=interpret)
    else:
        slab_fn = _slab_xla

    slab_arrs = [jnp.asarray(s) for s in slabs]
    pos = jnp.asarray(pos_of)

    def expand(f):  # uint32[n, wm] -> uint32[n, wm]
        f_pad = jnp.concatenate([f, jnp.zeros((1, wm), dtype=jnp.uint32)])
        out_perm = jnp.zeros((n, wm), dtype=jnp.uint32)
        for slab in slab_arrs:
            r = slab.shape[0]
            part = slab_fn(slab, f_pad)
            out_perm = out_perm.at[:r, :].set(out_perm[:r] | part)
        return out_perm[pos]

    return expand


def _make_wave_step(n, w, l_max, expand, prune_cap=None, donate=False):
    """One direction of Algorithm 2 for a whole wave, fully on device.

    ``prune_cap``: prune verdicts are computed lazily per level for the
    rows the BFS actually visited — a fixed-size ``prune_cap`` gather when
    the new frontier fits (cost tracks cone size, not n), falling back to
    the dense all-rows reduce on levels that visit more.  ``donate=True``
    donates the target label matrix and length vector into the jit so the
    append updates in place instead of device-to-device copying the whole
    matrix every wave; the step returns the pre-wave lengths so an
    overflowing sweep can be undone (appends only wrote columns past the
    old watermark) before growing and re-running.
    """
    import jax
    import jax.numpy as jnp

    wm = (w + 31) // 32
    word = np.arange(w, dtype=np.int32) // 32
    bit = np.uint32(1) << (np.arange(w, dtype=np.uint32) % np.uint32(32))
    if prune_cap is None:
        prune_cap = max(256, n // 8)
    prune_cap = min(prune_cap, n)

    def wave_step(L_src, L_tgt, len_tgt, members, valid, ranks):
        wordj = jnp.asarray(word)
        bitj = jnp.asarray(bit)

        # 1. hop_mask[h] = member words of members whose prune row holds h.
        #    Scatter-ADD is exact: each (member, hop) pair is unique, and
        #    distinct members in one word carry distinct bits, so add == OR.
        #    Row n stays zero (gather parking); row n+1 absorbs the scatter
        #    parking of padded member slots and INVALID label entries.
        rows_src = L_src[jnp.where(valid, members, 0)]  # [w, l_max]
        hops = jnp.where(valid[:, None] & (rows_src != INVALID), rows_src, n + 1)
        hop_mask = jnp.zeros((n + 2, wm), dtype=jnp.uint32)
        hop_mask = hop_mask.at[hops, wordj[:, None]].add(bitj[:, None])

        tgt_hops = jnp.where(L_tgt != INVALID, L_tgt, n)  # [n, l_max]

        # 2. fixpoint masked reach — a device while_loop, no host syncs.
        #    Verdicts are filled in lazily: each level computes them for the
        #    rows the previous level just visited (frontier-restricted
        #    gather), so the loop exits only after every visited row has its
        #    verdict — the final body makes no change, and a no-change body
        #    computed verdicts for all pending rows before expanding.
        start_rows = jnp.where(valid, members, n)  # n = out of bounds -> drop
        visited0 = jnp.zeros((n, wm), dtype=jnp.uint32).at[start_rows, wordj].add(
            bitj, mode="drop"
        )
        pruned0 = jnp.zeros((n, wm), dtype=jnp.uint32)
        computed0 = jnp.zeros(n, dtype=bool)

        def cond(state):
            return state[3]

        def body(state):
            v, pruned, computed, _ = state
            need = (v != 0).any(axis=1) & ~computed

            def sparse(p):
                # gather only the needy rows' label rows: OOB fill rows
                # clamp on gather and drop on scatter, so they are inert
                idx = jnp.nonzero(need, size=prune_cap, fill_value=n)[0]
                verd = jnp.bitwise_or.reduce(hop_mask[tgt_hops[idx]], axis=1)
                return p.at[idx].set(verd, mode="drop")

            def dense(p):
                verd = jnp.bitwise_or.reduce(hop_mask[tgt_hops], axis=1)
                return jnp.where(need[:, None], verd, p)

            pruned = jax.lax.cond(need.sum() <= prune_cap, sparse, dense, pruned)
            computed = computed | need
            new = v | expand(v & ~pruned)
            return new, pruned, computed, jnp.any(new != v)

        visited, pruned, _, _ = jax.lax.while_loop(
            cond, body, (visited0, pruned0, computed0, jnp.bool_(True))
        )

        # 3. segment-scatter append: member bits -> (row, lens + prefix) cols
        labeled = visited & ~pruned  # [n, wm] (never-visited rows are zero)
        bits_u = (labeled[:, word] >> jnp.asarray(np.arange(w) % 32, jnp.uint32)) & 1
        on = bits_u.astype(bool)  # [n, w]
        prefix = jnp.cumsum(bits_u, axis=1, dtype=jnp.int32) - bits_u.astype(jnp.int32)
        pos = len_tgt[:, None] + prefix
        cols = jnp.where(on, pos, l_max)  # l_max is out of bounds -> drop
        row_ids = jnp.arange(n, dtype=jnp.int32)[:, None]
        L_new = L_tgt.at[row_ids, cols].set(
            jnp.broadcast_to(ranks[None, :], (n, w)), mode="drop"
        )
        overflow = jnp.any(on & (pos >= l_max))
        len_new = len_tgt + bits_u.astype(jnp.int32).sum(axis=1)
        # len_tgt rides through as the pre-wave watermark: the overflow-undo
        # needs it, and under donation the caller no longer holds it
        return L_new, len_new, overflow, len_tgt

    if donate:
        return jax.jit(wave_step, donate_argnums=(1, 2))
    return jax.jit(wave_step)


def _make_undo():
    """Restore a donated label matrix to its pre-wave watermark: appends
    only ever write columns >= the old row length (which held INVALID), so
    masking those columns back to INVALID is an exact rollback."""
    import functools as _ft

    import jax
    import jax.numpy as jnp

    @_ft.partial(jax.jit, donate_argnums=(0,))
    def undo(L, len_prev):
        cols = jnp.arange(L.shape[1], dtype=jnp.int32)[None, :]
        return jnp.where(cols >= len_prev[:, None], INVALID, L)

    return undo


def certification_mask(labeled_rev, visited_rev, labeled_fwd, visited_fwd, members, w):
    """Device mirror of ``bitset.violation_mask`` — which members of an
    optimistic wave ran on stale prune sets.

    Inputs are the two sweeps' end-of-wave masks as the device engine
    already materializes them (uint32[n, ceil(w/32)]; ``labeled`` =
    ``visited & ~pruned``), plus the wave's member vertex ids.  Because the
    device engine keeps the sweep directions in separate arrays, member j
    is bit j in BOTH — no bank offsets — and the violation intersection is
    the same word math as the host pass: member j's reverse sweep is
    violated when some lower-ranked wave-mate i both appended into
    L_in(v_j) (``labeled_fwd[members][j]`` bit i) and labeled a row the
    reverse sweep visited (touch matrix of ``visited_rev``/``labeled_rev``);
    forward is symmetric.  Returns bool[w].  This is the schema the device
    engine will adopt speculative waves through — the wave-step outputs it
    needs (visited, pruned) already exist on device."""
    import jax.numpy as jnp

    wm = (w + 31) // 32
    word = np.arange(w, dtype=np.int32) // 32
    shift = np.arange(w, dtype=np.uint32) % np.uint32(32)
    # triangular prefix masks (bits < j), packed uint32[w, wm]
    jj = np.arange(w)
    pref_bool = jj[None, :] < jj[:, None]
    pref = jnp.asarray(bitset.pack_bool_rows_u32(pref_bool))

    def unpack(m):  # uint32[n, wm] -> bool[n, w]
        return ((m[:, word] >> jnp.asarray(shift)) & 1).astype(bool)

    def touch(v_mask, a_mask):  # T[j] = OR of a_mask rows with v-bit j set
        vb = unpack(v_mask)  # [n, w]
        return jnp.bitwise_or.reduce(
            jnp.where(vb[:, :, None], a_mask[:, None, :], jnp.uint32(0)), axis=0
        )  # [w, wm]

    own_rev = labeled_rev[members] & pref
    own_fwd = labeled_fwd[members] & pref
    t_rev = touch(visited_rev, labeled_rev)
    t_fwd = touch(visited_fwd, labeled_fwd)
    return ((own_fwd & t_rev) | (own_rev & t_fwd)).any(axis=1)


def _finalize_side(L, lens, n) -> np.ndarray:
    """Device label matrix -> the reference builder's byte layout (rows
    ascending, INVALID padded, width = next multiple of 8, min 8)."""
    lens = np.asarray(lens)
    lmax = int(lens.max()) if n else 1
    width = max(((max(lmax, 1) + 7) // 8) * 8, 8)
    mat = np.asarray(L[:, :width])
    if mat.shape[1] < width:  # small l_max that never overflowed: pad out
        pad = np.full((mat.shape[0], width - mat.shape[1]), INVALID, dtype=np.int32)
        mat = np.concatenate([mat, pad], axis=1)
    return sort_label_rows(mat)


def distribution_labeling_device(
    g: CSRGraph,
    order: Optional[np.ndarray] = None,
    order_name: str = "degree_product",
    max_wave: int = 64,
    l_max: int = 16,
    ell_width: int = 16,
    expand: str = "auto",
    interpret: bool | None = None,
    block_n: int = 128,
    mesh=None,
    waves: Optional[np.ndarray] = None,
    prune_cap: Optional[int] = None,
    donate: Optional[bool] = None,
) -> ReachabilityOracle:
    """Full sparse device wave build (host loop over waves, device sweeps).

    ``expand="pallas"`` drives the frontier through the Pallas ELL kernel
    (interpret mode off-TPU), ``"xla"`` through the equivalent jnp gather;
    ``"auto"`` picks pallas on TPU and xla elsewhere.  ``l_max`` is the
    starting label-matrix width — overflowing waves grow it geometrically
    and re-run after a watermark undo (appends only wrote columns past the
    pre-wave row lengths, so masking those back to INVALID is exact).
    ``prune_cap`` bounds the per-level frontier-restricted prune gather
    (default max(256, n // 8)); ``donate`` donates the target label matrix
    + lengths into the wave-step jit so appends update in place instead of
    device-to-device copying the whole matrix every wave (default: on for
    accelerator backends, off on CPU where XLA ignores donation).
    """
    import jax
    import jax.numpy as jnp

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if expand == "auto":
        expand = "pallas" if jax.default_backend() == "tpu" else "xla"
    if donate is None:
        donate = jax.default_backend() != "cpu"
    n = g.n
    if n == 0:
        return finalize_labels([], [], hop_rank=np.empty(0, dtype=np.int32))
    if order is None:
        order = get_order(g, order_name)
    order = np.asarray(order, dtype=np.int64)
    if waves is None:
        waves = wave_schedule(g, order, max_wave=max_wave)
    # the static member width follows the ACTUAL schedule (a caller may hand
    # in waves cut at a different cap), rounded to whole uint32 words
    max_wave = int(max(int(np.max(waves)) if waves.size else 1, 1))
    max_wave = ((max_wave + 31) // 32) * 32 if max_wave > 32 else max_wave
    g_rev = g.reverse()

    # reverse pass expands u -> in-neighbors w (edge w -> u): destination-
    # stationary rows = packed OUT-neighbor slabs; forward pass symmetric
    # with the reverse graph's rows
    slabs_out = bitset.ell_slabs(
        g.indptr.astype(np.int64), g.indices.astype(np.int64), n, width=ell_width
    )
    slabs_in = bitset.ell_slabs(
        g_rev.indptr.astype(np.int64), g_rev.indices.astype(np.int64), n, width=ell_width
    )

    w = int(max_wave)
    wm = (w + 31) // 32
    kw = dict(expand_impl=expand, interpret=interpret, block_n=block_n, mesh=mesh)
    # expansion closures are l_max-independent: built once (slab upload +
    # trace happen here only); the wave steps rebuild on overflow growth
    ex_out = _expand_fn(slabs_out[2], slabs_out[1], n, wm, **kw)
    ex_in = _expand_fn(slabs_in[2], slabs_in[1], n, wm, **kw)
    step_rev = None  # built lazily per l_max (re-built on overflow growth)
    step_fwd = None
    undo = _make_undo()  # shape-polymorphic: retraces per l_max as needed

    L_out = jnp.full((n, l_max), INVALID, dtype=jnp.int32)
    L_in = jnp.full((n, l_max), INVALID, dtype=jnp.int32)
    out_len = jnp.zeros(n, dtype=jnp.int32)
    in_len = jnp.zeros(n, dtype=jnp.int32)
    ranks_of = np.arange(n, dtype=np.int32)

    base = 0
    for wi, wlen in enumerate(waves):
        wlen = int(wlen)
        # annotate=True also emits a jax.profiler TraceAnnotation when the
        # tracer's jax_annotations flag is on, so device profiles line up
        # with the exported Chrome timeline wave-for-wave
        sp = (trace.span("build.wave", cat="build",
                         args={"index": wi, "size": wlen}, annotate=True)
              if ON.enabled else trace.NOOP_SPAN)
        with sp:
            members = np.full(w, 0, dtype=np.int32)
            members[:wlen] = order[base : base + wlen]
            valid = np.zeros(w, dtype=bool)
            valid[:wlen] = True
            ranks = np.zeros(w, dtype=np.int32)
            ranks[:wlen] = ranks_of[base : base + wlen]
            m_j, v_j, r_j = jnp.asarray(members), jnp.asarray(valid), jnp.asarray(ranks)
            # reverse then forward: the forward prune set L_out(v_j) must see
            # the member's own rank, which the reverse sweep just appended
            for direction in ("rev", "fwd"):
                while True:
                    if step_rev is None:
                        step_rev = _make_wave_step(
                            n, w, l_max, ex_out, prune_cap=prune_cap, donate=donate)
                        step_fwd = _make_wave_step(
                            n, w, l_max, ex_in, prune_cap=prune_cap, donate=donate)
                    # the target matrix + lengths may be donated into the
                    # step, so rebind to the outputs unconditionally — the
                    # old buffers are dead either way, and res[3] carries
                    # the pre-wave lengths an overflow undo needs
                    if direction == "rev":
                        res = step_rev(L_in, L_out, out_len, m_j, v_j, r_j)
                        L_out, out_len = res[0], res[1]
                    else:
                        res = step_fwd(L_out, L_in, in_len, m_j, v_j, r_j)
                        L_in, in_len = res[0], res[1]
                    if not bool(res[2]):  # overflow flag: one scalar per sweep
                        break
                    # overflow: watermark-undo the partial appends (they only
                    # wrote columns past the pre-wave lengths), grow the label
                    # matrices, and re-run this sweep
                    if ON.enabled:
                        sp.event("overflow_regrow", l_max=l_max * 2)
                    if direction == "rev":
                        L_out, out_len = undo(L_out, res[3]), res[3]
                    else:
                        L_in, in_len = undo(L_in, res[3]), res[3]
                    l_max *= 2
                    grow = functools.partial(
                        jnp.pad, pad_width=((0, 0), (0, l_max // 2)),
                        constant_values=INVALID,
                    )
                    L_out, L_in = grow(L_out), grow(L_in)
                    step_rev = step_fwd = None
        base += wlen

    return ReachabilityOracle(
        L_out=_finalize_side(L_out, out_len, n),
        L_in=_finalize_side(L_in, in_len, n),
        out_len=np.asarray(out_len),
        in_len=np.asarray(in_len),
        hop_rank=_hop_rank(order, n),
    )


# backwards-compatible alias (the dense demonstrator's public name)
distribution_labeling_wave_jax = distribution_labeling_device
