"""Scalar traversal + label-append helpers shared across construction code.

Before this module existed, the pruned-BFS/label-append loop was written out
twice in ``core/distribution.py`` (forward + reverse pass) and the k-hop /
label-inherit loops twice more in ``core/hierarchy.py`` and
``core/backbone.py``.  They now live here, once; both labeling algorithms and
the backbone builder import from this module.
"""
from __future__ import annotations

from collections import deque
from typing import List, Set

import numpy as np


def pruned_bfs_distribute(
    indptr: np.ndarray,
    indices: np.ndarray,
    source: int,
    source_label_set: Set[int],
    target_label_sets: List[Set[int]],
    target_label_lists: List[List[int]],
    visited: np.ndarray,
    stamp: int,
) -> None:
    """One pruned-BFS pass of Algorithm 2 (paper §5).

    Walk the graph given by (indptr, indices) from ``source``; at each vertex
    ``u``, if ``source_label_set`` already intersects ``target_label_sets[u]``
    the pair is covered through a higher-ranked hop — prune ``u`` (no label,
    no expansion).  Otherwise append ``source`` to u's label and expand.

    The reverse pass of Distribution-Labeling calls this with the reverse CSR
    and (L_in(v_i), L_out); the forward pass with the forward CSR and
    (L_out(v_i), L_in).  ``visited`` is an iteration-stamp array shared across
    calls so it never needs clearing.
    """
    dq = deque([source])
    visited[source] = stamp
    while dq:
        u = dq.popleft()
        if not source_label_set.isdisjoint(target_label_sets[u]):
            continue  # covered by a higher hop: prune u (and paths through it)
        target_label_sets[u].add(source)
        target_label_lists[u].append(source)
        for w in indices[indptr[u] : indptr[u + 1]]:
            if visited[w] != stamp:
                visited[w] = stamp
                dq.append(int(w))


def cone_resume_sweep(
    neighbors,
    labels,
    hop: int,
    hop_vertex: int,
    seed: int,
    side: str,
    stop_at_present: bool,
) -> int:
    """Resume one direction of Algorithm 2's pruned BFS from an arbitrary
    seed — the cone-scoped construction entry (re-exported by
    ``build.engine``) that ``repro.dynamic`` repairs labels through.

    The same prune-or-expand loop as ``pruned_bfs_distribute``, generalized
    for the dynamic path: the prune probe and the label append go through
    the ``labels`` object (rank-restricted, idempotent) instead of raw
    sets/lists, because repairs run against finalized rank-space labels.

    Where the wave engine runs every BFS of a wave from its own hop vertex
    over the whole graph, a dynamic repair restarts a single hop's sweep
    inside the affected cone only: after inserting DAG edge (u, v), hop h in
    L_in(u) resumes its FORWARD sweep at seed v (``side="in"``: distributing
    h into L_in of v's cone), and hop h in L_out(v) resumes its REVERSE sweep
    at seed u (``side="out"``).  Cones are tiny relative to n, so the scalar
    level loop beats re-running the batched wave sweep; the prune test is the
    same Algorithm 2 probe, restricted to ranks at least as high as ``hop``
    (numerically ``<= hop`` in rank space) so the verdicts match what the
    sequential §5.2 loop would have produced — repaired labels stay
    non-redundant per Theorem 4 up to covers that later edge updates created.

    Parameters
    ----------
    neighbors : callable v -> iterable of neighbor vertex ids
        Forward adjacency for ``side="in"``, reverse for ``side="out"``.
    labels : MutableLabels-protocol
        Must provide ``prune(vertex, hop, hop_vertex, side, include_equal)``
        (the restricted intersection probe; with ``include_equal`` an
        already-present hop also prunes) and ``add(side, vertex, hop)``
        (idempotent sorted insert).
    hop : int
        Rank-space value being distributed.
    hop_vertex : int
        The vertex whose rank is ``hop`` (its opposite-side row feeds the
        prune probe).
    seed : int
        Cone apex the sweep restarts from.
    side : str
        "in": write L_in rows (forward sweep); "out": write L_out rows.
    stop_at_present : bool
        True for insert repairs (a vertex already holding ``hop`` was fully
        explored when the hop first reached it — prune and do not expand);
        False for delete repairs (rows beyond a present vertex may have been
        invalidated and must be revisited).

    Returns the number of label appends performed.
    """
    appended = 0
    dq = deque([seed])
    seen = {seed}
    while dq:
        w = dq.popleft()
        if labels.prune(w, hop, hop_vertex, side, include_equal=stop_at_present):
            continue
        appended += labels.add(side, w, hop)
        for x in neighbors(w):
            if x not in seen:
                seen.add(x)
                dq.append(x)
    return appended


def khop_out(g, v: int, k: int) -> Set[int]:
    """Vertices within <= k forward steps of v (excluding v).

    Shared by the backbone builder (Formulas 1/2 candidate sets) and
    Hierarchical-Labeling (Formula 3 core labels + backbone sets).
    """
    seen = {v}
    frontier = [v]
    out: Set[int] = set()
    for _ in range(k):
        nxt = []
        for u in frontier:
            for w in g.out_neighbors(u):
                w = int(w)
                if w not in seen:
                    seen.add(w)
                    out.add(w)
                    nxt.append(w)
        frontier = nxt
    return out


def batched_union_rows(
    keys: np.ndarray, vals: np.ndarray, n_rows: int, domain: int
) -> List[np.ndarray]:
    """Per-key sorted-unique unions, one vectorized pass.

    (keys[t], vals[t]) pairs — vals in [0, domain) — collapse to a list of
    ``n_rows`` sorted unique int32 arrays (row k = union of vals with
    keys == k).  This is HL's level-wise label union (Formulas 4/5): all
    rows of a level are independent (they inherit only from higher-level
    backbone labels), so the whole level collapses into ONE np.unique over
    key-fused ints instead of a python set union per vertex — the last
    copy-pasted scalar traversal ``core/hierarchy.py`` carried.  The
    neighbor/backbone gathers feeding it come from ``bitset.csr_gather``,
    the same primitive the wave sweeps expand frontiers with.
    """
    fused = np.unique(keys.astype(np.int64) * np.int64(domain) + vals.astype(np.int64))
    k = fused // domain
    v = (fused % domain).astype(np.int32)
    starts = np.searchsorted(k, np.arange(n_rows + 1, dtype=np.int64))
    return [v[starts[i] : starts[i + 1]] for i in range(n_rows)]
