"""Scalar traversal + label-append helpers shared across construction code.

Before this module existed, the pruned-BFS/label-append loop was written out
twice in ``core/distribution.py`` (forward + reverse pass) and the k-hop /
label-inherit loops twice more in ``core/hierarchy.py`` and
``core/backbone.py``.  They now live here, once; both labeling algorithms and
the backbone builder import from this module.
"""
from __future__ import annotations

from collections import deque
from typing import List, Sequence, Set

import numpy as np


def pruned_bfs_distribute(
    indptr: np.ndarray,
    indices: np.ndarray,
    source: int,
    source_label_set: Set[int],
    target_label_sets: List[Set[int]],
    target_label_lists: List[List[int]],
    visited: np.ndarray,
    stamp: int,
) -> None:
    """One pruned-BFS pass of Algorithm 2 (paper §5).

    Walk the graph given by (indptr, indices) from ``source``; at each vertex
    ``u``, if ``source_label_set`` already intersects ``target_label_sets[u]``
    the pair is covered through a higher-ranked hop — prune ``u`` (no label,
    no expansion).  Otherwise append ``source`` to u's label and expand.

    The reverse pass of Distribution-Labeling calls this with the reverse CSR
    and (L_in(v_i), L_out); the forward pass with the forward CSR and
    (L_out(v_i), L_in).  ``visited`` is an iteration-stamp array shared across
    calls so it never needs clearing.
    """
    dq = deque([source])
    visited[source] = stamp
    while dq:
        u = dq.popleft()
        if not source_label_set.isdisjoint(target_label_sets[u]):
            continue  # covered by a higher hop: prune u (and paths through it)
        target_label_sets[u].add(source)
        target_label_lists[u].append(source)
        for w in indices[indptr[u] : indptr[u + 1]]:
            if visited[w] != stamp:
                visited[w] = stamp
                dq.append(int(w))


def khop_out(g, v: int, k: int) -> Set[int]:
    """Vertices within <= k forward steps of v (excluding v).

    Shared by the backbone builder (Formulas 1/2 candidate sets) and
    Hierarchical-Labeling (Formula 3 core labels + backbone sets).
    """
    seen = {v}
    frontier = [v]
    out: Set[int] = set()
    for _ in range(k):
        nxt = []
        for u in frontier:
            for w in g.out_neighbors(u):
                w = int(w)
                if w not in seen:
                    seen.add(w)
                    out.add(w)
                    nxt.append(w)
        frontier = nxt
    return out


def inherit_labels(
    gv: int,
    neighbor_globals: Sequence[int],
    backbone_locals: Sequence[int],
    to_global: np.ndarray,
    label_sets: List[Set[int]],
) -> Set[int]:
    """One side of HL's level-wise labeling (Formulas 4/5):

        L(v) = {v}  u  N1(v|G_i)  u  U_{u in B(v)} L(u)

    ``core/hierarchy.py`` previously spelled this out twice (once per
    direction); both call sites now share this helper.
    """
    lab: Set[int] = {gv}
    lab.update(int(w) for w in neighbor_globals)
    for u in backbone_locals:
        lab.update(label_sets[int(to_global[u])])
    return lab
