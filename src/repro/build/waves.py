"""Wave scheduler: batch Algorithm 2 iterations that provably commute.

Distribution-Labeling's outer loop is sequential in the §5.2 rank order, but
consecutive iterations commute whenever no wave member can reach another:

  * v_i's reverse pass appends v_i to L_out(u) for ancestors u; that append
    can only flip v_j's prune test L_out(u) ∩ L_in(v_j) if v_i ∈ L_in(v_j),
    i.e. v_i -> v_j.
  * symmetrically for the forward pass and v_j -> v_i.

So a *wave* = a maximal run of consecutive rank-order vertices that are
pairwise mutually unreachable; the whole wave runs as one batched sweep with
bit-per-member state and the result is exactly the sequential labeling (the
engine's differential tests assert byte-identity).

Two schedulers produce such partitions:

``scheduler="onepass"`` (default) — the one-pass rank-windowed scheduler.
The conflict relation is computed ONCE per build: candidates are seeded in
*pages* of consecutive ranks, each page's closure bits propagated through
its cones once into a persistent two-parity scratch, and the page's
conflict PAIRS extracted once into (rank-sorted, suffix-min) arrays.  Waves
are then carved greedily with one binary search per wave, so they cross
page boundaries freely and every carve window that overlaps a page REUSES
its propagated closure and extracted pairs — the blocked scheduler instead
re-materializes a dense per-block conflict matrix (its hottest line on
overlap-heavy tree graphs, the ~20-40% scheduler share ROADMAP calls out)
and truncates every wave at block boundaries.

``scheduler="blocked"`` — the original per-block closure scheduler, kept as
the equivalence reference (with ``block >= n`` both schedulers produce the
identical partition; tests assert it).

Certification inside either scheduler is two-tier, both sides conservative:

1. GRAIL-style DFS intervals (Yildirim et al., PAPERS.md): a DFS of a DAG
   assigns post-order numbers and ``low[v] = min(post over Reach(v))``; then
   ``u -> v  ==>  post[v] in [low[u], post[u]]`` for every traversal.  One
   vectorized all-pairs check refutes most pairs for free.  (Topo levels
   would add nothing here: they can only *confirm* reachability, never
   refute an interval false positive.)
2. An exact closure: budget-bounded multi-source reach propagation of
   per-candidate bit masks.  If it completes within budget it yields the
   *true* pairwise reachability among the candidates (bit a arriving at
   candidate b means a -> b).  Sparse graphs — exactly the ones whose BFS
   regions are tiny and therefore batch well — complete almost every
   closure; hub-dominated ranges blow the budget and fall back to the
   interval verdict (after a circuit breaker pays for the intervals once).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.build import bitset
from repro.graph.csr import CSRGraph


def _reverse_within_rows(indptr: np.ndarray, indices: np.ndarray) -> np.ndarray:
    """indices with every CSR row's neighbor list reversed (tie-break flip)."""
    m = indices.shape[0]
    counts = np.diff(indptr).astype(np.int64)
    starts = indptr[:-1].astype(np.int64)
    cum = np.cumsum(counts)
    pos_in_row = np.arange(m, dtype=np.int64) - np.repeat(cum - counts, counts)
    dest = np.repeat(starts + counts - 1, counts) - pos_in_row
    out = np.empty_like(indices)
    out[dest] = indices
    return out


def dfs_post_low(
    indptr: np.ndarray,
    indices: np.ndarray,
    roots: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """One DFS sweep over a DAG: (post, low) int64[n].

    post[v] = post-order number; low[v] = min post over Reach(v) (computable
    at finish time because every out-neighbor of a DAG vertex is already
    finished).  [low[v], post[v]] contains post[d] for every descendant d and
    post[v] itself.
    """
    n = indptr.shape[0] - 1
    iptr = indptr.tolist()
    idx = indices.tolist()
    post = [0] * n
    low = [0] * n
    state = bytearray(n)  # 0 new, 1 open, 2 done
    t = 0
    root_iter = range(n) if roots is None else roots.tolist()
    for r in root_iter:
        if state[r]:
            continue
        state[r] = 1
        stack = [r]
        ptr = [iptr[r]]
        while stack:
            v = stack[-1]
            p = ptr[-1]
            if p < iptr[v + 1]:
                ptr[-1] = p + 1
                w = idx[p]
                if not state[w]:
                    state[w] = 1
                    stack.append(w)
                    ptr.append(iptr[w])
            else:
                stack.pop()
                ptr.pop()
                lo = t
                for q in range(iptr[v], iptr[v + 1]):
                    lw = low[idx[q]]
                    if lw < lo:
                        lo = lw
                post[v] = t
                low[v] = lo
                state[v] = 2
                t += 1
    return np.asarray(post, dtype=np.int64), np.asarray(low, dtype=np.int64)


def dfs_intervals(g: CSRGraph, n_traversals: int = 2) -> Tuple[np.ndarray, np.ndarray]:
    """(post, low) stacked over traversals: int64[T, n] each.

    Traversal 0 uses natural root/neighbor order; traversal 1 flips both;
    further traversals use seeded random root/neighbor permutations.  More
    traversals refute more interval false positives (a pair is only "maybe"
    if EVERY traversal allows it) — the exact rescue in ``wave_schedule``
    makes 2 enough in practice.
    """
    posts, lows = [], []
    rng = np.random.default_rng(0x5EED)
    for t in range(n_traversals):
        if t == 0:
            p, l = dfs_post_low(g.indptr, g.indices)
        elif t == 1:
            p, l = dfs_post_low(
                g.indptr,
                _reverse_within_rows(g.indptr, g.indices),
                roots=np.arange(g.n - 1, -1, -1),
            )
        else:
            key = rng.random(g.m)
            row = np.repeat(np.arange(g.n), np.diff(g.indptr))
            p, l = dfs_post_low(
                g.indptr,
                g.indices[np.lexsort((key, row))],
                roots=rng.permutation(g.n),
            )
        posts.append(p)
        lows.append(l)
    return np.stack(posts), np.stack(lows)


def _interval_conflicts(P: np.ndarray, L: np.ndarray, cand: np.ndarray) -> np.ndarray:
    """bool[c, c] — conflict[a, b] = some traversal allows a -> b or b -> a."""
    p = P[:, cand]  # [T, c]
    l = L[:, cand]
    maybe = ((p[:, None, :] >= l[:, :, None]) & (p[:, None, :] <= p[:, :, None])).all(axis=0)
    return maybe | maybe.T


def _exact_conflicts(
    indptr: np.ndarray,
    indices: np.ndarray,
    cand: np.ndarray,
    scratch: np.ndarray,
    budget: int,
) -> Optional[np.ndarray]:
    """Exact pairwise reachability among candidates via a multi-source
    closure BFS with packed candidate-bit masks; None if the edge budget is
    exhausted (verdict would be unsound when truncated)."""
    c = cand.shape[0]
    mbits = bitset.member_bits(c, scratch.shape[1])
    scratch[cand] = mbits
    touched = [cand]
    frontier, fbits = cand, mbits
    edges = 0
    completed = True
    while frontier.size:
        # budget check BEFORE the gather: a single hub level can carry the
        # whole graph, and a doomed block must abort cheaply
        edges += int((indptr[frontier + 1] - indptr[frontier]).sum())
        if edges > budget:
            completed = False
            break
        nbrs, seg = bitset.csr_gather(indptr, indices, frontier)
        if nbrs.shape[0] == 0:
            break
        uniq, obits = bitset.group_or(nbrs, fbits[seg])  # indices already int64
        new = obits & ~scratch[uniq]
        keep = new.any(axis=1)
        frontier = uniq[keep]
        fbits = new[keep]
        scratch[frontier] |= fbits
        touched.append(frontier)
    if completed:
        arrived = scratch[cand] ^ mbits  # bits of OTHER candidates reaching each
        # conflicts are sparse: unpack only rows that received any bit
        nz = np.flatnonzero(arrived.any(axis=1))
        m = np.zeros((c, c), dtype=bool)  # m[b, a] = a -> b
        if nz.size:
            m[nz] = bitset.masks_to_matrix(arrived[nz], c)
        conflict = m | m.T
    scratch[np.concatenate(touched)] = 0
    return conflict if completed else None


# circuit breaker: after this many blown closures, pay for the DFS
# intervals once and stop bisecting (shared by both schedulers)
_BLOW_LIMIT = 64

_TRIU_CACHE: list = [np.zeros((0, 0), dtype=bool)]


def _triu_mask(c: int) -> np.ndarray:
    """Cached strict upper-triangle mask view (np.triu allocates per call)."""
    if _TRIU_CACHE[0].shape[0] < c:
        size = max(c, 256)
        _TRIU_CACHE[0] = np.triu(np.ones((size, size), dtype=bool), k=1)
    return _TRIU_CACHE[0][:c, :c]


def _block_waves(conflict: np.ndarray, c: int, max_wave: int, lengths: list) -> None:
    """Greedily split one block's conflict matrix into consecutive waves."""
    pos = 0
    while pos < c:
        limit = min(max_wave, c - pos)
        sub = conflict[pos : pos + limit, pos : pos + limit]
        bad = (sub & _triu_mask(limit)).any(axis=0)  # b conflicts with some a < b
        nz = np.flatnonzero(bad)
        wlen = max(int(nz[0]) if nz.size else limit, 1)
        lengths.append(wlen)
        pos += wlen


def wave_schedule_blocked(
    g: CSRGraph,
    order: np.ndarray,
    max_wave: int = 256,
    block: int = 256,
    n_traversals: int = 2,
    intervals: Tuple[np.ndarray, np.ndarray] | None = None,
    exact_budget: Optional[int] = None,
    abort_below_avg: Optional[float] = None,
) -> Optional[np.ndarray]:
    """The per-block closure scheduler (the original implementation).

    Block-and-split: one exact closure covers a whole ``block`` of
    consecutive vertices, and every wave inside the block is carved out of
    that single conflict matrix.  Larger blocks amortize closure calls but
    pay more mask words per edge.  When a block blows the closure budget (a
    hub cone is in range), bisect it so the hub lands in a small block
    alone; if closures keep blowing (closure-hostile graph), a circuit
    breaker pays once for the DFS intervals and uses them for all remaining
    fallbacks.

    Kept as the equivalence reference for the one-pass windowed scheduler
    (``wave_schedule``): with ``block >= len(order)`` both produce the
    identical partition.  See ``wave_schedule`` for the parameter contract.
    """
    order = np.asarray(order, dtype=np.int64)
    n_total = order.shape[0]
    if n_total == 0:
        return np.empty(0, dtype=np.int64)
    block = max(block, max_wave)
    if exact_budget is None:
        # generous: a completed closure buys exact (maximal) waves, and the
        # per-block cost is bounded by the budget either way
        exact_budget = max(131072, 16 * block * max(g.m // max(g.n, 1), 1))
    indptr = g.indptr.astype(np.int64)
    indices = g.indices.astype(np.int64)
    scratch = np.zeros((g.n, bitset.n_words(block)), dtype=np.uint64)
    iv = intervals
    blown = 0

    lengths: list = []
    i = 0
    while i < n_total:
        c = min(block, n_total - i)
        while True:
            if c == 1:
                lengths.append(1)  # a lone vertex is trivially a wave
                i += 1
                break
            cand = order[i : i + c]
            if iv is not None and blown >= _BLOW_LIMIT:
                conflict = _interval_conflicts(iv[0], iv[1], cand)
            else:
                conflict = _exact_conflicts(indptr, indices, cand, scratch, exact_budget)
                if conflict is None:  # budget blown: a huge cone is in range
                    blown += 1
                    if blown >= _BLOW_LIMIT:
                        # closure-hostile graph — switch every remaining
                        # fallback to the interval certificate
                        if iv is None:
                            iv = dfs_intervals(g, n_traversals)
                        c = min(c, max_wave)  # keep interval matrices small
                        continue
                    c = c // 2  # bisect: isolate the hub into a small block
                    continue
            _block_waves(conflict, c, max_wave, lengths)
            i += c
            break
        if abort_below_avg is not None and i >= 4096 and i / len(lengths) < abort_below_avg:
            return None
    return np.asarray(lengths, dtype=np.int64)


# ---------------------------------------------------------------------------
# one-pass rank-windowed scheduler
# ---------------------------------------------------------------------------


class _OnePassState:
    """Sliding-window closure state for ``wave_schedule`` (onepass).

    Candidates are seeded in *pages* of ``page`` consecutive ranks.  Rank p
    owns slot ``(p // page) % 2 * page + p % page`` — two pages of slots
    alternate, and because a wave (<= max_wave <= page members) never looks
    more than one page ahead of its start, at most two consecutive pages are
    ever live.  Page k's bits are cleared from its touched vertices exactly
    when page k+2 (same parity) is about to seed.
    """

    def __init__(self, g: CSRGraph, order: np.ndarray, page: int,
                 exact_budget: int, n_traversals: int,
                 intervals: Optional[Tuple[np.ndarray, np.ndarray]],
                 blow_limit: int = _BLOW_LIMIT, use_intervals: bool = True,
                 keep_raw: bool = False):
        self.g = g
        self.order = order
        self.page = page
        # speculative-schedule mode: a lower circuit breaker, no interval
        # fallback (optimistic waves don't need conservative certificates),
        # and raw (un-suffix-minned) pairs kept for wave annotations
        self.blow_limit = blow_limit
        self.use_intervals = use_intervals
        self.keep_raw = keep_raw
        self.raw: dict = {}  # page -> (lo sorted, hi) raw pairs | "dense" | None
        self.n_total = order.shape[0]
        self.k_words = bitset.n_words(2 * page)
        self.budget = exact_budget
        self.n_traversals = n_traversals
        self.indptr = g.indptr.astype(np.int64)
        self.indices = g.indices.astype(np.int64)
        # one CONTIGUOUS scratch per slot parity: the propagation sweep runs
        # at the blocked scheduler's mask width and never pays strided access
        half = self.k_words // 2
        self.scr = [
            np.zeros((g.n, half), dtype=np.uint64),
            np.zeros((g.n, half), dtype=np.uint64),
        ]
        # rank p's bits could not be propagated (budget blown) — the carve
        # treats p as conflicting per the interval certificate (or with
        # everything, before the circuit breaker pays for intervals)
        self.unknown = np.zeros(self.n_total, dtype=bool)
        self.touched: dict[int, list] = {}
        self.pairs: dict = {}  # page -> (lo sorted, suffix-min hi) or None
        self.iv = intervals
        self.blown = 0
        self.propagated = -1  # highest fully-seeded page

    # -- slot helpers ----------------------------------------------------

    def slots_of(self, ranks: np.ndarray) -> np.ndarray:
        return (ranks // self.page) % 2 * self.page + ranks % self.page

    # -- page lifecycle --------------------------------------------------

    def ensure_page(self, k: int) -> None:
        """Seed+propagate pages up to ``k`` (recycling dead slots first)."""
        while self.propagated < k:
            nxt = self.propagated + 1
            dead = nxt - 2
            if dead >= 0:
                t = self.touched.pop(dead, None)
                if t:  # a parity's scratch holds exactly one page's bits
                    self.scr[dead % 2][np.concatenate(t)] = 0
                self.pairs.pop(dead, None)
                self.raw.pop(dead, None)
            lo = nxt * self.page
            hi = min(lo + self.page, self.n_total)
            if lo < hi:
                self._propagate_range(np.arange(lo, hi, dtype=np.int64), nxt)
                self._extract_page_pairs(nxt)
            self.propagated = nxt

    def _propagate_range(self, ranks: np.ndarray, page_idx: int) -> None:
        """Propagate the closure bits of ``order[ranks]`` (one page or a
        bisected sub-range) through their cones: the budget-bounded
        multi-source sweep of ``_exact_conflicts``, but writing into the
        PERSISTENT sliding-window scratch — the bits are written once, read
        by every carve window that overlaps them, and no dense per-block
        conflict-matrix extraction (``masks_to_matrix``, the blocked
        scheduler's hottest line on overlap-heavy tree graphs) ever runs:
        ``_extract_page_pairs`` peels the set bits into sparse pair lists
        once per page."""
        if self.blown >= self.blow_limit:
            # closure-hostile graph: stop paying for closures, certify the
            # rest through the intervals (paid for once below).  In
            # speculative mode there is nothing to certify — unknown ranks
            # just ride in optimistic waves — so skip the interval DFS too.
            if self.iv is None and self.use_intervals:
                self.iv = dfs_intervals(self.g, self.n_traversals)
            self.unknown[ranks] = True
            return
        cands = self.order[ranks]
        half = self.k_words // 2
        q = page_idx % 2
        view = self.scr[q]
        sl = self.slots_of(ranks) - q * self.page  # page-local slot ids
        mbits = np.zeros((ranks.shape[0], half), dtype=np.uint64)
        mbits[np.arange(ranks.shape[0]), sl // 64] = _U64_ONE << (sl % 64).astype(np.uint64)
        view[cands] |= mbits
        touched = [cands]
        frontier, fbits = cands, mbits
        edges = 0
        ok = True
        while frontier.size:
            edges += int((self.indptr[frontier + 1] - self.indptr[frontier]).sum())
            if edges > self.budget:
                ok = False
                break
            nbrs, seg = bitset.csr_gather(self.indptr, self.indices, frontier)
            if nbrs.shape[0] == 0:
                break
            uniq, obits = bitset.group_or(nbrs, fbits[seg])
            new = obits & ~view[uniq]
            keep = new.any(axis=1)
            frontier = uniq[keep]
            fbits = new[keep]
            view[frontier] |= fbits
            touched.append(frontier)
        if not ok:  # budget blown: a huge cone is in range — roll back
            #         exactly this range's slot bits (a bisect sibling may
            #         already have propagated into the same parity)
            bits = np.zeros(half, dtype=np.uint64)
            np.bitwise_or.at(bits, sl // 64, _U64_ONE << (sl % 64).astype(np.uint64))
            view[np.concatenate(touched)] &= ~bits
            self.blown += 1
            if ranks.shape[0] == 1:
                self.unknown[ranks] = True  # a lone hub: carve isolates it
                return
            mid = ranks.shape[0] // 2  # bisect, like the blocked scheduler
            self._propagate_range(ranks[:mid], page_idx)
            self._propagate_range(ranks[mid:], page_idx)
            return
        self.touched.setdefault(page_idx, []).append(np.concatenate(touched))

    # -- conflict reads --------------------------------------------------

    def _extract_page_pairs(self, k: int) -> None:
        """Pull page k's conflict pairs out of its scratch parity, ONCE.

        A conflict involving a slot of page k is a page-k bit sitting on the
        row of a candidate of pages k-1 .. k+1 (windows never span further).
        Stored as (lo sorted ascending, suffix-min of hi) in GLOBAL rank
        space, so every carve window overlapping the page reads them with a
        binary search instead of re-scanning scratch."""
        r0 = max((k - 1) * self.page, 0)
        r1 = min((k + 2) * self.page, self.n_total)
        row_ranks = np.arange(r0, r1, dtype=np.int64)
        sub = self.scr[k % 2][self.order[r0:r1]]  # [R, K/2]
        # a page carrying > 64 conflicts per candidate is unbatchable — its
        # true waves are ~1 long regardless — so skip the (expensive)
        # extraction and let the carve treat the whole page conservatively
        # (hostile citeseerx-style graphs hit this on every page; the auto
        # probe then aborts without paying for exact pair lists)
        if int(bitset.popcount_u64(sub).sum()) > 64 * self.page:
            self.pairs[k] = "dense"
            if self.keep_raw:
                self.raw[k] = "dense"
            return
        a_out, b_out = [], []
        base = k * self.page
        for w in range(sub.shape[1]):
            act = np.flatnonzero(sub[:, w])
            vv = sub[act, w]
            it = 0
            # peel set bits lowest-first (cost tracks the conflict count);
            # rows still active after a few peels are dense — unpack those
            while act.size:
                if it >= 4:
                    bits = np.unpackbits(
                        np.ascontiguousarray(vv[:, None]).view(np.uint8),
                        axis=1, bitorder="little",
                    )
                    r, c = np.nonzero(bits)
                    a_out.append(base + w * 64 + c)
                    b_out.append(row_ranks[act[r]])
                    break
                low = vv & (~vv + _U64_ONE)
                a_out.append(base + w * 64 + bitset.popcount_u64(low - _U64_ONE))
                b_out.append(row_ranks[act])
                vv ^= low
                keep = vv != 0
                act, vv = act[keep], vv[keep]
                it += 1
        if not a_out:
            self.pairs[k] = None
            return
        a = np.concatenate(a_out).astype(np.int64)
        b = np.concatenate(b_out)
        lo = np.minimum(a, b)
        hi = np.maximum(a, b)
        keep = lo != hi  # self-bits land on the diagonal
        if not keep.any():
            self.pairs[k] = None
            return
        o = np.argsort(lo[keep], kind="stable")
        lo_s = lo[keep][o]
        hi_s = hi[keep][o]
        self.pairs[k] = (lo_s, np.minimum.accumulate(hi_s[::-1])[::-1])
        if self.keep_raw:  # actual pairs, pre suffix-min: wave annotations
            self.raw[k] = (lo_s, hi_s)

    def min_break(self, s: int) -> int:
        """Smallest global rank b such that some pair (a, b) has a >= s —
        the wave starting at rank s must end before b.  A "dense" page's
        pairs were never extracted: conservatively, no wave crosses into it
        and waves inside it have length 1 (sound; such pages carve to
        single-member waves under exact pairs too)."""
        out = self.n_total
        for k in (s // self.page, s // self.page + 1):
            pr = self.pairs.get(k)
            if pr is None:
                continue
            if isinstance(pr, str):  # dense marker
                start = k * self.page
                out = min(out, start if start > s else s + 1)
                continue
            lo_s, smin = pr
            i = int(np.searchsorted(lo_s, s))
            if i < lo_s.size:
                out = min(out, int(smin[i]))
        return out

    def unknown_pairs(self, pos: int, limit: int):
        """(lo sorted, suffix-min hi) of window-LOCAL pairs contributed by
        unknown candidates (blown closures) — interval-certified when the
        circuit breaker has paid for the intervals, conflict-with-everyone
        otherwise.  None when the window has no unknown candidates."""
        ranks = np.arange(pos, pos + limit, dtype=np.int64)
        u = np.flatnonzero(self.unknown[ranks])
        if u.size == 0:
            return None
        if self.iv is not None:
            civ = _interval_conflicts(self.iv[0], self.iv[1], self.order[ranks])
            r, c = np.nonzero(civ[u])
            a, b = u[r], c
        else:
            a = np.repeat(u, limit)
            b = np.tile(np.arange(limit, dtype=np.int64), u.size)
        lo = np.minimum(a, b) + pos
        hi = np.maximum(a, b) + pos
        keep = lo != hi
        if not keep.any():
            return None
        o = np.argsort(lo[keep], kind="stable")
        lo_s = lo[keep][o]
        hi_s = hi[keep][o]
        return lo_s, np.minimum.accumulate(hi_s[::-1])[::-1]


_U64_ONE = np.uint64(1)


def wave_schedule(
    g: CSRGraph,
    order: np.ndarray,
    max_wave: int = 256,
    block: int = 256,
    n_traversals: int = 2,
    intervals: Tuple[np.ndarray, np.ndarray] | None = None,
    exact_budget: Optional[int] = None,
    abort_below_avg: Optional[float] = None,
    scheduler: str = "onepass",
) -> Optional[np.ndarray]:
    """Partition ``order`` into consecutive waves of mutually unreachable
    vertices.  Returns int64[n_waves] wave lengths (summing to len(order));
    wave k covers order[sum(lengths[:k]) : sum(lengths[:k+1])].

    ``scheduler="onepass"`` (default): the rank-windowed one-pass scheduler
    (module docstring) — the conflict relation is computed once per build
    and reused across every window that overlaps it; waves are maximal runs
    capped only by ``max_wave``, never by block boundaries.
    ``scheduler="blocked"``: the per-block closure scheduler
    (``wave_schedule_blocked``), whose waves additionally truncate at
    ``block`` boundaries.

    ``abort_below_avg``: probe mode — once ~4k vertices are scheduled, give
    up and return None if the mean wave is below the threshold (the caller
    will not profit from batching; don't pay for the full schedule).
    """
    if scheduler in ("blocked", "per-block"):
        return wave_schedule_blocked(
            g, order, max_wave=max_wave, block=block, n_traversals=n_traversals,
            intervals=intervals, exact_budget=exact_budget,
            abort_below_avg=abort_below_avg,
        )
    if scheduler != "onepass":
        raise ValueError(f"unknown scheduler {scheduler!r}")
    order = np.asarray(order, dtype=np.int64)
    n_total = order.shape[0]
    if n_total == 0:
        return np.empty(0, dtype=np.int64)
    # word-aligned pages: each page's slots fill a contiguous uint64 half of
    # the scratch row (the propagation sweep runs on that half only)
    page = -(-max(block, max_wave) // 64) * 64
    if exact_budget is None:
        exact_budget = max(131072, 16 * page * max(g.m // max(g.n, 1), 1))
    state = _OnePassState(g, order, page, exact_budget, n_traversals, intervals)

    lengths: list = []
    pos = 0
    while pos < n_total:
        # read one conflict window spanning at most the two live pages and
        # carve as many waves out of it as fit — consecutive windows overlap
        # heavily when waves are short, so the read is amortized
        win = min(2 * page - pos % page, n_total - pos)
        state.ensure_page((pos + win - 1) // page)
        upairs = state.unknown_pairs(pos, win)
        off = 0
        while off < win:
            s = pos + off
            limit = min(max_wave, win - off)
            # a wave starting at s ends before the smallest b over pairs
            # (a, b) with a >= s — one binary search per live page
            b_min = state.min_break(s)
            if upairs is not None:
                lo_s, smin = upairs
                i = int(np.searchsorted(lo_s, s))
                if i < lo_s.size:
                    b_min = min(b_min, int(smin[i]))
            wlen = min(b_min - s, limit)
            if wlen == limit and limit < min(max_wave, n_total - s):
                break  # window-truncated, not conflict- or cap-ended: re-read
            wlen = max(wlen, 1)
            lengths.append(wlen)
            off += wlen
        pos += off
        if abort_below_avg is not None and pos >= 4096 and pos / len(lengths) < abort_below_avg:
            return None
    return np.asarray(lengths, dtype=np.int64)


# ---------------------------------------------------------------------------
# speculative (optimistic) scheduler
# ---------------------------------------------------------------------------


class SpecSchedule:
    """An optimistic wave partition: exact waves where the closure proved
    mutual unreachability, max-size *speculative* chunks everywhere else.

    ``lengths`` int64[n_waves] — consecutive rank runs summing to len(order).
    ``optimistic`` bool[n_waves] — False: proven conflict-free (the engine
    runs the plain exact sweep, no certification); True: unproven (the
    engine must certify the sweep and roll back / replay violations).
    ``pairs`` — per-wave annotation: None for exact waves; for optimistic
    waves either an int64[p, 2] array of wave-local intra-wave reach pairs
    the windowed closure already computed (advisory: the certification pass
    derives the true violation set from the sweep itself) or ``"unknown"``
    when the closure budget blew / the page was conflict-dense.
    """

    __slots__ = ("lengths", "optimistic", "pairs", "meta")

    def __init__(self, lengths, optimistic, pairs, meta):
        self.lengths = np.asarray(lengths, dtype=np.int64)
        self.optimistic = np.asarray(optimistic, dtype=bool)
        self.pairs = pairs
        self.meta = meta


def speculative_schedule(
    g: CSRGraph,
    order: np.ndarray,
    max_wave: int = 256,
    block: int = 256,
    spec_below: int = 24,
    exact_budget: Optional[int] = None,
    blow_limit: int = 8,
) -> SpecSchedule:
    """Optimistically partition ``order``: exact waves where they are long
    enough to amortize the batched sweep, rank-consecutive speculative
    chunks everywhere else.

    Reuses the one-pass windowed closure machinery, but in a cheap mode
    tuned for dense-reachability graphs — the exact scheduler's failure
    case: the closure budget is capped at ~m/4 edges (a page whose cones
    swallow the whole graph aborts fast instead of completing a useless
    whole-graph propagation), the circuit breaker trips after
    ``blow_limit`` blown closures, and no DFS-interval certificate is ever
    computed (unknown ranks simply ride in optimistic chunks — the engine's
    certification pass, not the scheduler, is the safety net).  Where
    propagation did complete, its conflict pairs carve exact waves for
    free; runs shorter than ``spec_below`` are merged into optimistic
    chunks and annotated with the intra-wave reach pairs already computed.
    """
    order = np.asarray(order, dtype=np.int64)
    n_total = order.shape[0]
    if n_total == 0:
        return SpecSchedule(np.empty(0, np.int64), np.empty(0, bool), [], {})
    page = -(-max(block, max_wave) // 64) * 64
    if exact_budget is None:
        exact_budget = min(
            max(131072, 16 * page * max(g.m // max(g.n, 1), 1)),
            max(g.m // 4, 8192),
        )
    state = _OnePassState(
        g, order, page, exact_budget, 2, None,
        blow_limit=blow_limit, use_intervals=False, keep_raw=True,
    )

    def _chunk_pairs(s: int, wlen: int):
        """Wave-local intra-wave pairs of [s, s+wlen), or "unknown"."""
        if state.unknown[s : s + wlen].any():
            return "unknown"
        a_out, b_out = [], []
        for k in range(s // page, (s + wlen - 1) // page + 1):
            pr = state.raw.get(k)
            if pr is None:
                continue
            if isinstance(pr, str):  # dense marker: pairs never extracted
                return "unknown"
            lo_s, hi_s = pr
            sel = (lo_s >= s) & (hi_s < s + wlen)
            if sel.any():
                a_out.append(lo_s[sel])
                b_out.append(hi_s[sel])
        if not a_out:
            return np.empty((0, 2), dtype=np.int64)
        return np.stack([np.concatenate(a_out) - s, np.concatenate(b_out) - s], axis=1)

    lengths: list = []
    optimistic: list = []
    pairs: list = []
    pos = 0
    while pos < n_total:
        win = min(2 * page - pos % page, n_total - pos)
        state.ensure_page((pos + win - 1) // page)
        off = 0
        while off < win:
            s = pos + off
            limit = min(max_wave, win - off)
            # longest exact wave from s: bounded by the first conflict pair
            # and the first budget-blown (unknown) rank at or after s
            b_min = state.min_break(s)
            unk = state.unknown[s : s + limit]
            if unk.any():
                b_min = min(b_min, s + int(np.argmax(unk)))
            wlen = min(b_min - s, limit)
            if wlen == limit and limit < min(max_wave, n_total - s):
                break  # window-truncated, not conflict-ended: re-read
            if wlen >= min(spec_below, n_total - s):
                lengths.append(wlen)
                optimistic.append(False)
                pairs.append(None)
            else:  # too short to amortize: speculate a full chunk instead
                if limit < min(max_wave, n_total - s):
                    break  # window tail: re-read so the chunk is full-size
                wlen = limit
                lengths.append(wlen)
                optimistic.append(True)
                pairs.append(_chunk_pairs(s, wlen))
            off += wlen
        pos += off
    opt = np.asarray(optimistic, dtype=bool)
    lens = np.asarray(lengths, dtype=np.int64)
    meta = {
        "n_waves": int(lens.shape[0]),
        "n_optimistic": int(opt.sum()),
        "optimistic_frac": float(lens[opt].sum() / max(n_total, 1)),
        "closures_blown": int(state.blown),
    }
    return SpecSchedule(lens, opt, pairs, meta)
