"""Wave scheduler: batch Algorithm 2 iterations that provably commute.

Distribution-Labeling's outer loop is sequential in the §5.2 rank order, but
consecutive iterations commute whenever no wave member can reach another:

  * v_i's reverse pass appends v_i to L_out(u) for ancestors u; that append
    can only flip v_j's prune test L_out(u) ∩ L_in(v_j) if v_i ∈ L_in(v_j),
    i.e. v_i -> v_j.
  * symmetrically for the forward pass and v_j -> v_i.

So a *wave* = a maximal run of consecutive rank-order vertices that are
pairwise mutually unreachable; the whole wave runs as one batched sweep with
bit-per-member state and the result is exactly the sequential labeling (the
engine's differential tests assert byte-identity).

Certification is two-tier, both sides conservative:

1. GRAIL-style DFS intervals (Yildirim et al., PAPERS.md): a DFS of a DAG
   assigns post-order numbers and ``low[v] = min(post over Reach(v))``; then
   ``u -> v  ==>  post[v] in [low[u], post[u]]`` for every traversal.  One
   vectorized all-pairs check refutes most pairs for free.  (Topo levels
   would add nothing here: they can only *confirm* reachability, never
   refute an interval false positive.)
2. When intervals report conflicts, an exact rescue: a budget-bounded
   multi-source closure BFS propagating one uint64 candidate-bit mask per
   vertex.  If it completes within budget it yields the *true* pairwise
   reachability among the candidates (bit a arriving at candidate b means
   a -> b), turning interval false positives back into full waves.  Sparse
   graphs — exactly the ones whose BFS regions are tiny and therefore batch
   well — complete almost every rescue; hub-dominated chunks blow the budget
   fast and fall back to the interval verdict.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.build import bitset
from repro.graph.csr import CSRGraph


def _reverse_within_rows(indptr: np.ndarray, indices: np.ndarray) -> np.ndarray:
    """indices with every CSR row's neighbor list reversed (tie-break flip)."""
    m = indices.shape[0]
    counts = np.diff(indptr).astype(np.int64)
    starts = indptr[:-1].astype(np.int64)
    cum = np.cumsum(counts)
    pos_in_row = np.arange(m, dtype=np.int64) - np.repeat(cum - counts, counts)
    dest = np.repeat(starts + counts - 1, counts) - pos_in_row
    out = np.empty_like(indices)
    out[dest] = indices
    return out


def dfs_post_low(
    indptr: np.ndarray,
    indices: np.ndarray,
    roots: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """One DFS sweep over a DAG: (post, low) int64[n].

    post[v] = post-order number; low[v] = min post over Reach(v) (computable
    at finish time because every out-neighbor of a DAG vertex is already
    finished).  [low[v], post[v]] contains post[d] for every descendant d and
    post[v] itself.
    """
    n = indptr.shape[0] - 1
    iptr = indptr.tolist()
    idx = indices.tolist()
    post = [0] * n
    low = [0] * n
    state = bytearray(n)  # 0 new, 1 open, 2 done
    t = 0
    root_iter = range(n) if roots is None else roots.tolist()
    for r in root_iter:
        if state[r]:
            continue
        state[r] = 1
        stack = [r]
        ptr = [iptr[r]]
        while stack:
            v = stack[-1]
            p = ptr[-1]
            if p < iptr[v + 1]:
                ptr[-1] = p + 1
                w = idx[p]
                if not state[w]:
                    state[w] = 1
                    stack.append(w)
                    ptr.append(iptr[w])
            else:
                stack.pop()
                ptr.pop()
                lo = t
                for q in range(iptr[v], iptr[v + 1]):
                    lw = low[idx[q]]
                    if lw < lo:
                        lo = lw
                post[v] = t
                low[v] = lo
                state[v] = 2
                t += 1
    return np.asarray(post, dtype=np.int64), np.asarray(low, dtype=np.int64)


def dfs_intervals(g: CSRGraph, n_traversals: int = 2) -> Tuple[np.ndarray, np.ndarray]:
    """(post, low) stacked over traversals: int64[T, n] each.

    Traversal 0 uses natural root/neighbor order; traversal 1 flips both;
    further traversals use seeded random root/neighbor permutations.  More
    traversals refute more interval false positives (a pair is only "maybe"
    if EVERY traversal allows it) — the exact rescue in ``wave_schedule``
    makes 2 enough in practice.
    """
    posts, lows = [], []
    rng = np.random.default_rng(0x5EED)
    for t in range(n_traversals):
        if t == 0:
            p, l = dfs_post_low(g.indptr, g.indices)
        elif t == 1:
            p, l = dfs_post_low(
                g.indptr,
                _reverse_within_rows(g.indptr, g.indices),
                roots=np.arange(g.n - 1, -1, -1),
            )
        else:
            key = rng.random(g.m)
            row = np.repeat(np.arange(g.n), np.diff(g.indptr))
            p, l = dfs_post_low(
                g.indptr,
                g.indices[np.lexsort((key, row))],
                roots=rng.permutation(g.n),
            )
        posts.append(p)
        lows.append(l)
    return np.stack(posts), np.stack(lows)


def _interval_conflicts(P: np.ndarray, L: np.ndarray, cand: np.ndarray) -> np.ndarray:
    """bool[c, c] — conflict[a, b] = some traversal allows a -> b or b -> a."""
    p = P[:, cand]  # [T, c]
    l = L[:, cand]
    maybe = ((p[:, None, :] >= l[:, :, None]) & (p[:, None, :] <= p[:, :, None])).all(axis=0)
    return maybe | maybe.T


def _exact_conflicts(
    indptr: np.ndarray,
    indices: np.ndarray,
    cand: np.ndarray,
    scratch: np.ndarray,
    budget: int,
) -> Optional[np.ndarray]:
    """Exact pairwise reachability among candidates via a multi-source
    closure BFS with packed candidate-bit masks; None if the edge budget is
    exhausted (verdict would be unsound when truncated)."""
    c = cand.shape[0]
    mbits = bitset.member_bits(c, scratch.shape[1])
    scratch[cand] = mbits
    touched = [cand]
    frontier, fbits = cand, mbits
    edges = 0
    completed = True
    while frontier.size:
        # budget check BEFORE the gather: a single hub level can carry the
        # whole graph, and a doomed block must abort cheaply
        edges += int((indptr[frontier + 1] - indptr[frontier]).sum())
        if edges > budget:
            completed = False
            break
        nbrs, seg = bitset.csr_gather(indptr, indices, frontier)
        if nbrs.shape[0] == 0:
            break
        uniq, obits = bitset.group_or(nbrs, fbits[seg])  # indices already int64
        new = obits & ~scratch[uniq]
        keep = new.any(axis=1)
        frontier = uniq[keep]
        fbits = new[keep]
        scratch[frontier] |= fbits
        touched.append(frontier)
    if completed:
        arrived = scratch[cand] ^ mbits  # bits of OTHER candidates reaching each
        # conflicts are sparse: unpack only rows that received any bit
        nz = np.flatnonzero(arrived.any(axis=1))
        m = np.zeros((c, c), dtype=bool)  # m[b, a] = a -> b
        if nz.size:
            m[nz] = bitset.masks_to_matrix(arrived[nz], c)
        conflict = m | m.T
    scratch[np.concatenate(touched)] = 0
    return conflict if completed else None


_TRIU_CACHE: list = [np.zeros((0, 0), dtype=bool)]


def _triu_mask(c: int) -> np.ndarray:
    """Cached strict upper-triangle mask view (np.triu allocates per call)."""
    if _TRIU_CACHE[0].shape[0] < c:
        size = max(c, 256)
        _TRIU_CACHE[0] = np.triu(np.ones((size, size), dtype=bool), k=1)
    return _TRIU_CACHE[0][:c, :c]


def _block_waves(conflict: np.ndarray, c: int, max_wave: int, lengths: list) -> None:
    """Greedily split one block's conflict matrix into consecutive waves."""
    pos = 0
    while pos < c:
        limit = min(max_wave, c - pos)
        sub = conflict[pos : pos + limit, pos : pos + limit]
        bad = (sub & _triu_mask(limit)).any(axis=0)  # b conflicts with some a < b
        nz = np.flatnonzero(bad)
        wlen = max(int(nz[0]) if nz.size else limit, 1)
        lengths.append(wlen)
        pos += wlen


def wave_schedule(
    g: CSRGraph,
    order: np.ndarray,
    max_wave: int = 256,
    block: int = 256,
    n_traversals: int = 2,
    intervals: Tuple[np.ndarray, np.ndarray] | None = None,
    exact_budget: Optional[int] = None,
    abort_below_avg: Optional[float] = None,
) -> Optional[np.ndarray]:
    """Partition ``order`` into consecutive waves of mutually unreachable
    vertices.  Returns int64[n_waves] wave lengths (summing to len(order));
    wave k covers order[sum(lengths[:k]) : sum(lengths[:k+1])].

    Block-and-split: one exact closure covers a whole ``block`` of
    consecutive vertices, and every wave inside the block is carved out of
    that single conflict matrix.  Larger blocks amortize closure calls but
    pay more mask words per edge; block == max_wave measures fastest across
    the bench families.  When a block blows the closure budget (a hub cone
    is in range), bisect it so the hub lands in a small block alone; if
    closures keep blowing (closure-hostile graph), a circuit breaker pays
    once for the DFS intervals and uses them for all remaining fallbacks.

    ``abort_below_avg``: probe mode — once ~4k vertices are scheduled, give
    up and return None if the mean wave is below the threshold (the caller
    will not profit from batching; don't pay for the full schedule).
    """
    order = np.asarray(order, dtype=np.int64)
    n_total = order.shape[0]
    if n_total == 0:
        return np.empty(0, dtype=np.int64)
    block = max(block, max_wave)
    if exact_budget is None:
        # generous: a completed closure buys exact (maximal) waves, and the
        # per-block cost is bounded by the budget either way
        exact_budget = max(131072, 16 * block * max(g.m // max(g.n, 1), 1))
    indptr = g.indptr.astype(np.int64)
    indices = g.indices.astype(np.int64)
    scratch = np.zeros((g.n, bitset.n_words(block)), dtype=np.uint64)
    iv = intervals
    blown = 0
    _BLOW_LIMIT = 64  # circuit breaker: after this many blown closures, pay
    #                   for the DFS intervals once and stop bisecting

    lengths: list = []
    i = 0
    while i < n_total:
        c = min(block, n_total - i)
        while True:
            if c == 1:
                lengths.append(1)  # a lone vertex is trivially a wave
                i += 1
                break
            cand = order[i : i + c]
            if iv is not None and blown >= _BLOW_LIMIT:
                conflict = _interval_conflicts(iv[0], iv[1], cand)
            else:
                conflict = _exact_conflicts(indptr, indices, cand, scratch, exact_budget)
                if conflict is None:  # budget blown: a huge cone is in range
                    blown += 1
                    if blown >= _BLOW_LIMIT:
                        # closure-hostile graph — switch every remaining
                        # fallback to the interval certificate
                        if iv is None:
                            iv = dfs_intervals(g, n_traversals)
                        c = min(c, max_wave)  # keep interval matrices small
                        continue
                    c = c // 2  # bisect: isolate the hub into a small block
                    continue
            _block_waves(conflict, c, max_wave, lengths)
            i += c
            break
        if abort_below_avg is not None and i >= 4096 and i / len(lengths) < abort_below_avg:
            return None
    return np.asarray(lengths, dtype=np.int64)
