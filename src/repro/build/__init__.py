"""Construction engine subsystem.

The serve side got its subsystem in PR 1 (``repro.serve``); this package is
the construction counterpart.  It owns every way an index gets *built*:

  * ``engine``      — Distribution-Labeling construction engine with pluggable
                      implementations: the seed scalar path (``impl="reference"``)
                      and the wave-scheduled bit-parallel path (``impl="wave"``).
  * ``waves``       — the wave scheduler: groups consecutive vertices of the
                      §5.2 rank order whose pruned-BFS sweeps provably commute
                      (mutual unreachability, certified by DFS interval labels).
  * ``bitset``      — packed uint64/uint32 bitset utilities shared by the host
                      engine, the device engine, and tests.
  * ``traverse``    — the scalar pruned-BFS / label-merge helpers shared by the
                      reference engine and Hierarchical-Labeling.
  * ``engine_jax``  — the device formulation of the wave sweep (frontier
                      expansion through the Pallas ``bitset_mm`` OR-AND kernel).

``repro.core.distribution`` and ``repro.core.hierarchy`` are thin wrappers
over this package.
"""
from repro.build.engine import build_distribution_labels
from repro.build.waves import wave_schedule

__all__ = ["build_distribution_labels", "wave_schedule"]
