"""Construction engine subsystem.

The serve side got its subsystem in PR 1 (``repro.serve``); this package is
the construction counterpart.  It owns every way an index gets *built*:

  * ``engine``      — Distribution-Labeling construction engine with pluggable
                      implementations: the seed scalar path (``impl="reference"``),
                      the wave-scheduled bit-parallel path (``impl="wave"``), and
                      the sparse device wave engine (``impl="device"``).
  * ``waves``       — the wave schedulers: the one-pass rank-windowed scheduler
                      (default) and the per-block closure scheduler, both grouping
                      consecutive vertices of the §5.2 rank order whose pruned-BFS
                      sweeps provably commute (mutual unreachability).
  * ``bitset``      — packed uint64/uint32 bitset utilities + the degree-sorted
                      ELL slab builder shared by host engine, device engine, tests.
  * ``traverse``    — the scalar pruned-BFS / label-merge helpers shared by the
                      reference engine and Hierarchical-Labeling.
  * ``engine_jax``  — the sparse device wave engine (packed-frontier ELL
                      expansion kernel, on-device segment-scatter label append,
                      optional shard_map vertex sharding).

``repro.core.distribution`` and ``repro.core.hierarchy`` are thin wrappers
over this package.
"""
from repro.build.engine import build_distribution_labels
from repro.build.waves import wave_schedule

__all__ = ["build_distribution_labels", "wave_schedule"]
