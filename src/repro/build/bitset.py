"""Packed-bitset utilities for the construction engine.

The wave engine represents per-wave BFS state as *member masks*: K = ceil(W/64)
uint64 words per vertex whose bit j says "wave member j".  Frontiers, visited
sets, prune verdicts, and the per-hop label-membership table are all arrays of
such words, so every Algorithm-2 prune test collapses to word-wide AND/OR over
contiguous numpy memory.  This module holds the word-level primitives; the
sweep logic lives in ``engine.py`` / ``engine_jax.py``.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

_U1 = np.uint64(1)
_SHIFTS = np.arange(64, dtype=np.uint64)

if hasattr(np, "bitwise_count"):  # numpy >= 2.0
    def _popcount(x: np.ndarray) -> np.ndarray:
        return np.bitwise_count(x).astype(np.int64)
else:  # SWAR fallback for older numpy
    def _popcount(x: np.ndarray) -> np.ndarray:
        x = x.astype(np.uint64)
        x = x - ((x >> _U1) & np.uint64(0x5555555555555555))
        x = (x & np.uint64(0x3333333333333333)) + ((x >> np.uint64(2)) & np.uint64(0x3333333333333333))
        x = (x + (x >> np.uint64(4))) & np.uint64(0x0F0F0F0F0F0F0F0F)
        return ((x * np.uint64(0x0101010101010101)) >> np.uint64(56)).astype(np.int64)


def popcount_u64(x: np.ndarray) -> np.ndarray:
    """Population count; multi-word mask rows ([..., K]) sum over words."""
    p = _popcount(x)
    return p.sum(axis=-1) if p.ndim > 1 else p


def n_words(width: int) -> int:
    """uint64 words needed for ``width`` member bits."""
    return max((width + 63) // 64, 1)


def member_bits(width: int, k: int | None = None) -> np.ndarray:
    """uint64[width, k] — row j holds the one-hot mask of member j.  ``k``
    defaults to the minimum word count; pass the scratch arrays' word count
    so masks align with preallocated state."""
    if k is None:
        k = n_words(width)
    bits = np.zeros((width, k), dtype=np.uint64)
    j = np.arange(width)
    bits[j, j // 64] = _U1 << (j % 64).astype(np.uint64)
    return bits


def expand_member_bits(
    bits: np.ndarray, width: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Unpack member-mask rows into (row, member, counts) index arrays.

    bits: uint64[k, K] -> (row int64[t], member int64[t], counts int64[k])
    listing every set bit, row-major: all members of bits[0] first
    (ascending member), then bits[1]…

    Most rows carry a single bit (one member labels the vertex), so those go
    through an arithmetic fast path; only multi-bit rows pay for the dense
    bit table.
    """
    counts = popcount_u64(bits)
    if int(counts.max(initial=0)) <= 1:
        rows = np.flatnonzero(counts)
        return rows, _single_bit_members(bits[rows]), counts
    single = counts == 1
    multi = ~single & (counts > 0)
    rows_s = np.flatnonzero(single)
    mem_s = _single_bit_members(bits[rows_s])
    rows_m = np.flatnonzero(multi)
    sub = bits[rows_m]
    table = (sub[:, :, None] >> _SHIFTS[None, None, :]) & _U1
    r_m, mem_m = np.nonzero(table.reshape(sub.shape[0], -1)[:, :width])
    # merge, keeping row-major order (each row is single xor multi, and the
    # stable sort preserves the ascending member order within a row)
    rows = np.concatenate([rows_s, rows_m[r_m]])
    members = np.concatenate([mem_s, mem_m.astype(np.int64)])
    order = np.argsort(rows, kind="stable")
    return rows[order], members[order], counts


def _single_bit_members(sub: np.ndarray) -> np.ndarray:
    """member index of each single-bit mask row: uint64[r, K] -> int64[r]."""
    if sub.shape[0] == 0:
        return np.empty(0, dtype=np.int64)
    word = np.argmax(sub != 0, axis=1)
    val = sub[np.arange(sub.shape[0]), word]
    return word * 64 + _popcount(val - _U1)


def prefix_bits(width: int, k: int | None = None) -> np.ndarray:
    """uint64[width, k] — row j holds the mask of members i < j.

    The triangular prefix masks the speculative certification pass ANDs
    against: a violation of member j can only come from a *lower-ranked*
    wave-mate, so every candidate mask is clipped to bits < j before the
    touch-matrix intersection."""
    if k is None:
        k = n_words(width)
    j = np.arange(width)
    out = np.zeros((width, k), dtype=np.uint64)
    w_idx = j // 64
    out[np.arange(k)[None, :] < w_idx[:, None]] = np.uint64(0xFFFFFFFFFFFFFFFF)
    rem = (j % 64).astype(np.uint64)
    out[j, w_idx] = (_U1 << rem) - _U1
    return out


def touch_matrix(v_bits: np.ndarray, a_bits: np.ndarray, width: int) -> np.ndarray:
    """uint64[width, K] — row j = OR of ``a_bits`` rows whose ``v_bits`` row
    has member bit j set.

    This is the label-touched-rows aggregation of the certification pass:
    with ``v_bits`` and ``a_bits`` both = appended-label masks of the same
    store rows (``v_bits`` pre-masked to the victim members), row j collects
    *which members appended a label at some row member j labeled* — the left
    operand of the violation intersection.  Cost tracks the set bits of
    ``v_bits``, so callers should pre-mask ``v_bits`` down to the member
    bits they actually need."""
    K = a_bits.shape[1]
    out = np.zeros((width, K), dtype=np.uint64)
    if v_bits.shape[0] == 0:
        return out
    rows, members, _ = expand_member_bits(v_bits, width)
    if rows.shape[0] == 0:
        return out
    keys, orw = group_or(members, a_bits[rows])
    out[keys] = orw
    return out


def violation_mask(
    own_rev: np.ndarray,
    own_fwd: np.ndarray,
    touch_rev: np.ndarray,
    touch_fwd: np.ndarray,
    sides: bool = False,
) -> np.ndarray:
    """bool[w] — which members of a speculative wave ran on stale prune sets.

    All four operands are bank-local uint64[w, Kr] masks over the wave's w
    members.  ``own_rev[j]`` / ``own_fwd[j]`` say which wave-mates appended
    into member j's own prune-source rows (L_out(v_j) / L_in(v_j)) during
    the speculative sweep; ``touch_rev[j]`` / ``touch_fwd[j]`` say which
    wave-mates appended at rows member j's reverse/forward sweep also
    labeled (``touch_matrix``).  Member j's reverse sweep is violated when
    some lower-ranked i both entered L_in(v_j) (its prune set was stale)
    and labeled a row the sweep labeled (the staleness changed a verdict);
    the forward case is symmetric.  Because the speculative sweep
    *over*-labels relative to the sequential loop (its wave-start prune
    sets are subsets of the sequential ones), the mask is exact: every true
    sequential divergence is flagged, and a member pruned at a touched row
    anyway is not.

    With ``sides=True`` returns the pair (viol_rev, viol_fwd) instead of
    their union — violations are per-sweep, so a member stale on one side
    only needs that side rolled back and replayed."""
    w = own_rev.shape[0]
    pref = prefix_bits(w, own_rev.shape[1])
    viol_rev = ((own_fwd & pref) & touch_rev).any(axis=1)
    viol_fwd = ((own_rev & pref) & touch_fwd).any(axis=1)
    if sides:
        return viol_rev, viol_fwd
    return viol_rev | viol_fwd


def masks_to_matrix(masks: np.ndarray, width: int) -> np.ndarray:
    """uint64[r, K] member masks -> bool[r, width] membership matrix."""
    table = (masks[:, :, None] >> _SHIFTS[None, None, :]) & _U1
    return table.reshape(masks.shape[0], -1)[:, :width].astype(bool)


def group_or(keys: np.ndarray, words: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """OR-combine mask rows that share a key: the scatter-OR of a frontier.

    keys int64[t], words uint64[t, K] -> (unique_keys_sorted, or_of_rows).
    This is how duplicate BFS edge hits and shared hops merge without
    np.ufunc.at.
    """
    if keys.size == 0:
        return keys, words
    order = np.argsort(keys, kind="stable")
    sk = keys[order]
    sw = words[order]
    starts = np.flatnonzero(np.concatenate(([True], sk[1:] != sk[:-1])))
    return sk[starts], np.bitwise_or.reduceat(sw, starts, axis=0)


def csr_gather(
    indptr: np.ndarray, indices: np.ndarray, verts: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """Concatenate the CSR neighbor lists of ``verts`` in one shot.

    Returns (neighbors, seg) where seg[k] is the position in ``verts`` whose
    adjacency produced neighbors[k] — the vectorized multi-source frontier
    expansion used by every wave sweep.
    """
    starts = indptr[verts]
    counts = indptr[verts + 1] - starts
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, dtype=indices.dtype), np.empty(0, dtype=np.int64)
    cum = np.cumsum(counts)
    offs = np.repeat(starts - (cum - counts), counts) + np.arange(total, dtype=np.int64)
    seg = np.repeat(np.arange(verts.shape[0], dtype=np.int64), counts)
    return indices[offs], seg


def pack_bool_rows_u32(mat: np.ndarray) -> np.ndarray:
    """bool[n, k] -> uint32[n, ceil(k/32)] with bit (j % 32) of word (j // 32)
    set iff mat[i, j] — the layout ``kernels/bitset_mm.py`` consumes."""
    n, k = mat.shape
    words = (k + 31) // 32
    padded = np.zeros((n, words * 32), dtype=bool)
    padded[:, :k] = mat
    bit = (np.uint32(1) << np.arange(32, dtype=np.uint32))[None, None, :]
    return (padded.reshape(n, words, 32).astype(np.uint32) * bit).sum(axis=2, dtype=np.uint32)


def ell_slabs(
    indptr: np.ndarray, indices: np.ndarray, n: int, width: int = 16
) -> Tuple[np.ndarray, np.ndarray, list]:
    """Degree-sorted ELL slab decomposition of a CSR adjacency.

    Rows are permuted by degree descending, then neighbor lists are cut into
    fixed-``width`` column slabs: slab s holds neighbor slots
    [s*width, (s+1)*width) and only spans the first r_s permuted rows (those
    with degree > s*width), so total slot count is O(m + n*width) — never
    the dense n x n bits the old device demonstrator materialized.  Skewed
    degree distributions cost extra slabs over a FEW rows instead of forcing
    every row to hub width.

    Returns (perm, pos_of, slabs): ``perm`` int64[n] degree-sorted vertex
    ids, ``pos_of`` its inverse (vertex -> permuted row), ``slabs`` a list
    of INVALID-padded int32[r_s, width] neighbor-id arrays whose row i holds
    slots of vertex perm[i].
    """
    deg = np.diff(indptr).astype(np.int64)
    perm = np.argsort(-deg, kind="stable").astype(np.int64)
    pos_of = np.empty(n, dtype=np.int64)
    pos_of[perm] = np.arange(n, dtype=np.int64)
    sdeg = deg[perm]
    starts = indptr[perm].astype(np.int64)
    max_deg = int(sdeg[0]) if n else 0
    slabs = []
    s = 0
    while s * width < max_deg:
        r = int(np.searchsorted(-sdeg, -(s * width), side="left"))
        r = max(r, 1)
        take = np.minimum(np.maximum(sdeg[:r] - s * width, 0), width)
        slab = np.full((r, width), -1, dtype=np.int32)
        cols = np.arange(width, dtype=np.int64)[None, :]
        in_row = cols < take[:, None]
        offs = starts[:r, None] + s * width + cols
        slab[in_row] = indices[offs[in_row]]
        slabs.append(slab)
        s += 1
    return perm, pos_of, slabs


