"""Fault tolerance: the training loop's checkpoint/restart posture
(``loop``) and the deterministic fault-injection harness the oracle
lifecycle's chaos tests drive (``inject``)."""
from repro.ft.inject import Injector, SimulatedFailure, active, fire, flip_bit, seeded

__all__ = [
    "FaultTolerantLoop",
    "SimulatedFailure",
    "Injector",
    "active",
    "fire",
    "flip_bit",
    "seeded",
]


def __getattr__(name):
    # FaultTolerantLoop pulls in jax + the checkpointer; keep that import
    # out of consumers that only need the injection hooks (repro.persist)
    if name == "FaultTolerantLoop":
        from repro.ft.loop import FaultTolerantLoop

        return FaultTolerantLoop
    raise AttributeError(name)
