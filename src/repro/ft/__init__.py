from repro.ft.loop import FaultTolerantLoop, SimulatedFailure

__all__ = ["FaultTolerantLoop", "SimulatedFailure"]
