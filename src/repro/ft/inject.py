"""Deterministic fault injection for the oracle lifecycle.

``ft/loop.py`` proved the posture for the training loop with a single
``fail_at`` hook; this module promotes it into a registry of named,
seed-addressable injection points that the chaos test suite (and the
``repro.launch.chaos`` smoke driver) aims at production code paths:

  ========================  =====================================================
  site                      fired by
  ========================  =====================================================
  ``build.wave``            ``build/engine.py`` before each wave sweep
  ``build.chunk``           before each speculative chunk
  ``build.spec_replay``     ``_correct_chunk`` between watermark rollback and
                            the surviving-entry re-append
  ``dynamic.publish``       ``dynamic/versioned.py`` mid-publish, after the
                            staged compacting rebuild, before the commit point
  ``serve.device_dispatch``  ``serve/engine.py`` before a device batch
  ``serve.retruncate``      ``serve/budget.py`` before the budget governor
                            re-truncates the label store (a budget apply)
  ``persist.pre_rename``    ``persist/blocks.py`` after the tmp write, before
                            the atomic rename
  ========================  =====================================================

Usage::

    from repro.ft import inject

    with inject.active(inject.Injector({"build.wave": 3})):
        build_distribution_labels(g, impl="wave", checkpoint_dir=d)
    # -> SimulatedFailure on the 4th (0-based index 3) wave boundary

Injectors are deterministic: a rule maps a site to the occurrence index that
fires (every ``fire`` call counts occurrences per site).  ``seeded`` derives
the occurrence indices from a seed so chaos sweeps can address "a random but
reproducible crash point" without hand-picking indices.  Production code
calls ``fire`` unconditionally; with no active injector it is a counter
bump and nothing more.

Faults come in two flavors:

  * **hard failures** (``rules``) raise ``SimulatedFailure`` at the chosen
    occurrence — the crash/outage case,
  * **latency stalls** (``latency``) sleep at the chosen occurrences instead
    of raising — the slow-device / slow-publish case the serving daemon's
    latency-SLO circuit breaker and deadline shedding exist for.  A stalled
    call still runs; only its wall time changes, so stalls compose with the
    failure rules (a site can stall at one occurrence and fail at another).

``flip_bit`` is the load-time corruption primitive: one deterministic bit
flip in a file on disk, for testing that checksummed loads fail loudly.
"""
from __future__ import annotations

import contextlib
import os
import time
from typing import Dict, Iterable, List, Optional, Tuple, Union

import numpy as np

from repro.obs import metrics, trace
from repro.obs.state import ON

_M_FAULTS = metrics.counter(
    "faults_injected_total", "simulated faults that actually fired, by kind",
    labelnames=("kind",))
_FAULT_STALL = _M_FAULTS.labels(kind="stall")
_FAULT_FAIL = _M_FAULTS.labels(kind="fail")


class SimulatedFailure(RuntimeError):
    """Raised by a fault-injection hook to emulate a crash.

    (Historically defined in ``ft/loop.py``; it lives here now and is
    re-exported there for compatibility.)"""


Rule = Union[int, Iterable[int]]


def _as_set(at: Rule) -> frozenset:
    return frozenset([at]) if isinstance(at, (int, np.integer)) else frozenset(at)


class Injector:
    """Deterministic injection plan: site -> occurrence index(es) that fail.

    ``rules`` maps a site name to the 0-based occurrence index at which
    ``fire(site)`` raises ``SimulatedFailure`` (or an iterable of such
    indexes).  ``latency`` maps a site to ``(occurrences, seconds)``: those
    occurrences SLEEP for ``seconds`` instead of raising — deterministic
    slow-path injection for deadline/SLO testing.  Occurrence counts live on
    the injector, so one plan can be inspected after the run (``counts``)
    and a fresh plan replays identically.  One occurrence counter per site
    feeds both rule kinds, so a plan addresses "stall the 2nd dispatch,
    kill the 5th" without double counting."""

    def __init__(self, rules: Optional[Dict[str, Rule]] = None,
                 latency: Optional[Dict[str, Tuple[Rule, float]]] = None):
        self.rules: Dict[str, frozenset] = {
            site: _as_set(at) for site, at in (rules or {}).items()
        }
        self.latency: Dict[str, Tuple[frozenset, float]] = {
            site: (_as_set(at), float(seconds))
            for site, (at, seconds) in (latency or {}).items()
        }
        self.counts: Dict[str, int] = {}
        self.fired: List[str] = []
        self.stalled: List[str] = []

    def fire(self, site: str, **info) -> None:
        idx = self.counts.get(site, 0)
        self.counts[site] = idx + 1
        lat = self.latency.get(site)
        if lat is not None and idx in lat[0]:
            # stall BEFORE the failure check: a site can be both slow and
            # then fail at a later occurrence, mirroring a degrading device
            self.stalled.append(f"{site}[{idx}]")
            _FAULT_STALL.inc()
            if ON.enabled:
                # the stall itself is a span: the exported timeline shows the
                # injected latency exactly where the dispatch paid it
                with trace.span("fault.stall", cat="fault",
                                args={"site": site, "occurrence": idx,
                                      "seconds": lat[1]}):
                    time.sleep(lat[1])
            else:
                time.sleep(lat[1])
        if idx in self.rules.get(site, ()):
            detail = " ".join(f"{k}={v}" for k, v in sorted(info.items()))
            self.fired.append(site)
            _FAULT_FAIL.inc()
            if ON.enabled:
                trace.event("fault.fail", cat="fault", site=site,
                            occurrence=idx, **info)
            raise SimulatedFailure(
                f"injected failure at {site}[{idx}]" + (f" ({detail})" if detail else ""))


def seeded(seed: int, sites: Dict[str, int]) -> Injector:
    """Seed-addressable plan: for each ``site -> horizon`` pick one
    occurrence index in ``[0, horizon)`` deterministically from ``seed``.
    Sites are consumed in sorted order so the plan depends only on
    ``(seed, sites)``."""
    rng = np.random.default_rng(seed)
    return Injector({s: int(rng.integers(0, max(int(h), 1)))
                     for s, h in sorted(sites.items())})


# ------------------------------------------------------------ active stack

_ACTIVE: List[Injector] = []


@contextlib.contextmanager
def active(injector: Injector):
    """Install ``injector`` for the duration of the block (stackable)."""
    _ACTIVE.append(injector)
    try:
        yield injector
    finally:
        _ACTIVE.remove(injector)


def fire(site: str, **info) -> None:
    """Production-side hook: raise if any active injector targets this
    occurrence of ``site``.  No-op (beyond counting) otherwise."""
    for inj in _ACTIVE:
        inj.fire(site, **info)


# ------------------------------------------------------- corruption tool

def flip_bit(path: str, seed: int = 0, offset: Optional[int] = None) -> int:
    """Flip one bit of the file at ``path`` in place; returns the byte
    offset touched.  Deterministic in ``(file size, seed)`` unless an
    explicit ``offset`` is given.  This is the chaos suite's "disk
    corruption" primitive for proving checksummed loads fail loudly."""
    size = os.path.getsize(path)
    if size == 0:
        raise ValueError(f"cannot corrupt empty file {path}")
    if offset is None:
        rng = np.random.default_rng(seed)
        # skip the first 16 bytes: corrupting an npy magic/header tests the
        # parser, not the checksum — the payload is the interesting target
        lo = min(16, size - 1)
        offset = int(rng.integers(lo, size))
    bit = 1 << int(np.random.default_rng(seed + 1).integers(0, 8))
    with open(path, "r+b") as f:
        f.seek(offset)
        byte = f.read(1)
        f.seek(offset)
        f.write(bytes([byte[0] ^ bit]))
    return offset
