"""Fault-tolerant training loop.

Production posture for 1000+ nodes (documented here, exercised at container
scale by tests):

  * checkpoint/restart: atomic step checkpoints (params + optimizer + step);
    on (re)start the loop scans the directory and resumes from the latest
    complete checkpoint. Data pipeline is (seed, step)-deterministic, so no
    reader state is persisted.
  * node failure: in synchronous SPMD a dead host kills the step; the
    launcher restarts the job and this loop resumes. SimulatedFailure tests
    that path end-to-end in-process.
  * elastic re-mesh: checkpoints are host-numpy and mesh-agnostic; a restart
    may jit the same step onto a different mesh shape (fewer/more DP ranks)
    — restore + re-jit is the whole migration.
  * straggler mitigation: synchronous steps can't drop a slow rank, so the
    levers are (a) deterministic, skew-free sharded data (no dynamic work
    imbalance), (b) async checkpointing off the critical path, (c) bounded
    per-step collective count (fused all-reduces), all implemented here /
    in optim. Speculative-redundancy (hot spares) is a launcher concern,
    noted in DESIGN.md.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

import jax

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
# SimulatedFailure moved to repro.ft.inject (the shared fault-injection
# harness); re-exported here so existing imports keep working
from repro.ft.inject import SimulatedFailure  # noqa: F401


class FaultTolerantLoop:
    def __init__(
        self,
        step_fn: Callable,           # (state, batch) -> (state, metrics)
        batch_fn: Callable,          # (step) -> batch
        init_state: Any,
        ckpt_dir: str,
        ckpt_every: int = 50,
        keep: int = 3,
        fail_at: Optional[int] = None,   # fault injection (tests)
    ):
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.state = init_state
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.ckpt = AsyncCheckpointer(ckpt_dir, keep=keep)
        self.fail_at = fail_at
        self.start_step = 0
        self.metrics_log: list[Dict] = []

    def maybe_restore(self) -> int:
        s = latest_step(self.ckpt_dir)
        if s is not None:
            self.state = restore_checkpoint(self.ckpt_dir, s, self.state)
            self.start_step = s
        return self.start_step

    def run(self, n_steps: int, log_every: int = 10) -> Any:
        step = self.maybe_restore()
        while step < n_steps:
            if self.fail_at is not None and step == self.fail_at:
                self.fail_at = None  # fail once
                raise SimulatedFailure(f"injected failure at step {step}")
            t0 = time.perf_counter()
            batch = self.batch_fn(step)
            self.state, metrics = self.step_fn(self.state, batch)
            step += 1
            if step % self.ckpt_every == 0 or step == n_steps:
                self.ckpt.save(step, self.state)
            if step % log_every == 0 or step == n_steps:
                m = {k: float(v) for k, v in metrics.items()}
                m.update(step=step, sec=time.perf_counter() - t0)
                self.metrics_log.append(m)
        self.ckpt.wait()
        return self.state
