"""Span tracer with a bounded ring buffer and Chrome-trace JSON export.

Everything the stack does between "request admitted" and "future resolved"
— and everything a build does between "schedule" and "finalize" — can open
a span here.  Completed spans land in a ``deque(maxlen=...)`` ring of
Chrome trace events (the `Trace Event Format`_ that ``chrome://tracing``
and https://ui.perfetto.dev load directly), so a faulted run exports a
timeline an operator can actually scrub:

  * daemon requests carry a ``trace_id`` from admission through queueing,
    the dispatch tick, the padded device call, and the merge/degradation
    rung to completion; sheds and queue expiries are terminal instant
    events on the same id,
  * build runs emit per-wave / per-chunk spans (schedule, sweep, prune
    gather, speculative certify / rollback / replay, checkpoint write),
  * injected faults (``repro.ft.inject``) log instant events at the exact
    occurrence that stalled or failed.

The tracer is process-global (``TRACER``) like the metrics registry.  When
``obs.disable()`` is active, ``span()`` returns one shared no-op context
manager and ``event()`` returns immediately; hot call sites additionally
guard on ``ON.enabled`` before building args dicts, making the disabled
path allocation-free.

``annotate=True`` spans also enter a ``jax.profiler.TraceAnnotation`` (when
jax is importable and annotations are switched on via
``TRACER.jax_annotations = True``), so device spans line up with XLA's own
profiler timeline.

.. _Trace Event Format:
   https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU
"""
from __future__ import annotations

import collections
import itertools
import json
import threading
import time
from typing import Dict, Optional

from repro.obs.state import ON

_EPOCH_NS = time.perf_counter_ns()


def _now_us() -> float:
    return (time.perf_counter_ns() - _EPOCH_NS) / 1000.0


class _NoopSpan:
    """Shared do-nothing span: the disabled path allocates nothing."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def event(self, name, **args):
        pass

    def set(self, **args):
        pass


NOOP_SPAN = _NoopSpan()


class _Span:
    __slots__ = ("tracer", "name", "cat", "args", "t0", "_ann")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: Optional[dict], annotate: bool):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self._ann = None
        if annotate and tracer.jax_annotations:
            try:
                import jax
                self._ann = jax.profiler.TraceAnnotation(name)
            except Exception:
                self._ann = None
        self.t0 = _now_us()

    def __enter__(self):
        if self._ann is not None:
            self._ann.__enter__()
        return self

    def __exit__(self, *exc):
        if self._ann is not None:
            self._ann.__exit__(*exc)
        self.tracer._complete(self.name, self.cat, self.t0,
                              _now_us() - self.t0, self.args)
        return False

    def event(self, name: str, **args) -> None:
        """Instant event nested inside this span (inherits cat/trace_id)."""
        if self.args and "trace_id" in self.args:
            args.setdefault("trace_id", self.args["trace_id"])
        self.tracer.event(name, cat=self.cat, **args)

    def set(self, **args) -> None:
        """Attach args discovered mid-span (e.g. the rung a dispatch took)."""
        if self.args is None:
            self.args = {}
        self.args.update(args)


class Tracer:
    """Bounded ring of completed Chrome trace events + span factories."""

    def __init__(self, capacity: int = 65536):
        self.events: collections.deque = collections.deque(maxlen=capacity)
        self.jax_annotations = False
        self._trace_ids = itertools.count(1)
        self._tid_map: Dict[int, int] = {}
        self._tid_lock = threading.Lock()

    # ------------------------------------------------------------ plumbing

    def new_trace_id(self) -> int:
        """Monotonic per-process request id, carried through span args."""
        return next(self._trace_ids)

    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tid_map.get(ident)
        if tid is None:
            with self._tid_lock:
                tid = self._tid_map.setdefault(ident, len(self._tid_map))
        return tid

    def _complete(self, name, cat, ts_us, dur_us, args) -> None:
        ev = {"ph": "X", "name": name, "cat": cat or "default", "pid": 0,
              "tid": self._tid(), "ts": ts_us, "dur": dur_us}
        if args:
            ev["args"] = args
        self.events.append(ev)

    # ------------------------------------------------------------- surface

    def span(self, name: str, cat: str = "", args: Optional[dict] = None,
             annotate: bool = False):
        """Context manager measuring one complete ("X") event."""
        if not ON.enabled:
            return NOOP_SPAN
        return _Span(self, name, cat, args, annotate)

    def begin(self, name: str, cat: str = "", args: Optional[dict] = None):
        """Explicit begin for spans that end in another thread/callback;
        finish with ``end(token)``."""
        if not ON.enabled:
            return None
        return (name, cat, args, _now_us())

    def end(self, token, **extra) -> None:
        if token is None or not ON.enabled:
            return
        name, cat, args, t0 = token
        if extra:
            args = dict(args or {}, **extra)
        self._complete(name, cat, t0, _now_us() - t0, args)

    def event(self, name: str, cat: str = "", **args) -> None:
        """Instant ("i") event — terminal sheds, breaker flips, faults."""
        if not ON.enabled:
            return
        ev = {"ph": "i", "name": name, "cat": cat or "default", "pid": 0,
              "tid": self._tid(), "ts": _now_us(), "s": "t"}
        if args:
            ev["args"] = args
        self.events.append(ev)

    # -------------------------------------------------------------- export

    def export_chrome(self, path: str, meta: Optional[dict] = None) -> None:
        """Write the ring as a Perfetto/chrome://tracing-loadable JSON file."""
        with open(path, "w") as f:
            json.dump(self.chrome_payload(meta), f)
            f.write("\n")

    def chrome_payload(self, meta: Optional[dict] = None) -> dict:
        payload = {"traceEvents": sorted(self.events, key=lambda e: e["ts"]),
                   "displayTimeUnit": "ms"}
        if meta:
            payload["metadata"] = meta
        return payload

    def clear(self) -> None:
        self.events.clear()


TRACER = Tracer()

span = TRACER.span
event = TRACER.event
begin = TRACER.begin
end = TRACER.end
new_trace_id = TRACER.new_trace_id
export_chrome = TRACER.export_chrome
