"""Process-global labeled Counter / Gauge / Histogram registry.

One registry for every layer of the serving stack: the daemon's admission
and shed counters, the QueryEngine's degradation ladder, build stage
seconds, dynamic publish totals, and injected-fault counts all register
here, so one ``snapshot()`` (or ``export_json``) answers what five ad-hoc
dicts used to.  The pre-existing surfaces — ``ServeDaemon.health()``,
``QueryEngine.stats()``, ``build_stats``, ``growth_log`` — remain as thin
views; the registry is the shared substrate underneath them.

Design constraints, in order:

  * **cheap on the daemon hot path** — a bound child (``Counter.labels``)
    resolves its label set once at module import; ``inc()`` afterwards is
    an enabled-flag check plus one integer add.  Histograms use fixed
    buckets and ``bisect`` into a preallocated count list — no allocation
    per observation.
  * **consistent snapshots** — ``snapshot()`` takes the registry lock, so
    a reader never sees a metric family mid-registration.  Individual adds
    are unlocked (each bound child is only ever incremented from one
    thread in practice; the GIL keeps the value sane either way).
  * **resettable** — ``reset()`` zeroes every value but keeps every
    instrument and bound child alive, so module-level bound references
    stay valid across bench reps and tests.

Metric naming: ``<layer>_<what>_<unit-or-total>``, labels for the
within-family dimension (``reason``, ``rung``, ``stage``, ``kind``).
Every name registered here must appear in the README metric table — a
tier-1 drift-guard test enforces it.
"""
from __future__ import annotations

import json
import threading
from bisect import bisect_right
from typing import Dict, Optional, Sequence, Tuple

from repro.obs.state import ON

# Default latency buckets (milliseconds): sub-ms dispatches up through the
# multi-second stalls fault injection produces.
LATENCY_BUCKETS_MS: Tuple[float, ...] = (
    0.5, 1, 2, 5, 10, 25, 50, 100, 250, 500, 1000, 2500, 5000,
)


class _BoundCounter:
    """A counter child bound to one label set; ``inc`` is the hot path."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if ON.enabled:
            self.value += n


class _BoundGauge:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = None

    def set(self, v) -> None:
        if ON.enabled:
            self.value = v


class _BoundHistogram:
    """Fixed-bucket histogram child: counts[i] = observations <= bounds[i],
    with one overflow slot; ``observe`` allocates nothing."""

    __slots__ = ("bounds", "counts", "total", "count")

    def __init__(self, bounds: Tuple[float, ...]) -> None:
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)
        self.total = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        if ON.enabled:
            self.counts[bisect_right(self.bounds, v)] += 1
            self.total += v
            self.count += 1


class _Metric:
    """One metric family: a name, a type, and its bound label children."""

    def __init__(self, name: str, kind: str, help: str,
                 labelnames: Tuple[str, ...],
                 buckets: Optional[Tuple[float, ...]] = None):
        self.name = name
        self.kind = kind
        self.help = help
        self.labelnames = labelnames
        self.buckets = buckets
        self._children: Dict[Tuple[str, ...], object] = {}
        self._lock = threading.Lock()

    def labels(self, **labels):
        """Resolve (and cache) the child for one label combination.  Call
        once at module scope and keep the bound child; do not call per
        operation."""
        if tuple(sorted(labels)) != tuple(sorted(self.labelnames)):
            raise ValueError(
                f"metric {self.name!r} takes labels {self.labelnames}, "
                f"got {tuple(labels)}")
        key = tuple(str(labels[k]) for k in self.labelnames)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._new_child()
                self._children[key] = child
        return child

    def _default(self):
        """The unlabeled child (metrics with no labelnames)."""
        return self.labels()

    def _new_child(self):
        if self.kind == "counter":
            return _BoundCounter()
        if self.kind == "gauge":
            return _BoundGauge()
        return _BoundHistogram(self.buckets)

    # unlabeled convenience passthroughs
    def inc(self, n: int = 1) -> None:
        self._default().inc(n)

    def set(self, v) -> None:
        self._default().set(v)

    def observe(self, v: float) -> None:
        self._default().observe(v)

    def value_snapshot(self) -> dict:
        out = {}
        for key, child in self._children.items():
            label = ",".join(f"{k}={v}" for k, v in zip(self.labelnames, key))
            if self.kind == "histogram":
                out[label] = {
                    "buckets_le": list(self.bounds_with_inf()),
                    "counts": list(child.counts),
                    "sum": child.total,
                    "count": child.count,
                }
            else:
                out[label] = child.value
        return out

    def bounds_with_inf(self):
        return tuple(self.buckets) + ("+Inf",)

    def reset_values(self) -> None:
        for child in self._children.values():
            if self.kind == "counter":
                child.value = 0
            elif self.kind == "gauge":
                child.value = None
            else:
                child.counts = [0] * (len(child.bounds) + 1)
                child.total = 0.0
                child.count = 0


class Registry:
    """Name -> metric family; get-or-create semantics so repeated module
    imports (pytest re-imports, multiple daemons) share one family."""

    def __init__(self) -> None:
        self._metrics: Dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _register(self, name: str, kind: str, help: str,
                  labelnames: Sequence[str],
                  buckets: Optional[Sequence[float]] = None) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if m.kind != kind or m.labelnames != tuple(labelnames):
                    raise ValueError(
                        f"metric {name!r} re-registered with a different "
                        f"shape ({m.kind}{m.labelnames} vs {kind}{tuple(labelnames)})")
                return m
            m = _Metric(name, kind, help, tuple(labelnames),
                        None if buckets is None else tuple(buckets))
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> _Metric:
        return self._register(name, "counter", help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> _Metric:
        return self._register(name, "gauge", help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = LATENCY_BUCKETS_MS) -> _Metric:
        return self._register(name, "histogram", help, labelnames,
                              buckets=buckets)

    def names(self):
        with self._lock:
            return sorted(self._metrics)

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    def snapshot(self) -> dict:
        """One consistent dict over every registered family:
        ``{name: {"type", "help", "labels", "values": {label-str: value}}}``."""
        with self._lock:
            fams = list(self._metrics.values())
        return {
            m.name: {
                "type": m.kind,
                "help": m.help,
                "labels": list(m.labelnames),
                "values": m.value_snapshot(),
            }
            for m in sorted(fams, key=lambda m: m.name)
        }

    def export_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2)
            f.write("\n")

    def reset(self) -> None:
        """Zero every value; every instrument and bound child stays alive
        (module-level bound references keep working)."""
        with self._lock:
            for m in self._metrics.values():
                m.reset_values()

    # small read helpers for tests / reconciliation
    def counter_value(self, name: str, **labels) -> int:
        m = self._metrics[name]
        key = tuple(str(labels[k]) for k in m.labelnames)
        child = m._children.get(key)
        return 0 if child is None else int(child.value)

    def counter_total(self, name: str) -> int:
        m = self._metrics[name]
        return sum(int(c.value) for c in m._children.values())


REGISTRY = Registry()

counter = REGISTRY.counter
gauge = REGISTRY.gauge
histogram = REGISTRY.histogram
snapshot = REGISTRY.snapshot
export_json = REGISTRY.export_json
