"""Unified observability layer: metrics registry + span tracing.

    from repro import obs

    obs.metrics.counter("x_total").inc()       # process-global registry
    with obs.trace.span("phase", cat="build"): # bounded Chrome-trace ring
        ...
    obs.metrics.snapshot()                     # one surface over all layers
    obs.trace.export_chrome("t.json")          # open in ui.perfetto.dev

``obs.disable()`` turns the whole layer into a no-op (instrumented hot
paths guard on ``obs.ON.enabled`` before allocating anything); the
overhead of the enabled path is gated by ``benchmarks/obs_overhead.py``
(< 3% sustained daemon qps).
"""
from repro.obs import metrics, trace
from repro.obs.state import ON, disable, enable, enabled

__all__ = ["ON", "disable", "enable", "enabled", "metrics", "trace"]
