"""Process-global on/off switch for the observability layer.

Both ``repro.obs.metrics`` and ``repro.obs.trace`` read ``ON.enabled`` on
every hot-path operation.  The flag lives in its own module (no imports
from the rest of ``repro.obs``) so instrumented code can do the cheapest
possible guard — one attribute load — before building span args or
touching a counter:

    from repro.obs.state import ON
    ...
    if ON.enabled:
        SPAN_ARGS = {...}   # only allocated when obs is on

``obs.disable()`` therefore buys a true zero-allocation no-op path: guarded
call sites skip even the argument construction, and unguarded instrument
methods return before touching any state.
"""
from __future__ import annotations


class _ObsState:
    __slots__ = ("enabled",)

    def __init__(self) -> None:
        self.enabled = True


ON = _ObsState()


def enable() -> None:
    ON.enabled = True


def disable() -> None:
    ON.enabled = False


def enabled() -> bool:
    return ON.enabled
