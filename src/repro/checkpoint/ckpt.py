"""Fault-tolerant pytree checkpointing (no orbax dependency).

Layout: <dir>/step_<N>/arrays.npz + tree.json, written to a tmp dir and
atomically renamed — a crash mid-save never corrupts the latest checkpoint.
Restore is mesh-agnostic: arrays come back as host numpy and re-shard at the
next jit call, which is what makes elastic re-mesh (restore onto a different
device count) work.

AsyncCheckpointer overlaps the host write with the next training steps
(device->host copy happens synchronously, the file I/O in a thread).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, keep: int = 3) -> str:
    """Blocking save. Returns the final checkpoint path."""
    leaves, treedef = _flatten_with_paths(tree)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "tree.json"), "w") as f:
        json.dump({"treedef": str(treedef), "n_leaves": len(leaves), "step": step}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(
        (int(d.split("_")[1]) for d in os.listdir(ckpt_dir) if d.startswith("step_")),
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir) if d.startswith("step_")]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like: Any) -> Any:
    """Restore into the structure of `like` (shapes/dtypes validated)."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    with np.load(os.path.join(path, "arrays.npz")) as data:
        leaves_like, treedef = jax.tree.flatten(like)
        leaves = []
        for i, ref in enumerate(leaves_like):
            arr = data[f"leaf_{i}"]
            ref_shape = tuple(getattr(ref, "shape", np.shape(ref)))
            if tuple(arr.shape) != ref_shape:
                raise ValueError(f"leaf {i}: ckpt {arr.shape} != expected {ref_shape}")
            leaves.append(arr)
    return jax.tree.unflatten(treedef, leaves)


class AsyncCheckpointer:
    """Fire-and-forget saves with at-most-one in flight (back-pressure)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        # device->host copy now (so the tree can keep training), I/O in thread
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def _run():
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree, self.keep)
            except BaseException as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
