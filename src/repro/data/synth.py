"""Deterministic synthetic data pipelines.

Every batch is a pure function of (seed, step) — restart-safe by
construction: after checkpoint restore the pipeline resumes from the stored
step with no data-state file, and elastic re-mesh keeps the same global
batch semantics (each shard slices the same deterministic global batch).

The LM stream is a Zipf-ish token model with short-range structure (so the
~100M-param end-to-end example has learnable signal, not uniform noise).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph
from repro.models.gnn.layers import GraphBatch


def lm_batch(seed: int, step: int, batch: int, seq: int, vocab: int) -> Dict[str, jnp.ndarray]:
    """{tokens, labels}: int32[B, S]; labels are next-token shifted."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k1, k2 = jax.random.split(key)
    # Zipf marginal via exponential transform of uniforms
    u = jax.random.uniform(k1, (batch, seq + 1), minval=1e-6, maxval=1.0)
    z = jnp.floor(jnp.exp(jnp.log(1.0 + vocab) * u) - 1.0).astype(jnp.int32)
    z = jnp.clip(z, 0, vocab - 1)
    # short-range structure: every other token echoes its predecessor mod V
    echo = jnp.roll(z, 1, axis=1) + 7
    mix = jax.random.bernoulli(k2, 0.3, z.shape)
    toks = jnp.where(mix, jnp.clip(echo, 0, vocab - 1), z)
    return {"tokens": toks[:, :seq], "labels": toks[:, 1:]}


def lm_batch_specs(batch: int, seq: int):
    return {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }


def graph_batch_from_csr(
    g: CSRGraph,
    d_feat: int,
    seed: int = 0,
    n_classes: int = 8,
    with_pos: bool = False,
    d_edge: int | None = None,
    pad_edges_to: int | None = None,
) -> GraphBatch:
    """Wrap a host CSR graph as a padded device GraphBatch."""
    rng = np.random.default_rng(seed)
    n = g.n
    src, dst = g.edges()
    m = src.shape[0]
    m_pad = pad_edges_to or m
    pad = m_pad - m
    assert pad >= 0
    return GraphBatch(
        x=jnp.asarray(rng.standard_normal((n, d_feat)).astype(np.float32)),
        edge_src=jnp.asarray(np.concatenate([src, np.zeros(pad, np.int32)])),
        edge_dst=jnp.asarray(np.concatenate([dst, np.zeros(pad, np.int32)])),
        edge_mask=jnp.asarray(np.concatenate([np.ones(m, bool), np.zeros(pad, bool)])),
        node_mask=jnp.ones(n, bool),
        edge_attr=(
            jnp.asarray(rng.standard_normal((m_pad, d_edge)).astype(np.float32))
            if d_edge
            else None
        ),
        pos=jnp.asarray(3.0 * rng.standard_normal((n, 3)).astype(np.float32))
        if with_pos
        else None,
        y=jnp.asarray(rng.integers(0, n_classes, n).astype(np.int32)),
    )


def recsys_batch(seed: int, step: int, batch: int, n_fields: int, vocab: int):
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k1, k2 = jax.random.split(key)
    ids = jax.random.randint(k1, (batch, n_fields), 0, vocab, dtype=jnp.int32)
    # clicks correlate with a hash of two fields (learnable signal);
    # Knuth constant folded into uint32 to avoid int32 overflow
    h = ids[:, 0].astype(jnp.uint32) * jnp.uint32(2654435761) + ids[:, 1].astype(jnp.uint32)
    y = (h % jnp.uint32(97) < 30).astype(jnp.float32)
    del k2
    return {"ids": ids, "y": y}


def recsys_batch_specs(batch: int, n_fields: int):
    return {
        "ids": jax.ShapeDtypeStruct((batch, n_fields), jnp.int32),
        "y": jax.ShapeDtypeStruct((batch,), jnp.float32),
    }
