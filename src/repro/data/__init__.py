from repro.data.synth import (
    lm_batch,
    lm_batch_specs,
    graph_batch_from_csr,
    recsys_batch,
    recsys_batch_specs,
)

__all__ = [
    "lm_batch",
    "lm_batch_specs",
    "graph_batch_from_csr",
    "recsys_batch",
    "recsys_batch_specs",
]
