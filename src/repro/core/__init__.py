"""The paper's primary contribution: fast, scalable reachability oracles.

Two construction algorithms (Hierarchical-Labeling, Distribution-Labeling),
the oracle container, the batched/distributed query engine, and every
baseline the paper compares against.
"""
from repro.core.api import CondensedOracle, build_oracle, oracle_from_snapshot
from repro.core.oracle import ReachabilityOracle, finalize_labels
from repro.core.distribution import distribution_labeling
from repro.core.distribution_jax import distribution_labeling_jax
from repro.core.hierarchy import hierarchical_labeling, decompose
from repro.core.backbone import one_side_backbone, fast_cover
from repro.core.order import get_order
from repro.serve.engine import QueryEngine, intersect_rows, select_backend, serve_step

__all__ = [
    "QueryEngine",
    "select_backend",
    "CondensedOracle",
    "build_oracle",
    "oracle_from_snapshot",
    "ReachabilityOracle",
    "finalize_labels",
    "distribution_labeling",
    "distribution_labeling_jax",
    "hierarchical_labeling",
    "decompose",
    "one_side_backbone",
    "fast_cover",
    "get_order",
    "serve_step",
    "intersect_rows",
]
