"""Every approach the paper's §6 compares against (Table 2-7 columns).

All are host reference implementations with a common duck-typed interface:
  build(g) -> index object with .query(u, v) -> bool and .index_size_ints
"""
from repro.core.baselines.online_search import OnlineBFS
from repro.core.baselines.grail import Grail
from repro.core.baselines.interval import IntervalTC
from repro.core.baselines.pwah import PWAHBitvector
from repro.core.baselines.twohop import TwoHopSetCover
from repro.core.baselines.kreach import KReach

__all__ = ["OnlineBFS", "Grail", "IntervalTC", "PWAHBitvector", "TwoHopSetCover", "KReach"]
