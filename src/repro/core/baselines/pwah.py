"""Bit-vector transitive-closure compression (van Schaik & de Moor [29]).

PWAH-8 partitions words into 8-bit blocks with run-length-encoded fill words.
We implement the same idea at word granularity: each vertex's closure bitset
(over a topological renumbering, which clusters reachable ids into runs) is
stored as (word_index, word) pairs for non-zero words — a sparse word-aligned
hybrid. Query = binary search the word index, test the bit.
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph, topological_order


class PWAHBitvector:
    name = "PWAH"

    def __init__(self, g: CSRGraph):
        self.g = g
        n = g.n
        topo = topological_order(g)
        rank = np.empty(n, dtype=np.int64)
        rank[topo] = np.arange(n)
        self.rank = rank.astype(np.int32)
        words = (n + 31) // 32

        # reverse-topo closure sweep with dense scratch row, stored sparse.
        self.word_idx: list[np.ndarray] = [None] * n  # type: ignore
        self.word_val: list[np.ndarray] = [None] * n  # type: ignore
        scratch = np.zeros(words, dtype=np.uint32)
        for v in topo[::-1]:
            v = int(v)
            scratch[:] = 0
            for w in g.out_neighbors(v):
                w = int(w)
                scratch[self.word_idx[w]] |= self.word_val[w]
                rw = int(rank[w])
                scratch[rw >> 5] |= np.uint32(1) << np.uint32(rw & 31)
            nz = np.nonzero(scratch)[0]
            self.word_idx[v] = nz.astype(np.int32)
            self.word_val[v] = scratch[nz].copy()

    @property
    def index_size_ints(self) -> int:
        return int(sum(w.size * 2 for w in self.word_idx))

    def query(self, u: int, v: int) -> bool:
        if u == v:
            return True
        rv = int(self.rank[v])
        wi = rv >> 5
        idx = self.word_idx[u]
        k = int(np.searchsorted(idx, wi))
        if k >= idx.shape[0] or idx[k] != wi:
            return False
        return bool((self.word_val[u][k] >> np.uint32(rv & 31)) & np.uint32(1))


def build(g: CSRGraph) -> PWAHBitvector:
    return PWAHBitvector(g)
