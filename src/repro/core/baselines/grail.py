"""GRAIL [Yildirim et al., PVLDB 2010]: random-DFS min-post interval labels.

Each of k traversals assigns L_t(v) = [min_post_in_subtree(v), post(v)].
Invariant: u reaches v  =>  L_t(v) is contained in L_t(u) for every t.
A query first tries to *refute* via non-containment; if all k labelings are
consistent, fall back to a DFS that prunes with the same test.

The paper uses 5 traversals (its §6.1 choice); we default to the same.
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph


class Grail:
    name = "GRAIL"

    def __init__(self, g: CSRGraph, k: int = 5, seed: int = 0):
        self.g = g
        self.k = k
        n = g.n
        self.lo = np.zeros((k, n), dtype=np.int32)  # min post in subtree
        self.hi = np.zeros((k, n), dtype=np.int32)  # own post
        rng = np.random.default_rng(seed)
        roots = np.nonzero(g.in_degree() == 0)[0]
        for t in range(k):
            self._random_dfs(t, rng, roots)
        self._stamp = np.full(n, -1, dtype=np.int64)
        self._qid = 0

    def _random_dfs(self, t: int, rng: np.random.Generator, roots: np.ndarray) -> None:
        g = self.g
        n = g.n
        visited = np.zeros(n, dtype=bool)
        post = 0
        lo, hi = self.lo[t], self.hi[t]
        order = rng.permutation(roots)
        # also cover vertices unreachable from roots (cycles impossible in DAG,
        # but isolated subgraphs may lack 0-indegree entry after generators)
        all_starts = list(order) + [v for v in rng.permutation(n)]
        for s in all_starts:
            if visited[s]:
                continue
            stack = [(int(s), iter(rng.permutation(g.out_neighbors(int(s)))))]
            visited[s] = True
            while stack:
                v, it = stack[-1]
                advanced = False
                for w in it:
                    w = int(w)
                    if not visited[w]:
                        visited[w] = True
                        stack.append((w, iter(rng.permutation(g.out_neighbors(w)))))
                        advanced = True
                        break
                if not advanced:
                    stack.pop()
                    # children all done: lo = min(own post about to be assigned, children lo)
                    child_lo = post
                    for w in g.out_neighbors(v):
                        child_lo = min(child_lo, lo[w])
                    lo[v] = child_lo
                    hi[v] = post
                    post += 1

    @property
    def index_size_ints(self) -> int:
        return 2 * self.k * self.g.n

    def _maybe(self, u: int, v: int) -> bool:
        """False => definitely unreachable."""
        return bool(np.all((self.lo[:, u] <= self.lo[:, v]) & (self.hi[:, v] <= self.hi[:, u])))

    def query(self, u: int, v: int) -> bool:
        if u == v:
            return True
        if not self._maybe(u, v):
            return False
        # pruned DFS
        g = self.g
        self._qid += 1
        stamp, qid = self._stamp, self._qid
        stack = [u]
        stamp[u] = qid
        while stack:
            x = stack.pop()
            if x == v:
                return True
            for w in g.out_neighbors(x):
                w = int(w)
                if stamp[w] != qid and self._maybe(w, v):
                    stamp[w] = qid
                    stack.append(w)
        return False


def build(g: CSRGraph, k: int = 5, seed: int = 0) -> Grail:
    return Grail(g, k=k, seed=seed)
