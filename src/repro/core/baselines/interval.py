"""Interval transitive-closure compression (Nuutila [21] / Agrawal [2] style).

Vertices are numbered by DFS post-order over a spanning forest, so every
tree-descendant range is contiguous. TC(v) is stored as a sorted list of
disjoint intervals over that numbering, computed in one reverse-topological
sweep: intervals(v) = merge(own tree interval, intervals of out-neighbors).

Query(u, v): binary-search post(v) in u's interval list — the "fastest query"
family in the paper's small-graph tables.
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph, topological_order


def _merge_intervals(parts: list[np.ndarray]) -> np.ndarray:
    """parts: list of int32[k_i, 2] sorted disjoint intervals -> merged."""
    if not parts:
        return np.empty((0, 2), dtype=np.int32)
    cat = np.concatenate(parts, axis=0)
    cat = cat[np.argsort(cat[:, 0], kind="stable")]
    out = []
    cur_s, cur_e = int(cat[0, 0]), int(cat[0, 1])
    for s, e in cat[1:]:
        s, e = int(s), int(e)
        if s <= cur_e + 1:
            cur_e = max(cur_e, e)
        else:
            out.append((cur_s, cur_e))
            cur_s, cur_e = s, e
    out.append((cur_s, cur_e))
    return np.asarray(out, dtype=np.int32)


class IntervalTC:
    name = "INTERVAL"

    def __init__(self, g: CSRGraph):
        self.g = g
        n = g.n
        # spanning-forest DFS post-order numbering
        post = np.full(n, -1, dtype=np.int32)
        tree_lo = np.full(n, -1, dtype=np.int32)  # min post in tree subtree
        counter = 0
        visited = np.zeros(n, dtype=bool)
        indptr, indices = g.indptr, g.indices
        roots = list(np.nonzero(g.in_degree() == 0)[0]) + list(range(n))
        for s in roots:
            if visited[s]:
                continue
            visited[s] = True
            stack = [(int(s), int(indptr[s]), counter)]
            while stack:
                v, ei, lo_at_entry = stack[-1]
                if ei < indptr[v + 1]:
                    stack[-1] = (v, ei + 1, lo_at_entry)
                    w = int(indices[ei])
                    if not visited[w]:
                        visited[w] = True
                        stack.append((w, int(indptr[w]), counter))
                else:
                    stack.pop()
                    post[v] = counter
                    tree_lo[v] = lo_at_entry
                    counter += 1
        self.post = post

        # reverse-topo interval merge
        self.intervals: list[np.ndarray] = [np.empty((0, 2), np.int32)] * n
        topo = topological_order(g)
        for v in topo[::-1]:
            v = int(v)
            parts = [np.array([[tree_lo[v], post[v]]], dtype=np.int32)]
            for w in g.out_neighbors(v):
                parts.append(self.intervals[int(w)])
            self.intervals[v] = _merge_intervals(parts)

    @property
    def index_size_ints(self) -> int:
        return int(sum(iv.size for iv in self.intervals)) + self.g.n

    def query(self, u: int, v: int) -> bool:
        if u == v:
            return True
        iv = self.intervals[u]
        p = self.post[v]
        lo_idx = int(np.searchsorted(iv[:, 0], p, side="right")) - 1
        if lo_idx < 0:
            return False
        s, e = iv[lo_idx]
        if not (s <= p <= e):
            return False
        # own tree interval includes u itself; exclude the self-hit only
        return True if p != self.post[u] else False


def build(g: CSRGraph) -> IntervalTC:
    return IntervalTC(g)
