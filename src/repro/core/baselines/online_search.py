"""The no-index extreme: query-time BFS with early exit (paper §2.1)."""
from __future__ import annotations

from collections import deque

import numpy as np

from repro.graph.csr import CSRGraph


class OnlineBFS:
    name = "BFS"

    def __init__(self, g: CSRGraph):
        self.g = g
        self._stamp = np.full(g.n, -1, dtype=np.int64)
        self._qid = 0

    @property
    def index_size_ints(self) -> int:
        return 0  # no index

    def query(self, u: int, v: int) -> bool:
        if u == v:
            return True
        g = self.g
        self._qid += 1
        stamp, qid = self._stamp, self._qid
        dq = deque([u])
        stamp[u] = qid
        indptr, indices = g.indptr, g.indices
        while dq:
            x = dq.popleft()
            for w in indices[indptr[x] : indptr[x + 1]]:
                if w == v:
                    return True
                if stamp[w] != qid:
                    stamp[w] = qid
                    dq.append(int(w))
        return False


def build(g: CSRGraph) -> OnlineBFS:
    return OnlineBFS(g)
