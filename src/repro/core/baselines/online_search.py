"""The no-index extreme: query-time BFS with early exit (paper §2.1)."""
from __future__ import annotations

from collections import deque

import numpy as np

from repro.graph.csr import CSRGraph


class OnlineBFS:
    name = "BFS"

    def __init__(self, g: CSRGraph):
        self.g = g
        self._stamp = np.full(g.n, -1, dtype=np.int64)
        self._qid = 0

    @property
    def index_size_ints(self) -> int:
        return 0  # no index

    def query(self, u: int, v: int) -> bool:
        if u == v:
            return True
        g = self.g
        self._qid += 1
        stamp, qid = self._stamp, self._qid
        dq = deque([u])
        stamp[u] = qid
        indptr, indices = g.indptr, g.indices
        while dq:
            x = dq.popleft()
            for w in indices[indptr[x] : indptr[x + 1]]:
                if w == v:
                    return True
                if stamp[w] != qid:
                    stamp[w] = qid
                    dq.append(int(w))
        return False


def build(g: CSRGraph) -> OnlineBFS:
    return OnlineBFS(g)


def bidirectional_query(
    g: CSRGraph,
    g_rev: CSRGraph,
    u: int,
    v: int,
    node_budget: int | None = None,
) -> bool:
    """Exact label-free reachability: alternating bidirectional BFS.

    The serve engine's last degradation rung — when labels are corrupt or
    unavailable it must still return a CORRECT verdict, so this is an exact
    search, not a heuristic.  Each round expands the currently *smaller*
    frontier (forward from ``u`` over ``g``, backward from ``v`` over
    ``g_rev``); any overlap proves u -> v.  ``node_budget`` bounds only the
    bidirectional phase: once the smaller-frontier expansions have popped
    that many nodes, the search completes forward-only from the surviving
    forward frontier (still exact — the budget trades the meet-in-the-middle
    speedup away, never correctness)."""
    if u == v:
        return True
    seen_f = np.zeros(g.n, dtype=bool)
    seen_b = np.zeros(g.n, dtype=bool)
    seen_f[u] = True
    seen_b[v] = True
    front_f = np.asarray([u], dtype=np.int64)
    front_b = np.asarray([v], dtype=np.int64)
    popped = 0

    def _expand(front, indptr, indices, seen):
        counts = indptr[front + 1] - indptr[front]
        if not counts.sum():
            return np.empty(0, dtype=np.int64)
        nbrs = np.concatenate([indices[indptr[x]: indptr[x + 1]] for x in front])
        nbrs = np.unique(nbrs)
        fresh = nbrs[~seen[nbrs]]
        seen[fresh] = True
        return fresh

    while front_f.size and front_b.size:
        if node_budget is not None and popped >= node_budget:
            break
        if front_f.size <= front_b.size:
            popped += front_f.size
            front_f = _expand(front_f, g.indptr, g.indices, seen_f)
            if seen_b[front_f].any():
                return True
        else:
            popped += front_b.size
            front_b = _expand(front_b, g_rev.indptr, g_rev.indices, seen_b)
            if seen_f[front_b].any():
                return True
    if not front_f.size or not front_b.size:
        return False
    # budget exhausted: finish forward-only (seen_f already prunes revisits)
    while front_f.size:
        front_f = _expand(front_f, g.indptr, g.indices, seen_f)
        if seen_b[front_f].any():
            return True
    return False
