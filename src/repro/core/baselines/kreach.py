"""K-Reach (Cheng et al., VLDB 2012) specialized to basic reachability (k=inf).

Vertex-cover based: greedily 2-approximate a vertex cover C of the DAG, then
fully materialize pairwise reachability among C (bitsets). Every edge has an
endpoint in C, so any path alternates into C quickly:

  query(u, v):  u,v in C        -> lookup
                u in C, v not   -> exists in-cover in-neighbor b of v: u ~> b
                u not, v in C   -> exists out-cover neighbor a of u: a ~> v
                neither         -> direct edge u->v, or a in N_out(u) cap C,
                                   b in N_in(v) cap C with a ~> b

The paper's observation (§2.3): the pairwise materialization over C is what
kills this approach on large graphs — C is often a large fraction of V.
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph
from repro.graph.reach import transitive_closure_bits


class KReach:
    name = "K-REACH"

    def __init__(self, g: CSRGraph):
        self.g = g
        n = g.n
        # greedy 2-approx vertex cover: repeatedly take both endpoints of an
        # uncovered edge (classic maximal matching).
        in_cover = np.zeros(n, dtype=bool)
        src, dst = g.edges()
        for a, b in zip(src, dst):
            if not (in_cover[a] or in_cover[b]):
                in_cover[a] = True
                in_cover[b] = True
        self.in_cover = in_cover
        cover = np.nonzero(in_cover)[0].astype(np.int32)
        self.cover = cover
        self.cover_id = np.full(n, -1, dtype=np.int32)
        self.cover_id[cover] = np.arange(cover.shape[0], dtype=np.int32)

        # pairwise reachability among cover, via the full-graph closure
        # projected onto C (an induced-subgraph closure would lose paths
        # through non-cover interior vertices).
        tc_full = transitive_closure_bits(g)
        kc = cover.shape[0]
        words_c = (kc + 31) // 32
        self.tc_cover = np.zeros((kc, words_c), dtype=np.uint32)
        for i, a in enumerate(cover):
            bits = np.unpackbits(tc_full[int(a)].view(np.uint8), bitorder="little")[:n]
            reach_cover = np.nonzero(bits[cover])[0]
            for j in reach_cover:
                self.tc_cover[i, j >> 5] |= np.uint32(1) << np.uint32(j & 31)

    @property
    def index_size_ints(self) -> int:
        return int(self.tc_cover.size) + self.g.n

    def _cc(self, i: int, j: int) -> bool:
        """cover-local reachability lookup (i, j cover ids)."""
        if i == j:
            return True
        return bool((self.tc_cover[i, j >> 5] >> np.uint32(j & 31)) & np.uint32(1))

    def query(self, u: int, v: int) -> bool:
        if u == v:
            return True
        g, cid = self.g, self.cover_id
        iu, iv = int(cid[u]), int(cid[v])
        if iu >= 0 and iv >= 0:
            return self._cc(iu, iv)
        if iu >= 0:
            # v not in cover: all in-edges of v come from cover
            rev_nbrs = [int(x) for x in self._in_neighbors(v)]
            return any(self._cc(iu, int(cid[b])) for b in rev_nbrs if cid[b] >= 0)
        if iv >= 0:
            out_nbrs = g.out_neighbors(u)
            return any(self._cc(int(cid[a]), iv) for a in out_nbrs if cid[a] >= 0)
        # neither in cover: direct edge, else through two cover vertices
        out_nbrs = [int(a) for a in g.out_neighbors(u)]
        if v in out_nbrs:
            return True
        in_nbrs = [int(b) for b in self._in_neighbors(v)]
        ca = [int(cid[a]) for a in out_nbrs if cid[a] >= 0]
        cb = [int(cid[b]) for b in in_nbrs if cid[b] >= 0]
        return any(self._cc(a, b) for a in ca for b in cb)

    def _in_neighbors(self, v: int):
        if not hasattr(self, "_grev"):
            self._grev = self.g.reverse()
        return self._grev.out_neighbors(v)


def build(g: CSRGraph) -> KReach:
    return KReach(g)
