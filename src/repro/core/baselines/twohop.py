"""Cohen et al. 2-hop labeling via greedy set cover [11] (the paper's 2HOP).

The classic construction the paper is beating: materialize the transitive
closure, then greedily select hops with a lazy (accelerated) greedy over the
"star" candidate family: hop w covers uncovered pairs in
(TC^-1(w) u {w}) x (TC(w) u {w}); benefit = newly covered / (|X| + |Y|).
Benefits only decrease as coverage grows (submodular), so a lazy priority
queue avoids full re-evaluation.

Deliberately faithful to the paper's complaint: requires the FULL transitive
closure (O(n^2/32) words) and repeated benefit scans — it is slow and
memory-hungry on large graphs (it fails there in the paper's Table 7 too;
benchmarks run it at reduced scale).
"""
from __future__ import annotations

import heapq

import numpy as np

from repro.core.oracle import ReachabilityOracle, finalize_labels
from repro.graph.csr import CSRGraph
from repro.graph.reach import transitive_closure_bits


def _bits_to_indices(row: np.ndarray) -> np.ndarray:
    return np.nonzero(np.unpackbits(row.view(np.uint8), bitorder="little"))[0]


class TwoHopSetCover:
    name = "2HOP"

    def __init__(self, g: CSRGraph, max_rounds: int | None = None):
        n = g.n
        tc = transitive_closure_bits(g)  # tc[u] = bitset of TC(u), no self bits
        # reverse closure bitsets
        rtc = np.zeros_like(tc)
        for u in range(n):
            for v in _bits_to_indices(tc[u]):
                rtc[v, u >> 5] |= np.uint32(1) << np.uint32(u & 31)

        uncovered = tc.copy()
        out_lists: list[list[int]] = [[w] for w in range(n)]  # self hops
        in_lists: list[list[int]] = [[w] for w in range(n)]

        def star(w: int):
            """(xs, ys_plus_bits): candidate sources and target bitset (TC(w)+{w})."""
            xs = _bits_to_indices(rtc[w])
            ys_plus = tc[w].copy()
            ys_plus[w >> 5] |= np.uint32(1) << np.uint32(w & 31)
            return xs, ys_plus

        def benefit(w: int) -> float:
            xs, ys_plus = star(w)
            rows = np.concatenate([xs, [w]])
            new = int(np.bitwise_count(uncovered[rows] & ys_plus[None, :]).sum())
            cost = rows.shape[0] + int(np.bitwise_count(ys_plus).sum())
            return new / max(cost, 1)

        heap = [(-benefit(w), 0, w) for w in range(n)]
        heapq.heapify(heap)
        version = np.zeros(n, dtype=np.int64)
        total_uncovered = int(np.bitwise_count(uncovered).sum())
        rounds, cap = 0, (max_rounds if max_rounds is not None else 8 * n)

        while total_uncovered > 0 and heap and rounds < cap:
            neg_b, ver, w = heapq.heappop(heap)
            if ver != version[w]:  # stale: refresh lazily
                version[w] += 1
                heapq.heappush(heap, (-benefit(w), int(version[w]), w))
                continue
            if -neg_b <= 0:
                break
            rounds += 1
            xs, ys_plus = star(w)
            rows = np.concatenate([xs, [w]]).astype(np.int64)
            gain_rows = rows[np.bitwise_count(uncovered[rows] & ys_plus[None, :]).sum(axis=1) > 0]
            if gain_rows.shape[0] == 0:
                version[w] += 1
                continue
            # targets that still need w in L_in: union of uncovered&TC(w) over gainers
            need = np.bitwise_or.reduce(uncovered[gain_rows] & tc[w][None, :], axis=0)
            for y in _bits_to_indices(need):
                in_lists[int(y)].append(w)
            for u in gain_rows:
                u = int(u)
                if u != w:
                    out_lists[u].append(w)
                covered_now = uncovered[u] & ys_plus
                uncovered[u] &= ~ys_plus
                total_uncovered -= int(np.bitwise_count(covered_now).sum())
            version[gain_rows] += 1
            version[w] += 1

        self.oracle: ReachabilityOracle = finalize_labels(out_lists, in_lists)

    @property
    def index_size_ints(self) -> int:
        return self.oracle.total_label_size

    def query(self, u: int, v: int) -> bool:
        if u == v:
            return True
        return self.oracle.query(u, v)


def build(g: CSRGraph) -> TwoHopSetCover:
    return TwoHopSetCover(g)
