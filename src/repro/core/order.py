"""Vertex total orders (§5.2 'Vertex Order').

The paper's recommended rank function is (|N_out(v)|+1) * (|N_in(v)|+1) —
the number of vertex pairs within distance 2 covered by v. Higher rank =
earlier processing = more vertices record the hop.
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph


def degree_product_rank(g: CSRGraph) -> np.ndarray:
    """Paper §5.2 rank: (dout+1)*(din+1), descending. Returns order int32[n]."""
    score = (g.out_degree().astype(np.int64) + 1) * (g.in_degree().astype(np.int64) + 1)
    # stable tiebreak on vertex id for reproducibility
    return np.argsort(-score, kind="stable").astype(np.int32)


def degree_sum_rank(g: CSRGraph) -> np.ndarray:
    score = g.out_degree().astype(np.int64) + g.in_degree().astype(np.int64)
    return np.argsort(-score, kind="stable").astype(np.int32)


def random_rank(g: CSRGraph, seed: int = 0) -> np.ndarray:
    return np.random.default_rng(seed).permutation(g.n).astype(np.int32)


ORDERS = {
    "degree_product": degree_product_rank,
    "degree_sum": degree_sum_rank,
    "random": random_rank,
}


def get_order(g: CSRGraph, name: str = "degree_product", **kw) -> np.ndarray:
    return ORDERS[name](g, **kw)
