"""Compatibility shim — the batched/sharded serve path moved to
``repro.serve.engine`` (the QueryEngine subsystem). Import from there; this
module keeps the long-standing ``repro.core.query`` entry points alive.
"""
from __future__ import annotations

from repro.serve.engine import (  # noqa: F401
    intersect_rows,
    make_hop_sharded_serve_step,
    make_sharded_serve_step,
    serve_step,
)

__all__ = [
    "intersect_rows",
    "serve_step",
    "make_sharded_serve_step",
    "make_hop_sharded_serve_step",
]
