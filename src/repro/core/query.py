"""Batched oracle query engine (the serve path).

One serve_step: queries int32[B, 2] -> bool[B].
  gather L_out[q[:,0]] and L_in[q[:,1]] rows, then batched intersection.

The intersection is the paper's hot loop. On TPU we replace the branchy
sorted-merge with an all-pairs tile compare (VPU-friendly; |L| <= a few
hundred so L^2 compares beat serial merges by orders of magnitude in
throughput). `use_kernel=True` routes through the Pallas kernel.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.graph.csr import INVALID


@jax.jit
def intersect_rows(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """a: int32[B, La], b: int32[B, Lb] (INVALID padded) -> bool[B]."""
    eq = a[:, :, None] == b[:, None, :]
    valid = (a[:, :, None] != INVALID) & (b[:, None, :] != INVALID)
    return (eq & valid).any(axis=(1, 2))


@partial(jax.jit, static_argnames=("use_kernel",))
def serve_step(
    L_out: jnp.ndarray,
    L_in: jnp.ndarray,
    queries: jnp.ndarray,
    use_kernel: bool = False,
) -> jnp.ndarray:
    """Answer a batch of reachability queries.

    L_out: int32[n, Lo], L_in: int32[n, Li], queries: int32[B, 2].
    """
    a = jnp.take(L_out, queries[:, 0], axis=0)
    b = jnp.take(L_in, queries[:, 1], axis=0)
    if use_kernel:
        from repro.kernels.ops import label_intersect

        return label_intersect(a, b)
    return intersect_rows(a, b)


def make_sharded_serve_step(mesh, data_axes=("pod", "data")):
    """Production serve_step: labels replicated over the model axis, queries
    sharded over the data axes. Returns (jitted_fn, in_shardings, out_sharding).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    label_sharding = NamedSharding(mesh, P())               # replicated
    query_sharding = NamedSharding(mesh, P(data_axes, None))
    out_sharding = NamedSharding(mesh, P(data_axes))

    fn = jax.jit(
        lambda lo, li, q: serve_step(lo, li, q),
        in_shardings=(label_sharding, label_sharding, query_sharding),
        out_shardings=out_sharding,
    )
    return fn, (label_sharding, label_sharding, query_sharding), out_sharding


def make_hop_sharded_serve_step(mesh, model_axis="model", data_axes=("pod", "data")):
    """Large-graph variant: label MATRICES sharded over the model axis along
    the hop dimension (each device holds a slice of every row); each shard
    computes a partial intersection hit and the results OR-reduce over the
    model axis. Queries sharded over data axes.

    This is the "labels larger than one device" serving mode.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    label_sharding = NamedSharding(mesh, P(None, model_axis))
    query_sharding = NamedSharding(mesh, P(data_axes, None))
    out_sharding = NamedSharding(mesh, P(data_axes))

    def step(L_out, L_in, queries):
        a = jnp.take(L_out, queries[:, 0], axis=0)
        b_full = jnp.take(L_in, queries[:, 1], axis=0)
        # each hop-shard of `a` must compare against ALL hops of b:
        # jnp ops under jit+sharding constraints let XLA insert the all-gather
        # of the (small) b rows; the big L_out stays sharded.
        eq = a[:, :, None] == b_full[:, None, :]
        valid = (a[:, :, None] != INVALID) & (b_full[:, None, :] != INVALID)
        return (eq & valid).any(axis=(1, 2))

    fn = jax.jit(
        step,
        in_shardings=(label_sharding, label_sharding, query_sharding),
        out_shardings=out_sharding,
    )
    return fn, (label_sharding, label_sharding, query_sharding), out_sharding
