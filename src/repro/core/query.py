"""DEPRECATED compatibility shim — the batched/sharded serve path moved to
``repro.serve.engine`` (the QueryEngine subsystem).  Import from
``repro.serve`` instead; this module keeps the long-standing
``repro.core.query`` entry points alive for one more release and warns on
import so downstream callers migrate before it is removed.

Removal date: 2026-10-01.  Nothing in-tree imports this module any more
(tests exercise the shim itself, via ``repro.serve.engine`` identity);
after that date delete the file and the shim test in tests/test_dynamic.py.
"""
from __future__ import annotations

import warnings

warnings.warn(
    "repro.core.query is deprecated and will be removed after 2026-10-01: "
    "the serve path lives in repro.serve (QueryEngine / serve_step / "
    "make_sharded_serve_step); import from repro.serve.engine instead",
    DeprecationWarning,
    stacklevel=2,
)

from repro.serve.engine import (  # noqa: F401,E402
    intersect_rows,
    make_hop_sharded_serve_step,
    make_sharded_serve_step,
    serve_step,
)

__all__ = [
    "intersect_rows",
    "serve_step",
    "make_sharded_serve_step",
    "make_hop_sharded_serve_step",
]
