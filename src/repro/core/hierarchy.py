"""Hierarchical-Labeling (paper §4, Algorithm 1).

1. Recursive hierarchical DAG decomposition (Definition 2): G_0 = G,
   G_{i+1} = one-side reachability backbone of G_i, until the level graph is
   small (<= core_max vertices) or max_levels reached.
2. Label the core graph G_h completely (we use Distribution-Labeling; the
   paper allows "the existing 2-hop labeling" — any complete core labeling
   preserves Theorem 1's induction. Formula 3 is also provided for
   diameter <= eps cores).
3. Level-wise labeling from h-1 down to 0 (Formulas 4/5 with the L_in typo
   corrected: L_in inherits L_in of the incoming backbone set):

     L_out(v) = {v} u N1_out(v|G_i) u  U_{u in B_out(v)} L_out(u)
     L_in(v)  = {v} u N1_in(v|G_i)  u  U_{u in B_in(v)}  L_in(u)

All hop ids in the final labels are global (G_0) vertex ids.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.build import bitset
from repro.build.traverse import batched_union_rows, khop_out as _khop_out
from repro.core.backbone import Backbone, one_side_backbone
from repro.core.distribution import distribution_labeling
from repro.core.oracle import ReachabilityOracle, finalize_labels
from repro.graph.csr import CSRGraph


@dataclasses.dataclass
class Hierarchy:
    """levels[i] = graph G_i with vertex ids local to level i;
    to_global[i][local_id] = global (G_0) vertex id."""

    levels: List[CSRGraph]
    to_global: List[np.ndarray]
    backbones: List[Backbone]  # backbones[i] maps G_i -> G_{i+1}

    @property
    def h(self) -> int:
        return len(self.levels) - 1


def decompose(g: CSRGraph, eps: int = 2, core_max: int = 1024, max_levels: int = 10) -> Hierarchy:
    levels = [g]
    to_global = [np.arange(g.n, dtype=np.int32)]
    backbones: List[Backbone] = []
    while levels[-1].n > core_max and len(levels) - 1 < max_levels:
        bb = one_side_backbone(levels[-1], eps)
        if bb.vstar.shape[0] == 0 or bb.vstar.shape[0] >= levels[-1].n:
            break  # no reduction possible — stop decomposing
        backbones.append(bb)
        levels.append(bb.graph)
        to_global.append(to_global[-1][bb.vstar])
    return Hierarchy(levels=levels, to_global=to_global, backbones=backbones)


def _backbone_sets(g_i: CSRGraph, g_rev: CSRGraph, in_vstar: np.ndarray,
                   v: int, eps: int):
    """(B_out, B_in) per Formulas 1/2: backbone vertices within eps of v,
    pruned when another candidate lies between (d(v,x)<=eps ^ d(x,u)<=eps).
    ``g_rev`` is the caller-hoisted reverse of ``g_i`` (this runs per
    vertex; rebuilding the reverse CSR each call dominated the level)."""
    cand_out = [u for u in _khop_out(g_i, v, eps) if in_vstar[u]]
    pruned_out: List[int] = []
    if cand_out:
        reach2 = {x: _khop_out(g_i, x, eps) for x in cand_out}
        for u in cand_out:
            if not any(x != u and u in reach2[x] for x in cand_out):
                pruned_out.append(u)

    cand_in = [u for u in _khop_out(g_rev, v, eps) if in_vstar[u]]
    pruned_in: List[int] = []
    if cand_in:
        reach2r = {x: _khop_out(g_rev, x, eps) for x in cand_in}
        for u in cand_in:
            # exists y with d(u,y)<=eps and d(y,v)<=eps  <=>  reverse: y reaches u
            if not any(x != u and u in reach2r[x] for x in cand_in):
                pruned_in.append(u)
    return pruned_out, pruned_in


def core_labels_formula3(core: CSRGraph, eps: int = 2):
    """Formula 3 (valid when diameter(core) <= eps): L = ceil(eps/2)-neighborhood."""
    k = (eps + 1) // 2
    rev = core.reverse()
    out_lists = [sorted({v} | _khop_out(core, v, k)) for v in range(core.n)]
    in_lists = [sorted({v} | _khop_out(rev, v, k)) for v in range(core.n)]
    return out_lists, in_lists


def hierarchical_labeling(
    g: CSRGraph,
    eps: int = 2,
    core_max: int = 1024,
    max_levels: int = 10,
    core_method: str = "distribution",
) -> ReachabilityOracle:
    hier = decompose(g, eps=eps, core_max=core_max, max_levels=max_levels)
    h = hier.h
    n = g.n

    empty = np.empty(0, dtype=np.int32)
    out_rows: List[np.ndarray] = [empty] * n  # sorted unique global hop ids
    in_rows: List[np.ndarray] = [empty] * n

    # ---- core labeling (global hop ids) ----
    core = hier.levels[h]
    core_glob = hier.to_global[h].astype(np.int32)
    if core_method == "formula3":
        c_out, c_in = core_labels_formula3(core, eps)
        for lv in range(core.n):
            gv = int(core_glob[lv])
            out_rows[gv] = np.sort(core_glob[np.asarray(c_out[lv], dtype=np.int64)])
            in_rows[gv] = np.sort(core_glob[np.asarray(c_in[lv], dtype=np.int64)])
    else:
        core_oracle = distribution_labeling(core)
        for lv in range(core.n):
            gv = int(core_glob[lv])
            # DL labels live in rank space; map back to core-local vertex ids
            # before lifting to global ids
            row_o = core_oracle.unrank(core_oracle.L_out[lv, : core_oracle.out_len[lv]])
            row_i = core_oracle.unrank(core_oracle.L_in[lv, : core_oracle.in_len[lv]])
            out_rows[gv] = np.sort(core_glob[row_o])
            in_rows[gv] = np.sort(core_glob[row_i])

    # ---- level-wise labeling h-1 .. 0 (Formulas 4/5) ----
    # All vertices of a level are independent (labels inherit only from
    # higher-level backbone rows and plain neighbor IDS), so each side of a
    # level is ONE batched union over (vertex, hop) pairs — the gathers run
    # through the wave sweeps' csr_gather, the union through
    # ``traverse.batched_union_rows``; no per-vertex python set work.
    for i in range(h - 1, -1, -1):
        g_i = hier.levels[i]
        glob_i = hier.to_global[i].astype(np.int32)
        bb = hier.backbones[i]
        in_vstar = np.zeros(g_i.n, dtype=bool)
        in_vstar[bb.vstar] = True
        g_i_rev = g_i.reverse()
        lvs = np.flatnonzero(~in_vstar).astype(np.int64)
        if lvs.size == 0:
            continue
        b_out_all, b_in_all = zip(*(_backbone_sets(g_i, g_i_rev, in_vstar,
                                                   int(lv), eps) for lv in lvs))
        for rows, g_dir, b_all in (
            (out_rows, g_i, b_out_all),
            (in_rows, g_i_rev, b_in_all),
        ):
            nbrs, seg = bitset.csr_gather(
                g_dir.indptr.astype(np.int64), g_dir.indices.astype(np.int64), lvs
            )
            keys = [np.arange(lvs.size, dtype=np.int64), seg]
            vals = [glob_i[lvs], glob_i[nbrs]]  # {v} u N1(v|G_i)
            for k, b_locals in enumerate(b_all):  # u U_{u in B(v)} L(u)
                for u in b_locals:
                    row = rows[int(glob_i[u])]
                    keys.append(np.full(row.shape[0], k, dtype=np.int64))
                    vals.append(row)
            level_rows = batched_union_rows(
                np.concatenate(keys), np.concatenate(vals), lvs.size, n
            )
            for k, lv in enumerate(lvs):
                rows[int(glob_i[lv])] = level_rows[k]

    return finalize_labels(out_rows, in_rows)
