"""Top-level oracle API: arbitrary digraphs (cycles allowed) in one call.

The paper (§2) assumes SCC condensation as a preprocessing step; this is
that step made first-class:

    oracle = build_oracle(graph)            # graph may have cycles
    oracle.query(u, v)                      # original vertex ids
    oracle.serve(queries)                   # batched engine path
    oracle.serve(queries, backend="kernel") # pick the intersection backend

Serving is owned by a ``repro.serve.QueryEngine`` (prefilters + length
bucketing + pluggable backends); the condensation's topological levels feed
the engine's level prefilter.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional

import numpy as np

from repro.core.distribution import distribution_labeling
from repro.core.hierarchy import hierarchical_labeling
from repro.core.oracle import ReachabilityOracle
from repro.graph.csr import CSRGraph
from repro.graph.scc import condense_to_dag
from repro.serve.engine import QueryEngine
from repro.serve.prefilter import topo_levels


@dataclasses.dataclass(frozen=True)
class CondensedOracle:
    """Reachability oracle over the SCC condensation of a digraph.

    Queries take ORIGINAL vertex ids; two vertices in the same SCC reach
    each other by definition (the engine's same-id prefilter answers them).
    """

    oracle: ReachabilityOracle
    comp: np.ndarray  # int32[n_original] -> condensation vertex id
    engine: QueryEngine

    @property
    def total_label_size(self) -> int:
        return self.oracle.total_label_size

    def query(self, u: int, v: int) -> bool:
        return self.engine.query(int(u), int(v))

    def serve(self, queries: np.ndarray, backend: Optional[str] = None) -> np.ndarray:
        """Batched engine path. queries: int[B, 2] original ids -> bool[B].

        The original->condensation mapping happens inside the engine through
        its ``comp_source`` hook (reading this oracle's current comp array),
        so the same-SCC short-circuit can never act on a stale cached copy
        when the condensation is maintained dynamically."""
        return self.engine.query_batch(np.asarray(queries), backend=backend)


def build_oracle(
    g: CSRGraph,
    method: Literal["distribution", "hierarchical"] = "distribution",
    backend: str = "auto",
    mesh=None,
    bucketing: bool = True,
    **kwargs,
) -> CondensedOracle:
    """Condense SCCs, label with DL (default) or HL, wire up the serve engine."""
    dag, comp = condense_to_dag(g)
    if method == "distribution":
        oracle = distribution_labeling(dag, **kwargs)
    elif method == "hierarchical":
        oracle = hierarchical_labeling(dag, **kwargs)
    else:
        raise ValueError(method)
    engine = QueryEngine(
        oracle,
        backend=backend,
        level=topo_levels(dag),
        mesh=mesh,
        bucketing=bucketing,
        # degradation ladder bottom rung: the condensation DAG the labels
        # index, so corrupted/missing rows degrade to exact online search
        fallback_graph=dag,
    )
    co = CondensedOracle(oracle=oracle, comp=comp, engine=engine)
    # queries reach the engine in original ids; the engine reads the comp
    # array through the oracle at call time (never a private cached copy)
    engine.comp_source = lambda: co.comp
    return co
