"""Top-level oracle API: arbitrary digraphs (cycles allowed) in one call.

The paper (§2) assumes SCC condensation as a preprocessing step; this is
that step made first-class:

    oracle = build_oracle(graph)            # graph may have cycles
    oracle.query(u, v)                      # original vertex ids
    oracle.serve(queries)                   # batched engine path
    oracle.serve(queries, backend="kernel") # pick the intersection backend

Serving is owned by a ``repro.serve.QueryEngine`` (prefilters + length
bucketing + pluggable backends); the condensation's topological levels feed
the engine's level prefilter.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional

import numpy as np

from repro.core.distribution import distribution_labeling
from repro.core.hierarchy import hierarchical_labeling
from repro.core.oracle import ReachabilityOracle
from repro.graph.csr import CSRGraph
from repro.graph.scc import condense_to_dag
from repro.serve.engine import QueryEngine
from repro.serve.prefilter import topo_levels


@dataclasses.dataclass(frozen=True)
class CondensedOracle:
    """Reachability oracle over the SCC condensation of a digraph.

    Queries take ORIGINAL vertex ids; two vertices in the same SCC reach
    each other by definition (the engine's same-id prefilter answers them).
    """

    oracle: ReachabilityOracle
    comp: np.ndarray  # int32[n_original] -> condensation vertex id
    engine: QueryEngine

    @property
    def total_label_size(self) -> int:
        return self.oracle.total_label_size

    def query(self, u: int, v: int) -> bool:
        return self.engine.query(int(u), int(v))

    def serve(self, queries: np.ndarray, backend: Optional[str] = None,
              deadline: Optional[float] = None) -> np.ndarray:
        """Batched engine path. queries: int[B, 2] original ids -> bool[B].

        The original->condensation mapping happens inside the engine through
        its ``comp_source`` hook (reading this oracle's current comp array),
        so the same-SCC short-circuit can never act on a stale cached copy
        when the condensation is maintained dynamically.  ``deadline`` is
        the daemon's absolute latency budget (see
        ``QueryEngine.query_batch``)."""
        return self.engine.query_batch(np.asarray(queries), backend=backend,
                                       deadline=deadline)


def build_oracle(
    g: CSRGraph,
    method: Literal["distribution", "hierarchical"] = "distribution",
    backend: str = "auto",
    mesh=None,
    bucketing: bool = True,
    **kwargs,
) -> CondensedOracle:
    """Condense SCCs, label with DL (default) or HL, wire up the serve engine."""
    dag, comp = condense_to_dag(g)
    if method == "distribution":
        oracle = distribution_labeling(dag, **kwargs)
    elif method == "hierarchical":
        oracle = hierarchical_labeling(dag, **kwargs)
    else:
        raise ValueError(method)
    engine = QueryEngine(
        oracle,
        backend=backend,
        level=topo_levels(dag),
        mesh=mesh,
        bucketing=bucketing,
        # degradation ladder bottom rung: the condensation DAG the labels
        # index, so corrupted/missing rows degrade to exact online search
        fallback_graph=dag,
    )
    co = CondensedOracle(oracle=oracle, comp=comp, engine=engine)
    # queries reach the engine in original ids; the engine reads the comp
    # array through the oracle at call time (never a private cached copy)
    engine.comp_source = lambda: co.comp
    return co


def oracle_from_snapshot(
    g: CSRGraph,
    path: str,
    mode: Literal["strict", "quarantine"] = "strict",
    backend: str = "auto",
    mesh=None,
    bucketing: bool = True,
) -> CondensedOracle:
    """Cold-start serving: wire a persisted label snapshot to ``g``'s
    condensation instead of rebuilding the index.

    ``mode="strict"`` raises ``persist.CorruptSnapshotError`` on any
    checksum mismatch; ``mode="quarantine"`` loads anyway, zeroes the
    corrupt row blocks, and arms the engine's quarantine masks so queries
    touching them degrade to exact online search over the condensation DAG
    (throughput cost, never a wrong verdict).

    The caller vouches that ``path`` was saved from THIS graph's
    condensation (``save_oracle(path, co.oracle)``); a snapshot of a
    different graph fails the cheap shape check here and answers garbage
    past it — persist snapshots are content-checksummed, not graph-keyed.
    """
    from repro.persist import load_oracle

    if mode not in ("strict", "quarantine"):
        raise ValueError(f"mode must be strict|quarantine, got {mode!r}")
    dag, comp = condense_to_dag(g)
    report = None
    if mode == "strict":
        oracle = load_oracle(path, strict=True)
    else:
        oracle, report = load_oracle(path, strict=False)
    if oracle.n != dag.n:
        raise ValueError(
            f"snapshot at {path} indexes {oracle.n} vertices but the "
            f"graph's condensation has {dag.n} — wrong snapshot for this graph")
    engine = QueryEngine(
        oracle, backend=backend, level=topo_levels(dag), mesh=mesh,
        bucketing=bucketing, fallback_graph=dag,
    )
    co = CondensedOracle(oracle=oracle, comp=comp, engine=engine)
    engine.comp_source = lambda: co.comp
    if report is not None and not report.clean:
        engine.set_quarantine(report.quarantine_out, report.quarantine_in)
    return co
