"""Top-level oracle API: arbitrary digraphs (cycles allowed) in one call.

The paper (§2) assumes SCC condensation as a preprocessing step; this is
that step made first-class:

    oracle = build_oracle(graph)            # graph may have cycles
    oracle.query(u, v)                      # original vertex ids
    oracle.serve(queries)                   # batched device path
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp
import numpy as np

from repro.core.distribution import distribution_labeling
from repro.core.hierarchy import hierarchical_labeling
from repro.core.oracle import ReachabilityOracle
from repro.core.query import serve_step
from repro.graph.csr import CSRGraph
from repro.graph.scc import condense_to_dag


@dataclasses.dataclass(frozen=True)
class CondensedOracle:
    """Reachability oracle over the SCC condensation of a digraph.

    Queries take ORIGINAL vertex ids; two vertices in the same SCC reach
    each other by definition.
    """

    oracle: ReachabilityOracle
    comp: np.ndarray  # int32[n_original] -> condensation vertex id

    @property
    def total_label_size(self) -> int:
        return self.oracle.total_label_size

    def query(self, u: int, v: int) -> bool:
        cu, cv = int(self.comp[u]), int(self.comp[v])
        if cu == cv:
            return True
        return self.oracle.query(cu, cv)

    def serve(self, queries: np.ndarray) -> np.ndarray:
        """Batched device path. queries: int32[B, 2] original ids -> bool[B]."""
        cq = self.comp[queries].astype(np.int32)
        lo, li = self.oracle.device_labels()
        same = cq[:, 0] == cq[:, 1]
        out = np.asarray(serve_step(lo, li, jnp.asarray(cq)))
        return out | same


def build_oracle(
    g: CSRGraph,
    method: Literal["distribution", "hierarchical"] = "distribution",
    **kwargs,
) -> CondensedOracle:
    """Condense SCCs, then label with DL (default) or HL."""
    dag, comp = condense_to_dag(g)
    if method == "distribution":
        oracle = distribution_labeling(dag, **kwargs)
    elif method == "hierarchical":
        oracle = hierarchical_labeling(dag, **kwargs)
    else:
        raise ValueError(method)
    return CondensedOracle(oracle=oracle, comp=comp)
