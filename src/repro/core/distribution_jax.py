"""Device (JAX) formulation of Distribution-Labeling.

The per-vertex unit of work in Algorithm 2 is re-expressed as dataflow:

  prune lookup:  lut[x] = x in L_out(v_i)   (scatter of one label row)
  prune test:    pruned[w] = any(lut[L_in(w, :)])          -- O(n*Lmax) gather
  masked BFS:    frontier sweep where only unpruned vertices expand
  label append:  L_in[w, in_len[w]] = v_i  for labeled w   -- one scatter

The outer vertex loop stays ordered (the algorithm requires it — Theorem 2's
V_s is the processed prefix), but every step inside an iteration is a dense
vectorized op that shards over the `data` mesh axis (vertices) — this is the
distributed-construction story for 1000+ node clusters: label state lives
with its vertex shard; the only cross-shard exchange per BFS step is the
frontier bitmap (all-gather of bool[n]/8 bytes) and the (tiny) label row of
v_i (broadcast).

The same `build_sweep` is what dryrun.py lowers at production scale.

The wave-batched device formulation — the same prune-gather / masked-reach /
append dataflow, but batched over up to 64 mutually independent vertices per
step through the Pallas OR-AND kernel — lives in ``repro.build.engine_jax``;
both share the row canonicalization below via ``repro.build.engine``.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.oracle import ReachabilityOracle
from repro.core.order import get_order
from repro.graph.csr import CSRGraph, INVALID


class LabelState(NamedTuple):
    L_out: jnp.ndarray   # int32[n, Lmax]
    L_in: jnp.ndarray    # int32[n, Lmax]
    out_len: jnp.ndarray  # int32[n]
    in_len: jnp.ndarray   # int32[n]
    overflow: jnp.ndarray  # bool[] — any label row exceeded Lmax


def init_state(n: int, l_max: int) -> LabelState:
    return LabelState(
        L_out=jnp.full((n, l_max), INVALID, dtype=jnp.int32),
        L_in=jnp.full((n, l_max), INVALID, dtype=jnp.int32),
        out_len=jnp.zeros(n, dtype=jnp.int32),
        in_len=jnp.zeros(n, dtype=jnp.int32),
        overflow=jnp.asarray(False),
    )


def _membership_lut(n: int, row: jnp.ndarray) -> jnp.ndarray:
    """bool[n]: lut[x] = x appears in `row` (row is INVALID padded)."""
    lut = jnp.zeros(n + 1, dtype=bool)
    idx = jnp.where(row == INVALID, n, row)  # park padding on the extra slot
    return lut.at[idx].set(True)[:n]


@partial(jax.jit, static_argnames=("n", "max_steps"))
def _masked_reach(
    source: jnp.ndarray,  # int32[] vertex id
    pruned: jnp.ndarray,  # bool[n] — visited-but-not-expanded set
    src: jnp.ndarray,
    dst: jnp.ndarray,
    n: int,
    max_steps: int,
) -> jnp.ndarray:
    """bool[n]: vertices visited by BFS from `source` where pruned vertices
    do not expand. Returns the VISITED set (includes pruned frontier hits)."""
    visited = jnp.zeros(n, dtype=bool).at[source].set(True)

    bitpack = n % 32 == 0
    bit_w = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)

    def body(state):
        step, visited, _ = state
        expand = visited & ~pruned
        if bitpack:
            # pack the frontier to uint32 words BEFORE the edge gather: the
            # cross-shard all-gather carries n/32 words instead of n int32
            # flags (32-128x less wire — EXPERIMENTS.md §Perf H5)
            words = jnp.sum(
                expand.reshape(-1, 32).astype(jnp.uint32) * bit_w[None, :], axis=1
            )
            active = (words[src >> 5] >> (src & 31).astype(jnp.uint32)) & 1
        else:
            active = expand[src].astype(jnp.uint32)
        # int8 payload: the scatter partial + its all-reduce carry 4x fewer
        # bytes than int32 (EXPERIMENTS.md §Perf H4)
        hit = jax.ops.segment_max(active.astype(jnp.int8), dst, num_segments=n) > 0
        new = visited | hit
        return step + 1, new, jnp.any(new != visited)

    def cond(state):
        step, _, changed = state
        return (step < max_steps) & changed

    _, visited, _ = jax.lax.while_loop(cond, body, (jnp.int32(0), visited, jnp.bool_(True)))
    return visited


def _dynamic_row(M: jnp.ndarray, vi: jnp.ndarray, mode: str) -> jnp.ndarray:
    """Extract row vi of a (possibly row-sharded) matrix.

    mode='gather' — plain M[vi]. Under SPMD with M row-sharded this makes XLA
    ALL-GATHER the whole matrix to index one row (measured: 2 x 2.56 GB on
    the 10M-vertex build sweep — the dominant collective).
    mode='onehot' — sum(onehot(vi) * M): each shard reduces its local rows to
    a [L] partial and the wire cost is one [L] all-reduce (256 B). The
    hillclimbed default for sharded builds.
    """
    if mode == "gather":
        return M[vi]
    onehot = (jnp.arange(M.shape[0], dtype=jnp.int32) == vi).astype(M.dtype)
    return jnp.einsum("n,nl->l", onehot, M)


@partial(
    jax.jit, static_argnames=("n", "max_steps", "row_extract"), donate_argnums=(0,)
)
def distribute_one(
    state: LabelState,
    vi: jnp.ndarray,  # int32[]
    fwd_src: jnp.ndarray,
    fwd_dst: jnp.ndarray,
    rev_src: jnp.ndarray,
    rev_dst: jnp.ndarray,
    n: int,
    max_steps: int,
    row_extract: str = "gather",
) -> LabelState:
    """One iteration of Algorithm 2 (both BFS passes), fully vectorized."""
    l_max = state.L_out.shape[1]

    # ---------- reverse pass: vi -> L_out(ancestors) ----------
    lin_vi = _dynamic_row(state.L_in, vi, row_extract)  # [Lmax] label row of vi
    lut_in = _membership_lut(n, lin_vi)
    # pruned[u] = L_out(u) cap L_in(vi) != empty
    hits = jnp.take(jnp.concatenate([lut_in, jnp.zeros(1, bool)]),
                    jnp.where(state.L_out == INVALID, n, state.L_out))
    pruned_r = hits.any(axis=1)
    visited_r = _masked_reach(vi, pruned_r, rev_src, rev_dst, n, max_steps)
    labeled_r = visited_r & ~pruned_r
    # append vi at column out_len[v] via an elementwise one-hot column mask:
    # a scatter with [n,2] indices makes SPMD all-gather the whole label
    # matrix (measured 2x80MB+ per iteration); this form emits ZERO
    # collectives (EXPERIMENTS.md §Perf H6)
    pos = jnp.minimum(state.out_len, l_max - 1)
    col = jnp.arange(l_max, dtype=jnp.int32)[None, :] == pos[:, None]
    L_out = jnp.where(col & labeled_r[:, None], vi, state.L_out)
    out_len = state.out_len + labeled_r.astype(jnp.int32)
    overflow = state.overflow | jnp.any(labeled_r & (state.out_len >= l_max))

    # ---------- forward pass: vi -> L_in(descendants) ----------
    lout_vi = _dynamic_row(L_out, vi, row_extract)
    lut_out = _membership_lut(n, lout_vi)
    hits_f = jnp.take(jnp.concatenate([lut_out, jnp.zeros(1, bool)]),
                      jnp.where(state.L_in == INVALID, n, state.L_in))
    pruned_f = hits_f.any(axis=1)
    visited_f = _masked_reach(vi, pruned_f, fwd_src, fwd_dst, n, max_steps)
    labeled_f = visited_f & ~pruned_f
    pos = jnp.minimum(state.in_len, l_max - 1)
    col = jnp.arange(l_max, dtype=jnp.int32)[None, :] == pos[:, None]
    L_in = jnp.where(col & labeled_f[:, None], vi, state.L_in)
    in_len = state.in_len + labeled_f.astype(jnp.int32)
    overflow = overflow | jnp.any(labeled_f & (state.in_len >= l_max))

    return LabelState(L_out=L_out, L_in=L_in, out_len=out_len, in_len=in_len, overflow=overflow)


def distribution_labeling_jax(
    g: CSRGraph,
    l_max: int = 64,
    order_name: str = "degree_product",
    max_steps: int | None = None,
) -> ReachabilityOracle:
    """Full device build (host loop over vertices, jitted per-vertex sweep)."""
    n = g.n
    order = get_order(g, order_name)
    fwd_src, fwd_dst = (jnp.asarray(x) for x in g.edges())
    g_rev = g.reverse()
    rev_src, rev_dst = (jnp.asarray(x) for x in g_rev.edges())
    steps = n if max_steps is None else max_steps

    state = init_state(n, l_max)
    for vi in order:
        state = distribute_one(
            state, jnp.int32(vi), fwd_src, fwd_dst, rev_src, rev_dst, n, steps
        )
    if bool(state.overflow):
        raise ValueError(f"label overflow: some row exceeded l_max={l_max}")

    from repro.build.engine import sort_label_rows

    return ReachabilityOracle(
        L_out=sort_label_rows(np.asarray(state.L_out)),
        L_in=sort_label_rows(np.asarray(state.L_in)),
        out_len=np.asarray(state.out_len),
        in_len=np.asarray(state.in_len),
    )


def build_sweep_specs(n: int, m: int, l_max: int):
    """ShapeDtypeStructs for lowering `distribute_one` at production scale
    (used by dryrun.py — no allocation)."""
    f32 = jnp.int32
    state = LabelState(
        L_out=jax.ShapeDtypeStruct((n, l_max), f32),
        L_in=jax.ShapeDtypeStruct((n, l_max), f32),
        out_len=jax.ShapeDtypeStruct((n,), f32),
        in_len=jax.ShapeDtypeStruct((n,), f32),
        overflow=jax.ShapeDtypeStruct((), jnp.bool_),
    )
    return dict(
        state=state,
        vi=jax.ShapeDtypeStruct((), f32),
        fwd_src=jax.ShapeDtypeStruct((m,), f32),
        fwd_dst=jax.ShapeDtypeStruct((m,), f32),
        rev_src=jax.ShapeDtypeStruct((m,), f32),
        rev_dst=jax.ShapeDtypeStruct((m,), f32),
    )
