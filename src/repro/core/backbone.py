"""One-side reachability backbone (paper Definition 1; SCARAB's FastCover).

For locality threshold eps (=2 throughout, as in the paper):

  V*  s.t. every pair (u, w) with d(u, w) = eps has a covering vertex x in V*
      with d(u, x) <= eps and d(x, w) <= eps.
  E*  = {(a, b) in V* x V* : d(a, b) <= eps + 1}, minus edges made redundant
      by an intermediate backbone vertex (paper's reduction rule).

Our FastCover variant is greedy-by-midpoint: process candidate midpoints x in
descending rank (dout+1)(din+1); select x iff some 2-pair through x is still
uncovered; selecting x covers all pairs N_in(x) x N_out(x). A pair is also
covered when u or w themselves are selected. This is conservative (never
marks an uncovered pair covered), so Definition 1 holds by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Set, Tuple

import numpy as np

from repro.build.traverse import khop_out as _khop_out  # shared traversal helper
from repro.core.order import degree_product_rank
from repro.graph.csr import CSRGraph, from_edges


@dataclasses.dataclass(frozen=True)
class Backbone:
    """Backbone of one decomposition level (vertex ids are *parent-graph local*)."""

    vstar: np.ndarray      # int32[k] selected vertex ids (parent-local), sorted
    graph: CSRGraph        # backbone graph over 0..k-1 (backbone-local ids)
    local_of: Dict[int, int]  # parent-local id -> backbone-local id


def fast_cover(g: CSRGraph, eps: int = 2) -> np.ndarray:
    """Select V* (bool[n]) for the one-side backbone, eps=2 specialization."""
    assert eps == 2, "this implementation specializes the paper's eps=2 setting"
    n = g.n
    g_rev = g.reverse()
    order = degree_product_rank(g)
    in_vstar = np.zeros(n, dtype=bool)
    covered: Set[int] = set()  # packed pair keys u * n + w

    indptr, indices = g.indptr, g.indices
    r_indptr, r_indices = g_rev.indptr, g_rev.indices

    for x in order:
        x = int(x)
        ins = r_indices[r_indptr[x] : r_indptr[x + 1]]
        outs = indices[indptr[x] : indptr[x + 1]]
        if ins.shape[0] == 0 or outs.shape[0] == 0:
            continue
        # does x have an uncovered 2-pair through it?
        selected = False
        for u in ins:
            u = int(u)
            if in_vstar[u]:
                continue  # all pairs from u are covered by u itself
            base = u * n
            for w in outs:
                w = int(w)
                if w == u or in_vstar[w]:
                    continue
                if (base + w) not in covered:
                    selected = True
                    break
            if selected:
                break
        if not selected:
            continue
        in_vstar[x] = True
        # x covers every (u, w) in N_in(x) x N_out(x)
        for u in ins:
            base = int(u) * n
            for w in outs:
                if int(w) != int(u):
                    covered.add(base + int(w))
    return in_vstar


def build_backbone_graph(g: CSRGraph, in_vstar: np.ndarray, eps: int = 2) -> Backbone:
    """E*: backbone pairs within distance eps+1, with the reduction rule:
    drop (a,b) if some other backbone x has d(a,x)<=eps and d(x,b)<=eps."""
    vstar = np.nonzero(in_vstar)[0].astype(np.int32)
    local_of = {int(v): i for i, v in enumerate(vstar)}
    k = vstar.shape[0]

    # cov_in[y] = backbone vertices x with d(x, y) <= eps (capped) — used by
    # the reduction rule test  exists x: d(a,x)<=eps AND d(x,b)<=eps.
    cov_cap = 8
    cov_in: List[Set[int]] = [set() for _ in range(g.n)]
    for a in vstar:
        a = int(a)
        reach = _khop_out(g, a, eps)
        reach.add(a)
        for y in reach:
            if len(cov_in[y]) < cov_cap:
                cov_in[y].add(a)

    src: List[int] = []
    dst: List[int] = []
    for a in vstar:
        a = int(a)
        near = _khop_out(g, a, eps)          # d(a, .) <= eps
        far = _khop_out(g, a, eps + 1)       # d(a, .) <= eps+1
        near_bb = {x for x in near if in_vstar[x]}
        for b in far:
            if not in_vstar[b] or b == a:
                continue
            # reduction: skip if an intermediate backbone covers (a, b)
            redundant = any((x != a and x != b and x in near_bb) for x in cov_in[b])
            if not redundant:
                src.append(local_of[a])
                dst.append(local_of[b])
    graph = from_edges(k, np.asarray(src, dtype=np.int64), np.asarray(dst, dtype=np.int64))
    return Backbone(vstar=vstar, graph=graph, local_of=local_of)


def one_side_backbone(g: CSRGraph, eps: int = 2) -> Backbone:
    return build_backbone_graph(g, fast_cover(g, eps), eps)
