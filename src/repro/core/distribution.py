"""Distribution-Labeling (paper §5, Algorithm 2).

Process vertices in a total order (default rank: (dout+1)*(din+1) desc).
For each vertex v_i:

  * reverse pruned BFS: visiting u, if L_out(u) cap L_in(v_i) != empty the
    pair (u, v_i) is already covered through a higher-ranked hop -> do not
    label u and do not expand u; otherwise add v_i to L_out(u) and expand.
  * forward pruned BFS (symmetric): label L_in(w) with v_i unless
    L_in(w) cap L_out(v_i) != empty.

Theorem 3: complete.  Theorem 4: non-redundant (no hop can be removed).
Worst case O(n(n+m)); output-sensitive in practice — the intersection test
prunes nearly everything, which is the paper's entire speed story.

This is the host (numpy+sets) fast path used for index *construction*
(an offline job). The device/sharded formulation lives in
``distribution_jax.py``; the serve path in ``query.py``.
"""
from __future__ import annotations

from collections import deque
from typing import Optional

import numpy as np

from repro.core.oracle import ReachabilityOracle, finalize_labels
from repro.core.order import get_order
from repro.graph.csr import CSRGraph


def distribution_labeling(
    g: CSRGraph,
    order: Optional[np.ndarray] = None,
    order_name: str = "degree_product",
) -> ReachabilityOracle:
    """Build the oracle for DAG ``g`` (int vertex ids 0..n-1)."""
    n = g.n
    g_rev = g.reverse()
    if order is None:
        order = get_order(g, order_name)

    # Python sets give C-speed isdisjoint (the pruning hot path); parallel
    # lists keep insertion order for the final packed arrays.
    L_out_sets = [set() for _ in range(n)]
    L_in_sets = [set() for _ in range(n)]
    L_out_lists: list[list[int]] = [[] for _ in range(n)]
    L_in_lists: list[list[int]] = [[] for _ in range(n)]

    indptr, indices = g.indptr, g.indices
    r_indptr, r_indices = g_rev.indptr, g_rev.indices

    visited = np.full(n, -1, dtype=np.int64)  # iteration stamp, avoids clearing

    for it, vi in enumerate(order):
        vi = int(vi)
        Lin_vi = L_in_sets[vi]
        Lout_vi = L_out_sets[vi]

        # ---- reverse BFS: distribute vi into L_out of its ancestors ----
        stamp = 2 * it
        dq = deque([vi])
        visited[vi] = stamp
        while dq:
            u = dq.popleft()
            if not Lin_vi.isdisjoint(L_out_sets[u]):
                continue  # covered by a higher hop: prune u (and paths through it)
            L_out_sets[u].add(vi)
            L_out_lists[u].append(vi)
            for w in r_indices[r_indptr[u] : r_indptr[u + 1]]:
                if visited[w] != stamp:
                    visited[w] = stamp
                    dq.append(int(w))

        # ---- forward BFS: distribute vi into L_in of its descendants ----
        stamp = 2 * it + 1
        dq = deque([vi])
        visited[vi] = stamp
        while dq:
            w = dq.popleft()
            if not Lout_vi.isdisjoint(L_in_sets[w]):
                continue
            L_in_sets[w].add(vi)
            L_in_lists[w].append(vi)
            for x in indices[indptr[w] : indptr[w + 1]]:
                if visited[x] != stamp:
                    visited[x] = stamp
                    dq.append(int(x))

    # rank space: hop_rank[order[i]] = i — rows come out rank-ordered, so the
    # serve-path merges hit the highest-ranked (most frequent) hop first
    hop_rank = np.empty(n, dtype=np.int32)
    hop_rank[np.asarray(order, dtype=np.int64)] = np.arange(n, dtype=np.int32)
    return finalize_labels(L_out_lists, L_in_lists, hop_rank=hop_rank)
