"""Distribution-Labeling (paper §5, Algorithm 2) — public entry point.

Process vertices in a total order (default rank: (dout+1)*(din+1) desc).
For each vertex v_i:

  * reverse pruned BFS: visiting u, if L_out(u) cap L_in(v_i) != empty the
    pair (u, v_i) is already covered through a higher-ranked hop -> do not
    label u and do not expand u; otherwise add v_i to L_out(u) and expand.
  * forward pruned BFS (symmetric): label L_in(w) with v_i unless
    L_in(w) cap L_out(v_i) != empty.

Theorem 3: complete.  Theorem 4: non-redundant (no hop can be removed).

Construction is owned by the ``repro.build`` engine: ``impl="wave"`` runs
the wave-scheduled bit-parallel sweep, ``impl="speculative"`` the
optimistic-chunk path for dense-reachability orders (sweep rank-consecutive
chunks without proving mutual unreachability, certify prune-order
violations exactly with word-level masks, correct violated members from
the chunk's append log), ``impl="device"`` the sparse device wave engine
(ELL frontier expansion + on-device label append), ``impl="reference"``
the seed scalar sets+deque path — all produce byte-identical labels (the
engine's differential tests assert this).  ``impl="auto"`` (default)
picks: reference below ~4k vertices; speculative when a sampled
reach-density probe (or a degenerate exact schedule) flags the
dense-reachability wall; otherwise the device engine when an accelerator
is attached, else the host wave engine.  The per-vertex device/sharded
formulation lives in ``distribution_jax.py``; the serve path in
``repro.serve``.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.oracle import ReachabilityOracle


def distribution_labeling(
    g,
    order: Optional[np.ndarray] = None,
    order_name: str = "degree_product",
    impl: str = "auto",
    **engine_kwargs,
) -> ReachabilityOracle:
    """Build the oracle for DAG ``g`` (int vertex ids 0..n-1)."""
    # deferred: repro.core's package init imports this module, while the
    # engine imports repro.core.oracle — a top-level import would cycle
    from repro.build.engine import build_distribution_labels

    return build_distribution_labels(
        g, order=order, order_name=order_name, impl=impl, **engine_kwargs
    )
