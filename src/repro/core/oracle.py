"""ReachabilityOracle: the hop-labeling container + query paths.

u reaches v  iff  L_out(u) `intersect` L_in(v) != empty.

Labels are finalized into dense padded int32 matrices [n, L_max] (rows sorted
ascending, INVALID = -1 padding) — the device/serving layout. The host keeps
per-row lengths for exact-size accounting (paper's index-size metric counts
total integers, Figures 3/4).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import jax.numpy as jnp
import numpy as np

from repro.graph.csr import INVALID


@dataclasses.dataclass(frozen=True)
class ReachabilityOracle:
    L_out: np.ndarray  # int32[n, Lo_max], sorted rows, INVALID padded
    L_in: np.ndarray   # int32[n, Li_max]
    out_len: np.ndarray  # int32[n]
    in_len: np.ndarray   # int32[n]

    @property
    def n(self) -> int:
        return int(self.L_out.shape[0])

    @property
    def total_label_size(self) -> int:
        """Paper's index-size metric: sum(|L_out| + |L_in|) in integers."""
        return int(self.out_len.sum() + self.in_len.sum())

    @property
    def max_label_len(self) -> int:
        return int(max(self.L_out.shape[1], self.L_in.shape[1]))

    # ---------------- host query paths ----------------

    def query(self, u: int, v: int) -> bool:
        """Single query via sorted-merge intersection (the paper's §1 fix:
        sorted vectors, not hash sets)."""
        a = self.L_out[u, : self.out_len[u]]
        b = self.L_in[v, : self.in_len[v]]
        i = j = 0
        na, nb = a.shape[0], b.shape[0]
        while i < na and j < nb:
            if a[i] == b[j]:
                return True
            if a[i] < b[j]:
                i += 1
            else:
                j += 1
        return False

    def query_batch_np(self, queries: np.ndarray) -> np.ndarray:
        """Vectorized all-pairs-compare batch query (numpy mirror of the
        device path). queries: int32[B, 2] -> bool[B]."""
        a = self.L_out[queries[:, 0]]  # [B, Lo]
        b = self.L_in[queries[:, 1]]   # [B, Li]
        eq = a[:, :, None] == b[:, None, :]
        valid = (a[:, :, None] != INVALID) & (b[:, None, :] != INVALID)
        return (eq & valid).any(axis=(1, 2))

    # ---------------- device arrays ----------------

    def device_labels(self):
        return jnp.asarray(self.L_out), jnp.asarray(self.L_in)


def finalize_labels(
    out_lists: Sequence[Sequence[int]],
    in_lists: Sequence[Sequence[int]],
    pad_to_multiple: int = 8,
) -> ReachabilityOracle:
    """Pack per-vertex python label lists into the dense oracle layout."""
    n = len(out_lists)
    out_len = np.array([len(x) for x in out_lists], dtype=np.int32)
    in_len = np.array([len(x) for x in in_lists], dtype=np.int32)

    def _pack(lists: Sequence[Sequence[int]], lens: np.ndarray) -> np.ndarray:
        lmax = int(lens.max()) if n else 1
        lmax = max(((lmax + pad_to_multiple - 1) // pad_to_multiple) * pad_to_multiple, pad_to_multiple)
        mat = np.full((n, lmax), INVALID, dtype=np.int32)
        for i, row in enumerate(lists):
            if row:
                mat[i, : len(row)] = np.sort(np.asarray(row, dtype=np.int32))
        return mat

    return ReachabilityOracle(
        L_out=_pack(out_lists, out_len),
        L_in=_pack(in_lists, in_len),
        out_len=out_len,
        in_len=in_len,
    )


def merge_hop_lists(parts: List[np.ndarray]) -> np.ndarray:
    """Sorted-unique union of hop id arrays (HL's label merge)."""
    if not parts:
        return np.empty(0, dtype=np.int32)
    cat = np.concatenate([np.asarray(p, dtype=np.int32) for p in parts])
    return np.unique(cat[cat != INVALID])
