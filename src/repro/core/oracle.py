"""ReachabilityOracle: the hop-labeling container + query paths.

u reaches v  iff  L_out(u) `intersect` L_in(v) != empty.

Labels are finalized into dense padded int32 matrices [n, L_max] (rows sorted
ascending, INVALID = -1 padding) — the device/serving layout. The host keeps
per-row lengths for exact-size accounting (paper's index-size metric counts
total integers, Figures 3/4).

Rank-ordered labels: when a construction order is available (DL's §5.2 rank),
``finalize_labels`` remaps every hop id to its *position in the processing
order*. The remap is a bijection, so intersection emptiness is unchanged, but
rows sorted ascending are now simultaneously value-sorted (searchsorted merge
still works) and rank-ordered: the highest-ranked hop — the one recorded by
the most labels — sits at the front of every row, so intersections terminate
early on positive queries (hierarchical-hub-labeling style early exit).
``hop_rank`` keeps the vertex->rank map; ``unrank`` recovers vertex ids.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.graph.csr import INVALID

# length of the high-rank prefix probed before the full merge in host queries
_PREFIX = 8
# row padding multiple shared with finalize_labels / the build engine
_PAD_MULT = 8


@dataclasses.dataclass(frozen=True)
class ReachabilityOracle:
    L_out: np.ndarray  # int32[n, Lo_max], sorted rows, INVALID padded
    L_in: np.ndarray   # int32[n, Li_max]
    out_len: np.ndarray  # int32[n]
    in_len: np.ndarray   # int32[n]
    # vertex -> rank when labels live in rank space (None = vertex-id space)
    hop_rank: Optional[np.ndarray] = None

    @property
    def n(self) -> int:
        return int(self.L_out.shape[0])

    @property
    def total_label_size(self) -> int:
        """Paper's index-size metric: sum(|L_out| + |L_in|) in integers."""
        return int(self.out_len.sum() + self.in_len.sum())

    @property
    def max_label_len(self) -> int:
        return int(max(self.L_out.shape[1], self.L_in.shape[1]))

    def unrank(self, hops: np.ndarray) -> np.ndarray:
        """Map label values back to vertex ids (identity in vertex-id space)."""
        if self.hop_rank is None:
            return np.asarray(hops)
        inv = getattr(self, "_inv_rank", None)
        if inv is None:  # memoize: inv[rank] = vertex
            inv = np.argsort(self.hop_rank).astype(np.int32)
            object.__setattr__(self, "_inv_rank", inv)
        return inv[np.asarray(hops)]

    # ---------------- row hooks (dynamic-oracle seam) ----------------

    def row_out(self, v: int) -> np.ndarray:
        """L_out(v) without padding (sorted ascending, rank space)."""
        return self.L_out[v, : self.out_len[v]]

    def row_in(self, v: int) -> np.ndarray:
        """L_in(v) without padding (sorted ascending, rank space)."""
        return self.L_in[v, : self.in_len[v]]

    def with_updated_rows(
        self,
        out_rows: "dict[int, Sequence[int]]",
        in_rows: "dict[int, Sequence[int]]",
    ) -> "ReachabilityOracle":
        """Copy-on-write row replacement: the append/invalidate hook used by
        ``repro.dynamic`` to publish repaired labels as a new immutable
        snapshot.  Each dict maps vertex -> full replacement row (sorted
        ascending, rank space, no padding; may be longer or shorter than the
        current row — matrices grow in the same multiple-of-8 padding as
        ``finalize_labels``).  The result is byte-identical to re-finalizing
        the mutated label lists.  A side with no updates shares the base
        matrix outright (snapshots are immutable); a side with updates is
        copied before writing, so publish cost tracks the dirtied side's
        matrix, not both."""

        def _cow(mat: np.ndarray, lens: np.ndarray, updates):
            if not updates:
                return mat, lens
            lens = lens.copy()
            need = int(max((len(r) for r in updates.values()), default=0))
            width = mat.shape[1]
            if need > width:
                width = max(
                    ((need + _PAD_MULT - 1) // _PAD_MULT) * _PAD_MULT, _PAD_MULT
                )
            grown = np.full((mat.shape[0], width), INVALID, dtype=np.int32)
            grown[:, : mat.shape[1]] = mat
            for v, row in updates.items():
                ln = len(row)
                grown[v, :ln] = np.asarray(row, dtype=np.int32)
                grown[v, ln : max(int(lens[v]), ln)] = INVALID
                lens[v] = ln
            return grown, lens

        L_out, out_len = _cow(self.L_out, self.out_len, out_rows)
        L_in, in_len = _cow(self.L_in, self.in_len, in_rows)
        return ReachabilityOracle(
            L_out=L_out, L_in=L_in, out_len=out_len, in_len=in_len,
            hop_rank=self.hop_rank,
        )

    # ---------------- host query paths ----------------

    def query(self, u: int, v: int) -> bool:
        """Single query: vectorized sorted intersection (searchsorted), with a
        high-rank prefix probe first — in rank space the frequent hops sort to
        the front, so most positive queries resolve in the prefix."""
        a = self.L_out[u, : self.out_len[u]]
        b = self.L_in[v, : self.in_len[v]]
        na, nb = a.shape[0], b.shape[0]
        if na == 0 or nb == 0:
            return False
        if a[0] == b[0]:
            return True
        if na > _PREFIX and nb > _PREFIX:
            pa, pb = a[:_PREFIX], b[:_PREFIX]
            pos = np.searchsorted(pa, pb)
            if (pa[np.minimum(pos, _PREFIX - 1)] == pb).any():
                return True
        pos = np.searchsorted(a, b)
        return bool((a[np.minimum(pos, na - 1)] == b).any())

    def query_batch_np(self, queries: np.ndarray) -> np.ndarray:
        """Vectorized all-pairs-compare batch query (numpy mirror of the
        device path). queries: int32[B, 2] -> bool[B]."""
        a = self.L_out[queries[:, 0]]  # [B, Lo]
        b = self.L_in[queries[:, 1]]   # [B, Li]
        eq = a[:, :, None] == b[:, None, :]
        valid = (a[:, :, None] != INVALID) & (b[:, None, :] != INVALID)
        return (eq & valid).any(axis=(1, 2))

    # ---------------- device arrays ----------------

    def device_labels(self):
        """Device copies of the label matrices, memoized per snapshot.

        Snapshots are immutable, so the first upload is cached on the
        instance: pinned-epoch serving (``repro.dynamic.versioned``) reads
        the SAME device arrays for the lifetime of the epoch instead of
        re-uploading per pin."""
        cached = getattr(self, "_device_labels", None)
        if cached is None:
            cached = (jnp.asarray(self.L_out), jnp.asarray(self.L_in))
            object.__setattr__(self, "_device_labels", cached)
        return cached


def finalize_labels(
    out_lists: Sequence[Sequence[int]],
    in_lists: Sequence[Sequence[int]],
    pad_to_multiple: int = 8,
    hop_rank: Optional[np.ndarray] = None,
) -> ReachabilityOracle:
    """Pack per-vertex python label lists into the dense oracle layout.

    With ``hop_rank`` (int32[n], rank[v] = position of v in the construction
    order, 0 = highest), hop ids are remapped to rank space before the
    ascending row sort — see module docstring.
    """
    n = len(out_lists)
    out_len = np.array([len(x) for x in out_lists], dtype=np.int32)
    in_len = np.array([len(x) for x in in_lists], dtype=np.int32)

    def _pack(lists: Sequence[Sequence[int]], lens: np.ndarray) -> np.ndarray:
        lmax = int(lens.max()) if n else 1
        lmax = max(((lmax + pad_to_multiple - 1) // pad_to_multiple) * pad_to_multiple, pad_to_multiple)
        mat = np.full((n, lmax), INVALID, dtype=np.int32)
        for i, row in enumerate(lists):
            if len(row):  # rows may be python lists OR numpy arrays
                vals = np.asarray(row, dtype=np.int32)
                if hop_rank is not None:
                    vals = hop_rank[vals]
                mat[i, : len(row)] = np.sort(vals)
        return mat

    return ReachabilityOracle(
        L_out=_pack(out_lists, out_len),
        L_in=_pack(in_lists, in_len),
        out_len=out_len,
        in_len=in_len,
        hop_rank=None if hop_rank is None else np.asarray(hop_rank, dtype=np.int32),
    )


def merge_hop_lists(parts: List[np.ndarray]) -> np.ndarray:
    """Sorted-unique union of hop id arrays (HL's label merge)."""
    if not parts:
        return np.empty(0, dtype=np.int32)
    cat = np.concatenate([np.asarray(p, dtype=np.int32) for p in parts])
    return np.unique(cat[cat != INVALID])
