"""End-to-end training driver (CPU-runnable example scale; production mesh
on real hardware via --mesh).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b \
      --smoke --steps 30                       # reduced config, CPU
  PYTHONPATH=src python -m repro.launch.train --arch xdeepfm --smoke
  PYTHONPATH=src python -m repro.launch.train --arch gcn-cora --smoke

Fault tolerance: --ckpt-dir + --ckpt-every enable checkpoint/restart;
re-running the same command resumes from the latest step. --fail-at N
injects a crash (the restart then proves recovery).
"""
from __future__ import annotations

import argparse
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data.synth import graph_batch_from_csr, lm_batch, recsys_batch
from repro.ft import FaultTolerantLoop, SimulatedFailure
from repro.graph.generators import random_dag
from repro.optim import adamw_init, adamw_update, cosine_schedule


def _lm_setup(mod, args):
    from repro.models import transformer as tf

    cfg = mod.smoke_config() if args.smoke else mod.full_config()
    params = tf.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = adamw_init(params)

    @jax.jit
    def step(state, batch):
        params, opt = state
        loss, grads = jax.value_and_grad(partial(tf.lm_loss, cfg))(params, batch)
        lr = cosine_schedule(opt.step, args.lr, warmup=20, total=args.steps)
        params, opt, metrics = adamw_update(grads, opt, params, lr)
        metrics["loss"] = loss
        return (params, opt), metrics

    batch_fn = lambda s: lm_batch(args.seed, s, args.batch, args.seq, cfg.vocab)
    return (params, opt), step, batch_fn


def _recsys_setup(mod, args):
    from repro.models.recsys import xdeepfm

    cfg = mod.smoke_config() if args.smoke else mod.full_config()
    params = xdeepfm.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = adamw_init(params)

    @jax.jit
    def step(state, batch):
        params, opt = state
        loss, grads = jax.value_and_grad(partial(xdeepfm.loss_fn, cfg))(params, batch)
        lr = cosine_schedule(opt.step, args.lr, warmup=20, total=args.steps)
        params, opt, metrics = adamw_update(grads, opt, params, lr, weight_decay=1e-5)
        metrics["loss"] = loss
        return (params, opt), metrics

    batch_fn = lambda s: recsys_batch(args.seed, s, args.batch, cfg.n_fields, cfg.vocab_per_field)
    return (params, opt), step, batch_fn


def _gnn_setup(mod, args):
    arch = mod.ARCH_ID
    cfg = mod.smoke_config() if args.smoke else mod.full_config()
    g = random_dag(args.gnn_nodes, args.gnn_nodes * 3, seed=args.seed)

    if arch == "gcn-cora":
        from repro.models.gnn import gcn as model
        batch = graph_batch_from_csr(g, cfg.d_in, seed=args.seed, n_classes=cfg.n_classes)
        loss_fn = partial(model.loss_fn, cfg)
    elif arch == "gatedgcn":
        from repro.models.gnn import gatedgcn as model
        batch = graph_batch_from_csr(
            g, cfg.d_in, seed=args.seed, n_classes=cfg.n_classes, d_edge=cfg.d_edge_in
        )
        loss_fn = partial(model.loss_fn, cfg)
    elif arch == "schnet":
        from repro.models.gnn import schnet as model
        batch = graph_batch_from_csr(g, 1, seed=args.seed, with_pos=True)
        batch = batch._replace(y=jnp.float32(3.0))
        loss_fn = partial(model.loss_fn, cfg)
    else:
        raise SystemExit(f"use dryrun for {arch}")

    params = model.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = adamw_init(params)

    @jax.jit
    def step(state, _):
        params, opt = state
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        lr = cosine_schedule(opt.step, args.lr, warmup=20, total=args.steps)
        params, opt, metrics = adamw_update(grads, opt, params, lr)
        metrics["loss"] = loss
        return (params, opt), metrics

    return (params, opt), step, lambda s: None


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--gnn-nodes", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()

    mod = get_arch(args.arch)
    family = mod.FAMILY
    if family == "lm":
        state, step, batch_fn = _lm_setup(mod, args)
    elif family == "recsys":
        state, step, batch_fn = _recsys_setup(mod, args)
    elif family == "gnn":
        state, step, batch_fn = _gnn_setup(mod, args)
    else:
        raise SystemExit(f"train driver does not cover family {family}")

    if args.ckpt_dir:
        loop = FaultTolerantLoop(
            step, batch_fn, state, args.ckpt_dir,
            ckpt_every=args.ckpt_every, fail_at=args.fail_at,
        )
        try:
            loop.run(args.steps)
        except SimulatedFailure as e:
            print(f"!! {e} — restarting from checkpoint")
            loop.maybe_restore()
            loop.run(args.steps)
        for m in loop.metrics_log:
            print(m)
        return

    for s in range(args.steps):
        state, metrics = step(state, batch_fn(s))
        if s % 10 == 0 or s == args.steps - 1:
            print({k: float(v) for k, v in metrics.items()} | {"step": s}, flush=True)


if __name__ == "__main__":
    main()
