"""Roofline-term extraction from lowered/compiled artifacts.

compute term    = HLO_FLOPs / (chips * peak_FLOPs)
memory term     = HLO_bytes / (chips * HBM_bw)
collective term = collective_bytes / (chips * link_bw)

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (3 links usable per direction is ignored: one-link figure,
conservative).

collective_bytes is parsed from the post-SPMD HLO text: the operand bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op.
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per chip (single ICI link)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum collective bytes per kind from post-optimization HLO text.

    Optimized HLO does not annotate operand types inline, so we size each op
    by its RESULT type (the `%x = <type> op(...)` LHS). For all-reduce,
    all-to-all and collective-permute, result bytes == operand bytes == wire
    bytes per device. For all-gather the result is the fully-gathered buffer
    (~= wire bytes received per device). reduce-scatter is sized by its
    (scattered) result and thus undercounts wire bytes by ~the group size —
    XLA on these modules emits all-reduce instead, so the skew is marginal;
    the per-kind breakdown keeps it auditable."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith("//") or "=" not in s:
            continue
        for kind in _COLLECTIVES:
            token = f" {kind}("
            if token in s:
                lhs = s.split(token, 1)[0]
                # result type(s) live between '=' and the opcode
                rhs_types = lhs.split("=", 1)[1] if "=" in lhs else lhs
                b = sum(
                    _shape_bytes(m.group(1), m.group(2))
                    for m in _SHAPE_RE.finditer(rhs_types)
                )
                out[kind] += b
                out["count"] += 1
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def roofline_terms(
    flops: float, bytes_accessed: float, coll_bytes: float, n_chips: int
) -> Dict[str, float]:
    """Per-step seconds for each roofline term. flops/bytes are WHOLE-program
    numbers (cost_analysis of the partitioned module is per-device already in
    recent jax — we pass per_device=True data when so; callers normalize)."""
    compute_s = flops / (n_chips * PEAK_FLOPS)
    memory_s = bytes_accessed / (n_chips * HBM_BW)
    collective_s = coll_bytes / (n_chips * LINK_BW)
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda kv: kv[1],
    )[0]
    return dict(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        bound_s=max(compute_s, memory_s, collective_s),
    )
