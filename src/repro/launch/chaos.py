"""Chaos smoke driver: kill-and-resume build, corrupt-index load, serve
degradation — the fault-tolerance acceptance checks as one CLI.

  PYTHONPATH=src python -m repro.launch.chaos            # all scenarios
  PYTHONPATH=src python -m repro.launch.chaos --scenario build --seed 3

Each scenario prints PASS/FAIL and the driver exits nonzero if any fails,
so CI can run it directly.  All faults go through ``repro.ft.inject`` and
are deterministic in ``--seed``.
"""
from __future__ import annotations

import argparse
import sys
import tempfile
import warnings

import numpy as np

from repro.build.engine import build_distribution_labels
from repro.core.api import build_oracle
from repro.dynamic import DurableDynamicOracle, DynamicOracle, UpdateBatch
from repro.ft import inject
from repro.ft.inject import SimulatedFailure
from repro.graph.generators import layered_dag, random_dag
from repro.obs import metrics, trace
from repro.persist import CorruptSnapshotError, load_oracle, save_oracle


def _fields_equal(a, b) -> bool:
    return all(
        getattr(a, f).tobytes() == getattr(b, f).tobytes()
        for f in ("L_out", "L_in", "out_len", "in_len", "hop_rank")
    )


def scenario_build(seed: int) -> bool:
    """Kill the build at a seed-picked wave/chunk boundary, resume from the
    latest checkpoint, and require byte-identity with an uninterrupted run."""
    ok = True
    for impl, g in (("wave", random_dag(300, 1200, seed=seed)),
                    ("speculative", layered_dag(240, 3.0, seed=seed + 1))):
        want = build_distribution_labels(g, impl=impl)
        with tempfile.TemporaryDirectory() as d:
            plan = inject.seeded(seed, {"build.wave": 8, "build.chunk": 6})
            try:
                with inject.active(plan):
                    build_distribution_labels(
                        g, impl=impl, checkpoint_dir=d, checkpoint_every=2)
                crashed = False
            except SimulatedFailure as e:
                crashed = True
                crash_at = str(e)
            got = build_distribution_labels(
                g, impl=impl, checkpoint_dir=d, checkpoint_every=2)
            ck = got.build_stats["checkpoint"]
            same = _fields_equal(want, got)
            ok &= same
            where = crash_at if crashed else "no boundary hit (ran clean)"
            print(f"  [{impl}] crash={where} resumed_from={ck['resumed_from']} "
                  f"byte-identical={same}")
    print(f"build kill-and-resume: {'PASS' if ok else 'FAIL'}")
    return ok


def scenario_corrupt(seed: int) -> bool:
    """Flip one bit in a saved index; the strict load must fail loudly and
    the non-strict load must quarantine exactly the corrupt block."""
    g = random_dag(150, 500, seed=seed)
    co = build_oracle(g)
    ok = True
    with tempfile.TemporaryDirectory() as d:
        save_oracle(d, co.oracle)
        clean = load_oracle(d)
        ok &= _fields_equal(co.oracle, clean)
        off = inject.flip_bit(f"{d}/L_out.00000.npy", seed=seed)
        try:
            load_oracle(d)
            print(f"  corrupt byte {off}: strict load DID NOT raise")
            ok = False
        except CorruptSnapshotError as e:
            print(f"  corrupt byte {off}: strict load failed loudly ({e})")
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            _, report = load_oracle(d, strict=False)
        ok &= report.bad_blocks == ["L_out.00000"]
        print(f"  non-strict quarantined blocks: {report.bad_blocks} "
              f"({int(report.quarantine_out.sum())} rows)")
    print(f"corrupt-index load: {'PASS' if ok else 'FAIL'}")
    return ok


def scenario_serve(seed: int) -> bool:
    """Inject a device dispatch failure and a quarantined row set; verdicts
    must match the clean host path while the degradation counters move."""
    g = random_dag(200, 700, seed=seed)
    co = build_oracle(g)
    rng = np.random.default_rng(seed)
    q = rng.integers(0, g.n, size=(2000, 2)).astype(np.int32)
    want = co.engine.query_batch(q, backend="host")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        with inject.active(inject.Injector({"serve.device_dispatch": 0})):
            got_dev = co.engine.query_batch(q, backend="dense")
    qmask = np.zeros(co.oracle.n, dtype=bool)
    qmask[rng.integers(0, co.oracle.n, size=co.oracle.n // 4)] = True
    co.engine.set_quarantine(qmask, None)
    got_search = co.engine.query_batch(q, backend="host")
    co.engine.set_quarantine(None, None)
    deg = co.engine.degradation
    ok = (bool((got_dev == want).all()) and bool((got_search == want).all())
          and deg["device_to_host"] > 0 and deg["searched"] > 0)
    print(f"  degradation counters: {deg}  verdicts-match="
          f"{bool((got_dev == want).all() and (got_search == want).all())}")
    print(f"serve degradation ladder: {'PASS' if ok else 'FAIL'}")
    return ok


def scenario_dynamic(seed: int) -> bool:
    """Crash a DurableDynamicOracle after WAL-acknowledged updates; recovery
    must agree with a fresh DynamicOracle fed the same batches."""
    g = random_dag(80, 260, seed=seed)
    rng = np.random.default_rng(seed)
    batches = []
    for _ in range(3):
        ups = [(bool(rng.integers(0, 2)), int(rng.integers(0, g.n)),
                int(rng.integers(0, g.n))) for _ in range(6)]
        batches.append(UpdateBatch.of(
            inserts=[(u, v) for ins, u, v in ups if ins and u != v],
            deletes=[(u, v) for ins, u, v in ups if not ins and u != v]))
    with tempfile.TemporaryDirectory() as d:
        dur = DurableDynamicOracle(g, state_dir=d)
        dur.apply(batches[0])
        dur.publish()
        dur.apply(batches[1])
        dur.apply(batches[2])  # acknowledged, never published: the crash tail
        del dur  # crash
        rec = DurableDynamicOracle.recover(d)
        ref = DynamicOracle(g)
        for b in batches:
            ref.apply(b)
        ref.publish()
        q = rng.integers(0, g.n, size=(1500, 2)).astype(np.int32)
        same = bool((rec.serve(q) == ref.serve(q)).all())
        print(f"  recovered epoch={rec._epoch} replayed={rec.recovered_records} "
              f"rebuild-agreement={same}")
    print(f"dynamic crash-recovery: {'PASS' if same else 'FAIL'}")
    return same


def scenario_daemon(seed: int) -> bool:
    """Kill the serving daemon mid-serve over a durable oracle (with a WAL
    tail acknowledged but unpublished), restart, recover snapshot+WAL, and
    drain cleanly — recovered serving state must be byte-deterministic and
    agree with a never-crashed reference oracle."""
    import asyncio

    from repro.serve.daemon import DaemonConfig, ServeDaemon, ShedError

    g = random_dag(250, 900, seed=seed)
    rng = np.random.default_rng(seed)

    def rand_batch(k: int = 40) -> UpdateBatch:
        ups = [(bool(rng.integers(0, 2)), int(rng.integers(0, g.n)),
                int(rng.integers(0, g.n))) for _ in range(k)]
        return UpdateBatch.of(
            inserts=[(u, v) for ins, u, v in ups if ins and u != v],
            deletes=[(u, v) for ins, u, v in ups if not ins and u != v])

    b_published, b_tail = rand_batch(), rand_batch()
    q_ref = rng.integers(0, g.n, size=(1200, 2)).astype(np.int32)
    report: dict = {}

    with tempfile.TemporaryDirectory() as d:
        dur = DurableDynamicOracle(g, state_dir=d)
        dur.apply(b_published)
        dur.publish()

        async def crash_phase() -> None:
            cfg = DaemonConfig(deadline_ms=1000.0, batch_window_ms=1.0,
                               backend="dense")
            daemon = ServeDaemon(dur, cfg)
            await daemon.start()
            ans_a = await daemon.submit(
                rng.integers(0, g.n, size=(64, 2)).astype(np.int32))
            dur.apply(b_tail)   # WAL-acknowledged, never published: crash tail
            killed = 0

            async def doomed() -> None:
                nonlocal killed
                try:
                    await daemon.submit(
                        rng.integers(0, g.n, size=(32, 2)).astype(np.int32))
                except ShedError as e:
                    killed += e.reason == "killed"

            # stall the next device dispatches so the kill lands mid-flight
            plan = inject.Injector(
                latency={"serve.device_dispatch": ([0, 1, 2], 0.3)})
            with inject.active(plan):
                tasks = [asyncio.create_task(doomed()) for _ in range(4)]
                await asyncio.sleep(0.08)
                await daemon.kill()
                await asyncio.gather(*tasks)
            report.update(answered=int(ans_a.shape[0]), killed=killed,
                          killed_state=daemon.state)

        asyncio.run(crash_phase())
        del dur   # crash: only the state dir survives

        rec = DurableDynamicOracle.recover(d)
        rec2 = DurableDynamicOracle.recover(d)
        report["recovery_deterministic"] = _fields_equal(
            rec._base_oracle, rec2._base_oracle)
        ref = DynamicOracle(g)
        ref.apply(b_published)
        ref.publish()
        ref.apply(b_tail)
        ref.publish()
        report["rebuild_agreement"] = bool(
            (rec.serve(q_ref) == ref.serve(q_ref)).all())

        async def drain_phase() -> None:
            daemon = ServeDaemon(rec, DaemonConfig(deadline_ms=1000.0))
            await daemon.start()
            parts = await asyncio.gather(
                *(daemon.submit(q_ref[i * 200:(i + 1) * 200])
                  for i in range(6)))
            stats = await daemon.drain()
            report["drained_clean"] = (daemon.state == "stopped"
                                       and stats["answered"] == stats["admitted"])
            report["recovered_serving_match"] = bool(
                (np.concatenate(parts) == ref.serve(q_ref)).all())

        asyncio.run(drain_phase())

    ok = (report["answered"] > 0 and report["killed"] > 0
          and report["killed_state"] == "killed"
          and report["recovery_deterministic"] and report["rebuild_agreement"]
          and report["drained_clean"] and report["recovered_serving_match"])
    print(f"  {report}")
    print(f"daemon kill-recover-drain: {'PASS' if ok else 'FAIL'}")
    return ok


def scenario_budget(seed: int) -> bool:
    """Drive a memory-pressure step-down mid-serve: the budget governor must
    re-truncate the label store IN PLACE (no rebuild — the engine's full
    oracle object survives untouched) while stalled batches are in flight,
    drop no request, change no verdict, and step back up with hysteresis
    once the pressure signal clears."""
    import asyncio

    from repro.serve.budget import BudgetController, PressureConfig, label_bytes
    from repro.serve.daemon import DaemonConfig, ServeDaemon

    g = random_dag(400, 1400, seed=seed)
    co = build_oracle(g)
    rng = np.random.default_rng(seed)
    q_all = rng.integers(0, g.n, size=(2000, 2)).astype(np.int32)
    want = co.engine.query_batch(q_all, backend="host")
    co.engine.reset_stats()
    full_oracle = co.engine.oracle   # identity-checked below: never rebuilt
    full = label_bytes(co.oracle)

    sig = {"bytes": 0.0}   # scripted pressure signal (deterministic)
    ctl = BudgetController(
        co.engine,
        pressure=PressureConfig(watermark_bytes=full // 2, step_factor=0.5,
                                recovery_ticks=2, check_interval_s=0.02),
        pressure_source=lambda: sig["bytes"])
    report: dict = {}

    async def run() -> None:
        daemon = ServeDaemon(
            co, DaemonConfig(deadline_ms=2000.0, backend="dense",
                             batch_window_ms=1.0), budget_ctl=ctl)
        await daemon.start()
        answers: dict = {}

        async def ask(i: int) -> None:
            answers[i] = await daemon.submit(q_all[i * 80:(i + 1) * 80])

        # phase 1: clean serving at full labels
        await asyncio.gather(*(ask(i) for i in range(10)))
        # phase 2: pressure crosses the watermark while device dispatches
        # are stalled — the step-down must land in the gaps BETWEEN stalled
        # in-flight batches, never tear one
        sig["bytes"] = float(full)
        plan = inject.Injector(
            latency={"serve.device_dispatch": (list(range(6)), 0.05)})
        with inject.active(plan):
            await asyncio.gather(*(ask(i) for i in range(10, 20)))
        report["steps_down_mid_serve"] = daemon.counters["budget_steps_down"]
        store = co.engine.budget_store
        report["truncated"] = store is not None and store.any_truncated
        # phase 3: budgeted serving continues under pressure
        await asyncio.gather(*(ask(i) for i in range(20, 25)))
        # phase 4: pressure clears; hysteresis must step all the way back up
        sig["bytes"] = 0.0
        for _ in range(300):
            await asyncio.sleep(0.02)
            if co.engine.budget_store is None:
                break
        report["stepped_back_up"] = co.engine.budget_store is None
        stats = await daemon.drain()
        report["answered"] = int(stats["answered"])
        report["admitted"] = int(stats["admitted"])
        report["shed"] = sum(v for k, v in stats.items() if k.startswith("shed_"))
        got = np.concatenate([answers[i] for i in range(25)])
        report["verdicts_match"] = bool((got == want).all())
        report["no_rebuild"] = co.engine.oracle is full_oracle
        report["retruncations"] = ctl.retruncations
        report["uncertain_searched"] = co.engine.degradation["uncertain"]

    asyncio.run(run())
    ok = (report["steps_down_mid_serve"] > 0 and report["truncated"]
          and report["stepped_back_up"] and report["verdicts_match"]
          and report["no_rebuild"] and report["shed"] == 0
          and report["answered"] == report["admitted"])
    print(f"  {report}")
    print(f"budget pressure step-down: {'PASS' if ok else 'FAIL'}")
    return ok


SCENARIOS = {
    "build": scenario_build,
    "corrupt": scenario_corrupt,
    "serve": scenario_serve,
    "dynamic": scenario_dynamic,
    "daemon": scenario_daemon,
    "budget": scenario_budget,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="all",
                    choices=["all", *SCENARIOS])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None,
                    help="write the run's Chrome-trace timeline here before "
                         "exiting (CI uploads it as a failure artifact)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the metrics-registry snapshot JSON here "
                         "before exiting")
    args = ap.parse_args()
    names = list(SCENARIOS) if args.scenario == "all" else [args.scenario]
    # every scenario runs even when an earlier one fails or raises — a crash
    # in one must not mask regressions in the rest, and the exit code must
    # report ALL failures, not just the first
    results: dict = {}
    for name in names:
        print(f"=== {name} ===")
        with trace.span(f"chaos.{name}", cat="chaos",
                        args={"seed": args.seed}):
            try:
                results[name] = bool(SCENARIOS[name](args.seed))
            except Exception as e:   # noqa: BLE001 - the driver is the backstop
                print(f"{name}: FAIL (unhandled {type(e).__name__}: {e})")
                results[name] = False
    failed = [n for n, ok in results.items() if not ok]
    if args.trace_out:
        trace.export_chrome(args.trace_out,
                            meta={"driver": "chaos", "seed": args.seed,
                                  "failed": failed})
        print(f"wrote trace -> {args.trace_out}")
    if args.metrics_out:
        metrics.export_json(args.metrics_out)
        print(f"wrote metrics -> {args.metrics_out}")
    if failed:
        print(f"chaos scenarios FAILED: {', '.join(failed)} "
              f"({len(failed)}/{len(results)})")
        sys.exit(1)
    print(f"all {len(results)} chaos scenarios passed")


if __name__ == "__main__":
    main()
