"""Oracle serving driver: closed-loop backend sweeps and the open-loop
serving daemon, over a built, snapshot-loaded, or WAL-recovered index.

Closed-loop sweep (the BENCH_serve.json backends section):

  PYTHONPATH=src python -m repro.launch.serve --dataset citeseer --scale 0.02 \
      --n-queries 100000 --batch 4096 --backend dense

Open-loop daemon (admission control + deadline shedding + circuit breaker;
SIGTERM drains gracefully):

  PYTHONPATH=src python -m repro.launch.serve --mode daemon --rate 400 \
      --arrival-batch 64 --duration 3 --deadline-ms 150

Lifecycle: ``--snapshot-dir`` cold-starts from a ``persist.load_oracle``
snapshot when one exists (``--load-mode quarantine`` arms the degradation
ladder instead of refusing a corrupt snapshot) and saves one after a fresh
build; ``--state-dir`` serves a ``DurableDynamicOracle``, recovering
snapshot + WAL when the directory is non-empty.  ``--inject-device-failure``
/ ``--inject-device-latency`` aim deterministic faults at the dispatch path
so overload behavior is reproducible, not anecdotal.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import signal
import threading
import time

import numpy as np

from repro import obs
from repro.core.api import build_oracle, oracle_from_snapshot
from repro.ft import inject
from repro.obs import metrics, trace
from repro.serve.daemon import DaemonConfig, ServeDaemon
from repro.serve.engine import select_backend
from repro.serve.openloop import run_open_loop
from repro.graph.generators import paper_dataset_analogue, random_dag
from repro.graph.reach import reachable_set

HOST_BACKENDS = ("host", "dense", "kernel")


def make_graph(args):
    g = (
        paper_dataset_analogue(args.dataset, scale=args.scale)
        if args.dataset != "random"
        else random_dag(20000, 50000, seed=args.seed)
    )
    print(f"graph: n={g.n} m={g.m}")
    return g


def build_target(args, g):
    """Resolve the serving target through the lifecycle ladder:
    durable-dynamic recovery > snapshot cold start > fresh build."""
    if args.state_dir:
        from repro.dynamic import DurableDynamicOracle

        has_state = os.path.isdir(args.state_dir) and any(
            name.startswith("snap_") for name in os.listdir(args.state_dir))
        if has_state:
            t0 = time.perf_counter()
            dyn = DurableDynamicOracle.recover(args.state_dir)
            print(f"recovered durable oracle from {args.state_dir} in "
                  f"{time.perf_counter() - t0:.2f}s (epoch={dyn.epoch}, "
                  f"wal records replayed={dyn.recovered_records})")
        else:
            dyn = DurableDynamicOracle(g, state_dir=args.state_dir)
            print(f"durable oracle initialized at {args.state_dir}")
        return dyn
    if args.snapshot_dir and os.path.isdir(args.snapshot_dir):
        t0 = time.perf_counter()
        co = oracle_from_snapshot(g, args.snapshot_dir, mode=args.load_mode)
        nq = co.engine.stats()["n_quarantined"]
        print(f"cold start from snapshot {args.snapshot_dir} in "
              f"{time.perf_counter() - t0:.2f}s"
              + (f" ({nq} rows quarantined)" if nq else ""))
        return co
    co = build(args, g)
    if args.snapshot_dir:
        from repro.persist import save_oracle

        save_oracle(args.snapshot_dir, co.oracle)
        print(f"saved index snapshot -> {args.snapshot_dir}")
    return co


def build(args, g=None):
    if g is None:
        g = make_graph(args)
    ckpt_kwargs = {}
    if args.checkpoint_dir:
        # crash-safe build: wave-granular checkpoints; a re-run with the same
        # flags resumes from the latest complete one and finishes byte-identical
        ckpt_kwargs = dict(checkpoint_dir=args.checkpoint_dir,
                           checkpoint_every=args.checkpoint_every)
    t0 = time.perf_counter()
    oracle = build_oracle(g, bucketing=not args.no_bucketing, **ckpt_kwargs)
    t_build = time.perf_counter() - t0
    print(
        f"DL build: {t_build:.2f}s  label ints={oracle.total_label_size} "
        f"(avg {oracle.total_label_size / g.n:.1f}/vertex)  "
        f"tier widths={oracle.engine.widths}"
    )
    ck = getattr(oracle.oracle, "build_stats", {}).get("checkpoint")
    if ck is not None:
        print(f"checkpoints: resumed_from={ck['resumed_from']} "
              f"written={ck['written']} -> {args.checkpoint_dir}")
    return oracle


def serve_loop(oracle, queries: np.ndarray, batch: int, backend: str) -> tuple[float, np.ndarray]:
    """Run the query stream through the engine; returns (seconds, answers)."""
    n_q = queries.shape[0]
    oracle.serve(queries[:batch], backend=backend)  # warmup/compile
    if n_q % batch:  # the tail batch pads to different tile shapes — warm it too
        oracle.serve(queries[n_q - n_q % batch :], backend=backend)
    t0 = time.perf_counter()
    results = []
    for lo in range(0, n_q, batch):
        results.append(oracle.serve(queries[lo : lo + batch], backend=backend))
    dt = time.perf_counter() - t0
    return dt, np.concatenate(results)


def check_sample(g, queries: np.ndarray, pred: np.ndarray, n_check: int = 200) -> int:
    bad = 0
    for i in range(min(n_check, queries.shape[0])):
        u, v = int(queries[i, 0]), int(queries[i, 1])
        truth = bool(reachable_set(g, u)[v]) or u == v
        bad += truth != bool(pred[i])
    return bad


# -------------------------------------------------------- closed-loop sweep


def run_sweep(args) -> None:
    backends = list(HOST_BACKENDS) if args.backend == "all" else [args.backend]
    for be in backends:
        if be != "auto":
            try:
                select_backend(be)
            except ValueError as e:
                raise SystemExit(str(e))

    g = make_graph(args)
    oracle = build_target(args, g)
    rng = np.random.default_rng(args.seed)
    queries = rng.integers(0, g.n, size=(args.n_queries, 2)).astype(np.int32)

    records = {}
    failed = False
    for be in backends:
        deg0 = dict(oracle.engine.degradation)
        if args.inject_device_failure is not None:
            # fresh plan per backend: occurrence counters live on the injector
            plan = inject.Injector(
                {"serve.device_dispatch": args.inject_device_failure})
            with inject.active(plan):
                dt, pred = serve_loop(oracle, queries, args.batch, be)
        else:
            dt, pred = serve_loop(oracle, queries, args.batch, be)
        stats = oracle.engine.last_stats
        mqps = args.n_queries / dt / 1e6
        print(
            f"[{stats['backend']}] served {args.n_queries} queries in {dt:.3f}s "
            f"({mqps:.2f} M qps; {dt / args.n_queries * 1e9:.0f} ns/query)  "
            f"prefiltered {stats['n_prefiltered']}/{stats['n_queries']} of last batch"
        )
        deg = {k: v - deg0.get(k, 0) for k, v in oracle.engine.degradation.items()}
        if any(deg.values()):
            print(f"[{stats['backend']}] degradation: "
                  f"device->host={deg['device_to_host']} "
                  f"searched={deg['searched']} quarantined={deg['quarantined']}")
        bad = check_sample(g, queries, pred)
        n_check = min(200, args.n_queries)
        print(f"[{stats['backend']}] correctness sample: {n_check - bad}/{n_check} ok")
        failed |= bad > 0
        records[stats["backend"]] = {
            "mqps": round(mqps, 4),
            "ns_per_query": round(dt / args.n_queries * 1e9, 1),
            "bucketing": not args.no_bucketing,
            "sample_errors": bad,
            "degradation": dict(deg),
        }

    if args.json_out:
        payload = {
            "dataset": args.dataset,
            "scale": args.scale,
            "n": g.n,
            "m": g.m,
            "n_queries": args.n_queries,
            "batch": args.batch,
            "label_ints": oracle.total_label_size,
            "tier_widths": oracle.engine.widths,
            "jax_platform": __import__("jax").default_backend(),
            "note": "kernel backend runs the Pallas kernel in interpret mode off-TPU",
            "backends": records,
        }
        # preserve sections other writers own (the open_loop rows)
        if os.path.exists(args.json_out):
            try:
                with open(args.json_out) as f:
                    prev = json.load(f)
                if "open_loop" in prev:
                    payload["open_loop"] = prev["open_loop"]
            except (json.JSONDecodeError, OSError):
                pass
        with open(args.json_out, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"wrote {args.json_out}")

    if failed:
        raise SystemExit(1)


# ----------------------------------------------------------- open-loop daemon


def _parse_occurrences(spec: str):
    """'3' -> [3];  '2-5' -> [2,3,4,5];  '1,4' -> [1,4]."""
    out = []
    for part in str(spec).split(","):
        if "-" in part:
            lo, hi = part.split("-")
            out.extend(range(int(lo), int(hi) + 1))
        else:
            out.append(int(part))
    return out


def fault_plan_from_args(args):
    """CLI fault flags -> one deterministic inject.Injector (or None)."""
    rules = {}
    latency = {}
    if args.inject_device_failure is not None:
        rules["serve.device_dispatch"] = _parse_occurrences(
            args.inject_device_failure)
    if args.inject_device_latency:
        occ, ms = args.inject_device_latency.rsplit(":", 1)
        latency["serve.device_dispatch"] = (
            _parse_occurrences(occ), float(ms) / 1000.0)
    if not rules and not latency:
        return None
    return inject.Injector(rules, latency=latency)


def _dump_obs(args) -> None:
    """Export the trace ring / metrics snapshot to the CLI out-files.

    Runs on every exit path (normal completion, SIGTERM drain, faulted
    abort), so a misbehaving run still leaves its timeline behind."""
    if getattr(args, "trace_out", None):
        trace.export_chrome(args.trace_out,
                            meta={"mode": args.mode, "dataset": args.dataset})
        print(f"wrote trace -> {args.trace_out}")
    if getattr(args, "metrics_out", None):
        metrics.export_json(args.metrics_out)
        print(f"wrote metrics -> {args.metrics_out}")


def budget_ctl_from_args(args, target):
    """CLI budget flags -> a BudgetController (or None).

    ``--budget-mb`` serves under a hard label-byte budget from the start;
    ``--pressure-watermark`` (MiB of resident label bytes) arms the live
    pressure loop — with no initial budget, the daemon serves the full
    store until the signal crosses the watermark, then steps down."""
    if args.budget_mb is None and args.pressure_watermark is None:
        return None
    from repro.serve.budget import BudgetController, PressureConfig

    engine = getattr(target, "engine", target)
    pressure = None
    if args.pressure_watermark is not None:
        pressure = PressureConfig(
            watermark_bytes=int(args.pressure_watermark * (1 << 20)))
    ctl = BudgetController(
        engine,
        budget_bytes=(None if args.budget_mb is None
                      else int(args.budget_mb * (1 << 20))),
        pressure=pressure,
    )
    snap = ctl.snapshot()
    print(f"budget: {snap['budget_bytes'] or 'none'} bytes over a "
          f"{snap['full_bytes']}-byte full store "
          f"(resident {snap['resident_bytes']}, rank_cut={snap['rank_cut']}"
          + (f", watermark {pressure.watermark_bytes}" if pressure else "")
          + ")")
    return ctl


def run_daemon(args) -> None:
    g = make_graph(args)
    target = build_target(args, g)
    budget_ctl = budget_ctl_from_args(args, target)
    cfg = DaemonConfig(
        batch_window_ms=args.batch_window_ms,
        max_batch=args.max_batch,
        queue_limit=args.queue_limit,
        deadline_ms=args.deadline_ms,
        backend=None if args.backend in ("auto", "all") else args.backend,
        breaker_failures=args.breaker_failures,
        breaker_slo_ms=args.breaker_slo_ms,
    )

    # SIGTERM/SIGINT -> graceful drain: admission starts shedding
    # ("draining"), already-admitted requests are served, then the loop
    # stops.  The handler only flips state; the drain in run_open_loop's
    # driver does the rest.
    daemon_box = {}

    def _drain_handler(signum, frame):
        d: ServeDaemon = daemon_box.get("daemon")
        if d is not None and d.state == "ready":
            print(f"signal {signum}: draining (new arrivals shed)")
            d.state = "draining"

    old_term = signal.signal(signal.SIGTERM, _drain_handler)
    old_int = signal.signal(signal.SIGINT, _drain_handler)

    # run_open_loop creates the daemon internally; intercept it via a small
    # subclass hook so the signal handler can reach it
    orig_init = ServeDaemon.__init__

    def _capturing_init(self, *a, **kw):
        orig_init(self, *a, **kw)
        daemon_box["daemon"] = self

    ServeDaemon.__init__ = _capturing_init
    # zero the registry and trace ring at daemon start: the exported metrics
    # snapshot then reconciles EXACTLY with this run's daemon counters
    # (build-time metrics would otherwise leak into the serving numbers)
    metrics.REGISTRY.reset()
    trace.TRACER.clear()
    stop_dump = threading.Event()
    dump_thread = None
    if args.metrics_out and args.metrics_interval > 0:
        def _periodic() -> None:
            while not stop_dump.wait(args.metrics_interval):
                metrics.export_json(args.metrics_out)

        dump_thread = threading.Thread(target=_periodic, daemon=True)
        dump_thread.start()
    try:
        report = run_open_loop(
            target, g,
            rate_arrivals_per_s=args.rate,
            arrival_batch=args.arrival_batch,
            duration_s=args.duration,
            deadline_ms=args.deadline_ms,
            config=cfg,
            fault_plan=fault_plan_from_args(args),
            seed=args.seed,
            budget_ctl=budget_ctl,
        )
    finally:
        ServeDaemon.__init__ = orig_init
        signal.signal(signal.SIGTERM, old_term)
        signal.signal(signal.SIGINT, old_int)
        stop_dump.set()
        if dump_thread is not None:
            dump_thread.join(timeout=2.0)
        _dump_obs(args)

    daemon = daemon_box.get("daemon")
    health = daemon.health() if daemon is not None else {}
    print(f"daemon: answered {report['answered']} of {report['submitted']} "
          f"submitted ({report['sustained_qps']} qps sustained, "
          f"offered {report['offered_qps']})")
    print(f"daemon: shed_rate={report['shed_rate']:.3f} {report['shed']}  "
          f"p50={report['p50_ms']:.1f}ms p99={report['p99_ms']:.1f}ms "
          f"(deadline {report['deadline_ms']:.0f}ms, "
          f"within={report['p99_within_deadline']})")
    print(f"daemon: breaker trips={report['breaker']['trips']} "
          f"degradation={report['degradation']}  "
          f"sample_errors={report['sample_errors']}")
    if report.get("budget"):
        b = report["budget"]
        print(f"daemon: budget resident={b['resident_bytes']}/{b['full_bytes']} "
              f"bytes rank_cut={b['rank_cut']} steps_down={b['steps_down']} "
              f"steps_up={b['steps_up']} retruncations={b['retruncations']}")
    if args.json_out:
        payload = {"dataset": args.dataset, "scale": args.scale,
                   "n": g.n, "m": g.m, "mode": "daemon",
                   "report": report, "health": health}
        with open(args.json_out, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"wrote {args.json_out}")
    if report["sample_errors"]:
        raise SystemExit(1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="sweep", choices=["sweep", "daemon"],
                    help="sweep = closed-loop backend sweep; daemon = "
                         "open-loop admission-controlled serving")
    ap.add_argument("--dataset", default="citeseer")
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--n-queries", type=int, default=100_000)
    ap.add_argument("--batch", type=int, default=4096)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="auto",
                    help="auto|host|dense|kernel, or 'all' to sweep")
    ap.add_argument("--no-bucketing", action="store_true",
                    help="disable length-bucketed micro-batching")
    ap.add_argument("--json-out", default=None,
                    help="write results to this JSON file")
    # lifecycle
    ap.add_argument("--checkpoint-dir", default=None,
                    help="wave-granular build checkpoints; re-running with the "
                         "same flags resumes from the latest complete one")
    ap.add_argument("--checkpoint-every", type=int, default=16,
                    help="schedule boundaries between checkpoints")
    ap.add_argument("--snapshot-dir", default=None,
                    help="cold-start from this persist.save_oracle snapshot "
                         "when it exists; save one after a fresh build")
    ap.add_argument("--load-mode", default="strict",
                    choices=["strict", "quarantine"],
                    help="strict: refuse a corrupt snapshot; quarantine: "
                         "serve around corrupt rows via the degradation ladder")
    ap.add_argument("--state-dir", default=None,
                    help="serve a DurableDynamicOracle out of this WAL+snapshot "
                         "dir (recovers when non-empty)")
    # daemon knobs
    ap.add_argument("--rate", type=float, default=400.0,
                    help="daemon mode: Poisson arrival rate (arrivals/sec)")
    ap.add_argument("--arrival-batch", type=int, default=64,
                    help="queries per arrival")
    ap.add_argument("--duration", type=float, default=3.0,
                    help="daemon mode: open-loop run seconds")
    ap.add_argument("--deadline-ms", type=float, default=150.0)
    ap.add_argument("--queue-limit", type=int, default=8192)
    ap.add_argument("--max-batch", type=int, default=4096)
    ap.add_argument("--batch-window-ms", type=float, default=2.0)
    ap.add_argument("--breaker-failures", type=int, default=3)
    ap.add_argument("--breaker-slo-ms", type=float, default=None)
    # memory budget
    ap.add_argument("--budget-mb", type=float, default=None,
                    help="daemon mode: serve under this label-byte budget "
                         "(MiB) via rank-prefix truncation; verdicts the cut "
                         "labels cannot prove route to exact online search — "
                         "wrong answers are impossible at any budget")
    ap.add_argument("--pressure-watermark", type=float, default=None,
                    help="daemon mode: arm the live memory-pressure loop — "
                         "step the budget down (re-truncate in place) while "
                         "resident label bytes exceed this watermark (MiB), "
                         "step back up with hysteresis once pressure clears")
    # faults
    ap.add_argument("--inject-device-failure", default=None, metavar="OCCS",
                    help="fault the given device-dispatch occurrences "
                         "('4' / '2-5' / '1,7'); sweep mode takes a single int")
    ap.add_argument("--inject-device-latency", default=None, metavar="OCCS:MS",
                    help="daemon mode: stall the given device-dispatch "
                         "occurrences by MS milliseconds (e.g. '2-6:60')")
    # observability
    ap.add_argument("--trace-out", default=None,
                    help="daemon mode: write the run's Chrome-trace timeline "
                         "here at exit (load in ui.perfetto.dev)")
    ap.add_argument("--metrics-out", default=None,
                    help="daemon mode: write the metrics-registry snapshot "
                         "JSON here at exit")
    ap.add_argument("--metrics-interval", type=float, default=0.0,
                    help="also rewrite --metrics-out every N seconds while "
                         "the daemon runs")
    ap.add_argument("--no-obs", action="store_true",
                    help="disable the observability layer entirely "
                         "(obs.disable(); the overhead-guard baseline)")
    args = ap.parse_args()

    if args.no_obs:
        obs.disable()
    if args.mode == "daemon":
        run_daemon(args)
    else:
        if args.inject_device_failure is not None:
            # sweep mode keeps the historical single-occurrence semantics
            args.inject_device_failure = int(args.inject_device_failure)
        run_sweep(args)


if __name__ == "__main__":
    main()
