"""Oracle serving driver: build the index, serve batched query streams
through the QueryEngine.

  PYTHONPATH=src python -m repro.launch.serve --dataset citeseer --scale 0.02 \
      --n-queries 100000 --batch 4096 --backend dense

Builds Distribution-Labeling on the (synthetic analogue) dataset, then runs
the engine's batched path (prefilters + length-bucketed micro-batching +
the chosen intersection backend) and reports throughput + correctness
against ground truth on a sample. ``--backend all`` sweeps every
single-host backend.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.api import build_oracle
from repro.ft import inject
from repro.serve.engine import select_backend
from repro.graph.generators import paper_dataset_analogue, random_dag
from repro.graph.reach import reachable_set

HOST_BACKENDS = ("host", "dense", "kernel")


def build(args):
    g = (
        paper_dataset_analogue(args.dataset, scale=args.scale)
        if args.dataset != "random"
        else random_dag(20000, 50000, seed=args.seed)
    )
    print(f"graph: n={g.n} m={g.m}")
    ckpt_kwargs = {}
    if args.checkpoint_dir:
        # crash-safe build: wave-granular checkpoints; a re-run with the same
        # flags resumes from the latest complete one and finishes byte-identical
        ckpt_kwargs = dict(checkpoint_dir=args.checkpoint_dir,
                           checkpoint_every=args.checkpoint_every)
    t0 = time.perf_counter()
    oracle = build_oracle(g, bucketing=not args.no_bucketing, **ckpt_kwargs)
    t_build = time.perf_counter() - t0
    print(
        f"DL build: {t_build:.2f}s  label ints={oracle.total_label_size} "
        f"(avg {oracle.total_label_size / g.n:.1f}/vertex)  "
        f"tier widths={oracle.engine.widths}"
    )
    ck = getattr(oracle.oracle, "build_stats", {}).get("checkpoint")
    if ck is not None:
        print(f"checkpoints: resumed_from={ck['resumed_from']} "
              f"written={ck['written']} -> {args.checkpoint_dir}")
    return g, oracle


def serve_loop(oracle, queries: np.ndarray, batch: int, backend: str) -> tuple[float, np.ndarray]:
    """Run the query stream through the engine; returns (seconds, answers)."""
    n_q = queries.shape[0]
    oracle.serve(queries[:batch], backend=backend)  # warmup/compile
    if n_q % batch:  # the tail batch pads to different tile shapes — warm it too
        oracle.serve(queries[n_q - n_q % batch :], backend=backend)
    t0 = time.perf_counter()
    results = []
    for lo in range(0, n_q, batch):
        results.append(oracle.serve(queries[lo : lo + batch], backend=backend))
    dt = time.perf_counter() - t0
    return dt, np.concatenate(results)


def check_sample(g, queries: np.ndarray, pred: np.ndarray, n_check: int = 200) -> int:
    bad = 0
    for i in range(min(n_check, queries.shape[0])):
        u, v = int(queries[i, 0]), int(queries[i, 1])
        truth = bool(reachable_set(g, u)[v]) or u == v
        bad += truth != bool(pred[i])
    return bad


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="citeseer")
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--n-queries", type=int, default=100_000)
    ap.add_argument("--batch", type=int, default=4096)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="auto",
                    help="auto|host|dense|kernel, or 'all' to sweep")
    ap.add_argument("--no-bucketing", action="store_true",
                    help="disable length-bucketed micro-batching")
    ap.add_argument("--json-out", default=None,
                    help="write per-backend M-qps results to this JSON file")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="wave-granular build checkpoints; re-running with the "
                         "same flags resumes from the latest complete one")
    ap.add_argument("--checkpoint-every", type=int, default=16,
                    help="schedule boundaries between checkpoints")
    ap.add_argument("--inject-device-failure", type=int, default=None,
                    metavar="K",
                    help="fault-inject the K-th device dispatch of each serve "
                         "run; queries degrade to the host rung (counted, "
                         "never a wrong verdict)")
    args = ap.parse_args()

    backends = list(HOST_BACKENDS) if args.backend == "all" else [args.backend]
    for be in backends:
        if be != "auto":
            try:
                select_backend(be)
            except ValueError as e:
                ap.error(str(e))

    g, oracle = build(args)
    rng = np.random.default_rng(args.seed)
    queries = rng.integers(0, g.n, size=(args.n_queries, 2)).astype(np.int32)

    records = {}
    failed = False
    for be in backends:
        deg0 = dict(oracle.engine.degradation)
        if args.inject_device_failure is not None:
            # fresh plan per backend: occurrence counters live on the injector
            plan = inject.Injector(
                {"serve.device_dispatch": args.inject_device_failure})
            with inject.active(plan):
                dt, pred = serve_loop(oracle, queries, args.batch, be)
        else:
            dt, pred = serve_loop(oracle, queries, args.batch, be)
        stats = oracle.engine.last_stats
        mqps = args.n_queries / dt / 1e6
        print(
            f"[{stats['backend']}] served {args.n_queries} queries in {dt:.3f}s "
            f"({mqps:.2f} M qps; {dt / args.n_queries * 1e9:.0f} ns/query)  "
            f"prefiltered {stats['n_prefiltered']}/{stats['n_queries']} of last batch"
        )
        deg = {k: v - deg0[k] for k, v in oracle.engine.degradation.items()}
        if any(deg.values()):
            print(f"[{stats['backend']}] degradation: "
                  f"device->host={deg['device_to_host']} "
                  f"searched={deg['searched']} quarantined={deg['quarantined']}")
        bad = check_sample(g, queries, pred)
        n_check = min(200, args.n_queries)
        print(f"[{stats['backend']}] correctness sample: {n_check - bad}/{n_check} ok")
        failed |= bad > 0
        records[stats["backend"]] = {
            "mqps": round(mqps, 4),
            "ns_per_query": round(dt / args.n_queries * 1e9, 1),
            "bucketing": not args.no_bucketing,
            "sample_errors": bad,
            "degradation": dict(deg),
        }

    if args.json_out:
        payload = {
            "dataset": args.dataset,
            "scale": args.scale,
            "n": g.n,
            "m": g.m,
            "n_queries": args.n_queries,
            "batch": args.batch,
            "label_ints": oracle.total_label_size,
            "tier_widths": oracle.engine.widths,
            "jax_platform": __import__("jax").default_backend(),
            "note": "kernel backend runs the Pallas kernel in interpret mode off-TPU",
            "backends": records,
        }
        with open(args.json_out, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"wrote {args.json_out}")

    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
