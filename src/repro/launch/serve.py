"""Oracle serving driver: build the index, answer batched query streams.

  PYTHONPATH=src python -m repro.launch.serve --dataset citeseer --scale 0.02 \
      --n-queries 100000 --batch 4096

Builds Distribution-Labeling on the (synthetic analogue) dataset, then runs
the batched serve_step (device path) and reports throughput + correctness
against ground truth on a sample.
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core.distribution import distribution_labeling
from repro.core.query import serve_step
from repro.graph.generators import paper_dataset_analogue, random_dag
from repro.graph.reach import reachable_set


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="citeseer")
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--n-queries", type=int, default=100_000)
    ap.add_argument("--batch", type=int, default=4096)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    g = (
        paper_dataset_analogue(args.dataset, scale=args.scale)
        if args.dataset != "random"
        else random_dag(20000, 50000, seed=args.seed)
    )
    print(f"graph: n={g.n} m={g.m}")
    t0 = time.perf_counter()
    oracle = distribution_labeling(g)
    t_build = time.perf_counter() - t0
    print(
        f"DL build: {t_build:.2f}s  label ints={oracle.total_label_size} "
        f"(avg {oracle.total_label_size / g.n:.1f}/vertex)"
    )

    rng = np.random.default_rng(args.seed)
    queries = rng.integers(0, g.n, size=(args.n_queries, 2)).astype(np.int32)
    lo, li = oracle.device_labels()

    # warmup + timed batched serving
    q0 = jnp.asarray(queries[: args.batch])
    serve_step(lo, li, q0).block_until_ready()
    t0 = time.perf_counter()
    n_done = 0
    results = []
    while n_done < args.n_queries:
        qb = jnp.asarray(queries[n_done : n_done + args.batch])
        results.append(serve_step(lo, li, qb))
        n_done += qb.shape[0]
    results[-1].block_until_ready()
    dt = time.perf_counter() - t0
    print(
        f"served {args.n_queries} queries in {dt:.3f}s "
        f"({args.n_queries / dt / 1e6:.2f} M qps; "
        f"{dt / args.n_queries * 1e9:.0f} ns/query)"
    )

    # correctness sample
    pred = np.concatenate([np.asarray(r) for r in results])
    n_check = min(200, args.n_queries)
    bad = 0
    for i in range(n_check):
        u, v = int(queries[i, 0]), int(queries[i, 1])
        truth = bool(reachable_set(g, u)[v]) or u == v
        bad += truth != bool(pred[i])
    print(f"correctness sample: {n_check - bad}/{n_check} ok")
    if bad:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
