import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Hillclimb profiler: print the top-K HLO ops by result bytes for a cell.

  PYTHONPATH=src python -m repro.launch.hlo_top --arch gatedgcn --shape ogb_products

With no real-TPU trace available, the lowered IR *is* the profile (system
prompt §Pallas hints): big result tensors = big HBM traffic; the collective
list = the wire schedule.
"""
import argparse
import re
from collections import defaultdict

from repro.launch.hlo_analysis import _DTYPE_BYTES, _SHAPE_RE

_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\(")


def top_ops(hlo_text: str, k: int = 20):
    rows = []
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rtype, opcode = m.groups()
        if opcode in ("parameter", "constant", "tuple", "get-tuple-element"):
            continue
        b = sum(
            _DTYPE_BYTES[s.group(1)] * (eval(s.group(2).replace(",", "*")) if s.group(2) else 1)
            for s in _SHAPE_RE.finditer(rtype)
        )
        rows.append((b, opcode, name, rtype[:60]))
    rows.sort(reverse=True)
    agg = defaultdict(int)
    for b, opcode, _, _ in rows:
        agg[opcode] += b
    return rows[:k], sorted(agg.items(), key=lambda kv: -kv[1])[:12]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--top", type=int, default=20)
    args = ap.parse_args()

    from repro.configs import get_arch
    from repro.launch.dryrun import make_variant_mesh

    mesh = make_variant_mesh(args.mesh, args.variant)
    cell = get_arch(args.arch).cells(args.shape, mesh, args.variant)
    with mesh:
        compiled = cell.lower().compile()
    hlo = compiled.as_text()
    rows, agg = top_ops(hlo, args.top)
    print(f"== top {args.top} ops by result bytes ({args.arch}/{args.shape}/{args.variant}) ==")
    for b, opcode, name, rtype in rows:
        print(f"{b/1e6:10.1f} MB  {opcode:22s} {name[:40]:40s} {rtype}")
    print("\n== bytes by opcode ==")
    for opcode, b in agg:
        print(f"{b/1e9:10.3f} GB  {opcode}")


if __name__ == "__main__":
    main()
