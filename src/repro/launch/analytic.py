"""Analytic roofline numerators for LM cells.

WHY: XLA's HloCostAnalysis counts every while-loop body ONCE. LM cells wrap
the layer stack in lax.scan and training adds a grad-accumulation scan, so
compiled.cost_analysis() underreports FLOPs/bytes by the (static) trip
counts. GNN / recsys / oracle cells are loop-free (python-unrolled) and use
the HLO numbers directly; LM cells use these analytic models instead, with
the raw HLO values recorded alongside for audit (EXPERIMENTS.md SS Roofline
documents the deviation).

All numbers are PER DEVICE PER STEP. Conventions:
  train FLOPs = 3x forward (fwd 2NT, bwd 4NT)
  causal attention averages T_eff = S/2 keys per query (window caps it)
  bf16 weights/activations (2B), fp32 optimizer (4B)
"""
from __future__ import annotations

from typing import Dict

from repro.models.transformer import LMConfig


def _attn_dims(cfg: LMConfig):
    if cfg.mla is not None:
        qk = cfg.mla.qk_nope_dim + cfg.mla.qk_rope_dim
        return cfg.n_heads, qk, cfg.mla.v_dim
    return cfg.n_heads, cfg.head_dim, cfg.head_dim


def lm_train_terms(
    cfg: LMConfig, batch: int, seq: int, n_accum: int, dp: int, tp: int
) -> Dict[str, float]:
    tokens = batch * seq
    chips = dp * tp
    n_act = cfg.active_param_count()
    H, dqk, dv = _attn_dims(cfg)

    # ---- compute ----
    t_eff = seq / 2 if cfg.window is None else min(seq / 2, cfg.window)
    attn_fwd = 2 * cfg.n_layers * H * (dqk + dv) * t_eff * tokens  # QK^T + PV
    flops_total = 3 * (2 * n_act * tokens + attn_fwd)
    flops_dev = flops_total / chips

    # ---- memory ----
    pbytes_dev = 2 * cfg.param_count() / tp            # bf16 weights per device
    micro_tokens_dev = tokens / n_accum / dp
    # weights stream fwd+bwd each microstep (3 passes), grads written once
    w_traffic = n_accum * 3 * pbytes_dev + 2 * pbytes_dev
    # optimizer: read+write master/mu/nu fp32 (ZeRO-sharded over chips)
    opt_traffic = 6 * 4 * cfg.param_count() / chips * 2
    # activations: ~12 residual-stream touches per layer with remat (+logits)
    act_traffic = n_accum * (
        12 * micro_tokens_dev * cfg.d_model * cfg.n_layers * 2
        + 2 * micro_tokens_dev * cfg.vocab / tp * 4
    )
    bytes_dev = w_traffic + opt_traffic + act_traffic

    # ---- collectives ----
    # TP: 2 all-reduces per layer fwd + 2 bwd, activation-sized
    tp_coll = 0.0
    if tp > 1:
        tp_coll = n_accum * 4 * cfg.n_layers * micro_tokens_dev * cfg.d_model * 2
    # DP: gradient reduce-scatter + all-gather (bf16, TP-sharded grads)
    dp_coll = 0.0
    if dp > 1:
        dp_coll = 2 * 2 * cfg.param_count() / tp
    # EP: MoE dispatch/combine all-to-all (2x tokens*d each way)
    ep_coll = 0.0
    if cfg.moe is not None and tp > 1:
        ep_coll = n_accum * 2 * cfg.n_layers * 2 * micro_tokens_dev * cfg.d_model * 2
    coll_dev = tp_coll + dp_coll + ep_coll

    return dict(flops=flops_dev, bytes=bytes_dev, coll=coll_dev, model_flops=flops_total)


def lm_prefill_terms(cfg: LMConfig, batch: int, seq: int, dp: int, tp: int) -> Dict[str, float]:
    tokens = batch * seq
    chips = dp * tp
    n_act = cfg.active_param_count()
    H, dqk, dv = _attn_dims(cfg)
    t_eff = seq / 2 if cfg.window is None else min(seq / 2, cfg.window)
    attn_fwd = 2 * cfg.n_layers * H * (dqk + dv) * t_eff * tokens
    flops_total = 2 * n_act * tokens + attn_fwd
    tokens_dev = tokens / dp
    pbytes_dev = 2 * cfg.param_count() / tp
    bytes_dev = (
        pbytes_dev                                   # weights streamed once
        + 8 * tokens_dev * cfg.d_model * cfg.n_layers * 2
        + 2 * tokens_dev * cfg.vocab / tp * 4 / seq  # last-position logits only
    )
    coll_dev = 2 * cfg.n_layers * tokens_dev * cfg.d_model * 2 * (2 if tp > 1 else 0)
    return dict(flops=flops_total / chips, bytes=bytes_dev, coll=coll_dev,
                model_flops=flops_total)


def lm_decode_terms(cfg: LMConfig, batch: int, cache_len: int, dp: int, tp: int) -> Dict[str, float]:
    chips = dp * tp
    n_act = cfg.active_param_count()
    H, dqk, dv = _attn_dims(cfg)
    t_eff = cache_len if cfg.window is None else min(cache_len, cfg.window)
    # per new token: weights matmuls + attention over the cache
    attn = 2 * cfg.n_layers * H * (dqk + dv) * t_eff * batch
    flops_total = 2 * n_act * batch + attn
    # memory: whole weights + cache read dominate (batch tiny)
    pbytes_dev = 2 * cfg.param_count() / tp
    if cfg.mla is not None:
        cache_row = cfg.mla.kv_lora + cfg.mla.qk_rope_dim
        cache_bytes = cfg.n_layers * batch * t_eff * cache_row * 2
    else:
        cache_bytes = cfg.n_layers * batch * t_eff * cfg.n_kv_heads * cfg.head_dim * 2 * 2
    bytes_dev = pbytes_dev + cache_bytes / chips
    coll_dev = (2 * cfg.n_layers * (batch / max(dp, 1)) * cfg.d_model * 2) * (2 if tp > 1 else 0)
    return dict(flops=flops_total / chips, bytes=bytes_dev, coll=coll_dev,
                model_flops=flops_total)
