import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

The two lines above MUST stay first — jax locks the device count at first
init, and the production meshes need 512 placeholder devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-7b
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-7b \
      --shape train_4k --mesh multi --variant opt

Each cell writes experiments/dryrun/<arch>__<shape>__<mesh>[__variant].json:
memory_analysis, cost_analysis FLOPs/bytes, per-kind collective bytes, and
the three roofline terms.
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ALL_ARCHS, get_arch
from repro.launch.hlo_analysis import collective_bytes, roofline_terms
from repro.launch.mesh import make_production_mesh


def make_variant_mesh(mesh_kind: str, variant: str):
    """The REQUIRED meshes are (16,16) and (2,16,16). Hillclimb variants may
    remap the same 256 chips to a different logical (data, model) split —
    'a different sharding scheme' per the perf methodology."""
    if variant.startswith("tp"):
        tp = int(variant[2:].split("-")[0])
        assert 256 % tp == 0
        return jax.make_mesh((256 // tp, tp), ("data", "model"))
    return make_production_mesh(multi_pod=(mesh_kind == "multi"))


def run_cell(arch_id: str, shape: str, mesh_kind: str, variant: str, out_dir: str) -> dict:
    mesh = make_variant_mesh(mesh_kind, variant)
    n_chips = mesh.devices.size
    mod = get_arch(arch_id)
    cell = mod.cells(shape, mesh, variant)
    tag = f"{arch_id}__{shape}__{mesh_kind}" + (f"__{variant}" if variant != "baseline" else "")
    rec: dict = dict(
        arch=arch_id, shape=shape, mesh=mesh_kind, variant=variant,
        n_chips=int(n_chips), kind=cell.kind, meta=cell.meta,
    )
    if cell.skip:
        rec["status"] = "skipped"
        rec["skip_reason"] = cell.skip
        _write(out_dir, tag, rec)
        return rec

    t0 = time.perf_counter()
    try:
        with mesh:
            lowered = cell.lower()
            rec["lower_s"] = time.perf_counter() - t0
            t1 = time.perf_counter()
            compiled = lowered.compile()
            rec["compile_s"] = time.perf_counter() - t1

            mem = compiled.memory_analysis()
            rec["memory"] = {
                k: int(getattr(mem, k))
                for k in (
                    "argument_size_in_bytes",
                    "output_size_in_bytes",
                    "temp_size_in_bytes",
                    "generated_code_size_in_bytes",
                )
                if hasattr(mem, k)
            }
            cost = compiled.cost_analysis()
            flops = float(cost.get("flops", 0.0))
            bytes_accessed = float(cost.get("bytes accessed", 0.0))
            rec["cost"] = {"flops": flops, "bytes_accessed": bytes_accessed}

            hlo = compiled.as_text()
            coll = collective_bytes(hlo)
            rec["collectives"] = coll

            # cost_analysis of the SPMD-partitioned module reports PER-DEVICE
            # flops/bytes; collective bytes parsed from HLO are also
            # per-device. Roofline terms therefore divide by 1 chip.
            # CAVEAT: HloCostAnalysis counts while-loop bodies ONCE. LM cells
            # (scan over layers + grad accumulation) therefore carry analytic
            # per-device terms in meta['analytic']; loop-free families use
            # the HLO numbers directly. Both are recorded.
            rec["roofline_hlo"] = roofline_terms(flops, bytes_accessed, coll["total"], 1)
            ana = cell.meta.get("analytic")
            if ana is not None:
                rec["roofline"] = roofline_terms(ana["flops"], ana["bytes"], ana["coll"], 1)
                rec["roofline"]["source"] = "analytic(loop-corrected)"
                rec["model_flops"] = ana.get("model_flops")
            else:
                rec["roofline"] = dict(rec["roofline_hlo"])
                rec["roofline"]["source"] = "hlo"
                rec["model_flops"] = cell.meta.get("model_flops")
            rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    _write(out_dir, tag, rec)
    return rec


def _write(out_dir: str, tag: str, rec: dict) -> None:
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{tag}.json"), "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=[None, "single", "multi"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ALL_ARCHS)
    meshes = [args.mesh] if args.mesh else ["single", "multi"]
    n_ok = n_skip = n_err = 0
    for arch_id in archs:
        mod = get_arch(arch_id)
        shapes = [args.shape] if args.shape else list(mod.SHAPES)
        for shape in shapes:
            for mesh_kind in meshes:
                tag = f"{arch_id}__{shape}__{mesh_kind}" + (
                    f"__{args.variant}" if args.variant != "baseline" else ""
                )
                path = os.path.join(args.out, f"{tag}.json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        rec = json.load(f)
                else:
                    rec = run_cell(arch_id, shape, mesh_kind, args.variant, args.out)
                status = rec["status"]
                n_ok += status == "ok"
                n_skip += status == "skipped"
                n_err += status == "error"
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (
                        f"dom={r['dominant']} comp={r['compute_s']:.2e}s "
                        f"mem={r['memory_s']:.2e}s coll={r['collective_s']:.2e}s "
                        f"compile={rec.get('compile_s', 0):.1f}s"
                    )
                elif status == "error":
                    extra = rec["error"][:160]
                print(f"[{status:7s}] {tag}  {extra}", flush=True)
    print(f"\nDRYRUN SUMMARY: ok={n_ok} skipped={n_skip} error={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
