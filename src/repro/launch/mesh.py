"""Production mesh definitions.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — dryrun.py must set XLA_FLAGS before any
device query, and tests must see the real single-CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes_of(mesh) -> tuple:
    """The DP axes for this mesh ('pod' folds into DP when present)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def data_parallel_size(mesh) -> int:
    n = 1
    for a in data_axes_of(mesh):
        n *= mesh.shape[a]
    return n
