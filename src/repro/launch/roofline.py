"""Roofline report generator: experiments/dryrun/*.json -> markdown table.

  PYTHONPATH=src python -m repro.launch.roofline [--mesh single] [--out experiments/roofline.md]

Per (arch x shape): the three roofline terms (seconds/step/device), dominant
bottleneck, MODEL_FLOPS (6ND-style useful work), the MODEL/HLO ratio, and a
one-line lever for the dominant term.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict

from repro.launch.hlo_analysis import HBM_BW, LINK_BW, PEAK_FLOPS


def model_flops_of(rec: Dict) -> float:
    """Whole-step useful FLOPs. LM cells carry it (6ND + attention);
    other families get family-level estimates from meta dims."""
    if rec.get("model_flops"):
        return float(rec["model_flops"])
    meta = rec.get("meta", {})
    arch, kind = rec["arch"], rec.get("kind")
    mult = 3 if kind == "train" else 1  # train = 3x forward
    if arch == "gcn-cora":
        n, m, d = meta["n_nodes"], meta["n_edges"], meta["d_feat"]
        return mult * 2 * (n * d * 16 + m * 16 + n * 16 * 7)
    if arch == "gatedgcn":
        n, m, d = meta["n_nodes"], meta["n_edges"], 70
        return mult * 16 * 2 * (5 * n * d * d + 3 * m * d)
    if arch == "schnet":
        n, m = meta["n_nodes"], meta["n_edges"]
        d, rbf = 64, 300
        return mult * 3 * 2 * (m * (rbf * d + 2 * d * d) + 2 * n * d * d)
    if arch == "graphcast":
        n_m, m_mesh = meta["n_mesh"], meta["m_mesh"]
        n_g = meta["n_grid"]
        d = 512
        proc = 16 * 2 * (m_mesh * (2 * d * d + d * d) + n_m * 2 * d * d)
        encdec = 2 * (4 * n_g * (2 * d * d)) + 2 * n_g * 227 * d
        return mult * (proc + encdec)
    if arch == "xdeepfm":
        B = meta.get("batch", meta.get("n_candidates", 1))
        m, D = 39, 10
        cin = 0
        h_prev = m
        for h in (200, 200, 200):
            cin += B * (h_prev * m * D + h * h_prev * m * D) * 2
            h_prev = h
        mlp = B * (m * D * 400 + 400 * 400 + 400) * 2
        return mult * (cin + mlp)
    if arch == "reachability-oracle":
        if rec["shape"].startswith("serve"):
            B, L = meta["queries"], meta["l_max"]
            return B * L * L  # compare ops
        n, m, L = meta["n"], meta["m"], meta["l_max"]
        return 64 * (n * L + m)  # per BFS level: prune lookups + edge sweep
    return 0.0


LEVERS = {
    ("lm", "compute"): "already MXU-bound: raise per-chip utilization via larger "
                       "microbatch / fused qkv; beyond that it is roofline",
    ("lm", "memory"): "cut HBM traffic: fuse attention chunks (flash kernel), "
                      "raise arithmetic intensity with bigger microbatches, "
                      "bf16 optimizer reads",
    ("lm", "collective"): "overlap TP all-reduces with compute (async collective "
                          "scheduling), shrink DP grad payload via int8 compression",
    ("gnn", "compute"): "MXU-align feature dims (pad to 128), batch small matmuls",
    ("gnn", "memory"): "edge-gather traffic dominates: degree-sort + ELL tiles "
                       "(ell_spmm kernel), cache hub features in VMEM",
    ("gnn", "collective"): "vertex-cut partitioning to localize segment-sums; "
                           "reduce-scatter instead of all-reduce on node grads",
    ("recsys", "memory"): "embedding row gathers dominate: row-shard tables + "
                          "batch dedup of repeated ids",
    ("recsys", "compute"): "CIN outer-product einsum is the hotspot: reorder to "
                           "contract D first, fuse ReLU",
    ("recsys", "collective"): "table gathers cross shards: hash-shard by field "
                              "to localize lookups",
    ("oracle", "memory"): "label rows stream once per query batch: sort queries "
                          "by source vertex to reuse gathered rows",
    ("oracle", "compute"): "L^2 compare is VPU-bound: bit-pack labels "
                           "(32x fewer lane ops, bitset_mm-style)",
    ("oracle", "collective"): "query->label-shard routing: sort queries by shard "
                              "to turn gathers into all-to-all",
}

FAMILY = {
    "h2o-danube-1.8b": "lm", "granite-3-2b": "lm", "deepseek-7b": "lm",
    "deepseek-v2-lite-16b": "lm", "granite-moe-1b-a400m": "lm",
    "gcn-cora": "gnn", "graphcast": "gnn", "schnet": "gnn", "gatedgcn": "gnn",
    "xdeepfm": "recsys", "reachability-oracle": "oracle",
}


def build_report(dryrun_dir: str, mesh: str, variant_suffix: str = "") -> str:
    rows = []
    pattern = os.path.join(dryrun_dir, f"*__{mesh}{variant_suffix}.json")
    for path in sorted(glob.glob(pattern)):
        base = os.path.basename(path)
        if variant_suffix == "" and base.count("__") != 2:
            continue  # skip variant files in the baseline table
        with open(path) as f:
            rec = json.load(f)
        if rec["status"] == "skipped":
            rows.append((rec["arch"], rec["shape"], None, rec["skip_reason"]))
            continue
        if rec["status"] != "ok":
            rows.append((rec["arch"], rec["shape"], None, "ERROR: " + rec["error"][:80]))
            continue
        r = rec["roofline"]
        n_chips = rec["n_chips"]
        mf = model_flops_of(rec)
        hlo_flops_dev = r["compute_s"] * PEAK_FLOPS
        ratio = (mf / n_chips) / hlo_flops_dev if hlo_flops_dev > 0 else float("nan")
        fam = FAMILY[rec["arch"]]
        lever = LEVERS.get((fam, r["dominant"]), "")
        rows.append((rec["arch"], rec["shape"], dict(
            comp=r["compute_s"], mem=r["memory_s"], coll=r["collective_s"],
            dom=r["dominant"], bound=r["bound_s"], source=r.get("source", "hlo"),
            model_flops=mf, ratio=ratio, lever=lever,
            frac=r["compute_s"] / r["bound_s"] if r["bound_s"] > 0 else 0.0,
        ), None))

    lines = [
        f"### Roofline — {mesh} mesh (per-device seconds/step; v5e: "
        f"{PEAK_FLOPS/1e12:.0f} TF bf16, {HBM_BW/1e9:.0f} GB/s HBM, "
        f"{LINK_BW/1e9:.0f} GB/s link)",
        "",
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "roofline frac (comp/bound) | MODEL_FLOPS | MODEL/HLO | src | lever for dominant term |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch, shape, d, note in rows:
        if d is None:
            lines.append(f"| {arch} | {shape} | — | — | — | skipped | — | — | — | — | {note} |")
            continue
        lines.append(
            f"| {arch} | {shape} | {d['comp']:.2e} | {d['mem']:.2e} | {d['coll']:.2e} "
            f"| **{d['dom']}** | {d['frac']:.2f} | {d['model_flops']:.2e} "
            f"| {d['ratio']:.2f} | {d['source'][:8]} | {d['lever']} |"
        )
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--out", default="experiments/roofline.md")
    args = ap.parse_args()
    report = build_report(args.dryrun_dir, args.mesh)
    with open(args.out, "w") as f:
        f.write(report + "\n")
    print(report)


if __name__ == "__main__":
    main()
