"""The oracle serve subsystem: QueryEngine + batching planner + prefilters
+ the overload-safe serving daemon (admission control, deadline shedding,
circuit-broken degradation) and its open-loop workload driver.

Every query path in the repo routes through ``QueryEngine``; future serving
work (caching, async, new shardings) lands here.
"""
from repro.serve.budget import (
    BudgetController,
    PressureConfig,
    TruncatedStore,
    rank_cut_for_budget,
    truncate_store,
)
from repro.serve.daemon import CircuitBreaker, DaemonConfig, ServeDaemon, ShedError
from repro.serve.engine import (
    BACKENDS,
    QueryEngine,
    intersect_rows,
    make_hop_sharded_serve_step,
    make_sharded_serve_step,
    select_backend,
    serve_step,
)
from repro.serve.openloop import run_open_loop
from repro.serve.planner import BatchPlan, TierPlan, plan_batch, tier_widths
from repro.serve.prefilter import PrefilterResult, apply_prefilters, topo_levels

__all__ = [
    "BACKENDS",
    "BudgetController",
    "PressureConfig",
    "TruncatedStore",
    "rank_cut_for_budget",
    "truncate_store",
    "CircuitBreaker",
    "DaemonConfig",
    "ServeDaemon",
    "ShedError",
    "run_open_loop",
    "QueryEngine",
    "select_backend",
    "serve_step",
    "intersect_rows",
    "make_sharded_serve_step",
    "make_hop_sharded_serve_step",
    "BatchPlan",
    "TierPlan",
    "plan_batch",
    "tier_widths",
    "PrefilterResult",
    "apply_prefilters",
    "topo_levels",
]
