"""The oracle serve subsystem: QueryEngine + batching planner + prefilters.

Every query path in the repo routes through ``QueryEngine``; future serving
work (caching, async, new shardings) lands here.
"""
from repro.serve.engine import (
    BACKENDS,
    QueryEngine,
    intersect_rows,
    make_hop_sharded_serve_step,
    make_sharded_serve_step,
    select_backend,
    serve_step,
)
from repro.serve.planner import BatchPlan, TierPlan, plan_batch, tier_widths
from repro.serve.prefilter import PrefilterResult, apply_prefilters, topo_levels

__all__ = [
    "BACKENDS",
    "QueryEngine",
    "select_backend",
    "serve_step",
    "intersect_rows",
    "make_sharded_serve_step",
    "make_hop_sharded_serve_step",
    "BatchPlan",
    "TierPlan",
    "plan_batch",
    "tier_widths",
    "PrefilterResult",
    "apply_prefilters",
    "topo_levels",
]
