"""Pre-intersection short-circuits (O'Reach-style cheap observations).

Hanauer et al. (2020) show that most reachability queries on real graphs can
be decided by O(1) pre-filters before any label work; the intersection then
only runs on the residue. Four filters, all vectorized and backend-agnostic
(numpy on host, jnp inside jitted serve steps — written against the common
array API so the same function traces on device):

  * u == v                      -> True  (reflexive; same condensation vertex
                                          also covers same-SCC original pairs.
                                          The engine maps original ids through
                                          its owner's comp_source at CALL time
                                          — never a comp array cached at
                                          engine construction — so dynamic
                                          SCC merges can't serve stale
                                          same-SCC verdicts)
  * out_len[u] == 0             -> False (u reaches nothing but itself)
  * in_len[v] == 0              -> False (nothing but v reaches v)
  * level[u] >= level[v]        -> False (topological-level filter: every
                                          edge strictly increases the level,
                                          so reachability implies
                                          level[u] < level[v])
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.csr import CSRGraph, topo_levels as _topo_levels_np


def topo_levels(g: CSRGraph) -> np.ndarray:
    """int32[n] longest-path level of each DAG vertex (sources = 0).

    u -> v (u != v) implies level[u] < level[v]; the contrapositive is the
    serve-path filter.  Vectorized in ``graph.csr.topo_levels`` (the scalar
    python walk this used to do was a visible slice of every dynamic-oracle
    rebuild publish).
    """
    return _topo_levels_np(g)


@dataclasses.dataclass(frozen=True)
class PrefilterResult:
    decided: np.ndarray  # bool[B] — query answered without intersection
    value: np.ndarray    # bool[B] — the answer where decided


def apply_prefilters(queries, out_len, in_len, level=None) -> PrefilterResult:
    """Decide what can be decided before gathering label rows.

    queries: int[B, 2] in oracle (condensation) id space. ``out_len``/
    ``in_len``/``level`` are per-vertex int arrays; ``level`` is optional.
    Works on numpy and jnp inputs alike.
    """
    u, v = queries[:, 0], queries[:, 1]
    same = u == v
    dead = (out_len[u] == 0) | (in_len[v] == 0)
    if level is not None:
        dead = dead | (level[u] >= level[v])
    # `same` wins over `dead` (level[u] >= level[v] always holds for u == v)
    return PrefilterResult(decided=same | dead, value=same)
