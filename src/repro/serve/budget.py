"""Memory-budgeted serving tier: truncated rank-prefix labels under a hard
byte budget, with a live pressure-driven budget governor.

The rank-ordered labels (§5.2 construction order; ``core.oracle``) have a
robustness property the serve stack never exploited: every row is sorted by
hop RANK, so the front of the row holds the hubs recorded by the most
labels and the tail holds the rare, highest-rank hops each recorded by
almost nothing.  A hard index-size budget can therefore be met by cutting
the highest-rank tail of every row — FERRARI-style (Seufert et al.,
arXiv 1211.3375: exact + truncated per-vertex entries under an index-size
restriction, online search as the escape hatch) — without ever risking a
wrong answer:

  * the cut is a single global **rank threshold** θ: an entry survives iff
    its rank value is < θ.  Rows are rank-sorted, so the cut is a per-vertex
    PREFIX — exactly the order §5.2 distributed the entries in, which means
    the truncated store is precisely the index a construction run stopped at
    rank θ would have produced;
  * verdicts become three-valued.  A hit on surviving prefixes is a proven
    YES (every surviving entry is a real label entry).  A miss is a proven
    NO unless BOTH rows were truncated: with a uniform threshold a kept
    entry (rank < θ) can never equal a dropped entry (rank >= θ), so the
    lost intersection lives entirely in dropped-x-dropped — it can only be
    non-empty when both sides dropped something.  The residue — miss with
    both rows cut — is UNCERTAIN and routes down the serve engine's
    existing degradation ladder to the exact bounded bidirectional search
    (``baselines.online_search.bidirectional_query``).  Wrong answers are
    impossible at any budget;
  * budgets are **monotone**: a smaller budget gives a smaller θ, kept
    prefixes shrink, and the per-query uncertain set only grows — so the
    uncertain rate is non-increasing in budget (gated in BENCH_serve).

``BudgetController`` is the live governor: it owns the retained full store
(or a ``persist`` snapshot path on memory-starved hosts), re-truncates IN
PLACE when a pressure signal crosses the watermark — a numpy prefix cut
over the retained store, never a rebuild — and steps the budget back up
with breaker-style hysteresis once pressure stays below the low watermark.
The serving daemon polls it between dispatch ticks, so a step never drops
an in-flight batch: batches capture their label view at entry.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.ft import inject
from repro.graph.csr import INVALID
from repro.obs import metrics, trace
from repro.obs.state import ON

_PAD_MULT = 8   # row padding multiple shared with finalize_labels

_M_BUDGET = metrics.gauge(
    "budget_bytes", "current label byte budget (0 = unbudgeted full store)")
_M_RESIDENT = metrics.gauge(
    "budget_resident_bytes", "resident truncated label bytes under the budget")
_M_STEPS = metrics.counter(
    "budget_pressure_steps_total", "pressure-driven budget steps, by direction",
    labelnames=("direction",))
_STEP_DOWN = _M_STEPS.labels(direction="down")
_STEP_UP = _M_STEPS.labels(direction="up")
_M_RETRUNC = metrics.counter(
    "budget_retruncations_total", "in-place re-truncations of the label store")


def label_bytes(oracle) -> int:
    """Resident bytes of the dense label matrices (what device memory pays)."""
    return int(oracle.L_out.nbytes + oracle.L_in.nbytes)


def pack_mask(mask: np.ndarray) -> np.ndarray:
    """bool[n] -> packed uint8[ceil(n/8)] (the persisted mask layout)."""
    return np.packbits(np.asarray(mask, dtype=bool))


def unpack_mask(packed: np.ndarray, n: int) -> np.ndarray:
    """Inverse of ``pack_mask``."""
    return np.unpackbits(np.asarray(packed, dtype=np.uint8), count=int(n)).astype(bool)


@dataclasses.dataclass(frozen=True)
class TruncatedStore:
    """An immutable rank-prefix truncation of a ReachabilityOracle.

    ``oracle`` is a real (smaller) ``ReachabilityOracle`` — same dense
    layout, same memoized device upload — whose rows are the rank-< θ
    prefixes of the full store's rows.  ``truncated_out/in`` mark the rows
    that lost entries; the serve engine's three-valued verdict logic reads
    them (see module docstring for why a miss needs BOTH marks to be
    uncertain).  ``budget_bytes`` is the budget the cut was derived from;
    ``resident_bytes`` what the truncated matrices actually occupy."""

    oracle: "object"            # ReachabilityOracle duck type
    truncated_out: np.ndarray   # bool[n] — L_out(v) lost entries
    truncated_in: np.ndarray    # bool[n]
    rank_cut: int               # θ: kept entries have rank value < θ
    budget_bytes: int
    resident_bytes: int
    dropped_ints: int           # label ints the cut removed

    @property
    def n(self) -> int:
        return int(self.oracle.n)

    @property
    def any_truncated(self) -> bool:
        return bool(self.truncated_out.any() or self.truncated_in.any())

    def packed_masks(self) -> tuple:
        """(packed_out, packed_in) uint8 bit masks — the persisted form."""
        return pack_mask(self.truncated_out), pack_mask(self.truncated_in)


def _snap(x: int) -> int:
    return max(((int(x) + _PAD_MULT - 1) // _PAD_MULT) * _PAD_MULT, _PAD_MULT)


def _cut_lens(mat: np.ndarray, lens: np.ndarray, theta: int) -> np.ndarray:
    """Per-row surviving-prefix length at rank threshold ``theta``.

    Rows hold their valid entries first (sorted ascending by rank value,
    INVALID = -1 padding after), so "count of entries < theta" IS the
    prefix length."""
    kept = ((mat != INVALID) & (mat < theta)).sum(axis=1).astype(np.int32)
    return np.minimum(kept, lens)


def _resident_at(oracle, theta: int) -> int:
    """Dense-layout bytes of the store truncated at ``theta``."""
    co = _cut_lens(oracle.L_out, oracle.out_len, theta)
    ci = _cut_lens(oracle.L_in, oracle.in_len, theta)
    wo = _snap(int(co.max()) if co.size else 0)
    wi = _snap(int(ci.max()) if ci.size else 0)
    return int(oracle.n * (wo + wi) * np.dtype(np.int32).itemsize)


def rank_cut_for_budget(oracle, budget_bytes: int) -> int:
    """Largest rank threshold θ whose truncated dense store fits the budget.

    Resident bytes are monotone non-decreasing in θ (prefixes only grow),
    so this is a binary search over θ in [0, n]; θ == n keeps everything.
    The floor θ = 0 empties every row — still exact (every non-structural
    verdict routes to the search rung), just slow: a budget too small for
    even one label column degrades to online search, it never lies."""
    n = int(oracle.n)
    budget_bytes = int(budget_bytes)
    if _resident_at(oracle, n) <= budget_bytes:
        return n
    lo, hi = 0, n          # invariant: resident(lo) <= budget < resident(hi)
    if _resident_at(oracle, 0) > budget_bytes:
        return 0
    while hi - lo > 1:
        mid = (lo + hi) // 2
        if _resident_at(oracle, mid) <= budget_bytes:
            lo = mid
        else:
            hi = mid
    return lo


def truncate_store(oracle, budget_bytes: Optional[int] = None,
                   rank_cut: Optional[int] = None) -> TruncatedStore:
    """Cut the highest-rank tail of every row to meet ``budget_bytes``
    (or an explicit ``rank_cut`` θ).  Pure numpy over the retained full
    store — this is the "re-truncate without a rebuild" primitive."""
    from repro.core.oracle import ReachabilityOracle

    if rank_cut is None:
        if budget_bytes is None:
            raise ValueError("truncate_store needs budget_bytes or rank_cut")
        rank_cut = rank_cut_for_budget(oracle, budget_bytes)
    theta = int(rank_cut)

    def _side(mat, lens):
        cut = _cut_lens(mat, lens, theta)
        width = _snap(int(cut.max()) if cut.size else 0)
        new = mat[:, :width].copy()
        # kill everything past each row's surviving prefix
        cols = np.arange(width)[None, :]
        new[cols >= cut[:, None]] = INVALID
        return new, cut

    L_out, out_cut = _side(oracle.L_out, oracle.out_len)
    L_in, in_cut = _side(oracle.L_in, oracle.in_len)
    truncated = ReachabilityOracle(
        L_out=L_out, L_in=L_in, out_len=out_cut, in_len=in_cut,
        hop_rank=oracle.hop_rank,
    )
    dropped = int((oracle.out_len - out_cut).sum() + (oracle.in_len - in_cut).sum())
    return TruncatedStore(
        oracle=truncated,
        truncated_out=out_cut < oracle.out_len,
        truncated_in=in_cut < oracle.in_len,
        rank_cut=theta,
        budget_bytes=int(budget_bytes) if budget_bytes is not None
        else label_bytes(truncated),
        resident_bytes=label_bytes(truncated),
        dropped_ints=dropped,
    )


# ------------------------------------------------------------- controller


@dataclasses.dataclass
class PressureConfig:
    """Knobs for the live pressure loop (breaker-style hysteresis)."""

    watermark_bytes: int                  # step DOWN while signal > this
    low_watermark_frac: float = 0.7       # step UP once signal < frac * mark
    step_factor: float = 0.5              # each step multiplies the budget
    min_budget_bytes: int = 4096          # floor the governor never cuts past
    recovery_ticks: int = 3               # consecutive calm ticks before up
    check_interval_s: float = 0.05        # daemon poll period

    @property
    def low_watermark_bytes(self) -> int:
        return int(self.watermark_bytes * self.low_watermark_frac)


class BudgetController:
    """Live budget governor for one QueryEngine.

    Owns (a) the retained FULL oracle — or, on hosts too small to retain
    it, a ``persist`` snapshot path to reload from — and (b) the current
    byte budget.  ``apply`` re-truncates the retained store in place (a
    numpy prefix cut, never a rebuild) and swaps the result into the
    engine; ``tick`` runs the pressure state machine:

        signal > watermark          -> step the budget DOWN by step_factor
        signal < low watermark for  -> step the budget back UP (un-step),
        ``recovery_ticks`` ticks       all the way to the full store

    The hysteresis gap (watermark vs low watermark x recovery ticks) is the
    breaker idiom: a signal bouncing on the watermark cannot flap the store.
    ``pressure_source`` abstracts the signal — default is the engine's own
    resident label bytes, production wires an RSS/HBM probe, tests and the
    chaos driver inject a scripted source."""

    def __init__(
        self,
        engine,
        budget_bytes: Optional[int] = None,
        pressure: Optional[PressureConfig] = None,
        pressure_source: Optional[Callable[[], float]] = None,
        full_oracle=None,
        snapshot_path: Optional[str] = None,
        retain_full: bool = True,
    ):
        self.engine = engine
        self._full = full_oracle if full_oracle is not None else engine.oracle
        self.snapshot_path = snapshot_path
        if not retain_full:
            if snapshot_path is None:
                raise ValueError(
                    "retain_full=False needs snapshot_path: stepping the "
                    "budget back up must have a full store to cut from")
            self._full = None
        self.full_bytes = (label_bytes(self._full) if self._full is not None
                           else None)
        self.budget_bytes = None if budget_bytes is None else int(budget_bytes)
        self.pressure = pressure
        self.pressure_source = pressure_source
        self._calm_ticks = 0
        self._step_depth = 0       # how many pressure step-downs are active
        self._configured = self.budget_bytes   # the operator-set budget
        self.retruncations = 0
        self.steps_down = 0
        self.steps_up = 0
        if self.budget_bytes is not None:
            self.apply(self.budget_bytes)

    # ------------------------------------------------------------- store ops

    def full_oracle(self):
        """The full store: retained, or reloaded from the snapshot."""
        if self._full is None:
            from repro.persist import load_oracle

            self._full = load_oracle(self.snapshot_path, strict=True)
            self.full_bytes = label_bytes(self._full)
        return self._full

    def resident_bytes(self) -> int:
        """Bytes the engine's served label matrices currently occupy."""
        store = getattr(self.engine, "budget_store", None)
        if store is not None:
            return store.resident_bytes
        return label_bytes(self.engine.oracle)

    def apply(self, budget_bytes: Optional[int]) -> Optional[TruncatedStore]:
        """Re-truncate to ``budget_bytes`` and swap the store into the
        engine (None = restore the full store).  In place: the cut runs
        over the retained full store, no label construction."""
        inject.fire("serve.retruncate", budget=budget_bytes)
        self.budget_bytes = None if budget_bytes is None else int(budget_bytes)
        if budget_bytes is None or (
                self.full_bytes is not None and budget_bytes >= self.full_bytes):
            self.engine.set_budget(None)
            _M_BUDGET.set(0)
            _M_RESIDENT.set(label_bytes(self.engine.oracle))
            return None
        sp = (trace.span("retruncate", cat="budget",
                         args={"budget_bytes": int(budget_bytes)})
              if ON.enabled else trace.NOOP_SPAN)
        with sp:
            store = truncate_store(self.full_oracle(), budget_bytes=budget_bytes)
            self.engine.set_budget(store)
        self.retruncations += 1
        _M_RETRUNC.inc()
        _M_BUDGET.set(int(budget_bytes))
        _M_RESIDENT.set(store.resident_bytes)
        return store

    def reapply(self) -> None:
        """Re-assert the current budget after an engine ``refresh`` dropped
        the store (new labels were published).  The refresh left the NEW
        full labels on ``engine.oracle`` — adopt them as the store to cut
        from; the old retained full store belongs to a dead epoch."""
        if self.budget_bytes is not None and getattr(
                self.engine, "budget_store", None) is None:
            if self._full is not None and self._full is not self.engine.oracle:
                self._full = self.engine.oracle
                self.full_bytes = label_bytes(self._full)
            self.apply(self.budget_bytes)

    # --------------------------------------------------------- pressure loop

    def signal(self) -> float:
        if self.pressure_source is not None:
            return float(self.pressure_source())
        return float(self.resident_bytes())

    def tick(self, now: Optional[float] = None) -> Optional[str]:
        """One pressure-loop step; returns "step_down" / "step_up" / None.

        Down steps halve (``step_factor``) the currently-resident budget
        immediately; up steps wait for ``recovery_ticks`` consecutive ticks
        below the low watermark, then undo one step at a time, ending at
        the operator-configured budget (or the full store)."""
        if self.pressure is None:
            return None
        cfg = self.pressure
        sig = self.signal()
        if sig > cfg.watermark_bytes:
            self._calm_ticks = 0
            current = (self.budget_bytes if self.budget_bytes is not None
                       else self.full_bytes or self.resident_bytes())
            nxt = max(int(current * cfg.step_factor), cfg.min_budget_bytes)
            if nxt >= current:
                return None          # already at the floor
            self.apply(nxt)
            self._step_depth += 1
            self.steps_down += 1
            _STEP_DOWN.inc()
            if ON.enabled:
                trace.event("budget_step", cat="budget", direction="down",
                            budget_bytes=nxt, signal=int(sig))
            return "step_down"
        if sig < cfg.low_watermark_bytes and self._step_depth > 0:
            self._calm_ticks += 1
            if self._calm_ticks < cfg.recovery_ticks:
                return None
            self._calm_ticks = 0
            self._step_depth -= 1
            if self._step_depth == 0:
                nxt = self._configured
            else:
                assert self.budget_bytes is not None
                nxt = int(self.budget_bytes / cfg.step_factor)
                if self._configured is not None:
                    nxt = min(nxt, self._configured)
                if self.full_bytes is not None:
                    nxt = min(nxt, self.full_bytes)
            self.apply(nxt)
            self.steps_up += 1
            _STEP_UP.inc()
            if ON.enabled:
                trace.event("budget_step", cat="budget", direction="up",
                            budget_bytes=nxt, signal=int(sig))
            return "step_up"
        if sig >= cfg.low_watermark_bytes:
            self._calm_ticks = 0
        return None

    def snapshot(self) -> dict:
        """Health-endpoint view of the governor."""
        store = getattr(self.engine, "budget_store", None)
        return {
            "budget_bytes": self.budget_bytes,
            "configured_budget_bytes": self._configured,
            "full_bytes": self.full_bytes,
            "resident_bytes": self.resident_bytes(),
            "rank_cut": None if store is None else store.rank_cut,
            "truncated": store is not None and store.any_truncated,
            "step_depth": self._step_depth,
            "retruncations": self.retruncations,
            "steps_down": self.steps_down,
            "steps_up": self.steps_up,
        }
