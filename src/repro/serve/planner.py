"""Length-bucketed micro-batching for the serve path.

The dense/kernel backends pay O(La * Lb) compares per query at the *padded*
matrix width, so one hub-heavy row forces every short-label query in the
batch to Lmax^2 work. The planner buckets queries by their true need —
max(|L_out(u)|, |L_in(v)|) — into a small set of padded width tiers, so the
short majority runs at a fraction of the compare cost.

Shapes are kept jit-friendly twice over:
  * tier widths are derived ONCE from the oracle's length distribution
    (quantiles snapped up to multiples of 8), not per batch — each tier
    compiles exactly one intersection trace;
  * tier row counts are padded up to power-of-two tiles (>= min_tile), so a
    varying query mix revisits a logarithmic set of batch shapes instead of
    retracing on every call.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

_PAD_WIDTH = 8


def _snap(x: int, multiple: int = _PAD_WIDTH) -> int:
    return max(((int(x) + multiple - 1) // multiple) * multiple, multiple)


def _pow2_at_least(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


def tier_widths(
    out_len: np.ndarray,
    in_len: np.ndarray,
    full_width: int,
    n_tiers: int = 3,
    quantiles: Sequence[float] = (0.5, 0.9),
) -> List[int]:
    """Ascending padded label widths, last always covering ``full_width``.

    Boundaries come from quantiles of the pooled per-vertex label lengths —
    a static property of the oracle, so the tier set is stable across
    batches.
    """
    full = _snap(full_width)
    pooled = np.concatenate([out_len, in_len])
    pooled = pooled[pooled > 0]
    if pooled.size == 0:
        return [full]
    widths = sorted({_snap(q) for q in np.quantile(pooled, quantiles[: n_tiers - 1])})
    return [w for w in widths if w < full] + [full]


@dataclasses.dataclass(frozen=True)
class TierPlan:
    idx: np.ndarray   # int32[k] positions into the original query batch
    width: int        # label columns this tier's intersection reads
    rows: int         # padded row count (power-of-two tile), rows >= k


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    tiers: List[TierPlan]
    n_queries: int

    @property
    def padded_rows(self) -> int:
        return sum(t.rows for t in self.tiers)

    def padded_queries(self, queries: np.ndarray, tier: TierPlan) -> np.ndarray:
        """Tier's query rows padded to its tile shape (pad rows gather vertex
        0 and are dropped at scatter time)."""
        q = queries[tier.idx]
        if tier.rows > q.shape[0]:
            pad = np.zeros((tier.rows - q.shape[0], 2), dtype=q.dtype)
            q = np.concatenate([q, pad], axis=0)
        return q

    def scatter(self, tier_results: Sequence[np.ndarray]) -> np.ndarray:
        """Reassemble per-tier results into batch order. Pad rows discarded."""
        out = np.zeros(self.n_queries, dtype=bool)
        for tier, res in zip(self.tiers, tier_results):
            out[tier.idx] = np.asarray(res)[: tier.idx.shape[0]]
        return out


def plan_batch(
    queries: np.ndarray,
    out_len: np.ndarray,
    in_len: np.ndarray,
    widths: Sequence[int],
    min_tile: int = 256,
) -> BatchPlan:
    """Assign each query to the narrowest tier that holds both its rows."""
    need = np.maximum(out_len[queries[:, 0]], in_len[queries[:, 1]])
    edges = np.asarray(widths, dtype=np.int64)
    tier_of = np.searchsorted(edges, need, side="left")
    tier_of = np.minimum(tier_of, len(widths) - 1)  # safety: clamp to widest
    tiers: List[TierPlan] = []
    for t, w in enumerate(widths):
        idx = np.nonzero(tier_of == t)[0].astype(np.int32)
        if idx.size == 0:
            continue
        rows = _pow2_at_least(max(int(idx.size), min_tile))
        tiers.append(TierPlan(idx=idx, width=int(w), rows=rows))
    return BatchPlan(tiers=tiers, n_queries=int(queries.shape[0]))
