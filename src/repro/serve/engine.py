"""QueryEngine: one serve subsystem, pluggable intersection backends.

Every query path in the repo (host point queries, batched device serving,
sharded production serving, benchmarks, examples) routes through here. The
engine owns the serving pipeline:

    queries -> prefilters (repro.serve.prefilter)
            -> length-bucketed micro-batches (repro.serve.planner)
            -> backend intersection
            -> scatter back

Backends:
  host         per-query sorted merge on the CPU (searchsorted + rank-ordered
               early exit; the reference path)
  dense        all-pairs jnp compare, jit per (tile, width) — the XLA path
  kernel       Pallas ``label_intersect`` (interpret off-TPU)
  sharded      labels replicated, queries sharded over the data axes
  sharded_hop  label matrices sharded over the model axis along the hop dim
               (labels-larger-than-one-device mode), OR-reduced

``backend="auto"`` picks: sharded when a mesh is supplied, kernel on TPU,
dense otherwise.
"""
from __future__ import annotations

import copy
import threading
import time
import warnings
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.ft import inject
from repro.graph.csr import INVALID
from repro.obs import metrics, trace
from repro.obs.state import ON
from repro.serve.planner import BatchPlan, plan_batch, tier_widths
from repro.serve.prefilter import apply_prefilters

BACKENDS = ("host", "dense", "kernel", "sharded", "sharded_hop")


def select_backend(name: Optional[str] = None, mesh=None) -> str:
    """Resolve a backend name ('auto'/None = detect from mesh + platform)."""
    if name in (None, "auto"):
        if mesh is not None:
            return "sharded"
        return "kernel" if jax.default_backend() == "tpu" else "dense"
    if name not in BACKENDS:
        raise ValueError(f"unknown backend {name!r}; expected one of {BACKENDS}")
    if name in ("sharded", "sharded_hop") and mesh is None:
        raise ValueError(f"backend {name!r} requires a mesh")
    return name


# ---------------------------------------------------------------- primitives


@jax.jit
def intersect_rows(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """a: int32[B, La], b: int32[B, Lb] (INVALID padded) -> bool[B]."""
    eq = a[:, :, None] == b[:, None, :]
    valid = (a[:, :, None] != INVALID) & (b[:, None, :] != INVALID)
    return (eq & valid).any(axis=(1, 2))


@partial(jax.jit, static_argnames=("use_kernel",))
def serve_step(
    L_out: jnp.ndarray,
    L_in: jnp.ndarray,
    queries: jnp.ndarray,
    use_kernel: bool = False,
) -> jnp.ndarray:
    """One-shot batched intersection at full label width (the legacy path;
    the engine adds prefilters + bucketing on top).

    L_out: int32[n, Lo], L_in: int32[n, Li], queries: int32[B, 2].
    """
    a = jnp.take(L_out, queries[:, 0], axis=0)
    b = jnp.take(L_in, queries[:, 1], axis=0)
    if use_kernel:
        from repro.kernels.ops import label_intersect

        return label_intersect(a, b)
    return intersect_rows(a, b)


@partial(jax.jit, static_argnames=("width", "use_kernel"))
def _tier_intersect(L_out, L_in, queries, width: int, use_kernel: bool):
    """Gather + truncate to the tier width + intersect. One trace per
    (tile rows, width, backend) triple."""
    a = jnp.take(L_out, queries[:, 0], axis=0)[:, :width]
    b = jnp.take(L_in, queries[:, 1], axis=0)[:, :width]
    if use_kernel:
        from repro.kernels.ops import label_intersect

        return label_intersect(a, b)
    return intersect_rows(a, b)


# ------------------------------------------------------------ sharded modes


def make_sharded_serve_step(mesh, data_axes=("pod", "data")):
    """Production serve_step: labels replicated over the model axis, queries
    sharded over the data axes. Returns (jitted_fn, in_shardings, out_sharding).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    label_sharding = NamedSharding(mesh, P())               # replicated
    query_sharding = NamedSharding(mesh, P(data_axes, None))
    out_sharding = NamedSharding(mesh, P(data_axes))

    fn = jax.jit(
        lambda lo, li, q: serve_step(lo, li, q),
        in_shardings=(label_sharding, label_sharding, query_sharding),
        out_shardings=out_sharding,
    )
    return fn, (label_sharding, label_sharding, query_sharding), out_sharding


def make_hop_sharded_serve_step(mesh, model_axis="model", data_axes=("pod", "data")):
    """Large-graph variant: label MATRICES sharded over the model axis along
    the hop dimension (each device holds a slice of every row); each shard
    computes a partial intersection hit and the results OR-reduce over the
    model axis. Queries sharded over data axes.

    This is the "labels larger than one device" serving mode.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    label_sharding = NamedSharding(mesh, P(None, model_axis))
    query_sharding = NamedSharding(mesh, P(data_axes, None))
    out_sharding = NamedSharding(mesh, P(data_axes))

    def step(L_out, L_in, queries):
        a = jnp.take(L_out, queries[:, 0], axis=0)
        b_full = jnp.take(L_in, queries[:, 1], axis=0)
        # each hop-shard of `a` must compare against ALL hops of b:
        # jnp ops under jit+sharding constraints let XLA insert the all-gather
        # of the (small) b rows; the big L_out stays sharded.
        eq = a[:, :, None] == b_full[:, None, :]
        valid = (a[:, :, None] != INVALID) & (b_full[:, None, :] != INVALID)
        return (eq & valid).any(axis=(1, 2))

    fn = jax.jit(
        step,
        in_shardings=(label_sharding, label_sharding, query_sharding),
        out_shardings=out_sharding,
    )
    return fn, (label_sharding, label_sharding, query_sharding), out_sharding


# ----------------------------------------------------------------- engine

# every downgrade the ladder can count; stats()/reset_stats() and the
# per-batch tallies all start from this shape so no consumer ever sees a
# partially populated counter dict
_ZERO_DEGRADATION = {
    "device_to_host": 0,   # device backend failed -> host merge
    "deadline_to_host": 0, # batch past deadline -> skip device (retrace risk)
    "searched": 0,         # labels unusable -> exact bidirectional search
    "quarantined": 0,      # queries that touched quarantined label rows
    "uncertain": 0,        # budget-truncated miss, BOTH rows cut -> search
}

# registry mirrors (process-global; the per-engine ``degradation`` dict stays
# the per-instance view health()/chaos read)
_M_QUERIES = metrics.counter(
    "engine_queries_total", "queries through QueryEngine.query_batch")
_M_PREFILTERED = metrics.counter(
    "engine_prefiltered_total", "queries decided by the prefilter stack")
_M_DEGRADED = metrics.counter(
    "engine_degraded_total", "ladder downgrades, by kind", labelnames=("kind",))
_DEGRADED_KIND = {k: _M_DEGRADED.labels(kind=k) for k in _ZERO_DEGRADATION}
_M_EPOCH = metrics.gauge(
    "engine_epoch", "label-snapshot epoch the engine currently serves")
_M_UNCERTAIN = metrics.counter(
    "engine_verdict_uncertain_total",
    "budget-truncated label misses that could not be proven NO and routed "
    "to the exact-search rung")


class QueryEngine:
    """The serve subsystem for one ReachabilityOracle.

    Parameters
    ----------
    oracle : ReachabilityOracle
        Labels in the engine's id space (condensation ids when built through
        ``repro.core.api``).
    backend : str
        One of BACKENDS or "auto".
    level : optional int32[n]
        Topological levels for the level prefilter (``prefilter.topo_levels``).
    mesh : optional jax Mesh
        Required for the sharded backends.
    bucketing : bool
        Length-bucketed micro-batching for dense/kernel backends.
    comp_source : optional callable -> int32[n_original]
        When set, queries arrive in ORIGINAL vertex ids and are mapped to the
        oracle's condensation id space through ``comp_source()`` at call time.
        The indirection is deliberate: the owner (``CondensedOracle`` /
        ``repro.dynamic.DynamicOracle``) controls which comp array is current,
        so SCC-condensation merges can never serve a stale same-SCC verdict
        from a comp array cached inside the engine.
    epoch : int
        Label-snapshot epoch this engine currently serves (see
        ``repro.dynamic.versioned``); bumped by ``refresh``.
    fallback_graph : optional CSRGraph or callable -> CSRGraph
        The DAG the labels index, in the ORACLE'S id space — the bottom rung
        of the degradation ladder (exact bidirectional online search when
        labels cannot be trusted).  Must be the graph of the SERVED epoch:
        owners with a mutating working graph (``repro.dynamic``) pass a
        frozen snapshot at every ``refresh``, never a live view.

    Degradation ladder
    ------------------
    Queries normally run device-side (kernel / dense / sharded).  A device
    backend failure downgrades the whole sub-batch to the host merge path
    (same labels, same verdicts); a query touching a *quarantined* label row
    (``set_quarantine`` — rows a non-strict snapshot load could not verify)
    skips labels entirely and runs the exact online search.  Every rung
    returns correct verdicts; ``self.degradation`` counts how often each
    downgrade fired so operators see corruption as a metric, not an outage.

    Memory budgets (three-valued verdicts)
    --------------------------------------
    ``set_budget`` installs a ``serve.budget.TruncatedStore`` — labels cut
    to a rank-prefix under a byte budget — and the engine serves from the
    truncated matrices.  Verdicts become three-valued: a HIT on surviving
    prefixes is a proven YES (every surviving entry is real); a MISS is a
    proven NO unless BOTH rows were truncated (a uniform rank threshold
    means kept entries can never match dropped entries, so the lost
    intersection lives entirely in dropped x dropped); the residue —
    both-rows-cut miss that no exact structural filter (same vertex, topo
    level) decides — is UNCERTAIN and routes to the exact-search rung.
    Wrong answers are impossible at any budget.  A batch captures its
    store view once at entry (the view tuple is swapped whole), so a
    concurrent re-truncation can never tear masks from matrices mid-batch.
    """

    def __init__(
        self,
        oracle,
        backend: str = "auto",
        level: Optional[np.ndarray] = None,
        mesh=None,
        data_axes: Optional[Sequence[str]] = None,
        model_axis: str = "model",
        bucketing: bool = True,
        n_tiers: int = 3,
        min_tile: int = 256,
        comp_source=None,
        epoch: int = 0,
        fallback_graph=None,
        search_node_budget: Optional[int] = None,
    ):
        self.oracle = oracle
        self.mesh = mesh
        self.backend = select_backend(backend, mesh)
        # own copy: the owner may keep mutating its working level array
        # between publishes (repro.dynamic), and queries must not see it
        self.level = None if level is None else np.array(level, dtype=np.int32)
        self.bucketing = bucketing
        self.min_tile = int(min_tile)
        self.n_tiers = int(n_tiers)
        if data_axes is None and mesh is not None:
            data_axes = tuple(ax for ax in mesh.axis_names if ax != model_axis)
        self.data_axes = data_axes
        self.model_axis = model_axis
        self.comp_source = comp_source
        self.epoch = int(epoch)
        self._lo, self._li = oracle.device_labels()
        self.widths = tier_widths(
            oracle.out_len, oracle.in_len, oracle.max_label_len, n_tiers=n_tiers
        )
        self._sharded_fns: dict = {}
        self.last_stats: dict = {}
        self._fallback_graph = fallback_graph
        self._fallback_csr = None   # resolved (graph, reverse) pair, lazy
        self.quarantine_out: Optional[np.ndarray] = None
        self.quarantine_in: Optional[np.ndarray] = None
        # cumulative downgrade counters (ladder observability); mutated only
        # under _stats_lock so stats()/reset_stats() are atomic with respect
        # to in-flight query_batch tallies (the daemon reads stats() from
        # its publish worker thread while dispatches run in another)
        self.degradation = dict(_ZERO_DEGRADATION)
        self._stats_lock = threading.Lock()
        # node cap for the search rung (None = unbounded; the search stays
        # exact either way — exhaustion falls back to forward-only BFS)
        self.search_node_budget = search_node_budget
        # (store, device L_out, device L_in, tier widths) — swapped whole in
        # set_budget so a batch's entry-time capture is internally consistent
        self._budget_view: Optional[tuple] = None

    # ---------------------------------------------------------- publishing

    def refresh(self, oracle, level: Optional[np.ndarray] = None,
                epoch: Optional[int] = None, fallback_graph=None) -> None:
        """Swap in a newly published label snapshot (epoch invalidation).

        Device label arrays and the tier-width plan refresh ONLY here — never
        mid-batch — so in-flight queries keep their pinned epoch's arrays.
        Tier widths are recomputed from the new length distribution, but when
        they come out unchanged (the common case for incremental repairs) the
        bucketed jit traces stay keyed to the same (rows, width) shapes and
        nothing retraces.
        """
        self.oracle = oracle
        if level is not None:
            self.level = np.array(level, dtype=np.int32)  # copy: see __init__
        self._lo, self._li = oracle.device_labels()
        self.widths = tier_widths(
            oracle.out_len, oracle.in_len, oracle.max_label_len, n_tiers=self.n_tiers
        )
        self.epoch = self.epoch + 1 if epoch is None else int(epoch)
        _M_EPOCH.set(self.epoch)
        if fallback_graph is not None:
            self._fallback_graph = fallback_graph
        # the ladder's search rung must answer against the newly served
        # epoch's graph — drop the previous epoch's resolved snapshot
        self._fallback_csr = None
        # new labels supersede any previous load-time quarantine
        self.quarantine_out = None
        self.quarantine_in = None
        # ...and any budget truncation (it was cut from the OLD labels); the
        # daemon's BudgetController re-applies its budget on the next tick
        self._budget_view = None

    # ------------------------------------------------------- observability

    def stats(self) -> dict:
        """Consistent snapshot of the engine's serving state for health
        endpoints: taken under ``_stats_lock``, so a reader can never
        observe counters torn between two batches — ``_tally`` publishes a
        finished batch's counters and its ``last_stats`` record under the
        same lock, and a reader in another thread (the daemon's publish
        worker) sees either all of a batch or none of it."""
        bv = self._budget_view
        with self._stats_lock:
            return {
                "epoch": self.epoch,
                "backend": self.backend,
                "widths": list(self.widths),
                "n_quarantined": int(
                    (0 if self.quarantine_out is None else int(self.quarantine_out.sum()))
                    + (0 if self.quarantine_in is None else int(self.quarantine_in.sum()))),
                "budget": None if bv is None else {
                    "budget_bytes": bv[0].budget_bytes,
                    "resident_bytes": bv[0].resident_bytes,
                    "rank_cut": bv[0].rank_cut,
                    "n_truncated_rows": int(bv[0].truncated_out.sum()
                                            + bv[0].truncated_in.sum()),
                },
                "degradation": dict(self.degradation),
                "last_batch": copy.deepcopy(self.last_stats),
            }

    def reset_stats(self) -> None:
        """Zero the cumulative degradation counters and the last-batch
        record (e.g. at daemon startup, or between bench runs).  Atomic with
        respect to in-flight ``query_batch`` tallies: the counter dict is
        swapped whole under the lock, never cleared in place."""
        with self._stats_lock:
            self.degradation = dict(_ZERO_DEGRADATION)
            self.last_stats = {}

    # ------------------------------------------------- degradation ladder

    def set_quarantine(self, quarantine_out: Optional[np.ndarray],
                       quarantine_in: Optional[np.ndarray]) -> None:
        """Mark label rows that must not be trusted (``persist.LoadReport``
        masks from a non-strict snapshot load).  Queries touching them route
        to the online-search rung instead of reading the rows."""
        def _norm(q):
            if q is None or not np.any(q):
                return None
            return np.asarray(q, dtype=bool)

        self.quarantine_out = _norm(quarantine_out)
        self.quarantine_in = _norm(quarantine_in)

    @property
    def budget_store(self):
        """The active ``TruncatedStore`` (None = serving the full labels)."""
        bv = self._budget_view
        return None if bv is None else bv[0]

    def set_budget(self, store) -> None:
        """Install (or with None, remove) a budget-truncated label store.

        The engine keeps serving ``self.oracle``'s graph — only the label
        MATRICES read by the intersection backends switch to the truncated
        store, together with its truncation masks and a tier-width plan fit
        to the truncated length distribution.  All four swap as one tuple:
        an in-flight batch that captured the previous view stays internally
        consistent (see class docstring), which is what lets the daemon's
        pressure loop re-truncate between dispatches without draining."""
        if store is None:
            self._budget_view = None
            return
        t = store.oracle
        lo, li = t.device_labels()
        widths = tier_widths(t.out_len, t.in_len, t.max_label_len,
                             n_tiers=self.n_tiers)
        self._budget_view = (store, lo, li, widths)

    def _fallback(self):
        """Resolve the fallback graph to a cached (g, g_rev) pair."""
        if self._fallback_csr is None:
            g = self._fallback_graph
            if g is None:
                raise RuntimeError(
                    "degradation ladder exhausted: quarantined label rows "
                    "need the online-search rung, but no fallback_graph was "
                    "configured on this QueryEngine")
            if callable(g):
                g = g()
            self._fallback_csr = (g, g.reverse())
        return self._fallback_csr

    def _search_batch(self, rest: np.ndarray) -> np.ndarray:
        """Bottom rung: exact bidirectional search, no label reads."""
        from repro.core.baselines.online_search import bidirectional_query

        g, g_rev = self._fallback()
        out = np.empty(rest.shape[0], dtype=bool)
        for i, (u, v) in enumerate(rest):
            out[i] = bidirectional_query(g, g_rev, int(u), int(v),
                                         node_budget=self.search_node_budget)
        return out

    # ------------------------------------------------------------- queries

    def _map_ids(self, queries: np.ndarray) -> np.ndarray:
        comp = self.comp_source() if self.comp_source is not None else None
        if comp is None:
            return queries
        return comp[np.asarray(queries, dtype=np.int64)].astype(np.int32)

    def query(self, u: int, v: int) -> bool:
        """Single host query (prefilters + rank-ordered sorted merge)."""
        if self.comp_source is not None:
            comp = self.comp_source()
            u, v = int(comp[u]), int(comp[v])
        if u == v:
            return True
        if (self.quarantine_out is not None and self.quarantine_out[u]) or (
                self.quarantine_in is not None and self.quarantine_in[v]):
            # untrusted rows: even the length/level prefilters would read
            # corrupt state — go straight to the search rung
            with self._stats_lock:
                self.degradation["quarantined"] += 1
                self.degradation["searched"] += 1
            _DEGRADED_KIND["quarantined"].inc()
            _DEGRADED_KIND["searched"].inc()
            return bool(self._search_batch(np.asarray([[u, v]]))[0])
        if self.level is not None and self.level[u] >= self.level[v]:
            return False
        bv = self._budget_view
        o = self.oracle if bv is None else bv[0].oracle
        if o.out_len[u] == 0 or o.in_len[v] == 0:
            # an empty TRUNCATED row is only a proven miss when at most one
            # side was cut — fall through to the uncertain check below
            hit = False
        else:
            hit = o.query(u, v)
        if hit:
            return True          # hits on surviving prefixes are proven YES
        if bv is not None and bv[0].truncated_out[u] and bv[0].truncated_in[v]:
            # miss with BOTH rows cut: uncertain -> exact search rung
            with self._stats_lock:
                self.degradation["uncertain"] += 1
                self.degradation["searched"] += 1
            _DEGRADED_KIND["uncertain"].inc()
            _DEGRADED_KIND["searched"].inc()
            _M_UNCERTAIN.inc()
            return bool(self._search_batch(np.asarray([[u, v]]))[0])
        return False

    def query_batch(self, queries: np.ndarray, backend: Optional[str] = None,
                    deadline: Optional[float] = None) -> np.ndarray:
        """Answer int[B, 2] queries -> bool[B].

        With ``comp_source`` set, queries are original vertex ids and the
        same-SCC short-circuit (the engine's ``u == v`` prefilter after
        mapping) reads the CURRENT condensation — not a cached copy.

        ``deadline`` (absolute ``time.monotonic()`` seconds) is the serving
        daemon's per-batch latency budget, propagated down here because the
        engine owns the one genuinely unpredictable step: a device dispatch
        can retrace (new tile/width shape) and stall for orders of magnitude
        longer than a warm call.  A batch already past its deadline
        therefore skips the device attempt and takes the predictable host
        merge (counted as ``deadline_to_host``).  Deadlines never change
        verdicts — every rung stays exact.
        """
        queries = self._map_ids(np.asarray(queries))
        queries = np.ascontiguousarray(np.asarray(queries, dtype=np.int32))
        backend = self.backend if backend is None else select_backend(backend, self.mesh)
        # capture the budget view ONCE: everything this batch reads (matrices,
        # masks, widths) comes from one immutable tuple, so a pressure-loop
        # re-truncation landing mid-batch cannot mix old masks with new rows
        bv = self._budget_view
        store = None if bv is None else bv[0]
        o = self.oracle if store is None else store.oracle
        out = np.zeros(queries.shape[0], dtype=bool)
        degraded = dict(_ZERO_DEGRADATION)

        # ladder rung 0 (when needed): queries touching quarantined label
        # rows bypass prefilters TOO — length/level prefilters read the very
        # state that failed verification, and a zero-filled out_len would
        # flip verdicts to False.  Everything they need comes from the
        # fallback graph.
        label_idx = np.arange(queries.shape[0])
        if self.quarantine_out is not None or self.quarantine_in is not None:
            qm = np.zeros(queries.shape[0], dtype=bool)
            if self.quarantine_out is not None:
                qm |= self.quarantine_out[queries[:, 0]]
            if self.quarantine_in is not None:
                qm |= self.quarantine_in[queries[:, 1]]
            q_idx = np.nonzero(qm)[0]
            if q_idx.size:
                degraded["quarantined"] += int(q_idx.size)
                degraded["searched"] += int(q_idx.size)
                out[q_idx] = self._search_batch(queries[q_idx])
                label_idx = np.nonzero(~qm)[0]

        pf = apply_prefilters(queries[label_idx], o.out_len, o.in_len, self.level)
        out[label_idx] = pf.decided & pf.value
        rest_idx = label_idx[~pf.decided]
        # the batch record is LOCAL until the batch finishes: _tally
        # publishes it (with the counter adds) atomically under _stats_lock,
        # so a concurrent stats()/reset_stats() never sees a half-built
        # record or tears a tally mid-batch
        stats = {
            "backend": backend,
            "n_queries": int(queries.shape[0]),
            "n_prefiltered": int(label_idx.shape[0] - rest_idx.size),
            "tiers": [],
            "degraded": degraded,
        }
        sp = trace.span("engine.batch", cat="engine", args={
            "backend": backend, "n": stats["n_queries"],
            "prefiltered": stats["n_prefiltered"]}) if ON.enabled else trace.NOOP_SPAN
        with sp:
            if rest_idx.size:
                rest = queries[rest_idx]

                if backend == "host":
                    res = self._host_batch(rest, o)
                elif deadline is not None and time.monotonic() > deadline:
                    # past budget before the device attempt: retrace risk is
                    # the one unbounded cost left — take the predictable path
                    degraded["deadline_to_host"] += int(rest.shape[0])
                    sp.event("degrade", kind="deadline_to_host", n=int(rest.shape[0]))
                    res = self._host_batch(rest, o)
                else:
                    try:
                        if backend in ("dense", "kernel"):
                            res = self._device_batch(
                                rest, use_kernel=backend == "kernel",
                                stats=stats, view=bv)
                        else:
                            res = self._sharded_batch(rest, backend, view=bv)
                    except Exception as e:  # ladder: device failure -> host merge
                        degraded["device_to_host"] += int(rest.shape[0])
                        sp.event("degrade", kind="device_to_host",
                                 n=int(rest.shape[0]), error=type(e).__name__)
                        warnings.warn(
                            f"{backend!r} backend failed ({type(e).__name__}: {e}); "
                            f"serving {rest.shape[0]} queries on the host merge path",
                            stacklevel=2)
                        res = self._host_batch(rest, o)
                out[rest_idx] = res

            # three-valued epilogue: under a budget, a False verdict from the
            # labels (backend miss OR emptiness prefilter on a cut-to-empty
            # row) is only proven when at most one row was truncated.  The
            # same-vertex and topo-level prefilters are graph facts, exact at
            # any budget, so they keep their verdicts.
            if store is not None and store.any_truncated and label_idx.size:
                lq = queries[label_idx]
                unc = (store.truncated_out[lq[:, 0]]
                       & store.truncated_in[lq[:, 1]] & ~out[label_idx])
                unc &= lq[:, 0] != lq[:, 1]
                if self.level is not None:
                    unc &= self.level[lq[:, 0]] < self.level[lq[:, 1]]
                unc_idx = label_idx[unc]
                if unc_idx.size:
                    degraded["uncertain"] += int(unc_idx.size)
                    degraded["searched"] += int(unc_idx.size)
                    sp.event("degrade", kind="uncertain", n=int(unc_idx.size))
                    out[unc_idx] = self._search_batch(queries[unc_idx])
            self._tally(stats, degraded)
            return out

    def _host_batch(self, rest: np.ndarray, o=None) -> np.ndarray:
        o = self.oracle if o is None else o
        return np.fromiter((o.query(int(u), int(v)) for u, v in rest), dtype=bool,
                           count=rest.shape[0])

    def _tally(self, stats: dict, degraded: dict) -> None:
        """Publish a finished batch: counters + last_stats flip together."""
        with self._stats_lock:
            for k, v in degraded.items():
                self.degradation[k] += v
            self.last_stats = stats
        _M_QUERIES.inc(stats["n_queries"])
        _M_PREFILTERED.inc(stats["n_prefiltered"])
        for k, v in degraded.items():
            if v:
                _DEGRADED_KIND[k].inc(v)
        if degraded.get("uncertain"):
            _M_UNCERTAIN.inc(degraded["uncertain"])

    # ------------------------------------------------------------ backends

    def _device_batch(self, rest: np.ndarray, use_kernel: bool,
                      stats: Optional[dict] = None,
                      view: Optional[tuple] = None) -> np.ndarray:
        # chaos hook: an injected device failure here exercises the ladder's
        # device -> host downgrade in query_batch
        inject.fire("serve.device_dispatch", backend="kernel" if use_kernel else "dense")
        if stats is None:
            stats = {"tiers": []}   # direct callers outside query_batch
        if view is not None:
            o, lo, li, widths = view[0].oracle, view[1], view[2], view[3]
        else:
            o, lo, li, widths = self.oracle, self._lo, self._li, self.widths
        if not self.bucketing:
            with trace.span("device_call", cat="device", annotate=True,
                            args={"rows": int(rest.shape[0])} if ON.enabled else None):
                r = serve_step(lo, li, jnp.asarray(rest), use_kernel=use_kernel)
            return np.asarray(r)
        plan = plan_batch(rest, o.out_len, o.in_len, widths, min_tile=self.min_tile)
        results = []
        for tier in plan.tiers:
            q = jnp.asarray(plan.padded_queries(rest, tier))
            with trace.span("device_call", cat="device", annotate=True,
                            args={"width": tier.width, "rows": tier.rows}
                            if ON.enabled else None):
                results.append(
                    _tier_intersect(lo, li, q, tier.width, use_kernel))
            stats["tiers"].append(
                {"width": tier.width, "count": int(tier.idx.size), "rows": tier.rows}
            )
        return plan.scatter([np.asarray(r) for r in results])

    def _sharded_batch(self, rest: np.ndarray, backend: str,
                       view: Optional[tuple] = None) -> np.ndarray:
        inject.fire("serve.device_dispatch", backend=backend)
        lo, li = (self._lo, self._li) if view is None else (view[1], view[2])
        fn = self._sharded_fns.get(backend)
        if fn is None:
            if backend == "sharded":
                fn, _, _ = make_sharded_serve_step(self.mesh, data_axes=self.data_axes)
            else:
                fn, _, _ = make_hop_sharded_serve_step(
                    self.mesh, model_axis=self.model_axis, data_axes=self.data_axes
                )
            self._sharded_fns[backend] = fn
        # fixed shapes across devices: pad the batch to a data-shard multiple
        shards = 1
        for ax in self.data_axes or ():
            shards *= self.mesh.shape[ax]
        B = rest.shape[0]
        pad = (-B) % max(shards, 1)
        if pad:
            rest = np.concatenate([rest, np.zeros((pad, 2), dtype=rest.dtype)], axis=0)
        res = np.asarray(fn(lo, li, jnp.asarray(rest)))
        return res[:B]
