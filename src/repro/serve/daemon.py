"""Overload-safe serving daemon: admission control, batching, degradation.

The QueryEngine answers batches; this module is the *system* around it that
keeps answering under open-loop load, device trouble, and concurrent
dynamic publishes.  One asyncio process, one dispatch at a time:

    submit() -> admission control -> bounded ingress queue
             -> collect-for-a-few-ms batching (one padded device dispatch
                per tick; tier bucketing via the engine's planner)
             -> circuit breaker (device SLO) -> engine degradation ladder
             -> per-request futures

Robustness posture (FERRARI-style budgeted serving, applied to latency):

  * **bounded ingress** — the queue admits at most ``queue_limit`` queries;
    past that, arrivals shed with ``queue_full`` instead of growing an
    unbounded backlog,
  * **deadline-aware shedding** — every request carries a deadline; at
    admission the daemon estimates queue depth / measured service rate and
    sheds requests that could not finish in budget ("deadline"), and at
    dispatch it sheds requests whose budget already expired ("expired") —
    serving a dead request only delays live ones,
  * **circuit breaker** — consecutive device-dispatch failures or
    latency-SLO misses trip the breaker: batches route straight to the host
    merge rung (retry-with-downgrade, never retry-same), and the device is
    re-probed after an exponential backoff.  Breaker state and the engine's
    ``degradation`` counters surface in ``health()``,
  * **pinned-epoch routing** — while a dynamic publish is in flight,
    batches serve from the ``LabelEpoch`` snapshot pinned at publish start,
    so no batch ever observes a half-refreshed engine and publishes never
    stall serving,
  * **graceful drain** — ``drain()`` (wired to SIGTERM in the CLI) stops
    admission, serves everything already admitted, then stops; ``kill()``
    is the abrupt variant the chaos suite uses.

Every rung stays exact: overload and faults shed or degrade, they never
produce a wrong verdict.
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from repro.obs import metrics, trace
from repro.obs.state import ON


class ShedError(RuntimeError):
    """A request the daemon refused (admission) or dropped (expired).

    ``reason`` is one of: queue_full, deadline, draining, expired, killed.
    Sheds are explicit backpressure — the client is told immediately, and
    the request never consumes service capacity."""

    def __init__(self, reason: str, detail: str = ""):
        super().__init__(f"shed[{reason}]" + (f": {detail}" if detail else ""))
        self.reason = reason


@dataclasses.dataclass
class DaemonConfig:
    """Knobs for the admission/batching loop and the breaker."""

    batch_window_ms: float = 2.0     # collect arrivals for this long per tick
    max_batch: int = 4096            # queries per padded device dispatch
    queue_limit: int = 8192          # bounded ingress (queries, not arrivals)
    deadline_ms: float = 100.0       # default per-request latency budget
    backend: Optional[str] = None    # None = the engine's default backend
    breaker_failures: int = 3        # consecutive bad dispatches that trip it
    breaker_slo_ms: Optional[float] = None   # default: deadline_ms / 2
    breaker_backoff_ms: float = 100.0        # first re-probe delay
    breaker_backoff_max_ms: float = 5000.0
    shed_headroom: float = 1.0       # admit while est. wait < headroom * budget

    @property
    def slo_s(self) -> float:
        slo = (self.deadline_ms / 2.0 if self.breaker_slo_ms is None
               else self.breaker_slo_ms)
        return slo / 1000.0


@dataclasses.dataclass
class _Request:
    queries: np.ndarray
    deadline: float            # absolute time.monotonic()
    t_submit: float
    future: asyncio.Future
    trace_id: int = 0          # obs.trace id carried admission -> completion


class CircuitBreaker:
    """Consecutive-failure / latency-SLO breaker over the device backend.

    closed -> (failures >= threshold) -> open -> (backoff elapses) ->
    half_open -> one probe batch -> closed on success, open (doubled
    backoff) on failure.  "Failure" is either a device dispatch the engine
    had to downgrade (its ladder already re-served the batch on the host —
    retry-with-downgrade, so no answers were lost) or a dispatch that blew
    the latency SLO."""

    def __init__(self, failures: int, backoff_s: float, backoff_max_s: float):
        self.threshold = max(int(failures), 1)
        self.backoff0 = float(backoff_s)
        self.backoff_max = float(backoff_max_s)
        self.state = "closed"
        self.consecutive = 0
        self.trips = 0
        self.backoff = self.backoff0
        self.open_until = 0.0

    def allow_device(self, now: float) -> bool:
        """May the next dispatch try the device?  Flips open -> half_open
        when the backoff has elapsed (the probe)."""
        if self.state == "closed":
            return True
        if self.state == "open" and now >= self.open_until:
            self.state = "half_open"
        return self.state == "half_open"

    def record(self, ok: bool, now: float) -> None:
        if ok:
            if self.state == "half_open":
                self.backoff = self.backoff0   # healthy probe: full reset
            self.state = "closed"
            self.consecutive = 0
            return
        self.consecutive += 1
        if self.state == "half_open":
            # failed probe: reopen immediately with a doubled backoff
            self.backoff = min(self.backoff * 2, self.backoff_max)
            self._trip(now)
        elif self.consecutive >= self.threshold:
            self._trip(now)

    def _trip(self, now: float) -> None:
        self.state = "open"
        self.trips += 1
        self.open_until = now + self.backoff
        self.consecutive = 0
        _BREAKER_TRIPS.inc()
        if ON.enabled:
            trace.event("breaker_open", cat="daemon", trips=self.trips,
                        backoff_ms=round(self.backoff * 1000, 1))

    def snapshot(self, now: float) -> dict:
        return {
            "state": self.state,
            "trips": self.trips,
            "consecutive_failures": self.consecutive,
            "backoff_ms": round(self.backoff * 1000, 1),
            "reprobe_in_ms": round(max(self.open_until - now, 0.0) * 1000, 1),
        }


_ZERO_COUNTERS = {
    "submitted": 0, "admitted": 0, "answered": 0,
    "shed_queue_full": 0, "shed_deadline": 0, "shed_draining": 0,
    "shed_expired": 0, "shed_killed": 0,
    "batches": 0, "device_batches": 0, "breaker_host_batches": 0,
    "pinned_epoch_batches": 0, "pinned_device_to_host": 0,
    "publishes": 0,
    "budget_steps_down": 0, "budget_steps_up": 0,
}

# Registry-backed mirrors of the per-daemon counter dict: every counter key
# maps to a bound child of a labeled family, resolved ONCE here so the hot
# path pays a dict lookup + one add.  The dict on the daemon instance stays
# the per-instance view (openloop reports read it); the registry is the
# process-global surface health()/--metrics-out export.
_REQUESTS = metrics.counter(
    "daemon_requests_total", "queries through admission, by outcome stage",
    labelnames=("event",))
_SHED = metrics.counter(
    "daemon_shed_total", "queries shed, by reason", labelnames=("reason",))
_BATCHES = metrics.counter(
    "daemon_batches_total", "dispatched batches, by serving rung",
    labelnames=("rung",))
_PUBLISHES = metrics.counter(
    "daemon_publishes_total", "dynamic epochs published through the daemon")
_BREAKER_TRIPS = metrics.counter(
    "daemon_breaker_trips_total", "circuit-breaker closed/half_open -> open flips")
_QUEUE_DEPTH = metrics.gauge(
    "daemon_queue_depth", "admitted queries waiting for a dispatch tick")
_REQ_LATENCY = metrics.histogram(
    "daemon_request_latency_ms", "answered requests, arrival -> future resolve")
_DISPATCH_MS = metrics.histogram(
    "daemon_dispatch_ms", "padded-batch dispatch wall time (worker thread)")
_BUDGET_STEPS = metrics.counter(
    "daemon_budget_steps_total",
    "pressure-loop budget steps taken between dispatch ticks",
    labelnames=("direction",))

_COUNTER_METRICS = {
    "submitted": _REQUESTS.labels(event="submitted"),
    "admitted": _REQUESTS.labels(event="admitted"),
    "answered": _REQUESTS.labels(event="answered"),
    "shed_queue_full": _SHED.labels(reason="queue_full"),
    "shed_deadline": _SHED.labels(reason="deadline"),
    "shed_draining": _SHED.labels(reason="draining"),
    "shed_expired": _SHED.labels(reason="expired"),
    "shed_killed": _SHED.labels(reason="killed"),
    "batches": _BATCHES.labels(rung="all"),
    "device_batches": _BATCHES.labels(rung="device"),
    "breaker_host_batches": _BATCHES.labels(rung="breaker_host"),
    "pinned_epoch_batches": _BATCHES.labels(rung="pinned_epoch"),
    "pinned_device_to_host": _BATCHES.labels(rung="pinned_host"),
    "publishes": _PUBLISHES.labels(),
    "budget_steps_down": _BUDGET_STEPS.labels(direction="down"),
    "budget_steps_up": _BUDGET_STEPS.labels(direction="up"),
}


class ServeDaemon:
    """Single-process async serving daemon over one oracle.

    ``target`` duck-types three shapes:

      * a ``repro.core.api.CondensedOracle`` (static labels),
      * a ``repro.dynamic.DynamicOracle`` / ``DurableDynamicOracle``
        (``publish`` + pinned-epoch routing become live),
      * a bare ``QueryEngine`` (tests).

    The engine dispatch runs in a worker thread (``run_in_executor``) so
    the event loop keeps admitting and timestamping arrivals while a padded
    batch is on the device — but there is only ever ONE dispatch in flight:
    the batch loop awaits it before collecting the next tick.
    """

    def __init__(self, target, config: Optional[DaemonConfig] = None,
                 budget_ctl=None):
        self.target = target
        self.engine = getattr(target, "engine", target)
        self.cfg = config or DaemonConfig()
        # optional serve.budget.BudgetController: when it carries a
        # PressureConfig, start() runs its tick between dispatch ticks —
        # re-truncations happen under _engine_lock, in the gaps between
        # batches, so a budget step can never drop an in-flight batch
        self.budget_ctl = budget_ctl
        self._dynamic = hasattr(target, "snapshot") and hasattr(target, "publish")
        self.state = "starting"
        self.counters: Dict[str, int] = dict(_ZERO_COUNTERS)
        self.latencies = collections.deque(maxlen=8192)  # answered, seconds
        self.breaker = CircuitBreaker(
            self.cfg.breaker_failures,
            self.cfg.breaker_backoff_ms / 1000.0,
            self.cfg.breaker_backoff_max_ms / 1000.0,
        )
        self._queue: asyncio.Queue = asyncio.Queue()
        self._queued = 0          # admitted queries not yet dispatched
        self._inflight = 0        # queries inside the current dispatch
        self._rate_qps: Optional[float] = None   # EWMA of service rate
        self._publishing = False
        self._publish_pin = None  # LabelEpoch served while a publish runs
        # serializes engine-path dispatches against engine.refresh: a batch
        # that entered the engine just before a publish flipped the pin flag
        # must finish before the publish may swap label arrays under it
        self._engine_lock = threading.Lock()
        self._loop_task: Optional[asyncio.Task] = None
        self._pressure_task: Optional[asyncio.Task] = None

    def _count(self, key: str, n: int = 1) -> None:
        """Bump the per-instance counter AND its registry mirror, so the
        daemon report and ``metrics.snapshot()`` reconcile exactly."""
        self.counters[key] += n
        _COUNTER_METRICS[key].inc(n)

    # ---------------------------------------------------------- lifecycle

    async def start(self) -> None:
        if self._loop_task is not None:
            return
        self._loop_task = asyncio.ensure_future(self._run())
        if self.budget_ctl is not None and self.budget_ctl.pressure is not None:
            self._pressure_task = asyncio.ensure_future(self._pressure_loop())
        self.state = "ready"

    async def drain(self) -> dict:
        """Graceful shutdown: stop admitting, serve the admitted backlog,
        stop the loop.  Returns the final counters."""
        self.state = "draining"
        while self._queued > 0 or self._inflight > 0:
            await asyncio.sleep(self.cfg.batch_window_ms / 1000.0)
        await self._stop_loop()
        self.state = "stopped"
        return dict(self.counters)

    async def kill(self) -> None:
        """Abrupt stop (the chaos suite's mid-serve crash): the batch loop
        is cancelled mid-dispatch, and both queued and in-flight requests
        get ``shed[killed]`` — nothing drains."""
        await self._stop_pressure()
        if self._loop_task is not None:
            self._loop_task.cancel()
            try:
                await self._loop_task
            except asyncio.CancelledError:
                pass
            self._loop_task = None
        while not self._queue.empty():
            req = self._queue.get_nowait()
            if req is not None and not req.future.done():
                req.future.set_exception(ShedError("killed"))
                self._count("shed_killed", req.queries.shape[0])
                if ON.enabled:
                    trace.event("shed", cat="request", reason="killed",
                                trace_id=req.trace_id)
        self._queued = 0
        self.state = "killed"

    async def _stop_loop(self) -> None:
        await self._stop_pressure()
        if self._loop_task is None:
            return
        self._queue.put_nowait(None)   # sentinel unblocks the collector
        await self._loop_task
        self._loop_task = None

    async def _stop_pressure(self) -> None:
        if self._pressure_task is None:
            return
        self._pressure_task.cancel()
        try:
            await self._pressure_task
        except asyncio.CancelledError:
            pass
        self._pressure_task = None

    # ------------------------------------------------------- pressure loop

    async def _pressure_loop(self) -> None:
        """Poll the BudgetController between dispatch ticks.

        The tick runs in a worker thread UNDER ``_engine_lock`` — the same
        lock every engine-path dispatch holds — so a re-truncation always
        lands in the gap between two batches: the in-flight batch keeps the
        store view it captured at entry, the next batch sees the new one,
        and no batch is ever dropped or torn by a budget step."""
        interval = self.budget_ctl.pressure.check_interval_s
        loop = asyncio.get_running_loop()
        while True:
            await asyncio.sleep(interval)
            step = await loop.run_in_executor(None, self._pressure_tick)
            if step == "step_down":
                self._count("budget_steps_down")
            elif step == "step_up":
                self._count("budget_steps_up")

    def _pressure_tick(self) -> Optional[str]:
        with self._engine_lock:
            # a publish may have refreshed the engine (dropping the cut that
            # was made from the OLD labels) — re-assert the budget over the
            # newly published store before judging pressure
            self.budget_ctl.reapply()
            return self.budget_ctl.tick()

    # ---------------------------------------------------------- admission

    def _estimated_wait_s(self, n_new: int) -> float:
        """Expected time until a request submitted now is answered."""
        wait = self.cfg.batch_window_ms / 1000.0
        if self._rate_qps:
            wait += (self._queued + self._inflight + n_new) / self._rate_qps
        return wait

    async def submit(self, queries: np.ndarray,
                     deadline_ms: Optional[float] = None) -> np.ndarray:
        """Admit a request (int[B, 2] queries) and await its answers.

        Raises ``ShedError`` instead of queueing when the request cannot be
        served in budget — load shedding is the daemon telling the client
        *now* rather than timing out later."""
        queries = np.ascontiguousarray(np.asarray(queries, dtype=np.int32))
        n = int(queries.shape[0])
        self._count("submitted", n)
        # the admission span + trace id are the start of the request's
        # lifecycle in the exported timeline; sheds are terminal events on
        # the same id (guarded: this is the per-request hot path)
        tid = trace.new_trace_id() if ON.enabled else 0
        adm = trace.begin("admission", cat="request",
                          args={"trace_id": tid, "n": n}) if ON.enabled else None
        if self.state != "ready":
            self._count("shed_draining", n)
            if adm is not None:
                trace.end(adm)
                trace.event("shed", cat="request", reason="draining",
                            trace_id=tid)
            raise ShedError("draining", f"daemon state={self.state}")
        if self._queued + n > self.cfg.queue_limit:
            self._count("shed_queue_full", n)
            if adm is not None:
                trace.end(adm)
                trace.event("shed", cat="request", reason="queue_full",
                            trace_id=tid)
            raise ShedError("queue_full",
                            f"{self._queued} queued >= {self.cfg.queue_limit}")
        budget_s = (self.cfg.deadline_ms if deadline_ms is None
                    else float(deadline_ms)) / 1000.0
        if self._estimated_wait_s(n) > self.cfg.shed_headroom * budget_s:
            self._count("shed_deadline", n)
            if adm is not None:
                trace.end(adm)
                trace.event("shed", cat="request", reason="deadline",
                            trace_id=tid)
            raise ShedError("deadline",
                            f"est wait {self._estimated_wait_s(n) * 1000:.1f}ms "
                            f"> budget {budget_s * 1000:.0f}ms")
        now = time.monotonic()
        req = _Request(queries=queries, deadline=now + budget_s,
                       t_submit=now,
                       future=asyncio.get_running_loop().create_future(),
                       trace_id=tid)
        self._count("admitted", n)
        self._queued += n
        _QUEUE_DEPTH.set(self._queued)
        if adm is not None:
            trace.end(adm, admitted=True)
        self._queue.put_nowait(req)
        return await req.future

    # ------------------------------------------------------- batching loop

    async def _run(self) -> None:
        while True:
            req = await self._queue.get()
            if req is None:
                return
            batch = [req]
            size = req.queries.shape[0]
            t_end = time.monotonic() + self.cfg.batch_window_ms / 1000.0
            while size < self.cfg.max_batch:
                timeout = t_end - time.monotonic()
                if timeout <= 0:
                    break
                try:
                    nxt = await asyncio.wait_for(self._queue.get(), timeout)
                except asyncio.TimeoutError:
                    break
                if nxt is None:
                    await self._dispatch(batch)
                    return
                batch.append(nxt)
                size += nxt.queries.shape[0]
            await self._dispatch(batch)

    async def _dispatch(self, batch: List[_Request]) -> None:
        now = time.monotonic()
        live: List[_Request] = []
        for req in batch:
            self._queued -= req.queries.shape[0]
            if req.deadline <= now:
                # admitted but its budget died in the queue: serving it would
                # only push live requests past THEIR deadlines
                self._count("shed_expired", req.queries.shape[0])
                if ON.enabled:
                    # the queue span ends here, terminally: expiry event
                    self._queue_span(req, now, expired=True)
                    trace.event("shed", cat="request", reason="expired",
                                trace_id=req.trace_id)
                req.future.set_exception(ShedError("expired"))
            else:
                if ON.enabled:
                    self._queue_span(req, now, expired=False)
                live.append(req)
        _QUEUE_DEPTH.set(self._queued)
        if not live:
            return
        q = np.concatenate([r.queries for r in live], axis=0)
        n = int(q.shape[0])
        batch_deadline = min(r.deadline for r in live)
        self._inflight = n
        self._count("batches")
        tick = trace.begin(
            "dispatch_tick", cat="daemon",
            args={"n_requests": len(live), "n_queries": n,
                  "trace_ids": [r.trace_id for r in live]}) if ON.enabled else None
        loop = asyncio.get_running_loop()
        try:
            t0 = time.monotonic()
            answers = await loop.run_in_executor(
                None, self._dispatch_sync, q, batch_deadline)
            dt = time.monotonic() - t0
        except asyncio.CancelledError:
            # kill() cancelled the loop mid-dispatch: the worker thread will
            # finish on its own, but its requests are dead to the client
            for req in live:
                if not req.future.done():
                    req.future.set_exception(ShedError("killed"))
                    self._count("shed_killed", req.queries.shape[0])
                    if ON.enabled:
                        trace.event("shed", cat="request", reason="killed",
                                    trace_id=req.trace_id)
            self._inflight = 0
            trace.end(tick, outcome="killed")
            raise
        except Exception as e:
            # a rung below already warned; requests fail loudly, not wrongly
            for req in live:
                if not req.future.done():
                    req.future.set_exception(e)
            self._inflight = 0
            trace.end(tick, outcome=f"error:{type(e).__name__}")
            return
        self._inflight = 0
        _DISPATCH_MS.observe(dt * 1000.0)
        trace.end(tick, outcome="answered")
        inst = n / max(dt, 1e-9)
        self._rate_qps = (inst if self._rate_qps is None
                          else 0.7 * self._rate_qps + 0.3 * inst)
        done = time.monotonic()
        lo = 0
        for req in live:
            hi = lo + req.queries.shape[0]
            if not req.future.done():   # kill() may have failed it already
                self._count("answered", hi - lo)
                lat_s = done - req.t_submit
                self.latencies.append(lat_s)
                _REQ_LATENCY.observe(lat_s * 1000.0)
                if ON.enabled:
                    trace.event("completed", cat="request",
                                trace_id=req.trace_id,
                                latency_ms=round(lat_s * 1000.0, 3))
                req.future.set_result(answers[lo:hi])
            lo = hi

    def _queue_span(self, req: _Request, now: float, expired: bool) -> None:
        """Retroactive queue-wait span: submit -> the dispatch tick that
        picked the request up (or expired it)."""
        t0 = trace._now_us() - (now - req.t_submit) * 1e6
        trace.TRACER._complete(
            "queue", "request", t0, (now - req.t_submit) * 1e6,
            {"trace_id": req.trace_id, "expired": expired})

    def _pad(self, q: np.ndarray) -> np.ndarray:
        """Pad the batch to a power-of-two row count (floor 64, cap
        max_batch) by repeating the first query.  Dispatch sizes otherwise
        vary per tick, and every new size is a fresh device compile — a
        multi-hundred-ms stall that starves the admission loop.  Padding
        bounds the compiled-shape set to the ladder, so steady state pays
        compile once per rung.  Extra rows are real (duplicate) queries:
        verdicts stay exact; callers slice answers back to the true count."""
        n = int(q.shape[0])
        size = 64
        while size < n:
            size *= 2
        size = min(size, max(self.cfg.max_batch, n))
        if size == n:
            return q
        return np.concatenate([q, np.repeat(q[:1], size - n, axis=0)], axis=0)

    def _dispatch_sync(self, q: np.ndarray, deadline: float) -> np.ndarray:
        """One padded dispatch through breaker + ladder (worker thread)."""
        n = int(q.shape[0])
        q = self._pad(q)
        now = time.monotonic()
        if self._publishing and self._publish_pin is not None:
            # pinned-epoch rung: a publish is refreshing the engine right
            # now — serve from the epoch snapshot frozen at publish start
            self._count("pinned_epoch_batches")
            pin = self._publish_pin
            with trace.span("dispatch", cat="daemon",
                            args={"rung": "pinned_epoch", "padded": int(q.shape[0])}):
                try:
                    return pin.query_batch(q)[:n]
                except Exception:
                    self._count("pinned_device_to_host")
                    return pin.query_batch(q, device=False)[:n]
        use_device = (self.cfg.backend != "host"
                      and self.breaker.allow_device(now))
        with self._engine_lock:
            if not use_device:
                self._count("breaker_host_batches")
                with trace.span("dispatch", cat="daemon",
                                args={"rung": "host", "padded": int(q.shape[0]),
                                      "breaker": self.breaker.state}):
                    return self._serve(q, "host", deadline)[:n]
            self._count("device_batches")
            t0 = time.monotonic()
            with trace.span("dispatch", cat="daemon", annotate=True,
                            args={"rung": "device", "padded": int(q.shape[0])}):
                answers = self._serve(q, self.cfg.backend, deadline)
            dt = time.monotonic() - t0
            # failure signal for the breaker: the engine's ladder downgraded
            # the device dispatch (it already re-served the batch on the
            # host — answers are complete and correct), or the dispatch
            # blew the latency SLO
            degraded = self.engine.last_stats.get("degraded", {})
            device_failed = (degraded.get("device_to_host", 0) > 0
                             or degraded.get("deadline_to_host", 0) > 0)
        self.breaker.record(not device_failed and dt <= self.cfg.slo_s,
                            time.monotonic())
        return answers[:n]

    def _serve(self, q: np.ndarray, backend: Optional[str],
               deadline: float) -> np.ndarray:
        serve = getattr(self.target, "serve", None)
        if serve is not None:
            return serve(q, backend=backend, deadline=deadline)
        return self.engine.query_batch(q, backend=backend, deadline=deadline)

    # ------------------------------------------------------------ publish

    async def publish(self, update_batch=None) -> int:
        """Apply an update batch (optional) and publish a new epoch without
        stalling serving: the current epoch is pinned first, the publish
        runs in a worker thread, and every batch dispatched meanwhile routes
        to the pinned snapshot — an in-flight batch can never observe the
        engine mid-refresh."""
        if not self._dynamic:
            raise RuntimeError("publish() requires a dynamic oracle target")
        self._publish_pin = self.target.snapshot()
        self._publishing = True
        loop = asyncio.get_running_loop()

        def _apply_publish():
            # the engine lock lets at most one already-started engine-path
            # dispatch finish before the publish may refresh the engine;
            # batches formed after the pin flag flipped route to the pinned
            # snapshot and never contend here
            with self._engine_lock:
                if update_batch is not None:
                    self.target.apply(update_batch)
                return self.target.publish()

        try:
            with trace.span("daemon.publish", cat="daemon"):
                epoch = await loop.run_in_executor(None, _apply_publish)
        finally:
            self._publishing = False
            self._publish_pin = None
        self._count("publishes")
        return int(epoch)

    # ------------------------------------------------------------- health

    def _latency_pctiles(self) -> dict:
        if not self.latencies:
            return {"p50_ms": None, "p99_ms": None}
        arr = np.asarray(self.latencies)
        return {"p50_ms": round(float(np.quantile(arr, 0.5)) * 1000, 3),
                "p99_ms": round(float(np.quantile(arr, 0.99)) * 1000, 3)}

    def health(self) -> dict:
        """Health/readiness snapshot: daemon state + breaker + queue +
        latency + the engine's consistent ``stats()`` snapshot (degradation
        counters included) — everything an operator needs to tell "shedding
        under overload" from "serving garbage"."""
        now = time.monotonic()
        c = self.counters
        shed = (c["shed_queue_full"] + c["shed_deadline"]
                + c["shed_draining"] + c["shed_expired"] + c["shed_killed"])
        return {
            "state": self.state,
            "ready": self.state == "ready",
            "dynamic": self._dynamic,
            "epoch": int(getattr(self.target, "epoch", self.engine.epoch)),
            "publishing": self._publishing,
            "queue_depth": self._queued,
            "inflight": self._inflight,
            "service_rate_qps": None if self._rate_qps is None else round(self._rate_qps),
            "shed_total": shed,
            "shed_rate": round(shed / c["submitted"], 4) if c["submitted"] else 0.0,
            "breaker": self.breaker.snapshot(now),
            "counters": dict(c),
            "latency": self._latency_pctiles(),
            "budget": (None if self.budget_ctl is None
                       else self.budget_ctl.snapshot()),
            "engine": self.engine.stats(),
            # the process-global registry: one surface over daemon, engine,
            # build, dynamic, and fault-injection metrics
            "metrics": metrics.snapshot(),
        }
