"""Open-loop Poisson workload driver for the serving daemon.

Closed-loop drivers (issue the next batch when the last one returns)
self-throttle: an overloaded server just makes the driver slower, and the
throughput number silently degrades to "whatever the server felt like".
This driver is open-loop — arrivals follow a Poisson process whose rate does
NOT react to service times — so overload has to go *somewhere*: the queue,
the shed counters, or the latency tail.  The report makes each explicit:

    sustained_qps   answered queries / duration (capacity actually served)
    shed_rate       queries refused or expired / queries submitted
    p50/p99_ms      latency of ANSWERED (admitted) queries, arrival->answer
    degradation     the engine ladder + breaker counters over the run

Used by ``benchmarks/serve_sweep.py`` (BENCH_serve.json open-loop rows),
``repro.launch.serve --mode daemon``, and the chaos daemon scenario.
"""
from __future__ import annotations

import asyncio
import time
from typing import Dict, List, Optional

import numpy as np

from repro.ft import inject
from repro.obs import trace
from repro.obs.state import ON
from repro.serve.daemon import DaemonConfig, ServeDaemon, ShedError


def check_truth(g, queries: np.ndarray, answers: np.ndarray,
                limit: int = 200) -> int:
    """Wrong-answer count vs BFS ground truth on up to ``limit`` queries
    (grouped by source so each distinct u costs one reachable_set)."""
    from repro.graph.reach import reachable_set

    wrong = 0
    reach_cache: Dict[int, np.ndarray] = {}
    for i in range(min(limit, queries.shape[0])):
        u, v = int(queries[i, 0]), int(queries[i, 1])
        if u not in reach_cache:
            reach_cache[u] = reachable_set(g, u)
        truth = bool(reach_cache[u][v]) or u == v
        wrong += truth != bool(answers[i])
    return wrong


async def _drive(daemon: ServeDaemon, arrivals: np.ndarray,
                 queries: List[np.ndarray], deadline_ms: float,
                 answered: list, shed: Dict[str, int]) -> None:
    t0 = time.monotonic()

    async def one(i: int) -> None:
        t_arr = t0 + float(arrivals[i])
        delay = t_arr - time.monotonic()
        if delay > 0:
            await asyncio.sleep(delay)
        try:
            ans = await daemon.submit(queries[i], deadline_ms=deadline_ms)
            # latency from the INTENDED arrival instant: loop scheduling
            # jitter is queueing too in a single-process harness
            answered.append((i, ans, time.monotonic() - t_arr))
        except ShedError as e:
            shed[e.reason] = shed.get(e.reason, 0) + queries[i].shape[0]

    await daemon.start()
    await asyncio.gather(*(one(i) for i in range(arrivals.shape[0])))
    await daemon.drain()


def run_open_loop(
    target,
    g,
    *,
    rate_arrivals_per_s: float = 400.0,
    arrival_batch: int = 64,
    duration_s: float = 2.0,
    deadline_ms: float = 150.0,
    config: Optional[DaemonConfig] = None,
    fault_plan: Optional[inject.Injector] = None,
    seed: int = 0,
    n_truth: int = 200,
    budget_ctl=None,
) -> dict:
    """Drive ``target`` (CondensedOracle / DynamicOracle) through an
    open-loop Poisson run; returns the BENCH-row report dict.

    ``fault_plan`` (an ``inject.Injector``, latency rules included) is
    active for the whole run, so device faults hit the daemon's real
    dispatch path — this is how the faulted BENCH row proves the ladder
    holds p99 bounded while shedding instead of collapsing.

    ``budget_ctl`` (a ``serve.budget.BudgetController``) serves the run
    under a memory budget; when it carries a PressureConfig the daemon's
    pressure loop runs live, and the report's ``budget`` section records
    the governor's final state (steps taken, resident bytes)."""
    # deferred: repro.dynamic imports repro.build which imports repro.serve —
    # a module-level import here would close that cycle
    from repro.dynamic.workload import poisson_times

    cfg = config or DaemonConfig(deadline_ms=deadline_ms)
    rng = np.random.default_rng(seed)
    arrivals = poisson_times(rate_arrivals_per_s, duration_s, seed=seed)
    queries = [rng.integers(0, g.n, size=(arrival_batch, 2)).astype(np.int32)
               for _ in range(arrivals.shape[0])]

    daemon = ServeDaemon(target, cfg, budget_ctl=budget_ctl)
    # warm every rung of the daemon's padded-dispatch ladder before the
    # clock starts (outside any fault plan, so injected occurrences hit the
    # measured run): each distinct batch shape pays device compile —
    # hundreds of ms — which would otherwise stall the queue mid-run and
    # expire a wave of arrivals that says nothing about steady-state
    # overload behavior
    warm_sp = (trace.span("openloop.warmup", cat="openloop",
                          args={"max_batch": cfg.max_batch})
               if ON.enabled else trace.NOOP_SPAN)
    with warm_sp:
        size = 64
        while True:
            wq = rng.integers(0, g.n, size=(min(size, cfg.max_batch), 2)).astype(
                np.int32)
            daemon.engine.query_batch(wq, backend=cfg.backend)
            if size >= cfg.max_batch:
                break
            size *= 2
    daemon.engine.reset_stats()
    answered: list = []
    shed: Dict[str, int] = {}
    drive_sp = (trace.span("openloop.drive", cat="openloop",
                           args={"rate": rate_arrivals_per_s,
                                 "duration_s": duration_s,
                                 "n_arrivals": int(arrivals.shape[0]),
                                 "faulted": fault_plan is not None})
                if ON.enabled else trace.NOOP_SPAN)
    t0 = time.perf_counter()
    with drive_sp:
        if fault_plan is not None:
            with inject.active(fault_plan):
                asyncio.run(_drive(daemon, arrivals, queries, deadline_ms,
                                   answered, shed))
        else:
            asyncio.run(_drive(daemon, arrivals, queries, deadline_ms,
                               answered, shed))
    wall_s = time.perf_counter() - t0

    c = daemon.counters
    n_answered = int(c["answered"])
    # the daemon's counters are authoritative (client-side reasons overlap
    # with shed_expired: the client sees those as ShedError too)
    n_shed = int(c["shed_queue_full"] + c["shed_deadline"]
                 + c["shed_draining"] + c["shed_expired"] + c["shed_killed"])
    lat = np.asarray([la for _, _, la in answered]) if answered else np.zeros(1)
    p50_ms = float(np.quantile(lat, 0.5)) * 1000
    p99_ms = float(np.quantile(lat, 0.99)) * 1000

    sample_errors = 0
    if answered and n_truth > 0:
        rep_sp = (trace.span("openloop.report", cat="openloop",
                             args={"n_truth": n_truth})
                  if ON.enabled else trace.NOOP_SPAN)
        with rep_sp:
            aq = np.concatenate([queries[i] for i, _, _ in answered], axis=0)
            aa = np.concatenate([a for _, a, _ in answered], axis=0)
            pick = rng.choice(aq.shape[0], size=min(n_truth, aq.shape[0]),
                              replace=False)
            sample_errors = check_truth(g, aq[pick], aa[pick], limit=n_truth)

    health = daemon.health()
    return {
        "rate_arrivals_per_s": rate_arrivals_per_s,
        "arrival_batch": int(arrival_batch),
        "offered_qps": round(rate_arrivals_per_s * arrival_batch),
        "duration_s": duration_s,
        "deadline_ms": deadline_ms,
        "n_arrivals": int(arrivals.shape[0]),
        "submitted": int(c["submitted"]),
        "answered": n_answered,
        "sustained_qps": round(n_answered / max(wall_s, 1e-9)),
        "shed": {k[len("shed_"):]: int(v) for k, v in c.items()
                 if k.startswith("shed_") and v},
        "shed_rate": round(n_shed / max(int(c["submitted"]), 1), 4),
        "p50_ms": round(p50_ms, 2),
        "p99_ms": round(p99_ms, 2),
        "p99_within_deadline": bool(p99_ms <= deadline_ms),
        "breaker": {"trips": daemon.breaker.trips,
                    "final_state": daemon.breaker.state},
        "batches": int(c["batches"]),
        "device_batches": int(c["device_batches"]),
        "breaker_host_batches": int(c["breaker_host_batches"]),
        "degradation": health["engine"]["degradation"],
        "budget": health["budget"],
        "faults": (None if fault_plan is None else
                   {"failed": list(fault_plan.fired),
                    "stalled": list(fault_plan.stalled)}),
        "sample_errors": int(sample_errors),
    }
