"""Checksummed save/load of ReachabilityOracle and LabelEpoch snapshots.

Label matrices are split into fixed-size ROW BLOCKS, each with its own
CRC32 (``persist.blocks``), so a corrupt block quarantines the rows it
backs instead of the whole index: ``load_oracle(path, strict=False)``
returns the oracle with those rows zeroed PLUS a ``LoadReport`` whose
``quarantine_out`` / ``quarantine_in`` masks name them — the serve engine
routes queries touching a quarantined row down its degradation ladder
(bounded online search) so corruption degrades throughput, never
correctness.  ``strict=True`` (default) refuses to load at all, with the
checksum diagnostic.

Per-row-block corruption semantics by block kind:

  * ``L_out.<k>`` / ``L_in.<k>`` row blocks -> quarantine those rows,
  * ``out_len`` / ``in_len`` -> the whole side is untrustworthy ->
    quarantine every row of that side,
  * ``hop_rank`` -> only affects ``unrank`` (observability), dropped with
    a warning,
  * an epoch's ``comp`` -> fatal even non-strict (there is no safe
    fallback for the vertex -> condensation map),
  * an epoch's ``level`` -> the level prefilter is disabled (``None``),
    queries fall through to the intersection paths,
  * a budgeted store's ``trunc_mask_out`` / ``trunc_mask_in`` -> that whole
    side is treated as truncated (all-True mask): truncation marks only
    route misses to the exact-search rung, so over-marking is always safe.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import List, Optional, Tuple

import numpy as np

from repro.persist.blocks import CorruptSnapshotError, load_blocks, save_blocks

ROW_BLOCK = 4096


@dataclasses.dataclass
class LoadReport:
    """What a non-strict load had to quarantine."""
    bad_blocks: List[str]
    quarantine_out: np.ndarray  # bool[n] — L_out rows that must not be trusted
    quarantine_in: np.ndarray   # bool[n]

    @property
    def clean(self) -> bool:
        return not self.bad_blocks


def _split_rows(name: str, mat: np.ndarray, row_block: int) -> dict:
    return {
        f"{name}.{k:05d}": mat[k * row_block: (k + 1) * row_block]
        for k in range((mat.shape[0] + row_block - 1) // row_block or 1)
    }


def _oracle_arrays(oracle, row_block: int) -> Tuple[dict, dict]:
    arrays = {}
    arrays.update(_split_rows("L_out", oracle.L_out, row_block))
    arrays.update(_split_rows("L_in", oracle.L_in, row_block))
    arrays["out_len"] = oracle.out_len
    arrays["in_len"] = oracle.in_len
    if oracle.hop_rank is not None:
        arrays["hop_rank"] = oracle.hop_rank
    meta = {
        "kind": "ReachabilityOracle",
        "n": oracle.n,
        "lo_width": int(oracle.L_out.shape[1]),
        "li_width": int(oracle.L_in.shape[1]),
        "row_block": int(row_block),
        "has_hop_rank": oracle.hop_rank is not None,
    }
    return arrays, meta


def save_oracle(path: str, oracle, row_block: int = ROW_BLOCK, extra_meta: Optional[dict] = None) -> str:
    """Atomic, checksummed snapshot of a finalized oracle."""
    arrays, meta = _oracle_arrays(oracle, row_block)
    if extra_meta:
        meta.update(extra_meta)
    return save_blocks(path, arrays, meta)


def _assemble_side(name, arrays, meta, n, width, bad_rows):
    """Reassemble one label matrix from its row blocks; quarantine holes."""
    rb = int(meta["row_block"])
    mat = np.zeros((n, width), dtype=np.int32)
    for k in range((n + rb - 1) // rb or 1):
        blk = arrays.get(f"{name}.{k:05d}")
        lo, hi = k * rb, min((k + 1) * rb, n)
        if blk is None:
            bad_rows[lo:hi] = True
        elif blk.shape[0]:
            mat[lo:hi] = blk
    return mat


def _load_oracle_parts(arrays, meta, bad):
    from repro.core.oracle import ReachabilityOracle

    n = int(meta["n"])
    q_out = np.zeros(n, dtype=bool)
    q_in = np.zeros(n, dtype=bool)
    L_out = _assemble_side("L_out", arrays, meta, n, int(meta["lo_width"]), q_out)
    L_in = _assemble_side("L_in", arrays, meta, n, int(meta["li_width"]), q_in)
    out_len = arrays.get("out_len")
    in_len = arrays.get("in_len")
    if out_len is None:  # lengths gone: the whole side is untrustworthy
        q_out[:] = True
        out_len = np.zeros(n, dtype=np.int32)
    if in_len is None:
        q_in[:] = True
        in_len = np.zeros(n, dtype=np.int32)
    hop_rank = arrays.get("hop_rank") if meta.get("has_hop_rank") else None
    if meta.get("has_hop_rank") and hop_rank is None:
        warnings.warn("snapshot hop_rank block corrupt: unrank() unavailable",
                      stacklevel=3)
    oracle = ReachabilityOracle(
        L_out=L_out, L_in=L_in,
        out_len=np.asarray(out_len, dtype=np.int32),
        in_len=np.asarray(in_len, dtype=np.int32),
        hop_rank=None if hop_rank is None else np.asarray(hop_rank, dtype=np.int32),
    )
    return oracle, LoadReport(bad_blocks=list(bad), quarantine_out=q_out, quarantine_in=q_in)


def load_oracle(path: str, strict: bool = True):
    """Load + verify an oracle snapshot.

    ``strict=True``: returns the oracle, raises ``CorruptSnapshotError`` on
    ANY checksum mismatch.  ``strict=False``: returns ``(oracle, report)``
    with corrupt row blocks zeroed and quarantined in the report."""
    arrays, meta, bad = load_blocks(path, strict=strict)
    if meta.get("kind") != "ReachabilityOracle":
        raise CorruptSnapshotError(
            f"{path}: expected a ReachabilityOracle snapshot, found {meta.get('kind')!r}")
    oracle, report = _load_oracle_parts(arrays, meta, bad)
    return oracle if strict else (oracle, report)


# ------------------------------------------------ budget-truncated stores

def save_budgeted(path: str, store, row_block: int = ROW_BLOCK) -> str:
    """Snapshot a ``serve.budget.TruncatedStore``: the truncated oracle's
    row blocks plus its packed truncation masks as their own block kind
    (``trunc_mask_out`` / ``trunc_mask_in``), so a budgeted serving tier
    can restart straight into its cut without re-truncating — or without
    ever holding the full store (edge hosts)."""
    arrays, meta = _oracle_arrays(store.oracle, row_block)
    packed_out, packed_in = store.packed_masks()
    arrays["trunc_mask_out"] = packed_out
    arrays["trunc_mask_in"] = packed_in
    meta.update(
        kind="BudgetedOracle",
        rank_cut=int(store.rank_cut),
        budget_bytes=int(store.budget_bytes),
        resident_bytes=int(store.resident_bytes),
        dropped_ints=int(store.dropped_ints),
    )
    return save_blocks(path, arrays, meta)


def load_budgeted(path: str, strict: bool = True):
    """Load + verify a budget-truncated store (see ``load_oracle`` for the
    strictness contract).

    Corruption semantics COMPOSE with the row-block semantics above: label
    row blocks quarantine exactly as in ``load_oracle`` (the report's masks
    feed ``QueryEngine.set_quarantine`` as usual), while a corrupt
    truncation-MASK block conservatively marks every row of that side as
    truncated.  Over-marking is safe by construction — truncation marks
    only ever route more label misses to the exact-search rung, so a lost
    mask costs latency, never a wrong verdict."""
    from repro.serve.budget import TruncatedStore, unpack_mask

    arrays, meta, bad = load_blocks(path, strict=strict)
    if meta.get("kind") != "BudgetedOracle":
        raise CorruptSnapshotError(
            f"{path}: expected a BudgetedOracle snapshot, found {meta.get('kind')!r}")
    oracle, report = _load_oracle_parts(arrays, meta, bad)
    n = int(meta["n"])

    def _mask(name: str) -> np.ndarray:
        blk = arrays.get(name)
        if blk is None:
            warnings.warn(
                f"{path}: {name} block corrupt; treating every row of that "
                "side as truncated (conservative: misses route to search)",
                stacklevel=2)
            return np.ones(n, dtype=bool)
        return unpack_mask(blk, n)

    store = TruncatedStore(
        oracle=oracle,
        truncated_out=_mask("trunc_mask_out"),
        truncated_in=_mask("trunc_mask_in"),
        rank_cut=int(meta["rank_cut"]),
        budget_bytes=int(meta["budget_bytes"]),
        resident_bytes=int(meta.get("resident_bytes", 0)),
        dropped_ints=int(meta.get("dropped_ints", 0)),
    )
    return store if strict else (store, report)


# ------------------------------------------------------------- LabelEpoch

def save_epoch(path: str, epoch, row_block: int = ROW_BLOCK) -> str:
    """Snapshot a ``repro.dynamic.versioned.LabelEpoch`` (oracle + comp +
    level + epoch number) in one checksummed directory."""
    arrays, meta = _oracle_arrays(epoch.oracle, row_block)
    arrays["comp"] = np.asarray(epoch.comp, dtype=np.int32)
    arrays["level"] = np.asarray(epoch.level, dtype=np.int32)
    meta.update(kind="LabelEpoch", epoch=int(epoch.epoch))
    return save_blocks(path, arrays, meta)


def load_epoch(path: str, strict: bool = True):
    """Load + verify a LabelEpoch snapshot (see ``load_oracle`` for the
    strictness contract).  A corrupt ``comp`` block is fatal regardless of
    ``strict`` — there is no safe fallback for the id map."""
    from repro.dynamic.versioned import LabelEpoch

    arrays, meta, bad = load_blocks(path, strict=strict)
    if meta.get("kind") != "LabelEpoch":
        raise CorruptSnapshotError(
            f"{path}: expected a LabelEpoch snapshot, found {meta.get('kind')!r}")
    comp = arrays.get("comp")
    if comp is None:
        raise CorruptSnapshotError(
            f"{path}: comp block corrupt — a LabelEpoch cannot serve without "
            "its vertex->condensation map")
    level = arrays.get("level")
    if level is None:
        warnings.warn(f"{path}: level block corrupt; level prefilter disabled",
                      stacklevel=2)
    oracle, report = _load_oracle_parts(arrays, meta, bad)
    ep = LabelEpoch(
        epoch=int(meta["epoch"]),
        oracle=oracle,
        comp=np.asarray(comp, dtype=np.int32),
        level=None if level is None else np.asarray(level, dtype=np.int32),
    )
    return ep if strict else (ep, report)
