"""Verified persistence for the oracle lifecycle.

Everything durable in the repo routes through this package:

  * ``blocks`` — the storage primitive: a directory of named array blocks,
    CRC32 per block + a manifest hash over the block table, written
    temp-then-rename so a crash mid-save never corrupts the previous
    snapshot.  Loads verify every checksum; ``strict=False`` quarantines
    bad blocks instead of raising (the serve-path degradation ladder's
    input).
  * ``oracle_io`` — checksummed save/load of ``ReachabilityOracle`` and
    ``LabelEpoch`` snapshots (label matrices split into row blocks so
    corruption quarantines a block of rows, not the whole index).
  * ``wal`` — the write-ahead log for dynamic edge updates: fixed-width
    CRC-framed records, torn-tail truncation on replay, seq-addressed so
    recovery replays exactly the records after the last snapshot.

The build engine's wave-granular checkpoints (``repro.build.engine``) and
the durable dynamic oracle (``repro.dynamic.durable``) are the two big
consumers.
"""
from repro.persist.blocks import (
    CorruptSnapshotError,
    load_blocks,
    pack_ragged,
    save_blocks,
    snapshot_meta,
    unpack_ragged,
)
from repro.persist.oracle_io import (
    LoadReport,
    load_budgeted,
    load_epoch,
    load_oracle,
    save_budgeted,
    save_epoch,
    save_oracle,
)
from repro.persist.wal import WalRecord, WriteAheadLog

__all__ = [
    "CorruptSnapshotError",
    "save_blocks",
    "load_blocks",
    "snapshot_meta",
    "pack_ragged",
    "unpack_ragged",
    "save_oracle",
    "load_oracle",
    "save_epoch",
    "load_epoch",
    "save_budgeted",
    "load_budgeted",
    "LoadReport",
    "WriteAheadLog",
    "WalRecord",
]
