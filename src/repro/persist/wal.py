"""Write-ahead log for dynamic oracle edge updates.

Record format (fixed width, little-endian)::

    <B  kind    0=delete edge, 1=insert edge, 2=publish marker
    <q  u       source vertex (publish: the epoch number)
    <q  v       target vertex (publish: unused, -1)
    <q  seq     monotonically increasing sequence number
    <I  crc32   over the 25 payload bytes above

Recovery contract: ``DurableDynamicOracle`` appends every edge update to
the WAL (fsync'd) *before* applying it in memory, and drops a publish
marker right after each successful publish + snapshot.  After a crash,
the oracle = latest snapshot + ``replay(after_seq=snapshot_seq)``.

A torn tail (partial last record from a crash mid-append, or a corrupt
record) truncates the log at the last good record with a warning — records
before the tear are intact because each carries its own CRC.  A corrupt
record *followed by good ones* is different: that is not a torn write but
real corruption, and replay refuses it loudly (``CorruptSnapshotError``)
rather than silently dropping updates from the middle of history.
"""
from __future__ import annotations

import dataclasses
import os
import struct
import warnings
import zlib
from typing import Iterator, List, Optional

from repro.persist.blocks import CorruptSnapshotError

_PAYLOAD = struct.Struct("<Bqqq")
_CRC = struct.Struct("<I")
RECORD_SIZE = _PAYLOAD.size + _CRC.size  # 29 bytes

KIND_DELETE = 0
KIND_INSERT = 1
KIND_PUBLISH = 2


@dataclasses.dataclass(frozen=True)
class WalRecord:
    kind: int
    u: int
    v: int
    seq: int

    @property
    def is_publish(self) -> bool:
        return self.kind == KIND_PUBLISH

    def encode(self) -> bytes:
        payload = _PAYLOAD.pack(self.kind, self.u, self.v, self.seq)
        return payload + _CRC.pack(zlib.crc32(payload) & 0xFFFFFFFF)

    @classmethod
    def decode(cls, raw: bytes) -> "WalRecord":
        payload, (crc,) = raw[:_PAYLOAD.size], _CRC.unpack(raw[_PAYLOAD.size:])
        if zlib.crc32(payload) & 0xFFFFFFFF != crc:
            raise ValueError("wal record crc mismatch")
        return cls(*_PAYLOAD.unpack(payload))


class WriteAheadLog:
    """Append-only, CRC-framed, fsync'd edge-update log."""

    def __init__(self, path: str):
        self.path = path
        self.last_seq = -1
        self._f = None
        self._scan()
        self._f = open(self.path, "ab")

    # -------------------------------------------------------------- write

    def append(self, kind: int, u: int, v: int) -> int:
        """Log one record durably (fsync before returning); returns its seq."""
        seq = self.last_seq + 1
        self._f.write(WalRecord(kind, u, v, seq).encode())
        self._f.flush()
        os.fsync(self._f.fileno())
        self.last_seq = seq
        return seq

    def publish_marker(self, epoch: int) -> int:
        """Mark that every record up to here is covered by epoch ``epoch``'s
        snapshot (replay splits batches at these)."""
        return self.append(KIND_PUBLISH, int(epoch), -1)

    def reset(self) -> None:
        """Truncate the log (the snapshot now covers everything)."""
        self._f.close()
        self._f = open(self.path, "wb")
        self.last_seq = -1

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    # --------------------------------------------------------------- read

    def _scan(self) -> None:
        """Find last_seq on open (tolerating a torn tail)."""
        for rec in self._read(truncate_torn=True):
            self.last_seq = rec.seq

    def _read(self, truncate_torn: bool) -> Iterator[WalRecord]:
        if not os.path.exists(self.path):
            return
        with open(self.path, "rb") as f:
            raw = f.read()
        n_full = len(raw) // RECORD_SIZE
        torn_at: Optional[int] = None
        records: List[WalRecord] = []
        for i in range(n_full):
            chunk = raw[i * RECORD_SIZE: (i + 1) * RECORD_SIZE]
            try:
                records.append(WalRecord.decode(chunk))
            except ValueError:
                torn_at = i
                break
        else:
            if len(raw) % RECORD_SIZE:
                torn_at = n_full
        if torn_at is not None:
            # corruption in the middle of history (good records after the bad
            # one) is not a torn write — refuse instead of dropping updates
            tail = raw[(torn_at + 1) * RECORD_SIZE:]
            for j in range(len(tail) // RECORD_SIZE):
                try:
                    WalRecord.decode(tail[j * RECORD_SIZE: (j + 1) * RECORD_SIZE])
                except ValueError:
                    continue
                raise CorruptSnapshotError(
                    f"wal {self.path}: corrupt record #{torn_at} followed by "
                    f"intact records — mid-log corruption, refusing to replay")
            if not truncate_torn:
                raise CorruptSnapshotError(
                    f"wal {self.path}: torn record #{torn_at}")
            warnings.warn(
                f"wal {self.path}: torn tail at record #{torn_at} "
                f"(byte {torn_at * RECORD_SIZE}); truncating", stacklevel=3)
            with open(self.path, "r+b") as f:
                f.truncate(torn_at * RECORD_SIZE)
        yield from records

    def replay(self, after_seq: int = -1) -> List[WalRecord]:
        """All intact records with ``seq > after_seq``, in order (the torn
        tail, if any, is truncated with a warning first)."""
        return [r for r in self._read(truncate_torn=True) if r.seq > after_seq]
