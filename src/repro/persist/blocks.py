"""Checksummed array-block snapshots (the persistence primitive).

Layout of one snapshot directory::

    <dir>/manifest.json        block table + user meta + manifest hash
    <dir>/<block>.npy          one numpy array per named block

Write protocol: everything lands in ``<dir>.tmp`` first, then one
``os.rename`` publishes the snapshot (same posture as ``checkpoint/ckpt.py``)
— a crash mid-save leaves the previous snapshot untouched and at worst a
stale ``.tmp`` that the next save clears.

Read protocol: the manifest's own SHA-256 is verified first (a corrupt
block table cannot be trusted to name its blocks), then every block's CRC32.
``strict=True`` (default) raises ``CorruptSnapshotError`` naming the block,
the expected and the observed checksum — loud failure, never garbage
arrays.  ``strict=False`` returns the readable blocks and the list of bad
ones, which is what the serve path's degradation ladder consumes
(quarantine the rows backed by a bad block, keep serving the rest).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import warnings
import zlib
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.ft import inject

MANIFEST_NAME = "manifest.json"
_FORMAT = 1


class CorruptSnapshotError(RuntimeError):
    """A snapshot failed checksum verification (the diagnostic names the
    block and both checksums — this error must stay loud, never be turned
    into a default value)."""


def _canonical(obj) -> bytes:
    return json.dumps(obj, sort_keys=True, separators=(",", ":")).encode()


def save_blocks(path: str, arrays: Dict[str, np.ndarray], meta: Optional[dict] = None) -> str:
    """Atomically write ``arrays`` as a checksummed snapshot at ``path``.

    Block names become file names (keep them to ``[A-Za-z0-9._-]``).
    Returns the final path."""
    meta = dict(meta or {})
    tmp = path.rstrip("/") + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    table = {}
    for name, arr in arrays.items():
        if "/" in name or name.startswith("."):
            raise ValueError(f"bad block name {name!r}")
        fname = f"{name}.npy"
        fpath = os.path.join(tmp, fname)
        np.save(fpath, np.ascontiguousarray(arr), allow_pickle=False)
        with open(fpath, "rb") as f:
            raw = f.read()
        table[name] = {
            "file": fname,
            "crc32": zlib.crc32(raw) & 0xFFFFFFFF,
            "nbytes": len(raw),
            "dtype": str(arr.dtype),
            "shape": list(np.asarray(arr).shape),
        }
    body = {"format": _FORMAT, "meta": meta, "blocks": table}
    manifest = dict(body, manifest_sha256=hashlib.sha256(_canonical(body)).hexdigest())
    with open(os.path.join(tmp, MANIFEST_NAME), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    # chaos hook: a crash here must leave the previous snapshot intact
    inject.fire("persist.pre_rename", path=path)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    return path


def load_blocks(
    path: str,
    strict: bool = True,
    only: Optional[Iterable[str]] = None,
) -> Tuple[Dict[str, Optional[np.ndarray]], dict, List[str]]:
    """Load a snapshot, verifying every checksum.

    Returns ``(arrays, meta, bad_blocks)``.  With ``strict=True`` any
    corruption raises ``CorruptSnapshotError`` and ``bad_blocks`` is always
    empty; with ``strict=False`` unreadable blocks come back as ``None`` and
    are listed in ``bad_blocks``.  ``only`` restricts which blocks are read
    (manifest + meta are always verified in full)."""
    mpath = os.path.join(path, MANIFEST_NAME)
    if not os.path.isfile(mpath):
        raise CorruptSnapshotError(f"no manifest at {mpath}: not a snapshot")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CorruptSnapshotError(f"unreadable manifest {mpath}: {e}") from e
    claimed = manifest.get("manifest_sha256")
    body = {k: manifest[k] for k in ("format", "meta", "blocks") if k in manifest}
    actual = hashlib.sha256(_canonical(body)).hexdigest()
    if claimed != actual:
        # a tampered block table could point checksums at the wrong files;
        # nothing downstream is trustworthy, so this is fatal even non-strict
        raise CorruptSnapshotError(
            f"manifest hash mismatch at {mpath}: manifest says {claimed}, "
            f"content hashes to {actual}")
    arrays: Dict[str, Optional[np.ndarray]] = {}
    bad: List[str] = []
    names = set(only) if only is not None else None
    for name, entry in manifest["blocks"].items():
        if names is not None and name not in names:
            continue
        fpath = os.path.join(path, entry["file"])
        err = None
        raw = None
        if not os.path.isfile(fpath):
            err = "block file missing"
        else:
            with open(fpath, "rb") as f:
                raw = f.read()
            crc = zlib.crc32(raw) & 0xFFFFFFFF
            if crc != entry["crc32"]:
                err = (f"crc mismatch: manifest 0x{entry['crc32']:08x}, "
                       f"file 0x{crc:08x} over {len(raw)} bytes")
        if err is None:
            try:
                arr = np.load(fpath, allow_pickle=False)
            except Exception as e:  # crc passed but npy parse failed
                err = f"undecodable npy: {e}"
            else:
                arrays[name] = arr
                continue
        diag = f"snapshot block '{name}' at {fpath}: {err}"
        if strict:
            raise CorruptSnapshotError(diag)
        warnings.warn(f"quarantining {diag}", stacklevel=2)
        arrays[name] = None
        bad.append(name)
    return arrays, manifest["meta"], bad


def snapshot_meta(path: str) -> dict:
    """Read just the (verified) meta dict of a snapshot."""
    _, meta, _ = load_blocks(path, strict=True, only=())
    return meta


# ---------------------------------------------------------------- ragged

def pack_ragged(rows: Sequence[Sequence[int]], dtype=np.int32) -> Tuple[np.ndarray, np.ndarray]:
    """Python list-of-lists -> (values, offsets int64[k+1]) block pair."""
    offsets = np.zeros(len(rows) + 1, dtype=np.int64)
    np.cumsum([len(r) for r in rows], out=offsets[1:])
    if offsets[-1]:
        values = np.concatenate([np.asarray(r, dtype=dtype) for r in rows if len(r)])
    else:
        values = np.empty(0, dtype=dtype)
    return values.astype(dtype, copy=False), offsets


def unpack_ragged(values: np.ndarray, offsets: np.ndarray) -> List[list]:
    """Inverse of ``pack_ragged`` (plain python lists)."""
    return [values[offsets[i]: offsets[i + 1]].tolist()
            for i in range(offsets.shape[0] - 1)]
