"""AdamW with fp32 master state over (possibly bf16) params, global-norm
clipping, and warmup+cosine schedule. Pure pytree functions (no optax dep)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any       # fp32 first moment (same tree as params)
    nu: Any       # fp32 second moment
    master: Any   # fp32 master copy of params


def adamw_init(params) -> AdamWState:
    f32 = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(f32, params),
        nu=jax.tree.map(f32, params),
        master=jax.tree.map(lambda p: p.astype(jnp.float32), params),
    )


def cosine_schedule(step, base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    warm = base_lr * (step + 1) / max(warmup, 1)
    progress = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * progress)))
    return jnp.where(step < warmup, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(
    grads,
    state: AdamWState,
    params,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
):
    """Returns (new_params, new_state, metrics)."""
    g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = global_norm(g32)
    scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-9))
    g32 = jax.tree.map(lambda g: g * scale, g32)

    step = state.step + 1
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, g32)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, g32)

    def upd(p32, m, v):
        mhat = m / bc1
        vhat = v / bc2
        return p32 - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p32)

    master = jax.tree.map(upd, state.master, mu, nu)
    new_params = jax.tree.map(lambda m, p: m.astype(p.dtype), master, params)
    return (
        new_params,
        AdamWState(step=step, mu=mu, nu=nu, master=master),
        {"grad_norm": gnorm, "lr": lr},
    )
