"""Gradient compression for the data-parallel all-reduce.

int8 block-quantized psum inside shard_map: each DP rank quantizes its local
gradient shard to int8 with a per-block fp32 scale, psums the int8 payload
(4x less ICI traffic than fp32, 2x less than bf16), then dequantizes. A
stochastic-rounding variant keeps the estimator unbiased.

This targets the collective roofline term of DP-heavy cells; dryrun variants
toggle it to measure the collective-bytes delta.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp


def _quantize_int8(x: jnp.ndarray, key: jnp.ndarray | None, block: int = 256):
    """x f32[...] -> (q int8[...], scale f32[blocks]) with per-block absmax."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    scaled = blocks / scale
    if key is not None:  # stochastic rounding (unbiased)
        noise = jax.random.uniform(key, scaled.shape) - 0.5
        q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    else:
        q = jnp.clip(jnp.round(scaled), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, shape, block: int = 256):
    flat = q.astype(jnp.float32) * scale
    size = 1
    for s in shape:
        size *= s
    return flat.reshape(-1)[:size].reshape(shape)


def quantized_psum_grads(grads, axis_name: str, key=None, block: int = 256):
    """Inside shard_map: all-reduce gradients with int8 payload.

    The int32 psum of int8 payloads is exact for <= 2^23 ranks worth of
    range; scales psum in fp32 and the dequant uses the mean scale — a
    standard approximation (error bounded by inter-rank scale spread).
    """
    n = jax.lax.psum(1, axis_name)

    def reduce_one(i, g):
        k = None if key is None else jax.random.fold_in(key, i)
        q, scale = _quantize_int8(g.astype(jnp.float32), k, block)
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        scale_mean = jax.lax.psum(scale, axis_name) / n
        deq = _dequantize_int8(q_sum, scale_mean, g.shape, block)
        return deq / n  # mean gradient

    leaves, treedef = jax.tree.flatten(grads)
    out = [reduce_one(i, g) for i, g in enumerate(leaves)]
    return jax.tree.unflatten(treedef, out)
