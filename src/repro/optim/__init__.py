from repro.optim.adamw import AdamWState, adamw_init, adamw_update, cosine_schedule
from repro.optim.compression import quantized_psum_grads

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "cosine_schedule",
    "quantized_psum_grads",
]
