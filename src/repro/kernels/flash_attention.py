"""Pallas TPU kernel: blocked flash attention (causal / sliding-window / GQA).

Online-softmax formulation (FlashAttention-2 schedule): grid is
(batch*q_heads, S/TQ, T/TK) with the key axis innermost; running max m,
normalizer l, and the unnormalized accumulator acc live in VMEM scratch and
carry across key blocks. Final key block writes acc / l.

Query positions are right-aligned against keys (qpos = iq + T - S), which
makes the same kernel serve training (S == T), chunked prefill (S < T), and
single-token decode (S == 1).

Sliding-window masking (h2o-danube's SWA) composes with causal: a key block
entirely outside [qpos - window, qpos] is skipped via the mask (the block
index map cannot skip compute in this simple schedule — the hillclimbed
variant in ops.py restricts the k-grid per q block instead).

MXU alignment: TQ, TK multiples of 128; D is the lane dim (128 for all
assigned LM archs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, scale, causal, window, t_total, s_total, block_q, block_k):
    ki = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]  # [TQ, D]
    k = k_ref[0]  # [TK, D]
    v = v_ref[0]  # [TK, D]

    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [TQ, TK]

    qpos = qi * block_q + jax.lax.iota(jnp.int32, block_q) + (t_total - s_total)
    kpos = ki * block_k + jax.lax.iota(jnp.int32, block_k)
    mask = jnp.ones((block_q, block_k), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_scr[...]          # [TQ, 1]
    l_prev = l_scr[...]          # [TQ, 1]
    m_cur = jnp.max(logits, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (m_new == NEG_INF): exp(NEG_INF - NEG_INF) would be 1
    row_dead = m_new <= NEG_INF / 2
    p = jnp.exp(logits - jnp.where(row_dead, 0.0, m_new))
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - jnp.where(row_dead, 0.0, m_new))
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, alpha)

    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret", "scale"),
)
def flash_attention_pallas(
    q: jnp.ndarray,  # [BH, S, D] (heads flattened into batch)
    k: jnp.ndarray,  # [BH, T, D] (GQA repeat done in ops.py index map — here 1:1)
    v: jnp.ndarray,  # [BH, T, D]
    scale: float,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    BH, S, D = q.shape
    _, T, _ = k.shape
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)
    grid = (BH, S // block_q, T // block_k)
    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        t_total=T,
        s_total=S,
        block_q=block_q,
        block_k=block_k,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, iq, ik: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
