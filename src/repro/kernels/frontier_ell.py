"""Pallas TPU kernel: packed-frontier OR-gather over ELL neighbor slabs.

out[i, :] = OR_{s : nbr[i, s] != INVALID}  F[nbr[i, s], :]      (uint32 words)

One BFS level of the sparse device wave engine (``build/engine_jax.py``):
``F`` is the packed member-frontier word matrix (bit j of word k = "wave
member 64k+j's BFS currently expands here"), ``nbr`` one destination-
stationary ELL slab.  This generalizes ``ell_spmm.py``'s tiling from
(f32 gather, +, *) to (uint32 gather, OR, select): TPUs have no scatter
atomics, so the schedule is inverted — each grid step owns a (TN)-row
destination tile whose padded neighbor ids live in VMEM, and frontier rows
are pulled from F (kept whole in ANY/HBM space) with dynamic row slices,
one neighbor slot at a time, OR-accumulating into a VMEM uint32 tile.

Unlike ``bitset_mm.py`` (whose A operand is a dense packed n x n/32 bit
matrix — closure-sized memory), the slab rows are int32 neighbor IDS: the
operand footprint is O(edges), which is what lets the wave engine run at
graph scale without materializing adjacency bits.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INVALID = -1


def _frontier_or_kernel(nbr_ref, f_ref, o_ref, *, block_n, max_deg):
    nbr = nbr_ref[...]  # int32[TN, d]
    acc = jnp.zeros_like(o_ref)  # uint32[TN, WM]

    def slot_body(s, acc):
        def row_body(i, acc):
            idx = nbr[i, s]
            safe = jnp.where(idx == INVALID, 0, idx)
            row = pl.load(f_ref, (pl.dslice(safe, 1), slice(None)))  # [1, WM]
            val = jnp.where(idx == INVALID, jnp.uint32(0), row[0])
            return acc.at[i].set(acc[i] | val)

        return jax.lax.fori_loop(0, block_n, row_body, acc)

    acc = jax.lax.fori_loop(0, max_deg, slot_body, acc)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def frontier_or_pallas(
    nbr: jnp.ndarray,  # int32[r, d]  ELL slab, INVALID-padded
    f: jnp.ndarray,    # uint32[n_src, WM]  packed frontier words
    block_n: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    r, d = nbr.shape
    n_src, wm = f.shape
    assert r % block_n == 0, (r, block_n)
    grid = (r // block_n,)
    kernel = functools.partial(_frontier_or_kernel, block_n=block_n, max_deg=d)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec(f.shape, lambda i: (0, 0)),  # whole F visible (ANY/HBM)
        ],
        out_specs=pl.BlockSpec((block_n, wm), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, wm), jnp.uint32),
        interpret=interpret,
    )(nbr, f)
