"""Public jit'd wrappers for the Pallas kernels.

Each wrapper pads inputs to tile multiples, dispatches to the kernel, and
slices the result back. ``interpret`` defaults to True off-TPU (this
container is CPU-only; TPU is the compile target).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as _ref
from repro.kernels.bitset_mm import bitset_mm_pallas
from repro.kernels.ell_spmm import ell_spmm_pallas
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.frontier_ell import frontier_or_pallas
from repro.kernels.label_intersect import label_intersect_pallas

INVALID = -1


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_axis(x: jnp.ndarray, axis: int, multiple: int, fill) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def label_intersect(a, b, block_b: int = 256, interpret: bool | None = None):
    """int32[B, La] x int32[B, Lb] -> bool[B]."""
    if interpret is None:
        interpret = not _on_tpu()
    B = a.shape[0]
    ap = _pad_axis(a, 0, block_b, INVALID)
    bp = _pad_axis(b, 0, block_b, INVALID)
    out = label_intersect_pallas(ap, bp, block_b=block_b, interpret=interpret)
    return out[:B]


@functools.partial(jax.jit, static_argnames=("block_n", "block_k", "block_w", "interpret"))
def bitset_mm(a_bits, x_bits, block_n=256, block_k=256, block_w=128, interpret=None):
    """uint32[n, ceil(k/32)] x uint32[k, wm] -> uint32[n, wm]."""
    if interpret is None:
        interpret = not _on_tpu()
    n, wk = a_bits.shape
    k, wm = x_bits.shape
    bn = min(block_n, max(8, n))
    bk = min(block_k, max(32, ((k + 31) // 32) * 32))
    bw = min(block_w, max(1, wm))
    ap = _pad_axis(_pad_axis(a_bits, 0, bn, 0), 1, bk // 32, 0)
    xp = _pad_axis(_pad_axis(x_bits, 0, bk, 0), 1, bw, 0)
    out = bitset_mm_pallas(ap, xp, block_n=bn, block_k=bk, block_w=bw, interpret=interpret)
    return out[:n, :wm]


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret", "scale"),
)
def flash_attention(
    q,  # [B, Hq, S, D]
    k,  # [B, Hkv, T, D]
    v,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
):
    """GQA flash attention. Returns [B, Hq, S, D]."""
    if interpret is None:
        interpret = not _on_tpu()
    B, Hq, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    if scale is None:
        scale = float(1.0 / np.sqrt(D))
    bq = min(block_q, S) if S % min(block_q, S) == 0 else S
    bk = min(block_k, T)
    # GQA: repeat kv heads (XLA fuses the broadcast into the gather; the
    # hillclimbed variant uses an index-map instead — see dryrun variants)
    kr = jnp.repeat(k, rep, axis=1).reshape(B * Hq, T, D)
    vr = jnp.repeat(v, rep, axis=1).reshape(B * Hq, T, D)
    qr = q.reshape(B * Hq, S, D)
    qp = _pad_axis(qr, 1, bq, 0)
    out = flash_attention_pallas(
        qp, kr, vr, scale=scale, causal=causal, window=window,
        block_q=bq, block_k=bk, interpret=interpret,
    )
    return out[:, :S].reshape(B, Hq, S, D)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def frontier_or(nbr, f, block_n: int = 128, interpret: bool | None = None):
    """Packed-frontier ELL OR-gather: int32[r, d], uint32[n_src, WM] ->
    uint32[r, WM] (one BFS level of the sparse device wave engine)."""
    if interpret is None:
        interpret = not _on_tpu()
    r = nbr.shape[0]
    if r == 0:
        return jnp.zeros((0, f.shape[1]), dtype=jnp.uint32)
    bn = min(block_n, r) if r % min(block_n, r) == 0 else r
    nbrp = _pad_axis(nbr, 0, bn, INVALID)
    out = frontier_or_pallas(nbrp, f, block_n=bn, interpret=interpret)
    return out[:r]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def ell_spmm(nbr, wgt, x, block_n: int = 128, interpret: bool | None = None):
    """ELL SpMM: int32[n, d], f32[n, d], f32[n_src, F] -> f32[n, F]."""
    if interpret is None:
        interpret = not _on_tpu()
    n = nbr.shape[0]
    bn = min(block_n, n) if n % min(block_n, n) == 0 else n
    nbrp = _pad_axis(nbr, 0, bn, INVALID)
    wgtp = _pad_axis(wgt, 0, bn, 0.0)
    out = ell_spmm_pallas(nbrp, wgtp, x, block_n=bn, interpret=interpret)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def embedding_bag(table, idx, block_b: int = 128, interpret: bool | None = None):
    """f32[V, D] gathered/sum-reduced by int32[B, bag] (neg = pad) -> f32[B, D]."""
    if interpret is None:
        interpret = not _on_tpu()
    B = idx.shape[0]
    bb = min(block_b, B) if B % min(block_b, B) == 0 else B
    idxp = _pad_axis(idx, 0, bb, INVALID)
    out = embedding_bag_pallas(table, idxp, block_b=bb, interpret=interpret)
    return out[:B]


# re-export refs for tests/benches
ref = _ref
