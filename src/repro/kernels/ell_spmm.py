"""Pallas TPU kernel: padded-neighbor-list (ELL) SpMM for GNN message passing.

out[i, :] = sum_j  w[i, j] * X[nbr[i, j], :]       nbr INVALID-padded

TPU adaptation note (DESIGN.md §2): GPU GNN kernels scatter per-edge with
atomics; TPUs have no atomics, so we invert the schedule — destination-
stationary tiles. Each grid step owns a (TN)-node tile; its padded neighbor
ids are small int32 VMEM blocks, and source rows are pulled from the
feature matrix (kept whole in ANY/HBM space) with dynamic row slices, one
neighbor slot at a time, accumulating in a VMEM f32 tile. The dynamic row
gather is the honest hot spot — on hardware each pl.load is a strided HBM
read issued by the scalar core (Mosaic supports dynamic sublane slices);
interpret mode validates the semantics.

The (beyond-paper) degree-sorted variant in ops.py reorders nodes by degree
so tiles have uniform slot counts, cutting wasted INVALID-slot bandwidth.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INVALID = -1


def _ell_spmm_kernel(nbr_ref, wgt_ref, x_ref, o_ref, *, block_n, max_deg):
    nbr = nbr_ref[...]  # int32[TN, d]
    wgt = wgt_ref[...]  # f32[TN, d]
    acc = jnp.zeros_like(o_ref)

    def slot_body(s, acc):
        def row_body(i, acc):
            idx = nbr[i, s]
            safe = jnp.where(idx == INVALID, 0, idx)
            row = pl.load(x_ref, (pl.dslice(safe, 1), slice(None)))  # [1, F]
            w = jnp.where(idx == INVALID, 0.0, wgt[i, s])
            return acc.at[i].add(w * row[0])

        return jax.lax.fori_loop(0, block_n, row_body, acc)

    acc = jax.lax.fori_loop(0, max_deg, slot_body, acc)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def ell_spmm_pallas(
    nbr: jnp.ndarray,   # int32[n, d]
    wgt: jnp.ndarray,   # f32[n, d]
    x: jnp.ndarray,     # f32[n_src, F]
    block_n: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    n, d = nbr.shape
    n_src, F = x.shape
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    kernel = functools.partial(_ell_spmm_kernel, block_n=block_n, max_deg=d)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec(x.shape, lambda i: (0, 0)),  # whole X visible (ANY/HBM)
        ],
        out_specs=pl.BlockSpec((block_n, F), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, F), x.dtype),
        interpret=interpret,
    )(nbr, wgt, x)
