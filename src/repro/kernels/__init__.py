"""Pallas TPU kernels for the perf-critical hot spots.

  label_intersect : batched hop-label intersection (oracle query core)
  bitset_mm       : bit-packed boolean matmul (TC closure / core labeling)
  flash_attention : blocked online-softmax attention (causal/SWA/GQA)
  ell_spmm        : padded-neighbor-list SpMM (GNN message passing)
  embedding_bag   : fused gather+sum over huge tables (recsys)

Use via repro.kernels.ops (jit'd, padding, interpret auto-detect); pure-jnp
oracles in repro.kernels.ref.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
