"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

INVALID = -1


def label_intersect_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """a: int32[B, La], b: int32[B, Lb] (INVALID padded) -> bool[B]:
    row-wise non-empty intersection over valid entries."""
    eq = a[:, :, None] == b[:, None, :]
    valid = (a[:, :, None] != INVALID) & (b[:, None, :] != INVALID)
    return (eq & valid).any(axis=(1, 2))


def bitset_mm_ref(a_bits: jnp.ndarray, x_bits: jnp.ndarray) -> jnp.ndarray:
    """Boolean matrix 'multiply' over bit-packed operands.

    a_bits: uint32[n, wk]  (row i = bitset over k)
    x_bits: uint32[k, wm]  (row j = bitset over m)
    out:    uint32[n, wm]  out[i] = OR_{j: a[i,j]} x_bits[j]
    """
    n, wk = a_bits.shape
    k, wm = x_bits.shape
    # unpack a to bool[n, k]
    bit = jnp.arange(32, dtype=jnp.uint32)
    a_bool = ((a_bits[:, :, None] >> bit[None, None, :]) & 1).astype(bool)
    a_bool = a_bool.reshape(n, wk * 32)[:, :k]
    sel = jnp.where(a_bool[:, :, None], x_bits[None, :, :], jnp.uint32(0))
    return jax.lax.reduce(sel, jnp.uint32(0), jax.lax.bitwise_or, (1,))


def ell_spmm_ref(nbr: jnp.ndarray, wgt: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Padded-neighbor-list SpMM: out[i] = sum_j wgt[i,j] * x[nbr[i,j]].

    nbr: int32[n, d] (INVALID padded), wgt: f32[n, d], x: f32[n_src, f].
    """
    safe = jnp.where(nbr == INVALID, 0, nbr)
    gathered = x[safe]  # [n, d, f]
    w = jnp.where(nbr == INVALID, 0.0, wgt)
    return jnp.einsum("nd,ndf->nf", w, gathered)


def flash_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jnp.ndarray:
    """q: [B, Hq, S, D], k/v: [B, Hkv, T, D] (GQA: Hq multiple of Hkv)."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    rep = Hq // Hkv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    s = scale if scale is not None else 1.0 / jnp.sqrt(D).astype(q.dtype)
    logits = jnp.einsum("bhsd,bhtd->bhst", q, k) * s
    T = k.shape[2]
    qpos = jnp.arange(S)[:, None] + (T - S)  # right-aligned (decode-friendly)
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > (qpos - window)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", probs, v)


def embedding_bag_ref(
    table: jnp.ndarray, idx: jnp.ndarray, offsets_mask: jnp.ndarray
) -> jnp.ndarray:
    """Sum-reduce bags of embedding rows.

    table: f32[V, D]; idx: int32[B, bag] (INVALID padded);
    offsets_mask: bool[B, bag] valid mask. -> f32[B, D]
    """
    safe = jnp.where(idx < 0, 0, idx)
    rows = table[safe]  # [B, bag, D]
    return jnp.sum(jnp.where(offsets_mask[..., None], rows, 0.0), axis=1)
