"""Pallas TPU kernel: embedding-bag (sum) for the recsys sparse hot path.

out[b, :] = sum_s  mask[b, s] * T[idx[b, s], :]

JAX has no native EmbeddingBag; this is the fused gather+segment-sum. The
schedule mirrors ell_spmm (destination-stationary bag tiles, dynamic row
pulls from the table kept in ANY/HBM); on hardware the table rows stream
through VMEM once per referencing bag — the xDeepFM tables (10^6 rows x 10)
never fit VMEM, so per-row dynamic slices are the only TPU-shaped access.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INVALID = -1


def _embag_kernel(idx_ref, table_ref, o_ref, *, block_b, bag):
    idx = idx_ref[...]  # int32[TB, bag]
    acc = jnp.zeros_like(o_ref)

    def slot_body(s, acc):
        def row_body(b, acc):
            i = idx[b, s]
            safe = jnp.where(i < 0, 0, i)
            row = pl.load(table_ref, (pl.dslice(safe, 1), slice(None)))
            valid = (i >= 0).astype(row.dtype)
            return acc.at[b].add(valid * row[0])

        return jax.lax.fori_loop(0, block_b, row_body, acc)

    acc = jax.lax.fori_loop(0, bag, slot_body, acc)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def embedding_bag_pallas(
    table: jnp.ndarray,  # f32[V, D]
    idx: jnp.ndarray,    # int32[B, bag], negative = padding
    block_b: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, bag = idx.shape
    V, D = table.shape
    assert B % block_b == 0, (B, block_b)
    grid = (B // block_b,)
    kernel = functools.partial(_embag_kernel, block_b=block_b, bag=bag)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, bag), lambda i: (i, 0)),
            pl.BlockSpec(table.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        interpret=interpret,
    )(idx, table)
