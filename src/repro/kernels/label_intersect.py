"""Pallas TPU kernel: batched hop-label intersection (the oracle query core).

For a query batch, decide per row whether sorted INVALID-padded label rows
a[i, :] and b[i, :] share a value. TPU-native design: instead of the CPU
sorted-merge (branchy, serial), each query does an La x Lb all-pairs compare
on the VPU — with La, Lb <= a few hundred this is a few thousand 1-cycle
lane ops, fully parallel across the query tile.

Tiling: queries tiled TB at a time; a-tile (TB, La) and b-tile (TB, Lb) live
in VMEM (TB=256, L=128 -> 2 x 128 KiB, well under the ~16 MiB VMEM budget).
The compare uses an 8x128-friendly layout: the (TB, La, Lb) intermediate is
never materialized in HBM — it exists only as VPU registers per (La-slice).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INVALID = -1


def _intersect_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...]  # [TB, La] int32
    b = b_ref[...]  # [TB, Lb] int32
    # all-pairs equality, padding filtered on both sides
    eq = (a[:, :, None] == b[:, None, :]) & (a[:, :, None] != INVALID) & (
        b[:, None, :] != INVALID
    )
    o_ref[...] = eq.any(axis=(1, 2))


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def label_intersect_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    block_b: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """a: int32[B, La], b: int32[B, Lb] -> bool[B]. B must be a multiple of
    block_b (ops.py pads)."""
    B, La = a.shape
    _, Lb = b.shape
    assert B % block_b == 0, (B, block_b)
    grid = (B // block_b,)
    return pl.pallas_call(
        _intersect_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, La), lambda i: (i, 0)),
            pl.BlockSpec((block_b, Lb), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.bool_),
        interpret=interpret,
    )(a, b)
