"""Pallas TPU kernel: bit-packed boolean matrix multiply (OR-AND semiring).

out[i, :] = OR_{j : A[i, j] = 1}  X[j, :]        (all bit-packed uint32)

This is the transitive-closure / core-graph-labeling workhorse: one closure
step is R |= A (.) R. The CPU version uses per-row word loops; the TPU
version tiles (node-rows x k-slices x word-columns) so each grid step
unpacks a (TN, TK) slab of A-bits in VREGs and OR-selects TK rows of X into
a (TN, TW) VMEM accumulator. No MXU — this is pure VPU integer work, but it
replaces 32 boolean ops per lane op (bit-packing) and streams X exactly
n/TN times.

Grid: (n/TN, wm/TW, k/TK), k innermost for accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bitset_mm_kernel(a_ref, x_ref, o_ref):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]  # uint32[TN, TK // 32]
    x = x_ref[...]  # uint32[TK, TW]
    tn, wk = a.shape
    tk = x.shape[0]
    # unpack A bits: bool[TN, TK]
    bit = jnp.arange(32, dtype=jnp.uint32)
    a_bool = ((a[:, :, None] >> bit[None, None, :]) & jnp.uint32(1)).astype(bool)
    a_bool = a_bool.reshape(tn, wk * 32)[:, :tk]
    # select rows of X where bit set, OR-reduce over the TK axis
    sel = jnp.where(a_bool[:, :, None], x[None, :, :], jnp.uint32(0))
    red = jax.lax.reduce(sel, jnp.uint32(0), jax.lax.bitwise_or, (1,))
    o_ref[...] |= red


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_k", "block_w", "interpret")
)
def bitset_mm_pallas(
    a_bits: jnp.ndarray,
    x_bits: jnp.ndarray,
    block_n: int = 256,
    block_k: int = 256,
    block_w: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """a_bits: uint32[n, k/32], x_bits: uint32[k, wm] -> uint32[n, wm].

    n % block_n == 0, k % block_k == 0 (so k/32 % (block_k/32) == 0),
    wm % block_w == 0. ops.py pads all three.
    """
    n, wk = a_bits.shape
    k, wm = x_bits.shape
    assert wk * 32 == ((k + 31) // 32) * 32 and k % 32 == 0, (wk, k)
    assert n % block_n == 0 and k % block_k == 0 and wm % block_w == 0
    grid = (n // block_n, wm // block_w, k // block_k)
    wblk = block_k // 32
    return pl.pallas_call(
        _bitset_mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, wblk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_w), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_w), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, wm), jnp.uint32),
        interpret=interpret,
    )(a_bits, x_bits)
