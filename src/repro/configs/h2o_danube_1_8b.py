"""h2o-danube-1.8b [arXiv:2401.16818; hf]: 24L d=2560 32H (GQA kv=8)
d_ff=6912 vocab=32000 — llama+mistral mix with sliding-window attention
(Mistral-style window 4096). SWA makes it sub-quadratic -> long_500k runs."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.lm_cells import LM_SHAPES, lm_cell
from repro.models.transformer import LMConfig

ARCH_ID = "h2o-danube-1.8b"
FAMILY = "lm"
SHAPES = tuple(LM_SHAPES)


def full_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID,
        n_layers=24,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6912,
        vocab=32000,
        window=4096,
        rope_theta=10000.0,
        dtype=jnp.bfloat16,
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=128,
        window=32,
        dtype=jnp.float32,
        remat=False,
    )


def cells(shape: str, mesh, variant: str = "baseline"):
    return lm_cell(
        full_config(), ARCH_ID, shape, mesh, variant,
        accum_micro_per_device=2, sub_quadratic=True,
    )
