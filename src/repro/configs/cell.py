"""CellSpec: one (architecture x input-shape x mesh) dry-run unit.

A cell carries everything dryrun.py needs to `.lower().compile()` at
production scale with zero allocation: the step callable, ShapeDtypeStruct
argument specs, and in/out shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class CellSpec:
    arch: str
    shape: str
    kind: str                      # train | prefill | decode | serve | retrieval | build
    fn: Callable
    args: Tuple[Any, ...]          # pytrees of ShapeDtypeStruct
    in_shardings: Any
    out_shardings: Any = None
    donate_argnums: Tuple[int, ...] = ()
    skip: Optional[str] = None     # populated when the cell is inapplicable
    meta: dict = dataclasses.field(default_factory=dict)

    def lower(self):
        jitted = jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate_argnums,
        )
        return jitted.lower(*self.args)


def shardings_of(mesh: Mesh, pspecs: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs, is_leaf=lambda x: isinstance(x, P)
    )


def data_axes_of(mesh: Mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def dp_size(mesh: Mesh) -> int:
    n = 1
    for a in data_axes_of(mesh):
        n *= mesh.shape[a]
    return n


def zero_pspecs(shape_tree: Any, pspec_tree: Any, mesh: Mesh) -> Any:
    """ZeRO sharding for optimizer state: take each param's pspec and
    additionally shard the first free, divisible dimension over the DP axes.
    Falls back to the param spec when nothing divides."""
    axes = data_axes_of(mesh)
    dp = dp_size(mesh)

    def one(sds, spec):
        dims = tuple(sds.shape)
        entries = list(spec) + [None] * (len(dims) - len(spec))
        for i, (d, s) in enumerate(zip(dims, entries)):
            if s is None and d > 0 and d % dp == 0:
                entries[i] = axes if len(axes) > 1 else axes[0]
                return P(*entries)
        return P(*entries)

    return jax.tree.map(one, shape_tree, pspec_tree, is_leaf=lambda x: isinstance(x, P))


def batch_pspec(mesh: Mesh, extra_dims: int = 1) -> P:
    """Shard the leading (batch) dim over all DP axes."""
    axes = data_axes_of(mesh)
    lead = axes if len(axes) > 1 else axes[0]
    return P(lead, *([None] * extra_dims))


def spec_bytes(tree: Any) -> int:
    return sum(
        int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree.leaves(tree)
    )
