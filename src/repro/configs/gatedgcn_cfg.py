"""gatedgcn [arXiv:2003.00982]: 16 layers, d_hidden=70, gated aggregator."""
from __future__ import annotations

from functools import partial

import jax

from repro.configs.gnn_cells import GNN_SHAPES, gnn_train_cell, shape_dims
from repro.models.gnn import gatedgcn

ARCH_ID = "gatedgcn"
FAMILY = "gnn"
SHAPES = tuple(GNN_SHAPES)
D_EDGE = 8


def full_config(d_in: int = 1433) -> gatedgcn.GatedGCNConfig:
    return gatedgcn.GatedGCNConfig(
        name=ARCH_ID, n_layers=16, d_in=d_in, d_edge_in=D_EDGE, d_hidden=70, n_classes=8
    )


def smoke_config() -> gatedgcn.GatedGCNConfig:
    return gatedgcn.GatedGCNConfig(
        name=ARCH_ID + "-smoke", n_layers=3, d_in=8, d_edge_in=4, d_hidden=16, n_classes=4
    )


def cells(shape: str, mesh, variant: str = "baseline"):
    _, _, d_feat = shape_dims(shape)
    cfg = full_config(d_in=d_feat)
    if variant in ("dstlocal", "opt"):
        # hillclimbed message passing: dst-local edge layout + shard_map —
        # kills the dense-partial all-reduces (EXPERIMENTS.md §Perf)
        from repro.configs.cell import data_axes_of

        loss = gatedgcn.make_dstlocal_loss(cfg, mesh, data_axes_of(mesh))
    else:
        loss = partial(gatedgcn.loss_fn, cfg)
    return gnn_train_cell(
        ARCH_ID, shape, mesh,
        loss_fn=loss,
        init_fn=lambda: gatedgcn.init_params(cfg, jax.random.PRNGKey(0)),
        d_edge=D_EDGE,
    )
