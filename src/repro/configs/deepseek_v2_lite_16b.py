"""deepseek-v2-lite-16b [arXiv:2405.04434; hf]: 27L d=2048 16H, MLA
(kv_lora=512, qk_nope 128, qk_rope 64, v 128), MoE: 64 routed experts top-6
+ 2 shared, expert d_ff=1408, vocab=102400.

NOTE on the assignment line: the bracket spec says "MoE 64e top-6" while the
comment says "160 routed" (that is full V2, not Lite). We follow the
structured spec + the published V2-Lite card: 64 routed + 2 shared, top-6.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.lm_cells import LM_SHAPES, lm_cell
from repro.models.transformer import LMConfig, MLACfg, MoECfg

ARCH_ID = "deepseek-v2-lite-16b"
FAMILY = "lm"
SHAPES = tuple(LM_SHAPES)


def full_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID,
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=0,
        vocab=102400,
        mla=MLACfg(kv_lora=512, qk_nope_dim=128, qk_rope_dim=64, v_dim=128),
        moe=MoECfg(
            n_experts=64, top_k=6, d_ff_expert=1408,
            n_shared=2, d_ff_shared=1408, capacity_factor=1.25, group_size=1024,
        ),
        dtype=jnp.bfloat16,
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab=128,
        mla=MLACfg(kv_lora=32, qk_nope_dim=16, qk_rope_dim=8, v_dim=16),
        moe=MoECfg(n_experts=8, top_k=2, d_ff_expert=32, n_shared=1,
                   d_ff_shared=32, capacity_factor=4.0, group_size=32),
        dtype=jnp.float32,
        remat=False,
    )


def cells(shape: str, mesh, variant: str = "baseline"):
    return lm_cell(
        full_config(), ARCH_ID, shape, mesh, variant,
        accum_micro_per_device=1, sub_quadratic=False,
    )
