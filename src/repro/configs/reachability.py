"""The paper's own architecture: the reachability oracle at production scale.

Cells (these are EXTRA, beyond the 40 assigned-pool cells):
  serve_1m      batched oracle queries: n=10M vertices, L_max=64, 1M-query
                batch -> serve_step (gather 2 label rows + intersect)
  serve_xl      n=25M (uniprotenc_150m scale), L_max=32, 1M queries
  build_sweep   one Distribution-Labeling iteration (distribute_one) at
                n=10M, m=30M: the per-vertex unit of the distributed build
  build_sweep_xl n=25M, m=25M (tree-like, uniprot scale)

Labels shard over the data axes (vertex-partitioned, labels live with their
vertex shard); query batches shard over data; the frontier bitmap is the only
per-step cross-shard exchange in the build sweep.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.cell import CellSpec, data_axes_of, shardings_of
from repro.core.distribution_jax import LabelState, distribute_one
from repro.serve.engine import serve_step

ARCH_ID = "reachability-oracle"
FAMILY = "oracle"
SHAPES = ("serve_1m", "serve_xl", "build_sweep", "build_sweep_xl")

ORACLE_SHAPES = {
    "serve_1m": dict(kind="serve", n=10_000_000, l_max=64, queries=1_000_000),
    "serve_xl": dict(kind="serve", n=25_000_000, l_max=32, queries=1_000_000),
    "build_sweep": dict(kind="build", n=10_000_000, m=30_000_000, l_max=64),
    "build_sweep_xl": dict(kind="build", n=25_000_000, m=25_000_000, l_max=32),
}


def full_config():
    return dict(ORACLE_SHAPES)


def smoke_config():
    return dict(n=200, m=500, l_max=16, queries=64)


def cells(shape: str, mesh, variant: str = "baseline"):
    info = ORACLE_SHAPES[shape]
    axes = data_axes_of(mesh)
    lead = axes if len(axes) > 1 else axes[0]
    n = info["n"]
    l_max = info["l_max"]

    if info["kind"] == "serve":
        B = info["queries"]
        label_spec = jax.ShapeDtypeStruct((n, l_max), jnp.int32)
        q_spec = jax.ShapeDtypeStruct((B, 2), jnp.int32)
        # labels vertex-sharded over data axes; queries data-sharded; the
        # row gather crosses shards (all-to-all-ish) — the serve collective
        label_sh = shardings_of(mesh, P(lead, None))
        q_sh = shardings_of(mesh, P(lead, None))
        fn = lambda lo, li, q: serve_step(lo, li, q)
        return CellSpec(
            arch=ARCH_ID, shape=shape, kind="serve", fn=fn,
            args=(label_spec, label_spec, q_spec),
            in_shardings=(label_sh, label_sh, q_sh),
            meta=dict(n=n, l_max=l_max, queries=B),
        )

    # build sweep: one distribute_one iteration at full scale
    m = info["m"]
    state_spec = LabelState(
        L_out=jax.ShapeDtypeStruct((n, l_max), jnp.int32),
        L_in=jax.ShapeDtypeStruct((n, l_max), jnp.int32),
        out_len=jax.ShapeDtypeStruct((n,), jnp.int32),
        in_len=jax.ShapeDtypeStruct((n,), jnp.int32),
        overflow=jax.ShapeDtypeStruct((), jnp.bool_),
    )
    state_sh = LabelState(
        L_out=shardings_of(mesh, P(lead, None)),
        L_in=shardings_of(mesh, P(lead, None)),
        out_len=shardings_of(mesh, P(lead)),
        in_len=shardings_of(mesh, P(lead)),
        overflow=shardings_of(mesh, P()),
    )
    edge_spec = jax.ShapeDtypeStruct((m,), jnp.int32)
    edge_sh = shardings_of(mesh, P(lead))
    vi_spec = jax.ShapeDtypeStruct((), jnp.int32)
    # bound BFS depth: real diameters are <= a few hundred; 64 is the
    # production sweep bound (deeper graphs re-enter the loop).
    # variant 'rowfix': one-hot row extraction (kills the 2x2.56GB label
    # matrix all-gathers — see EXPERIMENTS.md §Perf).
    row_mode = "onehot" if variant in ("rowfix", "opt") else "gather"
    fn = partial(distribute_one, n=n, max_steps=64, row_extract=row_mode)
    return CellSpec(
        arch=ARCH_ID, shape=shape, kind="build", fn=fn,
        args=(state_spec, vi_spec, edge_spec, edge_spec, edge_spec, edge_spec),
        in_shardings=(state_sh, shardings_of(mesh, P()), edge_sh, edge_sh, edge_sh, edge_sh),
        out_shardings=state_sh,
        donate_argnums=(0,),
        meta=dict(n=n, m=m, l_max=l_max),
    )
