"""Cell factory for the LM family (train / prefill / decode / long-decode).

Shapes (assignment):
  train_4k     seq 4,096   global_batch 256   -> train_step (fwd+bwd+AdamW,
                                                 grad accumulation)
  prefill_32k  seq 32,768  global_batch 32    -> prefill (chunked attention)
  decode_32k   cache 32,768 global_batch 128  -> decode_step (KV/MLA cache)
  long_500k    cache 524,288 global_batch 1   -> decode_step; ONLY for
               sub-quadratic archs (SWA) — full-attention archs skip.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.cell import (
    CellSpec,
    batch_pspec,
    data_axes_of,
    dp_size,
    shardings_of,
    zero_pspecs,
)
from repro.data.synth import lm_batch_specs
from repro.models import transformer as tf
from repro.optim import adamw_init, adamw_update, cosine_schedule

LM_SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def _params_specs(cfg: tf.LMConfig):
    return jax.eval_shape(lambda: tf.init_params(cfg, jax.random.PRNGKey(0)))


def _cache_pspecs(cfg: tf.LMConfig, mesh, batch: int):
    """Mesh-aware cache sharding. GQA cache [L, B, Hkv, T, Dh]:
    prefer kv-head sharding over the model axis; fall back to head_dim; for
    batch==1 (long-context) shard T over data. MLA cache [L, B, T, lora]
    shards lora over model."""
    axes = data_axes_of(mesh)
    dlead = axes if len(axes) > 1 else axes[0]
    msz = mesh.shape["model"]
    dp = dp_size(mesh)
    if cfg.mla is not None:
        bspec = dlead if batch % dp == 0 and batch >= dp else None
        tspec = None if bspec is not None else dlead
        return {
            "c_kv": P(None, bspec, tspec, "model" if cfg.mla.kv_lora % msz == 0 else None),
            "k_rope": P(None, bspec, tspec, None),
            "pos": P(),
        }
    if cfg.n_kv_heads % msz == 0:
        head_axis, hd_axis = "model", None
    elif cfg.head_dim % msz == 0:
        head_axis, hd_axis = None, "model"
    else:
        head_axis = hd_axis = None
    bspec = dlead if batch % dp == 0 and batch >= dp else None
    tspec = None if bspec is not None else dlead
    spec = P(None, bspec, head_axis, tspec, hd_axis)
    return {"k": spec, "v": spec, "pos": P()}


def make_train_step(cfg: tf.LMConfig, n_accum: int, mesh):
    axes = data_axes_of(mesh)
    dlead = axes if len(axes) > 1 else axes[0]

    def train_step(params, opt_state, batch):
        def accum(carry, mb):
            g_acc, loss_acc = carry
            mb = jax.tree.map(
                lambda x: jax.lax.with_sharding_constraint(
                    x, jax.sharding.NamedSharding(mesh, P(dlead, None))
                ),
                mb,
            )
            loss, g = jax.value_and_grad(partial(tf.lm_loss, cfg))(params, mb)
            g = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (g, loss_acc + loss), None

        micro = jax.tree.map(
            lambda x: x.reshape(n_accum, x.shape[0] // n_accum, *x.shape[1:]), batch
        )
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), _ = jax.lax.scan(accum, (zeros, jnp.float32(0)), micro)
        grads = jax.tree.map(lambda g: g / n_accum, grads)
        lr = cosine_schedule(opt_state.step, 3e-4, warmup=2000, total=100_000)
        params, opt_state, metrics = adamw_update(grads, opt_state, params, lr)
        metrics["loss"] = loss_sum / n_accum
        return params, opt_state, metrics

    return train_step


def lm_cell(
    cfg: tf.LMConfig,
    arch_id: str,
    shape: str,
    mesh,
    variant: str = "baseline",
    accum_micro_per_device: int = 1,
    sub_quadratic: bool = False,
) -> CellSpec:
    info = LM_SHAPES[shape]
    kind = info["kind"]
    seq, batch = info["seq"], info["batch"]

    if shape == "long_500k" and not sub_quadratic:
        return CellSpec(
            arch=arch_id, shape=shape, kind=kind, fn=None, args=(),
            in_shardings=None,
            skip="full-attention arch: 500k decode requires sub-quadratic attention "
                 "(see DESIGN.md SS4)",
        )

    # variant knobs (hillclimbing switches these)
    attn_impl = "chunked_skip" if ("skip" in variant or variant == "opt") else "chunked"
    cfg = dataclasses.replace(cfg, attn_impl=attn_impl)

    params_specs = _params_specs(cfg)
    pspecs = tf.param_pspecs(cfg)
    param_sh = shardings_of(mesh, pspecs)
    dp = dp_size(mesh)
    tp = mesh.shape["model"]

    if kind == "train":
        micro = accum_micro_per_device * dp
        n_accum = max(batch // micro, 1)
        opt_specs = jax.eval_shape(adamw_init, params_specs)
        opt_sh = shardings_of(mesh, _opt_pspecs(params_specs, pspecs, mesh))
        batch_specs = lm_batch_specs(batch, seq)
        batch_sh = shardings_of(
            mesh, jax.tree.map(lambda _: batch_pspec(mesh, 1), batch_specs)
        )
        fn = make_train_step(cfg, n_accum, mesh)
        from repro.launch.analytic import lm_train_terms

        return CellSpec(
            arch=arch_id, shape=shape, kind=kind, fn=fn,
            args=(params_specs, opt_specs, batch_specs),
            in_shardings=(param_sh, opt_sh, batch_sh),
            out_shardings=(param_sh, opt_sh, None),
            donate_argnums=(0, 1),
            meta=dict(
                n_accum=n_accum, tokens=batch * seq,
                model_params=cfg.param_count(),
                active_params=cfg.active_param_count(),
                analytic=lm_train_terms(cfg, batch, seq, n_accum, dp, tp),
            ),
        )

    if kind == "prefill":
        batch_specs = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
        fn = partial(tf.prefill, cfg)
        from repro.launch.analytic import lm_prefill_terms

        return CellSpec(
            arch=arch_id, shape=shape, kind=kind, fn=fn,
            args=(params_specs, batch_specs),
            in_shardings=(param_sh, shardings_of(mesh, batch_pspec(mesh, 1))),
            meta=dict(tokens=batch * seq, model_params=cfg.param_count(),
                      active_params=cfg.active_param_count(),
                      analytic=lm_prefill_terms(cfg, batch, seq, dp, tp)),
        )

    # decode
    cache_specs = jax.eval_shape(lambda: tf.init_cache(cfg, batch, seq))
    cache_sh = shardings_of(mesh, _cache_pspecs(cfg, mesh, batch))
    tok_specs = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    tok_spec_p = batch_pspec(mesh, 1) if batch % dp == 0 and batch >= dp else P(None, None)
    fn = partial(tf.decode_step, cfg)
    from repro.launch.analytic import lm_decode_terms

    return CellSpec(
        arch=arch_id, shape=shape, kind=kind, fn=fn,
        args=(params_specs, cache_specs, tok_specs),
        in_shardings=(param_sh, cache_sh, shardings_of(mesh, tok_spec_p)),
        out_shardings=(None, cache_sh),
        donate_argnums=(1,),
        meta=dict(tokens=batch, cache_len=seq, model_params=cfg.param_count(),
                  active_params=cfg.active_param_count(),
                  analytic=lm_decode_terms(cfg, batch, seq, dp, tp)),
    )


def _opt_pspecs(params_specs, pspecs, mesh):
    from repro.optim.adamw import AdamWState

    zp = zero_pspecs(params_specs, pspecs, mesh)
    return AdamWState(step=P(), mu=zp, nu=zp, master=zp)
