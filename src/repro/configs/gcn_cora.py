"""gcn-cora [arXiv:1609.02907]: 2 layers, d_hidden=16, mean/sym aggregator.
d_in follows the shape cell's d_feat (1433 on full_graph_sm = Cora)."""
from __future__ import annotations

from functools import partial

import jax

from repro.configs.gnn_cells import GNN_SHAPES, gnn_train_cell, shape_dims
from repro.models.gnn import gcn

ARCH_ID = "gcn-cora"
FAMILY = "gnn"
SHAPES = tuple(GNN_SHAPES)


def full_config(d_in: int = 1433) -> gcn.GCNConfig:
    return gcn.GCNConfig(name=ARCH_ID, n_layers=2, d_in=d_in, d_hidden=16, n_classes=7)


def smoke_config() -> gcn.GCNConfig:
    return gcn.GCNConfig(name=ARCH_ID + "-smoke", n_layers=2, d_in=8, d_hidden=8, n_classes=4)


def cells(shape: str, mesh, variant: str = "baseline"):
    _, _, d_feat = shape_dims(shape)
    cfg = full_config(d_in=d_feat)
    return gnn_train_cell(
        ARCH_ID, shape, mesh,
        loss_fn=partial(gcn.loss_fn, cfg),
        init_fn=lambda: gcn.init_params(cfg, jax.random.PRNGKey(0)),
    )
