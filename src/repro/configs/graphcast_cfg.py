"""graphcast [arXiv:2212.12794]: 16 processor layers, d_hidden=512,
mesh_refinement=6, sum aggregator, n_vars=227.

Shape mapping: the generic GNN shapes give (n_grid, n_mesh_edges); the mesh
node set is n_grid/8 (the icosahedral mesh at refinement 6 has ~41k nodes for
the 1-degree 65k-cell grid — the /8 ratio mirrors that), g2m/m2g edge counts
are 2x grid nodes (nearest-mesh-triangle connectivity). n_vars=227 always
(the arch defines its feature width; the shape's d_feat is superseded —
noted per-cell in meta)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.cell import CellSpec, data_axes_of, shardings_of
from repro.configs.gnn_cells import GNN_SHAPES, shape_dims
from repro.models.gnn import graphcast
from repro.optim import adamw_init, adamw_update, cosine_schedule

ARCH_ID = "graphcast"
FAMILY = "gnn"
SHAPES = tuple(GNN_SHAPES)


def full_config() -> graphcast.GraphCastConfig:
    return graphcast.GraphCastConfig(
        name=ARCH_ID, n_layers=16, d_hidden=512, n_vars=227, mesh_refinement=6,
        dtype=jnp.bfloat16,
    )


def smoke_config() -> graphcast.GraphCastConfig:
    return graphcast.GraphCastConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_hidden=32, n_vars=11,
        mesh_refinement=1, dtype=jnp.float32,
    )


def mesh_dims(shape: str):
    from repro.configs.gnn_cells import _pad_to

    n_grid, m_mesh, _ = shape_dims(shape)
    n_mesh = _pad_to(max(n_grid // 8, 64))
    m_g2m = 2 * n_grid
    m_m2g = 2 * n_grid
    return n_grid, n_mesh, m_g2m, _pad_to(min(m_mesh, 16 * n_mesh)), m_m2g


def batch_specs(shape: str, cfg: graphcast.GraphCastConfig):
    n_g, n_m, m_g2m, m_mesh, m_m2g = mesh_dims(shape)
    i32 = jnp.int32
    return graphcast.MeshBatch(
        grid_x=jax.ShapeDtypeStruct((n_g, cfg.n_vars), jnp.float32),
        g2m_src=jax.ShapeDtypeStruct((m_g2m,), i32),
        g2m_dst=jax.ShapeDtypeStruct((m_g2m,), i32),
        mesh_src=jax.ShapeDtypeStruct((m_mesh,), i32),
        mesh_dst=jax.ShapeDtypeStruct((m_mesh,), i32),
        m2g_src=jax.ShapeDtypeStruct((m_m2g,), i32),
        m2g_dst=jax.ShapeDtypeStruct((m_m2g,), i32),
        target=jax.ShapeDtypeStruct((n_g, cfg.n_vars), jnp.float32),
    )


def cells(shape: str, mesh, variant: str = "baseline"):
    cfg = full_config()
    n_g, n_m, m_g2m, m_mesh, m_m2g = mesh_dims(shape)
    b_specs = batch_specs(shape, cfg)
    axes = data_axes_of(mesh)
    lead = axes if len(axes) > 1 else axes[0]
    b_sh = shardings_of(
        mesh,
        graphcast.MeshBatch(
            grid_x=P(lead, None),
            g2m_src=P(lead), g2m_dst=P(lead),
            mesh_src=P(lead), mesh_dst=P(lead),
            m2g_src=P(lead), m2g_dst=P(lead),
            target=P(lead, None),
        ),
    )
    init_fn = lambda: graphcast.init_params(cfg, jax.random.PRNGKey(0))
    params_specs = jax.eval_shape(init_fn)
    # d=512 MLPs: shard the hidden dim over the model axis (TP)
    def pspec_of(path_leaf):
        return P()
    params_sh = shardings_of(mesh, jax.tree.map(lambda _: P(), params_specs))
    opt_specs = jax.eval_shape(adamw_init, params_specs)
    opt_sh = shardings_of(mesh, jax.tree.map(lambda _: P(), opt_specs))

    loss = partial(graphcast.loss_fn, cfg)

    def train_step(params, opt_state, b):
        l, grads = jax.value_and_grad(lambda p: loss(p, b, n_m))(params)
        lr = cosine_schedule(opt_state.step, 1e-3, warmup=100, total=10_000)
        params, opt_state, metrics = adamw_update(grads, opt_state, params, lr)
        metrics["loss"] = l
        return params, opt_state, metrics

    return CellSpec(
        arch=ARCH_ID, shape=shape, kind="train", fn=train_step,
        args=(params_specs, opt_specs, b_specs),
        in_shardings=(params_sh, opt_sh, b_sh),
        out_shardings=(params_sh, opt_sh, None),
        donate_argnums=(0, 1),
        meta=dict(n_grid=n_g, n_mesh=n_m, m_mesh=m_mesh,
                  note="n_vars=227 supersedes shape d_feat"),
    )
