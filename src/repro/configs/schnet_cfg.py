"""schnet [arXiv:1706.08566]: 3 interactions, d=64, 300 RBFs, cutoff 10.
Positions are synthesized for non-molecular shape cells (the kernel regime —
pairwise RBF gather/scatter — is shape-independent)."""
from __future__ import annotations

from functools import partial

import jax

from repro.configs.gnn_cells import GNN_SHAPES, gnn_train_cell, shape_dims
from repro.models.gnn import schnet

ARCH_ID = "schnet"
FAMILY = "gnn"
SHAPES = tuple(GNN_SHAPES)


def full_config() -> schnet.SchNetConfig:
    return schnet.SchNetConfig(
        name=ARCH_ID, n_interactions=3, d_hidden=64, n_rbf=300, cutoff=10.0
    )


def smoke_config() -> schnet.SchNetConfig:
    return schnet.SchNetConfig(
        name=ARCH_ID + "-smoke", n_interactions=2, d_hidden=16, n_rbf=20, cutoff=5.0
    )


def cells(shape: str, mesh, variant: str = "baseline"):
    cfg = full_config()
    return gnn_train_cell(
        ARCH_ID, shape, mesh,
        loss_fn=partial(schnet.loss_fn, cfg),
        init_fn=lambda: schnet.init_params(cfg, jax.random.PRNGKey(0)),
        with_pos=True,
    )
