"""Architecture registry: one module per assigned arch + the paper's own.

Each arch module exposes:
  ARCH_ID        str
  FAMILY         'lm' | 'gnn' | 'recsys' | 'oracle'
  full_config()  exact published config (dry-run only — never allocated)
  smoke_config() reduced same-family config (CPU tests)
  SHAPES         tuple of shape names valid for this arch
  cells(shape, mesh, variant='baseline') -> CellSpec (see configs.cell)
"""
from __future__ import annotations

import importlib

_ARCH_MODULES = {
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1_8b",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "deepseek-7b": "repro.configs.deepseek_7b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b_a400m",
    "gcn-cora": "repro.configs.gcn_cora",
    "graphcast": "repro.configs.graphcast_cfg",
    "schnet": "repro.configs.schnet_cfg",
    "gatedgcn": "repro.configs.gatedgcn_cfg",
    "xdeepfm": "repro.configs.xdeepfm_cfg",
    "reachability-oracle": "repro.configs.reachability",
}

ALL_ARCHS = tuple(_ARCH_MODULES)
ASSIGNED_ARCHS = tuple(a for a in ALL_ARCHS if a != "reachability-oracle")


def get_arch(arch_id: str):
    return importlib.import_module(_ARCH_MODULES[arch_id])
