"""xdeepfm [arXiv:1803.05170]: 39 sparse fields, embed_dim=10,
CIN 200-200-200, MLP 400-400.

Shapes:
  train_batch    B=65,536    train_step
  serve_p99      B=512       forward (online inference)
  serve_bulk     B=262,144   forward (offline scoring)
  retrieval_cand B=1, C=1,000,000  batched candidate scoring (no loop)

Embedding tables row-shard over the model axis (the 39 x 1M x 10 table is
the memory + gather hot path — same layout logic as the oracle's hop-sharded
labels)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.cell import CellSpec, batch_pspec, data_axes_of, shardings_of
from repro.data.synth import recsys_batch_specs
from repro.models.recsys import xdeepfm
from repro.optim import adamw_init, adamw_update, cosine_schedule

ARCH_ID = "xdeepfm"
FAMILY = "recsys"
SHAPES = ("train_batch", "serve_p99", "serve_bulk", "retrieval_cand")

RECSYS_SHAPES = {
    "train_batch": dict(batch=65_536, kind="train"),
    "serve_p99": dict(batch=512, kind="serve"),
    "serve_bulk": dict(batch=262_144, kind="serve"),
    "retrieval_cand": dict(batch=1, n_candidates=1_000_000, kind="retrieval"),
}


def full_config() -> xdeepfm.XDeepFMConfig:
    return xdeepfm.XDeepFMConfig(
        name=ARCH_ID, n_fields=39, embed_dim=10, vocab_per_field=1_000_000,
        cin_layers=(200, 200, 200), mlp_layers=(400, 400),
    )


def smoke_config() -> xdeepfm.XDeepFMConfig:
    return xdeepfm.XDeepFMConfig(
        name=ARCH_ID + "-smoke", n_fields=6, embed_dim=8, vocab_per_field=64,
        cin_layers=(8, 8), mlp_layers=(16,),
    )


def cells(shape: str, mesh, variant: str = "baseline"):
    info = RECSYS_SHAPES[shape]
    cfg = full_config()
    init_fn = lambda: xdeepfm.init_params(cfg, jax.random.PRNGKey(0))
    params_specs = jax.eval_shape(init_fn)
    params_sh = shardings_of(mesh, xdeepfm.param_pspecs(cfg))

    if info["kind"] == "train":
        B = info["batch"]
        batch_specs = recsys_batch_specs(B, cfg.n_fields)
        b_sh = shardings_of(
            mesh,
            {"ids": batch_pspec(mesh, 1), "y": batch_pspec(mesh, 0)},
        )
        opt_specs = jax.eval_shape(adamw_init, params_specs)
        from repro.configs.cell import zero_pspecs

        opt_p = zero_pspecs(params_specs, xdeepfm.param_pspecs(cfg), mesh)
        from repro.optim.adamw import AdamWState

        opt_sh = shardings_of(
            mesh, AdamWState(step=P(), mu=opt_p, nu=opt_p, master=opt_p)
        )

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(partial(xdeepfm.loss_fn, cfg))(params, batch)
            lr = cosine_schedule(opt_state.step, 1e-3, warmup=500, total=50_000)
            params, opt_state, metrics = adamw_update(
                grads, opt_state, params, lr, weight_decay=1e-5
            )
            metrics["loss"] = loss
            return params, opt_state, metrics

        return CellSpec(
            arch=ARCH_ID, shape=shape, kind="train", fn=train_step,
            args=(params_specs, opt_specs, batch_specs),
            in_shardings=(params_sh, opt_sh, b_sh),
            out_shardings=(params_sh, opt_sh, None),
            donate_argnums=(0, 1),
            meta=dict(batch=B, table_rows=cfg.n_fields * cfg.vocab_per_field),
        )

    if info["kind"] == "serve":
        B = info["batch"]
        ids = jax.ShapeDtypeStruct((B, cfg.n_fields), jnp.int32)
        ids_sh = shardings_of(mesh, batch_pspec(mesh, 1))
        fn = partial(xdeepfm.forward, cfg)
        return CellSpec(
            arch=ARCH_ID, shape=shape, kind="serve", fn=fn,
            args=(params_specs, ids),
            in_shardings=(params_sh, ids_sh),
            meta=dict(batch=B),
        )

    # retrieval: 1 user x 1M candidates
    C = info["n_candidates"]
    user = jax.ShapeDtypeStruct((1, cfg.n_fields), jnp.int32)
    cands = jax.ShapeDtypeStruct((C,), jnp.int32)
    axes = data_axes_of(mesh)
    lead = axes if len(axes) > 1 else axes[0]
    fn = partial(xdeepfm.retrieval_score, cfg)
    return CellSpec(
        arch=ARCH_ID, shape=shape, kind="retrieval", fn=fn,
        args=(params_specs, user, cands),
        in_shardings=(
            params_sh,
            shardings_of(mesh, P(None, None)),
            shardings_of(mesh, P(lead)),
        ),
        meta=dict(n_candidates=C),
    )
