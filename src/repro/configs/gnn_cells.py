"""Cell factory for the GNN family.

Shapes (assignment):
  full_graph_sm  n=2,708   m=10,556       d_feat=1,433  (full-batch, Cora)
  minibatch_lg   n=232,965 m=114,615,892  batch=1,024 fanout 15-10 (sampled)
  ogb_products   n=2,449,029 m=61,859,140 d_feat=100    (full-batch-large)
  molecule       30 nodes / 64 edges x batch 128        (batched-small)

Sampled training lowers the per-step BLOCK (1024 seeds -> 16,384 1-hop ->
153,600 2-hop nodes, 168,960 edges) — the neighbor sampler (graph/sampler.py)
produces exactly these static shapes. Full-batch cells lower the whole padded
graph; vertices/edges shard over the DP axes.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.cell import CellSpec, batch_pspec, data_axes_of, shardings_of
from repro.graph.sampler import block_shapes
from repro.models.gnn.layers import GraphBatch
from repro.optim import adamw_init, adamw_update, cosine_schedule

GNN_SHAPES = {
    "full_graph_sm": dict(n=2708, m=10556, d_feat=1433, kind="train"),
    "minibatch_lg": dict(
        n=232_965, m=114_615_892, batch_nodes=1024, fanout=(15, 10),
        d_feat=602, kind="train",
    ),
    "ogb_products": dict(n=2_449_029, m=61_859_140, d_feat=100, kind="train"),
    "molecule": dict(n=30 * 128, m=64 * 128, d_feat=16, kind="train"),
}


def graph_specs(n: int, m: int, d_feat: int, with_pos: bool, d_edge, n_classes: int = 8):
    """ShapeDtypeStruct GraphBatch."""
    return GraphBatch(
        x=jax.ShapeDtypeStruct((n, d_feat), jnp.float32),
        edge_src=jax.ShapeDtypeStruct((m,), jnp.int32),
        edge_dst=jax.ShapeDtypeStruct((m,), jnp.int32),
        edge_mask=jax.ShapeDtypeStruct((m,), jnp.bool_),
        node_mask=jax.ShapeDtypeStruct((n,), jnp.bool_),
        edge_attr=jax.ShapeDtypeStruct((m, d_edge), jnp.float32) if d_edge else None,
        pos=jax.ShapeDtypeStruct((n, 3), jnp.float32) if with_pos else None,
        y=jax.ShapeDtypeStruct((n,), jnp.int32),
    )


def graph_pspecs(mesh, with_pos: bool, d_edge):
    """Vertices and edges both shard over the DP axes (model axis free for
    feature-dim sharding on wide GNNs — GraphCast uses it)."""
    axes = data_axes_of(mesh)
    lead = axes if len(axes) > 1 else axes[0]
    return GraphBatch(
        x=P(lead, None),
        edge_src=P(lead),
        edge_dst=P(lead),
        edge_mask=P(lead),
        node_mask=P(lead),
        edge_attr=P(lead, None) if d_edge else None,
        pos=P(lead, None) if with_pos else None,
        y=P(lead),
    )


def _pad_to(x: int, mult: int = 512) -> int:
    """Node/edge counts pad to a DP-divisible multiple (the data pipeline
    pads with masked entries; 512 covers every mesh's DP extent)."""
    return ((x + mult - 1) // mult) * mult


def shape_dims(shape: str):
    info = GNN_SHAPES[shape]
    if shape == "minibatch_lg":
        n, m = block_shapes(info["batch_nodes"], info["fanout"])
        return _pad_to(n), _pad_to(m), info["d_feat"]
    return _pad_to(info["n"]), _pad_to(info["m"]), info["d_feat"]


def gnn_train_cell(
    arch_id: str,
    shape: str,
    mesh,
    loss_fn: Callable,        # (params, graph) -> scalar
    init_fn: Callable,        # () -> params (for eval_shape)
    with_pos: bool = False,
    d_edge=None,
    extra_meta: Dict | None = None,
    params_model_sharded: bool = False,
) -> CellSpec:
    n, m, d_feat = shape_dims(shape)
    g_specs = graph_specs(n, m, d_feat, with_pos, d_edge)
    g_sh = shardings_of(mesh, graph_pspecs(mesh, with_pos, d_edge))
    params_specs = jax.eval_shape(init_fn)
    params_sh = shardings_of(
        mesh, jax.tree.map(lambda _: P(), params_specs)
    )
    opt_specs = jax.eval_shape(adamw_init, params_specs)
    opt_sh = shardings_of(mesh, jax.tree.map(lambda _: P(), opt_specs))

    def train_step(params, opt_state, g):
        loss, grads = jax.value_and_grad(loss_fn)(params, g)
        lr = cosine_schedule(opt_state.step, 1e-3, warmup=100, total=10_000)
        params, opt_state, metrics = adamw_update(grads, opt_state, params, lr)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return CellSpec(
        arch=arch_id, shape=shape, kind="train", fn=train_step,
        args=(params_specs, opt_specs, g_specs),
        in_shardings=(params_sh, opt_sh, g_sh),
        out_shardings=(params_sh, opt_sh, None),
        donate_argnums=(0, 1),
        meta=dict(n_nodes=n, n_edges=m, d_feat=d_feat, **(extra_meta or {})),
    )
