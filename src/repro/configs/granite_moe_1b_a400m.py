"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base]: 24L
d=1024 16H (GQA kv=8) expert d_ff=512, 32 experts top-8, vocab=49155
(padded to 49408 for TP divisibility)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.lm_cells import LM_SHAPES, lm_cell
from repro.models.transformer import LMConfig, MoECfg

ARCH_ID = "granite-moe-1b-a400m"
FAMILY = "lm"
SHAPES = tuple(LM_SHAPES)
VOCAB_REAL = 49155


def full_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID,
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        d_ff=0,
        vocab=49408,  # padded from 49155
        moe=MoECfg(n_experts=32, top_k=8, d_ff_expert=512,
                   capacity_factor=1.25, group_size=1024),
        dtype=jnp.bfloat16,
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=0,
        vocab=128,
        moe=MoECfg(n_experts=8, top_k=4, d_ff_expert=32,
                   capacity_factor=4.0, group_size=32),
        dtype=jnp.float32,
        remat=False,
    )


def cells(shape: str, mesh, variant: str = "baseline"):
    return lm_cell(
        full_config(), ARCH_ID, shape, mesh, variant,
        accum_micro_per_device=4, sub_quadratic=False,
    )
