"""deepseek-7b [arXiv:2401.02954; hf]: 30L d=4096 32H (GQA kv=32 = MHA)
d_ff=11008 vocab=102400 — llama-architecture."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.lm_cells import LM_SHAPES, lm_cell
from repro.models.transformer import LMConfig

ARCH_ID = "deepseek-7b"
FAMILY = "lm"
SHAPES = tuple(LM_SHAPES)


def full_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID,
        n_layers=30,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        d_ff=11008,
        vocab=102400,
        dtype=jnp.bfloat16,
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=192,
        vocab=128,
        dtype=jnp.float32,
        remat=False,
    )


def cells(shape: str, mesh, variant: str = "baseline"):
    return lm_cell(
        full_config(), ARCH_ID, shape, mesh, variant,
        accum_micro_per_device=1, sub_quadratic=False,
    )
