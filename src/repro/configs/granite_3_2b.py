"""granite-3-2b [hf:ibm-granite/granite-3.0-2b-base]: 40L d=2048 32H
(GQA kv=8) d_ff=8192 vocab=49155 (padded to 49408 for TP divisibility —
Megatron-style vocab padding; logits over pad ids are never selected by
data with labels < 49155)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.lm_cells import LM_SHAPES, lm_cell
from repro.models.transformer import LMConfig

ARCH_ID = "granite-3-2b"
FAMILY = "lm"
SHAPES = tuple(LM_SHAPES)
VOCAB_REAL = 49155


def full_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID,
        n_layers=40,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        d_ff=8192,
        vocab=49408,  # padded from 49155 (divisible by 256)
        dtype=jnp.bfloat16,
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=160,
        vocab=128,
        dtype=jnp.float32,
        remat=False,
    )


def cells(shape: str, mesh, variant: str = "baseline"):
    return lm_cell(
        full_config(), ARCH_ID, shape, mesh, variant,
        accum_micro_per_device=2, sub_quadratic=False,
    )
