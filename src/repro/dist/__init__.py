"""Distribution layer: multi-device execution patterns that are not
oracle-specific (the oracle's own sharded serve lives in ``repro.serve``).
"""
from repro.dist.pipeline import pipeline_apply

__all__ = ["pipeline_apply"]
