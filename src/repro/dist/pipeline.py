"""GPipe-style pipeline parallelism via shard_map + ppermute.

Params carry a leading stage dimension (leaf shape [S, ...]); the input is a
stream of M microbatches (axis 0). Each mesh device along the stage axis owns
one stage's params; microbatches stream through the ring with one
collective_permute per step, so the full schedule is M + S - 1 steps with all
stages busy in the steady state.

The stage fn must be shape-preserving on the microbatch (activation in ==
activation out), which is the standard homogeneous-pipeline contract.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def pipeline_apply(params, x, fn, mesh, stage_axis: str = "stage"):
    """Apply S stacked stages to M microbatches with pipeline parallelism.

    params: pytree, every leaf shaped [S, ...] (stage-major).
    x:      [M, ...] microbatch stream (replicated across the mesh).
    fn:     (stage_params, microbatch) -> microbatch, shape-preserving.

    Returns [M, ...]: microbatch i pushed through stages 0..S-1, identical to
    the sequential reference ``for s in range(S): x = fn(params[s], x)``.
    """
    S = mesh.shape[stage_axis]
    M = x.shape[0]
    ring = [(i, (i + 1) % S) for i in range(S)]

    def local(p_shard, xfull):
        s = jax.lax.axis_index(stage_axis)
        p_local = jax.tree.map(lambda a: a[0], p_shard)
        buf = jnp.zeros_like(xfull[0])
        out = jnp.zeros_like(xfull)

        def step(t, carry):
            buf, out = carry
            # stage 0 injects microbatch t from the stream; later stages
            # consume what the previous stage handed over last step
            mb = jnp.where(s == 0, xfull[jnp.clip(t, 0, M - 1)], buf)
            y = fn(p_local, mb)
            buf_next = jax.lax.ppermute(y, stage_axis, ring)
            # the last stage emits microbatch t-(S-1) once the fill drains
            idx = t - (S - 1)
            take = (s == S - 1) & (idx >= 0)
            out = jnp.where(take, out.at[jnp.clip(idx, 0, M - 1)].set(y), out)
            return buf_next, out

        _, out = jax.lax.fori_loop(0, M + S - 1, step, (buf, out))
        # only the last stage holds results; psum replicates (others are zero)
        return jax.lax.psum(out, stage_axis)

    run = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
    )
    return run(params, x)
