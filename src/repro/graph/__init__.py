"""Graph substrate: CSR containers, SCC condensation, BFS, generators, sampling.

Host-side (numpy) structures feed both the oracle construction algorithms and
the JAX/device compute paths (which consume the arrays as jnp buffers).
"""
from repro.graph.csr import CSRGraph, from_edges, ELLGraph
from repro.graph.scc import condense_to_dag, tarjan_scc
from repro.graph.generators import (
    random_dag,
    layered_dag,
    tree_dag,
    scale_free_dag,
    paper_dataset_analogue,
    PAPER_DATASETS,
)

__all__ = [
    "CSRGraph",
    "ELLGraph",
    "from_edges",
    "condense_to_dag",
    "tarjan_scc",
    "random_dag",
    "layered_dag",
    "tree_dag",
    "scale_free_dag",
    "paper_dataset_analogue",
    "PAPER_DATASETS",
]
