"""Strongly-connected components (iterative Tarjan) + DAG condensation.

The paper (like all reachability work) assumes the input digraph has been
condensed: every SCC is coalesced into a single DAG vertex, so intra-SCC
reachability is trivially true.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.graph.csr import CSRGraph, from_edges


def tarjan_scc(g: CSRGraph) -> Tuple[np.ndarray, int]:
    """Iterative Tarjan. Returns (comp_id int32[n], n_comps).

    Component ids are assigned in *reverse topological order of the
    condensation* (Tarjan's natural output order), i.e. if comp(u) can reach
    comp(v) in the condensation and comp(u) != comp(v), then
    comp_id[u] > comp_id[v].
    """
    n = g.n
    index = np.full(n, -1, dtype=np.int64)
    low = np.zeros(n, dtype=np.int64)
    on_stack = np.zeros(n, dtype=bool)
    comp = np.full(n, -1, dtype=np.int32)
    stack: list[int] = []
    next_index = 0
    n_comps = 0

    indptr, indices = g.indptr, g.indices

    for root in range(n):
        if index[root] != -1:
            continue
        # (vertex, next-edge-offset) explicit DFS stack
        work = [(root, indptr[root])]
        index[root] = low[root] = next_index
        next_index += 1
        stack.append(root)
        on_stack[root] = True
        while work:
            v, ei = work[-1]
            if ei < indptr[v + 1]:
                work[-1] = (v, ei + 1)
                w = int(indices[ei])
                if index[w] == -1:
                    index[w] = low[w] = next_index
                    next_index += 1
                    stack.append(w)
                    on_stack[w] = True
                    work.append((w, indptr[w]))
                elif on_stack[w]:
                    if index[w] < low[v]:
                        low[v] = index[w]
            else:
                work.pop()
                if work:
                    pv = work[-1][0]
                    if low[v] < low[pv]:
                        low[pv] = low[v]
                if low[v] == index[v]:
                    while True:
                        w = stack.pop()
                        on_stack[w] = False
                        comp[w] = n_comps
                        if w == v:
                            break
                    n_comps += 1
    return comp, n_comps


def condense_to_dag(g: CSRGraph) -> Tuple[CSRGraph, np.ndarray]:
    """Coalesce SCCs. Returns (dag, comp_id) with comp_id int32[n_original].

    The resulting DAG vertex ids are the component ids.
    """
    comp, k = tarjan_scc(g)
    src, dst = g.edges()
    csrc, cdst = comp[src], comp[dst]
    keep = csrc != cdst
    return from_edges(k, csrc[keep], cdst[keep]), comp
