"""JAX frontier-vector BFS.

The TPU-native replacement for queue BFS: the frontier is a dense bool[n]
vector; one step gathers every frontier-adjacent edge and scatter-ORs into the
next frontier with segment_max. Multi-source BFS turns the step into a
(bool[s, n] x adjacency) matmul-OR, which batches onto the VPU/MXU.

All functions are jit-compatible (static shapes; `jax.lax.while_loop`).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph


def csr_device_arrays(g: CSRGraph):
    """(src int32[m], dst int32[m]) edge list on device, sorted by src."""
    src, dst = g.edges()
    return jnp.asarray(src), jnp.asarray(dst)


@partial(jax.jit, static_argnames=("n",))
def bfs_step(reached: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray, n: int) -> jnp.ndarray:
    """One OR-step: reached |= exists edge (u->v) with reached[u].

    reached: bool[n]. Returns new reached (monotone).
    """
    active = reached[src]
    hit = jax.ops.segment_max(
        active.astype(jnp.int32), dst, num_segments=n, indices_are_sorted=False
    )
    return reached | (hit > 0)


@partial(jax.jit, static_argnames=("n", "max_steps"))
def bfs_reach(
    sources: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray, n: int, max_steps: int
) -> jnp.ndarray:
    """bool[n] reachable-set (inclusive of sources) after <= max_steps steps.

    sources: bool[n] initial frontier. Converges early when the frontier
    stops growing; max_steps is a static upper bound.
    """

    def loop_cond(state):
        step, reached, changed = state
        return (step < max_steps) & changed

    def loop_body(state):
        step, reached, _ = state
        new = bfs_step(reached, src, dst, n)
        return step + 1, new, jnp.any(new != reached)

    _, out, _ = jax.lax.while_loop(loop_cond, loop_body, (jnp.int32(0), sources, jnp.bool_(True)))
    return out


@partial(jax.jit, static_argnames=("n", "k"))
def k_hop_neighborhood(
    sources: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray, n: int, k: int
) -> jnp.ndarray:
    """bool[n]: vertices within <= k forward steps of sources (inclusive)."""
    reached = sources
    for _ in range(k):
        reached = bfs_step(reached, src, dst, n)
    return reached


@partial(jax.jit, static_argnames=("n", "max_steps"))
def bfs_levels_device(
    source: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray, n: int, max_steps: int
) -> jnp.ndarray:
    """int32[n] levels from a single source index; -1 unreached."""
    level = jnp.full((n,), -1, dtype=jnp.int32).at[source].set(0)

    def loop_body(state):
        step, level, _ = state
        reached = level >= 0
        new = bfs_step(reached, src, dst, n)
        fresh = new & ~reached
        level = jnp.where(fresh, step + 1, level)
        return step + 1, level, jnp.any(fresh)

    def loop_cond(state):
        step, _, changed = state
        return (step < max_steps) & changed

    _, level, _ = jax.lax.while_loop(loop_cond, loop_body, (jnp.int32(0), level, jnp.bool_(True)))
    return level


def multi_source_reach(
    sources: np.ndarray, g: CSRGraph, max_steps: int | None = None
) -> np.ndarray:
    """bool[s, n]: row i = reachable set of sources[i]. Batched frontier matrix."""
    n = g.n
    src, dst = csr_device_arrays(g)
    steps = n if max_steps is None else max_steps
    init = jnp.zeros((sources.shape[0], n), dtype=bool)
    init = init.at[jnp.arange(sources.shape[0]), jnp.asarray(sources)].set(True)

    @partial(jax.jit, static_argnames=())
    def run(frontiers):
        def loop_cond(state):
            step, reached, changed = state
            return (step < steps) & changed

        def loop_body(state):
            step, reached, _ = state
            active = reached[:, src]  # [s, m]
            hit = jax.vmap(
                lambda a: jax.ops.segment_max(a.astype(jnp.int32), dst, num_segments=n)
            )(active)
            new = reached | (hit > 0)
            return step + 1, new, jnp.any(new != reached)

        _, out, _ = jax.lax.while_loop(
            loop_cond, loop_body, (jnp.int32(0), frontiers, jnp.bool_(True))
        )
        return out

    return np.asarray(run(init))
