"""Fanout neighbor sampler for sampled GNN training (minibatch_lg shape).

GraphSAGE-style layered sampling: for a seed batch, sample up to fanout[0]
in-neighbors, then fanout[1] of theirs, etc. Produces a fixed-shape padded
block (device-friendly: every batch lowers to the same shapes).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np

from repro.graph.csr import CSRGraph, INVALID


@dataclasses.dataclass(frozen=True)
class SampledBlock:
    """One sampled computation block (fixed shapes for a given (batch, fanouts)).

    nodes:    int32[n_total]    global ids, INVALID padding; seeds first
    edge_src: int32[n_edges]    local indices into `nodes`
    edge_dst: int32[n_edges]    local indices into `nodes`
    edge_mask: bool[n_edges]
    n_seeds:  int
    """

    nodes: np.ndarray
    edge_src: np.ndarray
    edge_dst: np.ndarray
    edge_mask: np.ndarray
    n_seeds: int


def sample_block(
    g_rev: CSRGraph,
    seeds: np.ndarray,
    fanouts: Sequence[int],
    rng: np.random.Generator,
) -> SampledBlock:
    """Sample a block from the *reverse* CSR (message flow dst<-src).

    Shapes depend only on (len(seeds), fanouts): n_total = B*(1+f0+f0*f1+...),
    n_edges = B*f0 + B*f0*f1 + ...
    """
    B = seeds.shape[0]
    layer_nodes = [np.asarray(seeds, dtype=np.int32)]
    layer_sizes = [B]
    all_src, all_dst, all_mask = [], [], []
    offset = 0  # local index offset of current dst layer
    for f in fanouts:
        dst_nodes = layer_nodes[-1]
        k = dst_nodes.shape[0]
        src_nodes = np.full(k * f, INVALID, dtype=np.int32)
        e_src = np.arange(k * f, dtype=np.int32) + offset + k  # provisional; fixed below
        e_dst = np.repeat(np.arange(k, dtype=np.int32) + offset, f)
        mask = np.zeros(k * f, dtype=bool)
        for i, v in enumerate(dst_nodes):
            if v == INVALID:
                continue
            nbrs = g_rev.out_neighbors(int(v))  # in-neighbors of v in the original graph
            if nbrs.shape[0] == 0:
                continue
            take = min(f, nbrs.shape[0])
            choice = rng.choice(nbrs, size=take, replace=nbrs.shape[0] < take)
            src_nodes[i * f : i * f + take] = choice
            mask[i * f : i * f + take] = True
        src_local = np.arange(k * f, dtype=np.int32) + offset + k
        all_src.append(src_local)
        all_dst.append(e_dst)
        all_mask.append(mask)
        layer_nodes.append(src_nodes)
        layer_sizes.append(k * f)
        offset += k
    nodes = np.concatenate(layer_nodes)
    return SampledBlock(
        nodes=nodes,
        edge_src=np.concatenate(all_src),
        edge_dst=np.concatenate(all_dst),
        edge_mask=np.concatenate(all_mask),
        n_seeds=B,
    )


def block_shapes(batch: int, fanouts: Sequence[int]) -> Tuple[int, int]:
    """(n_total_nodes, n_edges) for given batch/fanouts — static per config."""
    n_total, n_edges, k = batch, 0, batch
    for f in fanouts:
        n_edges += k * f
        k = k * f
        n_total += k
    return n_total, n_edges
