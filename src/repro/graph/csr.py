"""CSR graph container (host numpy) + padded ELL view for device kernels.

All construction algorithms operate on int32 CSR. Edges are stored sorted by
source (CSR) and can be re-materialized sorted by destination (CSC of the
reverse graph) for segment-sum style scatter on device.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Tuple

import numpy as np

INVALID = np.int32(-1)


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Immutable CSR directed graph.

    indptr:  int32[n+1]
    indices: int32[m]   -- out-neighbors of vertex i are indices[indptr[i]:indptr[i+1]]
    """

    indptr: np.ndarray
    indices: np.ndarray

    @property
    def n(self) -> int:
        return int(self.indptr.shape[0] - 1)

    @property
    def m(self) -> int:
        return int(self.indices.shape[0])

    def out_neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]

    def out_degree(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int32)

    def reverse(self) -> "CSRGraph":
        """CSR of the reverse graph (in-neighbors become out-neighbors)."""
        n, m = self.n, self.m
        src = np.repeat(np.arange(n, dtype=np.int32), np.diff(self.indptr))
        dst = self.indices
        order = np.argsort(dst, kind="stable")
        r_indices = src[order]
        counts = np.bincount(dst, minlength=n).astype(np.int64)
        r_indptr = np.zeros(n + 1, dtype=np.int32)
        np.cumsum(counts, out=r_indptr[1:])
        return CSRGraph(r_indptr.astype(np.int32), r_indices.astype(np.int32))

    def in_degree(self) -> np.ndarray:
        return np.bincount(self.indices, minlength=self.n).astype(np.int32)

    def edges(self) -> Tuple[np.ndarray, np.ndarray]:
        """(src, dst) int32 arrays of all edges."""
        src = np.repeat(np.arange(self.n, dtype=np.int32), np.diff(self.indptr))
        return src, self.indices.copy()

    def to_ell(self, max_deg: int | None = None) -> "ELLGraph":
        """Padded neighbor-list view: int32[n, max_deg] with INVALID padding."""
        deg = self.out_degree()
        md = int(deg.max()) if max_deg is None else int(max_deg)
        md = max(md, 1)
        nbr = np.full((self.n, md), INVALID, dtype=np.int32)
        for v in range(self.n):
            row = self.out_neighbors(v)[:md]
            nbr[v, : row.shape[0]] = row
        return ELLGraph(neighbors=nbr, degrees=np.minimum(deg, md).astype(np.int32))

    def subgraph(self, keep: np.ndarray) -> Tuple["CSRGraph", np.ndarray]:
        """Induced subgraph over `keep` (bool[n] or index array).

        Returns (subgraph, old_ids) where old_ids[i] is the original id of
        new vertex i.
        """
        if keep.dtype == np.bool_:
            old_ids = np.nonzero(keep)[0].astype(np.int32)
        else:
            old_ids = np.asarray(keep, dtype=np.int32)
        remap = np.full(self.n, INVALID, dtype=np.int32)
        remap[old_ids] = np.arange(old_ids.shape[0], dtype=np.int32)
        src, dst = self.edges()
        mask = (remap[src] != INVALID) & (remap[dst] != INVALID)
        return (
            from_edges(old_ids.shape[0], remap[src[mask]], remap[dst[mask]]),
            old_ids,
        )


@dataclasses.dataclass(frozen=True)
class ELLGraph:
    """Padded fixed-width neighbor lists (device-friendly).

    neighbors: int32[n, max_deg], INVALID-padded
    degrees:   int32[n]
    """

    neighbors: np.ndarray
    degrees: np.ndarray

    @property
    def n(self) -> int:
        return int(self.neighbors.shape[0])

    @property
    def max_deg(self) -> int:
        return int(self.neighbors.shape[1])


def from_edges(n: int, src: Iterable[int], dst: Iterable[int], dedup: bool = True) -> CSRGraph:
    """Build CSR from edge lists. Self-loops removed; duplicates optionally removed."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    if dedup and src.shape[0] > 0:
        key = src * np.int64(n) + dst
        _, uidx = np.unique(key, return_index=True)
        src, dst = src[uidx], dst[uidx]
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    counts = np.bincount(src, minlength=n)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSRGraph(indptr.astype(np.int32), dst.astype(np.int32))


def is_dag(g: CSRGraph) -> bool:
    """Kahn's algorithm: true iff g is acyclic."""
    indeg = g.in_degree().astype(np.int64)
    stack = list(np.nonzero(indeg == 0)[0])
    seen = 0
    while stack:
        v = stack.pop()
        seen += 1
        for w in g.out_neighbors(v):
            indeg[w] -= 1
            if indeg[w] == 0:
                stack.append(int(w))
    return seen == g.n


def topo_levels(g: CSRGraph) -> np.ndarray:
    """int32[n] longest-path level of each DAG vertex (sources = 0).

    Level-synchronous Kahn rounds, all-numpy: a vertex's in-degree hits
    zero exactly when its last predecessor's round finishes, so the round
    it enters the frontier IS its longest-path level.  ``u -> v`` (u != v)
    implies ``level[u] < level[v]`` — the serve-path prefilter's invariant.
    """
    n = g.n
    indptr = g.indptr.astype(np.int64)
    indices = g.indices.astype(np.int64)
    indeg = np.bincount(indices, minlength=n)
    level = np.zeros(n, dtype=np.int32)
    frontier = np.flatnonzero(indeg == 0)
    lv = 0
    seen = frontier.size
    while frontier.size:
        starts = indptr[frontier]
        counts = indptr[frontier + 1] - starts
        total = int(counts.sum())
        lv += 1
        if total == 0:
            break
        cum = np.cumsum(counts)
        offs = np.repeat(starts - (cum - counts), counts) + np.arange(total, dtype=np.int64)
        nbrs = indices[offs]
        indeg -= np.bincount(nbrs, minlength=n)
        uniq = np.unique(nbrs)
        frontier = uniq[indeg[uniq] == 0]
        level[frontier] = lv
        seen += frontier.size
    if seen != n:
        raise ValueError("graph has a cycle")
    return level


def topological_order(g: CSRGraph) -> np.ndarray:
    """Topological order of a DAG (raises on cycles). int32[n]: order[i] = i-th vertex."""
    indeg = g.in_degree().astype(np.int64)
    stack = list(np.nonzero(indeg == 0)[0][::-1])
    out = np.empty(g.n, dtype=np.int32)
    k = 0
    while stack:
        v = stack.pop()
        out[k] = v
        k += 1
        for w in g.out_neighbors(v):
            indeg[w] -= 1
            if indeg[w] == 0:
                stack.append(int(w))
    if k != g.n:
        raise ValueError("graph has a cycle")
    return out
