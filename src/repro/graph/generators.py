"""Synthetic DAG generators matched to the paper's benchmark dataset profiles.

The 2013 paper evaluates on 12 small graphs (biological/XML, n ~ 1k-40k,
m ~= n, sparse & shallow) and 9 large graphs (citation/protein, n up to 25M).
Those exact files are not redistributable here, so each named dataset maps to
a generator with matched (n, m) and a structural family:

  * ``*cyc`` / kegg / reactome etc.  -> sparse near-tree DAGs (m ~= 1.05 n)
  * citeseer / citeseerx / cit-Patents -> citation-style layered DAGs
  * go_uniprot / uniprotenc_*        -> wide shallow ontology trees
  * mapped_*                         -> sparse random DAGs

All generators return a condensed DAG (they generate DAGs directly).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.graph.csr import CSRGraph, from_edges


def random_dag(n: int, m: int, seed: int = 0) -> CSRGraph:
    """Uniform random DAG: m edges oriented low->high under a random permutation."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n).astype(np.int64)
    # sample pairs, orient by permutation rank
    k = int(m * 1.3) + 16
    a = rng.integers(0, n, size=k)
    b = rng.integers(0, n, size=k)
    mask = a != b
    a, b = a[mask], b[mask]
    ra, rb = perm[a], perm[b]
    src = np.where(ra < rb, a, b)
    dst = np.where(ra < rb, b, a)
    return from_edges(n, src[:m], dst[:m])


def layered_dag(
    n: int, avg_out: float = 2.0, n_layers: int = 12, skip: float = 0.15, seed: int = 0
) -> CSRGraph:
    """Citation-style DAG: vertices in layers, edges point to earlier layers,
    with a `skip` fraction jumping >1 layer (long-range citations)."""
    rng = np.random.default_rng(seed)
    layer = rng.integers(0, n_layers, size=n)
    order = np.argsort(layer, kind="stable")
    rank = np.empty(n, dtype=np.int64)
    rank[order] = np.arange(n)
    m = int(n * avg_out)
    src = rng.integers(0, n, size=m)
    # destination: a vertex with strictly smaller rank (earlier layer region)
    lo = np.maximum(rank[src] * (1.0 - np.where(rng.random(m) < skip, 0.9, 0.3)), 0)
    dst_rank = (lo + rng.random(m) * np.maximum(rank[src] - lo, 1)).astype(np.int64)
    dst_rank = np.minimum(dst_rank, np.maximum(rank[src] - 1, 0))
    dst = order[dst_rank]
    keep = rank[src] > rank[dst]
    return from_edges(n, src[keep], dst[keep])


def tree_dag(n: int, branching: int = 8, extra_frac: float = 0.05, seed: int = 0) -> CSRGraph:
    """Ontology-style: a shallow tree (root -> leaves) + a few cross edges.

    Matches go_uniprot / uniprotenc profiles (m ~= n - 1).
    """
    rng = np.random.default_rng(seed)
    parent = np.maximum((np.arange(1, n) - 1) // branching, 0)
    src = [parent, ]
    dst = [np.arange(1, n), ]
    n_extra = int(n * extra_frac)
    if n_extra:
        a = rng.integers(0, n, size=n_extra)
        b = rng.integers(0, n, size=n_extra)
        lo, hi = np.minimum(a, b), np.maximum(a, b)
        keep = lo != hi
        src.append(lo[keep])
        dst.append(hi[keep])
    return from_edges(n, np.concatenate(src), np.concatenate(dst))


def scale_free_dag(n: int, avg_out: float = 4.0, seed: int = 0) -> CSRGraph:
    """Preferential-attachment DAG (new vertex links to earlier, degree-biased)."""
    rng = np.random.default_rng(seed)
    m = int(n * avg_out)
    # Efficient PA approximation: sample targets from the edge-endpoint pool.
    src = np.empty(m, dtype=np.int64)
    dst = np.empty(m, dtype=np.int64)
    pool = np.zeros(m, dtype=np.int64)  # endpoint pool for preferential choice
    pool_size = 0
    e = 0
    for v in range(1, n):
        k = int(np.clip(rng.poisson(avg_out), 1, v))
        k = min(k, m - e)
        for _ in range(k):
            if pool_size > 0 and rng.random() < 0.7:
                t = pool[rng.integers(0, pool_size)]
            else:
                t = rng.integers(0, v)
            src[e] = v
            dst[e] = t
            if pool_size < m:
                pool[pool_size] = t
                pool_size += 1
            e += 1
        if e >= m:
            break
    return from_edges(n, src[:e], dst[:e])


def chain_dag(n: int, width: int = 4, seed: int = 0) -> CSRGraph:
    """Deep narrow DAG (worst-ish case for hop labeling depth)."""
    rng = np.random.default_rng(seed)
    layers = n // width
    src, dst = [], []
    for l in range(layers - 1):
        a = np.arange(l * width, (l + 1) * width)
        for _ in range(2):
            b = l * width + width + rng.integers(0, width, size=width)
            src.append(a)
            dst.append(np.minimum(b, n - 1))
    return from_edges(n, np.concatenate(src), np.concatenate(dst))


# ---------------------------------------------------------------------------
# Paper dataset registry: name -> (n, m, family). n/m from Table 1.
# "small" graphs are generated at full scale; "large" at full scale for DL
# benchmarking (construction is O(n+m)-ish) but capped via --scale for CI.
# ---------------------------------------------------------------------------
PAPER_DATASETS: Dict[str, dict] = {
    # small (Table 1 left)
    "agrocyc": dict(n=12684, m=13408, family="sparse"),
    "amaze": dict(n=3710, m=3600, family="sparse"),
    "anthra": dict(n=12499, m=13104, family="sparse"),
    "ecoo": dict(n=12620, m=13350, family="sparse"),
    "hpycyc": dict(n=4771, m=5859, family="sparse"),
    "human": dict(n=38811, m=39576, family="sparse"),
    "kegg": dict(n=3617, m=3908, family="sparse"),
    "mtbrv": dict(n=9602, m=10245, family="sparse"),
    "nasa": dict(n=5605, m=7735, family="layered"),
    "reactome": dict(n=901, m=846, family="sparse"),
    "vchocyc": dict(n=9491, m=10143, family="sparse"),
    "xmark": dict(n=6080, m=7028, family="tree"),
    # large (Table 1 right)
    "citeseer": dict(n=693947, m=312282, family="layered"),
    "go_uniprot": dict(n=6967956, m=34770235, family="tree"),
    "mapped_100K": dict(n=2658702, m=2660628, family="sparse"),
    "mapped_1M": dict(n=9387448, m=9440404, family="sparse"),
    "uniprotenc_22m": dict(n=1595443, m=1595442, family="tree"),
    "uniprotenc_100m": dict(n=16087294, m=16087293, family="tree"),
    "uniprotenc_150m": dict(n=25037599, m=25037598, family="tree"),
    "citeseerx": dict(n=6540399, m=15011259, family="layered"),
    "cit-Patents": dict(n=3774768, m=16518947, family="layered"),
}


def paper_dataset_analogue(name: str, scale: float = 1.0, seed: int = 7) -> CSRGraph:
    """Generate the synthetic analogue of a paper dataset, optionally scaled down."""
    spec = PAPER_DATASETS[name]
    n = max(int(spec["n"] * scale), 64)
    m = max(int(spec["m"] * scale), n // 2)
    fam = spec["family"]
    if fam == "sparse":
        return random_dag(n, m, seed=seed)
    if fam == "layered":
        return layered_dag(n, avg_out=max(m / n, 0.5), seed=seed)
    if fam == "tree":
        branching = max(int(round(n / max(m - n, 1))) if m > n else 8, 2)
        return tree_dag(n, branching=min(branching, 64), extra_frac=max(m / n - 1.0, 0.02), seed=seed)
    raise ValueError(fam)
