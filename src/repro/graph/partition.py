"""Vertex/edge partitioning for distributed message passing.

The dst-local contract: vertex blocks are contiguous ranges of n/P; edge
block p contains exactly the edges whose DESTINATION lies in vertex block p
(padded to equal size). Under this layout a segment-sum into destination
rows is shard-LOCAL — no dense n-sized partials, no all-reduce (the measured
dominant cost of the naive SPMD lowering; EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.graph.csr import CSRGraph


def partition_edges_by_dst(
    g: CSRGraph, n_shards: int, n_pad: int | None = None
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Returns (src, dst, mask, edges_per_shard) with edges grouped by the
    destination's vertex block and each block padded to the max block size.

    n_pad: padded vertex count (blocks are n_pad / n_shards wide).
    """
    n = n_pad or g.n
    assert n % n_shards == 0, (n, n_shards)
    block = n // n_shards
    src, dst = g.edges()
    owner = dst // block
    order = np.argsort(owner, kind="stable")
    src, dst, owner = src[order], dst[order], owner[order]
    counts = np.bincount(owner, minlength=n_shards)
    width = int(counts.max())
    out_src = np.zeros((n_shards, width), dtype=np.int32)
    out_dst = np.zeros((n_shards, width), dtype=np.int32)
    out_mask = np.zeros((n_shards, width), dtype=bool)
    start = 0
    for p in range(n_shards):
        c = int(counts[p])
        out_src[p, :c] = src[start : start + c]
        out_dst[p, :c] = dst[start : start + c]
        out_mask[p, :c] = True
        # padded entries point at the shard's own first vertex (masked anyway)
        out_dst[p, c:] = p * block
        start += c
    return (
        out_src.reshape(-1),
        out_dst.reshape(-1),
        out_mask.reshape(-1),
        width,
    )
