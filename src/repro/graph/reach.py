"""Ground-truth reachability utilities (host, bit-packed numpy).

Used by tests (oracle completeness oracle), by the set-cover/PWAH/K-Reach
baselines that genuinely require transitive closure, and by positive-query
sampling for the paper's "equal" query workload.
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph, topological_order


def transitive_closure_bits(g: CSRGraph) -> np.ndarray:
    """Bit-packed transitive closure of a DAG.

    Returns uint32[n, ceil(n/32)]; bit j of row i set iff i -> j (i != j,
    reflexive bits NOT set).

    Single reverse-topological sweep: TC(v) = OR_{w in N_out(v)} (bit(w) | TC(w)).
    O(n * n/32) words.
    """
    n = g.n
    words = (n + 31) // 32
    tc = np.zeros((n, words), dtype=np.uint32)
    topo = topological_order(g)
    for v in topo[::-1]:
        row = tc[v]
        for w in g.out_neighbors(v):
            row |= tc[w]
            row[w >> 5] |= np.uint32(1) << np.uint32(w & 31)
    return tc


def reaches_bit(tc: np.ndarray, u: int, v: int) -> bool:
    return bool((tc[u, v >> 5] >> np.uint32(v & 31)) & np.uint32(1))


def reachable_set(g: CSRGraph, u: int) -> np.ndarray:
    """bool[n] of vertices reachable from u (excluding u unless on a cycle-free path)."""
    n = g.n
    seen = np.zeros(n, dtype=bool)
    stack = [int(u)]
    while stack:
        v = stack.pop()
        for w in g.out_neighbors(v):
            if not seen[w]:
                seen[w] = True
                stack.append(int(w))
    return seen


def bfs_levels(g: CSRGraph, u: int, max_steps: int | None = None) -> np.ndarray:
    """int32[n] BFS levels from u; -1 = unreached; level[u] = 0."""
    n = g.n
    level = np.full(n, -1, dtype=np.int32)
    level[u] = 0
    frontier = [int(u)]
    d = 0
    while frontier and (max_steps is None or d < max_steps):
        d += 1
        nxt = []
        for v in frontier:
            for w in g.out_neighbors(v):
                if level[w] == -1:
                    level[w] = d
                    nxt.append(int(w))
        frontier = nxt
    return level


def sample_query_workload(
    g: CSRGraph,
    n_queries: int,
    rng: np.random.Generator,
    equal: bool = True,
    tc: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Paper §6.1 query workloads.

    equal=True: ~50% positive / 50% negative pairs (positives sampled from TC).
    equal=False ("random"): uniform random pairs.
    Returns (queries int32[n_queries, 2], truth bool[n_queries]).
    """
    n = g.n
    if not equal:
        q = rng.integers(0, n, size=(n_queries, 2)).astype(np.int32)
        if tc is None:
            tc = transitive_closure_bits(g)
        truth = np.array([reaches_bit(tc, int(a), int(b)) for a, b in q])
        return q, truth

    if tc is None:
        tc = transitive_closure_bits(g)
    # positive pool: expand bit rows of random sources
    pos: list[tuple[int, int]] = []
    attempts = 0
    while len(pos) < n_queries // 2 and attempts < 50 * n_queries:
        attempts += 1
        u = int(rng.integers(0, n))
        row = tc[u]
        nz = np.nonzero(row)[0]
        if nz.shape[0] == 0:
            continue
        w = int(nz[rng.integers(0, nz.shape[0])])
        bits = int(row[w])
        choices = [b for b in range(32) if (bits >> b) & 1]
        v = (w << 5) + choices[int(rng.integers(0, len(choices)))]
        pos.append((u, v))
    neg: list[tuple[int, int]] = []
    while len(neg) < n_queries - len(pos):
        u = int(rng.integers(0, n))
        v = int(rng.integers(0, n))
        if u != v and not reaches_bit(tc, u, v):
            neg.append((u, v))
    q = np.array(pos + neg, dtype=np.int32)
    truth = np.array([True] * len(pos) + [False] * len(neg))
    perm = rng.permutation(q.shape[0])
    return q[perm], truth[perm]
