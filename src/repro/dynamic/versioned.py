"""Versioned serving over a mutating oracle: epochs, COW publish, budgets.

The serving contract under churn:

  * every published **epoch** is an immutable ``LabelEpoch`` snapshot —
    labels, condensation comp array, and topological levels frozen together
    so a query pinned to epoch e sees one consistent world,
  * updates mutate a WORKING copy (``repair.MutableLabels`` + the live
    ``delta.CondensationState``); nothing a query can observe changes until
    ``publish()``,
  * publish copy-on-writes only the dirty rows into the previous snapshot's
    dense layout (``ReachabilityOracle.with_updated_rows``) and refreshes
    the QueryEngine in place — device label arrays and the bucketed-batching
    tier plan are re-derived exactly once per epoch, and when the tier
    widths come out unchanged the jit traces survive untouched,
  * a **staleness budget** decides repair-vs-rebuild: structural SCC events
    (merge/split), oversized delete cones, or cumulative churn beyond a
    fraction of the index all route the next publish through ``repro.build``
    for a compacting full rebuild (fresh §5.2 order, fresh ranks, fresh
    levels).

Query routing: the current epoch serves through the QueryEngine (all
backends, prefilters, bucketing); older pinned epochs serve through their
snapshot's retained device arrays (prefilters + one batched device
intersect — see ``LabelEpoch``), with the scalar host merge kept only as a
differential-test path.

Observability: every publish appends to ``growth_log`` — label-int count,
appends/drops of the epoch window, and the per-epoch growth rate.  Rank
drift under churn (repairs distribute hops at stale build-time ranks) shows
up as a persistently positive growth rate long before the staleness budget
fires; BENCH_dynamic.json surfaces it.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import List, Optional

import numpy as np

from repro.build.engine import build_distribution_labels
from repro.core.oracle import ReachabilityOracle
from repro.ft import inject
from repro.dynamic import delta as delta_mod
from repro.dynamic.delta import CondensationState, UpdateBatch
from repro.dynamic.repair import MutableLabels, repair_delete, repair_insert
from repro.graph.csr import CSRGraph
from repro.obs import metrics, trace
from repro.obs.state import ON
from repro.serve.engine import QueryEngine
from repro.serve.prefilter import apply_prefilters, topo_levels

# growth_log stays the per-epoch history view; the registry carries the
# live aggregates the unified snapshot surface reports
_M_PUBLISHES = metrics.counter(
    "dynamic_publishes_total", "published epochs, by kind",
    labelnames=("kind",))
_PUB_REPAIRED = _M_PUBLISHES.labels(kind="repaired")
_PUB_REBUILT = _M_PUBLISHES.labels(kind="rebuilt")
_M_LABEL_INTS = metrics.gauge(
    "dynamic_label_ints", "label ints in the latest published epoch")
_M_GROWTH_RATE = metrics.gauge(
    "dynamic_growth_rate", "label-int growth rate of the latest publish")


@dataclasses.dataclass(frozen=True)
class LabelEpoch:
    """One immutable published snapshot.

    The snapshot's device label arrays stay ALIVE for as long as the epoch
    is pinnable (``ReachabilityOracle.device_labels`` memoizes the upload on
    the immutable oracle), so pinned-epoch batches run the same prefilter +
    device-intersect path as the current epoch instead of falling back to a
    per-query host merge — pinning costs one upload per epoch, not one per
    pin."""
    epoch: int
    oracle: ReachabilityOracle
    comp: np.ndarray     # original vertex -> condensation id, frozen copy
    level: np.ndarray    # topological levels of the condensation, frozen

    def query_batch(self, queries: np.ndarray, device: bool = True) -> np.ndarray:
        """Batch answers in ORIGINAL vertex ids (pinned epoch).

        ``device=False`` forces the old per-query host merge (kept for
        differential tests)."""
        cq = self.comp[np.asarray(queries, dtype=np.int64)].astype(np.int32)
        o = self.oracle
        pf = apply_prefilters(cq, o.out_len, o.in_len, self.level)
        out = pf.decided & pf.value
        rest = np.nonzero(~pf.decided)[0]
        if rest.size == 0:
            return out
        if device:
            import jax.numpy as jnp

            from repro.serve.engine import serve_step

            lo, li = o.device_labels()  # memoized: no per-pin re-upload
            out[rest] = np.asarray(serve_step(lo, li, jnp.asarray(cq[rest])))
            return out
        for i in rest:
            out[i] = o.query(int(cq[i, 0]), int(cq[i, 1]))
        return out


@dataclasses.dataclass
class ApplyStats:
    """What one ``apply`` batch did."""
    n_updates: int = 0
    noop: int = 0
    repaired_inserts: int = 0
    repaired_deletes: int = 0
    structural: int = 0
    deferred: int = 0          # events skipped because a rebuild is pending
    label_appends: int = 0
    label_drops: int = 0
    rebuild_pending: bool = False


class DynamicOracle:
    """Reachability oracle over a LIVE digraph: edge updates between epochs.

    Parameters
    ----------
    g : CSRGraph
        Initial digraph (cycles allowed — SCCs are condensed and maintained
        incrementally from then on).
    backend, mesh, bucketing : forwarded to the QueryEngine.
    staleness_budget : float
        Fraction of the index (in label ints) the incremental repairs may
        churn before the next publish compacts via a full rebuild.
    max_cone_frac : float
        A delete whose affected cone (|anc(u)| + |desc(v)|) exceeds this
        fraction of live condensation vertices falls back to rebuild — past
        that point the scoped re-distribution costs more than building.
    keep_epochs : int
        How many published snapshots stay pinnable.
    """

    def __init__(
        self,
        g: CSRGraph,
        backend: str = "auto",
        mesh=None,
        bucketing: bool = True,
        staleness_budget: float = 0.5,
        max_cone_frac: float = 0.1,
        keep_epochs: int = 4,
        build_impl: str = "auto",
    ):
        self.delta = CondensationState(g)
        self.staleness_budget = float(staleness_budget)
        self.max_cone_frac = float(max_cone_frac)
        self.keep_epochs = int(keep_epochs)
        self.build_impl = build_impl
        self._rebuild_pending = False
        self._churn = 0
        self.rebuild_count = 0
        self.repair_count = 0
        # per-publish label-ints trajectory (rank-drift observability)
        self.growth_log: List[dict] = []
        self._last_ints = 0
        self._rebuild_labels()
        self._last_ints = self.labels.label_ints()
        self._epochs: "OrderedDict[int, LabelEpoch]" = OrderedDict()
        self._epoch = 0
        self.engine = QueryEngine(
            self._snapshot_oracle(), backend=backend, mesh=mesh,
            bucketing=bucketing, level=self.level,
            comp_source=self._current_comp, epoch=0,
            # frozen materialization of the initial condensation DAG: the
            # degradation ladder's search rung must answer against the
            # SERVED epoch's graph, never the live mutating delta
            fallback_graph=self.delta.dag_csr(),
        )
        self._install_epoch(self._snapshot_oracle())

    # ----------------------------------------------------------- internals

    def _current_comp(self) -> np.ndarray:
        """Comp array of the CURRENT epoch (what the engine serves)."""
        return self._epochs[self._epoch].comp if self._epochs else self.delta.comp

    def _rebuild_labels(self) -> None:
        """Compacting rebuild: fresh order/ranks/levels from repro.build."""
        dag = self.delta.dag_csr()
        oracle = build_distribution_labels(dag, impl=self.build_impl)
        self.hop_rank = oracle.hop_rank
        self.inv_rank = np.argsort(self.hop_rank).astype(np.int32)
        self.labels = MutableLabels.from_oracle(oracle)
        self.level = topo_levels(dag)
        self._base_oracle = oracle  # COW base for the next publish
        self._rebuild_pending = False
        self._churn = 0
        self.rebuild_count += 1

    def _snapshot_oracle(self) -> ReachabilityOracle:
        """Finalize the working rows into an immutable oracle via COW."""
        out_rows, in_rows = self.labels.take_dirty()
        if out_rows or in_rows:
            self._base_oracle = self._base_oracle.with_updated_rows(out_rows, in_rows)
        return self._base_oracle

    def _install_epoch(self, oracle: ReachabilityOracle) -> None:
        ep = LabelEpoch(
            epoch=self._epoch,
            oracle=oracle,
            comp=self.delta.comp.copy(),
            level=np.asarray(self.level, dtype=np.int32).copy(),
        )
        self._epochs[self._epoch] = ep
        while len(self._epochs) > self.keep_epochs:
            self._epochs.popitem(last=False)

    def _raise_levels(self, cu: int, cv: int) -> None:
        """Scoped topological-level maintenance after DAG insert (cu, cv).

        Levels must stay a valid topological numbering for the serve-path
        level prefilter to remain sound; deletions only relax constraints
        (the old numbering stays valid), insertions propagate forward."""
        if self.level[cu] < self.level[cv]:
            return
        level = self.level
        level[cv] = level[cu] + 1
        stack = [cv]
        while stack:
            x = stack.pop()
            lx = level[x] + 1
            for w in self.delta.dag_out[x]:
                if level[w] < lx:
                    level[w] = lx
                    stack.append(w)

    # -------------------------------------------------------------- update

    def apply(self, batch: UpdateBatch) -> ApplyStats:
        """Apply an update batch to the WORKING state (visible at publish).

        Each update flows: condensation maintenance (``delta``) -> label
        repair for plain DAG events -> structural events or budget misses
        mark the epoch for a compacting rebuild at the next publish.
        """
        stats = ApplyStats(n_updates=len(batch))
        max_cone = max(64, int(self.max_cone_frac * max(self.delta.n_live, 1)))
        for up in batch.updates:
            ev = (self.delta.insert(up.u, up.v) if up.insert
                  else self.delta.delete(up.u, up.v))
            if ev.kind == delta_mod.NOOP:
                stats.noop += 1
                continue
            if ev.structural:
                stats.structural += 1
                self._rebuild_pending = True
                continue
            if self._rebuild_pending:
                stats.deferred += 1
                continue  # labels are already stale; the rebuild covers it
            if ev.kind == delta_mod.DAG_INSERT:
                before = self.labels.appends
                repair_insert(self.labels, self.delta, self.inv_rank,
                              ev.cu, ev.cv)
                self._raise_levels(ev.cu, ev.cv)
                stats.repaired_inserts += 1
                stats.label_appends += self.labels.appends - before
                self.repair_count += 1
            else:  # DAG_DELETE
                before_a, before_d = self.labels.appends, self.labels.drops
                ok = repair_delete(self.labels, self.delta, self.hop_rank,
                                   self.inv_rank, ev.cu, ev.cv, max_cone)
                if not ok:
                    self._rebuild_pending = True
                    continue
                stats.repaired_deletes += 1
                stats.label_appends += self.labels.appends - before_a
                stats.label_drops += self.labels.drops - before_d
                self.repair_count += 1
        self._churn += stats.label_appends + stats.label_drops
        total = max(self.labels.label_ints(), 1)
        if self._churn > self.staleness_budget * total:
            self._rebuild_pending = True
        stats.rebuild_pending = self._rebuild_pending
        return stats

    def publish(self) -> int:
        """Publish the working state as a new immutable epoch.

        TRANSACTIONAL: every expensive step (compacting rebuild, COW row
        merge, frozen-DAG materialization) is staged into locals first; live
        state — epoch counter, pinned snapshots, the serving engine, the
        dirty-row sets — mutates only at the commit point below.  A failure
        mid-publish (crash, injected fault, rebuild OOM) leaves the previous
        epoch serving and the working state intact, so the publish can
        simply be retried."""
        rebuilt = self._rebuild_pending
        sp = (trace.span("publish.stage", cat="dynamic",
                         args={"epoch": self._epoch + 1, "rebuilt": rebuilt})
              if ON.enabled else trace.NOOP_SPAN)
        # ---- stage ----------------------------------------------------
        with sp:
            staged_rebuild = None
            if rebuilt:
                dag = self.delta.dag_csr()
                base = build_distribution_labels(dag, impl=self.build_impl)
                staged_rebuild = {
                    "hop_rank": base.hop_rank,
                    "inv_rank": np.argsort(base.hop_rank).astype(np.int32),
                    "labels": MutableLabels.from_oracle(base),
                    "level": topo_levels(dag),
                }
                oracle = base
            else:
                out_rows, in_rows = self.labels.peek_dirty()
                oracle = (self._base_oracle.with_updated_rows(out_rows, in_rows)
                          if (out_rows or in_rows) else self._base_oracle)
            fallback = self.delta.dag_csr()  # frozen graph of THIS epoch
            # chaos hook: a crash here must leave the old epoch serving and
            # the epoch counter unchanged (regression: dynamic.publish
            # injection)
            inject.fire("dynamic.publish", epoch=self._epoch + 1,
                        rebuilt=rebuilt)
        sp = (trace.span("publish.commit", cat="dynamic",
                         args={"epoch": self._epoch + 1, "rebuilt": rebuilt})
              if ON.enabled else trace.NOOP_SPAN)
        # ---- commit ---------------------------------------------------
        with sp:
            # read the epoch window's churn BEFORE a rebuild swaps in a fresh
            # MutableLabels (whose counters start at zero) — rebuild epochs
            # are exactly the churn-heaviest ones
            appends, drops = self.labels.epoch_counters()
            if rebuilt:
                self.hop_rank = staged_rebuild["hop_rank"]
                self.inv_rank = staged_rebuild["inv_rank"]
                self.labels = staged_rebuild["labels"]
                self.level = staged_rebuild["level"]
                self._rebuild_pending = False
                self._churn = 0
                self.rebuild_count += 1
            else:
                self.labels.clear_dirty()
            self._base_oracle = oracle
            self._epoch += 1
            self._install_epoch(oracle)
            self.engine.refresh(oracle, level=self.level, epoch=self._epoch,
                                fallback_graph=fallback)
        # growth-rate tracking: a persistently positive rate under churn is
        # rank drift (repairs distribute at stale build-time ranks) and
        # argues for re-ranking before the staleness budget fires
        ints = self.labels.label_ints()
        prev = max(self._last_ints, 1)
        rate = round((ints - self._last_ints) / prev, 6)
        self.growth_log.append({
            "epoch": self._epoch,
            "label_ints": ints,
            "appends": appends,
            "drops": drops,
            "rebuilt": rebuilt,
            "growth_rate": rate,
        })
        (_PUB_REBUILT if rebuilt else _PUB_REPAIRED).inc()
        _M_LABEL_INTS.set(ints)
        _M_GROWTH_RATE.set(rate)
        self._last_ints = ints
        return self._epoch

    # -------------------------------------------------------------- serve

    @property
    def epoch(self) -> int:
        return self._epoch

    @property
    def epochs(self) -> List[int]:
        return list(self._epochs.keys())

    @property
    def total_label_size(self) -> int:
        return self._epochs[self._epoch].oracle.total_label_size

    def snapshot(self, epoch: Optional[int] = None) -> LabelEpoch:
        ep = self._epoch if epoch is None else int(epoch)
        if ep not in self._epochs:
            raise KeyError(
                f"epoch {ep} not pinnable (kept: {list(self._epochs)})")
        return self._epochs[ep]

    def query(self, u: int, v: int, epoch: Optional[int] = None) -> bool:
        """Single query in ORIGINAL vertex ids, optionally pinned."""
        if epoch is None or epoch == self._epoch:
            return self.engine.query(int(u), int(v))
        ep = self.snapshot(epoch)
        return bool(ep.query_batch(np.array([[u, v]], dtype=np.int64))[0])

    def serve(self, queries: np.ndarray, backend: Optional[str] = None,
              epoch: Optional[int] = None,
              deadline: Optional[float] = None) -> np.ndarray:
        """Batched queries in ORIGINAL vertex ids.

        ``epoch=None`` (or the current epoch) runs the full QueryEngine
        path; an older pinned epoch answers from its frozen snapshot.
        ``deadline`` is the daemon's absolute latency budget (see
        ``QueryEngine.query_batch``; pinned-epoch snapshots ignore it — the
        snapshot path has no retrace risk to dodge)."""
        if epoch is None or epoch == self._epoch:
            return self.engine.query_batch(np.asarray(queries), backend=backend,
                                           deadline=deadline)
        return self.snapshot(epoch).query_batch(np.asarray(queries))
