"""Dynamic oracle: incremental label maintenance under edge updates.

    dyn = DynamicOracle(g)                  # cycles allowed, SCCs maintained
    dyn.apply(UpdateBatch.of(inserts=[(u, v)], deletes=[(a, b)]))
    e = dyn.publish()                       # new immutable epoch
    dyn.serve(queries)                      # current epoch, full engine path
    dyn.serve(queries, epoch=e - 1)         # pinned older snapshot

Layers: ``delta`` (edge log + SCC-condensation maintenance), ``repair``
(resumed pruned-BFS label repair), ``versioned`` (epoch snapshots, COW
publish, staleness budget), ``durable`` (WAL + snapshot crash recovery),
``workload`` (interleaved trace generation and replay).
"""
from repro.dynamic.delta import (
    CondensationState,
    DeltaEvent,
    EdgeUpdate,
    UpdateBatch,
)
from repro.dynamic.durable import DurableDynamicOracle
from repro.dynamic.repair import MutableLabels, repair_delete, repair_insert
from repro.dynamic.versioned import ApplyStats, DynamicOracle, LabelEpoch
from repro.dynamic.workload import ReplayStats, TraceOp, generate_trace, replay

__all__ = [
    "ApplyStats",
    "CondensationState",
    "DeltaEvent",
    "DurableDynamicOracle",
    "DynamicOracle",
    "EdgeUpdate",
    "LabelEpoch",
    "MutableLabels",
    "ReplayStats",
    "TraceOp",
    "UpdateBatch",
    "generate_trace",
    "repair_delete",
    "repair_insert",
    "replay",
]
