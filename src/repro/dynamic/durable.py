"""Crash-safe dynamic oracle: WAL-logged updates + publish-boundary snapshots.

``DurableDynamicOracle`` wraps the in-memory ``DynamicOracle`` with the
standard database recovery contract:

  * every accepted edge update is appended (fsync'd) to a write-ahead log
    BEFORE it mutates in-memory state — an acknowledged update survives any
    crash,
  * every ``publish`` writes a checksummed snapshot of the full oracle state
    (labels + condensation + levels) through ``repro.persist`` and then
    drops a publish marker into the WAL,
  * ``recover(state_dir)`` = newest verifiable snapshot + WAL replay of the
    records past it, re-publishing at each replayed marker and once more
    for any unpublished tail — so recovery serves every acknowledged
    update and its verdicts agree with a fresh rebuild of the final graph.

Snapshots restore WITHOUT a rebuild: they are taken only at publish
boundaries, where the working label rows equal the published oracle's rows,
and the condensation is serialized exactly (``CondensationState.to_arrays``)
because a fresh Tarjan pass could assign different comp ids than the
incrementally maintained ones the saved labels are written in.
"""
from __future__ import annotations

import os
import re
import shutil
import warnings
from collections import OrderedDict
from typing import List

import numpy as np

from repro.core.oracle import ReachabilityOracle
from repro.dynamic.delta import CondensationState, EdgeUpdate, UpdateBatch
from repro.dynamic.repair import MutableLabels
from repro.dynamic.versioned import DynamicOracle
from repro.graph.csr import CSRGraph
from repro.persist.blocks import CorruptSnapshotError, load_blocks, save_blocks
from repro.persist.wal import KIND_DELETE, KIND_INSERT, WriteAheadLog
from repro.serve.engine import QueryEngine

_SNAP_RE = re.compile(r"^snap_(\d{8})$")
_WAL_NAME = "wal.bin"


class DurableDynamicOracle(DynamicOracle):
    """``DynamicOracle`` + durability (see module docstring).

    ``state_dir`` holds the WAL (``wal.bin``) and the last
    ``snapshot_keep`` publish snapshots (``snap_<epoch>``)."""

    def __init__(self, g: CSRGraph, state_dir: str, snapshot_keep: int = 2,
                 **kwargs):
        os.makedirs(state_dir, exist_ok=True)
        self.state_dir = state_dir
        self.snapshot_keep = max(int(snapshot_keep), 1)
        self._replaying = False
        super().__init__(g, **kwargs)
        self.wal = WriteAheadLog(os.path.join(state_dir, _WAL_NAME))
        self._snapshot_state()
        self.wal.publish_marker(self._epoch)

    # ------------------------------------------------------------ durability

    def apply(self, batch: UpdateBatch):
        """WAL first, memory second: an update is acknowledged only once it
        is durable, so a crash can lose at most unacknowledged work."""
        if not self._replaying:
            for up in batch.updates:
                self.wal.append(KIND_INSERT if up.insert else KIND_DELETE,
                                up.u, up.v)
        return super().apply(batch)

    def publish(self) -> int:
        ep = super().publish()
        # crash windows: after the (transactional, in-memory) publish but
        # before the snapshot -> recovery replays the WAL tail onto the
        # previous snapshot; after the snapshot but before the marker ->
        # the snapshot's recorded wal_seq already covers everything and the
        # marker is simply rewritten at the next publish
        self._snapshot_state()
        self.wal.publish_marker(ep)
        return ep

    def _snapshot_state(self) -> None:
        d_arr, d_meta = self.delta.to_arrays()
        o = self._base_oracle
        arrays = {f"delta_{k}": v for k, v in d_arr.items()}
        arrays.update(
            L_out=o.L_out, L_in=o.L_in, out_len=o.out_len, in_len=o.in_len,
            hop_rank=o.hop_rank, level=np.asarray(self.level, dtype=np.int32),
        )
        meta = {
            "kind": "DurableDynamicOracle",
            "delta": d_meta,
            "epoch": int(self._epoch),
            "churn": int(self._churn),
            "wal_seq": int(self.wal.last_seq),
            "rebuild_count": int(self.rebuild_count),
            "repair_count": int(self.repair_count),
            "last_ints": int(self._last_ints),
        }
        save_blocks(os.path.join(self.state_dir, f"snap_{self._epoch:08d}"),
                    arrays, meta)
        self._gc_snapshots()

    def _gc_snapshots(self) -> None:
        names = sorted(d for d in os.listdir(self.state_dir) if _SNAP_RE.match(d))
        for stale in names[: -self.snapshot_keep]:
            shutil.rmtree(os.path.join(self.state_dir, stale),
                          ignore_errors=True)

    # -------------------------------------------------------------- recovery

    @classmethod
    def recover(cls, state_dir: str, backend: str = "auto", mesh=None,
                bucketing: bool = True, staleness_budget: float = 0.5,
                max_cone_frac: float = 0.1, keep_epochs: int = 4,
                build_impl: str = "auto") -> "DurableDynamicOracle":
        """Restore from ``state_dir``: newest verifiable snapshot + WAL
        replay.  Raises ``CorruptSnapshotError`` when no snapshot passes
        verification (loud failure — a silently empty oracle would serve
        wrong verdicts)."""
        names = sorted((d for d in os.listdir(state_dir) if _SNAP_RE.match(d)),
                       reverse=True)
        arrays = meta = None
        for name in names:
            spath = os.path.join(state_dir, name)
            try:
                arrays, meta, _ = load_blocks(spath, strict=True)
                break
            except CorruptSnapshotError as e:
                warnings.warn(f"skipping unusable snapshot {spath}: {e}",
                              stacklevel=2)
        if arrays is None:
            raise CorruptSnapshotError(
                f"no verifiable snapshot in {state_dir} "
                f"(found {len(names)}, all corrupt or none present)")

        self = object.__new__(cls)
        self.state_dir = state_dir
        self.snapshot_keep = 2
        self._replaying = False
        self.staleness_budget = float(staleness_budget)
        self.max_cone_frac = float(max_cone_frac)
        self.keep_epochs = int(keep_epochs)
        self.build_impl = build_impl
        self.delta = CondensationState.from_arrays(
            {k[len("delta_"):]: v for k, v in arrays.items()
             if k.startswith("delta_")},
            meta["delta"])
        oracle = ReachabilityOracle(
            L_out=np.ascontiguousarray(arrays["L_out"], dtype=np.int32),
            L_in=np.ascontiguousarray(arrays["L_in"], dtype=np.int32),
            out_len=np.ascontiguousarray(arrays["out_len"], dtype=np.int32),
            in_len=np.ascontiguousarray(arrays["in_len"], dtype=np.int32),
            hop_rank=np.ascontiguousarray(arrays["hop_rank"], dtype=np.int32),
        )
        # no rebuild: the snapshot was taken at a publish boundary, where the
        # working rows equal the published oracle's rows exactly
        self.hop_rank = oracle.hop_rank
        self.inv_rank = np.argsort(self.hop_rank).astype(np.int32)
        self.labels = MutableLabels.from_oracle(oracle)
        self.level = np.ascontiguousarray(arrays["level"], dtype=np.int32)
        self._base_oracle = oracle
        self._rebuild_pending = False  # publish boundaries never carry one
        self._churn = int(meta["churn"])
        self.rebuild_count = int(meta["rebuild_count"])
        self.repair_count = int(meta["repair_count"])
        self.growth_log: List[dict] = []
        self._last_ints = int(meta["last_ints"])
        self._epochs = OrderedDict()
        self._epoch = int(meta["epoch"])
        self._install_epoch(oracle)
        self.engine = QueryEngine(
            oracle, backend=backend, mesh=mesh, bucketing=bucketing,
            level=self.level, comp_source=self._current_comp,
            epoch=self._epoch, fallback_graph=self.delta.dag_csr(),
        )

        self.wal = WriteAheadLog(os.path.join(state_dir, _WAL_NAME))
        tail = self.wal.replay(after_seq=int(meta["wal_seq"]))
        self.recovered_records = len(tail)
        self._replaying = True
        try:
            pending: List[EdgeUpdate] = []
            for rec in tail:
                if rec.is_publish:
                    if pending:  # a marker with no tail is already covered
                        self.apply(UpdateBatch(tuple(pending)))
                        pending = []
                        self.publish()
                else:
                    pending.append(
                        EdgeUpdate(rec.kind == KIND_INSERT, rec.u, rec.v))
            if pending:
                # acknowledged (WAL-durable) but never published before the
                # crash: recovery publishes them so they are served
                self.apply(UpdateBatch(tuple(pending)))
                self.publish()
        finally:
            self._replaying = False
        return self
