"""Edge-update log + incremental SCC-condensation maintenance.

The static pipeline condenses SCCs once (``graph/scc.py``) and labels the
resulting DAG.  Under live edge updates the condensation itself mutates:

  * an insertion (u, v) whose condensation endpoints already reach back
    (cv ->* cu) closes a cycle — every condensation vertex on a cv ~> cu
    path collapses **in place** into one SCC (the representative keeps its
    id; absorbed ids become dead, empty vertices so label rows and ranks
    stay index-stable),
  * a deletion inside an SCC may split it — a **scoped** re-check runs
    Tarjan (``graph/scc.py``) on the induced subgraph of that SCC's members
    only, never the whole graph; split parts get fresh condensation ids.

Everything else is a plain DAG edge event: insertions/deletions between
distinct comps adjust a per-condensation-edge multiplicity (several original
edges can back one DAG edge) and only surface to the label layer when a DAG
edge actually appears or disappears.  ``CondensationState.apply`` returns
one ``DeltaEvent`` per update so ``repro.dynamic.versioned`` can route:
``dag_insert``/``dag_delete`` -> incremental label repair (``repair.py``),
``merge``/``split`` (structural=True) -> compacting rebuild.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Set, Tuple

import numpy as np

from repro.graph.csr import CSRGraph, from_edges
from repro.graph.scc import tarjan_scc

# event kinds
NOOP = "noop"
DAG_INSERT = "dag_insert"   # new condensation edge, still a DAG -> repairable
DAG_DELETE = "dag_delete"   # condensation edge vanished            -> repairable
MERGE = "merge"             # insertion closed a cycle              -> structural
SPLIT = "split"             # deletion split an SCC                 -> structural


@dataclasses.dataclass(frozen=True)
class EdgeUpdate:
    """One logged update in ORIGINAL vertex space."""
    insert: bool
    u: int
    v: int


@dataclasses.dataclass(frozen=True)
class UpdateBatch:
    """An ordered batch of edge updates (the unit of apply/publish)."""
    updates: Tuple[EdgeUpdate, ...]

    def __len__(self) -> int:
        return len(self.updates)

    @staticmethod
    def of(inserts: Iterable[Tuple[int, int]] = (),
           deletes: Iterable[Tuple[int, int]] = ()) -> "UpdateBatch":
        ups = [EdgeUpdate(True, int(u), int(v)) for u, v in inserts]
        ups += [EdgeUpdate(False, int(u), int(v)) for u, v in deletes]
        return UpdateBatch(tuple(ups))


@dataclasses.dataclass(frozen=True)
class DeltaEvent:
    """What one edge update did to the condensation."""
    kind: str
    cu: int = -1            # condensation endpoints (dag_insert / dag_delete)
    cv: int = -1
    merged: Tuple[int, ...] = ()   # comp ids collapsed (merge)
    split_into: Tuple[int, ...] = ()  # comp ids after a split

    @property
    def structural(self) -> bool:
        return self.kind in (MERGE, SPLIT)


class CondensationState:
    """Mutable SCC condensation of a digraph under edge updates.

    Original-graph adjacency lives in python sets (the update log's working
    form); the condensation is comp ids + DAG adjacency sets + per-DAG-edge
    multiplicities.  Comp ids are index-stable: merges keep the
    representative's id and leave absorbed ids dead (no members, no edges);
    splits append fresh ids.  ``dag_csr()`` materializes the current DAG for
    rebuilds; dead ids come out isolated and never receive queries because
    ``comp`` never points at them.
    """

    def __init__(self, g: CSRGraph):
        self.n_orig = g.n
        self.out_adj: List[Set[int]] = [set(map(int, g.out_neighbors(v)))
                                        for v in range(g.n)]
        self.in_adj: List[Set[int]] = [set() for _ in range(g.n)]
        for u in range(g.n):
            for w in self.out_adj[u]:
                self.in_adj[w].add(u)
        comp, k = tarjan_scc(g)
        self.comp = comp.astype(np.int32).copy()
        self.n_comp = int(k)
        self.members: List[List[int]] = [[] for _ in range(k)]
        for v in range(g.n):
            self.members[int(comp[v])].append(v)
        self.dead: Set[int] = set()
        self.edge_mult: Dict[Tuple[int, int], int] = {}
        for u in range(g.n):
            cu = int(comp[u])
            for w in self.out_adj[u]:
                cw = int(comp[w])
                if cu != cw:
                    key = (cu, cw)
                    self.edge_mult[key] = self.edge_mult.get(key, 0) + 1
        self.dag_out: List[Set[int]] = [set() for _ in range(k)]
        self.dag_in: List[Set[int]] = [set() for _ in range(k)]
        for (a, b) in self.edge_mult:
            self.dag_out[a].add(b)
            self.dag_in[b].add(a)

    # ------------------------------------------------------------ queries

    @property
    def n_live(self) -> int:
        return self.n_comp - len(self.dead)

    def dag_m(self) -> int:
        return len(self.edge_mult)

    def dag_csr(self) -> CSRGraph:
        """Materialize the current condensation DAG (dead ids isolated)."""
        if self.edge_mult:
            src, dst = zip(*self.edge_mult.keys())
        else:
            src, dst = (), ()
        return from_edges(self.n_comp, np.asarray(src, dtype=np.int64),
                          np.asarray(dst, dtype=np.int64))

    # ------------------------------------------------------- serialization

    def to_arrays(self) -> Tuple[Dict[str, np.ndarray], dict]:
        """Exact state as (named arrays, meta) for ``repro.persist``.

        Only the irreducible state is saved: the original edge list, the
        comp map, the members lists (in live order — future split id
        assignments depend on it), and the DAG edge multiplicities.
        ``in_adj``, ``dag_out``/``dag_in`` and ``dead`` are derived on load
        (dead ids are exactly the memberless ones)."""
        from repro.persist.blocks import pack_ragged

        src = np.fromiter(
            (u for u in range(self.n_orig) for _ in self.out_adj[u]),
            dtype=np.int64)
        dst = np.fromiter(
            (w for u in range(self.n_orig) for w in sorted(self.out_adj[u])),
            dtype=np.int64)
        mem_vals, mem_offs = pack_ragged(self.members, dtype=np.int64)
        if self.edge_mult:
            em = np.asarray(
                [(a, b, c) for (a, b), c in sorted(self.edge_mult.items())],
                dtype=np.int64)
        else:
            em = np.empty((0, 3), dtype=np.int64)
        arrays = {
            "edges_src": src, "edges_dst": dst,
            "comp": self.comp, "members_vals": mem_vals,
            "members_offs": mem_offs, "edge_mult": em,
        }
        return arrays, {"n_orig": self.n_orig, "n_comp": self.n_comp}

    @classmethod
    def from_arrays(cls, arrays: Dict[str, np.ndarray], meta: dict) -> "CondensationState":
        """Rebuild the exact state saved by ``to_arrays`` — no Tarjan run
        (a fresh SCC pass could assign different comp ids than the
        incrementally maintained ones the saved labels are written in)."""
        self = object.__new__(cls)
        self.n_orig = int(meta["n_orig"])
        self.out_adj = [set() for _ in range(self.n_orig)]
        self.in_adj = [set() for _ in range(self.n_orig)]
        for u, w in zip(arrays["edges_src"], arrays["edges_dst"]):
            self.out_adj[int(u)].add(int(w))
            self.in_adj[int(w)].add(int(u))
        self.comp = np.asarray(arrays["comp"], dtype=np.int32).copy()
        self.n_comp = int(meta["n_comp"])
        from repro.persist.blocks import unpack_ragged

        self.members = unpack_ragged(arrays["members_vals"], arrays["members_offs"])
        self.dead = {c for c in range(self.n_comp) if not self.members[c]}
        self.edge_mult = {
            (int(a), int(b)): int(c) for a, b, c in arrays["edge_mult"]}
        self.dag_out = [set() for _ in range(self.n_comp)]
        self.dag_in = [set() for _ in range(self.n_comp)]
        for (a, b) in self.edge_mult:
            self.dag_out[a].add(b)
            self.dag_in[b].add(a)
        return self

    def _dag_reaches(self, a: int, b: int) -> bool:
        """BFS a ->* b over the condensation (scoped cycle probe)."""
        if a == b:
            return True
        seen = {a}
        stack = [a]
        while stack:
            x = stack.pop()
            for y in self.dag_out[x]:
                if y == b:
                    return True
                if y not in seen:
                    seen.add(y)
                    stack.append(y)
        return False

    def _cone(self, root: int, adj: List[Set[int]]) -> Set[int]:
        """Reflexive closure of ``root`` under ``adj`` (descendants for
        dag_out, ancestors for dag_in)."""
        seen = {root}
        stack = [root]
        while stack:
            x = stack.pop()
            for y in adj[x]:
                if y not in seen:
                    seen.add(y)
                    stack.append(y)
        return seen

    # ------------------------------------------------------------ updates

    def apply(self, batch: UpdateBatch) -> List[DeltaEvent]:
        return [self.insert(up.u, up.v) if up.insert else self.delete(up.u, up.v)
                for up in batch.updates]

    def insert(self, u: int, v: int) -> DeltaEvent:
        u, v = int(u), int(v)
        if u == v or v in self.out_adj[u]:
            return DeltaEvent(NOOP)
        self.out_adj[u].add(v)
        self.in_adj[v].add(u)
        cu, cv = int(self.comp[u]), int(self.comp[v])
        if cu == cv:
            return DeltaEvent(NOOP)  # intra-SCC edge: condensation unchanged
        key = (cu, cv)
        if key in self.edge_mult:
            self.edge_mult[key] += 1
            return DeltaEvent(NOOP)  # DAG edge already present
        if self._dag_reaches(cv, cu):
            # the new edge closes a cycle: every comp on a cv ~> cu path
            # joins one SCC.  S = desc(cv) cap anc(cu) (reflexive), computed
            # before wiring the new edge in.
            S = self._cone(cv, self.dag_out) & self._cone(cu, self.dag_in)
            S.add(cu)
            S.add(cv)
            self.edge_mult[key] = 1
            self.dag_out[cu].add(cv)
            self.dag_in[cv].add(cu)
            rep = self._merge(S)
            return DeltaEvent(MERGE, cu=rep, merged=tuple(sorted(S)))
        self.edge_mult[key] = 1
        self.dag_out[cu].add(cv)
        self.dag_in[cv].add(cu)
        return DeltaEvent(DAG_INSERT, cu=cu, cv=cv)

    def delete(self, u: int, v: int) -> DeltaEvent:
        u, v = int(u), int(v)
        if u == v or v not in self.out_adj[u]:
            return DeltaEvent(NOOP)
        self.out_adj[u].discard(v)
        self.in_adj[v].discard(u)
        cu, cv = int(self.comp[u]), int(self.comp[v])
        if cu != cv:
            key = (cu, cv)
            self.edge_mult[key] -= 1
            if self.edge_mult[key] > 0:
                return DeltaEvent(NOOP)  # other original edges still back it
            del self.edge_mult[key]
            self.dag_out[cu].discard(cv)
            self.dag_in[cv].discard(cu)
            return DeltaEvent(DAG_DELETE, cu=cu, cv=cv)
        # intra-SCC deletion: scoped re-check of THIS component only
        return self._recheck_scc(cu)

    # --------------------------------------------------------- structural

    def _merge(self, S: Set[int]) -> int:
        """Collapse comps ``S`` in place; the smallest id is representative."""
        rep = min(S)
        for c in S:
            if c == rep:
                continue
            for ov in self.members[c]:
                self.comp[ov] = rep
            self.members[rep].extend(self.members[c])
            self.members[c] = []
            self.dead.add(c)
        # remap condensation edges touching S
        moved: Dict[Tuple[int, int], int] = {}
        for (a, b) in list(self.edge_mult.keys()):
            if a in S or b in S:
                cnt = self.edge_mult.pop((a, b))
                a2 = rep if a in S else a
                b2 = rep if b in S else b
                if a2 != b2:
                    moved[(a2, b2)] = moved.get((a2, b2), 0) + cnt
                self.dag_out[a].discard(b)
                self.dag_in[b].discard(a)
        for (a, b), cnt in moved.items():
            self.edge_mult[(a, b)] = self.edge_mult.get((a, b), 0) + cnt
            self.dag_out[a].add(b)
            self.dag_in[b].add(a)
        return rep

    def _recheck_scc(self, c: int) -> DeltaEvent:
        """Tarjan on the induced subgraph of comp ``c``'s members."""
        mem = self.members[c]
        if len(mem) <= 1:
            return DeltaEvent(NOOP)
        local = {ov: i for i, ov in enumerate(mem)}
        src, dst = [], []
        for ov in mem:
            li = local[ov]
            for w in self.out_adj[ov]:
                lj = local.get(w)
                if lj is not None:
                    src.append(li)
                    dst.append(lj)
        sub = from_edges(len(mem), np.asarray(src, dtype=np.int64),
                         np.asarray(dst, dtype=np.int64))
        lcomp, lk = tarjan_scc(sub)
        if lk == 1:
            return DeltaEvent(NOOP)  # still strongly connected
        # split: local comp 0 keeps id c, the rest get fresh ids
        new_ids = [c] + list(range(self.n_comp, self.n_comp + lk - 1))
        self.n_comp += lk - 1
        for _ in range(lk - 1):
            self.members.append([])
            self.dag_out.append(set())
            self.dag_in.append(set())
        groups: List[List[int]] = [[] for _ in range(lk)]
        for i, ov in enumerate(mem):
            groups[int(lcomp[i])].append(ov)
        for gi, group in enumerate(groups):
            cid = new_ids[gi]
            self.members[cid] = group
            for ov in group:
                self.comp[ov] = cid
        # recompute condensation edges incident to the old component: drop
        # everything touching c, then re-derive from the members' original
        # edges (intra-SCC edges may now cross sub-comps, and old cross
        # edges re-attach to the right sub-comp)
        for (a, b) in list(self.edge_mult.keys()):
            if a == c or b == c:
                del self.edge_mult[(a, b)]
                self.dag_out[a].discard(b)
                self.dag_in[b].discard(a)
        touched: Dict[Tuple[int, int], int] = {}
        for ov in mem:
            co = int(self.comp[ov])
            for w in self.out_adj[ov]:
                cw = int(self.comp[w])
                if cw != co:
                    touched[(co, cw)] = touched.get((co, cw), 0) + 1
            for w in self.in_adj[ov]:
                if w in local:
                    continue  # member->member edges were counted above
                cw = int(self.comp[w])
                touched[(cw, co)] = touched.get((cw, co), 0) + 1
        for (a, b), cnt in touched.items():
            self.edge_mult[(a, b)] = self.edge_mult.get((a, b), 0) + cnt
            self.dag_out[a].add(b)
            self.dag_in[b].add(a)
        return DeltaEvent(SPLIT, cu=c, split_into=tuple(new_ids))
