"""Interleaved update/query workload: trace generation + replay.

A trace is an alternating sequence of update batches and query batches —
the shape of live traffic against a mutating graph.  The generator keeps a
mirror of the live edge set so deletions always target existing edges and
insertions never duplicate; ``dag_preserving=True`` orients every insertion
by a fixed topological order of the initial graph, guaranteeing the
condensation never cycles (the pure label-repair fast path);
``dag_preserving=False`` samples arbitrary pairs and exercises SCC
merge/split maintenance too.

The replayer drives a ``DynamicOracle`` through the trace, publishing an
epoch per update batch and timing both sides of the interleave: update
apply+publish throughput and query latency under churn.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.dynamic.delta import UpdateBatch
from repro.graph.csr import CSRGraph, topological_order


@dataclasses.dataclass(frozen=True)
class TraceOp:
    """One trace element: an update batch or a query batch."""
    kind: str  # "update" | "query"
    batch: Optional[UpdateBatch] = None
    queries: Optional[np.ndarray] = None


def poisson_times(rate_per_s: float, duration_s: float,
                  seed: int = 0) -> np.ndarray:
    """Open-loop arrival times: a Poisson process at ``rate_per_s`` over
    ``[0, duration_s)``, as a sorted float64 array of offsets in seconds.

    Open-loop means arrivals are INDEPENDENT of service completions — the
    workload keeps coming whether or not the server keeps up, which is the
    regime that exposes overload behavior (closed-loop drivers self-throttle
    and hide it).  The serving daemon's benchmark rows replay these."""
    rng = np.random.default_rng(seed)
    rate = max(float(rate_per_s), 1e-9)
    # draw in chunks: E[count] + 5 sigma covers the horizon w.h.p.
    est = int(rate * duration_s + 5 * np.sqrt(rate * duration_s) + 16)
    times = np.cumsum(rng.exponential(1.0 / rate, size=est))
    while times.size and times[-1] < duration_s:
        more = np.cumsum(rng.exponential(1.0 / rate, size=est)) + times[-1]
        times = np.concatenate([times, more])
    return times[times < duration_s]


def generate_trace(
    g: CSRGraph,
    rounds: int = 10,
    updates_per_round: int = 50,
    queries_per_round: int = 1000,
    insert_frac: float = 0.6,
    dag_preserving: bool = True,
    seed: int = 0,
) -> List[TraceOp]:
    """Alternating update/query trace over ``g`` (original vertex ids)."""
    rng = np.random.default_rng(seed)
    n = g.n
    # set for O(1) membership + parallel list (swap-pop) for O(1) sampling
    live = set()
    live_list: List[Tuple[int, int]] = []
    src, dst = g.edges()
    for a, b in zip(src.tolist(), dst.tolist()):
        if (a, b) not in live:
            live.add((a, b))
            live_list.append((a, b))
    if dag_preserving:
        topo = topological_order(g)
        pos = np.empty(n, dtype=np.int64)
        pos[topo] = np.arange(n)
    ops: List[TraceOp] = []
    for _ in range(rounds):
        inserts: List[Tuple[int, int]] = []
        deletes: List[Tuple[int, int]] = []
        for _ in range(updates_per_round):
            if rng.random() < insert_frac or not live:
                for _attempt in range(64):
                    a = int(rng.integers(0, n))
                    b = int(rng.integers(0, n))
                    if a == b:
                        continue
                    if dag_preserving:
                        if pos[a] == pos[b]:
                            continue
                        if pos[a] > pos[b]:
                            a, b = b, a
                    if (a, b) not in live:
                        live.add((a, b))
                        live_list.append((a, b))
                        inserts.append((a, b))
                        break
            else:
                k = int(rng.integers(0, len(live_list)))
                edge = live_list[k]
                live_list[k] = live_list[-1]
                live_list.pop()
                live.discard(edge)
                deletes.append(edge)
        ops.append(TraceOp("update", batch=UpdateBatch.of(inserts, deletes)))
        q = rng.integers(0, n, size=(queries_per_round, 2)).astype(np.int32)
        ops.append(TraceOp("query", queries=q))
    return ops


@dataclasses.dataclass
class ReplayStats:
    n_updates: int = 0
    n_queries: int = 0
    update_seconds: float = 0.0     # apply + publish
    query_seconds: float = 0.0
    query_latencies: List[float] = dataclasses.field(default_factory=list)
    repaired: int = 0
    rebuilds: int = 0
    structural: int = 0
    epochs: int = 0

    @property
    def updates_per_sec(self) -> float:
        return self.n_updates / self.update_seconds if self.update_seconds else 0.0

    def query_pctile(self, q: float) -> float:
        """Per-batch query latency percentile, seconds."""
        if not self.query_latencies:
            return 0.0
        return float(np.quantile(np.asarray(self.query_latencies), q))


def replay(dyn, trace: List[TraceOp], backend: Optional[str] = None,
           check_truth=None) -> ReplayStats:
    """Drive a DynamicOracle through a trace.

    ``check_truth(dyn, queries, answers)`` (optional) runs after every query
    batch — the hook the equivalence tests and the benchmark's
    rebuild-comparison use.
    """
    stats = ReplayStats()
    rebuilds0 = dyn.rebuild_count
    for op in trace:
        if op.kind == "update":
            t0 = time.perf_counter()
            st = dyn.apply(op.batch)
            dyn.publish()
            stats.update_seconds += time.perf_counter() - t0
            stats.n_updates += st.n_updates
            stats.repaired += st.repaired_inserts + st.repaired_deletes
            stats.structural += st.structural
            stats.epochs += 1
        else:
            t0 = time.perf_counter()
            ans = dyn.serve(op.queries, backend=backend)
            dt = time.perf_counter() - t0
            stats.query_seconds += dt
            stats.query_latencies.append(dt)
            stats.n_queries += op.queries.shape[0]
            if check_truth is not None:
                check_truth(dyn, op.queries, ans)
    stats.rebuilds = dyn.rebuild_count - rebuilds0
    return stats
