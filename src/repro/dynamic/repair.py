"""Incremental label repair under DAG edge updates (the §5.2 resume).

Labels here are the same 2-hop rows the static builder produces, held in a
mutable working form between published epochs.  Both repairs distribute hops
through ``repro.build.engine.cone_resume_sweep`` — the cone-scoped
resumption of Algorithm 2's pruned BFS — with the prune probe restricted to
ranks at least as high as the hop being distributed, so every verdict
matches what the sequential construction loop would have produced and the
repaired labels stay non-redundant (Theorem 4) up to covers created by
later updates.

Insert (u, v), DAG-preserving
    New reachable pairs all factor as x ->* u -> v ->* y.  The highest-
    ranked vertex on any such path sits either in the x ->* u half — then it
    is already (canonically) in L_in(u) — or in the v ->* y half — then in
    L_out(v).  So it suffices to resume, in rank order:
      * each hop h in L_in(u): h's FORWARD sweep, seeded at v (h now reaches
        v's cone through the new edge),
      * each hop h in L_out(v): h's REVERSE sweep, seeded at u.
    Seeding with existing labels as the prune set keeps the sweeps inside
    the affected cone: a vertex whose pair with h is already covered prunes
    immediately.

Delete (u, v), DAG edge removed
    Only pairs x in A = anc(u), y in B = desc(v) can change, and label
    entries change only in the (row in A, hop in B) / (row in B, hop in A)
    pattern: any x -> h walk through the deleted edge needs x ->* u and
    v ->* h.  The repair therefore
      1. invalidates exactly those entries (found by masking rows of A/B
         against the cone's rank set — the witness tally says which hops are
         referenced at all, so unreferenced cones skip the scan), then
      2. re-distributes the affected hops in rank order: hop h in B re-runs
         its reverse sweep from h itself, hop h in A its forward sweep,
         interleaved ascending by rank so every prune probe reads labels
         that are already final for all higher ranks (exactly the state the
         static loop would have seen).
    Everything outside the pattern is untouched — those entries are provably
    canonical-stable under the deletion.
"""
from __future__ import annotations

import bisect
from typing import Dict, List, Set

import numpy as np

from repro.build.engine import cone_resume_sweep


class MutableLabels:
    """Working (between-epochs) form of the oracle's label rows.

    Rank-space values in per-vertex sorted lists — the ragged
    ``_LabelStore`` layout without the dense matrix, because repairs touch a
    few rows at a time and publish copy-on-writes them back into the dense
    serving layout.  Tracks dirty rows for COW publish and a witness tally
    (per-hop reference counts) for the delete repair's invalidation scan and
    the repair-vs-rebuild cost signal.
    """

    def __init__(self, out_rows: List[List[int]], in_rows: List[List[int]]):
        self.n = len(out_rows)
        self.out_rows = out_rows
        self.in_rows = in_rows
        self.dirty_out: Set[int] = set()
        self.dirty_in: Set[int] = set()
        self.appends = 0
        self.drops = 0
        self._mark_appends = 0
        self._mark_drops = 0
        # witness tally: how many rows reference each hop rank
        self.tally_out = np.zeros(self.n, dtype=np.int64)
        self.tally_in = np.zeros(self.n, dtype=np.int64)
        for row in out_rows:
            for r in row:
                self.tally_out[r] += 1
        for row in in_rows:
            for r in row:
                self.tally_in[r] += 1

    @classmethod
    def from_oracle(cls, oracle) -> "MutableLabels":
        out_rows = [oracle.row_out(v).tolist() for v in range(oracle.n)]
        in_rows = [oracle.row_in(v).tolist() for v in range(oracle.n)]
        return cls(out_rows, in_rows)

    # ------------------------------------------------------------- reads

    def _rows(self, side: str) -> List[List[int]]:
        return self.out_rows if side == "out" else self.in_rows

    def label_ints(self) -> int:
        return sum(len(r) for r in self.out_rows) + sum(len(r) for r in self.in_rows)

    def prune(self, vertex: int, hop: int, hop_vertex: int, side: str,
              include_equal: bool) -> bool:
        """Algorithm 2's prune probe, rank-restricted.

        side="out" (distributing ``hop`` into L_out(vertex)): a cover g with
        vertex ->* g ->* hop_vertex lives in L_out(vertex) cap
        L_in(hop_vertex).  side="in" mirrors it.  Only covers ranked at
        least as high as ``hop`` count (g < hop; g == hop means "already
        present" and prunes only when ``include_equal``).
        """
        if side == "out":
            a, b = self.out_rows[vertex], self.in_rows[hop_vertex]
        else:
            a, b = self.in_rows[vertex], self.out_rows[hop_vertex]
        limit = hop + 1 if include_equal else hop
        i = j = 0
        na, nb = len(a), len(b)
        while i < na and j < nb:
            x, y = a[i], b[j]
            if x >= limit or y >= limit:
                return False
            if x == y:
                return True
            if x < y:
                i += 1
            else:
                j += 1
        return False

    def has(self, side: str, vertex: int, hop: int) -> bool:
        row = self._rows(side)[vertex]
        k = bisect.bisect_left(row, hop)
        return k < len(row) and row[k] == hop

    # ------------------------------------------------------------ writes

    def add(self, side: str, vertex: int, hop: int) -> int:
        """Idempotent sorted insert; returns 1 if a value was appended."""
        row = self._rows(side)[vertex]
        k = bisect.bisect_left(row, hop)
        if k < len(row) and row[k] == hop:
            return 0
        row.insert(k, hop)
        (self.dirty_out if side == "out" else self.dirty_in).add(vertex)
        (self.tally_out if side == "out" else self.tally_in)[hop] += 1
        self.appends += 1
        return 1

    def drop_in_set(self, side: str, vertex: int, ranks: Set[int]) -> int:
        """Invalidate every entry of ``vertex`` whose value is in ``ranks``."""
        row = self._rows(side)[vertex]
        kept = [r for r in row if r not in ranks]
        dropped = len(row) - len(kept)
        if dropped:
            tally = self.tally_out if side == "out" else self.tally_in
            for r in row:
                if r in ranks:
                    tally[r] -= 1
            self._rows(side)[vertex][:] = kept
            (self.dirty_out if side == "out" else self.dirty_in).add(vertex)
            self.drops += dropped
        return dropped

    def epoch_counters(self) -> tuple[int, int]:
        """(appends, drops) accumulated since the previous call — the
        per-epoch churn window ``versioned.DynamicOracle`` logs so label
        growth (rank drift under churn) is measurable per publish."""
        a = self.appends - self._mark_appends
        d = self.drops - self._mark_drops
        self._mark_appends, self._mark_drops = self.appends, self.drops
        return a, d

    def peek_dirty(self) -> tuple[Dict[int, List[int]], Dict[int, List[int]]]:
        """Dirty rows since the last publish, WITHOUT consuming them — the
        transactional publish stages from this and calls ``clear_dirty``
        only at its commit point, so a failed publish stays retryable."""
        out = {v: list(self.out_rows[v]) for v in self.dirty_out}
        inn = {v: list(self.in_rows[v]) for v in self.dirty_in}
        return out, inn

    def clear_dirty(self) -> None:
        self.dirty_out = set()
        self.dirty_in = set()

    def take_dirty(self) -> tuple[Dict[int, List[int]], Dict[int, List[int]]]:
        """Dirty rows since the last publish (and reset the dirty sets)."""
        out, inn = self.peek_dirty()
        self.clear_dirty()
        return out, inn


def repair_insert(labels: MutableLabels, delta, inv_rank: np.ndarray,
                  cu: int, cv: int) -> int:
    """Repair labels after DAG edge (cu, cv) was inserted (no cycle).

    Resumes, in rank order (highest first), the forward sweep of every hop
    in L_in(cu) from seed cv and the reverse sweep of every hop in
    L_out(cv) from seed cu.  Self-entries make cu and cv themselves part of
    the resumed set.  Returns the number of label appends.
    """
    resumes = [(h, "in") for h in labels.in_rows[cu]]
    resumes += [(h, "out") for h in labels.out_rows[cv]]
    resumes.sort()
    fwd = delta.dag_out
    rev = delta.dag_in
    appended = 0
    for h, side in resumes:
        hv = int(inv_rank[h])
        if side == "in":
            # hop reaches cu, now reaches cv's cone: forward sweep from cv
            appended += cone_resume_sweep(
                lambda w: fwd[w], labels, h, hv, cv, "in", stop_at_present=True
            )
        else:
            # cv reaches hop, cu's cone now reaches it: reverse sweep from cu
            appended += cone_resume_sweep(
                lambda w: rev[w], labels, h, hv, cu, "out", stop_at_present=True
            )
    return appended


def repair_delete(labels: MutableLabels, delta, rank: np.ndarray,
                  inv_rank: np.ndarray, cu: int, cv: int,
                  max_cone: int) -> bool:
    """Repair labels after DAG edge (cu, cv) was deleted.

    Returns False when the affected cone exceeds ``max_cone`` vertices — the
    caller should fall back to a compacting rebuild (the repair-vs-rebuild
    crossover the staleness budget tracks).
    """
    A = delta._cone(cu, delta.dag_in)    # ancestors of u (reflexive)
    B = delta._cone(cv, delta.dag_out)   # descendants of v (reflexive)
    if len(A) + len(B) > max_cone:
        return False
    rank_A = {int(rank[x]) for x in A}
    rank_B = {int(rank[x]) for x in B}
    # 1. invalidate the (row in A, hop in B) / (row in B, hop in A) pattern.
    #    The witness tally bounds the scan: cones whose ranks are referenced
    #    nowhere can skip their rows entirely.
    if any(labels.tally_out[r] for r in rank_B):
        for x in A:
            labels.drop_in_set("out", x, rank_B)
    if any(labels.tally_in[r] for r in rank_A):
        for y in B:
            labels.drop_in_set("in", y, rank_A)
    # 2. re-distribute affected hops, both sides interleaved in rank order
    #    so every prune probe reads final labels for all higher ranks
    redo = sorted([(r, "out") for r in rank_B] + [(r, "in") for r in rank_A])
    fwd = delta.dag_out
    rev = delta.dag_in
    for h, side in redo:
        hv = int(inv_rank[h])
        if side == "out":
            # hop in B: its reverse sweep re-runs from the hop itself
            cone_resume_sweep(
                lambda w: rev[w], labels, h, hv, hv, "out", stop_at_present=False
            )
        else:
            cone_resume_sweep(
                lambda w: fwd[w], labels, h, hv, hv, "in", stop_at_present=False
            )
    return True
