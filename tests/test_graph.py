"""Graph substrate unit tests."""
import numpy as np
import pytest

from repro.graph.csr import (
    CSRGraph,
    from_edges,
    is_dag,
    topo_levels,
    topological_order,
)
from repro.graph.generators import (
    chain_dag,
    layered_dag,
    paper_dataset_analogue,
    random_dag,
    scale_free_dag,
    tree_dag,
)
from repro.graph.reach import (
    bfs_levels,
    reachable_set,
    reaches_bit,
    sample_query_workload,
    transitive_closure_bits,
)
from repro.graph.scc import condense_to_dag, tarjan_scc


def test_csr_roundtrip():
    g = from_edges(5, [0, 0, 1, 3], [1, 2, 2, 4])
    assert g.n == 5 and g.m == 4
    assert list(g.out_neighbors(0)) == [1, 2]
    src, dst = g.edges()
    g2 = from_edges(5, src, dst)
    assert (g2.indptr == g.indptr).all() and (g2.indices == g.indices).all()


def test_reverse_degrees():
    g = random_dag(100, 300, seed=1)
    r = g.reverse()
    assert (g.in_degree() == r.out_degree()).all()
    assert g.m == r.m
    # double reverse == identity (as edge set)
    rr = r.reverse()
    s1 = set(zip(*g.edges()))
    s2 = set(zip(*rr.edges()))
    assert s1 == s2


def test_generators_are_dags():
    for g in [
        random_dag(200, 600, seed=0),
        layered_dag(200, 2.5, seed=1),
        tree_dag(200, 4, seed=2),
        scale_free_dag(200, 3.0, seed=3),
        chain_dag(200, 4, seed=4),
        paper_dataset_analogue("amaze", scale=0.5),
    ]:
        assert is_dag(g)
        topo = topological_order(g)
        pos = np.empty(g.n, dtype=np.int64)
        pos[topo] = np.arange(g.n)
        src, dst = g.edges()
        assert (pos[src] < pos[dst]).all()


def test_topo_levels_longest_path():
    """Vectorized topo levels == the scalar longest-path relaxation, and
    every edge strictly increases the level (the serve-filter invariant)."""
    for g in (random_dag(200, 600, seed=1), tree_dag(150, branching=3, seed=2),
              chain_dag(120, seed=3)):
        level = topo_levels(g)
        expect = np.zeros(g.n, dtype=np.int32)
        for v in topological_order(g):
            for w in g.out_neighbors(v):
                expect[w] = max(expect[w], expect[v] + 1)
        assert np.array_equal(level, expect)
        src = np.repeat(np.arange(g.n), np.diff(g.indptr))
        assert (level[src] < level[g.indices]).all()
    with pytest.raises(ValueError):
        topo_levels(from_edges(3, [0, 1, 2], [1, 2, 0], dedup=False))


def test_scc_condensation():
    # two 3-cycles connected by an edge + isolated vertex
    src = [0, 1, 2, 3, 4, 5, 2]
    dst = [1, 2, 0, 4, 5, 3, 3]
    g = from_edges(7, src, dst)
    dag, comp = condense_to_dag(g)
    assert dag.n == 3  # {0,1,2}, {3,4,5}, {6}
    assert is_dag(dag)
    assert comp[0] == comp[1] == comp[2]
    assert comp[3] == comp[4] == comp[5]
    assert comp[0] != comp[3] != comp[6]


def test_tc_bits_vs_dfs():
    g = random_dag(150, 400, seed=2)
    tc = transitive_closure_bits(g)
    rng = np.random.default_rng(0)
    for u in rng.integers(0, g.n, 12):
        rs = reachable_set(g, int(u))
        for v in rng.integers(0, g.n, 25):
            assert reaches_bit(tc, int(u), int(v)) == bool(rs[v])


def test_bfs_levels_monotone():
    g = layered_dag(120, 2.0, seed=3)
    lv = bfs_levels(g, 0)
    src, dst = g.edges()
    for s, d in zip(src, dst):
        if lv[s] >= 0 and lv[d] >= 0:
            assert lv[d] <= lv[s] + 1


def test_query_workload_balance():
    g = random_dag(150, 500, seed=4)
    rng = np.random.default_rng(1)
    q, truth = sample_query_workload(g, 200, rng, equal=True)
    assert q.shape == (200, 2)
    assert 0.3 <= truth.mean() <= 0.7
