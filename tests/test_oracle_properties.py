"""Property-based tests of the paper's core invariants (hypothesis).

  * Completeness (Theorems 1 & 3): for any DAG, u->v iff
    L_out(u) cap L_in(v) != empty — for BOTH labeling algorithms.
  * Non-redundancy of Distribution-Labeling (Theorem 4): removing any single
    hop from any label breaks completeness.
  * Host DL == device DL (the distributed formulation is exact).
  * Label size sanity: DL <= HL on average (the paper's empirical finding).
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.distribution import distribution_labeling
from repro.core.distribution_jax import distribution_labeling_jax
from repro.core.hierarchy import hierarchical_labeling
from repro.core.oracle import ReachabilityOracle
from repro.graph.csr import from_edges, is_dag
from repro.graph.generators import layered_dag, random_dag, tree_dag
from repro.graph.reach import reaches_bit, transitive_closure_bits


@st.composite
def small_dags(draw):
    n = draw(st.integers(8, 40))
    m = draw(st.integers(n // 2, 3 * n))
    seed = draw(st.integers(0, 10_000))
    return random_dag(n, m, seed=seed)


def _assert_complete(g, oracle: ReachabilityOracle, name: str):
    tc = transitive_closure_bits(g)
    for u in range(g.n):
        for v in range(g.n):
            if u == v:
                continue
            truth = reaches_bit(tc, u, v)
            pred = oracle.query(u, v)
            assert truth == pred, f"{name}: {u}->{v} truth={truth} pred={pred}"


@settings(max_examples=30, deadline=None)
@given(small_dags())
def test_distribution_labeling_complete(g):
    _assert_complete(g, distribution_labeling(g), "DL")


@settings(max_examples=15, deadline=None)
@given(small_dags())
def test_hierarchical_labeling_complete(g):
    _assert_complete(g, hierarchical_labeling(g, core_max=8), "HL")


@settings(max_examples=10, deadline=None)
@given(small_dags())
def test_device_dl_matches_host(g):
    host = distribution_labeling(g)
    dev = distribution_labeling_jax(g, l_max=max(int(host.max_label_len), 8))
    for v in range(g.n):
        for h_mat, d_mat in ((host.L_out, dev.L_out), (host.L_in, dev.L_in)):
            # host labels live in rank space; map back to vertex ids
            a = set(host.unrank(h_mat[v][h_mat[v] != -1]).tolist())
            b = set(d_mat[v][d_mat[v] != -1].tolist())
            assert a == b, (v, a, b)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 1000))
def test_dl_non_redundancy(seed):
    """Theorem 4: every hop in every DL label is load-bearing."""
    g = random_dag(18, 36, seed=seed)
    oracle = distribution_labeling(g)
    tc = transitive_closure_bits(g)

    def complete_without(mat_name: str, vertex: int, drop: int) -> bool:
        L_out = oracle.L_out.copy()
        L_in = oracle.L_in.copy()
        mat = L_out if mat_name == "out" else L_in
        row = mat[vertex]
        row[row == drop] = -1
        o2 = ReachabilityOracle(L_out, L_in, oracle.out_len, oracle.in_len)
        # Theorem 4's Cov includes the reflexive pairs: a self-hop's load
        # may be exactly query(v, v) (answered by label intersection, not a
        # shortcut), so u == v is part of completeness here.
        for u in range(g.n):
            for v in range(g.n):
                truth = True if u == v else reaches_bit(tc, u, v)
                if truth != o2.query(u, v):
                    return False
        return True

    for v in range(g.n):
        for hop in oracle.L_out[v][oracle.L_out[v] != -1]:
            assert not complete_without("out", v, int(hop)), (
                f"hop {hop} in L_out({v}) is redundant"
            )
        for hop in oracle.L_in[v][oracle.L_in[v] != -1]:
            assert not complete_without("in", v, int(hop)), (
                f"hop {hop} in L_in({v}) is redundant"
            )


def test_dl_label_size_beats_hl_on_families():
    """Paper finding (Figures 3/4): DL labels are smaller than HL labels."""
    wins = 0
    total = 0
    for gen, kw in [
        (random_dag, dict(n=150, m=400)),
        (layered_dag, dict(n=150, avg_out=2.0)),
        (tree_dag, dict(n=200, branching=5)),
    ]:
        for seed in range(3):
            g = gen(seed=seed, **kw)
            dl = distribution_labeling(g).total_label_size
            hl = hierarchical_labeling(g, core_max=16).total_label_size
            wins += dl <= hl
            total += 1
    assert wins >= total - 1, f"DL larger than HL on {total - wins}/{total} graphs"


def test_query_self_reach():
    g = random_dag(30, 60, seed=5)
    o = distribution_labeling(g)
    for v in range(g.n):
        assert o.query(v, v)
