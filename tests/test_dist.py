"""Distribution layer: pipeline parallelism, sharded serve, small-mesh
dry-run — all in subprocesses that set the fake-device XLA flag (the main
test process must keep the real 1-CPU topology)."""
import subprocess
import sys

import pytest

PIPELINE_SNIPPET = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
import jax, jax.numpy as jnp
from repro.dist.pipeline import pipeline_apply
mesh = jax.make_mesh((4,), ('stage',))
W = jax.random.normal(jax.random.PRNGKey(0), (4, 2, 16, 16)) * 0.3
def block(p, x):
    for i in range(2):
        x = jnp.tanh(x @ p[i])
    return x
x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, 16))
out = pipeline_apply({'w': W}, x, lambda p, x: block(p['w'], x), mesh)
ref = x
for s in range(4):
    for i in range(2):
        ref = jnp.tanh(ref @ W[s, i])
assert float(jnp.abs(out - ref).max()) < 1e-5
print('PIPELINE_OK')
"""

SHARDED_SERVE_SNIPPET = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import jax, jax.numpy as jnp, numpy as np
from repro.core.distribution import distribution_labeling
from repro.serve.engine import make_sharded_serve_step, make_hop_sharded_serve_step
from repro.graph.generators import random_dag
from repro.graph.reach import transitive_closure_bits, sample_query_workload
mesh = jax.make_mesh((4, 2), ('data', 'model'))
g = random_dag(256, 700, seed=0)
o = distribution_labeling(g)
tc = transitive_closure_bits(g)
rng = np.random.default_rng(0)
q, truth = sample_query_workload(g, 64, rng, equal=True, tc=tc)
lo, li = o.device_labels()
# pad label width to a model-axis multiple for the hop-sharded path
pad = (-lo.shape[1]) % 2
lo = jnp.pad(lo, ((0,0),(0,pad)), constant_values=-1)
li = jnp.pad(li, ((0,0),(0,pad)), constant_values=-1)
fn, _, _ = make_sharded_serve_step(mesh, data_axes=('data',))
pred = np.asarray(fn(lo, li, jnp.asarray(q)))
assert (pred == truth).all(), 'replicated-label serve mismatch'
fn2, _, _ = make_hop_sharded_serve_step(mesh, data_axes=('data',))
pred2 = np.asarray(fn2(lo, li, jnp.asarray(q)))
assert (pred2 == truth).all(), 'hop-sharded serve mismatch'
print('SERVE_OK')
"""

SMALL_DRYRUN_SNIPPET = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import jax
from jax.sharding import Mesh
import numpy as np
mesh = jax.make_mesh((4, 2), ('data', 'model'))
from repro.configs import get_arch
# exercise the cell machinery end-to-end on a small mesh: lower + compile
cell = get_arch('gcn-cora').cells('full_graph_sm', mesh)
with mesh:
    compiled = cell.lower().compile()
    assert compiled.cost_analysis() is not None
print('DRYRUN_OK')
"""


def _run(snippet: str, marker: str):
    proc = subprocess.run(
        [sys.executable, "-c", snippet],
        # generous: these spawn full XLA compiles and share the host with
        # other jobs — 420s flakes when the machine is loaded
        capture_output=True, text=True, timeout=1200,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        cwd="/root/repo",
    )
    assert marker in proc.stdout, f"stdout={proc.stdout}\nstderr={proc.stderr[-2000:]}"


DSTLOCAL_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.graph.generators import random_dag
from repro.graph.partition import partition_edges_by_dst
from repro.models.gnn import gatedgcn
from repro.models.gnn.layers import GraphBatch
mesh = jax.make_mesh((4,), ("data",))
rng = np.random.default_rng(0)
n = 64
g = random_dag(n, 200, seed=1)
src, dst, mask, width = partition_edges_by_dst(g, 4, n_pad=n)
cfg = gatedgcn.GatedGCNConfig(n_layers=3, d_in=8, d_edge_in=4, d_hidden=16, n_classes=4)
params = gatedgcn.init_params(cfg, jax.random.PRNGKey(0))
m = src.shape[0]
batch = GraphBatch(
    x=jnp.asarray(rng.standard_normal((n, 8)).astype(np.float32)),
    edge_src=jnp.asarray(src), edge_dst=jnp.asarray(dst),
    edge_mask=jnp.asarray(mask), node_mask=jnp.ones(n, bool),
    edge_attr=jnp.asarray(rng.standard_normal((m, 4)).astype(np.float32)),
    y=jnp.asarray(rng.integers(0, 4, n).astype(np.int32)),
)
base = gatedgcn.loss_fn(cfg, params, batch)
dl = gatedgcn.make_dstlocal_loss(cfg, mesh, ("data",))
opt = dl(params, batch)
# dstlocal exchanges the node stream in bf16 (H8) -> bf16-level tolerance
assert abs(float(base) - float(opt)) < 5e-3
gb = jax.grad(lambda p: gatedgcn.loss_fn(cfg, p, batch))(params)
go = jax.grad(lambda p: dl(p, batch))(params)
gerr = max(float(jnp.abs(a - b).max()) for a, b in zip(jax.tree.leaves(gb), jax.tree.leaves(go)))
assert gerr < 2e-2, gerr
print("DSTLOCAL_OK")
"""


def test_pipeline_parallel_matches_sequential():
    _run(PIPELINE_SNIPPET, "PIPELINE_OK")


def test_dstlocal_message_passing_matches_baseline():
    _run(DSTLOCAL_SNIPPET, "DSTLOCAL_OK")


def test_sharded_serve_correct():
    _run(SHARDED_SERVE_SNIPPET, "SERVE_OK")


def test_small_mesh_dryrun_cell():
    _run(SMALL_DRYRUN_SNIPPET, "DRYRUN_OK")
