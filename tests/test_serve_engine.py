"""The serve subsystem: cross-backend agreement, planner, prefilters, ranks.

The headline property: every QueryEngine backend returns bit-identical
answers to BFS ground truth — on random DAGs, cyclic digraphs (same-SCC
pairs included), and graphs with isolated vertices.
"""
import numpy as np
import pytest

from repro.core.api import build_oracle
from repro.core.distribution import distribution_labeling
from repro.graph.csr import from_edges
from repro.graph.generators import layered_dag, random_dag, tree_dag
from repro.serve.engine import BACKENDS, QueryEngine, select_backend
from repro.serve.planner import plan_batch, tier_widths
from repro.serve.prefilter import apply_prefilters, topo_levels

HOST_BACKENDS = ("host", "dense", "kernel")


def _truth_matrix(n, src, dst):
    """bool[n, n] reachability (reflexive) by BFS from each vertex."""
    adj = [[] for _ in range(n)]
    for s, d in zip(src, dst):
        adj[int(s)].append(int(d))
    out = np.zeros((n, n), dtype=bool)
    for u in range(n):
        seen = {u}
        stack = [u]
        while stack:
            x = stack.pop()
            for w in adj[x]:
                if w not in seen:
                    seen.add(w)
                    stack.append(w)
        out[u, list(seen)] = True
    return out


def _graph_families(rng):
    """(name, graph) pairs spanning DAGs, cycles, and isolated vertices."""
    fams = []
    fams.append(("random_dag", random_dag(70, 200, seed=1)))
    fams.append(("layered_dag", layered_dag(80, avg_out=2.5, seed=2)))
    fams.append(("tree_dag", tree_dag(90, branching=4, seed=3)))
    # cyclic digraph: uniform random edges leave plenty of nontrivial SCCs
    n = 60
    src, dst = rng.integers(0, n, 170), rng.integers(0, n, 170)
    fams.append(("cyclic", from_edges(n, src, dst)))
    # sparse cyclic graph with isolated vertices (edges only touch the
    # first half of the id space)
    n = 80
    src, dst = rng.integers(0, n // 2, 60), rng.integers(0, n // 2, 60)
    fams.append(("isolated", from_edges(n, src, dst)))
    return fams


def test_cross_backend_agreement_with_bfs_truth(rng):
    """All engine backends == BFS ground truth, >= 10k queries, >= 3 families."""
    total = 0
    for name, g in _graph_families(rng):
        truth = _truth_matrix(g.n, *g.edges())
        oracle = build_oracle(g)
        # uniform pairs + forced diagonal/same-SCC pairs + corner ids
        q = rng.integers(0, g.n, size=(2200, 2)).astype(np.int32)
        diag = np.arange(g.n, dtype=np.int32)
        q = np.concatenate([q, np.stack([diag, diag], 1),
                            np.array([[0, g.n - 1], [g.n - 1, 0]], np.int32)])
        exp = truth[q[:, 0], q[:, 1]]
        for be in HOST_BACKENDS:
            pred = oracle.serve(q, backend=be)
            assert (pred == exp).all(), (name, be, int((pred != exp).sum()))
        total += q.shape[0]
    assert total >= 10_000


def test_hierarchical_method_cross_backend(rng):
    """HL-built oracles serve correctly too (the HL core inherits DL labels,
    which live in rank space — this guards the unrank at the seam)."""
    g = random_dag(150, 500, seed=0)
    truth = _truth_matrix(g.n, *g.edges())
    o = build_oracle(g, method="hierarchical", core_max=16)
    q = rng.integers(0, g.n, size=(4000, 2)).astype(np.int32)
    exp = truth[q[:, 0], q[:, 1]]
    for be in HOST_BACKENDS:
        pred = o.serve(q, backend=be)
        assert (pred == exp).all(), (be, int((pred != exp).sum()))


def test_engine_point_queries_match_batch(rng):
    g = random_dag(50, 140, seed=7)
    truth = _truth_matrix(g.n, *g.edges())
    o = build_oracle(g)
    for u in range(g.n):
        for v in range(g.n):
            assert o.query(u, v) == truth[u, v], (u, v)


def test_bucketing_matches_unbucketed(rng):
    g = layered_dag(150, avg_out=3.0, seed=11)
    o_b = build_oracle(g, bucketing=True)
    o_n = build_oracle(g, bucketing=False)
    q = rng.integers(0, g.n, size=(4000, 2)).astype(np.int32)
    for be in ("dense", "kernel"):
        a = o_b.serve(q, backend=be)
        b = o_n.serve(q, backend=be)
        assert (a == b).all(), be
    # bucketing actually engaged (at least one tier ran under the full width)
    assert o_b.engine.last_stats["tiers"], "no tiers ran"


def test_backend_selection():
    assert select_backend(None) in BACKENDS
    assert select_backend("auto") in ("dense", "kernel")
    assert select_backend("host") == "host"
    with pytest.raises(ValueError):
        select_backend("nope")
    with pytest.raises(ValueError):
        select_backend("sharded")  # no mesh


def test_planner_partitions_and_covers(rng):
    out_len = rng.integers(0, 40, 500).astype(np.int32)
    in_len = rng.integers(0, 40, 500).astype(np.int32)
    widths = tier_widths(out_len, in_len, 40)
    assert widths == sorted(widths) and widths[-1] >= 40
    q = rng.integers(0, 500, size=(3000, 2)).astype(np.int32)
    plan = plan_batch(q, out_len, in_len, widths)
    idx_all = np.concatenate([t.idx for t in plan.tiers])
    # exact partition of the batch
    assert np.array_equal(np.sort(idx_all), np.arange(3000))
    for t in plan.tiers:
        need = np.maximum(out_len[q[t.idx, 0]], in_len[q[t.idx, 1]])
        assert (need <= t.width).all()
        assert t.rows >= t.idx.size and (t.rows & (t.rows - 1)) == 0  # pow2 tile


def test_prefilters_sound(rng):
    g = random_dag(60, 150, seed=5)
    truth = _truth_matrix(g.n, *g.edges())
    o = distribution_labeling(g)
    level = topo_levels(g)
    q = rng.integers(0, g.n, size=(5000, 2)).astype(np.int32)
    pf = apply_prefilters(q, o.out_len, o.in_len, level)
    exp = truth[q[:, 0], q[:, 1]]
    # every decided answer is correct (soundness — never a wrong short-circuit)
    assert (pf.value[pf.decided] == exp[pf.decided]).all()
    # and the filters actually fire on a random workload
    assert pf.decided.sum() > 0


def test_rank_ordered_labels(rng):
    g = layered_dag(120, avg_out=2.5, seed=9)
    o = distribution_labeling(g)
    assert o.hop_rank is not None
    # rows are ascending in rank space (value-sorted == rank-sorted)
    for mat, lens in ((o.L_out, o.out_len), (o.L_in, o.in_len)):
        for v in range(g.n):
            row = mat[v, : lens[v]]
            assert (np.diff(row) > 0).all(), v
    # unrank round-trips to real vertex ids
    row = o.L_out[0, : o.out_len[0]]
    verts = o.unrank(row)
    assert ((verts >= 0) & (verts < g.n)).all()
    assert set(o.hop_rank[verts].tolist()) == set(row.tolist())


def test_sharded_backend_agreement():
    """Replicated + hop-sharded serving agree with truth on a multi-device
    host mesh (subprocess — the main process must keep 1 CPU device)."""
    import os
    import subprocess
    import sys

    snippet = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import jax, numpy as np
from repro.core.distribution import distribution_labeling
from repro.graph.generators import random_dag
from repro.graph.reach import transitive_closure_bits, sample_query_workload
from repro.serve.engine import QueryEngine
mesh = jax.make_mesh((4, 2), ('data', 'model'))
g = random_dag(200, 520, seed=0)
o = distribution_labeling(g)
tc = transitive_closure_bits(g)
rng = np.random.default_rng(0)
q, truth = sample_query_workload(g, 100, rng, equal=True, tc=tc)
eng = QueryEngine(o, mesh=mesh, data_axes=('data',))
for be in ('sharded', 'sharded_hop'):
    pred = eng.query_batch(np.asarray(q), backend=be)
    assert (pred == truth).all(), be
print('SHARDED_ENGINE_OK')
"""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    # inherit the environment (JAX_PLATFORMS etc.) — a stripped env can send
    # the child probing for TPUs on CPU-only hosts
    env = {**os.environ, "PYTHONPATH": os.path.join(repo, "src")}
    proc = subprocess.run(
        [sys.executable, "-c", snippet],
        capture_output=True, text=True, timeout=1200, env=env, cwd=repo,
    )
    assert "SHARDED_ENGINE_OK" in proc.stdout, proc.stderr[-2000:]
