"""The dynamic subsystem: repair == rebuild, SCC maintenance, epochs.

Headline property (the acceptance bar): after ANY interleaving of random
edge inserts/deletes applied through the incremental path (condensation
maintenance + label repair + versioned publish), every (u, v) query through
the engine matches a from-scratch rebuild of the mutated graph — across the
same five graph families the serve tests use.
"""
import numpy as np
import pytest

from repro.core.api import build_oracle
from repro.dynamic import (
    CondensationState,
    DynamicOracle,
    MutableLabels,
    UpdateBatch,
    generate_trace,
    replay,
)
from repro.graph.csr import from_edges
from repro.graph.generators import layered_dag, random_dag, tree_dag

HOST_BACKENDS = ("host", "dense", "kernel")


def _graph_families(rng):
    """The five serve-test families: DAGs, cycles, isolated vertices."""
    fams = []
    fams.append(("random_dag", random_dag(70, 200, seed=1)))
    fams.append(("layered_dag", layered_dag(80, avg_out=2.5, seed=2)))
    fams.append(("tree_dag", tree_dag(90, branching=4, seed=3)))
    n = 60
    src, dst = rng.integers(0, n, 170), rng.integers(0, n, 170)
    fams.append(("cyclic", from_edges(n, src, dst)))
    n = 80
    src, dst = rng.integers(0, n // 2, 60), rng.integers(0, n // 2, 60)
    fams.append(("isolated", from_edges(n, src, dst)))
    return fams


def _truth_matrix(n, adj):
    out = np.zeros((n, n), dtype=bool)
    for u in range(n):
        seen = {u}
        stack = [u]
        while stack:
            x = stack.pop()
            for w in adj[x]:
                if w not in seen:
                    seen.add(w)
                    stack.append(w)
        out[u, list(seen)] = True
    return out


def _mirror(g):
    return [set(map(int, g.out_neighbors(v))) for v in range(g.n)]


def _random_interleaving(g, adj, rng, n_updates, insert_frac=0.55):
    """Mutate the adjacency mirror; return (inserts, deletes) applied."""
    ins, dels = [], []
    n = g.n
    for _ in range(n_updates):
        if rng.random() < insert_frac:
            a, b = int(rng.integers(0, n)), int(rng.integers(0, n))
            if a != b and b not in adj[a]:
                ins.append((a, b))
                adj[a].add(b)
        else:
            cands = [(u, w) for u in range(n) for w in adj[u]]
            if cands:
                e = cands[int(rng.integers(0, len(cands)))]
                dels.append(e)
                adj[e[0]].discard(e[1])
    return ins, dels


# ---------------------------------------------------------------------------
# the acceptance property, deterministic: all five families, every backend
# ---------------------------------------------------------------------------


def test_dynamic_matches_rebuild_all_families(rng):
    """<=50 random inserts/deletes per family; answers == fresh rebuild
    (checked against BFS truth AND a from-scratch build_oracle) for every
    host backend."""
    for name, g in _graph_families(rng):
        dyn = DynamicOracle(g)
        adj = _mirror(g)
        for batch_no in range(5):
            ins, dels = _random_interleaving(g, adj, rng, 10)
            dyn.apply(UpdateBatch.of(ins, dels))
            dyn.publish()
        truth = _truth_matrix(g.n, adj)
        # fresh rebuild of the mutated graph for exact-agreement comparison
        src = [u for u in range(g.n) for _ in adj[u]]
        dst = [w for u in range(g.n) for w in adj[u]]
        fresh = build_oracle(from_edges(g.n, src, dst))
        q = rng.integers(0, g.n, size=(1500, 2)).astype(np.int32)
        diag = np.arange(g.n, dtype=np.int32)
        q = np.concatenate([q, np.stack([diag, diag], 1)])
        exp = truth[q[:, 0], q[:, 1]]
        assert (fresh.serve(q) == exp).all(), name  # sanity on the reference
        for be in HOST_BACKENDS:
            pred = dyn.serve(q, backend=be)
            assert (pred == exp).all(), (name, be, int((pred != exp).sum()))


def test_repair_path_actually_engages():
    """On a DAG-preserving workload the updates go through label repair,
    not the rebuild fallback (the fast path the benchmark measures)."""
    g = layered_dag(400, avg_out=2.0, seed=5)
    # generous budgets: this test pins the routing, not the crossover
    dyn = DynamicOracle(g, staleness_budget=100.0, max_cone_frac=1.0)
    trace = generate_trace(g, rounds=3, updates_per_round=20,
                           queries_per_round=50, dag_preserving=True, seed=7)
    stats = replay(dyn, trace, backend="host")
    assert stats.repaired > 0
    assert stats.rebuilds == 0
    assert stats.structural == 0
    assert stats.epochs == 3


# ---------------------------------------------------------------------------
# hypothesis: random interleavings on random graphs (skipped when the
# container lacks hypothesis — the deterministic test above keeps coverage)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:

    @st.composite
    def interleavings(draw):
        fam = draw(st.integers(0, 4))
        seed = draw(st.integers(0, 10_000))
        n_updates = draw(st.integers(1, 50))
        n_batches = draw(st.integers(1, 4))
        return fam, seed, n_updates, n_batches

    @given(interleavings())
    @settings(max_examples=20, deadline=None)
    def test_dynamic_equivalence_property(spec):
        """Any interleaving of <=50 random inserts/deletes + repairs answers
        identically to a fresh build_labels rebuild of the mutated graph."""
        fam, seed, n_updates, n_batches = spec
        rng = np.random.default_rng(seed)
        name, g = _graph_families(rng)[fam]
        dyn = DynamicOracle(g)
        adj = _mirror(g)
        per_batch = max(1, n_updates // n_batches)
        for _ in range(n_batches):
            ins, dels = _random_interleaving(g, adj, rng, per_batch)
            dyn.apply(UpdateBatch.of(ins, dels))
            dyn.publish()
        truth = _truth_matrix(g.n, adj)
        q = rng.integers(0, g.n, size=(800, 2)).astype(np.int32)
        exp = truth[q[:, 0], q[:, 1]]
        pred = dyn.serve(q, backend="host")
        assert (pred == exp).all(), (name, int((pred != exp).sum()))


# ---------------------------------------------------------------------------
# condensation maintenance units
# ---------------------------------------------------------------------------


def test_scc_merge_collapses_in_place():
    # 0 -> 1 -> 2 -> 3; inserting 3 -> 0 rolls the whole chain into one SCC
    g = from_edges(4, [0, 1, 2], [1, 2, 3])
    cs = CondensationState(g)
    assert cs.n_live == 4
    ev = cs.insert(3, 0)
    assert ev.kind == "merge" and ev.structural
    assert cs.n_live == 1
    rep = int(cs.comp[0])
    assert all(int(cs.comp[v]) == rep for v in range(4))
    assert cs.dag_m() == 0  # no condensation edges left

    # the dynamic oracle serves it correctly after the structural rebuild
    dyn = DynamicOracle(g)
    assert not dyn.query(3, 0)
    dyn.apply(UpdateBatch.of(inserts=[(3, 0)]))
    dyn.publish()
    for u in range(4):
        for v in range(4):
            assert dyn.query(u, v), (u, v)


def test_scc_split_is_scoped():
    # two 2-cycles joined into one 4-cycle; deleting one closing edge splits
    g = from_edges(4, [0, 1, 1, 2, 3, 0], [1, 0, 2, 3, 2, 3])
    # edges: 0<->1, 2<->3 (via 2->3, 3->2), 1->2, 0->3 -- plus 3->... build:
    cs = CondensationState(g)
    dyn = DynamicOracle(g)
    # make one big SCC first
    ev = cs.insert(2, 0)
    dyn.apply(UpdateBatch.of(inserts=[(2, 0)]))
    dyn.publish()
    assert cs.n_live == 1
    assert dyn.query(3, 1)
    # deleting the back edge splits the SCC again (scoped re-check)
    ev = cs.delete(2, 0)
    assert ev.kind == "split" and ev.structural
    assert cs.n_live >= 2
    dyn.apply(UpdateBatch.of(deletes=[(2, 0)]))
    dyn.publish()
    assert dyn.query(1, 2) and not dyn.query(2, 0)


def test_dag_edge_multiplicity():
    # two original edges can back one condensation edge: deleting one of
    # them must NOT remove the DAG edge.  SCC {0,1} with edges 0->2, 1->2.
    g2 = from_edges(4, [0, 1, 0, 1], [1, 0, 2, 2])
    cs = CondensationState(g2)
    c01, c2 = int(cs.comp[0]), int(cs.comp[2])
    assert int(cs.comp[1]) == c01
    assert cs.edge_mult[(c01, c2)] == 2
    ev = cs.delete(0, 2)
    assert ev.kind == "noop"  # 1->2 still backs the condensation edge
    ev = cs.delete(1, 2)
    assert ev.kind == "dag_delete"
    assert (c01, c2) not in cs.edge_mult


# ---------------------------------------------------------------------------
# versioning / serve integration
# ---------------------------------------------------------------------------


def test_epoch_pinning_and_retention():
    g = from_edges(5, [0, 1], [1, 2])
    dyn = DynamicOracle(g, keep_epochs=3)
    e0 = dyn.epoch
    assert dyn.query(0, 2) and not dyn.query(0, 3)
    dyn.apply(UpdateBatch.of(inserts=[(2, 3)]))
    e1 = dyn.publish()
    assert dyn.query(0, 3)
    assert not dyn.query(0, 3, epoch=e0)  # pinned snapshot is immutable
    dyn.apply(UpdateBatch.of(deletes=[(0, 1)]))
    e2 = dyn.publish()
    assert not dyn.query(0, 3)
    assert dyn.query(0, 3, epoch=e1)
    # retention: keep_epochs=3 keeps {e0, e1, e2}; one more evicts e0
    dyn.apply(UpdateBatch.of(inserts=[(3, 4)]))
    dyn.publish()
    with pytest.raises(KeyError):
        dyn.snapshot(e0)
    # batched pinned serve agrees with point queries
    q = np.array([[0, 3], [2, 3], [0, 2]], dtype=np.int32)
    pinned = dyn.serve(q, epoch=e2)  # (0,1) deleted at e2: 0 no longer reaches
    assert pinned.tolist() == [False, True, False]


def test_pinned_epoch_device_path_matches_host_merge(rng):
    """Pinned epochs serve through their retained device arrays; answers
    must equal the scalar host merge, and the device upload must be
    memoized on the snapshot (one upload per epoch, not per pin)."""
    g = layered_dag(120, avg_out=2.0, seed=7)
    dyn = DynamicOracle(g)
    trace = generate_trace(g, rounds=2, updates_per_round=10,
                           queries_per_round=1, dag_preserving=True, seed=3)
    replay(dyn, trace)
    old_epoch = dyn.epochs[0]
    snap = dyn.snapshot(old_epoch)
    q = np.stack([rng.integers(0, g.n, 300), rng.integers(0, g.n, 300)], axis=1)
    dev = snap.query_batch(q, device=True)
    host = snap.query_batch(q, device=False)
    assert np.array_equal(dev, host)
    # serve(epoch=...) routes through the same snapshot path
    assert np.array_equal(dyn.serve(q, epoch=old_epoch), dev)
    # memoized device arrays: same objects on every pin
    lo1, li1 = snap.oracle.device_labels()
    lo2, li2 = snap.oracle.device_labels()
    assert lo1 is lo2 and li1 is li2


def test_growth_log_tracks_label_ints_per_epoch():
    g = layered_dag(150, avg_out=2.0, seed=11)
    dyn = DynamicOracle(g)
    trace = generate_trace(g, rounds=3, updates_per_round=8,
                           queries_per_round=1, dag_preserving=True, seed=5)
    replay(dyn, trace)
    gl = dyn.growth_log
    assert len(gl) == dyn.epoch  # one entry per publish
    for e in gl:
        assert {"epoch", "label_ints", "appends", "drops", "rebuilt",
                "growth_rate"} <= set(e)
    assert gl[-1]["label_ints"] == dyn.labels.label_ints()
    # growth rate is the relative label-ints delta between publishes
    ints = [e["label_ints"] for e in gl]
    for prev, e in zip(ints, gl[1:]):
        assert e["growth_rate"] == pytest.approx(
            (e["label_ints"] - prev) / max(prev, 1), abs=1e-5)


def test_cow_publish_reuses_clean_rows():
    g = layered_dag(200, avg_out=2.0, seed=3)
    dyn = DynamicOracle(g)
    before = dyn.snapshot().oracle
    # a DAG-preserving insert repairs a few rows; publish is COW
    trace = generate_trace(g, rounds=1, updates_per_round=5,
                           queries_per_round=1, dag_preserving=True, seed=1)
    replay(dyn, trace)
    after = dyn.snapshot().oracle
    assert after is not before
    if after.L_out.shape == before.L_out.shape:
        same = (after.L_out == before.L_out).all(axis=1)
        assert same.sum() >= g.n - 64  # only repaired rows differ

def test_engine_refresh_keeps_epoch_and_widths():
    g = layered_dag(300, avg_out=2.0, seed=9)
    dyn = DynamicOracle(g)
    eng = dyn.engine
    w0, e0 = list(eng.widths), eng.epoch
    dyn.apply(UpdateBatch.of(inserts=[]))
    e1 = dyn.publish()
    assert eng.epoch == e1 == e0 + 1
    assert eng.widths == w0  # no label change -> same tier plan, no retrace


def test_mutable_labels_roundtrip_and_tally():
    g = random_dag(50, 120, seed=2)
    o = build_oracle(g)
    labels = MutableLabels.from_oracle(o.oracle)
    assert labels.label_ints() == o.oracle.total_label_size
    # tally counts every reference
    assert int(labels.tally_out.sum() + labels.tally_in.sum()) == labels.label_ints()
    # add/drop bookkeeping
    v = 0
    r = int(labels.out_rows[v][0])
    assert labels.add("out", v, r) == 0  # idempotent
    dropped = labels.drop_in_set("out", v, {r})
    assert dropped == 1 and not labels.has("out", v, r)
    labels.add("out", v, r)
    out_d, in_d = labels.take_dirty()
    assert v in out_d
    assert labels.take_dirty() == ({}, {})


def test_check_monotone_gate(tmp_path):
    import json
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    try:
        from benchmarks.common import check_monotone
    finally:
        sys.path.pop(0)

    def entry(ints, speedup, match=True, reps=2):
        return {
            "reps": reps,
            "engine": {"impl": "wave", "label_ints": ints, "seconds": 1.0,
                       "labels_per_sec": ints},
            "reference": {"seconds": speedup, "label_ints": ints,
                          "labels_per_sec": ints},
            "speedup": speedup,
            "labels_match_reference": match,
        }

    committed = {"ds@1": entry(1000, 3.0)}
    lines = []

    def fresh(e):
        p = tmp_path / "fresh.json"
        p.write_text(json.dumps({"datasets": {"ds@1": e}}))
        return str(p)

    ok = check_monotone(fresh(entry(1000, 3.1)), committed,
                        serve_path="/nonexistent", dynamic_path="/nonexistent",
                          out=lines.append)
    assert ok == []
    # >10% index growth fails
    assert check_monotone(fresh(entry(1200, 3.0)), committed,
                          serve_path="/nonexistent", dynamic_path="/nonexistent",
                          out=lines.append)
    # >10% speedup drop fails
    assert check_monotone(fresh(entry(1000, 2.0)), committed,
                          serve_path="/nonexistent", dynamic_path="/nonexistent",
                          out=lines.append)
    # lost byte-identity fails
    assert check_monotone(fresh(entry(1000, 3.0, match=False)), committed,
                          serve_path="/nonexistent", dynamic_path="/nonexistent",
                          out=lines.append)
    # single-rep rows skip the (noisy) speedup ratio check
    assert check_monotone(fresh(entry(1000, 2.0, reps=1)), committed,
                          serve_path="/nonexistent", dynamic_path="/nonexistent",
                          out=lines.append) == []

    # scheduler share: > 15-point creep fails, smaller wobble passes
    def sched_entry(share):
        e = entry(1000, 3.0)
        e["scheduler"] = {"share_onepass": share}
        return e

    committed_s = {"ds@1": sched_entry(0.25)}
    assert check_monotone(fresh(sched_entry(0.33)), committed_s,
                          serve_path="/nonexistent", dynamic_path="/nonexistent",
                          out=lines.append) == []
    assert check_monotone(fresh(sched_entry(0.45)), committed_s,
                          serve_path="/nonexistent", dynamic_path="/nonexistent",
                          out=lines.append)

    # device-engine rows gate on byte-identity unconditionally
    def fresh_dev(match):
        p = tmp_path / "fresh_dev.json"
        p.write_text(json.dumps({
            "datasets": {},
            "device_engine": {"ds@1": {"labels_match_reference": match}},
        }))
        return str(p)

    assert check_monotone(fresh_dev(True), {}, serve_path="/nonexistent",
                          dynamic_path="/nonexistent", out=lines.append) == []
    assert check_monotone(fresh_dev(False), {}, serve_path="/nonexistent",
                          dynamic_path="/nonexistent", out=lines.append)


def test_deprecation_shim_warns():
    # the shim is slated for removal (see its docstring for the date); until
    # then it must warn on import and re-export the EXACT serve.engine
    # objects — not copies — so behavior cannot drift between the two paths
    import sys
    import warnings

    from repro.serve import engine as serve_engine

    sys.modules.pop("repro.core.query", None)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        import repro.core.query as shim
        assert any(issubclass(x.category, DeprecationWarning) for x in w)
    for name in shim.__all__:
        assert getattr(shim, name) is getattr(serve_engine, name)
