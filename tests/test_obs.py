"""Observability layer tests: metrics registry semantics, tracer/Chrome
export structure, the disabled no-op path, and the two end-to-end contracts
the layer exists for —

  * a deterministic faulted daemon run exports a timeline that STRUCTURALLY
    contains the request lifecycle (admission span, expired-shed terminal
    event, breaker-open event, host-rung dispatch span), validated by
    event ph/cat/name/args rather than string matching, and
  * the registry snapshot reconciles exactly with the daemon's own shed /
    served counters (the registry is the substrate under ``health()``, not
    a second set of books),

plus the README drift guard: every registered metric family must be
documented in the README metric table.
"""
import asyncio
import json
import warnings

import numpy as np
import pytest

from repro import obs
from repro.core.api import build_oracle
from repro.ft import inject
from repro.graph.generators import random_dag
from repro.obs import metrics, trace
from repro.serve.daemon import (
    _COUNTER_METRICS,
    DaemonConfig,
    ServeDaemon,
    ShedError,
)

G = random_dag(300, 1000, seed=7)


@pytest.fixture(scope="module")
def co():
    return build_oracle(G)


@pytest.fixture(autouse=True)
def _obs_enabled_after():
    """No test may leave the process-global obs switch off."""
    yield
    obs.enable()


def _queries(rng, k=64):
    return rng.integers(0, G.n, size=(k, 2)).astype(np.int32)


# ------------------------------------------------------------------ registry


def test_counter_labels_and_snapshot():
    c = metrics.counter("t_obs_requests_total", "test counter",
                        labelnames=("event",))
    a = c.labels(event="a")
    b = c.labels(event="b")
    assert c.labels(event="a") is a          # children are cached
    a.inc()
    a.inc(3)
    b.inc()
    snap = metrics.snapshot()["t_obs_requests_total"]
    assert snap["type"] == "counter"
    assert snap["labels"] == ["event"]
    assert snap["values"]["event=a"] == 4
    assert snap["values"]["event=b"] == 1
    assert metrics.REGISTRY.counter_value("t_obs_requests_total", event="a") == 4
    assert metrics.REGISTRY.counter_total("t_obs_requests_total") == 5


def test_reregistration_shares_family_but_rejects_shape_change():
    c1 = metrics.counter("t_obs_shared_total", labelnames=("kind",))
    c2 = metrics.counter("t_obs_shared_total", labelnames=("kind",))
    assert c1 is c2
    with pytest.raises(ValueError):
        metrics.gauge("t_obs_shared_total", labelnames=("kind",))
    with pytest.raises(ValueError):
        metrics.counter("t_obs_shared_total", labelnames=("other",))
    with pytest.raises(ValueError):
        c1.labels(wrong="x")


def test_reset_zeroes_values_but_keeps_bound_children():
    c = metrics.counter("t_obs_reset_total", labelnames=("k",))
    bound = c.labels(k="x")
    bound.inc(7)
    metrics.REGISTRY.reset()
    assert bound.value == 0
    bound.inc()                              # the module-level ref still works
    assert metrics.REGISTRY.counter_value("t_obs_reset_total", k="x") == 1


def test_histogram_buckets_and_overflow():
    h = metrics.histogram("t_obs_lat_ms", buckets=(1.0, 10.0))
    child = h.labels()
    for v in (0.2, 0.7, 5.0, 99.0):
        child.observe(v)
    snap = metrics.snapshot()["t_obs_lat_ms"]["values"][""]
    assert snap["buckets_le"] == [1.0, 10.0, "+Inf"]
    assert snap["counts"] == [2, 1, 1]
    assert snap["count"] == 4
    assert snap["sum"] == pytest.approx(104.9)


def test_disabled_is_a_noop_everywhere():
    c = metrics.counter("t_obs_off_total")
    g = metrics.gauge("t_obs_off_gauge")
    h = metrics.histogram("t_obs_off_ms", buckets=(1.0,))
    tr = trace.Tracer(capacity=16)
    obs.disable()
    try:
        c.inc()
        g.set(5)
        h.observe(0.5)
        assert tr.span("s") is trace.NOOP_SPAN
        with tr.span("s", cat="x", args={"a": 1}):
            pass
        tr.event("e")
        assert tr.begin("b") is None
        tr.end(None)
    finally:
        obs.enable()
    assert metrics.REGISTRY.counter_value("t_obs_off_total") == 0
    # the bound child exists (binding is registration, not observation)
    # but no value ever landed
    assert metrics.snapshot()["t_obs_off_gauge"]["values"][""] is None
    assert metrics.snapshot()["t_obs_off_ms"]["values"][""]["count"] == 0
    assert len(tr.events) == 0


# -------------------------------------------------------------------- tracer


def test_chrome_payload_structure_and_ring_bound(tmp_path):
    tr = trace.Tracer(capacity=4)
    with tr.span("outer", cat="test", args={"trace_id": 42}) as sp:
        sp.event("mid", detail=1)            # inherits cat + trace_id
        sp.set(extra="late")
    tok = tr.begin("cross_thread", cat="test")
    tr.end(tok, outcome="done")
    payload = tr.chrome_payload(meta={"k": "v"})
    assert payload["displayTimeUnit"] == "ms"
    assert payload["metadata"] == {"k": "v"}
    evs = payload["traceEvents"]
    assert [e["ts"] for e in evs] == sorted(e["ts"] for e in evs)
    outer = next(e for e in evs if e["name"] == "outer")
    assert outer["ph"] == "X" and outer["dur"] >= 0
    assert outer["args"] == {"trace_id": 42, "extra": "late"}
    mid = next(e for e in evs if e["name"] == "mid")
    assert mid["ph"] == "i" and mid["s"] == "t"
    assert mid["args"]["trace_id"] == 42 and mid["cat"] == "test"
    cross = next(e for e in evs if e["name"] == "cross_thread")
    assert cross["args"] == {"outcome": "done"}
    # export round-trips as plain JSON
    p = tmp_path / "t.json"
    tr.export_chrome(str(p))
    assert json.loads(p.read_text())["traceEvents"]
    # bounded ring: capacity 4 keeps only the newest 4
    for i in range(6):
        tr.event(f"e{i}")
    assert len(tr.events) == 4
    tr.clear()
    assert len(tr.events) == 0


# ---------------------------------------------- faulted end-to-end contracts


@pytest.fixture(scope="module")
def faulted_run(co):
    """One deterministic faulted daemon run, traced from a clean registry:
    occurrence 0 of ``serve.device_dispatch`` stalls 150ms (expiring a
    30ms-budget request queued behind it), occurrences 1-2 fail (tripping
    the 2-failure breaker), and a final submit serves on the host rung."""
    plan = inject.Injector({"serve.device_dispatch": [1, 2]},
                           latency={"serve.device_dispatch": ([0], 0.15)})
    metrics.REGISTRY.reset()
    trace.TRACER.clear()

    async def go():
        daemon = ServeDaemon(co, DaemonConfig(
            batch_window_ms=1.0, backend="dense", deadline_ms=10_000.0,
            breaker_failures=2, breaker_backoff_ms=60_000.0))
        await daemon.start()
        rng = np.random.default_rng(0)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            with inject.active(plan):
                slow = asyncio.ensure_future(
                    daemon.submit(_queries(rng), deadline_ms=5000.0))
                await asyncio.sleep(0.03)    # stalled dispatch in flight
                doomed = asyncio.ensure_future(
                    daemon.submit(_queries(rng, 32), deadline_ms=30.0))
                await slow
                with pytest.raises(ShedError) as ei:
                    await doomed
                assert ei.value.reason == "expired"
                for _ in range(2):           # failures 1, 2: breaker trips
                    await daemon.submit(_queries(rng))
                assert daemon.breaker.state == "open"
                await daemon.submit(_queries(rng))   # breaker-open host rung
        await daemon.drain()
        return daemon

    daemon = asyncio.run(go())
    return daemon, trace.TRACER.chrome_payload(meta={"test": "faulted_run"})


def test_faulted_timeline_contains_request_lifecycle(faulted_run, tmp_path):
    daemon, payload = faulted_run
    evs = payload["traceEvents"]

    def spans(name, **want_args):
        return [e for e in evs if e["ph"] == "X" and e["name"] == name
                and all(e.get("args", {}).get(k) == v
                        for k, v in want_args.items())]

    def instants(name, **want_args):
        return [e for e in evs if e["ph"] == "i" and e["name"] == name
                and all(e.get("args", {}).get(k) == v
                        for k, v in want_args.items())]

    admissions = spans("admission")
    assert admissions and all(e["cat"] == "request" for e in admissions)
    # each admission carries the id the rest of the lifecycle references
    tids = {e["args"]["trace_id"] for e in admissions}
    assert len(tids) == len(admissions)

    expired = instants("shed", reason="expired")
    assert len(expired) == 1
    assert expired[0]["cat"] == "request"
    assert expired[0]["args"]["trace_id"] in tids

    trips = instants("breaker_open")
    assert len(trips) == 1 and trips[0]["cat"] == "daemon"
    assert trips[0]["args"]["trips"] == 1

    host_dispatch = spans("dispatch", rung="host")
    assert host_dispatch and host_dispatch[0]["cat"] == "daemon"
    # the breaker was open when the host rung served
    assert host_dispatch[0]["args"]["breaker"] == "open"
    # every retroactive queue span references an admitted request
    queue_spans = spans("queue")
    assert queue_spans
    assert all(e["args"]["trace_id"] in tids for e in queue_spans)
    assert any(e["args"]["expired"] for e in queue_spans)

    # faults themselves are on the timeline at their occurrence
    assert spans("fault.stall") and len(instants("fault.fail")) == 2

    # and the whole thing exports as a loadable chrome trace
    out = tmp_path / "faulted.json"
    trace.TRACER.export_chrome(str(out), meta={"test": "faulted_run"})
    loaded = json.loads(out.read_text())
    assert loaded["displayTimeUnit"] == "ms"
    assert {e["name"] for e in loaded["traceEvents"]} >= {
        "admission", "shed", "breaker_open", "dispatch"}


def test_metrics_snapshot_reconciles_with_daemon_counters(faulted_run):
    daemon, _ = faulted_run
    # the registry was reset at run start, so every mirrored counter must
    # equal the daemon's own books EXACTLY — no sampling, no drift
    for key, bound in _COUNTER_METRICS.items():
        assert bound.value == daemon.counters[key], key
    snap = metrics.snapshot()
    shed_total = sum(snap["daemon_shed_total"]["values"].values())
    c = daemon.counters
    assert shed_total == (c["shed_queue_full"] + c["shed_deadline"]
                          + c["shed_draining"] + c["shed_expired"]
                          + c["shed_killed"])
    assert snap["daemon_requests_total"]["values"]["event=answered"] == \
        c["answered"]
    assert metrics.REGISTRY.counter_total("faults_injected_total") == 3
    # latency histogram observed exactly the answered requests
    lat = snap["daemon_request_latency_ms"]["values"][""]
    assert lat["count"] == len(daemon.latencies)


# -------------------------------------------------------------- drift guard


def test_every_registered_metric_is_documented_in_readme():
    """Importing the wired layers registers every production metric family;
    each name must appear (backticked) in the README metric table."""
    import repro.build.engine        # noqa: F401
    import repro.dynamic.versioned   # noqa: F401
    import repro.ft.inject           # noqa: F401
    import repro.serve.daemon        # noqa: F401
    import repro.serve.engine        # noqa: F401

    import pathlib
    readme = (pathlib.Path(__file__).resolve().parent.parent
              / "README.md").read_text()
    undocumented = [
        name for name in metrics.REGISTRY.names()
        if not name.startswith("t_obs_") and f"`{name}`" not in readme
    ]
    assert not undocumented, (
        f"metric families missing from the README table: {undocumented}")
