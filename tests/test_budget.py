"""Memory-budgeted serving tier: rank-prefix truncation soundness, budget
monotonicity, the pressure governor's hysteresis, and persisted truncated
stores.

The load-bearing claim (see ``serve/budget.py``): with a uniform rank
threshold, a kept entry can never equal a dropped entry, so the only
verdicts the cut can change are label-misses where BOTH rows were cut —
and those are routed to exact search, never answered from the labels.
These tests check that claim directly against BFS truth at every budget,
not just against the full-label path.
"""
import numpy as np
import pytest

from repro.core import build_oracle
from repro.graph.csr import INVALID
from repro.graph.generators import layered_dag, random_dag
from repro.graph.reach import reaches_bit, transitive_closure_bits
from repro.serve.budget import (
    BudgetController,
    PressureConfig,
    TruncatedStore,
    label_bytes,
    pack_mask,
    rank_cut_for_budget,
    truncate_store,
    unpack_mask,
)


def _truth(g):
    tc = transitive_closure_bits(g)
    return lambda u, v: u == v or reaches_bit(tc, int(u), int(v))


# ------------------------------------------------------------- pure cut


def test_pack_unpack_mask_roundtrip(rng):
    for n in (1, 7, 8, 9, 64, 301):
        mask = rng.random(n) < 0.4
        assert np.array_equal(unpack_mask(pack_mask(mask), n), mask)


def test_full_budget_is_identity(rng):
    g = random_dag(120, 420, seed=5)
    co = build_oracle(g)
    full = label_bytes(co.oracle)
    st = truncate_store(co.oracle, budget_bytes=full)
    assert st.rank_cut == co.oracle.n
    assert not st.any_truncated
    assert st.dropped_ints == 0
    assert np.array_equal(st.oracle.out_len, co.oracle.out_len)
    assert np.array_equal(st.oracle.in_len, co.oracle.in_len)


def test_rank_cut_monotone_and_within_budget():
    g = random_dag(150, 600, seed=6)
    oracle = build_oracle(g).oracle
    full = label_bytes(oracle)
    prev_theta = None
    for frac in (1.0, 0.9, 0.75, 0.5, 0.25, 0.1, 0.02):
        budget = int(full * frac)
        theta = rank_cut_for_budget(oracle, budget)
        st = truncate_store(oracle, rank_cut=theta)
        # the binary search met the budget unless even the empty store's
        # padded floor (n * 2 * _PAD_MULT ints) exceeds it
        assert st.resident_bytes <= budget or theta == 0
        if prev_theta is not None:
            assert theta <= prev_theta   # smaller budget -> smaller theta
        prev_theta = theta


def test_truncation_is_rank_prefix():
    """Kept entries are exactly the rank-< theta prefix of each row — the
    index a construction run stopped at rank theta would have produced."""
    g = layered_dag(130, 2.2, seed=7)
    oracle = build_oracle(g).oracle
    theta = rank_cut_for_budget(oracle, label_bytes(oracle) // 2)
    st = truncate_store(oracle, rank_cut=theta)
    for mat, lens, tmat, tlens in (
        (oracle.L_out, oracle.out_len, st.oracle.L_out, st.oracle.out_len),
        (oracle.L_in, oracle.in_len, st.oracle.L_in, st.oracle.in_len),
    ):
        for v in range(oracle.n):
            row = mat[v, : lens[v]]
            want = row[row < theta]          # rows are rank-sorted
            got = tmat[v, : tlens[v]]
            assert np.array_equal(got, want), v
            assert np.all(tmat[v, tlens[v]:] == INVALID), v
    # mask flags exactly the rows that lost entries
    assert np.array_equal(st.truncated_out, st.oracle.out_len < oracle.out_len)
    assert np.array_equal(st.truncated_in, st.oracle.in_len < oracle.in_len)


def test_kept_never_meets_dropped():
    """The soundness core: a surviving hit is a real hit (kept entries are a
    subset of the full rows), and a lost intersection implies BOTH rows were
    truncated — the 'miss + both cut' residue is the ONLY uncertain case."""
    g = random_dag(140, 560, seed=8)
    oracle = build_oracle(g).oracle
    st = truncate_store(oracle, budget_bytes=label_bytes(oracle) // 3)
    assert st.any_truncated
    n = oracle.n
    for u in range(n):
        for v in range(n):
            full_out = set(oracle.L_out[u, : oracle.out_len[u]].tolist())
            full_in = set(oracle.L_in[v, : oracle.in_len[v]].tolist())
            cut_out = set(st.oracle.L_out[u, : st.oracle.out_len[u]].tolist())
            cut_in = set(st.oracle.L_in[v, : st.oracle.in_len[v]].tolist())
            if cut_out & cut_in:
                assert full_out & full_in, (u, v)   # hit => proven YES
            if (full_out & full_in) and not (cut_out & cut_in):
                # lost intersection lives in dropped x dropped
                assert st.truncated_out[u] and st.truncated_in[v], (u, v)


# ------------------------------------------------- engine three-valued path


@pytest.mark.parametrize("frac", [1.0, 0.75, 0.5, 0.25, 0.05])
def test_engine_exact_at_every_budget(frac, rng):
    g = random_dag(180, 720, seed=9)
    co = build_oracle(g)
    truth = _truth(g)
    q = rng.integers(0, g.n, size=(1500, 2)).astype(np.int32)
    want = np.array([truth(u, v) for u, v in q])
    full = label_bytes(co.oracle)
    st = truncate_store(co.oracle, budget_bytes=int(full * frac))
    co.engine.set_budget(st)
    co.engine.reset_stats()
    got = co.engine.query_batch(q, backend="host")
    assert np.array_equal(got, want)
    deg = co.engine.last_stats["degraded"]
    if frac == 1.0:
        assert not st.any_truncated
        assert deg["uncertain"] == 0
    # single-query path agrees with the batch path
    for u, v in q[:60]:
        assert co.engine.query(int(u), int(v)) == truth(u, v)
    co.engine.set_budget(None)


def test_uncertain_rate_monotone_in_budget(rng):
    """Smaller budget -> nested uncertain sets -> the uncertain count on a
    FIXED query set is monotone non-increasing in budget (the BENCH_serve
    gate, checked here deterministically)."""
    g = random_dag(200, 800, seed=10)
    co = build_oracle(g)
    q = rng.integers(0, g.n, size=(2500, 2)).astype(np.int32)
    full = label_bytes(co.oracle)
    counts = []
    for frac in (1.0, 0.75, 0.5, 0.25, 0.1):
        co.engine.set_budget(
            truncate_store(co.oracle, budget_bytes=int(full * frac)))
        co.engine.reset_stats()
        co.engine.query_batch(q, backend="host")
        counts.append(co.engine.last_stats["degraded"]["uncertain"])
    co.engine.set_budget(None)
    assert counts[0] == 0
    assert all(a <= b for a, b in zip(counts, counts[1:])), counts


def test_stats_and_clear(rng):
    g = random_dag(90, 300, seed=11)
    co = build_oracle(g)
    assert co.engine.stats()["budget"] is None
    st = truncate_store(co.oracle, budget_bytes=label_bytes(co.oracle) // 2)
    co.engine.set_budget(st)
    b = co.engine.stats()["budget"]
    assert b["resident_bytes"] == st.resident_bytes
    assert b["rank_cut"] == st.rank_cut
    assert b["n_truncated_rows"] == int(st.truncated_out.sum()
                                        + st.truncated_in.sum())
    co.engine.set_budget(None)
    assert co.engine.stats()["budget"] is None
    q = rng.integers(0, g.n, size=(200, 2)).astype(np.int32)
    truth = _truth(g)
    got = co.engine.query_batch(q, backend="host")
    assert np.array_equal(got, np.array([truth(u, v) for u, v in q]))


# ------------------------------------------------------------- controller


def test_controller_hysteresis_walk():
    g = random_dag(160, 640, seed=12)
    co = build_oracle(g)
    full = label_bytes(co.oracle)
    sig = {"bytes": 0.0}
    ctl = BudgetController(
        co.engine,
        pressure=PressureConfig(watermark_bytes=full // 2, step_factor=0.5,
                                recovery_ticks=2),
        pressure_source=lambda: sig["bytes"],
    )
    assert ctl.tick() is None                      # calm: nothing happens
    sig["bytes"] = float(full)                     # pressure!
    assert ctl.tick() == "step_down"
    first = ctl.budget_bytes
    assert first is not None and co.engine.budget_store is not None
    assert ctl.tick() == "step_down"               # still hot: halve again
    assert ctl.budget_bytes < first
    assert ctl.snapshot()["step_depth"] == 2
    sig["bytes"] = 0.0                             # pressure gone
    assert ctl.tick() is None                      # calm tick 1 of 2
    assert ctl.tick() == "step_up"                 # undo one step
    assert ctl.budget_bytes == first
    assert ctl.tick() is None
    assert ctl.tick() == "step_up"                 # back to configured=None
    assert ctl.budget_bytes is None
    assert co.engine.budget_store is None          # full store restored
    assert ctl.snapshot()["step_depth"] == 0
    assert ctl.retruncations >= 3


def test_controller_floor_and_configured_budget():
    g = random_dag(100, 350, seed=13)
    co = build_oracle(g)
    full = label_bytes(co.oracle)
    configured = full // 2
    sig = {"bytes": float(full)}
    ctl = BudgetController(
        co.engine, budget_bytes=configured,
        pressure=PressureConfig(watermark_bytes=full // 4, step_factor=0.5,
                                recovery_ticks=1,
                                min_budget_bytes=configured // 4),
        pressure_source=lambda: sig["bytes"],
    )
    assert ctl.budget_bytes == configured          # operator budget applied
    while ctl.tick() == "step_down":
        pass
    assert ctl.budget_bytes == configured // 4     # clamped at the floor
    assert ctl.tick() is None                      # hot but floored: no flap
    sig["bytes"] = 0.0
    while ctl.snapshot()["step_depth"] > 0:
        ctl.tick()
    assert ctl.budget_bytes == configured          # recovers to CONFIGURED,
    assert co.engine.budget_store is not None      # not to the full store


def test_controller_reapply_after_refresh(rng):
    g = random_dag(110, 380, seed=14)
    co = build_oracle(g)
    ctl = BudgetController(co.engine,
                           budget_bytes=label_bytes(co.oracle) // 2)
    assert co.engine.budget_store is not None
    co.engine.refresh(co.oracle)                   # publish drops the view
    assert co.engine.budget_store is None
    ctl.reapply()                                  # daemon tick re-asserts
    st = co.engine.budget_store
    assert st is not None and st.any_truncated
    truth = _truth(g)
    q = rng.integers(0, g.n, size=(400, 2)).astype(np.int32)
    got = co.engine.query_batch(q, backend="host")
    assert np.array_equal(got, np.array([truth(u, v) for u, v in q]))


def test_controller_retain_full_requires_snapshot():
    g = random_dag(40, 100, seed=15)
    co = build_oracle(g)
    with pytest.raises(ValueError):
        BudgetController(co.engine, retain_full=False)


def test_controller_snapshot_path_reload(tmp_path):
    """retain_full=False: stepping back up reloads the full store from the
    persist snapshot instead of holding it in memory."""
    from repro.persist import save_oracle

    g = random_dag(100, 340, seed=16)
    co = build_oracle(g)
    path = str(tmp_path / "full")
    save_oracle(path, co.oracle)
    ctl = BudgetController(
        co.engine, budget_bytes=label_bytes(co.oracle) // 2,
        snapshot_path=path, retain_full=False,
    )
    assert ctl._full is None or ctl.budget_bytes is not None
    st = co.engine.budget_store
    assert st is not None and st.any_truncated
    ctl.apply(None)                                # step up => snapshot load
    assert co.engine.budget_store is None
    assert label_bytes(co.engine.oracle) == label_bytes(ctl.full_oracle())


# --------------------------------------------------------------- persist


def test_persist_budgeted_roundtrip(tmp_path):
    from repro.persist import load_budgeted, save_budgeted

    g = random_dag(130, 480, seed=17)
    oracle = build_oracle(g).oracle
    st = truncate_store(oracle, budget_bytes=label_bytes(oracle) // 2)
    path = str(tmp_path / "budgeted")
    save_budgeted(path, st)
    back = load_budgeted(path, strict=True)
    assert isinstance(back, TruncatedStore)
    assert back.rank_cut == st.rank_cut
    assert back.budget_bytes == st.budget_bytes
    assert back.resident_bytes == st.resident_bytes
    assert back.dropped_ints == st.dropped_ints
    assert np.array_equal(back.truncated_out, st.truncated_out)
    assert np.array_equal(back.truncated_in, st.truncated_in)
    assert np.array_equal(back.oracle.L_out, st.oracle.L_out)
    assert np.array_equal(back.oracle.L_in, st.oracle.L_in)


def test_persist_budgeted_wrong_kind(tmp_path):
    from repro.persist import CorruptSnapshotError, load_budgeted, save_oracle

    g = random_dag(60, 160, seed=18)
    oracle = build_oracle(g).oracle
    path = str(tmp_path / "plain")
    save_oracle(path, oracle)
    with pytest.raises(CorruptSnapshotError):
        load_budgeted(path, strict=True)


def test_persist_corrupt_mask_degrades_conservatively(tmp_path):
    """A corrupt truncation mask must never UNDER-mark: the non-strict load
    falls back to all-True (every row treated as truncated), which only
    routes more misses to exact search — it cannot create a wrong NO."""
    import glob

    from repro.ft.inject import flip_bit
    from repro.persist import (CorruptSnapshotError, load_budgeted,
                               save_budgeted)

    g = random_dag(120, 420, seed=19)
    co = build_oracle(g)
    st = truncate_store(co.oracle, budget_bytes=label_bytes(co.oracle) // 2)
    path = str(tmp_path / "budgeted")
    save_budgeted(path, st)
    (mask_file,) = glob.glob(str(tmp_path / "budgeted" / "trunc_mask_out*"))
    flip_bit(mask_file, seed=3)
    with pytest.raises(CorruptSnapshotError):
        load_budgeted(path, strict=True)
    back, report = load_budgeted(path, strict=False)
    assert any("trunc_mask_out" in b for b in report.bad_blocks)
    assert back.truncated_out.all()                # conservative fallback
    assert np.array_equal(back.truncated_in, st.truncated_in)
    # serving from the degraded store is still exact
    truth = _truth(g)
    co.engine.set_budget(back)
    q = np.random.default_rng(20).integers(0, g.n, size=(600, 2)).astype(np.int32)
    got = co.engine.query_batch(q, backend="host")
    assert np.array_equal(got, np.array([truth(u, v) for u, v in q]))
    co.engine.set_budget(None)
