"""Exhaustive correctness of every baseline the paper compares against."""
import numpy as np
import pytest

from repro.core.baselines import (
    Grail,
    IntervalTC,
    KReach,
    OnlineBFS,
    PWAHBitvector,
    TwoHopSetCover,
)
from repro.graph.generators import layered_dag, random_dag, tree_dag
from repro.graph.reach import reaches_bit, transitive_closure_bits

BASELINES = [OnlineBFS, Grail, IntervalTC, PWAHBitvector, TwoHopSetCover, KReach]


def _check(g, idx):
    tc = transitive_closure_bits(g)
    for u in range(g.n):
        for v in range(g.n):
            if u == v:
                continue
            assert reaches_bit(tc, u, v) == idx.query(u, v), (
                f"{idx.name}: {u}->{v}"
            )


@pytest.mark.parametrize("cls", BASELINES, ids=lambda c: c.name)
@pytest.mark.parametrize("seed", [0, 1])
def test_baseline_correct_random(cls, seed):
    _check(random_dag(45, 110, seed=seed), cls(random_dag(45, 110, seed=seed)))


@pytest.mark.parametrize("cls", BASELINES, ids=lambda c: c.name)
def test_baseline_correct_tree(cls):
    g = tree_dag(60, 3, seed=2)
    _check(g, cls(g))


@pytest.mark.parametrize("cls", BASELINES, ids=lambda c: c.name)
def test_baseline_correct_layered(cls):
    g = layered_dag(60, 2.0, seed=3)
    _check(g, cls(g))


def test_index_sizes_reported():
    g = random_dag(45, 110, seed=0)
    for cls in BASELINES:
        idx = cls(g)
        assert idx.index_size_ints >= 0
