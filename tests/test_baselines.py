"""Exhaustive correctness of every baseline the paper compares against."""
import numpy as np
import pytest

from repro.core.baselines import (
    Grail,
    IntervalTC,
    KReach,
    OnlineBFS,
    PWAHBitvector,
    TwoHopSetCover,
)
from repro.graph.generators import layered_dag, random_dag, tree_dag
from repro.graph.reach import reaches_bit, transitive_closure_bits

BASELINES = [OnlineBFS, Grail, IntervalTC, PWAHBitvector, TwoHopSetCover, KReach]


def _check(g, idx):
    tc = transitive_closure_bits(g)
    for u in range(g.n):
        for v in range(g.n):
            if u == v:
                continue
            assert reaches_bit(tc, u, v) == idx.query(u, v), (
                f"{idx.name}: {u}->{v}"
            )


@pytest.mark.parametrize("cls", BASELINES, ids=lambda c: c.name)
@pytest.mark.parametrize("seed", [0, 1])
def test_baseline_correct_random(cls, seed):
    _check(random_dag(45, 110, seed=seed), cls(random_dag(45, 110, seed=seed)))


@pytest.mark.parametrize("cls", BASELINES, ids=lambda c: c.name)
def test_baseline_correct_tree(cls):
    g = tree_dag(60, 3, seed=2)
    _check(g, cls(g))


@pytest.mark.parametrize("cls", BASELINES, ids=lambda c: c.name)
def test_baseline_correct_layered(cls):
    g = layered_dag(60, 2.0, seed=3)
    _check(g, cls(g))


def test_index_sizes_reported():
    g = random_dag(45, 110, seed=0)
    for cls in BASELINES:
        idx = cls(g)
        assert idx.index_size_ints >= 0


# --------------------------------------------- bidirectional_query (direct)
# The serve engine's exactness escape hatch: the quarantine rung (PR 7) and
# the budget-truncation uncertain rung both bottom out here, so it gets
# direct coverage, not just incidental exercise through chaos scenarios.

from repro.core.baselines.online_search import bidirectional_query  # noqa: E402
from repro.graph.csr import from_edges  # noqa: E402


def _all_pairs_agree(g, node_budget=None):
    g_rev = g.reverse()
    tc = transitive_closure_bits(g)
    for u in range(g.n):
        for v in range(g.n):
            want = u == v or reaches_bit(tc, u, v)
            got = bidirectional_query(g, g_rev, u, v, node_budget=node_budget)
            assert got == want, (u, v, node_budget)


@pytest.mark.parametrize("seed", [0, 1])
def test_bidirectional_matches_truth_all_pairs(seed):
    _all_pairs_agree(random_dag(40, 100, seed=seed))


@pytest.mark.parametrize("node_budget", [1, 3, 8, 10_000])
def test_bidirectional_budget_exhausted_forward_only(node_budget):
    # node_budget=1 exhausts the bidirectional phase after one expansion, so
    # nearly every positive pair completes on the forward-only fallback; the
    # verdicts must be identical at EVERY budget — bounding trades the
    # meet-in-the-middle speedup, never correctness
    _all_pairs_agree(random_dag(40, 100, seed=2), node_budget=node_budget)
    _all_pairs_agree(layered_dag(40, 2.0, seed=3), node_budget=node_budget)


def test_bidirectional_reversed_graph_correctness():
    # a long chain forces the search to alternate frontiers: the backward
    # frontier expands over g_rev, so a wrong reverse graph cannot pass
    n = 30
    chain = from_edges(n, np.arange(n - 1), np.arange(1, n))
    g_rev = chain.reverse()
    for i in range(n):
        for j in range(n):
            assert bidirectional_query(chain, g_rev, i, j) == (i <= j), (i, j)
    # reverse of the reverse serves the reversed reachability relation
    for i in range(n):
        for j in range(n):
            assert bidirectional_query(g_rev, chain, i, j) == (i >= j), (i, j)


@pytest.mark.parametrize("node_budget", [None, 1])
def test_bidirectional_self_reachability(node_budget):
    g = random_dag(25, 40, seed=4)   # sparse: leaves some vertices isolated
    g_rev = g.reverse()
    for u in range(g.n):
        assert bidirectional_query(g, g_rev, u, u, node_budget=node_budget)


@pytest.mark.parametrize("node_budget", [None, 2])
def test_bidirectional_disconnected_pairs(node_budget):
    # two components with no cross edges: every cross pair is False, and the
    # search must terminate on frontier exhaustion, not wander
    half = 12
    src = list(range(half - 1)) + [half + i for i in range(half - 1)]
    dst = list(range(1, half)) + [half + i + 1 for i in range(half - 1)]
    g = from_edges(2 * half, src, dst)
    g_rev = g.reverse()
    for u in range(half):
        for v in range(half, 2 * half):
            assert not bidirectional_query(g, g_rev, u, v,
                                           node_budget=node_budget)
            assert not bidirectional_query(g, g_rev, v, u,
                                           node_budget=node_budget)
