"""The construction engine: wave/bitset builder vs reference vs BFS truth.

Headline property: the wave engine produces BYTE-IDENTICAL finalized labels
to the seed scalar reference builder under the same vertex order, across the
same five graph families the serve engine is tested on — and both agree with
BFS ground truth.  Plus: wave-schedule soundness (members pairwise mutually
unreachable), bitset helper units, device-engine parity, and (slow) property
tests of completeness / non-redundancy for the wave engine.
"""
import numpy as np
import pytest

from repro.build import bitset
from repro.build.engine import build_distribution_labels
from repro.build.waves import dfs_intervals, wave_schedule
from repro.core.distribution import distribution_labeling
from repro.graph.csr import from_edges
from repro.graph.generators import layered_dag, random_dag, tree_dag
from repro.graph.reach import reachable_set, reaches_bit, transitive_closure_bits
from repro.graph.scc import condense_to_dag


def _dag_families(rng):
    """Five families mirroring tests/test_serve_engine.py, condensed to DAGs
    (the construction engine's input contract)."""
    fams = [
        ("random_dag", random_dag(70, 200, seed=1)),
        ("layered_dag", layered_dag(80, avg_out=2.5, seed=2)),
        ("tree_dag", tree_dag(90, branching=4, seed=3)),
    ]
    n = 60
    src, dst = rng.integers(0, n, 170), rng.integers(0, n, 170)
    fams.append(("cyclic", condense_to_dag(from_edges(n, src, dst))[0]))
    n = 80
    src, dst = rng.integers(0, n // 2, 60), rng.integers(0, n // 2, 60)
    fams.append(("isolated", condense_to_dag(from_edges(n, src, dst))[0]))
    return fams


def _assert_identical(ref, wav, tag):
    assert ref.L_out.tobytes() == wav.L_out.tobytes(), tag
    assert ref.L_in.tobytes() == wav.L_in.tobytes(), tag
    assert np.array_equal(ref.out_len, wav.out_len), tag
    assert np.array_equal(ref.in_len, wav.in_len), tag
    assert np.array_equal(ref.hop_rank, wav.hop_rank), tag


def test_wave_byte_identical_to_reference_all_families(rng):
    for name, g in _dag_families(rng):
        ref = build_distribution_labels(g, impl="reference")
        wav = build_distribution_labels(g, impl="wave")
        _assert_identical(ref, wav, name)


def test_wave_byte_identical_under_order_variants(rng):
    g = random_dag(120, 360, seed=8)
    for order_name in ("degree_product", "degree_sum", "random"):
        ref = build_distribution_labels(g, impl="reference", order_name=order_name)
        wav = build_distribution_labels(g, impl="wave", order_name=order_name)
        _assert_identical(ref, wav, order_name)


def test_wave_complete_vs_bfs_truth(rng):
    """Engine labels answer reachability exactly (Theorem 3), all families."""
    for name, g in _dag_families(rng):
        oracle = build_distribution_labels(g, impl="wave")
        tc = transitive_closure_bits(g)
        for u in range(g.n):
            for v in range(g.n):
                if u == v:
                    continue
                assert oracle.query(u, v) == reaches_bit(tc, u, v), (name, u, v)


def test_wave_handles_small_wave_caps(rng):
    """Forcing tiny waves (more batching boundaries) must not change labels."""
    g = layered_dag(150, avg_out=2.5, seed=4)
    ref = build_distribution_labels(g, impl="reference")
    for max_wave in (2, 7, 64):
        wav = build_distribution_labels(g, impl="wave", max_wave=max_wave)
        _assert_identical(ref, wav, f"max_wave={max_wave}")


def test_wave_schedule_members_mutually_unreachable(rng):
    """Soundness of the certificate: no wave member reaches another —
    both schedulers."""
    for name, g in _dag_families(rng):
        order = np.argsort(-g.out_degree().astype(np.int64), kind="stable").astype(np.int64)
        for scheduler in ("onepass", "blocked"):
            waves = wave_schedule(g, order, scheduler=scheduler)
            assert int(waves.sum()) == g.n, (name, scheduler)
            base = 0
            for wlen in waves:
                members = order[base : base + int(wlen)]
                for v in members:
                    reach = reachable_set(g, int(v))
                    others = members[members != v]
                    assert not reach[others].any(), (name, scheduler, int(v))
                base += int(wlen)


def test_onepass_schedule_equals_blocked_closure(rng):
    """Scheduler equivalence: with ``block >= n`` the per-block closure
    scheduler carves maximal greedy waves with exact conflicts — exactly
    what the one-pass windowed scheduler produces for ANY block size."""
    from repro.build.waves import wave_schedule_blocked
    from repro.core.order import get_order

    for name, g in _dag_families(rng):
        order = get_order(g, "degree_product")
        for max_wave in (2, 7, 64, 256):
            one = wave_schedule(g, order, max_wave=max_wave)
            blk = wave_schedule_blocked(
                g, order, max_wave=max_wave, block=max(g.n, max_wave)
            )
            assert np.array_equal(one, blk), (name, max_wave)


def test_onepass_schedule_budget_fallback_sound(rng):
    """A starved edge budget routes through bisection + conflict-with-all
    (or interval) fallbacks — the schedule must stay sound regardless."""
    from repro.core.order import get_order

    g = layered_dag(400, avg_out=2.0, seed=5)
    order = get_order(g, "degree_product")
    waves = wave_schedule(g, order, exact_budget=40)
    assert int(waves.sum()) == g.n
    base = 0
    for wlen in waves:
        members = order[base : base + int(wlen)]
        for v in members:
            reach = reachable_set(g, int(v))
            assert not reach[members[members != v]].any(), int(v)
        base += int(wlen)


def test_dfs_intervals_sound(rng):
    """u -> v implies post[v] in [low[u], post[u]] for every traversal."""
    g = random_dag(80, 240, seed=6)
    P, L = dfs_intervals(g, n_traversals=2)
    for u in range(g.n):
        reach = reachable_set(g, u)
        for v in np.nonzero(reach)[0]:
            for t in range(P.shape[0]):
                assert L[t, u] <= P[t, v] <= P[t, u], (u, int(v), t)


def test_auto_impl_routes_and_matches(rng):
    g = random_dag(300, 900, seed=9)
    auto = distribution_labeling(g)  # n < 4096 -> reference path
    assert getattr(auto, "build_impl") == "reference"
    wav = distribution_labeling(g, impl="wave")
    _assert_identical(auto, wav, "auto-vs-wave")


# ---------------------------------------------------------------------------
# bitset helper units
# ---------------------------------------------------------------------------


def test_bitset_group_or_and_gather(rng):
    keys = rng.integers(0, 10, 64).astype(np.int64)
    words = rng.integers(0, 2**63 - 1, (64, 2)).astype(np.uint64)
    uk, ow = bitset.group_or(keys, words)
    assert np.array_equal(uk, np.unique(keys))
    for i, k in enumerate(uk):
        expect = np.bitwise_or.reduce(words[keys == k], axis=0)
        assert np.array_equal(ow[i], expect)

    g = random_dag(40, 120, seed=3)
    indptr, indices = g.indptr.astype(np.int64), g.indices.astype(np.int64)
    verts = np.array([0, 5, 17], dtype=np.int64)
    nbrs, seg = bitset.csr_gather(indptr, indices, verts)
    expect = np.concatenate([g.out_neighbors(int(v)) for v in verts])
    assert np.array_equal(nbrs, expect)
    assert np.array_equal(seg, np.repeat([0, 1, 2], [len(g.out_neighbors(int(v))) for v in verts]))


def test_bitset_member_expansion(rng):
    w = 130  # spans 3 words
    mb = bitset.member_bits(w)
    assert mb.shape == (w, 3)
    rows, members, counts = bitset.expand_member_bits(mb, w)
    assert np.array_equal(rows, np.arange(w))
    assert np.array_equal(members, np.arange(w))
    assert np.array_equal(counts, np.ones(w, dtype=np.int64))
    # multi-bit rows expand row-major with ascending members
    combo = np.zeros((2, 3), dtype=np.uint64)
    combo[0] = mb[3] | mb[77] | mb[129]
    combo[1] = mb[0]
    rows, members, counts = bitset.expand_member_bits(combo, w)
    assert rows.tolist() == [0, 0, 0, 1]
    assert members.tolist() == [3, 77, 129, 0]
    assert counts.tolist() == [3, 1]
    assert bitset.popcount_u64(combo).tolist() == [3, 1]


def test_pack_bool_rows_u32(rng):
    mat = rng.random((7, 45)) < 0.3
    packed = bitset.pack_bool_rows_u32(mat)
    assert packed.shape == (7, 2)
    for i in range(7):
        for j in range(45):
            assert bool((packed[i, j // 32] >> np.uint32(j % 32)) & 1) == mat[i, j]


def test_ell_slabs_cover_all_edges(rng):
    """The degree-sorted slab decomposition lists every edge exactly once
    (row i of slab s = neighbor slots [s*w, (s+1)*w) of vertex perm[i])."""
    g = random_dag(90, 400, seed=13)
    indptr, indices = g.indptr.astype(np.int64), g.indices.astype(np.int64)
    perm, pos_of, slabs = bitset.ell_slabs(indptr, indices, g.n, width=4)
    assert np.array_equal(perm[pos_of], np.arange(g.n))
    per_vertex = {v: [] for v in range(g.n)}
    for slab in slabs:
        for i, row in enumerate(slab):
            per_vertex[int(perm[i])].extend(int(x) for x in row if x != -1)
    total = 0
    for v in range(g.n):
        assert per_vertex[v] == list(g.out_neighbors(v)), v
        total += len(per_vertex[v])
    assert total == g.m


# ---------------------------------------------------------------------------
# sparse device wave engine (ELL expansion, on-device append)
# ---------------------------------------------------------------------------


def test_device_engine_byte_identical_all_families(rng):
    """Fast rows: the XLA expansion path (same dataflow the Pallas kernel
    compiles on TPU) across the five serve-test graph families."""
    from repro.build.engine_jax import distribution_labeling_device

    for name, g in _dag_families(rng):
        ref = build_distribution_labels(g, impl="reference")
        dev = distribution_labeling_device(g, max_wave=32, expand="xla")
        _assert_identical(ref, dev, name)


def test_device_engine_byte_identical_under_order_variants(rng):
    from repro.build.engine_jax import distribution_labeling_device

    g = random_dag(120, 360, seed=8)
    for order_name in ("degree_product", "degree_sum", "random"):
        ref = build_distribution_labels(g, impl="reference", order_name=order_name)
        dev = distribution_labeling_device(
            g, order_name=order_name, max_wave=32, expand="xla"
        )
        _assert_identical(ref, dev, order_name)


def test_device_engine_label_matrix_growth(rng):
    """A deliberately tiny starting l_max forces the overflow-grow-rerun
    path; labels must stay byte-identical."""
    from repro.build.engine_jax import distribution_labeling_device

    g = random_dag(60, 170, seed=7)
    ref = build_distribution_labels(g, impl="reference")
    dev = distribution_labeling_device(g, max_wave=16, l_max=2, expand="xla")
    _assert_identical(ref, dev, "l_max growth")
    # an l_max below the reference's minimum row width that never overflows
    # must still finalize to the min-width-8 INVALID-padded layout
    from repro.graph.csr import from_edges as _fe

    g2 = _fe(3, [0, 1], [1, 2])
    ref2 = build_distribution_labels(g2, impl="reference")
    dev2 = distribution_labeling_device(g2, max_wave=4, l_max=4, expand="xla")
    assert dev2.L_out.shape == ref2.L_out.shape == (3, 8)
    _assert_identical(ref2, dev2, "min width pad")


def test_device_engine_pallas_interpret_row():
    """One fast interpret-mode row through the actual Pallas ELL kernel."""
    from repro.build.engine_jax import distribution_labeling_device

    g = random_dag(40, 110, seed=11)
    ref = build_distribution_labels(g, impl="reference")
    dev = distribution_labeling_device(
        g, max_wave=16, expand="pallas", interpret=True
    )
    _assert_identical(ref, dev, "pallas interpret")


def test_device_engine_sharded_expansion(rng):
    """The shard_map vertex-sharded expansion (single-device mesh on CPU;
    the same in/out specs place shards on real meshes)."""
    import jax
    from jax.sharding import Mesh

    from repro.build.engine_jax import distribution_labeling_device

    g = layered_dag(80, avg_out=2.5, seed=2)
    ref = build_distribution_labels(g, impl="reference")
    mesh = Mesh(np.array(jax.devices()), ("data",))
    dev = distribution_labeling_device(g, max_wave=16, expand="xla", mesh=mesh)
    _assert_identical(ref, dev, "shard_map mesh")


def test_engine_impl_device_routing_and_stats(rng):
    """impl='device' routes through the engine entry point; every build
    carries the scheduler-cost breakdown breadcrumb."""
    g = random_dag(70, 200, seed=1)
    ref = build_distribution_labels(g, impl="reference")
    dev = build_distribution_labels(g, impl="device", expand="xla")
    _assert_identical(ref, dev, "engine impl=device")
    for o, impl in ((ref, "reference"), (dev, "device")):
        stats = o.build_stats
        assert stats["impl"] == impl == o.build_impl
        assert {"schedule_seconds", "sweep_seconds", "n_waves"} <= set(stats)
    assert dev.build_stats["scheduler"] == "onepass"
    assert dev.build_stats["n_waves"] >= 1


@pytest.mark.slow
def test_device_engine_hardware_parity():
    """The hardware configuration: Pallas expansion (interpret off-TPU,
    compiled on TPU), wide waves spanning multiple uint32 words, and the
    engine-scheduled wave cap."""
    from repro.build.engine_jax import distribution_labeling_device

    g = layered_dag(300, avg_out=1.2, seed=9)
    host = build_distribution_labels(g, impl="wave")
    dev = distribution_labeling_device(g, max_wave=96, expand="pallas")
    _assert_identical(host, dev, "device-vs-host")


# The hypothesis property tests (Theorems 3-4 for the wave engine) live in
# tests/test_build_properties.py — module-level importorskip would skip this
# whole file on hypothesis-less environments.
