"""End-to-end oracle API over cyclic digraphs (SCC condensation path)."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.api import build_oracle
from repro.graph.csr import from_edges


def _brute_reach(n, src, dst):
    """bool[n, n] reachability (reflexive) by BFS from each vertex."""
    adj = [[] for _ in range(n)]
    for s, d in zip(src, dst):
        adj[s].append(d)
    out = np.zeros((n, n), dtype=bool)
    for u in range(n):
        seen = {u}
        stack = [u]
        while stack:
            x = stack.pop()
            for w in adj[x]:
                if w not in seen:
                    seen.add(w)
                    stack.append(w)
        out[u, list(seen)] = True
    return out


@st.composite
def cyclic_digraphs(draw):
    n = draw(st.integers(5, 30))
    m = draw(st.integers(n, 4 * n))
    seed = draw(st.integers(0, 10_000))
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    return n, src, dst


@settings(max_examples=25, deadline=None)
@given(cyclic_digraphs())
def test_condensed_oracle_complete_on_cyclic_graphs(graph):
    n, src, dst = graph
    g = from_edges(n, src, dst)
    truth = _brute_reach(n, *g.edges())
    for method in ("distribution",):
        oracle = build_oracle(g, method=method)
        for u in range(n):
            for v in range(n):
                assert oracle.query(u, v) == truth[u, v], (method, u, v)


def test_condensed_oracle_serve_batch():
    rng = np.random.default_rng(0)
    n, m = 60, 200
    src, dst = rng.integers(0, n, m), rng.integers(0, n, m)
    g = from_edges(n, src, dst)
    truth = _brute_reach(n, *g.edges())
    oracle = build_oracle(g)
    q = rng.integers(0, n, size=(300, 2)).astype(np.int32)
    pred = oracle.serve(q)
    exp = truth[q[:, 0], q[:, 1]]
    assert (pred == exp).all()


def test_hierarchical_method_on_cyclic():
    rng = np.random.default_rng(3)
    n, m = 40, 120
    src, dst = rng.integers(0, n, m), rng.integers(0, n, m)
    g = from_edges(n, src, dst)
    truth = _brute_reach(n, *g.edges())
    oracle = build_oracle(g, method="hierarchical", core_max=8)
    for u in range(n):
        for v in range(n):
            assert oracle.query(u, v) == truth[u, v]
