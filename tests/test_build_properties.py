"""Property-based tests of the wave construction engine (hypothesis).

  * Completeness (Theorem 3) + byte-identity to the scalar reference on
    arbitrary small random DAGs.
  * Non-redundancy (Theorem 4): every hop the wave engine emits is
    load-bearing.

These complement the deterministic family tests in test_build_engine.py;
both carry the ``slow`` marker (deselect with ``-m "not slow"``).
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.build.engine import build_distribution_labels
from repro.core.oracle import ReachabilityOracle
from repro.graph.generators import random_dag
from repro.graph.reach import reaches_bit, transitive_closure_bits


@st.composite
def small_dags(draw):
    n = draw(st.integers(8, 40))
    m = draw(st.integers(n // 2, 3 * n))
    seed = draw(st.integers(0, 10_000))
    return random_dag(n, m, seed=seed)


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(small_dags())
def test_wave_engine_complete_and_matches_reference(g):
    """Theorem 3 (complete) + byte-identity on arbitrary small DAGs."""
    ref = build_distribution_labels(g, impl="reference")
    wav = build_distribution_labels(g, impl="wave")
    assert ref.L_out.tobytes() == wav.L_out.tobytes()
    assert ref.L_in.tobytes() == wav.L_in.tobytes()
    assert np.array_equal(ref.out_len, wav.out_len)
    assert np.array_equal(ref.in_len, wav.in_len)
    tc = transitive_closure_bits(g)
    for u in range(g.n):
        for v in range(g.n):
            if u != v:
                assert wav.query(u, v) == reaches_bit(tc, u, v)


@pytest.mark.slow
@settings(max_examples=6, deadline=None)
@given(st.integers(0, 1000))
def test_wave_engine_non_redundant(seed):
    """Theorem 4: every hop the wave engine emits is load-bearing."""
    g = random_dag(16, 32, seed=seed)
    oracle = build_distribution_labels(g, impl="wave")
    tc = transitive_closure_bits(g)

    def complete_without(mat_name, vertex, drop) -> bool:
        L_out, L_in = oracle.L_out.copy(), oracle.L_in.copy()
        mat = L_out if mat_name == "out" else L_in
        row = mat[vertex]
        row[row == drop] = -1
        o2 = ReachabilityOracle(L_out, L_in, oracle.out_len, oracle.in_len)
        for u in range(g.n):
            for v in range(g.n):
                truth = True if u == v else reaches_bit(tc, u, v)
                if truth != o2.query(u, v):
                    return False
        return True

    for v in range(g.n):
        for hop in oracle.L_out[v][oracle.L_out[v] != -1]:
            assert not complete_without("out", v, int(hop))
        for hop in oracle.L_in[v][oracle.L_in[v] != -1]:
            assert not complete_without("in", v, int(hop))