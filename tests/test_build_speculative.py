"""The speculative construction path: optimistic chunks + certify + correct.

Headline property: ``impl="speculative"`` produces BYTE-IDENTICAL finalized
labels to the scalar reference builder — on the five DAG families, on the
dense-reachability paper analogues (citeseerx / cit-Patents) where the exact
wave scheduler degenerates, and on an adversarial rank-consecutive chain
that forces a near-100% violation rate.  Plus: auto-dispatch routes dense
schedules to the speculative engine, the scalar bailout engages on sustained
worst-case chains, `_LabelStore` rollback restores exact watermarks (deep
tails and null-refill included), and the certification word primitives /
device certification mask agree with brute force.
"""
import numpy as np
import pytest

from repro.build import bitset
from repro.build.engine import _LabelStore, build_distribution_labels
from repro.core.distribution import distribution_labeling
from repro.graph.csr import from_edges
from repro.graph.generators import paper_dataset_analogue

from test_build_engine import _assert_identical, _dag_families


def _chain(n: int):
    """Directed path 0 -> 1 -> ... -> n-1; with order = identity every pair
    of consecutive ranks truly conflicts, the worst case for speculation."""
    return from_edges(n, np.arange(n - 1), np.arange(1, n))


def _chain_segments(n: int, seg: int):
    """Disjoint directed paths of length ``seg`` laid out rank-consecutively:
    identical per-chunk conflict structure to one long chain, but label rows
    stay O(seg) so the reference build is cheap at thousands of ranks."""
    src = np.concatenate(
        [np.arange(s, s + seg - 1) for s in range(0, n, seg)])
    return from_edges(n, src, src + 1)


# ---------------------------------------------------------------------------
# byte identity
# ---------------------------------------------------------------------------


def test_speculative_byte_identical_all_families(rng):
    for name, g in _dag_families(rng):
        ref = build_distribution_labels(g, impl="reference")
        spec = build_distribution_labels(g, impl="speculative")
        _assert_identical(ref, spec, name)


def test_speculative_byte_identical_under_order_variants(rng):
    from repro.graph.generators import random_dag

    g = random_dag(120, 360, seed=8)
    for order_name in ("degree_product", "degree_sum", "random"):
        ref = build_distribution_labels(g, impl="reference", order_name=order_name)
        spec = build_distribution_labels(g, impl="speculative", order_name=order_name)
        _assert_identical(ref, spec, order_name)


@pytest.mark.parametrize(
    "name,scale", [("citeseerx", 0.0008), ("cit-Patents", 0.001)]
)
def test_speculative_byte_identical_dense_analogues(name, scale):
    g = paper_dataset_analogue(name, scale=scale, seed=7)
    ref = build_distribution_labels(g, impl="reference")
    spec = build_distribution_labels(g, impl="speculative")
    _assert_identical(ref, spec, name)
    st = spec.build_stats["speculation"]
    assert st["spec_waves"] > 0 and st["spec_members"] > 0
    assert not st["scalar_bailout"]


def test_auto_routes_speculative_on_dense_analogue():
    g = paper_dataset_analogue("citeseerx", scale=0.0008, seed=7)
    assert g.n >= 4096  # above the small-graph reference cutoff
    auto = distribution_labeling(g)
    assert auto.build_impl == "speculative"
    assert auto.build_stats["impl"] == "speculative"
    assert "violation_rate" in auto.build_stats["speculation"]
    ref = build_distribution_labels(g, impl="reference")
    _assert_identical(ref, auto, "auto-vs-reference")


# ---------------------------------------------------------------------------
# adversarial rank-consecutive chains
# ---------------------------------------------------------------------------


def test_adversarial_chain_near_total_violation():
    n = 128
    g = _chain(n)
    order = np.arange(n)
    ref = build_distribution_labels(g, order=order, impl="reference")
    spec = build_distribution_labels(g, order=order, impl="speculative")
    _assert_identical(ref, spec, "chain")
    st = spec.build_stats["speculation"]
    # every member except each chunk's lowest rank runs on stale prune sets
    assert st["violations"] == st["spec_members"] - st["spec_waves"]
    assert st["violation_rate"] >= 0.9
    assert st["replayed_members"] == st["violations"]
    assert not st["scalar_bailout"]  # too short to give up on


def test_adversarial_chain_scalar_bailout():
    # 9 optimistic schedule pages of 256 ranks: the bailout check at the
    # ninth sees >= 2048 speculated members with the cap ground down to its
    # floor and ~0.88 of members replayed -> the rest run the scalar loop
    n, seg = 2304, 32
    g = _chain_segments(n, seg)
    order = np.arange(n)
    ref = build_distribution_labels(g, order=order, impl="reference")
    spec = build_distribution_labels(g, order=order, impl="speculative")
    _assert_identical(ref, spec, "chain-segments")
    st = spec.build_stats["speculation"]
    assert st["scalar_bailout"]
    assert st["violation_rate"] >= 0.8
    assert st["spec_members"] < n  # the tail ranks never speculated


# ---------------------------------------------------------------------------
# _LabelStore rollback watermarks
# ---------------------------------------------------------------------------


def test_labelstore_rollback_restores_watermark():
    store = _LabelStore(4, deep_cap=8, null=9)
    v = np.array([0, 2], dtype=np.int64)
    store.append(v, np.array([3, 2]), np.array([1, 2, 3, 4, 5], dtype=np.int32))
    before = [store.row(u).copy() for u in range(4)]
    marks = store.lens[v].copy()
    store.append(v, np.array([2, 4]), np.arange(10, 16, dtype=np.int32))
    store.rollback(v, marks)
    for u in range(4):
        assert np.array_equal(store.row(u), before[u]), u
    # null-refill invariant: every head slot past the row length holds the
    # null sentinel again (the rectangular prune gather relies on it)
    for u in range(4):
        assert (store.mat[u, store.lens[u]:] == 9).all(), u


def test_labelstore_rollback_across_deep_boundary():
    store = _LabelStore(2, deep_cap=4, null=7)
    v = np.array([0], dtype=np.int64)
    store.append(v, np.array([3]), np.arange(3, dtype=np.int32))
    mark = store.lens[v].copy()
    # push the row through the dense head into the deep tail, then undo
    store.append(v, np.array([6]), np.arange(10, 16, dtype=np.int32))
    assert store.lens[0] == 9 and 0 in store.deep
    store.rollback(v, mark)
    assert np.array_equal(store.row(0), np.arange(3, dtype=np.int32))
    assert 0 not in store.deep
    assert (store.mat[0, 3:] == 7).all()
    # partial rollback that still ends inside the deep tail
    store.append(v, np.array([6]), np.arange(20, 26, dtype=np.int32))
    store.rollback(v, np.array([6], dtype=np.int32))
    assert np.array_equal(
        store.row(0), np.array([0, 1, 2, 20, 21, 22], dtype=np.int32))
    assert len(store.deep[0]) == 2


def test_labelstore_rollback_to_empty():
    store = _LabelStore(3, deep_cap=8, null=5)
    v = np.array([1], dtype=np.int64)
    store.append(v, np.array([4]), np.arange(4, dtype=np.int32))
    store.rollback(v, np.zeros(1, dtype=np.int32))
    assert store.lens[1] == 0
    assert store.row(1).size == 0
    assert (store.mat[1] == 5).all()


# ---------------------------------------------------------------------------
# certification word primitives
# ---------------------------------------------------------------------------


def test_prefix_bits_triangular():
    w = 70  # crosses a word boundary
    pref = bitset.prefix_bits(w)
    mb = bitset.member_bits(w)
    for j in range(w):
        for i in range(w):
            have = bool((pref[j] & mb[i]).any())
            assert have == (i < j), (i, j)


def test_touch_matrix_brute_force(rng):
    w, rows = 11, 40
    vb = rng.integers(0, 2, (rows, w)).astype(bool)
    ab = rng.integers(0, 2, (rows, w)).astype(bool)
    mb = bitset.member_bits(w)
    v_words = np.zeros((rows, mb.shape[1]), dtype=np.uint64)
    a_words = np.zeros((rows, mb.shape[1]), dtype=np.uint64)
    for r in range(rows):
        for j in range(w):
            if vb[r, j]:
                v_words[r] |= mb[j]
            if ab[r, j]:
                a_words[r] |= mb[j]
    t = bitset.touch_matrix(v_words, a_words, w)
    for j in range(w):
        exp = np.zeros(mb.shape[1], dtype=np.uint64)
        for r in range(rows):
            if vb[r, j]:
                exp |= a_words[r]
        assert np.array_equal(t[j], exp), j


def test_violation_mask_sides_consistent(rng):
    w = 9
    mb = bitset.member_bits(w)

    def rand_words(rows):
        out = np.zeros((rows, mb.shape[1]), dtype=np.uint64)
        for r in range(rows):
            for j in range(w):
                if rng.integers(0, 2):
                    out[r] |= mb[j]
        return out

    own_rev, own_fwd = rand_words(w), rand_words(w)
    t_rev, t_fwd = rand_words(w), rand_words(w)
    both = bitset.violation_mask(own_rev, own_fwd, t_rev, t_fwd)
    vr, vf = bitset.violation_mask(own_rev, own_fwd, t_rev, t_fwd, sides=True)
    assert np.array_equal(both, vr | vf)
    pref = bitset.prefix_bits(w)
    exp_r = ((own_fwd & pref) & t_rev).any(axis=1)
    exp_f = ((own_rev & pref) & t_fwd).any(axis=1)
    assert np.array_equal(vr, exp_r)
    assert np.array_equal(vf, exp_f)


def test_device_certification_mask_matches_brute_force(rng):
    jax = pytest.importorskip("jax")
    from repro.build.engine_jax import certification_mask

    n, w = 14, 6
    lab_rev = rng.integers(0, 2, (n, w)).astype(bool)
    vis_rev = lab_rev | rng.integers(0, 2, (n, w)).astype(bool)
    lab_fwd = rng.integers(0, 2, (n, w)).astype(bool)
    vis_fwd = lab_fwd | rng.integers(0, 2, (n, w)).astype(bool)
    members = rng.permutation(n)[:w].astype(np.int64)

    got = np.asarray(
        certification_mask(
            *(bitset.pack_bool_rows_u32(m)
              for m in (lab_rev, vis_rev, lab_fwd, vis_fwd)),
            members, w,
        )
    )
    exp = np.zeros(w, dtype=bool)
    for j in range(w):
        for i in range(j):
            rev_hit = lab_fwd[members[j], i] and any(
                vis_rev[r, j] and lab_rev[r, i] for r in range(n))
            fwd_hit = lab_rev[members[j], i] and any(
                vis_fwd[r, j] and lab_fwd[r, i] for r in range(n))
            if rev_hit or fwd_hit:
                exp[j] = True
    assert np.array_equal(got, exp)
