"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + no NaNs. One test per assigned architecture (f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_arch
from repro.data.synth import graph_batch_from_csr, lm_batch, recsys_batch
from repro.graph.generators import random_dag

LM_ARCHS = [a for a in ASSIGNED_ARCHS if get_arch(a).FAMILY == "lm"]
GNN_ARCHS = [a for a in ASSIGNED_ARCHS if get_arch(a).FAMILY == "gnn"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    from repro.models import transformer as tf
    from repro.optim import adamw_init, adamw_update

    mod = get_arch(arch)
    cfg = mod.smoke_config()
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    batch = lm_batch(0, 0, 2, 32, cfg.vocab)
    logits, aux = tf.forward(cfg, params, batch["tokens"])
    assert logits.shape == (2, 32, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())

    loss, grads = jax.value_and_grad(lambda p: tf.lm_loss(cfg, p, batch))(params)
    assert np.isfinite(float(loss))
    opt = adamw_init(params)
    params2, opt, metrics = adamw_update(grads, opt, params, 1e-3)
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert moved


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_decode_matches_forward(arch):
    from repro.models import transformer as tf

    mod = get_arch(arch)
    cfg = mod.smoke_config()
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    logits, _ = tf.forward(cfg, params, toks)
    cache = tf.init_cache(cfg, 2, 16)
    outs = []
    for t in range(16):
        lg, cache = tf.decode_step(cfg, params, cache, toks[:, t : t + 1])
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    err = float(jnp.abs(dec - logits).max())
    assert err < 5e-3, err


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke_train_step(arch):
    from repro.optim import adamw_init, adamw_update

    mod = get_arch(arch)
    cfg = mod.smoke_config()
    g = random_dag(64, 200, seed=0)

    if arch == "gcn-cora":
        from repro.models.gnn import gcn as model

        batch = graph_batch_from_csr(g, cfg.d_in, n_classes=cfg.n_classes)
        loss_fn = lambda p: model.loss_fn(cfg, p, batch)
        out_fn = lambda p: model.forward(cfg, p, batch)
        out_shape = (64, cfg.n_classes)
    elif arch == "gatedgcn":
        from repro.models.gnn import gatedgcn as model

        batch = graph_batch_from_csr(
            g, cfg.d_in, n_classes=cfg.n_classes, d_edge=cfg.d_edge_in
        )
        loss_fn = lambda p: model.loss_fn(cfg, p, batch)
        out_fn = lambda p: model.forward(cfg, p, batch)
        out_shape = (64, cfg.n_classes)
    elif arch == "schnet":
        from repro.models.gnn import schnet as model

        batch = graph_batch_from_csr(g, 1, with_pos=True)
        batch = batch._replace(y=jnp.float32(2.0))
        loss_fn = lambda p: model.loss_fn(cfg, p, batch)
        out_fn = lambda p: model.forward(cfg, p, batch)
        out_shape = (64, 1)
    else:  # graphcast
        from repro.models.gnn import graphcast as model

        rng = np.random.default_rng(0)
        n_g, n_m = 48, 16
        batch = model.MeshBatch(
            grid_x=jnp.asarray(rng.standard_normal((n_g, cfg.n_vars)).astype(np.float32)),
            g2m_src=jnp.asarray(rng.integers(0, n_g, 96).astype(np.int32)),
            g2m_dst=jnp.asarray(rng.integers(0, n_m, 96).astype(np.int32)),
            mesh_src=jnp.asarray(rng.integers(0, n_m, 64).astype(np.int32)),
            mesh_dst=jnp.asarray(rng.integers(0, n_m, 64).astype(np.int32)),
            m2g_src=jnp.asarray(rng.integers(0, n_m, 96).astype(np.int32)),
            m2g_dst=jnp.asarray(rng.integers(0, n_g, 96).astype(np.int32)),
            target=jnp.asarray(rng.standard_normal((n_g, cfg.n_vars)).astype(np.float32)),
        )
        loss_fn = lambda p: model.loss_fn(cfg, p, batch, n_m)
        out_fn = lambda p: model.forward(cfg, p, batch, n_m)
        out_shape = (n_g, cfg.n_vars)

    params = model.init_params(cfg, jax.random.PRNGKey(0))
    out = out_fn(params)
    assert tuple(out.shape) == out_shape
    assert not bool(jnp.isnan(out).any())
    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    opt = adamw_init(params)
    _, _, metrics = adamw_update(grads, opt, params, 1e-3)
    assert np.isfinite(float(metrics["grad_norm"]))


def test_xdeepfm_smoke():
    from repro.models.recsys import xdeepfm
    from repro.optim import adamw_init, adamw_update

    mod = get_arch("xdeepfm")
    cfg = mod.smoke_config()
    params = xdeepfm.init_params(cfg, jax.random.PRNGKey(0))
    batch = recsys_batch(0, 0, 32, cfg.n_fields, cfg.vocab_per_field)
    logit = xdeepfm.forward(cfg, params, batch["ids"])
    assert logit.shape == (32,)
    assert not bool(jnp.isnan(logit).any())
    loss, grads = jax.value_and_grad(lambda p: xdeepfm.loss_fn(cfg, p, batch))(params)
    assert np.isfinite(float(loss))
    opt = adamw_init(params)
    _, _, m = adamw_update(grads, opt, params, 1e-3)
    assert np.isfinite(float(m["grad_norm"]))
    # retrieval path
    sc = xdeepfm.retrieval_score(
        cfg, params, batch["ids"][:1], jnp.arange(100, dtype=jnp.int32)
    )
    assert sc.shape == (100,)


def test_lm_loss_decreases_short_run():
    """a few steps of training actually reduce loss on structured data."""
    from functools import partial

    from repro.models import transformer as tf
    from repro.optim import adamw_init, adamw_update

    cfg = get_arch("h2o-danube-1.8b").smoke_config()
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(partial(tf.lm_loss, cfg))(params, batch)
        params, opt, _ = adamw_update(grads, opt, params, 3e-3)
        return params, opt, loss

    losses = []
    for s in range(30):
        batch = lm_batch(0, s, 8, 32, cfg.vocab)
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses
