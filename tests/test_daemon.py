"""Serving daemon tests: admission control + shedding, deadline handling,
circuit breaker, pinned-epoch publishes, lifecycle (drain/kill), and the
stats/health surfaces.

Everything runs on small graphs with ``asyncio.run`` directly (no async
test plugin); where wall-clock matters the margins are coarse (a 150ms
injected stall against a 30ms deadline), so the assertions hold under CI
scheduling jitter.
"""
import asyncio
import time
import warnings

import numpy as np
import pytest

from repro.core.api import build_oracle
from repro.dynamic import DynamicOracle, UpdateBatch
from repro.ft import inject
from repro.graph.generators import random_dag
from repro.serve.daemon import (
    CircuitBreaker,
    DaemonConfig,
    ServeDaemon,
    ShedError,
)

G = random_dag(300, 1000, seed=7)


@pytest.fixture(scope="module")
def co():
    return build_oracle(G)


def _queries(rng, k=64):
    return rng.integers(0, G.n, size=(k, 2)).astype(np.int32)


# ------------------------------------------------------------ happy path


def test_roundtrip_answers_match_host_then_drains_clean(co, rng):
    qs = [_queries(rng) for _ in range(5)]
    want = [co.engine.query_batch(q, backend="host") for q in qs]

    async def go():
        daemon = ServeDaemon(co, DaemonConfig(batch_window_ms=1.0))
        await daemon.start()
        got = await asyncio.gather(*(daemon.submit(q) for q in qs))
        stats = await daemon.drain()
        return daemon, got, stats

    daemon, got, stats = asyncio.run(go())
    for w, g_ in zip(want, got):
        assert (w == g_).all()
    assert daemon.state == "stopped"
    assert stats["answered"] == stats["admitted"] == 5 * 64
    assert daemon.health()["ready"] is False
    assert daemon.health()["queue_depth"] == 0


# ------------------------------------------------------------- admission


def test_queue_full_sheds(co, rng):
    async def go():
        daemon = ServeDaemon(co, DaemonConfig(queue_limit=64))
        daemon.state = "ready"   # admission open, batch loop deliberately off
        first = asyncio.ensure_future(daemon.submit(_queries(rng, 64)))
        await asyncio.sleep(0)   # let it enqueue
        with pytest.raises(ShedError) as ei:
            await daemon.submit(_queries(rng, 1))
        first.cancel()
        return ei.value.reason, daemon.counters["shed_queue_full"]

    reason, n = asyncio.run(go())
    assert reason == "queue_full"
    assert n == 1


def test_deadline_budget_sheds_at_admission(co, rng):
    async def go():
        daemon = ServeDaemon(co, DaemonConfig())
        daemon.state = "ready"
        daemon._rate_qps = 50.0   # 64 queries => ~1.3s estimated wait
        with pytest.raises(ShedError) as ei:
            await daemon.submit(_queries(rng, 64), deadline_ms=10.0)
        return ei.value.reason

    assert asyncio.run(go()) == "deadline"


def test_draining_state_sheds(co, rng):
    async def go():
        daemon = ServeDaemon(co, DaemonConfig())
        daemon.state = "draining"
        with pytest.raises(ShedError) as ei:
            await daemon.submit(_queries(rng, 4))
        return ei.value.reason

    assert asyncio.run(go()) == "draining"


def test_expired_in_queue_sheds_at_dispatch(co, rng):
    """A request whose budget dies while an injected stall holds the
    dispatch must shed as ``expired``, never be served late."""
    plan = inject.Injector(latency={"serve.device_dispatch": ([0], 0.15)})

    async def go():
        daemon = ServeDaemon(
            co, DaemonConfig(batch_window_ms=1.0, backend="dense"))
        await daemon.start()
        with inject.active(plan):
            slow = asyncio.ensure_future(
                daemon.submit(_queries(rng), deadline_ms=5000.0))
            await asyncio.sleep(0.03)   # stalled dispatch now in flight
            doomed = asyncio.ensure_future(
                daemon.submit(_queries(rng, 32), deadline_ms=30.0))
            ans = await slow
            with pytest.raises(ShedError) as ei:
                await doomed
        await daemon.drain()
        return ans, ei.value.reason, daemon.counters["shed_expired"]

    ans, reason, n_expired = asyncio.run(go())
    assert ans.shape == (64,)
    assert reason == "expired"
    assert n_expired == 32


# --------------------------------------------------------------- breaker


def test_breaker_unit_lifecycle():
    br = CircuitBreaker(failures=2, backoff_s=1.0, backoff_max_s=4.0)
    assert br.allow_device(0.0)
    br.record(False, 0.0)
    assert br.state == "closed"          # one failure: under threshold
    br.record(False, 0.0)
    assert br.state == "open" and br.trips == 1
    assert not br.allow_device(0.5)      # backoff still running
    assert br.allow_device(1.5)          # elapsed: half_open probe allowed
    br.record(False, 1.5)                # failed probe: reopen, doubled
    assert br.state == "open" and br.backoff == 2.0 and br.trips == 2
    assert br.allow_device(4.0)
    br.record(True, 4.0)                 # healthy probe: closed, full reset
    assert br.state == "closed" and br.backoff == 1.0


def test_consecutive_device_failures_trip_breaker_then_reprobe(co, rng):
    plan = inject.Injector({"serve.device_dispatch": [0, 1]})
    q_check = _queries(rng, 32)
    want = co.engine.query_batch(q_check, backend="host")

    async def go():
        daemon = ServeDaemon(co, DaemonConfig(
            batch_window_ms=1.0, backend="dense", deadline_ms=10_000.0,
            breaker_failures=2, breaker_backoff_ms=60.0))
        await daemon.start()
        rng2 = np.random.default_rng(0)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            with inject.active(plan):
                # two failing dispatches: engine downgrades each to host
                # (answers stay correct), breaker counts and trips
                for _ in range(2):
                    await daemon.submit(_queries(rng2))
                tripped = daemon.breaker.state
                # breaker open: batches route straight to host
                await daemon.submit(_queries(rng2))
                host_batches = daemon.counters["breaker_host_batches"]
                await asyncio.sleep(0.1)   # past the backoff: re-probe
                await daemon.submit(_queries(rng2))
                reprobed = daemon.breaker.state
        await daemon.drain()
        return daemon, tripped, host_batches, reprobed

    daemon, tripped, host_batches, reprobed = asyncio.run(go())
    assert tripped == "open"
    assert daemon.breaker.trips == 1
    assert host_batches >= 1
    assert reprobed == "closed"          # healthy probe closed it
    assert daemon.engine.degradation["device_to_host"] > 0
    # every answer correct throughout (spot check one fresh batch)
    got = asyncio.run(_one_shot(daemon.target, q_check))
    assert (got == want).all()


async def _one_shot(target, q):
    daemon = ServeDaemon(target, DaemonConfig(batch_window_ms=1.0))
    await daemon.start()
    ans = await daemon.submit(q)
    await daemon.drain()
    return ans


def test_latency_slo_breach_trips_breaker(co, rng):
    plan = inject.Injector(latency={"serve.device_dispatch": ([0], 0.08)})

    async def go():
        daemon = ServeDaemon(co, DaemonConfig(
            batch_window_ms=1.0, backend="dense",
            breaker_failures=1, breaker_slo_ms=20.0))
        await daemon.start()
        with inject.active(plan):
            ans = await daemon.submit(_queries(rng))
        state = daemon.breaker.state
        await daemon.drain()
        return ans, state, daemon.breaker.trips

    ans, state, trips = asyncio.run(go())
    assert ans.shape == (64,)
    assert state == "open" and trips == 1


# ------------------------------------------------- pinned-epoch publishes


def test_publish_pins_epoch_and_new_epoch_serves_after(rng):
    g = random_dag(200, 600, seed=3)
    dyn = DynamicOracle(g)
    q = rng.integers(0, g.n, size=(256, 2)).astype(np.int32)
    want_old = dyn.serve(q)
    topo_edges = [(int(u), int(v)) for u, v in
                  zip(rng.integers(0, g.n // 2, 8),
                      rng.integers(g.n // 2, g.n, 8)) if u != v]
    batch = UpdateBatch.of(inserts=topo_edges)
    plan = inject.Injector(latency={"dynamic.publish": ([0], 0.2)})

    async def go():
        daemon = ServeDaemon(dyn, DaemonConfig(batch_window_ms=1.0,
                                               deadline_ms=10_000.0))
        await daemon.start()
        with inject.active(plan):
            pub = asyncio.ensure_future(daemon.publish(batch))
            await asyncio.sleep(0.05)    # publish pinned + stalled
            assert daemon.health()["publishing"] is True
            during = await daemon.submit(q)
            epoch = await pub
        after = await daemon.submit(q)
        await daemon.drain()
        return daemon, during, after, epoch

    daemon, during, after, epoch = asyncio.run(go())
    # the batch dispatched mid-publish served from the pinned epoch: its
    # verdicts are exactly the pre-publish verdicts
    assert daemon.counters["pinned_epoch_batches"] >= 1
    assert (during == want_old).all()
    assert epoch >= 1
    assert daemon.counters["publishes"] == 1
    ref = DynamicOracle(g)
    ref.apply(batch)
    ref.publish()
    assert (after == ref.serve(q)).all()


# ------------------------------------------------------------- lifecycle


def test_kill_fails_pending_and_closes_admission(co, rng):
    async def go():
        daemon = ServeDaemon(co, DaemonConfig())
        daemon.state = "ready"   # loop off: requests stay queued
        pend = asyncio.ensure_future(daemon.submit(_queries(rng)))
        await asyncio.sleep(0)
        await daemon.kill()
        with pytest.raises(ShedError) as ei:
            await pend
        reason = ei.value.reason
        with pytest.raises(ShedError) as ei2:
            await daemon.submit(_queries(rng, 4))
        return daemon, reason, ei2.value.reason

    daemon, reason, after_reason = asyncio.run(go())
    assert reason == "killed"
    assert daemon.state == "killed"
    assert after_reason == "draining"
    assert daemon.counters["shed_killed"] == 64


# --------------------------------------------------- stats/health surfaces


def test_engine_stats_snapshot_is_consistent_copy(co, rng):
    co.engine.query_batch(_queries(rng), backend="host")
    s = co.engine.stats()
    assert s["backend"] in ("host", "dense", "kernel")
    assert s["last_batch"]["n_queries"] == 64
    # mutating the snapshot must not leak into the engine
    s["degradation"]["searched"] = 10 ** 9
    s["last_batch"]["n_queries"] = -1
    s2 = co.engine.stats()
    assert s2["degradation"]["searched"] != 10 ** 9
    assert s2["last_batch"]["n_queries"] == 64


def test_engine_reset_stats(co, rng):
    qmask = np.ones(co.oracle.n, dtype=bool)
    co.engine.set_quarantine(qmask, None)
    co.engine.query_batch(_queries(rng), backend="host")
    co.engine.set_quarantine(None, None)
    assert co.engine.degradation["searched"] > 0
    co.engine.reset_stats()
    assert all(v == 0 for v in co.engine.degradation.values())
    assert co.engine.stats()["last_batch"] == {}


def test_engine_deadline_degrades_to_host_same_verdicts(co, rng):
    q = _queries(rng, 128)
    want = co.engine.query_batch(q, backend="host")
    got = co.engine.query_batch(q, backend="dense",
                                deadline=time.monotonic() - 1.0)
    assert (got == want).all()
    assert co.engine.last_stats["degraded"]["deadline_to_host"] > 0


def test_health_surfaces_breaker_and_degradation(co, rng):
    async def go():
        daemon = ServeDaemon(co, DaemonConfig())
        await daemon.start()
        await daemon.submit(_queries(rng))
        h = daemon.health()
        await daemon.drain()
        return h

    h = asyncio.run(go())
    assert h["ready"] is True
    assert h["breaker"]["state"] == "closed"
    assert h["counters"]["answered"] == 64
    assert "degradation" in h["engine"]
    assert h["shed_rate"] == 0.0
