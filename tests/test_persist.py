"""Verified persistence: checksummed block snapshots, oracle/epoch save-load
byte-identity, corruption quarantine semantics, and the WAL framing contract
(torn-tail truncation vs mid-log corruption refusal).
"""
import os

import numpy as np
import pytest

from repro.build.engine import build_distribution_labels
from repro.dynamic import DynamicOracle
from repro.ft import inject
from repro.graph.generators import random_dag
from repro.persist import (
    CorruptSnapshotError,
    WriteAheadLog,
    load_blocks,
    load_epoch,
    load_oracle,
    save_blocks,
    save_epoch,
    save_oracle,
    snapshot_meta,
)
from repro.persist.wal import KIND_DELETE, KIND_INSERT, KIND_PUBLISH, RECORD_SIZE

ORACLE_FIELDS = ("L_out", "L_in", "out_len", "in_len", "hop_rank")


@pytest.fixture
def oracle():
    return build_distribution_labels(random_dag(130, 420, seed=4), impl="wave")


# ------------------------------------------------------------------ blocks

def test_blocks_round_trip(tmp_path):
    arrays = {"a": np.arange(100, dtype=np.int32).reshape(10, 10),
              "b.00001": np.zeros(0, dtype=np.int64)}
    p = save_blocks(str(tmp_path / "snap"), arrays, {"tag": 7})
    got, meta, bad = load_blocks(p)
    assert bad == [] and meta == {"tag": 7}
    assert got["a"].tobytes() == arrays["a"].tobytes()
    assert got["b.00001"].shape == (0,)
    assert snapshot_meta(p) == {"tag": 7}


def test_blocks_flip_bit_strict_raises_naming_block(tmp_path):
    p = save_blocks(str(tmp_path / "snap"), {"x": np.arange(512)})
    inject.flip_bit(os.path.join(p, "x.npy"), seed=2)
    with pytest.raises(CorruptSnapshotError, match="'x'.*crc mismatch"):
        load_blocks(p)
    with pytest.warns(UserWarning, match="quarantining"):
        got, _, bad = load_blocks(p, strict=False)
    assert bad == ["x"] and got["x"] is None


def test_blocks_manifest_tamper_fatal_even_nonstrict(tmp_path):
    p = save_blocks(str(tmp_path / "snap"), {"x": np.arange(8)})
    mpath = os.path.join(p, "manifest.json")
    with open(mpath) as f:
        txt = f.read()
    with open(mpath, "w") as f:
        f.write(txt.replace('"x.npy"', '"y.npy"'))
    with pytest.raises(CorruptSnapshotError, match="manifest hash mismatch"):
        load_blocks(p, strict=False)


def test_blocks_atomic_crash_before_rename_preserves_previous(tmp_path):
    p = str(tmp_path / "snap")
    save_blocks(p, {"x": np.arange(4)}, {"gen": 1})
    with pytest.raises(inject.SimulatedFailure):
        with inject.active(inject.Injector({"persist.pre_rename": 0})):
            save_blocks(p, {"x": np.arange(9)}, {"gen": 2})
    got, meta, _ = load_blocks(p)
    assert meta == {"gen": 1} and got["x"].shape == (4,)


# ------------------------------------------------------------------ oracle

def test_oracle_save_load_byte_identical(tmp_path, oracle):
    p = save_oracle(str(tmp_path / "oracle"), oracle, row_block=64)
    got = load_oracle(p)
    for f in ORACLE_FIELDS:
        assert getattr(got, f).tobytes() == getattr(oracle, f).tobytes(), f


def test_oracle_corrupt_row_block_quarantines_those_rows(tmp_path, oracle):
    # row_block=64 over n=130 rows -> blocks 00000..00002; corrupt the middle
    p = save_oracle(str(tmp_path / "oracle"), oracle, row_block=64)
    inject.flip_bit(os.path.join(p, "L_out.00001.npy"), seed=1)
    with pytest.raises(CorruptSnapshotError, match="L_out.00001"):
        load_oracle(p)
    with pytest.warns(UserWarning):
        got, report = load_oracle(p, strict=False)
    assert not report.clean and report.bad_blocks == ["L_out.00001"]
    want = np.zeros(oracle.n, dtype=bool)
    want[64:128] = True
    assert np.array_equal(report.quarantine_out, want)
    assert not report.quarantine_in.any()
    # rows outside the quarantine are intact, quarantined rows zero-filled
    assert got.L_out[:64].tobytes() == oracle.L_out[:64].tobytes()
    assert not got.L_out[64:128].any()


def test_oracle_corrupt_len_block_quarantines_whole_side(tmp_path, oracle):
    p = save_oracle(str(tmp_path / "oracle"), oracle)
    inject.flip_bit(os.path.join(p, "in_len.npy"), seed=3)
    with pytest.warns(UserWarning):
        _, report = load_oracle(p, strict=False)
    assert report.quarantine_in.all() and not report.quarantine_out.any()


def test_epoch_save_load_round_trip(tmp_path, rng):
    n = 60
    src, dst = rng.integers(0, n, 170), rng.integers(0, n, 170)
    from repro.graph.csr import from_edges

    dyn = DynamicOracle(from_edges(n, src, dst))
    ep = dyn._epochs[dyn._epoch]
    p = save_epoch(str(tmp_path / "epoch"), ep)
    got = load_epoch(p)
    assert got.epoch == ep.epoch
    assert np.array_equal(got.comp, ep.comp)
    assert np.array_equal(got.level, ep.level)
    for f in ORACLE_FIELDS:
        assert getattr(got.oracle, f).tobytes() == getattr(ep.oracle, f).tobytes()
    # comp corruption is fatal even non-strict: no safe fallback for the map
    inject.flip_bit(os.path.join(p, "comp.npy"), seed=5)
    with pytest.raises(CorruptSnapshotError, match="comp"):
        load_epoch(p, strict=False)


def test_oracle_kind_mismatch_refused(tmp_path):
    p = save_blocks(str(tmp_path / "other"), {"x": np.arange(3)}, {"kind": "zzz"})
    with pytest.raises(CorruptSnapshotError, match="expected a ReachabilityOracle"):
        load_oracle(p)


# --------------------------------------------------------------------- WAL

def test_wal_append_replay_and_seq_filter(tmp_path):
    w = WriteAheadLog(str(tmp_path / "wal.bin"))
    w.append(KIND_INSERT, 1, 2)
    w.append(KIND_DELETE, 3, 4)
    mark_seq = w.publish_marker(epoch=1)
    w.append(KIND_INSERT, 5, 6)
    w.close()

    w2 = WriteAheadLog(str(tmp_path / "wal.bin"))
    recs = w2.replay()
    assert [(r.kind, r.u, r.v) for r in recs] == [
        (KIND_INSERT, 1, 2), (KIND_DELETE, 3, 4),
        (KIND_PUBLISH, 1, -1), (KIND_INSERT, 5, 6)]
    assert [r.seq for r in recs] == [0, 1, 2, 3]
    assert recs[2].is_publish
    tail = w2.replay(after_seq=mark_seq)
    assert [(r.u, r.v) for r in tail] == [(5, 6)]
    assert w2.last_seq == 3  # scan on open recovered the cursor
    w2.close()


def test_wal_torn_tail_truncated_with_warning(tmp_path):
    path = str(tmp_path / "wal.bin")
    w = WriteAheadLog(path)
    w.append(KIND_INSERT, 1, 2)
    w.append(KIND_INSERT, 3, 4)
    w.close()
    with open(path, "r+b") as f:  # crash mid-append: half a record
        f.seek(0, os.SEEK_END)
        f.write(b"\x01garbage")
    with pytest.warns(UserWarning, match="torn tail"):
        w2 = WriteAheadLog(path)
    assert [(r.u, r.v) for r in w2.replay()] == [(1, 2), (3, 4)]
    assert os.path.getsize(path) == 2 * RECORD_SIZE  # tail physically removed
    # the log stays appendable after truncation
    w2.append(KIND_DELETE, 5, 6)
    assert w2.replay()[-1].seq == 2
    w2.close()


def test_wal_mid_log_corruption_refused_loudly(tmp_path):
    path = str(tmp_path / "wal.bin")
    w = WriteAheadLog(path)
    for i in range(4):
        w.append(KIND_INSERT, i, i + 1)
    w.close()
    inject.flip_bit(path, offset=RECORD_SIZE + 3)  # record #1, good ones follow
    with pytest.raises(CorruptSnapshotError, match="mid-log corruption"):
        WriteAheadLog(path)


def test_wal_reset_truncates(tmp_path):
    w = WriteAheadLog(str(tmp_path / "wal.bin"))
    w.append(KIND_INSERT, 1, 2)
    w.reset()
    assert w.last_seq == -1 and w.replay() == []
    assert w.append(KIND_INSERT, 7, 8) == 0
    w.close()
