"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp refs (interpret)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("B,La,Lb", [(7, 8, 8), (64, 24, 16), (300, 64, 48), (1, 128, 128)])
def test_label_intersect_sweep(B, La, Lb, rng):
    a = rng.integers(0, 60, size=(B, La)).astype(np.int32)
    b = rng.integers(0, 60, size=(B, Lb)).astype(np.int32)
    a[rng.random((B, La)) < 0.3] = -1
    b[rng.random((B, Lb)) < 0.3] = -1
    out = np.asarray(ops.label_intersect(jnp.asarray(a), jnp.asarray(b), block_b=64))
    exp = np.asarray(ref.label_intersect_ref(jnp.asarray(a), jnp.asarray(b)))
    assert (out == exp).all()


def test_label_intersect_all_padding(rng):
    a = np.full((16, 8), -1, np.int32)
    b = np.full((16, 8), -1, np.int32)
    out = np.asarray(ops.label_intersect(jnp.asarray(a), jnp.asarray(b), block_b=16))
    assert not out.any()


@pytest.mark.parametrize("n,k,m", [(16, 32, 32), (70, 90, 100), (128, 256, 64)])
def test_bitset_mm_sweep(n, k, m, rng):
    wk, wm = (k + 31) // 32, (m + 31) // 32
    A = rng.integers(0, 2**32, size=(n, wk), dtype=np.uint32)
    X = rng.integers(0, 2**32, size=(k, wm), dtype=np.uint32)
    out = np.asarray(ops.bitset_mm(jnp.asarray(A), jnp.asarray(X), block_n=16, block_k=32, block_w=8))
    exp = np.asarray(ref.bitset_mm_ref(jnp.asarray(A), jnp.asarray(X)))
    assert (out == exp).all()


@pytest.mark.parametrize("r,d,n_src,wm", [(13, 4, 50, 1), (128, 16, 200, 2), (1, 7, 9, 3)])
def test_frontier_or_sweep(r, d, n_src, wm, rng):
    """The packed-frontier ELL OR-gather == a dense per-row OR reference."""
    nbr = rng.integers(0, n_src, size=(r, d)).astype(np.int32)
    nbr[rng.random((r, d)) < 0.35] = -1
    f = rng.integers(0, 2**32, size=(n_src, wm), dtype=np.uint32)
    out = np.asarray(ops.frontier_or(jnp.asarray(nbr), jnp.asarray(f), block_n=16))
    exp = np.zeros((r, wm), dtype=np.uint32)
    for i in range(r):
        for s in range(d):
            if nbr[i, s] != -1:
                exp[i] |= f[nbr[i, s]]
    assert (out == exp).all()


def test_bitset_mm_is_closure_step():
    """one OR-matmul step == one step of transitive closure R |= A.R"""
    from repro.graph.generators import random_dag
    from repro.graph.reach import transitive_closure_bits

    g = random_dag(64, 160, seed=0)
    n = g.n
    words = (n + 31) // 32
    A = np.zeros((n, words), dtype=np.uint32)
    src, dst = g.edges()
    for s, d in zip(src, dst):
        A[s, d >> 5] |= np.uint32(1) << np.uint32(d & 31)
    R = A.copy()
    for _ in range(n.bit_length() + 1):  # repeated squaring-ish iteration
        step = np.asarray(ops.bitset_mm(jnp.asarray(R), jnp.asarray(R)))
        new = R | step
        if (new == R).all():
            break
        R = new
    assert (R == transitive_closure_bits(g)).all()


@pytest.mark.parametrize(
    "B,Hq,Hkv,S,T,D,causal,window",
    [
        (1, 2, 2, 128, 128, 32, True, None),
        (2, 4, 2, 256, 256, 64, True, None),      # GQA
        (1, 4, 1, 128, 128, 64, True, 48),        # MQA + SWA
        (2, 2, 2, 1, 256, 32, True, None),        # decode
        (1, 2, 2, 128, 256, 32, True, None),      # chunked prefill (S < T)
        (1, 2, 2, 128, 128, 32, False, None),     # bidirectional
    ],
)
def test_flash_attention_sweep(B, Hq, Hkv, S, T, D, causal, window, rng):
    q = rng.standard_normal((B, Hq, S, D)).astype(np.float32)
    k = rng.standard_normal((B, Hkv, T, D)).astype(np.float32)
    v = rng.standard_normal((B, Hkv, T, D)).astype(np.float32)
    out = np.asarray(
        ops.flash_attention(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
            causal=causal, window=window, block_q=64, block_k=64,
        )
    )
    exp = np.asarray(
        ref.flash_attention_ref(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=causal, window=window
        )
    )
    np.testing.assert_allclose(out, exp, rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(3)
    q = rng.standard_normal((1, 2, 128, 64)).astype(np.float32)
    k = rng.standard_normal((1, 2, 128, 64)).astype(np.float32)
    v = rng.standard_normal((1, 2, 128, 64)).astype(np.float32)
    qb, kb, vb = (jnp.asarray(x, dtype=jnp.bfloat16) for x in (q, k, v))
    out = np.asarray(ops.flash_attention(qb, kb, vb, causal=True).astype(jnp.float32))
    exp = np.asarray(
        ref.flash_attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=True)
    )
    np.testing.assert_allclose(out, exp, rtol=0.05, atol=0.05)


@pytest.mark.parametrize("n,d,ns,F", [(32, 4, 50, 8), (96, 7, 200, 32), (64, 1, 64, 128)])
def test_ell_spmm_sweep(n, d, ns, F, rng):
    nbr = rng.integers(0, ns, size=(n, d)).astype(np.int32)
    nbr[rng.random((n, d)) < 0.3] = -1
    wgt = rng.standard_normal((n, d)).astype(np.float32)
    x = rng.standard_normal((ns, F)).astype(np.float32)
    out = np.asarray(ops.ell_spmm(jnp.asarray(nbr), jnp.asarray(wgt), jnp.asarray(x), block_n=32))
    exp = np.asarray(ref.ell_spmm_ref(jnp.asarray(nbr), jnp.asarray(wgt), jnp.asarray(x)))
    np.testing.assert_allclose(out, exp, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("V,D,B,bag", [(100, 8, 32, 4), (500, 16, 64, 9), (64, 32, 16, 1)])
def test_embedding_bag_sweep(V, D, B, bag, rng):
    table = rng.standard_normal((V, D)).astype(np.float32)
    idx = rng.integers(0, V, size=(B, bag)).astype(np.int32)
    idx[rng.random((B, bag)) < 0.25] = -1
    out = np.asarray(ops.embedding_bag(jnp.asarray(table), jnp.asarray(idx), block_b=16))
    exp = np.asarray(
        ref.embedding_bag_ref(jnp.asarray(table), jnp.asarray(idx), jnp.asarray(idx >= 0))
    )
    np.testing.assert_allclose(out, exp, rtol=1e-5, atol=1e-5)


def test_serve_step_kernel_path_matches():
    """the oracle serve engine with use_kernel=True equals the jnp path."""
    from repro.core.distribution import distribution_labeling
    from repro.serve.engine import serve_step
    from repro.graph.generators import random_dag

    g = random_dag(120, 320, seed=1)
    o = distribution_labeling(g)
    lo, li = o.device_labels()
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.integers(0, g.n, size=(257, 2)).astype(np.int32))
    a = np.asarray(serve_step(lo, li, q, use_kernel=False))
    b = np.asarray(serve_step(lo, li, q, use_kernel=True))
    assert (a == b).all()
