"""Chaos suite: deterministic fault injection across the oracle lifecycle.

Acceptance properties (ISSUE: crash-safe oracle lifecycle):
  * a build killed at an arbitrary wave/chunk boundary — including the
    worst-case window between a speculative rollback and its replay —
    resumes from the latest checkpoint and finishes BYTE-IDENTICAL to an
    uninterrupted run, on all five test graph families,
  * a crashed ``DurableDynamicOracle`` recovers as snapshot + WAL replay
    and its verdicts agree with a fresh rebuild fed the same updates,
  * a failed publish leaves the previous epoch serving (transactional) and
    stays retryable,
  * a serve-path device failure or corrupt/quarantined label row degrades
    per-query down the ladder — counted, never a wrong verdict.

All injections go through ``repro.ft.inject`` and are addressed by
(site, occurrence-index), so every crash point here is reproducible.
"""
import warnings

import numpy as np
import pytest

from repro.build.engine import build_distribution_labels
from repro.core.api import build_oracle
from repro.dynamic import DurableDynamicOracle, DynamicOracle, UpdateBatch
from repro.ft import inject
from repro.ft.inject import SimulatedFailure
from repro.graph.csr import from_edges
from repro.graph.generators import random_dag
from repro.persist import CorruptSnapshotError, load_oracle, save_oracle
from repro.serve.engine import QueryEngine

from test_build_engine import _assert_identical, _dag_families

pytestmark = pytest.mark.chaos


def _crash_then_resume(g, impl, rules, d, tag):
    """Kill a checkpointed build via ``rules``, rebuild from the same dir,
    and require byte-identity with the uninterrupted build."""
    want = build_distribution_labels(g, impl=impl)
    crashed = False
    try:
        with inject.active(inject.Injector(rules)):
            build_distribution_labels(g, impl=impl, checkpoint_dir=str(d),
                                      checkpoint_every=1)
    except SimulatedFailure:
        crashed = True
    assert crashed, f"{tag}: injection never fired — the test exercised nothing"
    got = build_distribution_labels(g, impl=impl, checkpoint_dir=str(d),
                                    checkpoint_every=1)
    _assert_identical(want, got, tag)
    return got


def test_wave_build_kill_and_resume_all_families(rng, tmp_path):
    for name, g in _dag_families(rng):
        got = _crash_then_resume(g, "wave", {"build.wave": 2},
                                 tmp_path / name, name)
        assert got.build_stats["checkpoint"]["resumed_from"] == 2, name


def test_speculative_build_kill_and_resume_all_families(rng, tmp_path):
    # every family's speculative schedule has at least one optimistic chunk
    for name, g in _dag_families(rng):
        _crash_then_resume(g, "speculative", {"build.chunk": 0},
                           tmp_path / name, name)


def test_speculative_crash_between_rollback_and_replay(rng, tmp_path):
    """The worst-case crash window: the watermark rollback has destroyed the
    optimistic appends but the corrected replay has not landed yet.  The
    checkpoint cursor sits at the previous chunk boundary, so resume replays
    the whole chunk — composed watermark rollback + resume stays exact."""
    for name, g in _dag_families(rng):
        _crash_then_resume(g, "speculative", {"build.spec_replay": 0},
                           tmp_path / name, name)


def test_resume_after_multiple_crashes(tmp_path):
    """Crash, resume, crash later, resume again — checkpoints stack."""
    g = random_dag(300, 1200, seed=7)
    want = build_distribution_labels(g, impl="wave")
    for wave_at in (3, 9):
        with pytest.raises(SimulatedFailure):
            with inject.active(inject.Injector({"build.wave": wave_at})):
                build_distribution_labels(g, impl="wave",
                                          checkpoint_dir=str(tmp_path),
                                          checkpoint_every=1)
    got = build_distribution_labels(g, impl="wave", checkpoint_dir=str(tmp_path),
                                    checkpoint_every=1)
    # occurrence counting restarts on resume, so the second crash lands past
    # wave 9 in absolute terms — the checkpoints still stack monotonically
    assert got.build_stats["checkpoint"]["resumed_from"] >= 9
    _assert_identical(want, got, "double crash")


# ----------------------------------------------------------- dynamic oracle

def _structural_batches(g, rng, k=3, per=8):
    """Update batches with repeats of existing edges deleted and random
    inserts — enough to exercise SCC merges/splits on a cyclic graph."""
    batches = []
    src, dst = g.edges()
    for _ in range(k):
        ins = [(int(rng.integers(0, g.n)), int(rng.integers(0, g.n)))
               for _ in range(per)]
        picks = rng.integers(0, src.shape[0], size=per // 2)
        dels = [(int(src[i]), int(dst[i])) for i in picks]
        batches.append(UpdateBatch.of(
            inserts=[(u, v) for u, v in ins if u != v], deletes=dels))
    return batches


def test_durable_recovery_agrees_with_fresh_rebuild(rng, tmp_path):
    # cyclic input: recovery must restore the incrementally maintained
    # condensation (comp ids), not re-run Tarjan over the final graph
    n = 60
    src, dst = rng.integers(0, n, 170), rng.integers(0, n, 170)
    g = from_edges(n, src, dst)
    batches = _structural_batches(g, rng)

    dur = DurableDynamicOracle(g, state_dir=str(tmp_path))
    dur.apply(batches[0])
    dur.publish()
    dur.apply(batches[1])
    dur.publish()
    dur.apply(batches[2])  # acknowledged, not yet published
    del dur  # crash

    rec = DurableDynamicOracle.recover(str(tmp_path))
    ref = DynamicOracle(g)
    for b in batches:
        ref.apply(b)
    ref.publish()
    # the unpublished tail was WAL-durable: recovery re-publishes it
    assert rec.recovered_records > 0
    q = rng.integers(0, n, size=(2000, 2)).astype(np.int32)
    assert np.array_equal(rec.serve(q), ref.serve(q))


def test_durable_recovery_skips_corrupt_snapshot(rng, tmp_path):
    g = random_dag(50, 150, seed=9)
    dur = DurableDynamicOracle(g, state_dir=str(tmp_path))
    dur.apply(UpdateBatch.of(inserts=[(0, 49), (3, 41)]))
    dur.publish()
    q = rng.integers(0, 50, size=(500, 2)).astype(np.int32)
    want = dur.serve(q)
    del dur
    # corrupt the NEWEST snapshot: recovery must fall back to the previous
    # one and replay the WAL across the gap
    import os
    snaps = sorted(d for d in os.listdir(tmp_path) if d.startswith("snap_"))
    assert len(snaps) == 2
    inject.flip_bit(str(tmp_path / snaps[-1] / "L_out.npy"), seed=2)
    with pytest.warns(UserWarning, match="skipping unusable snapshot"):
        rec = DurableDynamicOracle.recover(str(tmp_path))
    assert np.array_equal(rec.serve(q), want)


def test_publish_is_transactional_and_retryable(rng):
    n = 60
    src, dst = rng.integers(0, n, 170), rng.integers(0, n, 170)
    g = from_edges(n, src, dst)
    dyn = DynamicOracle(g)
    batch = _structural_batches(g, rng, k=1)[0]
    dyn.apply(batch)
    q = rng.integers(0, n, size=(1500, 2)).astype(np.int32)
    before = dyn.serve(q)
    with pytest.raises(SimulatedFailure):
        with inject.active(inject.Injector({"dynamic.publish": 0})):
            dyn.publish()
    # failed publish: epoch unchanged, the old epoch still serves
    assert dyn._epoch == 0
    assert np.array_equal(dyn.serve(q), before)
    # and the publish stays retryable — same result as never having crashed
    assert dyn.publish() == 1
    ref = DynamicOracle(g)
    ref.apply(batch)
    ref.publish()
    assert np.array_equal(dyn.serve(q), ref.serve(q))


# -------------------------------------------------------------- serve ladder

def test_device_failure_degrades_to_host_same_verdicts(rng):
    for name, g in _dag_families(rng):
        co = build_oracle(g, method="distribution", impl="reference")
        q = rng.integers(0, g.n, size=(800, 2)).astype(np.int32)
        want = co.engine.query_batch(q, backend="host")
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            with inject.active(inject.Injector({"serve.device_dispatch": 0})):
                got = co.engine.query_batch(q, backend="dense")
        assert np.array_equal(got, want), name
        assert co.engine.degradation["device_to_host"] > 0, name


def test_corrupt_label_rows_degrade_to_search_same_verdicts(rng, tmp_path):
    """End-to-end acceptance: save, corrupt on disk, load non-strict, serve
    with the load's quarantine masks — every verdict still correct."""
    g = random_dag(130, 420, seed=4)
    oracle = build_distribution_labels(g, impl="wave")
    from repro.serve.prefilter import topo_levels

    p = save_oracle(str(tmp_path / "oracle"), oracle, row_block=64)
    inject.flip_bit(str(tmp_path / "oracle" / "L_out.00001.npy"), seed=1)
    with pytest.raises(CorruptSnapshotError):
        load_oracle(p)  # strict load fails loudly
    with pytest.warns(UserWarning):
        loaded, report = load_oracle(p, strict=False)
    assert not report.clean

    eng = QueryEngine(loaded, backend="host", level=topo_levels(g),
                      fallback_graph=g)
    eng.set_quarantine(report.quarantine_out, report.quarantine_in)
    ref = QueryEngine(oracle, backend="host", level=topo_levels(g))
    q = rng.integers(0, g.n, size=(2500, 2)).astype(np.int32)
    assert np.array_equal(eng.query_batch(q), ref.query_batch(q))
    assert eng.degradation["quarantined"] > 0
    assert eng.degradation["searched"] == eng.degradation["quarantined"]
    # single-query path takes the same ladder
    u = int(np.flatnonzero(report.quarantine_out)[0])
    for v in range(0, g.n, 7):
        assert eng.query(u, v) == ref.query(u, v)


def test_combined_degradation_paths_in_one_batch(rng):
    """All three ladder rungs fire inside ONE batch — quarantined rows go
    to exact search, the device dispatch fails and the rest re-serves on
    the host merge path — across the five graph families, with every
    verdict still matching the clean host path."""
    for name, g in _dag_families(rng):
        co = build_oracle(g)
        q = rng.integers(0, g.n, size=(800, 2)).astype(np.int32)
        want = co.engine.query_batch(q, backend="host")
        qmask = np.zeros(co.oracle.n, dtype=bool)
        qmask[rng.integers(0, co.oracle.n,
                           size=max(co.oracle.n // 4, 1))] = True
        co.engine.set_quarantine(qmask, None)
        co.engine.reset_stats()
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            with inject.active(inject.Injector({"serve.device_dispatch": 0})):
                got = co.engine.query_batch(q, backend="dense")
        co.engine.set_quarantine(None, None)
        deg = co.engine.last_stats["degraded"]   # this one batch's counters
        assert np.array_equal(got, want), name
        assert deg["quarantined"] > 0, name
        assert deg["searched"] > 0, name
        assert deg["device_to_host"] > 0, name


def test_quarantine_truncation_device_failure_one_batch(rng):
    """Every degradation source at once: quarantined rows (PR 7 load
    semantics), budget-TRUNCATED rows (rank-prefix cut at half the full
    label bytes), and an injected device failure — all inside ONE
    ``query_batch``, across the five graph families.  The ladder must
    compose: verdicts agree with BFS ground truth, not merely with another
    label path."""
    from repro.graph.reach import reaches_bit, transitive_closure_bits
    from repro.serve.budget import label_bytes, truncate_store

    total = {"quarantined": 0, "uncertain": 0, "device_to_host": 0,
             "searched": 0}
    for name, g in _dag_families(rng):
        co = build_oracle(g)
        q = rng.integers(0, g.n, size=(700, 2)).astype(np.int32)
        tc = transitive_closure_bits(g)
        want = np.array([u == v or reaches_bit(tc, int(u), int(v))
                         for u, v in q])
        st = truncate_store(co.oracle,
                            budget_bytes=label_bytes(co.oracle) // 2)
        co.engine.set_budget(st)
        qmask = np.zeros(co.oracle.n, dtype=bool)
        qmask[rng.integers(0, co.oracle.n,
                           size=max(co.oracle.n // 4, 1))] = True
        co.engine.set_quarantine(qmask, None)
        co.engine.reset_stats()
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            with inject.active(inject.Injector({"serve.device_dispatch": 0})):
                got = co.engine.query_batch(q, backend="dense")
        deg = co.engine.last_stats["degraded"]   # this one batch's counters
        assert np.array_equal(got, want), name
        assert deg["quarantined"] > 0, name
        assert st.any_truncated, name
        for k in total:
            total[k] += deg[k]
        # single-query path composes the same rungs
        for u, v in q[:40]:
            assert co.engine.query(int(u), int(v)) == (
                u == v or reaches_bit(tc, int(u), int(v))), name
    # each rung fired somewhere across the families (which rung serves a
    # given query depends on the family's truncation/level geometry)
    assert total["quarantined"] > 0
    assert total["uncertain"] > 0
    assert total["device_to_host"] > 0
    assert total["searched"] >= total["quarantined"] + total["uncertain"]


def test_quarantine_cleared_by_refresh(rng):
    g = random_dag(80, 240, seed=6)
    oracle = build_distribution_labels(g, impl="wave")
    eng = QueryEngine(oracle, backend="host", fallback_graph=g)
    eng.set_quarantine(np.ones(g.n, dtype=bool), None)
    q = rng.integers(0, g.n, size=(300, 2)).astype(np.int32)
    eng.query_batch(q)
    assert eng.degradation["searched"] > 0
    eng.refresh(oracle)  # new labels supersede the load-time quarantine
    n0 = eng.degradation["searched"]
    eng.query_batch(q)
    assert eng.degradation["searched"] == n0
