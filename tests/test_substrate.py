"""Training substrate: optimizer, checkpointing, fault tolerance, data."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.data.synth import lm_batch, recsys_batch
from repro.ft import FaultTolerantLoop, SimulatedFailure
from repro.optim import adamw_init, adamw_update, cosine_schedule


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    opt = adamw_init(params)
    target = jnp.array([1.0, 1.0, 1.0])

    @jax.jit
    def step(params, opt):
        loss, g = jax.value_and_grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, opt, _ = adamw_update(g, opt, params, 0.05, weight_decay=0.0)
        return params, opt, loss

    for _ in range(300):
        params, opt, loss = step(params, opt)
    assert float(loss) < 1e-3


def test_cosine_schedule_shape():
    lrs = [float(cosine_schedule(jnp.int32(s), 1.0, warmup=10, total=100)) for s in range(100)]
    assert lrs[0] < lrs[9]          # warmup rises
    assert lrs[10] >= lrs[50] >= lrs[99]  # cosine decays
    assert lrs[99] >= 0.099         # min_frac floor


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": np.arange(10, dtype=np.float32), "b": {"c": np.ones((3, 4))}}
    save_checkpoint(str(tmp_path), 5, tree)
    assert latest_step(str(tmp_path)) == 5
    like = {"a": np.zeros(10, np.float32), "b": {"c": np.zeros((3, 4))}}
    out = restore_checkpoint(str(tmp_path), 5, like)
    np.testing.assert_array_equal(out["a"], tree["a"])
    np.testing.assert_array_equal(out["b"]["c"], tree["b"]["c"])


def test_checkpoint_gc_keeps_latest(tmp_path):
    tree = {"x": np.zeros(4)}
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, tree, keep=2)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [4, 5]


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"x": np.zeros(4)})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), 1, {"x": np.zeros(5)})


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    ck.save(7, {"x": np.arange(5)})
    ck.wait()
    assert latest_step(str(tmp_path)) == 7


def test_fault_tolerant_restart(tmp_path):
    """crash at step 7, restart, final state identical to an uninterrupted run."""

    def make_loop(fail_at):
        @jax.jit
        def step(state, batch):
            return state + jnp.sum(batch), {"s": state}

        return FaultTolerantLoop(
            step_fn=step,
            batch_fn=lambda s: jnp.full((4,), float(s)),
            init_state=jnp.float32(0),
            ckpt_dir=str(tmp_path / "ft"),
            ckpt_every=2,
            fail_at=fail_at,
        )

    loop = make_loop(fail_at=7)
    with pytest.raises(SimulatedFailure):
        loop.run(12)
    # restart (fresh loop object — as a new process would)
    loop2 = make_loop(fail_at=None)
    final = loop2.run(12)
    expected = float(sum(4 * s for s in range(12)))
    assert float(final) == expected
    # resumed from a durable checkpoint (>= step 2). The step-6 save is
    # ASYNC and may legitimately be lost in-flight when the crash lands —
    # recovery correctness is the `final == expected` assert above.
    assert loop2.start_step >= 2


def test_data_determinism_and_restart_safety():
    a = lm_batch(seed=3, step=17, batch=4, seq=16, vocab=101)
    b = lm_batch(seed=3, step=17, batch=4, seq=16, vocab=101)
    c = lm_batch(seed=3, step=18, batch=4, seq=16, vocab=101)
    assert (a["tokens"] == b["tokens"]).all()
    assert not (a["tokens"] == c["tokens"]).all()
    r1 = recsys_batch(1, 5, 8, 10, 100)
    r2 = recsys_batch(1, 5, 8, 10, 100)
    assert (r1["ids"] == r2["ids"]).all()


def test_grad_compression_unbiased_ish():
    """int8 quantized psum approximates the mean within block-quant error."""
    from repro.optim.compression import _dequantize_int8, _quantize_int8

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000).astype(np.float32) * 0.01)
    q, s = _quantize_int8(x, None)
    x2 = _dequantize_int8(q, s, x.shape)
    rel = float(jnp.abs(x2 - x).max() / jnp.abs(x).max())
    assert rel < 0.02
