"""Paper Tables 2/3 (small) & 5/6 (large): query time, equal + random loads.

Reports host-side per-query latency for every method, plus the QueryEngine
batched serve path (the oracle's real serving mode) for DL — swept across
intersection backends with prefilters + length-bucketed batching enabled.

  PYTHONPATH=src python -m benchmarks.query_time --backend dense,kernel
  PYTHONPATH=src python -m benchmarks.query_time --backend all
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import (
    LARGE_DATASETS,
    LARGE_SCALE,
    METHODS,
    SMALL_DATASETS,
    csv_row,
    load_dataset,
)
from repro.graph.reach import sample_query_workload, transitive_closure_bits
from repro.serve import QueryEngine, topo_levels

N_QUERIES_HOST = 2000
N_QUERIES_DEV = 100_000

ENGINE_BACKENDS = ("host", "dense", "kernel")


def _bench_engine(g, idx, ds_tag, out, backends, n_dev=N_QUERIES_DEV):
    """Batched engine serving sweep (the production path) for a DL index."""
    engine = QueryEngine(idx.oracle, level=topo_levels(g), bucketing=True)
    rng = np.random.default_rng(1)
    qd = rng.integers(0, g.n, size=(n_dev, 2)).astype(np.int32)
    for be in backends:
        # warm with the FULL batch: tier tile shapes depend on batch size, so
        # a small warmup would leave per-tier compiles inside the timed region
        engine.query_batch(qd, backend=be)
        t0 = time.perf_counter()
        engine.query_batch(qd, backend=be)
        dt = time.perf_counter() - t0
        tiers = ";".join(f"w{t['width']}x{t['count']}" for t in engine.last_stats["tiers"])
        out(csv_row(
            f"query/{ds_tag}/DL-engine-{be}", dt / n_dev * 1e6,
            f"batch={n_dev};prefiltered={engine.last_stats['n_prefiltered']};tiers={tiers}",
        ))


def _bench_methods(g, queries, methods, ds_tag, out, backends):
    for name in methods:
        builder = METHODS[name][0]
        idx = builder(g)
        t0 = time.perf_counter()
        for u, v in queries:
            idx.query(int(u), int(v))
        dt = time.perf_counter() - t0
        out(csv_row(f"query/{ds_tag}/{name}", dt / len(queries) * 1e6,
                    f"n={g.n};queries={len(queries)}"))
        if name == "DL":
            _bench_engine(g, idx, ds_tag, out, backends)


def run(*, out=print, backends=("dense",)):
    from benchmarks.common import HL_LARGE_OK

    small_methods = ["BFS", "GRAIL", "INTERVAL", "PWAH", "K-REACH", "2HOP", "HL", "DL"]
    large_methods = ["GRAIL", "INTERVAL", "HL", "DL"]

    for table, equal in (("table2_query_equal_small", True), ("table3_query_random_small", False)):
        out(f"# {table} (paper Table {'2' if equal else '3'})")
        out("name,us_per_call,derived")
        for ds in SMALL_DATASETS[:4]:
            g = load_dataset(ds, scale=1.0)
            tc = transitive_closure_bits(g)
            rng = np.random.default_rng(0)
            q, _ = sample_query_workload(g, N_QUERIES_HOST, rng, equal=equal, tc=tc)
            _bench_methods(g, q, small_methods, f"{ds}/{'eq' if equal else 'rnd'}", out, backends)

    out("# table5_6_query_large (paper Tables 5/6; scaled analogues)")
    out("name,us_per_call,derived")
    for ds in LARGE_DATASETS[:3]:
        scale = LARGE_SCALE[ds]
        g = load_dataset(ds, scale=scale)
        rng = np.random.default_rng(0)
        q = rng.integers(0, g.n, size=(N_QUERIES_HOST, 2)).astype(np.int32)
        methods = [m for m in large_methods if m != "HL" or ds in HL_LARGE_OK]
        _bench_methods(g, q, methods, f"{ds}@{scale}/rnd", out, backends)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="dense",
                    help="comma-separated engine backends to sweep, or 'all'")
    args = ap.parse_args()
    backends = ENGINE_BACKENDS if args.backend == "all" else tuple(args.backend.split(","))
    run(backends=backends)


if __name__ == "__main__":
    main()
