"""Paper Tables 2/3 (small) & 5/6 (large): query time, equal + random loads.

Reports host-side per-query latency for every method, plus the DEVICE
batched serve path (the oracle's real serving mode) for DL.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    LARGE_DATASETS,
    LARGE_SCALE,
    METHODS,
    SMALL_DATASETS,
    csv_row,
    load_dataset,
)
from repro.core.query import serve_step
from repro.graph.reach import sample_query_workload, transitive_closure_bits

N_QUERIES_HOST = 2000
N_QUERIES_DEV = 100_000


def _bench_methods(g, queries, methods, ds_tag, out):
    for name in methods:
        builder = METHODS[name][0]
        idx = builder(g)
        t0 = time.perf_counter()
        for u, v in queries:
            idx.query(int(u), int(v))
        dt = time.perf_counter() - t0
        out(csv_row(f"query/{ds_tag}/{name}", dt / len(queries) * 1e6,
                    f"n={g.n};queries={len(queries)}"))
        if name == "DL":
            # device batched serving (the production path)
            lo, li = idx.oracle.device_labels()
            rng = np.random.default_rng(1)
            qd = jnp.asarray(rng.integers(0, g.n, size=(N_QUERIES_DEV, 2), dtype=np.int32))
            serve_step(lo, li, qd[:1024]).block_until_ready()  # compile
            t0 = time.perf_counter()
            serve_step(lo, li, qd).block_until_ready()
            dt = time.perf_counter() - t0
            out(csv_row(f"query/{ds_tag}/DL-device-batch", dt / N_QUERIES_DEV * 1e6,
                        f"batch={N_QUERIES_DEV}"))


def run(*, out=print):
    from benchmarks.common import HL_LARGE_OK

    small_methods = ["BFS", "GRAIL", "INTERVAL", "PWAH", "K-REACH", "2HOP", "HL", "DL"]
    large_methods = ["GRAIL", "INTERVAL", "HL", "DL"]

    for table, equal in (("table2_query_equal_small", True), ("table3_query_random_small", False)):
        out(f"# {table} (paper Table {'2' if equal else '3'})")
        out("name,us_per_call,derived")
        for ds in SMALL_DATASETS[:4]:
            g = load_dataset(ds, scale=1.0)
            tc = transitive_closure_bits(g)
            rng = np.random.default_rng(0)
            q, _ = sample_query_workload(g, N_QUERIES_HOST, rng, equal=equal, tc=tc)
            _bench_methods(g, q, small_methods, f"{ds}/{'eq' if equal else 'rnd'}", out)

    out("# table5_6_query_large (paper Tables 5/6; scaled analogues)")
    out("name,us_per_call,derived")
    for ds in LARGE_DATASETS[:3]:
        scale = LARGE_SCALE[ds]
        g = load_dataset(ds, scale=scale)
        rng = np.random.default_rng(0)
        q = rng.integers(0, g.n, size=(N_QUERIES_HOST, 2)).astype(np.int32)
        methods = [m for m in large_methods if m != "HL" or ds in HL_LARGE_OK]
        _bench_methods(g, q, methods, f"{ds}@{scale}/rnd", out)


if __name__ == "__main__":
    run()
