"""Benchmark driver — one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only query_time

Prints ``name,us_per_call,derived`` CSV sections.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--only",
        default=None,
        choices=[None, "query_time", "construction_time", "index_size", "kernel_bench"],
    )
    args = ap.parse_args()

    from benchmarks import construction_time, index_size, kernel_bench, query_time

    sections = {
        "kernel_bench": kernel_bench.run,
        "index_size": index_size.run,
        "construction_time": construction_time.run,
        "query_time": query_time.run,
    }
    flushing = lambda s: print(s, flush=True)
    t0 = time.perf_counter()
    for name, fn in sections.items():
        if args.only and name != args.only:
            continue
        print(f"\n## section: {name}", flush=True)
        fn(out=flushing)
    print(f"\n## total_bench_seconds,{time.perf_counter() - t0:.1f},", flush=True)


if __name__ == "__main__":
    main()
