"""Benchmark driver — one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only query_time
  PYTHONPATH=src python -m benchmarks.run --quick    # smoke mode

Prints ``name,us_per_call,derived`` CSV sections.  The construction section
also writes machine-readable ``BENCH_build.json`` (see
benchmarks/construction_time.py); ``--quick`` runs a one-dataset smoke of
the construction section (JSON goes to BENCH_build_quick.json so the
tracked full-grid record is never clobbered) so CI can exercise the
harness in seconds, while the full sweep remains this one command.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--only",
        default=None,
        choices=[None, "query_time", "construction_time", "index_size",
                 "kernel_bench", "serve_smoke", "obs_overhead"],
    )
    ap.add_argument("--quick", action="store_true",
                    help="smoke mode: construction section only, tiny dataset")
    ap.add_argument("--ci", action="store_true",
                    help="medium-cost CI tier: construction section on one "
                         "mid-size dataset at best-of-4 (so --check-monotone "
                         "gates the engine speedup RATIO; single-rep quick "
                         "rows are too noisy for that) plus a few-second "
                         "open-loop serving-daemon smoke with an injected "
                         "device fault (gated via the serve invariants)")
    ap.add_argument("--json-out", default=None,
                    help="where the construction section writes its JSON record "
                         "(default: BENCH_build.json, BENCH_build_quick.json "
                         "in --quick mode, BENCH_build_ci.json in --ci mode)")
    ap.add_argument("--check-monotone", action="store_true",
                    help="after the run, diff the fresh construction record "
                         "against the committed BENCH trajectory and exit "
                         "nonzero on a >10%% regression (index size growth, "
                         "engine-speedup drop, lost byte-identity, or recorded "
                         "serve sample errors)")
    args = ap.parse_args()
    if args.json_out is None:
        args.json_out = ("BENCH_build_ci.json" if args.ci
                         else "BENCH_build_quick.json" if args.quick
                         else "BENCH_build.json")

    from benchmarks import (
        construction_time,
        index_size,
        kernel_bench,
        obs_overhead,
        query_time,
        serve_sweep,
    )
    from benchmarks.common import check_monotone, load_trajectory

    # snapshot the committed trajectory before any section overwrites it
    trajectory = load_trajectory() if args.check_monotone else None

    serve_ci_json = "BENCH_serve_ci.json"
    sections = {
        "kernel_bench": kernel_bench.run,
        "index_size": index_size.run,
        "construction_time": lambda *, out: construction_time.run(
            out=out, quick=args.quick, ci=args.ci, json_out=args.json_out
        ),
        "query_time": query_time.run,
        "serve_smoke": lambda *, out: serve_sweep.ci_smoke(
            json_out=serve_ci_json, out=out),
        "obs_overhead": lambda *, out: obs_overhead.run(
            out=out, quick=args.quick, ci=args.ci),
    }
    if (args.quick or args.ci) and not args.only:
        # the CI tier adds the open-loop daemon smoke (faulted + clean) so
        # overload robustness is gated per push, not just when the full
        # serve benchmark is regenerated
        sections = {"construction_time": sections["construction_time"]}
        if args.ci:
            sections["serve_smoke"] = lambda *, out: serve_sweep.ci_smoke(
                json_out=serve_ci_json, out=out)
    flushing = lambda s: print(s, flush=True)
    t0 = time.perf_counter()
    ran = set()
    gate_failures = []
    for name, fn in sections.items():
        if args.only and name != args.only:
            continue
        print(f"\n## section: {name}", flush=True)
        result = fn(out=flushing)
        if isinstance(result, dict) and result.get("gate_failed"):
            gate_failures.append(name)
        ran.add(name)
    print(f"\n## total_bench_seconds,{time.perf_counter() - t0:.1f},", flush=True)
    if gate_failures:
        raise SystemExit(f"section gate failed: {', '.join(gate_failures)}")

    if args.check_monotone:
        if "construction_time" not in ran:
            # without a fresh record the diff would compare the committed
            # baseline against itself and pass vacuously
            raise SystemExit(
                "--check-monotone: the construction section did not run "
                f"(sections ran: {sorted(ran)}); drop --only")
        regressions = check_monotone(
            args.json_out, trajectory,
            serve_fresh_path=(serve_ci_json if "serve_smoke" in ran else None),
            out=flushing)
        if regressions:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
