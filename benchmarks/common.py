"""Shared benchmark plumbing.

Method registry (every §6 column) + dataset registry (paper Table 1
analogues, large ones scaled so the whole harness stays CPU-tractable; the
--scale flag raises them toward full size on real hardware).
"""
from __future__ import annotations

import time
from typing import Callable, Dict

import numpy as np

from repro.core.baselines import (
    Grail,
    IntervalTC,
    KReach,
    OnlineBFS,
    PWAHBitvector,
    TwoHopSetCover,
)
from repro.core.distribution import distribution_labeling
from repro.core.hierarchy import hierarchical_labeling
from repro.graph.generators import paper_dataset_analogue


class _OracleIndex:
    """Adapter: ReachabilityOracle -> baseline duck-type."""

    def __init__(self, oracle, name):
        self.oracle = oracle
        self.name = name

    @property
    def index_size_ints(self):
        return self.oracle.total_label_size

    def query(self, u, v):
        if u == v:
            return True
        return self.oracle.query(u, v)


def build_hl(g):
    return _OracleIndex(hierarchical_labeling(g, core_max=512), "HL")


def build_dl(g):
    """DL through the construction engine (impl='auto': wave where it pays)."""
    return _OracleIndex(distribution_labeling(g), "DL")


def build_dl_ref(g):
    """DL through the seed scalar reference builder (the engine's baseline)."""
    return _OracleIndex(distribution_labeling(g, impl="reference"), "DL-ref")


# name -> (builder, scales_to_large)
METHODS: Dict[str, tuple] = {
    "BFS": (OnlineBFS, True),
    "GRAIL": (Grail, True),
    "INTERVAL": (IntervalTC, True),
    "PWAH": (PWAHBitvector, False),   # dense TC rows: small/medium only
    "K-REACH": (KReach, False),
    "2HOP": (TwoHopSetCover, False),
    "HL": (build_hl, True),
    "DL": (build_dl, True),
    "DL-ref": (build_dl_ref, True),
}

SMALL_DATASETS = ["amaze", "kegg", "nasa", "reactome", "xmark", "hpycyc"]
LARGE_DATASETS = ["citeseer", "mapped_100K", "uniprotenc_22m", "citeseerx", "cit-Patents"]

# CPU-tractable default scales for the large analogues
LARGE_SCALE = {
    "citeseer": 0.05,
    "mapped_100K": 0.02,
    "uniprotenc_22m": 0.03,
    "citeseerx": 0.005,
    "cit-Patents": 0.005,
}

# HL's FastCover tracks covered 2-hop pairs explicitly; on hub-heavy graphs
# (layered/citation analogues) the pair set explodes — the paper's HL also
# fails on citeseerx/cit-Patents (Table 7 dashes). Benchmarks run HL on the
# large graphs only where its backbone stays tractable.
HL_LARGE_OK = {"uniprotenc_22m", "mapped_100K", "citeseer"}


def load_dataset(name: str, scale: float = 1.0):
    return paper_dataset_analogue(name, scale=scale)


def time_once(fn: Callable) -> tuple:
    t0 = time.perf_counter()
    out = fn()
    return time.perf_counter() - t0, out


def time_queries(idx, queries: np.ndarray) -> float:
    """total seconds for the batch of (u, v) host queries."""
    t0 = time.perf_counter()
    for u, v in queries:
        idx.query(int(u), int(v))
    return time.perf_counter() - t0


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.3f},{derived}"


# ---------------------------------------------------------------------------
# trajectory regression gate (benchmarks/run.py --check-monotone)
# ---------------------------------------------------------------------------

# earlier files win on key overlap: the full-grid record is the
# highest-quality baseline, the ci/quick tiers cover keys only they track
MONOTONE_TRAJECTORY_FILES = (
    "BENCH_build.json", "BENCH_build_ci.json", "BENCH_build_quick.json",
)


def load_trajectory(paths=MONOTONE_TRAJECTORY_FILES) -> dict:
    """Snapshot the committed per-dataset records BEFORE a run overwrites
    them.  Returns dataset-key -> committed entry."""
    import json
    import os

    committed = {}
    for p in paths:
        if not os.path.exists(p):
            continue
        with open(p) as f:
            payload = json.load(f)
        for key, entry in payload.get("datasets", {}).items():
            committed.setdefault(key, entry)
    return committed


_MISSING = object()


def _field(row: dict, path: str, key: str, origin: str, out) -> object:
    """Guarded dotted-path lookup for BENCH rows.

    Committed trajectory rows can predate schema changes (older sessions
    wrote fewer fields); a raw ``row["engine"]["impl"]`` KeyError would
    abort the whole monotone gate on the first drifted row.  Returns
    ``_MISSING`` after naming the field AND which row (committed vs fresh)
    lacks it, so the caller skips just that check with a warning."""
    cur = row
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            out(f"# WARNING: {origin} row '{key}' has no field '{path}' "
                f"(schema drift) — skipping checks that need it")
            return _MISSING
        cur = cur[part]
    return cur


def _serve_invariants(payload: dict, origin: str, out) -> list:
    """Machine-robust invariants every serve record must satisfy.

    Absolute latency/throughput numbers never gate (they don't transfer
    across hardware); these do, because admission control is precisely the
    mechanism that holds them regardless of machine speed:
      * every backend row and open-loop row answered with zero wrong answers,
      * open-loop p99 of ADMITTED queries stayed inside the deadline (the
        daemon sheds rather than serving late — a violated deadline means
        shedding broke, not that the machine was slow),
      * the device-faulted row actually shed (queue bound + injected stalls
        are sized to force overflow; zero sheds means backpressure is
        disconnected) while still answering some queries,
      * the faulted row's engine ladder saw activity (device->host or
        breaker host batches) — faults that fault nothing gate nothing,
      * the budget frontier (``budget_frontier`` section, when present)
        recorded ZERO wrong answers at every budget point — closed-loop
        rows vs both the full-store verdicts and the BFS truth sample, and
        every budgeted open-loop faulted row — and its uncertain rate is
        monotone non-increasing in budget (the rank-prefix cut's nesting
        property; a violation means the three-valued verdict logic leaked),
        with zero uncertainty at the full budget.
    """
    bad = []
    for be, rec in payload.get("backends", {}).items():
        if rec.get("sample_errors", 0):
            bad.append(f"serve[{origin}/{be}]: {rec['sample_errors']} "
                       f"sample errors recorded")
    for name, row in (payload.get("open_loop") or {}).items():
        where = f"serve[{origin}/open_loop.{name}]"
        if row.get("sample_errors", 0):
            bad.append(f"{where}: {row['sample_errors']} wrong answers")
        if not row.get("answered", 0):
            bad.append(f"{where}: answered no queries at all")
        if not row.get("p99_within_deadline", True):
            bad.append(f"{where}: p99 {row.get('p99_ms')}ms blew the "
                       f"{row.get('deadline_ms')}ms deadline — load shedding "
                       f"failed to protect admitted queries")
        if name == "device_faulted":
            if row.get("shed_rate", 0) <= 0:
                bad.append(f"{where}: zero sheds under forced overload — "
                           f"backpressure is disconnected")
            if row.get("shed_rate", 0) >= 0.9:
                bad.append(f"{where}: shed_rate {row['shed_rate']} — the "
                           f"daemon shed nearly everything")
            deg = row.get("degradation") or {}
            ladder = (deg.get("device_to_host", 0)
                      + row.get("breaker_host_batches", 0))
            if not ladder:
                bad.append(f"{where}: injected device faults produced no "
                           f"ladder activity (device_to_host=0, "
                           f"breaker_host_batches=0)")
    bf = payload.get("budget_frontier")
    if bf:
        rows = sorted(bf.get("rows") or [], key=lambda r: r["budget_bytes"])
        full = bf.get("full_label_bytes", 0)
        prev_rate = None
        for r in rows:
            where = f"serve[{origin}/budget_frontier@{r.get('fraction')}]"
            wrong = r.get("wrong_vs_full", 0) + r.get("sample_errors", 0)
            if wrong:
                bad.append(f"{where}: {wrong} wrong answers under the "
                           f"budget — truncation is supposed to be unable "
                           f"to change a verdict")
            rate = r.get("uncertain_rate", 0.0)
            if prev_rate is not None and rate > prev_rate + 1e-9:
                bad.append(f"{where}: uncertain_rate {rate} EXCEEDS the "
                           f"smaller budget's {prev_rate} — rate must be "
                           f"monotone non-increasing in budget")
            prev_rate = rate
            if r.get("budget_bytes", 0) >= full and r.get("uncertain", 0):
                bad.append(f"{where}: {r['uncertain']} uncertain verdicts "
                           f"at the FULL budget (nothing is truncated)")
        for frac, row in (bf.get("open_loop_faulted") or {}).items():
            where = f"serve[{origin}/budget_frontier.faulted@{frac}]"
            if row.get("sample_errors", 0):
                bad.append(f"{where}: {row['sample_errors']} wrong answers")
            if not row.get("answered", 0):
                bad.append(f"{where}: answered no queries at all")
            if not row.get("p99_within_deadline", True):
                bad.append(f"{where}: p99 {row.get('p99_ms')}ms blew the "
                           f"deadline under the budget")
            budget = row.get("budget") or {}
            if not budget.get("truncated", False):
                bad.append(f"{where}: the budgeted run served an "
                           f"untruncated store — the budget did not bite")
    return bad


def check_monotone(fresh_path: str, trajectory: dict, tol: float = 0.10,
                   ratio_tol: float = 0.25,
                   serve_path: str = "BENCH_serve.json",
                   dynamic_path: str = "BENCH_dynamic.json",
                   serve_fresh_path: str = None, out=print) -> list:
    """Diff a freshly written BENCH_build JSON against the committed
    trajectory; returns the list of regressions (empty = monotone).

    Checks, per dataset key present in both:
      * byte-identity between engine and reference labels must still hold,
      * index size (label ints) must not grow by more than ``tol``,
      * the engine-vs-reference speedup RATIO must not drop by more than
        ``ratio_tol`` — ratios are same-machine normalized, so the gate
        transfers across hardware; absolute seconds are never compared.
        The tolerance is wider than ``tol`` because a ratio divides two
        noisy timings (best-of-N runs still swing ~20% under CI load);
        single-rep (quick / smoke) rows skip the ratio check entirely.
      * rows the auto-dispatch routed to the SPECULATIVE engine (the
        dense-reachability families) additionally gate speedup >= 1.0
        absolutely (reps >= 2 rows only): the speculative path exists to
        crack the dense wall, and a sub-1.0 ratio means the wall silently
        reopened — that floor holds regardless of what the trajectory says.
      * when both records carry a scheduler breakdown (reps >= 2), the
        one-pass scheduler's share of the build must not creep up by more
        than 15 percentage points (an absolute slack — shares are ratios of
        two timings and noisier than the speedup ratio).
      * when both records carry ``engine.stage_shares`` (the obs layer's
        per-stage build attribution), no stage's share of total build time
        may creep by more than 15 points either — the scheduler gate
        generalized to prune gather / label append / certify / replay /
        finalize / checkpoint.
    The fresh record's device_engine rows (sparse device wave engine) gate
    unconditionally on byte-identity — that check is deterministic.
    The committed BENCH_serve.json and BENCH_dynamic.json ride along as
    tripwires: every serve record (backend rows AND open-loop daemon rows)
    must satisfy ``_serve_invariants`` — zero wrong answers, p99 of admitted
    queries inside the deadline, and real shedding + ladder activity in the
    device-faulted row; the dynamic record's rebuild-agreement check must
    show zero mismatches, and its repair-vs-rebuild ratio must stay at or
    above the 5x acceptance bar.  ``serve_fresh_path`` (the CI open-loop
    smoke's just-written record) gets the same invariants plus a shed-rate
    regression gate against the committed faulted row when both ran the
    same workload config.
    """
    import json
    import os

    regressions = []
    with open(fresh_path) as f:
        fresh_all = json.load(f)
    fresh = fresh_all.get("datasets", {})
    compared = 0
    for key, new in fresh.items():
        n_impl = _field(new, "engine.impl", key, "fresh", out)
        n_speed = _field(new, "speedup", key, "fresh", out)
        # absolute dense-wall floor: no committed baseline required
        if (n_impl == "speculative" and new.get("reps", 1) >= 2
                and n_speed is not _MISSING and n_speed < 1.0):
            regressions.append(
                f"{key}: speculative engine fell below the reference builder "
                f"({n_speed:.2f}x < 1.0) — dense-reachability wall reopened")
        if not new.get("labels_match_reference", False):
            regressions.append(f"{key}: engine labels no longer byte-identical")
        old = trajectory.get(key)
        if old is None:
            continue
        compared += 1
        ni = _field(new, "engine.label_ints", key, "fresh", out)
        oi = _field(old, "engine.label_ints", key, "committed", out)
        if ni is not _MISSING and oi is not _MISSING and ni > oi * (1 + tol):
            regressions.append(
                f"{key}: index size regressed {oi} -> {ni} ints (> {tol:.0%})")
        batched = ("wave", "device", "speculative")
        o_impl = _field(old, "engine.impl", key, "committed", out)
        o_speed = _field(old, "speedup", key, "committed", out)
        if (new.get("reps", 1) >= 2 and old.get("reps", 1) >= 2
                and n_impl in batched and o_impl in batched):
            if _MISSING in (n_speed, o_speed):
                ns = os_ = None
            else:
                ns, os_ = n_speed, o_speed
            if ns is not None and ns < os_ * (1 - ratio_tol):
                regressions.append(
                    f"{key}: engine speedup regressed {os_:.2f}x -> {ns:.2f}x "
                    f"(> {ratio_tol:.0%} drop)")
            n_sh = (new.get("scheduler") or {}).get("share_onepass")
            o_sh = (old.get("scheduler") or {}).get("share_onepass")
            if n_sh is not None and o_sh is not None and n_sh > o_sh + 0.15:
                regressions.append(
                    f"{key}: scheduler share regressed {o_sh:.1%} -> {n_sh:.1%} "
                    f"(> 15 points)")
            # generic stage-attribution gate: any build stage's share of
            # total build time creeping > 15 points is a perf regression in
            # that stage even when the end-to-end ratio still passes (the
            # scheduler-share special case above, generalized).  "sweep" is
            # excluded: it is the complement of "schedule", so a scheduler
            # IMPROVEMENT would read as sweep-share creep.  Soft lookups:
            # committed rows predating stage_shares simply skip the gate.
            n_st = (new.get("engine") or {}).get("stage_shares") or {}
            o_st = (old.get("engine") or {}).get("stage_shares") or {}
            for s_name in sorted(set(n_st) & set(o_st) - {"sweep"}):
                if n_st[s_name] > o_st[s_name] + 0.15:
                    regressions.append(
                        f"{key}: build stage '{s_name}' share crept "
                        f"{o_st[s_name]:.1%} -> {n_st[s_name]:.1%} "
                        f"(> 15 points)")
    for key, row in fresh_all.get("device_engine", {}).items():
        if not row.get("labels_match_reference", False):
            regressions.append(
                f"device[{key}]: sparse device engine labels not byte-identical")
    committed_serve = None
    if os.path.exists(serve_path):
        with open(serve_path) as f:
            committed_serve = json.load(f)
        regressions += _serve_invariants(committed_serve, "committed", out)
    if serve_fresh_path is not None and os.path.exists(serve_fresh_path):
        # a freshly produced serve record (the CI open-loop smoke, or a
        # regenerated BENCH_serve.json): same invariants, plus a shed-rate
        # regression gate against the committed faulted row when the two
        # records ran the same workload config
        with open(serve_fresh_path) as f:
            fresh_serve = json.load(f)
        regressions += _serve_invariants(fresh_serve, "fresh", out)
        fr = (fresh_serve.get("open_loop") or {}).get("device_faulted")
        cr = ((committed_serve or {}).get("open_loop") or {}).get(
            "device_faulted")
        if fr and cr:
            same_workload = all(
                fr.get(k) == cr.get(k)
                for k in ("rate_arrivals_per_s", "arrival_batch",
                          "duration_s", "deadline_ms"))
            if same_workload and fr.get("shed_rate", 0) > (
                    cr.get("shed_rate", 0) + 0.25):
                regressions.append(
                    f"serve[open_loop.device_faulted]: shed_rate regressed "
                    f"{cr.get('shed_rate')} -> {fr.get('shed_rate')} "
                    f"(> 0.25 absolute slack) — the daemon now refuses far "
                    f"more of the same workload")
    if os.path.exists(dynamic_path):
        with open(dynamic_path) as f:
            dyn = json.load(f)
        mism = dyn.get("correctness_vs_rebuild", {}).get("mismatches", 0)
        if mism:
            regressions.append(
                f"dynamic: {mism} rebuild-agreement mismatches recorded")
        ratio = dyn.get("repair_vs_rebuild_ratio")
        if ratio is not None and ratio < 5.0:
            regressions.append(
                f"dynamic: repair/rebuild ratio {ratio} fell below the 5x bar")
    out(f"# check-monotone: {compared} dataset(s) compared against the "
        f"committed trajectory, {len(regressions)} regression(s)")
    for r in regressions:
        out(f"# REGRESSION: {r}")
    return regressions
