"""Shared benchmark plumbing.

Method registry (every §6 column) + dataset registry (paper Table 1
analogues, large ones scaled so the whole harness stays CPU-tractable; the
--scale flag raises them toward full size on real hardware).
"""
from __future__ import annotations

import time
from typing import Callable, Dict

import numpy as np

from repro.core.baselines import (
    Grail,
    IntervalTC,
    KReach,
    OnlineBFS,
    PWAHBitvector,
    TwoHopSetCover,
)
from repro.core.distribution import distribution_labeling
from repro.core.hierarchy import hierarchical_labeling
from repro.graph.generators import paper_dataset_analogue


class _OracleIndex:
    """Adapter: ReachabilityOracle -> baseline duck-type."""

    def __init__(self, oracle, name):
        self.oracle = oracle
        self.name = name

    @property
    def index_size_ints(self):
        return self.oracle.total_label_size

    def query(self, u, v):
        if u == v:
            return True
        return self.oracle.query(u, v)


def build_hl(g):
    return _OracleIndex(hierarchical_labeling(g, core_max=512), "HL")


def build_dl(g):
    """DL through the construction engine (impl='auto': wave where it pays)."""
    return _OracleIndex(distribution_labeling(g), "DL")


def build_dl_ref(g):
    """DL through the seed scalar reference builder (the engine's baseline)."""
    return _OracleIndex(distribution_labeling(g, impl="reference"), "DL-ref")


# name -> (builder, scales_to_large)
METHODS: Dict[str, tuple] = {
    "BFS": (OnlineBFS, True),
    "GRAIL": (Grail, True),
    "INTERVAL": (IntervalTC, True),
    "PWAH": (PWAHBitvector, False),   # dense TC rows: small/medium only
    "K-REACH": (KReach, False),
    "2HOP": (TwoHopSetCover, False),
    "HL": (build_hl, True),
    "DL": (build_dl, True),
    "DL-ref": (build_dl_ref, True),
}

SMALL_DATASETS = ["amaze", "kegg", "nasa", "reactome", "xmark", "hpycyc"]
LARGE_DATASETS = ["citeseer", "mapped_100K", "uniprotenc_22m", "citeseerx", "cit-Patents"]

# CPU-tractable default scales for the large analogues
LARGE_SCALE = {
    "citeseer": 0.05,
    "mapped_100K": 0.02,
    "uniprotenc_22m": 0.03,
    "citeseerx": 0.005,
    "cit-Patents": 0.005,
}

# HL's FastCover tracks covered 2-hop pairs explicitly; on hub-heavy graphs
# (layered/citation analogues) the pair set explodes — the paper's HL also
# fails on citeseerx/cit-Patents (Table 7 dashes). Benchmarks run HL on the
# large graphs only where its backbone stays tractable.
HL_LARGE_OK = {"uniprotenc_22m", "mapped_100K", "citeseer"}


def load_dataset(name: str, scale: float = 1.0):
    return paper_dataset_analogue(name, scale=scale)


def time_once(fn: Callable) -> tuple:
    t0 = time.perf_counter()
    out = fn()
    return time.perf_counter() - t0, out


def time_queries(idx, queries: np.ndarray) -> float:
    """total seconds for the batch of (u, v) host queries."""
    t0 = time.perf_counter()
    for u, v in queries:
        idx.query(int(u), int(v))
    return time.perf_counter() - t0


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.3f},{derived}"
