"""Paper Tables 4 & 7: index construction time per method per dataset."""
from __future__ import annotations

from benchmarks.common import (
    HL_LARGE_OK,
    LARGE_DATASETS,
    LARGE_SCALE,
    METHODS,
    SMALL_DATASETS,
    csv_row,
    load_dataset,
    time_once,
)


def run(small_methods=None, large_methods=None, *, out=print):
    out("# table4_construction_small (paper Table 4)")
    out("name,us_per_call,derived")
    for ds in SMALL_DATASETS:
        g = load_dataset(ds, scale=1.0)
        for name, (builder, _) in METHODS.items():
            if name == "BFS":
                continue
            if small_methods and name not in small_methods:
                continue
            try:
                dt, idx = time_once(lambda b=builder: b(g))
                out(csv_row(f"build/{ds}/{name}", dt * 1e6,
                            f"n={g.n};m={g.m};size_ints={idx.index_size_ints}"))
            except MemoryError:
                out(csv_row(f"build/{ds}/{name}", float("nan"), "OOM"))

    out("# table7_construction_large (paper Table 7; scaled analogues)")
    out("name,us_per_call,derived")
    for ds in LARGE_DATASETS:
        scale = LARGE_SCALE[ds]
        g = load_dataset(ds, scale=scale)
        for name in ("GRAIL", "INTERVAL", "HL", "DL"):
            if large_methods and name not in large_methods:
                continue
            if name == "HL" and ds not in HL_LARGE_OK:
                out(csv_row(f"build/{ds}@{scale}/{name}", float("nan"),
                            "skipped(hub-pairs; paper Table 7 also dashes HL here)"))
                continue
            builder = METHODS[name][0]
            try:
                dt, idx = time_once(lambda b=builder: b(g))
                out(csv_row(f"build/{ds}@{scale}/{name}", dt * 1e6,
                            f"n={g.n};m={g.m};size_ints={idx.index_size_ints}"))
            except MemoryError:
                out(csv_row(f"build/{ds}@{scale}/{name}", float("nan"), "OOM"))


if __name__ == "__main__":
    run()
