"""Paper Tables 4 & 7: index construction time per method per dataset.

Besides the CSV rows, emits machine-readable ``BENCH_build.json`` (mirroring
``serve_sweep.py``'s BENCH_serve.json) so the construction-perf trajectory is
tracked PR over PR: per dataset, build seconds / label ints / labels-per-sec
for the wave engine vs the scalar reference builder, plus the byte-identity
check between the two.

  PYTHONPATH=src python -m benchmarks.run --only construction_time
  PYTHONPATH=src python -m benchmarks.build_sweep          # JSON only
  PYTHONPATH=src python -m benchmarks.run --quick          # smoke mode
"""
from __future__ import annotations

import json
import time

from benchmarks.common import (
    HL_LARGE_OK,
    LARGE_DATASETS,
    LARGE_SCALE,
    METHODS,
    SMALL_DATASETS,
    csv_row,
    load_dataset,
    time_once,
)

# (dataset, scale, reps) for the engine-vs-reference record.  Scales are
# chosen so the reference build takes seconds (stable ratios) while the whole
# sweep stays CPU-tractable; citeseerx is the deliberately engine-hostile row
# (dense layered reachability -> tiny waves -> impl="auto" routes to the
# reference builder).
BUILD_COMPARE = [
    ("citeseer", 0.15, 2),
    ("mapped_100K", 0.12, 2),
    ("uniprotenc_22m", 0.03, 2),
    ("uniprotenc_100m", 0.005, 2),
    ("citeseerx", 0.005, 1),
]
BUILD_COMPARE_QUICK = [("citeseer", 0.02, 1)]


def _best_of(fn, reps: int):
    best_dt, out = time_once(fn)
    for _ in range(reps - 1):
        dt, out = time_once(fn)
        best_dt = min(best_dt, dt)
    return best_dt, out


def _engine_vs_reference(out=print, quick: bool = False) -> dict:
    """The tracked record: auto-engine vs scalar reference, same graph."""
    from repro.core.distribution import distribution_labeling

    datasets = {}
    out("# build_engine_vs_reference (-> BENCH_build.json)")
    out("name,us_per_call,derived")
    for ds, scale, reps in (BUILD_COMPARE_QUICK if quick else BUILD_COMPARE):
        g = load_dataset(ds, scale=scale)
        t_ref, o_ref = _best_of(lambda: distribution_labeling(g, impl="reference"), reps)
        t_eng, o_eng = _best_of(lambda: distribution_labeling(g, impl="auto"), reps)
        ints = o_ref.total_label_size
        match = (
            o_ref.L_out.tobytes() == o_eng.L_out.tobytes()
            and o_ref.L_in.tobytes() == o_eng.L_in.tobytes()
        )
        speedup = t_ref / t_eng if t_eng > 0 else float("inf")
        key = f"{ds}@{scale}"
        datasets[key] = {
            "n": g.n,
            "m": g.m,
            "reps": reps,
            "reference": {
                "seconds": round(t_ref, 4),
                "label_ints": ints,
                "labels_per_sec": round(ints / t_ref),
            },
            "engine": {
                "impl": getattr(o_eng, "build_impl", "?"),
                "seconds": round(t_eng, 4),
                "label_ints": o_eng.total_label_size,
                "labels_per_sec": round(o_eng.total_label_size / t_eng),
            },
            "speedup": round(speedup, 3),
            "labels_match_reference": bool(match),
        }
        out(csv_row(
            f"build/{key}/engine-vs-ref", t_eng * 1e6,
            f"ref_s={t_ref:.3f};eng_s={t_eng:.3f};speedup={speedup:.2f}x;"
            f"impl={getattr(o_eng, 'build_impl', '?')};identical={match}",
        ))
    return datasets


def run(small_methods=None, large_methods=None, *, out=print,
        quick: bool = False, json_out: str | None = None):
    t0 = time.time()
    datasets = _engine_vs_reference(out=out, quick=quick)

    out("# table4_construction_small (paper Table 4)")
    out("name,us_per_call,derived")
    small = SMALL_DATASETS[:2] if quick else SMALL_DATASETS
    for ds in small:
        g = load_dataset(ds, scale=1.0)
        for name, (builder, _) in METHODS.items():
            if name == "BFS":
                continue
            if quick and name not in ("DL", "DL-ref", "GRAIL"):
                continue
            if small_methods and name not in small_methods:
                continue
            try:
                dt, idx = time_once(lambda b=builder: b(g))
                out(csv_row(f"build/{ds}/{name}", dt * 1e6,
                            f"n={g.n};m={g.m};size_ints={idx.index_size_ints}"))
            except MemoryError:
                out(csv_row(f"build/{ds}/{name}", float("nan"), "OOM"))

    if not quick:
        out("# table7_construction_large (paper Table 7; scaled analogues)")
        out("name,us_per_call,derived")
        for ds in LARGE_DATASETS:
            scale = LARGE_SCALE[ds]
            g = load_dataset(ds, scale=scale)
            for name in ("GRAIL", "INTERVAL", "HL", "DL"):
                if large_methods and name not in large_methods:
                    continue
                if name == "HL" and ds not in HL_LARGE_OK:
                    out(csv_row(f"build/{ds}@{scale}/{name}", float("nan"),
                                "skipped(hub-pairs; paper Table 7 also dashes HL here)"))
                    continue
                builder = METHODS[name][0]
                try:
                    dt, idx = time_once(lambda b=builder: b(g))
                    out(csv_row(f"build/{ds}@{scale}/{name}", dt * 1e6,
                                f"n={g.n};m={g.m};size_ints={idx.index_size_ints}"))
                except MemoryError:
                    out(csv_row(f"build/{ds}@{scale}/{name}", float("nan"), "OOM"))

    if json_out:
        _write_json(datasets, quick, time.time() - t0, json_out, out=out)


def _write_json(datasets: dict, quick: bool, elapsed: float, json_out: str, out=print):
    import jax

    speedups = {k: v["speedup"] for k, v in datasets.items()
                if v["engine"]["impl"] == "wave"}
    payload = {
        "quick": quick,
        "jax_platform": jax.default_backend(),
        "numpy": __import__("numpy").__version__,
        "note": ("engine impl='auto' picks the wave/bitset builder where "
                 "it pays and the scalar reference otherwise; "
                 "labels are byte-identical either way"),
        "datasets": datasets,
        "speedup_summary": {
            "wave_datasets_ge_3x": sorted(k for k, s in speedups.items() if s >= 3.0),
            "max_wave_speedup": max(speedups.values(), default=None),
            "bench_seconds": round(elapsed, 1),
        },
    }
    with open(json_out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    out(f"# wrote {json_out}")


def _engine_vs_reference_json(json_out: str, quick: bool = False, out=print):
    """JSON-only entry point (benchmarks/build_sweep.py)."""
    t0 = time.time()
    datasets = _engine_vs_reference(out=out, quick=quick)
    _write_json(datasets, quick, time.time() - t0, json_out, out=out)


if __name__ == "__main__":
    run(json_out="BENCH_build.json")
