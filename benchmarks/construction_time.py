"""Paper Tables 4 & 7: index construction time per method per dataset.

Besides the CSV rows, emits machine-readable ``BENCH_build.json`` (mirroring
``serve_sweep.py``'s BENCH_serve.json) so the construction-perf trajectory is
tracked PR over PR: per dataset, build seconds / label ints / labels-per-sec
for the wave engine vs the scalar reference builder, plus the byte-identity
check between the two.

  PYTHONPATH=src python -m benchmarks.run --only construction_time
  PYTHONPATH=src python -m benchmarks.build_sweep          # JSON only
  PYTHONPATH=src python -m benchmarks.run --quick          # smoke mode
"""
from __future__ import annotations

import json
import time

from benchmarks.common import (
    HL_LARGE_OK,
    LARGE_DATASETS,
    LARGE_SCALE,
    METHODS,
    SMALL_DATASETS,
    csv_row,
    load_dataset,
    time_once,
)

# (dataset, scale, reps) for the engine-vs-reference record.  Scales are
# chosen so the reference build takes seconds (stable ratios) while the whole
# sweep stays CPU-tractable; citeseerx and cit-Patents are the dense-
# reachability rows (true conflicts every ~1-2 consecutive ranks -> the exact
# wave scheduler degenerates) that impl="auto" now routes to the SPECULATIVE
# engine — the rows that used to sit below 1.0x against the reference.
BUILD_COMPARE = [
    ("citeseer", 0.15, 2),
    ("mapped_100K", 0.12, 2),
    ("uniprotenc_22m", 0.03, 2),
    ("uniprotenc_100m", 0.005, 2),
    ("citeseerx", 0.005, 1),
    ("cit-Patents", 0.004, 1),
]
BUILD_COMPARE_QUICK = [("citeseer", 0.02, 1)]
# the medium-cost CI tier: one mid-size dataset at best-of-4, so the
# --check-monotone speedup-RATIO gate (which skips single-rep rows as too
# noisy) fires on every PR, not just on full sweeps, plus one dense-
# reachability row (reduced-scale citeseerx analogue) so the speculative
# engine's speedup >= 1.0 floor and byte-identity are gated on every PR —
# the dense wall can never silently reopen.  Scales are deliberately
# distinct from the full grid's rows: each CI key's baseline lives in the
# committed BENCH_build_ci.json, measured at the SAME tier (same reps) it
# is gated at.
BUILD_COMPARE_CI = [("uniprotenc_22m", 0.035, 4), ("citeseerx", 0.002, 2)]

# the sparse device engine column: XLA on CPU hosts runs the same dataflow
# the TPU path compiles, but emulating the per-wave device sweep costs
# ~50-100ms per wave there — so the tracked device rows run at reduced
# scales (byte-identity is still checked on every row; absolute seconds are
# a CPU-emulation floor, not the accelerator story)
DEVICE_COMPARE = [("amaze", 1.0), ("citeseer", 0.005), ("uniprotenc_22m", 0.005)]
DEVICE_COMPARE_QUICK = [("citeseer", 0.002)]


def _best_of(fn, reps: int):
    best_dt, out = time_once(fn)
    for _ in range(reps - 1):
        dt, out = time_once(fn)
        best_dt = min(best_dt, dt)
    return best_dt, out


def _scheduler_breakdown(g, reps: int) -> dict:
    """Scheduler-cost breakdown: one-pass windowed vs per-block closure on
    the same order — the ROADMAP's "scheduler is 20-40% of wave builds"
    claim, tracked instead of anecdotal."""
    import numpy as np

    from repro.build.waves import wave_schedule, wave_schedule_blocked
    from repro.core.order import get_order

    order = np.asarray(get_order(g, "degree_product"), dtype=np.int64)
    t_one, waves_one = _best_of(lambda: wave_schedule(g, order), reps)
    t_blk, waves_blk = _best_of(lambda: wave_schedule_blocked(g, order), reps)
    return {
        "onepass_seconds": round(t_one, 4),
        "blocked_seconds": round(t_blk, 4),
        "n_waves_onepass": int(waves_one.shape[0]),
        "n_waves_blocked": int(waves_blk.shape[0]),
    }


def _engine_vs_reference(out=print, compare=None) -> dict:
    """The tracked record: auto-engine vs scalar reference, same graph."""
    from repro.core.distribution import distribution_labeling

    datasets = {}
    out("# build_engine_vs_reference (-> BENCH_build.json)")
    out("name,us_per_call,derived")
    for ds, scale, reps in (BUILD_COMPARE if compare is None else compare):
        g = load_dataset(ds, scale=scale)
        t_ref, o_ref = _best_of(lambda: distribution_labeling(g, impl="reference"), reps)
        t_eng, o_eng = _best_of(lambda: distribution_labeling(g, impl="auto"), reps)
        ints = o_ref.total_label_size
        match = (
            o_ref.L_out.tobytes() == o_eng.L_out.tobytes()
            and o_ref.L_in.tobytes() == o_eng.L_in.tobytes()
        )
        speedup = t_ref / t_eng if t_eng > 0 else float("inf")
        key = f"{ds}@{scale}"
        stats = getattr(o_eng, "build_stats", {})
        entry = {
            "n": g.n,
            "m": g.m,
            "reps": reps,
            "reference": {
                "seconds": round(t_ref, 4),
                "label_ints": ints,
                "labels_per_sec": round(ints / t_ref),
            },
            "engine": {
                "impl": getattr(o_eng, "build_impl", "?"),
                "seconds": round(t_eng, 4),
                "label_ints": o_eng.total_label_size,
                "labels_per_sec": round(o_eng.total_label_size / t_eng),
                "schedule_seconds": stats.get("schedule_seconds"),
                "sweep_seconds": stats.get("sweep_seconds"),
                # per-stage attribution of the LAST rep (fractions of total
                # build time; within-sweep stages overlap "sweep") — the
                # check-monotone stage-share creep gate reads these
                "stage_shares": stats.get("stage_shares"),
            },
            "speedup": round(speedup, 3),
            "labels_match_reference": bool(match),
        }
        if entry["engine"]["impl"] in ("wave", "device"):
            sched = _scheduler_breakdown(g, reps)
            sweep = stats.get("sweep_seconds") or 0.0
            sched["share_onepass"] = round(
                sched["onepass_seconds"] / max(sched["onepass_seconds"] + sweep, 1e-9), 4)
            sched["share_blocked"] = round(
                sched["blocked_seconds"] / max(sched["blocked_seconds"] + sweep, 1e-9), 4)
            entry["scheduler"] = sched
        spec = stats.get("speculation")
        if spec is not None:
            # the dense-wall record: optimistic chunks attempted, how often
            # certification caught a stale prune set, and what the
            # corrections cost relative to the whole build
            entry["speculation"] = dict(spec)
        datasets[key] = entry
        extra = ""
        if spec is not None:
            extra = f";viol_rate={spec.get('violation_rate')}"
        out(csv_row(
            f"build/{key}/engine-vs-ref", t_eng * 1e6,
            f"ref_s={t_ref:.3f};eng_s={t_eng:.3f};speedup={speedup:.2f}x;"
            f"impl={getattr(o_eng, 'build_impl', '?')};identical={match}{extra}",
        ))
    return datasets


def _device_engine_tier(out=print, quick: bool = False) -> dict:
    """The sparse device engine column: byte-identity + build time at the
    reduced DEVICE_COMPARE scales (see the constant's comment)."""
    from repro.core.distribution import distribution_labeling

    rows = {}
    out("# build_device_engine (sparse device wave engine, XLA expand)")
    out("name,us_per_call,derived")
    for ds, scale in (DEVICE_COMPARE_QUICK if quick else DEVICE_COMPARE):
        g = load_dataset(ds, scale=scale)
        t_ref, o_ref = time_once(lambda: distribution_labeling(g, impl="reference"))
        t_dev, o_dev = time_once(
            lambda: distribution_labeling(g, impl="device", expand="xla")
        )
        match = (
            o_ref.L_out.tobytes() == o_dev.L_out.tobytes()
            and o_ref.L_in.tobytes() == o_dev.L_in.tobytes()
        )
        key = f"{ds}@{scale}"
        rows[key] = {
            "n": g.n,
            "m": g.m,
            "seconds": round(t_dev, 4),
            "reference_seconds": round(t_ref, 4),
            "label_ints": o_dev.total_label_size,
            "labels_match_reference": bool(match),
            "n_waves": getattr(o_dev, "build_stats", {}).get("n_waves"),
        }
        out(csv_row(
            f"build/{key}/device", t_dev * 1e6,
            f"ref_s={t_ref:.3f};dev_s={t_dev:.3f};identical={match}",
        ))
    return rows


def _compare_grid(quick: bool, ci: bool):
    if ci:
        return BUILD_COMPARE_CI
    return BUILD_COMPARE_QUICK if quick else BUILD_COMPARE


def run(small_methods=None, large_methods=None, *, out=print,
        quick: bool = False, ci: bool = False, json_out: str | None = None):
    t0 = time.time()
    datasets = _engine_vs_reference(out=out, compare=_compare_grid(quick, ci))
    device_rows = _device_engine_tier(out=out, quick=quick or ci)
    if ci:
        # the CI tier is the engine-vs-reference ratio + device identity
        # only; the paper tables stay on the quick/full paths
        if json_out:
            _write_json(datasets, device_rows, "ci", time.time() - t0, json_out, out=out)
        return

    out("# table4_construction_small (paper Table 4)")
    out("name,us_per_call,derived")
    small = SMALL_DATASETS[:2] if quick else SMALL_DATASETS
    for ds in small:
        g = load_dataset(ds, scale=1.0)
        for name, (builder, _) in METHODS.items():
            if name == "BFS":
                continue
            if quick and name not in ("DL", "DL-ref", "GRAIL"):
                continue
            if small_methods and name not in small_methods:
                continue
            try:
                dt, idx = time_once(lambda b=builder: b(g))
                out(csv_row(f"build/{ds}/{name}", dt * 1e6,
                            f"n={g.n};m={g.m};size_ints={idx.index_size_ints}"))
            except MemoryError:
                out(csv_row(f"build/{ds}/{name}", float("nan"), "OOM"))

    if not quick:
        out("# table7_construction_large (paper Table 7; scaled analogues)")
        out("name,us_per_call,derived")
        for ds in LARGE_DATASETS:
            scale = LARGE_SCALE[ds]
            g = load_dataset(ds, scale=scale)
            for name in ("GRAIL", "INTERVAL", "HL", "DL"):
                if large_methods and name not in large_methods:
                    continue
                if name == "HL" and ds not in HL_LARGE_OK:
                    out(csv_row(f"build/{ds}@{scale}/{name}", float("nan"),
                                "skipped(hub-pairs; paper Table 7 also dashes HL here)"))
                    continue
                builder = METHODS[name][0]
                try:
                    dt, idx = time_once(lambda b=builder: b(g))
                    out(csv_row(f"build/{ds}@{scale}/{name}", dt * 1e6,
                                f"n={g.n};m={g.m};size_ints={idx.index_size_ints}"))
                except MemoryError:
                    out(csv_row(f"build/{ds}@{scale}/{name}", float("nan"), "OOM"))

    if json_out:
        _write_json(datasets, device_rows, "quick" if quick else "full",
                    time.time() - t0, json_out, out=out)


def _write_json(datasets: dict, device_rows: dict, tier: str, elapsed: float,
                json_out: str, out=print):
    import jax

    speedups = {k: v["speedup"] for k, v in datasets.items()
                if v["engine"]["impl"] in ("wave", "device")}
    spec_speedups = {k: v["speedup"] for k, v in datasets.items()
                     if v["engine"]["impl"] == "speculative"}
    payload = {
        "tier": tier,  # full | quick | ci — the records are self-describing
        "jax_platform": jax.default_backend(),
        "numpy": __import__("numpy").__version__,
        "note": ("engine impl='auto' picks the wave/bitset builder (or the "
                 "sparse device engine on accelerators) where it pays, the "
                 "SPECULATIVE engine (optimistic chunks + certification + "
                 "log-based correction) on dense-reachability schedules, and "
                 "the scalar reference otherwise; labels are byte-identical "
                 "every way.  'scheduler' breaks the build into schedule "
                 "vs sweep (one-pass windowed vs per-block closure); "
                 "'speculation' records chunks attempted / violation rate / "
                 "correction cost; 'device_engine' tracks the sparse device "
                 "path at reduced scales (interpret/XLA on CPU hosts)."),
        "datasets": datasets,
        "device_engine": device_rows,
        "speedup_summary": {
            "wave_datasets_ge_3x": sorted(k for k, s in speedups.items() if s >= 3.0),
            "max_wave_speedup": max(speedups.values(), default=None),
            "speculative_datasets_ge_1x": sorted(
                k for k, s in spec_speedups.items() if s >= 1.0),
            "min_speculative_speedup": min(spec_speedups.values(), default=None),
            "bench_seconds": round(elapsed, 1),
        },
    }
    with open(json_out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    out(f"# wrote {json_out}")


def _engine_vs_reference_json(json_out: str, quick: bool = False,
                              ci: bool = False, out=print):
    """JSON-only entry point (benchmarks/build_sweep.py)."""
    t0 = time.time()
    datasets = _engine_vs_reference(out=out, compare=_compare_grid(quick, ci))
    device_rows = _device_engine_tier(out=out, quick=quick or ci)
    tier = "ci" if ci else "quick" if quick else "full"
    _write_json(datasets, device_rows, tier, time.time() - t0, json_out, out=out)


if __name__ == "__main__":
    run(json_out="BENCH_build.json")
