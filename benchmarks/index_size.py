"""Paper Figures 3 & 4: index size (total integers) per method per dataset."""
from __future__ import annotations

from benchmarks.common import (
    LARGE_DATASETS,
    LARGE_SCALE,
    METHODS,
    SMALL_DATASETS,
    csv_row,
    load_dataset,
)


def run(*, out=print):
    out("# fig3_index_size_small (paper Figure 3)")
    out("name,us_per_call,derived")
    for ds in SMALL_DATASETS:
        g = load_dataset(ds, scale=1.0)
        for name in ("GRAIL", "INTERVAL", "PWAH", "K-REACH", "2HOP", "HL", "DL"):
            builder = METHODS[name][0]
            idx = builder(g)
            out(csv_row(f"size/{ds}/{name}", 0.0,
                        f"size_ints={idx.index_size_ints};per_vertex={idx.index_size_ints / g.n:.2f}"))

    out("# fig4_index_size_large (paper Figure 4; scaled analogues)")
    out("name,us_per_call,derived")
    for ds in LARGE_DATASETS[:3]:
        scale = LARGE_SCALE[ds]
        g = load_dataset(ds, scale=scale)
        for name in ("GRAIL", "INTERVAL", "HL", "DL"):
            builder = METHODS[name][0]
            idx = builder(g)
            out(csv_row(f"size/{ds}@{scale}/{name}", 0.0,
                        f"size_ints={idx.index_size_ints};per_vertex={idx.index_size_ints / g.n:.2f}"))


if __name__ == "__main__":
    run()
