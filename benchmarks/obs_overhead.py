"""Observability overhead guard: daemon open-loop qps, obs on vs off.

The unified observability layer rides the daemon's hot path (per-request
counters, trace spans, latency histograms).  This section measures what
that costs where it matters — sustained qps of an OVERLOADED open-loop
daemon run (an under-offered run hides overhead: sustained merely tracks
the arrival rate) — and fails when the enabled layer gives up more than
``OVERHEAD_BUDGET`` (3%) of the disabled baseline's throughput.

Runs alternate disabled/enabled per rep (best-of-``reps`` each side) so a
thermal or noisy-neighbor drift hits both sides symmetrically.

  PYTHONPATH=src python -m benchmarks.run --only obs_overhead
  PYTHONPATH=src python -m benchmarks.obs_overhead           # module direct
"""
from __future__ import annotations

import json

from benchmarks.common import csv_row

# fail when obs-enabled sustained qps drops below (1 - budget) x disabled
OVERHEAD_BUDGET = 0.03

# overload workload: offered qps sits far above what the single-process
# daemon sustains, so sustained qps measures capacity (admission + dispatch
# machinery, where the obs instrumentation lives), not the arrival rate
_WORKLOAD = dict(
    rate_arrivals_per_s=1500.0,
    arrival_batch=64,
    duration_s=1.2,
    deadline_ms=60.0,
    seed=0,
    n_truth=0,
)


def _build_target():
    from repro.core.api import build_oracle
    from repro.graph.generators import random_dag

    g = random_dag(4000, 10000, seed=0)
    return g, build_oracle(g)


def _one_run(co, g) -> float:
    from repro.serve.daemon import DaemonConfig
    from repro.serve.openloop import run_open_loop

    cfg = DaemonConfig(deadline_ms=_WORKLOAD["deadline_ms"])
    rep = run_open_loop(co, g, config=cfg, **_WORKLOAD)
    return float(rep["sustained_qps"])


def run(*, out=print, quick: bool = False, ci: bool = False,
        json_out: str | None = None, reps: int = 3) -> dict:
    from repro import obs

    reps = 1 if quick else reps
    g, co = _build_target()
    out("# obs_overhead (daemon open-loop sustained qps, obs on vs off)")
    out("name,us_per_call,derived")
    _one_run(co, g)  # warm every dispatch shape once, outside the clock
    best = {"off": 0.0, "on": 0.0}
    try:
        for _ in range(reps):
            # disabled first within each pair: a monotone machine slowdown
            # then penalizes the DISABLED side, never flattering obs
            obs.disable()
            best["off"] = max(best["off"], _one_run(co, g))
            obs.enable()
            best["on"] = max(best["on"], _one_run(co, g))
    finally:
        obs.enable()
    overhead = 1.0 - best["on"] / max(best["off"], 1e-9)
    ok = overhead <= OVERHEAD_BUDGET
    record = {
        "qps_disabled": round(best["off"]),
        "qps_enabled": round(best["on"]),
        "overhead": round(overhead, 4),
        "budget": OVERHEAD_BUDGET,
        "reps": reps,
        "workload": dict(_WORKLOAD),
        "pass": bool(ok),
        "gate_failed": not ok,
    }
    out(csv_row(
        "obs_overhead/daemon_openloop", 0.0,
        f"qps_off={record['qps_disabled']};qps_on={record['qps_enabled']};"
        f"overhead={overhead:.1%};budget={OVERHEAD_BUDGET:.0%};"
        f"{'PASS' if ok else 'FAIL'}"))
    if json_out:
        with open(json_out, "w") as f:
            json.dump(record, f, indent=2)
            f.write("\n")
        out(f"# wrote {json_out}")
    if not ok:
        out(f"# FAIL: observability layer costs {overhead:.1%} sustained qps "
            f"(> {OVERHEAD_BUDGET:.0%} budget)")
    return record


if __name__ == "__main__":
    rec = run()
    raise SystemExit(0 if rec["pass"] else 1)
