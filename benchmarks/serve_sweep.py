"""Serving benchmark -> BENCH_serve.json: closed-loop backend sweep +
open-loop daemon rows.

Phase 1 (backends section) runs the QueryEngine over every single-host
backend on the citeseer analogue and records M-qps per backend — the
serving-perf trajectory tracked PR over PR.

Phase 2 (open_loop section) drives the serving daemon with an open-loop
Poisson workload twice: a clean run, and a run with injected device stalls
and hard failures at a deliberately overflowing queue.  The faulted row is
the robustness record: it must show sheds (backpressure engaged), breaker
and ladder activity, p99 of admitted queries inside the deadline, and zero
wrong answers — those invariants are what ``--check-monotone`` gates.

Phase 3 (budget_frontier section) sweeps the memory-budgeted tier: at
25/50/75/100% of the full label bytes it records the index-bytes vs
latency vs uncertain-rate frontier on a deterministic closed-loop query set
(every budget point compared against the full-store verdicts AND a BFS
truth sample), then re-runs the device-faulted open-loop workload under
each non-full budget.  The gates: zero wrong answers at EVERY budget
point, and the uncertain rate monotone non-increasing in budget.

  PYTHONPATH=src python -m benchmarks.serve_sweep
  PYTHONPATH=src python -m benchmarks.serve_sweep --scale 0.05 --n-queries 200000
  PYTHONPATH=src python -m benchmarks.serve_sweep --skip-sweep   # open-loop only
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from repro.core.api import build_oracle
from repro.ft import inject
from repro.graph.generators import paper_dataset_analogue
from repro.launch.serve import main as serve_main
from repro.serve.budget import BudgetController, label_bytes, truncate_store
from repro.serve.daemon import DaemonConfig
from repro.serve.openloop import check_truth, run_open_loop

# the faulted row's fault plan: stalls long enough to overflow the bounded
# queue at the offered rate (so sheds MUST appear), then a consecutive
# failure run long enough to trip the breaker
STALL_OCCURRENCES = list(range(2, 11))
STALL_SECONDS = 0.06
FAIL_OCCURRENCES = [12, 13, 14]

BUDGET_FRACTIONS = (0.25, 0.5, 0.75, 1.0)


def _fault_plan() -> inject.Injector:
    """A FRESH injector per run — occurrence counters live on the plan."""
    return inject.Injector(
        {"serve.device_dispatch": FAIL_OCCURRENCES},
        latency={"serve.device_dispatch": (STALL_OCCURRENCES, STALL_SECONDS)})


def budget_frontier(co, g, *, fractions=BUDGET_FRACTIONS,
                    n_queries: int = 20_000, batch: int = 2048,
                    seed: int = 0, open_loop_base: dict = None,
                    open_loop_config: DaemonConfig = None,
                    out=print) -> dict:
    """Index bytes vs latency vs uncertain-rate frontier for the budgeted
    serving tier (README "Memory budgets").

    Closed-loop rows are deterministic (fixed seed, host backend) so the
    monotone-uncertain gate compares like with like; the per-fraction
    ``open_loop_faulted`` rows re-run the device-faulted Poisson workload
    under each non-full budget — the acceptance record that a daemon under
    ``--budget-mb`` returns zero wrong answers while degraded."""
    engine = co.engine
    full = label_bytes(co.oracle)
    rng = np.random.default_rng(seed)
    q = rng.integers(0, g.n, size=(n_queries, 2)).astype(np.int32)
    engine.set_budget(None)
    want = engine.query_batch(q, backend="host")   # full-store verdicts
    rows = []
    for frac in sorted(fractions):
        budget = int(full * frac)
        st = truncate_store(co.oracle, budget_bytes=budget)
        engine.set_budget(st)
        engine.reset_stats()
        lat_ms = []
        got = np.empty(n_queries, dtype=bool)
        for lo in range(0, n_queries, batch):
            t0 = time.perf_counter()
            got[lo:lo + batch] = engine.query_batch(q[lo:lo + batch],
                                                    backend="host")
            lat_ms.append((time.perf_counter() - t0) * 1e3)
        deg = engine.last_stats["degraded"]
        row = {
            "fraction": frac,
            "budget_bytes": budget,
            "resident_bytes": st.resident_bytes,
            "rank_cut": st.rank_cut,
            "n_truncated_rows": int(st.truncated_out.sum()
                                    + st.truncated_in.sum()),
            "n_queries": n_queries,
            "uncertain": int(deg["uncertain"]),
            "uncertain_rate": round(deg["uncertain"] / n_queries, 6),
            "wrong_vs_full": int((got != want).sum()),
            "sample_errors": check_truth(g, q, got, limit=300),
            "batch_p50_ms": round(float(np.percentile(lat_ms, 50)), 3),
            "batch_p99_ms": round(float(np.percentile(lat_ms, 99)), 3),
        }
        rows.append(row)
        out(f"  budget {frac:>5.0%}: resident {st.resident_bytes}B "
            f"theta={st.rank_cut} uncertain_rate={row['uncertain_rate']} "
            f"wrong={row['wrong_vs_full'] + row['sample_errors']} "
            f"p99/batch {row['batch_p99_ms']}ms")
    engine.set_budget(None)

    faulted = {}
    if open_loop_base is not None:
        for frac in sorted(fractions):
            if frac >= 1.0:
                continue
            out(f"  open-loop faulted run under {frac:.0%} budget")
            ctl = BudgetController(engine, budget_bytes=int(full * frac))
            row = run_open_loop(co, g, **open_loop_base,
                                config=open_loop_config,
                                fault_plan=_fault_plan(), budget_ctl=ctl)
            engine.set_budget(None)
            faulted[f"{frac}"] = row
            out(f"    {row['sustained_qps']} qps, p99 {row['p99_ms']}ms, "
                f"uncertain {row['degradation'].get('uncertain', 0)}, "
                f"errors {row['sample_errors']}")
    return {"full_label_bytes": full, "rows": rows,
            "open_loop_faulted": faulted}


def open_loop_rows(args) -> tuple:
    g = paper_dataset_analogue(args.dataset, scale=args.scale)
    co = build_oracle(g)
    base = dict(rate_arrivals_per_s=args.rate, arrival_batch=args.arrival_batch,
                duration_s=args.duration, deadline_ms=args.deadline_ms,
                seed=args.seed)
    print("open-loop: clean run")
    clean = run_open_loop(co, g, **base)
    print(f"  sustained {clean['sustained_qps']} qps, shed_rate "
          f"{clean['shed_rate']}, p99 {clean['p99_ms']}ms")
    print("open-loop: device-faulted run (stalls + failures, bounded queue)")
    cfg = DaemonConfig(deadline_ms=args.deadline_ms,
                       queue_limit=args.faulted_queue_limit)
    faulted = run_open_loop(co, g, **base, config=cfg,
                            fault_plan=_fault_plan())
    print(f"  sustained {faulted['sustained_qps']} qps, shed_rate "
          f"{faulted['shed_rate']}, p99 {faulted['p99_ms']}ms, breaker trips "
          f"{faulted['breaker']['trips']}, degradation {faulted['degradation']}")
    rows = {"clean": clean, "device_faulted": faulted}
    if args.skip_budget:
        return rows, None
    print("budget frontier: closed-loop sweep + faulted runs per budget")
    # budgeted rows get deadline headroom: the uncertain->search rung is a
    # recorded latency cost, not a shedding failure (see ci_smoke note)
    bbase = dict(base, deadline_ms=args.budget_deadline_ms)
    bcfg = DaemonConfig(deadline_ms=args.budget_deadline_ms,
                        queue_limit=args.faulted_queue_limit)
    frontier = budget_frontier(co, g, n_queries=args.budget_queries,
                               seed=args.seed, open_loop_base=bbase,
                               open_loop_config=bcfg)
    return rows, frontier


def ci_smoke(json_out: str = "BENCH_serve_ci.json", out=print) -> dict:
    """Few-second open-loop daemon smoke for the CI tier: a Poisson run
    with injected device stalls + hard failures over a tight queue bound,
    plus a short clean run.  Writes ``json_out`` in the BENCH_serve schema
    so ``check_monotone(serve_fresh_path=...)`` gates it: sheds must appear,
    the ladder must fire, p99 of admitted queries must hold the deadline,
    and zero wrong answers."""
    from repro.graph.generators import random_dag

    g = random_dag(2000, 6000, seed=0)
    co = build_oracle(g)
    base = dict(rate_arrivals_per_s=300.0, arrival_batch=32,
                deadline_ms=150.0, seed=0, n_truth=150)
    out("serve smoke: clean open-loop run")
    clean = run_open_loop(co, g, duration_s=1.0, **base)
    out(f"serve_smoke_clean,{clean['sustained_qps']},"
        f"shed={clean['shed_rate']} p99={clean['p99_ms']}ms")
    out("serve smoke: device-faulted open-loop run")
    plan = inject.Injector(
        {"serve.device_dispatch": FAIL_OCCURRENCES},
        latency={"serve.device_dispatch": (STALL_OCCURRENCES, STALL_SECONDS)})
    faulted = run_open_loop(
        co, g, duration_s=1.5,
        config=DaemonConfig(deadline_ms=150.0, queue_limit=256),
        fault_plan=plan, **base)
    out(f"serve_smoke_faulted,{faulted['sustained_qps']},"
        f"shed={faulted['shed_rate']} p99={faulted['p99_ms']}ms "
        f"trips={faulted['breaker']['trips']} "
        f"degradation={faulted['degradation']}")
    out("serve smoke: budget frontier (closed loop) + 50%-budget faulted run")
    # the budgeted rows run with deadline headroom: the uncertain rung buys
    # memory with real service time (exact search), and the frontier records
    # that price — the gate is zero wrong answers + monotone uncertainty,
    # not that truncation is latency-free
    bbase = dict(base, deadline_ms=300.0, duration_s=1.0)
    frontier = budget_frontier(
        co, g, fractions=(0.5, 1.0), n_queries=4000, batch=512,
        open_loop_base=bbase,
        open_loop_config=DaemonConfig(deadline_ms=300.0, queue_limit=256),
        out=out)
    payload = {
        "dataset": "random_dag_smoke", "n": g.n, "m": g.m, "mode": "ci_smoke",
        "open_loop": {"clean": clean, "device_faulted": faulted},
        "budget_frontier": frontier,
    }
    with open(json_out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    out(f"# wrote {json_out}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="citeseer")
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--n-queries", type=int, default=100_000)
    ap.add_argument("--batch", type=int, default=4096)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--skip-sweep", action="store_true",
                    help="only refresh the open_loop section")
    ap.add_argument("--skip-open-loop", action="store_true",
                    help="only refresh the backends section")
    # open-loop knobs
    ap.add_argument("--rate", type=float, default=250.0,
                    help="Poisson arrivals/sec")
    ap.add_argument("--arrival-batch", type=int, default=64)
    ap.add_argument("--duration", type=float, default=2.0)
    ap.add_argument("--deadline-ms", type=float, default=150.0)
    ap.add_argument("--faulted-queue-limit", type=int, default=768,
                    help="queue bound for the faulted row; small enough that "
                         "an injected stall overflows it at the offered rate")
    ap.add_argument("--skip-budget", action="store_true",
                    help="skip the budget_frontier section")
    ap.add_argument("--budget-queries", type=int, default=20_000,
                    help="closed-loop query count per budget point")
    ap.add_argument("--budget-deadline-ms", type=float, default=300.0,
                    help="deadline for the budgeted open-loop rows (the "
                         "uncertain->search rung costs real service time)")
    args = ap.parse_args()

    if not args.skip_sweep:
        # phase 1 through the serving driver's sweep mode (it preserves an
        # existing open_loop section when rewriting the JSON)
        sys.argv = [
            "serve_sweep", "--dataset", args.dataset, "--scale", str(args.scale),
            "--n-queries", str(args.n_queries), "--batch", str(args.batch),
            "--seed", str(args.seed), "--backend", "all",
            "--json-out", args.out,
        ]
        serve_main()

    if not args.skip_open_loop:
        rows, frontier = open_loop_rows(args)
        try:
            with open(args.out) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError):
            data = {}
        data["open_loop"] = rows
        if frontier is not None:
            data["budget_frontier"] = frontier
        with open(args.out, "w") as f:
            json.dump(data, f, indent=2)
            f.write("\n")
        print(f"wrote open_loop rows -> {args.out}")
        bad = rows["clean"]["sample_errors"] + rows["device_faulted"]["sample_errors"]
        for row in (frontier or {}).get("rows", []):
            bad += row["wrong_vs_full"] + row["sample_errors"]
        for row in ((frontier or {}).get("open_loop_faulted") or {}).values():
            bad += row["sample_errors"]
        if bad:
            raise SystemExit(f"serve rows recorded {bad} wrong answers")


if __name__ == "__main__":
    main()
