"""Serving benchmark -> BENCH_serve.json: closed-loop backend sweep +
open-loop daemon rows.

Phase 1 (backends section) runs the QueryEngine over every single-host
backend on the citeseer analogue and records M-qps per backend — the
serving-perf trajectory tracked PR over PR.

Phase 2 (open_loop section) drives the serving daemon with an open-loop
Poisson workload twice: a clean run, and a run with injected device stalls
and hard failures at a deliberately overflowing queue.  The faulted row is
the robustness record: it must show sheds (backpressure engaged), breaker
and ladder activity, p99 of admitted queries inside the deadline, and zero
wrong answers — those invariants are what ``--check-monotone`` gates.

  PYTHONPATH=src python -m benchmarks.serve_sweep
  PYTHONPATH=src python -m benchmarks.serve_sweep --scale 0.05 --n-queries 200000
  PYTHONPATH=src python -m benchmarks.serve_sweep --skip-sweep   # open-loop only
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.core.api import build_oracle
from repro.ft import inject
from repro.graph.generators import paper_dataset_analogue
from repro.launch.serve import main as serve_main
from repro.serve.daemon import DaemonConfig
from repro.serve.openloop import run_open_loop

# the faulted row's fault plan: stalls long enough to overflow the bounded
# queue at the offered rate (so sheds MUST appear), then a consecutive
# failure run long enough to trip the breaker
STALL_OCCURRENCES = list(range(2, 11))
STALL_SECONDS = 0.06
FAIL_OCCURRENCES = [12, 13, 14]


def open_loop_rows(args) -> dict:
    g = paper_dataset_analogue(args.dataset, scale=args.scale)
    co = build_oracle(g)
    base = dict(rate_arrivals_per_s=args.rate, arrival_batch=args.arrival_batch,
                duration_s=args.duration, deadline_ms=args.deadline_ms,
                seed=args.seed)
    print("open-loop: clean run")
    clean = run_open_loop(co, g, **base)
    print(f"  sustained {clean['sustained_qps']} qps, shed_rate "
          f"{clean['shed_rate']}, p99 {clean['p99_ms']}ms")
    print("open-loop: device-faulted run (stalls + failures, bounded queue)")
    plan = inject.Injector(
        {"serve.device_dispatch": FAIL_OCCURRENCES},
        latency={"serve.device_dispatch": (STALL_OCCURRENCES, STALL_SECONDS)})
    cfg = DaemonConfig(deadline_ms=args.deadline_ms,
                       queue_limit=args.faulted_queue_limit)
    faulted = run_open_loop(co, g, **base, config=cfg, fault_plan=plan)
    print(f"  sustained {faulted['sustained_qps']} qps, shed_rate "
          f"{faulted['shed_rate']}, p99 {faulted['p99_ms']}ms, breaker trips "
          f"{faulted['breaker']['trips']}, degradation {faulted['degradation']}")
    return {"clean": clean, "device_faulted": faulted}


def ci_smoke(json_out: str = "BENCH_serve_ci.json", out=print) -> dict:
    """Few-second open-loop daemon smoke for the CI tier: a Poisson run
    with injected device stalls + hard failures over a tight queue bound,
    plus a short clean run.  Writes ``json_out`` in the BENCH_serve schema
    so ``check_monotone(serve_fresh_path=...)`` gates it: sheds must appear,
    the ladder must fire, p99 of admitted queries must hold the deadline,
    and zero wrong answers."""
    from repro.graph.generators import random_dag

    g = random_dag(2000, 6000, seed=0)
    co = build_oracle(g)
    base = dict(rate_arrivals_per_s=300.0, arrival_batch=32,
                deadline_ms=150.0, seed=0, n_truth=150)
    out("serve smoke: clean open-loop run")
    clean = run_open_loop(co, g, duration_s=1.0, **base)
    out(f"serve_smoke_clean,{clean['sustained_qps']},"
        f"shed={clean['shed_rate']} p99={clean['p99_ms']}ms")
    out("serve smoke: device-faulted open-loop run")
    plan = inject.Injector(
        {"serve.device_dispatch": FAIL_OCCURRENCES},
        latency={"serve.device_dispatch": (STALL_OCCURRENCES, STALL_SECONDS)})
    faulted = run_open_loop(
        co, g, duration_s=1.5,
        config=DaemonConfig(deadline_ms=150.0, queue_limit=256),
        fault_plan=plan, **base)
    out(f"serve_smoke_faulted,{faulted['sustained_qps']},"
        f"shed={faulted['shed_rate']} p99={faulted['p99_ms']}ms "
        f"trips={faulted['breaker']['trips']} "
        f"degradation={faulted['degradation']}")
    payload = {
        "dataset": "random_dag_smoke", "n": g.n, "m": g.m, "mode": "ci_smoke",
        "open_loop": {"clean": clean, "device_faulted": faulted},
    }
    with open(json_out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    out(f"# wrote {json_out}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="citeseer")
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--n-queries", type=int, default=100_000)
    ap.add_argument("--batch", type=int, default=4096)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--skip-sweep", action="store_true",
                    help="only refresh the open_loop section")
    ap.add_argument("--skip-open-loop", action="store_true",
                    help="only refresh the backends section")
    # open-loop knobs
    ap.add_argument("--rate", type=float, default=250.0,
                    help="Poisson arrivals/sec")
    ap.add_argument("--arrival-batch", type=int, default=64)
    ap.add_argument("--duration", type=float, default=2.0)
    ap.add_argument("--deadline-ms", type=float, default=150.0)
    ap.add_argument("--faulted-queue-limit", type=int, default=768,
                    help="queue bound for the faulted row; small enough that "
                         "an injected stall overflows it at the offered rate")
    args = ap.parse_args()

    if not args.skip_sweep:
        # phase 1 through the serving driver's sweep mode (it preserves an
        # existing open_loop section when rewriting the JSON)
        sys.argv = [
            "serve_sweep", "--dataset", args.dataset, "--scale", str(args.scale),
            "--n-queries", str(args.n_queries), "--batch", str(args.batch),
            "--seed", str(args.seed), "--backend", "all",
            "--json-out", args.out,
        ]
        serve_main()

    if not args.skip_open_loop:
        rows = open_loop_rows(args)
        try:
            with open(args.out) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError):
            data = {}
        data["open_loop"] = rows
        with open(args.out, "w") as f:
            json.dump(data, f, indent=2)
            f.write("\n")
        print(f"wrote open_loop rows -> {args.out}")
        bad = rows["clean"]["sample_errors"] + rows["device_faulted"]["sample_errors"]
        if bad:
            raise SystemExit(f"open-loop rows recorded {bad} wrong answers")


if __name__ == "__main__":
    main()
