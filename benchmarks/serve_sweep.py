"""Backend-sweep serving benchmark -> BENCH_serve.json.

Runs the QueryEngine over every single-host backend on the citeseer analogue
and records M-qps per backend, so the serving-perf trajectory is tracked
PR over PR.

  PYTHONPATH=src python -m benchmarks.serve_sweep
  PYTHONPATH=src python -m benchmarks.serve_sweep --scale 0.05 --n-queries 200000
"""
from __future__ import annotations

import sys

from repro.launch.serve import main

DEFAULTS = [
    "--dataset", "citeseer",
    "--scale", "0.02",
    "--n-queries", "100000",
    "--backend", "all",
    "--json-out", "BENCH_serve.json",
]

if __name__ == "__main__":
    seen = set(a for a in sys.argv[1:] if a.startswith("--"))
    extra = []
    for flag, val in zip(DEFAULTS[::2], DEFAULTS[1::2]):
        if flag not in seen:
            extra += [flag, val]
    sys.argv += extra
    main()
